"""Continuous batcher: bucket requests by executable, pad to size buckets.

Batching policy (the vLLM-style continuous-batching loop, specialized to
transforms where every request in a bucket is the *same* computation):

  * requests are grouped by :func:`repro.serve.request.bucket_key` —
    same compiled executable, so stacking is free at the collective
    level (PR 5: a (B, ...) stack runs the SAME per-stage collective
    count as B=1);
  * a bucket dispatches when it reaches ``max_batch`` or when its oldest
    request has waited ``max_wait_s`` (latency bound under low load);
  * the stacked batch is zero-padded up to the next power of two
    (:func:`padded_size`), so each bucket compiles at most
    ``log2(max_batch) + 1`` distinct batched executables — compile-cache
    hygiene against occupancy diversity.  Padding rows are dead weight
    the collectives carry; occupancy (real / padded) is the efficiency
    metric the bench reports.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Optional, Sequence

import numpy as np

from repro.serve.request import PRIORITY_NORMAL, TransformRequest


def _priority(item) -> int:
    """Priority of a pending item (the service queues ``_Pending``
    wrappers; bare ``TransformRequest``s work too for direct users)."""
    return getattr(getattr(item, "req", item), "priority", PRIORITY_NORMAL)


def _req_id(item) -> int:
    return getattr(getattr(item, "req", item), "req_id", 0)


def padded_size(n: int, max_batch: int) -> int:
    """Next power of two >= n, capped at ``max_batch`` (n <= max_batch)."""
    if n < 1:
        raise ValueError("empty batch")
    if n > max_batch:
        raise ValueError(f"batch of {n} exceeds max_batch={max_batch}")
    p = 1
    while p < n:
        p *= 2
    return min(p, max_batch)


def stack_and_pad(arrays: Sequence[np.ndarray], pad_to: int) -> np.ndarray:
    """Stack host payloads into a (pad_to, ...) batch, zero rows beyond
    ``len(arrays)`` (zeros transform to zeros — dead but harmless)."""
    batch = np.zeros((pad_to,) + tuple(arrays[0].shape), arrays[0].dtype)
    for i, a in enumerate(arrays):
        batch[i] = a
    return batch


@dataclasses.dataclass
class Bucket:
    """Pending same-executable requests awaiting dispatch."""

    key: str
    requests: list = dataclasses.field(default_factory=list)
    t_oldest: float = 0.0
    #: why this bucket dispatched: "full" | "deadline" | "drain"
    #: (set by the pop that releases it; span/metric attribution)
    reason: str = ""

    def add(self, req: TransformRequest, now: float) -> None:
        if not self.requests:
            self.t_oldest = now
        self.requests.append(req)

    def __len__(self) -> int:
        return len(self.requests)


class Batcher:
    """Accumulates requests into per-executable buckets and decides when
    each dispatches.  Not thread-safe by itself — the service's single
    worker thread owns it."""

    def __init__(self, max_batch: int = 8, max_wait_s: float = 0.002):
        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        self.max_batch = max_batch
        self.max_wait_s = max_wait_s
        self._buckets: dict[str, Bucket] = {}

    def add(self, key: str, req: TransformRequest,
            now: Optional[float] = None) -> None:
        now = time.monotonic() if now is None else now
        bucket = self._buckets.get(key)
        if bucket is None:
            bucket = self._buckets[key] = Bucket(key)
        bucket.add(req, now)

    def pop_ready(self, now: Optional[float] = None) -> list[Bucket]:
        """Buckets due for dispatch: full, or oldest request past the
        wait budget.  Popped buckets leave the pending set."""
        now = time.monotonic() if now is None else now
        ready = []
        for b in self._buckets.values():
            if len(b) >= self.max_batch:
                b.reason = "full"
                ready.append(b)
            elif (now - b.t_oldest) >= self.max_wait_s:
                b.reason = "deadline"
                ready.append(b)
        for b in ready:
            del self._buckets[b.key]
        # high-priority buckets dispatch first when several are ready at
        # once (a bucket's priority is its most important request's)
        ready.sort(key=lambda b: min(_priority(r) for r in b.requests))
        return ready

    def shed_lowest(self):
        """Remove and return the least-important pending item: highest
        priority value first, newest arrival (largest req_id) within a
        class — so bounded-queue load shedding evicts the requests whose
        SLO matters least and keeps the oldest of equals (closest to
        dispatch).  None when nothing is pending."""
        worst_b, worst_i, worst_key = None, None, None
        for b in self._buckets.values():
            for i, item in enumerate(b.requests):
                key = (_priority(item), _req_id(item))
                if worst_key is None or key > worst_key:
                    worst_b, worst_i, worst_key = b, i, key
        if worst_b is None:
            return None
        item = worst_b.requests.pop(worst_i)
        if not worst_b.requests:
            del self._buckets[worst_b.key]
        return item

    def pop_all(self) -> list[Bucket]:
        """Drain every pending bucket (shutdown path)."""
        out = list(self._buckets.values())
        for b in out:
            b.reason = "drain"
        self._buckets.clear()
        return out

    def next_deadline(self, now: Optional[float] = None) -> Optional[float]:
        """Seconds until the earliest wait-budget expiry (None = empty);
        the worker uses it as its queue-poll timeout so dispatch never
        oversleeps a latency bound."""
        if not self._buckets:
            return None
        now = time.monotonic() if now is None else now
        expiry = min(b.t_oldest + self.max_wait_s
                     for b in self._buckets.values())
        return max(0.0, expiry - now)

    @property
    def pending(self) -> int:
        # list() snapshots the dict atomically (single C call under the
        # GIL) so stats() can read this while the worker adds buckets
        return sum(len(b) for b in list(self._buckets.values()))
