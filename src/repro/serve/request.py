"""Request/result types for the spectral transform service.

A :class:`TransformRequest` is the service's wire unit: one field (or
spectrum, for inverse requests) plus the problem description that picks
the executable.  Requests carry *host* arrays — like RPC payloads — and
results come back as host arrays, so service latency honestly includes
the H2D/D2H hops a real deployment pays.

Problem classes (ISSUE/ROADMAP item 2):

  "c2c"       complex transform, forward or inverse
  "r2c"       real transform (forward: real field -> half spectrum;
              inverse: half spectrum + the plan's Nz -> real field)
  "filtered"  c2c forward with a fused k-space multiply (the request
              brings its own ``h``; the multiply rides as a schedule
              epilogue inside the same executable)

Two requests may share a batch exactly when every knob that changes the
compiled executable matches — shape, dtype, problem, direction,
filteredness.  :func:`bucket_key` captures that contract; the plan-cache
key (``repro.tuning.wisdom.wisdom_key``) is its plan-selection prefix.
"""

from __future__ import annotations

import dataclasses
import itertools
import time
from typing import Optional

import numpy as np

PROBLEMS = ("c2c", "r2c", "filtered")
DIRECTIONS = ("forward", "inverse")

#: priority classes: lower value = more important.  Load shedding under
#: a bounded queue rejects the highest-valued (least important) pending
#: request first; dispatch ordering prefers lower-valued buckets.
PRIORITY_HIGH, PRIORITY_NORMAL, PRIORITY_LOW = 0, 1, 2
PRIORITIES = (PRIORITY_HIGH, PRIORITY_NORMAL, PRIORITY_LOW)

_ids = itertools.count()


@dataclasses.dataclass
class TransformRequest:
    """One transform request (host payload + problem description)."""

    x: np.ndarray
    problem: str = "c2c"
    direction: str = "forward"
    #: "filtered" only: the k-space filter, shaped like the spectrum
    h: Optional[np.ndarray] = None
    #: global (Nx, Ny, Nz) grid shape; inferred from the payload for
    #: forward requests, REQUIRED for r2c inverse (Nz is ambiguous there)
    shape: Optional[tuple] = None
    #: spectrum dtype the plan computes in
    dtype: np.dtype = np.complex64
    #: priority class (PRIORITY_HIGH/NORMAL/LOW): sheds last/first under
    #: a bounded queue, dispatches first/last among ready buckets
    priority: int = PRIORITY_NORMAL
    #: seconds after submit by which dispatch must start; a request whose
    #: deadline has passed when its batch forms resolves with a typed
    #: ShedResult instead of running (None = no deadline)
    deadline_s: Optional[float] = None
    req_id: int = dataclasses.field(default_factory=lambda: next(_ids))
    t_submit: float = dataclasses.field(default_factory=time.monotonic)

    @property
    def t_deadline(self) -> Optional[float]:
        """Absolute dispatch deadline on the ``time.monotonic()`` clock."""
        return (None if self.deadline_s is None
                else self.t_submit + self.deadline_s)

    def expired(self, now: Optional[float] = None) -> bool:
        td = self.t_deadline
        if td is None:
            return False
        return (time.monotonic() if now is None else now) > td

    def payload_finite(self) -> bool:
        """True when every payload value (x, and h if present) is finite
        — the NaN/Inf isolation predicate, checked only when a batch's
        output came back non-finite (never on the happy path)."""
        if not np.isfinite(self.x).all():
            return False
        return self.h is None or bool(np.isfinite(self.h).all())

    def __post_init__(self):
        if self.problem not in PROBLEMS:
            raise ValueError(f"problem must be one of {PROBLEMS}, "
                             f"got {self.problem!r}")
        if self.direction not in DIRECTIONS:
            raise ValueError(f"direction must be one of {DIRECTIONS}, "
                             f"got {self.direction!r}")
        if self.problem == "filtered":
            if self.direction != "forward":
                raise ValueError("filtered requests are forward-only (the "
                                 "filter fuses into the forward epilogue)")
            if self.h is None:
                raise ValueError("filtered requests need a filter h")
        elif self.h is not None:
            raise ValueError('a filter rides only on problem="filtered"')
        if getattr(self.x, "ndim", None) != 3:
            raise ValueError("request payload must be a rank-3 array "
                             f"(got shape {getattr(self.x, 'shape', None)})")
        if self.shape is None:
            if self.problem == "r2c" and self.direction == "inverse":
                raise ValueError("r2c inverse requests must pass shape= — "
                                 "Nz cannot be inferred from the half "
                                 "spectrum (Nh = Nz//2 + 1 is two-to-one)")
            self.shape = tuple(int(s) for s in self.x.shape)
        else:
            self.shape = tuple(int(s) for s in self.shape)
        if len(self.shape) != 3:
            raise ValueError(f"shape must be 3-D, got {self.shape}")
        self.dtype = np.dtype(self.dtype)
        self.priority = int(self.priority)
        if self.priority < 0:
            raise ValueError(f"priority must be >= 0 (0 = most "
                             f"important), got {self.priority}")
        if self.deadline_s is not None:
            self.deadline_s = float(self.deadline_s)
            if self.deadline_s < 0:
                raise ValueError(f"deadline_s must be >= 0, "
                                 f"got {self.deadline_s}")

    @property
    def plan_problem(self) -> str:
        """The Croft3D problem class serving this request ("filtered" is
        a c2c plan; the filter is an argument, not a different plan)."""
        return "r2c" if self.problem == "r2c" else "c2c"

    def expected_payload_shape(self) -> tuple:
        """What ``x`` must look like for (shape, problem, direction)."""
        nx, ny, nz = self.shape
        if self.problem == "r2c" and self.direction == "inverse":
            return (nx, ny, nz // 2 + 1)
        return self.shape

    def validate_payload(self) -> None:
        """Early shape/dtype validation (raise at submit, not dispatch —
        a malformed request must not poison a whole batch)."""
        expect = self.expected_payload_shape()
        if tuple(self.x.shape) != expect:
            raise ValueError(
                f"payload shape {tuple(self.x.shape)} != expected {expect} "
                f"for {self.problem}/{self.direction} on grid {self.shape}")
        if self.problem == "r2c" and self.direction == "forward":
            if np.iscomplexobj(self.x):
                raise ValueError("r2c forward payload must be real")
        if self.h is not None:
            nx, ny, nz = self.shape
            hshape = (self.shape if self.plan_problem == "c2c"
                      else (nx, ny, nz // 2 + 1))
            if tuple(self.h.shape) != hshape:
                raise ValueError(f"filter shape {tuple(self.h.shape)} != "
                                 f"spectrum shape {hshape}")


def bucket_key(req: TransformRequest, plan_key: str) -> str:
    """Batchability key: requests sharing it run in ONE stacked dispatch.

    ``plan_key`` (the wisdom key: shape|mesh|dtype|backend[|problem])
    already pins shape, spectrum dtype, mesh, and plan problem class; the
    suffix adds the per-request knobs that select a *different executable
    on the same plan* — direction, and whether a fused filter argument is
    present.  Omitting either would silently alias executables (a
    forward batched with an inverse, or a filtered request dropped into
    an unfiltered batch losing its ``h``).
    """
    return f"{plan_key}|{req.direction}" + ("|filt" if req.h is not None
                                            else "")


@dataclasses.dataclass
class TransformResult:
    """What the caller's future resolves to."""

    req_id: int
    value: Optional[np.ndarray]
    ok: bool = True
    error: Optional[str] = None
    #: end-to-end seconds from submit to result materialization
    latency_s: float = 0.0
    #: how many real requests shared the dispatch, and the padded size
    batch_size: int = 1
    padded_size: int = 1
    #: plan provenance: "hit" | "cold" | "warm" (see serve.plan_cache)
    plan_state: str = "hit"
    plan_key: str = ""
    #: lifecycle timestamps on the ``time.monotonic()`` clock (the same
    #: clock spans use): submit -> dispatch (batch formed, device work
    #: starts) -> done (result on host).  0.0 on failure paths.
    t_submit: float = 0.0
    t_dispatch: float = 0.0
    t_done: float = 0.0


@dataclasses.dataclass
class ShedResult(TransformResult):
    """A request the service *rejected* rather than ran — typed so
    clients can tell load shedding from a transform failure and decide
    to retry elsewhere/later.  Futures always resolve (never hang):
    ``ok`` is False, ``value`` is None, and ``shed_reason`` says why:

      "queue-full"  bounded-queue load shedding evicted it (lowest
                    priority class first, newest first within a class)
      "deadline"    its dispatch deadline passed before its batch formed
      "preempted"   the service was draining for preemption/shutdown
    """

    shed_reason: str = ""
