"""repro.serve — plan-cached, continuously batched spectral transforms.

The serving layer over the PR 1-5 stack (ROADMAP item 2): heterogeneous
transform requests (shape x dtype x {c2c, r2c, filtered} x direction)
arrive on an async queue, are bucketed by compiled-executable identity,
stacked into the batched packed pipelines — which PR 5 made free at the
collective level: a (B, ...) stack compiles to the SAME per-stage
collective count as B=1 — and dispatched with donated buffers.

Plan selection is FFTW's planner-in-production: the first request of a
problem key pays only ``mode="wisdom"``/``"model"`` (zero execution),
a background thread upgrades hot keys with ``mode="measure"`` and merges
the winner into the wisdom store atomically, and an LRU cap with
``Croft3D.release()`` keeps the compiled-executable set bounded under
shape diversity.

    from repro.serve import TransformService
    with TransformService(mesh, max_batch=8, wisdom_path="wisdom.json",
                          measure_after=32) as svc:
        spectrum = svc.transform(field, problem="r2c")

Benchmarked by ``benchmarks/serve_bench.py`` (``BENCH_serve.json``):
p50/p99 latency vs offered QPS under a synthetic open-loop load, batch
occupancy, plan-cache hit rate, and a deterministic collective-count
batching gate.
"""

from repro.serve.batcher import Batcher, Bucket, padded_size, stack_and_pad
from repro.serve.plan_cache import CachedPlan, CacheStats, PlanCache
from repro.serve.request import (DIRECTIONS, PRIORITIES, PRIORITY_HIGH,
                                 PRIORITY_LOW, PRIORITY_NORMAL, PROBLEMS,
                                 ShedResult, TransformRequest,
                                 TransformResult, bucket_key)
from repro.serve.service import TransformService

__all__ = [
    "Batcher", "Bucket", "CacheStats", "CachedPlan", "DIRECTIONS",
    "PRIORITIES", "PRIORITY_HIGH", "PRIORITY_LOW", "PRIORITY_NORMAL",
    "PROBLEMS", "PlanCache", "ShedResult", "TransformRequest",
    "TransformResult", "TransformService", "bucket_key", "padded_size",
    "stack_and_pad",
]
