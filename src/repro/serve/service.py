"""The transform service: async queue -> buckets -> batched dispatch.

One worker thread owns the device: it pulls requests off the queue,
groups them by executable (:mod:`repro.serve.batcher`), resolves plans
through the :mod:`repro.serve.plan_cache`, stacks/pads the payloads, and
dispatches the batched transform with donated buffers.  Clients get
``concurrent.futures.Future``s; results materialize on the host so
latency includes the D2H hop.

The loop is continuous batching in the transform setting: while the
device runs one batch, the queue keeps filling, so the next batch forms
from whatever arrived meanwhile — occupancy rises with offered load
instead of being fixed at a static batch size.

    with TransformService(mesh, max_batch=8) as svc:
        fut = svc.submit(field, problem="r2c")
        spectrum = fut.result().value
"""

from __future__ import annotations

import dataclasses
import logging
import queue
import threading
import time
from typing import Optional

import jax
import numpy as np

from repro.obs import metrics as metrics_lib
from repro.obs import tracer as tracer_lib
from repro.resil import inject as inject_lib
from repro.serve.batcher import Batcher, Bucket, padded_size, stack_and_pad
from repro.serve.plan_cache import PlanCache
from repro.serve.request import (PRIORITY_NORMAL, ShedResult,
                                 TransformRequest, TransformResult,
                                 bucket_key)

_log = logging.getLogger("repro.serve")


@dataclasses.dataclass
class _Pending:
    req: TransformRequest
    future: "object"  # concurrent.futures.Future[TransformResult]


class TransformService:
    """Plan-cached, continuously batched spectral transform service."""

    def __init__(self, mesh=None, *, max_batch: int = 8,
                 max_wait_ms: float = 2.0,
                 cache: Optional[PlanCache] = None,
                 wisdom_path: Optional[str] = None,
                 max_plans: int = 16,
                 measure_after: Optional[int] = None,
                 tune_kw: Optional[dict] = None,
                 latency_window: int = 4096,
                 registry: Optional[metrics_lib.MetricsRegistry] = None,
                 max_queue: Optional[int] = None,
                 dispatch_retries: int = 2,
                 retry_backoff_s: float = 0.01,
                 nan_guard: bool = True,
                 quarantine_after: int = 3,
                 preemption=None):
        self.mesh = mesh
        self.max_batch = max_batch
        self.max_wait_s = max_wait_ms / 1e3
        #: bounded-queue load shedding: when more than ``max_queue``
        #: requests are pending in the batcher, the least-important one
        #: (highest priority value, newest first) resolves with a typed
        #: ShedResult instead of waiting (None = unbounded)
        self.max_queue = max_queue
        self.dispatch_retries = dispatch_retries
        self.retry_backoff_s = retry_backoff_s
        self.nan_guard = nan_guard
        #: train.fault.PreemptionHandler (or None): when its flag flips
        #: (SIGTERM), the worker drains pending buckets and stops cleanly
        self.preemption = preemption
        # every serving number lives in the metrics registry (repro.obs);
        # stats() below is a thin compatibility view over it.  Each
        # service owns its registry by default so two services never mix
        # counters; pass registry= to share one exposition endpoint.
        self.registry = registry if registry is not None \
            else metrics_lib.MetricsRegistry()
        self.cache = cache if cache is not None else PlanCache(
            mesh, wisdom_path=wisdom_path, max_plans=max_plans,
            measure_after=measure_after, tune_kw=tune_kw,
            registry=self.registry, quarantine_after=quarantine_after)
        self._queue: "queue.Queue" = queue.Queue()
        self._batcher = Batcher(max_batch, self.max_wait_s)
        self._worker: Optional[threading.Thread] = None
        self._running = False
        self._lock = threading.Lock()
        del latency_window  # kept for API compat; quantiles now come
        #                     from the registry's log-bucketed histogram
        self._m_submitted = self.registry.counter(
            "serve_requests_submitted", "requests accepted by submit()")
        self._m_requests = self.registry.counter(
            "serve_requests", "requests served successfully")
        self._m_batches = self.registry.counter(
            "serve_batches", "batched dispatches")
        self._m_real_rows = self.registry.counter(
            "serve_real_rows", "real rows across dispatched batches")
        self._m_padded_rows = self.registry.counter(
            "serve_padded_rows", "padded rows across dispatched batches")
        self._m_waste_rows = self.registry.counter(
            "serve_padding_waste_rows",
            "padded slots that carried no request (dead collective weight)")
        self._m_failures = self.registry.counter(
            "serve_failures", "requests resolved with ok=False")
        self._m_batch_hist = self.registry.histogram(
            "serve_batch_size", "real batch size per dispatch",
            bounds=range(1, max_batch + 1))
        self._m_latency = self.registry.histogram(
            "serve_latency_s", "submit-to-result seconds")
        self._m_queue_wait = self.registry.histogram(
            "serve_queue_wait_s", "submit-to-dispatch seconds")
        # resilience counters (ISSUE 10): every shed/retry/poison event
        # is counted exactly once so chaos gates can assert equality
        self._m_shed = self.registry.counter(
            "serve_shed_requests",
            "requests rejected by bounded-queue load shedding")
        self._m_deadline = self.registry.counter(
            "serve_deadline_misses",
            "requests whose dispatch deadline passed before their batch")
        self._m_retries = self.registry.counter(
            "serve_dispatch_retries",
            "transient dispatch faults retried with backoff")
        self._m_poisoned = self.registry.counter(
            "serve_poisoned_requests",
            "requests isolated for non-finite payloads")
        self._m_redispatch = self.registry.counter(
            "serve_poison_redispatches",
            "healthy batch-mates re-dispatched individually after a "
            "poisoned co-batched dispatch")
        self._m_nan_outputs = self.registry.counter(
            "serve_nan_outputs",
            "dispatches producing non-finite output from finite input")
        self._m_preempt = self.registry.counter(
            "serve_preemption_drains",
            "graceful drains triggered by the preemption handler")
        self._m_leaked = self.registry.counter(
            "serve_leaked_upgrade_threads",
            "upgrade threads still alive after stop()'s join timeout")

    # -- lifecycle ----------------------------------------------------------
    def start(self) -> "TransformService":
        with self._lock:
            if self._running:
                return self
            self._running = True
        if self.preemption is not None:
            self.preemption.install()  # SIGTERM -> flag; worker drains
        self._worker = threading.Thread(target=self._run, daemon=True,
                                        name="transform-service")
        self._worker.start()
        return self

    def stop(self, drain: bool = True) -> None:
        """Stop the worker; ``drain=True`` serves everything already
        queued first (in-flight futures never dangle)."""
        with self._lock:
            if not self._running:
                return
            self._running = False
        self._queue.put(None)  # wake the worker
        if self._worker is not None:
            self._worker.join()
            self._worker = None
        if drain:
            self._drain_all()
        else:
            self._fail_pending("service stopped")
        if not self.cache.wait_idle(timeout=30.0):
            leaked = self.cache.alive_upgrades()
            self._m_leaked.inc(leaked)
            tracer_lib.get_tracer().instant(
                "service:leaked-upgrade-threads", "plan", {"n": leaked})
            _log.warning("stop(): %d upgrade thread(s) still running "
                         "after join timeout (daemon threads; they die "
                         "with the process)", leaked)

    def __enter__(self) -> "TransformService":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # -- client API ---------------------------------------------------------
    def submit(self, x, *, problem: str = "c2c", direction: str = "forward",
               h=None, shape=None, dtype=None,
               priority: int = PRIORITY_NORMAL,
               deadline_s: Optional[float] = None):
        """Enqueue one transform; returns a Future[TransformResult].

        Payloads are host arrays (the wire format); validation happens
        here, synchronously, so a malformed request raises at the call
        site instead of poisoning a batch.  ``priority`` and
        ``deadline_s`` are the request-lifecycle knobs: priority decides
        who sheds first under a bounded queue and which ready bucket
        dispatches first; a passed deadline resolves the future with a
        typed :class:`~repro.serve.request.ShedResult` instead of
        running stale work."""
        req = TransformRequest(
            x=np.asarray(x), problem=problem, direction=direction,
            h=None if h is None else np.asarray(h), shape=shape,
            dtype=np.complex64 if dtype is None else dtype,
            priority=priority, deadline_s=deadline_s)
        req.validate_payload()
        import concurrent.futures
        fut = concurrent.futures.Future()
        # check-and-enqueue under the lifecycle lock: stop() flips
        # _running under the same lock, so no request can slip in after
        # _fail_pending has swept the queue (its future would never
        # resolve and the caller would hang on fut.result()).
        with self._lock:
            if not self._running:
                raise RuntimeError("service not started (use `with "
                                   "service:` or service.start())")
            self._queue.put(_Pending(req, fut))
        self._m_submitted.inc()
        tracer_lib.get_tracer().instant(
            "request:submit", "queue",
            {"req_id": req.req_id, "problem": req.problem,
             "direction": req.direction})
        return fut

    def transform(self, x, **kw) -> np.ndarray:
        """Synchronous convenience: submit, wait, unwrap (raises on a
        failed request)."""
        res = self.submit(x, **kw).result()
        if not res.ok:
            raise RuntimeError(f"transform failed: {res.error}")
        return res.value

    # -- worker -------------------------------------------------------------
    def _run(self) -> None:
        while True:
            if (self.preemption is not None
                    and self.preemption.preemption_requested):
                self._preempt_drain()
                return
            deadline = self._batcher.next_deadline()
            timeout = 0.05 if deadline is None else min(deadline, 0.05)
            try:
                item = self._queue.get(timeout=timeout)
            except queue.Empty:
                item = False  # timeout tick: check wait budgets below
            if item is None:
                return  # stop() sentinel; stop() handles the remainder
            if item is not False:
                self._batcher.add(self._bucket_key(item.req), item)
                self._shed_overflow()
            for bucket in self._batcher.pop_ready():
                self._dispatch(bucket)

    def _shed_overflow(self) -> None:
        """Bounded-queue load shedding: evict the least-important pending
        request (see ``Batcher.shed_lowest``) until back under
        ``max_queue``.  Evicted futures resolve immediately with a typed
        ShedResult — a shed request can never hang."""
        if self.max_queue is None:
            return
        while self._batcher.pending > self.max_queue:
            item = self._batcher.shed_lowest()
            if item is None:
                return
            self._m_shed.inc()
            tracer_lib.get_tracer().instant(
                "request:shed", "queue",
                {"req_id": item.req.req_id, "priority": item.req.priority})
            item.future.set_result(ShedResult(
                req_id=item.req.req_id, value=None, ok=False,
                error=f"shed: queue full (max_queue={self.max_queue})",
                shed_reason="queue-full", t_submit=item.req.t_submit))

    def _preempt_drain(self) -> None:
        """Preemption (SIGTERM): flip to not-running so new submits are
        refused, then serve everything already pending — a preempted
        service finishes its work, it does not drop it."""
        with self._lock:
            self._running = False
        self._m_preempt.inc()
        tracer_lib.get_tracer().instant("service:preempt-drain", "queue")
        self._drain_all()

    def _bucket_key(self, req: TransformRequest) -> str:
        # token_for (not key_for): once a plan is built the bucket key
        # carries its pipeline token, so requests never co-batch across
        # an upgrade that swapped in a different (e.g. searched) pipeline
        return bucket_key(req, self.cache.token_for(
            req.shape, req.dtype, req.plan_problem))

    def _drain_all(self) -> None:
        """Serve every queued/pending request (shutdown, tests)."""
        while True:
            try:
                item = self._queue.get_nowait()
            except queue.Empty:
                break
            if item is not None and item is not False:
                self._batcher.add(self._bucket_key(item.req), item)
        # buckets here can exceed max_batch (leftover partial bucket plus
        # late arrivals); chunk them, since padded_size rejects oversize
        # and stop(drain=True) promises every queued request is served
        for bucket in self._batcher.pop_all():
            reqs = bucket.requests
            for i in range(0, len(reqs), self.max_batch):
                self._dispatch(Bucket(bucket.key,
                                      reqs[i:i + self.max_batch]))

    def _fail_pending(self, msg: str) -> None:
        while True:
            try:
                item = self._queue.get_nowait()
            except queue.Empty:
                break
            if item is not None and item is not False:
                item.future.set_result(TransformResult(
                    req_id=item.req.req_id, value=None, ok=False, error=msg))
        for bucket in self._batcher.pop_all():
            for p in bucket.requests:
                p.future.set_result(TransformResult(
                    req_id=p.req.req_id, value=None, ok=False, error=msg))

    # -- dispatch -----------------------------------------------------------
    def _dispatch(self, bucket, _isolate: bool = True) -> None:
        tracer = tracer_lib.get_tracer()
        t_dispatch = time.monotonic()
        # deadline enforcement: a request whose dispatch deadline passed
        # while it queued resolves typed and never runs (stale work is
        # dead weight for every batch-mate's collective)
        pendings = []
        for p in bucket.requests:
            if p.req.expired(t_dispatch):
                self._m_deadline.inc()
                tracer.instant("request:deadline-miss", "queue",
                               {"req_id": p.req.req_id,
                                "deadline_s": p.req.deadline_s})
                p.future.set_result(ShedResult(
                    req_id=p.req.req_id, value=None, ok=False,
                    error=f"deadline exceeded ({p.req.deadline_s}s)",
                    shed_reason="deadline", t_submit=p.req.t_submit))
            else:
                pendings.append(p)
        if not pendings:
            return
        req0 = pendings[0].req
        n = len(pendings)
        # retroactive queue-wait spans: started on the client thread at
        # submit (req.t_submit is on the same monotonic clock), ended now
        for p in pendings:
            tracer.complete("request:queue", "queue", p.req.t_submit,
                            t_dispatch, {"req_id": p.req.req_id,
                                         "reason": bucket.reason})
            self._m_queue_wait.observe(t_dispatch - p.req.t_submit)
        cp = None
        try:
            with tracer.span("batch:dispatch", "queue", n=n,
                             reason=bucket.reason, bucket=bucket.key):
                cp = self.cache.get(req0.shape, req0.dtype,
                                    req0.plan_problem)
                out = self._run_batch(cp, pendings, bucket)
            if self.nan_guard and not np.isfinite(out[:n]).all():
                self._handle_nonfinite(cp, bucket, pendings, t_dispatch,
                                       _isolate)
                return
            t_done = time.monotonic()
            padded = out.shape[0]
            for i, p in enumerate(pendings):
                p.future.set_result(TransformResult(
                    req_id=p.req.req_id, value=out[i],
                    latency_s=t_done - p.req.t_submit, batch_size=n,
                    padded_size=padded, plan_state=cp.state,
                    plan_key=cp.key, t_submit=p.req.t_submit,
                    t_dispatch=t_dispatch, t_done=t_done))
            self._m_requests.inc(n)
            self._m_batches.inc()
            self._m_real_rows.inc(n)
            self._m_padded_rows.inc(padded)
            self._m_waste_rows.inc(padded - n)
            self._m_batch_hist.observe(n)
            for p in pendings:
                self._m_latency.observe(t_done - p.req.t_submit)
        except Exception as e:  # resolve futures, never kill the worker
            msg = f"{type(e).__name__}: {e}"
            self._m_failures.inc(n)
            if cp is not None:
                # count toward quarantine: quarantine_after consecutive
                # failed dispatches re-route the bucket to the next
                # degradation-ladder rung (repro.resil.degrade)
                self.cache.report_dispatch_failure(cp.key)
            for p in pendings:
                if not p.future.done():
                    p.future.set_result(TransformResult(
                        req_id=p.req.req_id, value=None, ok=False,
                        error=msg))

    def _run_batch(self, cp, pendings, bucket) -> np.ndarray:
        """Execute with retry-with-backoff for *transient* dispatch
        faults (typed ``resil.TransientFault`` — real device errors are
        not transient-classifiable and fail straight through)."""
        attempt = 0
        while True:
            try:
                inject_lib.fire("serve.dispatch", bucket.key)
                return self._execute(cp.plan, pendings)
            except inject_lib.TransientFault:
                if attempt >= self.dispatch_retries:
                    raise
                self._m_retries.inc()
                tracer_lib.get_tracer().instant(
                    "batch:retry", "queue",
                    {"bucket": bucket.key, "attempt": attempt})
                if self.retry_backoff_s:
                    time.sleep(self.retry_backoff_s * (2 ** attempt))
                attempt += 1

    def _handle_nonfinite(self, cp, bucket, pendings, t_dispatch,
                          isolate: bool) -> None:
        """A dispatch produced NaN/Inf rows.  If any *input* was
        non-finite, this is payload poisoning: the poisoned requests
        resolve as typed failures and every healthy batch-mate
        re-dispatches individually — one bad request must not corrupt
        its neighbors (donated buffers and shared collectives make
        row-level containment unverifiable).  All-finite inputs mean the
        *plan* produced garbage: every request fails typed and the
        failure counts toward the plan's quarantine."""
        poisoned = [p for p in pendings if not p.req.payload_finite()]
        if not poisoned:
            self._m_nan_outputs.inc()
            self._m_failures.inc(len(pendings))
            self.cache.report_dispatch_failure(cp.key)
            for p in pendings:
                p.future.set_result(TransformResult(
                    req_id=p.req.req_id, value=None, ok=False,
                    error="non-finite output from finite input (plan "
                          "poisoned; counted toward quarantine)",
                    plan_key=cp.key, t_submit=p.req.t_submit,
                    t_dispatch=t_dispatch))
            return
        bad = {id(p) for p in poisoned}
        self._m_poisoned.inc(len(poisoned))
        self._m_failures.inc(len(poisoned))
        for p in poisoned:
            tracer_lib.get_tracer().instant(
                "request:poisoned", "queue", {"req_id": p.req.req_id})
            p.future.set_result(TransformResult(
                req_id=p.req.req_id, value=None, ok=False,
                error="poisoned payload: non-finite input",
                plan_key=cp.key, t_submit=p.req.t_submit,
                t_dispatch=t_dispatch))
        healthy = [p for p in pendings if id(p) not in bad]
        if not healthy:
            return
        if not isolate:  # already a 1-request redispatch; don't recurse
            for p in healthy:
                p.future.set_result(TransformResult(
                    req_id=p.req.req_id, value=None, ok=False,
                    error="non-finite output on isolated redispatch",
                    plan_key=cp.key, t_submit=p.req.t_submit,
                    t_dispatch=t_dispatch))
            return
        self._m_redispatch.inc(len(healthy))
        for p in healthy:
            self._dispatch(Bucket(bucket.key, [p], reason="redispatch"),
                           _isolate=False)

    def _execute(self, plan, pendings) -> np.ndarray:
        """Stack, pad, place, run the batched executable, fetch to host.

        Phase spans (h2d -> compute -> d2h) are emitted when tracing is
        enabled; the compute span then pays one extra
        ``block_until_ready`` so the d2h span measures only the fetch.
        With the no-op tracer the call sequence is byte-identical to the
        untraced path."""
        req0 = pendings[0].req
        tracer = tracer_lib.get_tracer()
        n = len(pendings)
        padded = padded_size(n, self.max_batch)
        forward = req0.direction == "forward"
        in_dtype = (plan.input_dtype if forward else plan.dtype)
        with tracer.span("batch:h2d", "h2d/d2h", rows=padded):
            xs = stack_and_pad([p.req.x for p in pendings],
                               padded).astype(in_dtype, copy=False)
            xd = self._place(xs, plan.batched_sharding(
                "input" if forward else "output"))
            hd = None
            if req0.h is not None:
                hs = stack_and_pad([p.req.h for p in pendings],
                                   padded).astype(plan.dtype, copy=False)
                hd = self._place(hs, plan.batched_sharding("output"))
        with tracer.span("batch:compute", "fft", rows=padded,
                         direction=req0.direction, problem=req0.problem):
            if hd is not None:
                out = plan.forward_filtered_batched(xd, hd)
            elif forward:
                out = plan.forward_batched(xd)
            else:
                out = plan.inverse_batched(xd)
            if tracer.enabled:
                jax.block_until_ready(out)
        with tracer.span("batch:d2h", "h2d/d2h", rows=padded):
            return np.asarray(jax.device_get(out))

    @staticmethod
    def _place(host: np.ndarray, sharding):
        if sharding is None:
            return jax.numpy.asarray(host)
        return jax.device_put(host, sharding)

    # -- stats --------------------------------------------------------------
    def stats(self) -> dict:
        """Compatibility view over the metrics registry: the dict shape
        predates ``repro.obs`` and is kept for callers/benches; new code
        should read ``service.registry`` directly (``snapshot()`` /
        ``to_prometheus()``)."""
        n_requests = int(self._m_requests.value)
        n_batches = int(self._m_batches.value)
        real_rows = int(self._m_real_rows.value)
        padded_rows = int(self._m_padded_rows.value)

        # exact batch-size histogram back out of the explicit-bounds
        # buckets (cumulative -> per-size counts keyed by int size)
        batch_hist = {}
        prev = 0
        for edge, cum in self._m_batch_hist.buckets()[:-1]:
            if cum > prev:
                batch_hist[int(edge)] = cum - prev
            prev = cum

        def q(p):
            v = self._m_latency.quantile(p)
            return None if v is None else v * 1e3

        return {
            "requests": n_requests,
            "batches": n_batches,
            "mean_batch": (n_requests / n_batches if n_batches else 0.0),
            "real_rows": real_rows,
            "padded_rows": padded_rows,
            "padding_waste_rows": int(self._m_waste_rows.value),
            "occupancy": (real_rows / padded_rows if padded_rows else 0.0),
            "batch_hist": batch_hist,
            "pending": self._batcher.pending + self._queue.qsize(),
            "latency_ms": {"p50": q(0.50), "p90": q(0.90), "p99": q(0.99)},
            "plan_cache": self.cache.snapshot(),
        }
