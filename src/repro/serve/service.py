"""The transform service: async queue -> buckets -> batched dispatch.

One worker thread owns the device: it pulls requests off the queue,
groups them by executable (:mod:`repro.serve.batcher`), resolves plans
through the :mod:`repro.serve.plan_cache`, stacks/pads the payloads, and
dispatches the batched transform with donated buffers.  Clients get
``concurrent.futures.Future``s; results materialize on the host so
latency includes the D2H hop.

The loop is continuous batching in the transform setting: while the
device runs one batch, the queue keeps filling, so the next batch forms
from whatever arrived meanwhile — occupancy rises with offered load
instead of being fixed at a static batch size.

    with TransformService(mesh, max_batch=8) as svc:
        fut = svc.submit(field, problem="r2c")
        spectrum = fut.result().value
"""

from __future__ import annotations

import dataclasses
import queue
import threading
import time
from typing import Optional

import jax
import numpy as np

from repro.obs import metrics as metrics_lib
from repro.obs import tracer as tracer_lib
from repro.serve.batcher import Batcher, Bucket, padded_size, stack_and_pad
from repro.serve.plan_cache import PlanCache
from repro.serve.request import (TransformRequest, TransformResult,
                                 bucket_key)


@dataclasses.dataclass
class _Pending:
    req: TransformRequest
    future: "object"  # concurrent.futures.Future[TransformResult]


class TransformService:
    """Plan-cached, continuously batched spectral transform service."""

    def __init__(self, mesh=None, *, max_batch: int = 8,
                 max_wait_ms: float = 2.0,
                 cache: Optional[PlanCache] = None,
                 wisdom_path: Optional[str] = None,
                 max_plans: int = 16,
                 measure_after: Optional[int] = None,
                 tune_kw: Optional[dict] = None,
                 latency_window: int = 4096,
                 registry: Optional[metrics_lib.MetricsRegistry] = None):
        self.mesh = mesh
        self.max_batch = max_batch
        self.max_wait_s = max_wait_ms / 1e3
        # every serving number lives in the metrics registry (repro.obs);
        # stats() below is a thin compatibility view over it.  Each
        # service owns its registry by default so two services never mix
        # counters; pass registry= to share one exposition endpoint.
        self.registry = registry if registry is not None \
            else metrics_lib.MetricsRegistry()
        self.cache = cache if cache is not None else PlanCache(
            mesh, wisdom_path=wisdom_path, max_plans=max_plans,
            measure_after=measure_after, tune_kw=tune_kw,
            registry=self.registry)
        self._queue: "queue.Queue" = queue.Queue()
        self._batcher = Batcher(max_batch, self.max_wait_s)
        self._worker: Optional[threading.Thread] = None
        self._running = False
        self._lock = threading.Lock()
        del latency_window  # kept for API compat; quantiles now come
        #                     from the registry's log-bucketed histogram
        self._m_submitted = self.registry.counter(
            "serve_requests_submitted", "requests accepted by submit()")
        self._m_requests = self.registry.counter(
            "serve_requests", "requests served successfully")
        self._m_batches = self.registry.counter(
            "serve_batches", "batched dispatches")
        self._m_real_rows = self.registry.counter(
            "serve_real_rows", "real rows across dispatched batches")
        self._m_padded_rows = self.registry.counter(
            "serve_padded_rows", "padded rows across dispatched batches")
        self._m_waste_rows = self.registry.counter(
            "serve_padding_waste_rows",
            "padded slots that carried no request (dead collective weight)")
        self._m_failures = self.registry.counter(
            "serve_failures", "requests resolved with ok=False")
        self._m_batch_hist = self.registry.histogram(
            "serve_batch_size", "real batch size per dispatch",
            bounds=range(1, max_batch + 1))
        self._m_latency = self.registry.histogram(
            "serve_latency_s", "submit-to-result seconds")
        self._m_queue_wait = self.registry.histogram(
            "serve_queue_wait_s", "submit-to-dispatch seconds")

    # -- lifecycle ----------------------------------------------------------
    def start(self) -> "TransformService":
        with self._lock:
            if self._running:
                return self
            self._running = True
        self._worker = threading.Thread(target=self._run, daemon=True,
                                        name="transform-service")
        self._worker.start()
        return self

    def stop(self, drain: bool = True) -> None:
        """Stop the worker; ``drain=True`` serves everything already
        queued first (in-flight futures never dangle)."""
        with self._lock:
            if not self._running:
                return
            self._running = False
        self._queue.put(None)  # wake the worker
        if self._worker is not None:
            self._worker.join()
            self._worker = None
        if drain:
            self._drain_all()
        else:
            self._fail_pending("service stopped")
        self.cache.wait_idle(timeout=30.0)

    def __enter__(self) -> "TransformService":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # -- client API ---------------------------------------------------------
    def submit(self, x, *, problem: str = "c2c", direction: str = "forward",
               h=None, shape=None, dtype=None):
        """Enqueue one transform; returns a Future[TransformResult].

        Payloads are host arrays (the wire format); validation happens
        here, synchronously, so a malformed request raises at the call
        site instead of poisoning a batch."""
        req = TransformRequest(
            x=np.asarray(x), problem=problem, direction=direction,
            h=None if h is None else np.asarray(h), shape=shape,
            dtype=np.complex64 if dtype is None else dtype)
        req.validate_payload()
        import concurrent.futures
        fut = concurrent.futures.Future()
        # check-and-enqueue under the lifecycle lock: stop() flips
        # _running under the same lock, so no request can slip in after
        # _fail_pending has swept the queue (its future would never
        # resolve and the caller would hang on fut.result()).
        with self._lock:
            if not self._running:
                raise RuntimeError("service not started (use `with "
                                   "service:` or service.start())")
            self._queue.put(_Pending(req, fut))
        self._m_submitted.inc()
        tracer_lib.get_tracer().instant(
            "request:submit", "queue",
            {"req_id": req.req_id, "problem": req.problem,
             "direction": req.direction})
        return fut

    def transform(self, x, **kw) -> np.ndarray:
        """Synchronous convenience: submit, wait, unwrap (raises on a
        failed request)."""
        res = self.submit(x, **kw).result()
        if not res.ok:
            raise RuntimeError(f"transform failed: {res.error}")
        return res.value

    # -- worker -------------------------------------------------------------
    def _run(self) -> None:
        while True:
            deadline = self._batcher.next_deadline()
            timeout = 0.05 if deadline is None else min(deadline, 0.05)
            try:
                item = self._queue.get(timeout=timeout)
            except queue.Empty:
                item = False  # timeout tick: check wait budgets below
            if item is None:
                return  # stop() sentinel; stop() handles the remainder
            if item is not False:
                self._batcher.add(self._bucket_key(item.req), item)
            for bucket in self._batcher.pop_ready():
                self._dispatch(bucket)

    def _bucket_key(self, req: TransformRequest) -> str:
        # token_for (not key_for): once a plan is built the bucket key
        # carries its pipeline token, so requests never co-batch across
        # an upgrade that swapped in a different (e.g. searched) pipeline
        return bucket_key(req, self.cache.token_for(
            req.shape, req.dtype, req.plan_problem))

    def _drain_all(self) -> None:
        """Serve every queued/pending request (shutdown, tests)."""
        while True:
            try:
                item = self._queue.get_nowait()
            except queue.Empty:
                break
            if item is not None and item is not False:
                self._batcher.add(self._bucket_key(item.req), item)
        # buckets here can exceed max_batch (leftover partial bucket plus
        # late arrivals); chunk them, since padded_size rejects oversize
        # and stop(drain=True) promises every queued request is served
        for bucket in self._batcher.pop_all():
            reqs = bucket.requests
            for i in range(0, len(reqs), self.max_batch):
                self._dispatch(Bucket(bucket.key,
                                      reqs[i:i + self.max_batch]))

    def _fail_pending(self, msg: str) -> None:
        while True:
            try:
                item = self._queue.get_nowait()
            except queue.Empty:
                break
            if item is not None and item is not False:
                item.future.set_result(TransformResult(
                    req_id=item.req.req_id, value=None, ok=False, error=msg))
        for bucket in self._batcher.pop_all():
            for p in bucket.requests:
                p.future.set_result(TransformResult(
                    req_id=p.req.req_id, value=None, ok=False, error=msg))

    # -- dispatch -----------------------------------------------------------
    def _dispatch(self, bucket) -> None:
        pendings = bucket.requests
        req0 = pendings[0].req
        tracer = tracer_lib.get_tracer()
        t_dispatch = time.monotonic()
        n = len(pendings)
        # retroactive queue-wait spans: started on the client thread at
        # submit (req.t_submit is on the same monotonic clock), ended now
        for p in pendings:
            tracer.complete("request:queue", "queue", p.req.t_submit,
                            t_dispatch, {"req_id": p.req.req_id,
                                         "reason": bucket.reason})
            self._m_queue_wait.observe(t_dispatch - p.req.t_submit)
        try:
            with tracer.span("batch:dispatch", "queue", n=n,
                             reason=bucket.reason, bucket=bucket.key):
                cp = self.cache.get(req0.shape, req0.dtype,
                                    req0.plan_problem)
                out = self._execute(cp.plan, pendings)
            t_done = time.monotonic()
            padded = out.shape[0]
            for i, p in enumerate(pendings):
                p.future.set_result(TransformResult(
                    req_id=p.req.req_id, value=out[i],
                    latency_s=t_done - p.req.t_submit, batch_size=n,
                    padded_size=padded, plan_state=cp.state,
                    plan_key=cp.key, t_submit=p.req.t_submit,
                    t_dispatch=t_dispatch, t_done=t_done))
            self._m_requests.inc(n)
            self._m_batches.inc()
            self._m_real_rows.inc(n)
            self._m_padded_rows.inc(padded)
            self._m_waste_rows.inc(padded - n)
            self._m_batch_hist.observe(n)
            for p in pendings:
                self._m_latency.observe(t_done - p.req.t_submit)
        except Exception as e:  # resolve futures, never kill the worker
            msg = f"{type(e).__name__}: {e}"
            self._m_failures.inc(n)
            for p in pendings:
                if not p.future.done():
                    p.future.set_result(TransformResult(
                        req_id=p.req.req_id, value=None, ok=False,
                        error=msg))

    def _execute(self, plan, pendings) -> np.ndarray:
        """Stack, pad, place, run the batched executable, fetch to host.

        Phase spans (h2d -> compute -> d2h) are emitted when tracing is
        enabled; the compute span then pays one extra
        ``block_until_ready`` so the d2h span measures only the fetch.
        With the no-op tracer the call sequence is byte-identical to the
        untraced path."""
        req0 = pendings[0].req
        tracer = tracer_lib.get_tracer()
        n = len(pendings)
        padded = padded_size(n, self.max_batch)
        forward = req0.direction == "forward"
        in_dtype = (plan.input_dtype if forward else plan.dtype)
        with tracer.span("batch:h2d", "h2d/d2h", rows=padded):
            xs = stack_and_pad([p.req.x for p in pendings],
                               padded).astype(in_dtype, copy=False)
            xd = self._place(xs, plan.batched_sharding(
                "input" if forward else "output"))
            hd = None
            if req0.h is not None:
                hs = stack_and_pad([p.req.h for p in pendings],
                                   padded).astype(plan.dtype, copy=False)
                hd = self._place(hs, plan.batched_sharding("output"))
        with tracer.span("batch:compute", "fft", rows=padded,
                         direction=req0.direction, problem=req0.problem):
            if hd is not None:
                out = plan.forward_filtered_batched(xd, hd)
            elif forward:
                out = plan.forward_batched(xd)
            else:
                out = plan.inverse_batched(xd)
            if tracer.enabled:
                jax.block_until_ready(out)
        with tracer.span("batch:d2h", "h2d/d2h", rows=padded):
            return np.asarray(jax.device_get(out))

    @staticmethod
    def _place(host: np.ndarray, sharding):
        if sharding is None:
            return jax.numpy.asarray(host)
        return jax.device_put(host, sharding)

    # -- stats --------------------------------------------------------------
    def stats(self) -> dict:
        """Compatibility view over the metrics registry: the dict shape
        predates ``repro.obs`` and is kept for callers/benches; new code
        should read ``service.registry`` directly (``snapshot()`` /
        ``to_prometheus()``)."""
        n_requests = int(self._m_requests.value)
        n_batches = int(self._m_batches.value)
        real_rows = int(self._m_real_rows.value)
        padded_rows = int(self._m_padded_rows.value)

        # exact batch-size histogram back out of the explicit-bounds
        # buckets (cumulative -> per-size counts keyed by int size)
        batch_hist = {}
        prev = 0
        for edge, cum in self._m_batch_hist.buckets()[:-1]:
            if cum > prev:
                batch_hist[int(edge)] = cum - prev
            prev = cum

        def q(p):
            v = self._m_latency.quantile(p)
            return None if v is None else v * 1e3

        return {
            "requests": n_requests,
            "batches": n_batches,
            "mean_batch": (n_requests / n_batches if n_batches else 0.0),
            "real_rows": real_rows,
            "padded_rows": padded_rows,
            "padding_waste_rows": int(self._m_waste_rows.value),
            "occupancy": (real_rows / padded_rows if padded_rows else 0.0),
            "batch_hist": batch_hist,
            "pending": self._batcher.pending + self._queue.qsize(),
            "latency_ms": {"p50": q(0.50), "p90": q(0.90), "p99": q(0.99)},
            "plan_cache": self.cache.snapshot(),
        }
