"""Plan cache — FFTW's planner-in-production, fronting ``repro.tuning``.

The serving story for plan selection (ROADMAP item 2):

  cold   the FIRST request of a problem key builds its plan with
         ``mode="wisdom"`` — a stored plan if the wisdom file has one,
         otherwise the zero-execution analytic model (FFTW ESTIMATE).
         Nothing is ever timed on the request path.
  warm   once a key turns hot (``measure_after`` dispatches), a
         background thread re-plans it with ``mode="measure"`` (FFTW
         PATIENT) and atomically merges the measured winner into the
         wisdom store (``tuning.upgrade_wisdom``).  The cache swaps the
         measured plan in; every later process starts warm from wisdom.
  hit    every other request reuses the cached, already-compiled plan.

Hygiene: shape diversity is the production hazard — every distinct
(shape, dtype, problem) compiles its own executables, and XLA's compile
cache grows without bound.  The cache is LRU-capped at ``max_plans``;
eviction calls ``Croft3D.release()`` which drops the plan's compiled
executables, so the live-executable set tracks the working set.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import Optional

import jax
import jax.numpy as jnp

from repro.obs import metrics as metrics_lib
from repro.obs import tracer as tracer_lib
from repro.resil import degrade as degrade_lib
from repro.resil import inject as inject_lib


@dataclasses.dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    evictions: int = 0
    upgrades: int = 0

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def as_dict(self) -> dict:
        return {"hits": self.hits, "misses": self.misses,
                "evictions": self.evictions, "upgrades": self.upgrades,
                "hit_rate": round(self.hit_rate, 4)}


@dataclasses.dataclass
class CachedPlan:
    """A cached ``Croft3D`` plus its serving lifecycle state."""

    plan: object                 # Croft3D
    key: str
    state: str                   # "cold" (model/wisdom-model) | "warm"
    hits: int = 0
    last_used: int = 0           # monotonic use counter (LRU order)
    upgrading: bool = False
    #: degradation-ladder rung serving this key ("primary" = tuner pick;
    #: see repro.resil.degrade.RUNGS)
    rung: str = "primary"
    #: consecutive dispatch failures on this entry; at
    #: PlanCache.quarantine_after the entry is quarantined and the
    #: bucket re-routes to the next rung down
    failures: int = 0
    #: failed background upgrades; capped at upgrade_max_retries
    upgrade_failures: int = 0
    #: a quarantined key never re-arms the measurement upgrade (the
    #: measured winner is the plan that just got it quarantined)
    quarantined: bool = False

    @property
    def plan_token(self) -> str:
        """The plan's pipeline identity (searched plans included) — what
        batch-compatibility bucketing must key on, since two plans for
        the same wisdom key stop being batchable the moment a background
        upgrade swaps a searched pipeline in under one of them."""
        try:
            return self.plan.candidate().plan_key
        except Exception:
            return self.key  # meshless plans carry no candidate identity


class PlanCache:
    """LRU plan cache keyed by the wisdom problem key.

    ``mesh=None`` serves single-device plans (nothing to tune; every
    plan is built directly and stays "warm" — there is no better plan to
    measure).  With a mesh, plans come from the tuner: cold =
    wisdom-or-model, and ``measure_after=N`` arms the background
    measurement upgrade after N dispatches of a key.
    """

    def __init__(self, mesh=None, *, max_plans: int = 16,
                 wisdom_path: Optional[str] = None,
                 measure_after: Optional[int] = None,
                 upgrade_async: bool = True,
                 tune_kw: Optional[dict] = None,
                 registry: Optional[metrics_lib.MetricsRegistry] = None,
                 quarantine_after: int = 3,
                 upgrade_max_retries: int = 2):
        if max_plans < 1:
            raise ValueError("max_plans must be >= 1")
        if quarantine_after < 1:
            raise ValueError("quarantine_after must be >= 1")
        self.mesh = mesh
        self.max_plans = max_plans
        self.wisdom_path = wisdom_path
        self.measure_after = measure_after
        self.upgrade_async = upgrade_async
        self.quarantine_after = quarantine_after
        self.upgrade_max_retries = upgrade_max_retries
        self.tune_kw = dict(tune_kw or {})
        self.stats = CacheStats()
        # lifecycle counters mirror CacheStats into the metrics registry
        # (the service passes its own registry in; standalone caches get
        # a private one so two caches never mix counts)
        self.registry = registry if registry is not None \
            else metrics_lib.MetricsRegistry()
        self._plans: dict[str, CachedPlan] = {}
        self._clock = 0
        self._lock = threading.RLock()
        self._upgrade_threads: list[threading.Thread] = []

    # -- keys ---------------------------------------------------------------
    def key_for(self, shape, dtype, problem: str) -> str:
        """The wisdom key this (shape, dtype, problem) plans under — the
        same string the tuner reads/writes, so cache misses warm-start
        from whatever wisdom previous runs persisted."""
        from repro.tuning import wisdom_key
        if self.mesh is None:
            return wisdom_key(shape, {}, jnp.dtype(dtype), "local", problem)
        return wisdom_key(shape, dict(self.mesh.shape), jnp.dtype(dtype),
                          jax.default_backend(), problem)

    def token_for(self, shape, dtype, problem: str) -> str:
        """Batch-bucket token for (shape, dtype, problem): the wisdom key
        while the plan is unbuilt (cold requests for one key can always
        bucket together — they will share whatever plan the miss builds),
        extended with the built plan's pipeline token afterwards.  The
        wisdom-key prefix keeps shape/dtype separation; the plan-token
        suffix splits buckets when an upgrade swaps in a different
        pipeline (e.g. a searched schedule), since requests batched into
        one vmapped call must share one executable."""
        key = self.key_for(shape, dtype, problem)
        with self._lock:
            cp = self._plans.get(key)
        if cp is None:
            return key
        return f"{key}@{cp.plan_token}"

    # -- lookup/build -------------------------------------------------------
    def get(self, shape, dtype=jnp.complex64, problem: str = "c2c"
            ) -> CachedPlan:
        """The plan for (shape, dtype, problem): cached, or built cold."""
        key = self.key_for(shape, dtype, problem)
        with self._lock:
            cp = self._plans.get(key)
            if cp is not None:
                self.stats.hits += 1
                self.registry.counter("plan_cache_hits").inc()
                tracer_lib.get_tracer().instant(
                    "plan:hit", "plan", {"key": key, "state": cp.state})
                self._touch(cp)
                self._maybe_upgrade(cp)
                return cp
            self.stats.misses += 1
            self.registry.counter("plan_cache_misses").inc()
            with tracer_lib.get_tracer().span("plan:build", "plan",
                                              key=key):
                cp = self._build(key, tuple(shape), jnp.dtype(dtype),
                                 problem)
            self._plans[key] = cp
            self._touch(cp)
            # _evict_lru returns False when every other plan is mid-upgrade
            # (upgrading plans are pinned); bail rather than spin — the
            # upgrade threads need this lock to finish, so looping here
            # would livelock the worker.  Temporary over-capacity drains
            # on the next miss once upgrades land.
            while (len(self._plans) > self.max_plans
                   and self._evict_lru(keep=key)):
                pass
            return cp

    def _touch(self, cp: CachedPlan) -> None:
        self._clock += 1
        cp.last_used = self._clock
        cp.hits += 1

    def _build(self, key: str, shape, dtype, problem: str) -> CachedPlan:
        try:
            inject_lib.fire("plan.build", key)
            return self._build_primary(key, shape, dtype, problem)
        except Exception:
            # a failed build must not fail the request if any ladder rung
            # below the tuner's pick still builds (repro.resil.degrade);
            # _build_fallback re-raises when nothing does
            self.registry.counter("plan_build_failures").inc()
            tracer_lib.get_tracer().instant("plan:build-fail", "plan",
                                            {"key": key})
            cp = self._build_fallback(key, shape, dtype, problem)
            self.registry.counter("plan_build_fallbacks").inc()
            tracer_lib.get_tracer().instant(
                "plan:build-fallback", "plan", {"key": key, "rung": cp.rung})
            return cp

    def _build_primary(self, key: str, shape, dtype,
                       problem: str) -> CachedPlan:
        from repro.core.api import Croft3D
        if self.mesh is None:
            # single device: nothing to tune, and nothing to upgrade to
            plan = Croft3D(shape, dtype=dtype, problem=problem)
            return CachedPlan(plan=plan, key=key, state="warm")
        plan = Croft3D.tuned(shape, self.mesh, mode="wisdom",
                             wisdom_path=self.wisdom_path, dtype=dtype,
                             problem=problem, **self.tune_kw)
        measured = (plan.tune_result is not None
                    and plan.tune_result.measured_s is not None)
        return CachedPlan(plan=plan, key=key,
                          state="warm" if measured else "cold")

    def _build_fallback(self, key: str, shape, dtype,
                        problem: str) -> CachedPlan:
        from repro.core.api import Croft3D
        if self.mesh is None:
            # the plain meshless plan IS the bottom rung; retry it
            plan = Croft3D(shape, dtype=dtype, problem=problem)
            return CachedPlan(plan=plan, key=key, state="warm",
                              rung="default")
        cand = degrade_lib.bottom_candidate(shape, dict(self.mesh.shape),
                                            problem)
        if cand is None:
            raise RuntimeError(f"no fallback plan for {key}: even the "
                               "default decomposition is invalid")
        plan = Croft3D(shape, self.mesh, cand.decomp, cand.opts,
                       dtype=dtype, problem=problem,
                       strategy=getattr(cand, "strategy", None))
        return CachedPlan(plan=plan, key=key, state="cold", rung="default")

    def _evict_lru(self, keep: str) -> bool:
        """Evict the LRU evictable plan; False if none is evictable."""
        victims = [cp for cp in self._plans.values()
                   if cp.key != keep and not cp.upgrading]
        if not victims:
            return False
        victim = min(victims, key=lambda cp: cp.last_used)
        del self._plans[victim.key]
        self.stats.evictions += 1
        self.registry.counter("plan_cache_evictions").inc()
        tracer_lib.get_tracer().instant(
            "plan:evict", "plan", {"key": victim.key, "hits": victim.hits})
        victim.plan.release()  # compile-cache hygiene
        return True

    # -- failure reporting and quarantine ----------------------------------
    def report_dispatch_failure(self, key: str) -> Optional[CachedPlan]:
        """One dispatch on ``key``'s plan failed (after retries).  At
        ``quarantine_after`` consecutive failures the entry is
        quarantined: the next ladder rung is built and swapped in, its
        plan token re-routes the bucket, and the failure counter resets
        so the *new* rung gets its own budget before walking further
        down.  Returns the (possibly replaced) entry."""
        with self._lock:
            cp = self._plans.get(key)
            if cp is None:
                return None
            cp.failures += 1
            self.registry.counter("plan_dispatch_failures").inc()
            if cp.failures < self.quarantine_after:
                return cp
            return self._quarantine(cp)

    def _quarantine(self, cp: CachedPlan) -> CachedPlan:
        """Swap ``cp`` for the first ladder rung below it that builds.
        Caller holds the lock."""
        self.registry.counter("plan_quarantines").inc()
        tracer_lib.get_tracer().instant(
            "plan:quarantine", "plan",
            {"key": cp.key, "rung": cp.rung, "failures": cp.failures})
        for rung, cand in degrade_lib.ladder(cp.plan):
            try:
                plan = degrade_lib.build_plan(cp.plan, cand)
            except Exception:
                continue  # this rung does not build either; walk down
            new = CachedPlan(plan=plan, key=cp.key, state="cold",
                             hits=cp.hits, last_used=cp.last_used,
                             rung=rung, quarantined=True,
                             upgrade_failures=cp.upgrade_failures)
            self._plans[cp.key] = new
            self.registry.counter("plan_degradations").inc()
            tracer_lib.get_tracer().instant(
                "plan:degrade", "plan", {"key": cp.key, "rung": rung,
                                         "plan": cand.label})
            if cp.plan is not plan and not cp.upgrading:
                cp.plan.release()  # compile-cache hygiene
            return new
        # bottom of the ladder (or meshless): keep serving the entry;
        # callers keep seeing failures rather than a silent swallow
        self.registry.counter("plan_degrade_exhausted").inc()
        cp.failures = 0  # one quarantine event per quarantine_after burst
        return cp

    # -- background measurement upgrade ------------------------------------
    def _maybe_upgrade(self, cp: CachedPlan) -> None:
        if (self.measure_after is None or self.mesh is None
                or cp.state != "cold" or cp.upgrading or cp.quarantined
                or cp.upgrade_failures >= self.upgrade_max_retries
                or cp.hits < self.measure_after):
            return
        cp.upgrading = True
        self.registry.counter("plan_cache_upgrade_starts").inc()
        tracer_lib.get_tracer().instant(
            "plan:upgrade-start", "plan", {"key": cp.key, "hits": cp.hits})
        if self.upgrade_async:
            t = threading.Thread(target=self._upgrade, args=(cp,),
                                 daemon=True, name=f"plan-upgrade-{cp.key}")
            self._upgrade_threads.append(t)
            t.start()
        else:
            self._upgrade(cp)

    def _upgrade(self, cp: CachedPlan) -> None:
        """Measure-mode re-plan of a hot key, off the request path.

        Compiles and times the model-ranked top candidates on the live
        mesh, merges the winner into the wisdom store (atomic, locked —
        see ``tuning.wisdom.merge_entries``), and swaps the measured plan
        into the cache.  In-flight dispatches keep using the old plan
        object; the swap is a reference replacement, not a mutation.
        """
        from repro.core.api import Croft3D
        tracer = tracer_lib.get_tracer()
        try:
            with tracer.span("plan:upgrade", "plan", key=cp.key):
                inject_lib.fire("plan.upgrade", cp.key)
                from repro import tuning
                result = tuning.upgrade_wisdom(
                    cp.plan.shape, self.mesh, dtype=cp.plan.dtype,
                    problem=cp.plan.problem, wisdom_path=self.wisdom_path,
                    **self.tune_kw)
                plan = Croft3D(cp.plan.shape, self.mesh, result.decomp,
                               result.opts, dtype=cp.plan.dtype,
                               problem=cp.plan.problem,
                               strategy=result.strategy,
                               schedule=getattr(result, "schedule", None))
                plan.tune_result = result
            with self._lock:
                old = self._plans.get(cp.key)
                new = CachedPlan(plan=plan, key=cp.key, state="warm",
                                 hits=cp.hits, last_used=cp.last_used)
                self._plans[cp.key] = new
                self.stats.upgrades += 1
                self.registry.counter("plan_cache_upgrades").inc()
                if old is not None and old.plan is not plan:
                    old.plan.release()
            tracer.instant("plan:upgrade-win", "plan",
                           {"key": cp.key, "plan": result.summary()})
        except Exception:
            # an upgrade failure must never take the service down: roll
            # the *current* map entry (cp may be stale if something
            # swapped it meanwhile) back to its servable cold state, and
            # cap retries — a deterministically failing measure mode must
            # not re-arm on every Nth hit forever
            tracer.instant("plan:upgrade-fail", "plan", {"key": cp.key})
            self.registry.counter("serve_upgrade_failures").inc()
            with self._lock:
                cp.upgrading = False
                cp.upgrade_failures += 1
                cur = self._plans.get(cp.key)
                if cur is not None and cur is not cp:
                    cur.upgrading = False
                    cur.upgrade_failures += 1

    def wait_idle(self, timeout: Optional[float] = None) -> bool:
        """Join outstanding upgrade threads (tests and orderly shutdown).
        True when every thread actually finished; False on a timed-out
        join, so shutdown can tell "idle" from "still measuring" (a
        leaked daemon thread dies with the process but should be
        counted, not mistaken for a clean drain)."""
        with self._lock:
            threads = list(self._upgrade_threads)
        deadline = (None if timeout is None
                    else time.monotonic() + timeout)
        joined = True
        for t in threads:
            t.join(timeout if deadline is None
                   else max(0.0, deadline - time.monotonic()))
            joined = joined and not t.is_alive()
        with self._lock:
            self._upgrade_threads = [
                t for t in self._upgrade_threads if t.is_alive()]
        return joined

    def alive_upgrades(self) -> int:
        """Upgrade threads still running (leftovers after a timed-out
        ``wait_idle``)."""
        with self._lock:
            return sum(1 for t in self._upgrade_threads if t.is_alive())

    # -- introspection ------------------------------------------------------
    def __len__(self) -> int:
        with self._lock:
            return len(self._plans)

    def keys(self) -> list[str]:
        with self._lock:
            return list(self._plans)

    def snapshot(self) -> dict:
        """Stats + per-key lifecycle state, for logs and the bench JSON."""
        with self._lock:
            return {
                "stats": self.stats.as_dict(),
                "plans": {k: {"state": cp.state, "hits": cp.hits,
                              "rung": cp.rung, "failures": cp.failures,
                              "quarantined": cp.quarantined}
                          for k, cp in self._plans.items()},
            }
