"""Plan cache — FFTW's planner-in-production, fronting ``repro.tuning``.

The serving story for plan selection (ROADMAP item 2):

  cold   the FIRST request of a problem key builds its plan with
         ``mode="wisdom"`` — a stored plan if the wisdom file has one,
         otherwise the zero-execution analytic model (FFTW ESTIMATE).
         Nothing is ever timed on the request path.
  warm   once a key turns hot (``measure_after`` dispatches), a
         background thread re-plans it with ``mode="measure"`` (FFTW
         PATIENT) and atomically merges the measured winner into the
         wisdom store (``tuning.upgrade_wisdom``).  The cache swaps the
         measured plan in; every later process starts warm from wisdom.
  hit    every other request reuses the cached, already-compiled plan.

Hygiene: shape diversity is the production hazard — every distinct
(shape, dtype, problem) compiles its own executables, and XLA's compile
cache grows without bound.  The cache is LRU-capped at ``max_plans``;
eviction calls ``Croft3D.release()`` which drops the plan's compiled
executables, so the live-executable set tracks the working set.
"""

from __future__ import annotations

import dataclasses
import threading
from typing import Optional

import jax
import jax.numpy as jnp

from repro.obs import metrics as metrics_lib
from repro.obs import tracer as tracer_lib


@dataclasses.dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    evictions: int = 0
    upgrades: int = 0

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def as_dict(self) -> dict:
        return {"hits": self.hits, "misses": self.misses,
                "evictions": self.evictions, "upgrades": self.upgrades,
                "hit_rate": round(self.hit_rate, 4)}


@dataclasses.dataclass
class CachedPlan:
    """A cached ``Croft3D`` plus its serving lifecycle state."""

    plan: object                 # Croft3D
    key: str
    state: str                   # "cold" (model/wisdom-model) | "warm"
    hits: int = 0
    last_used: int = 0           # monotonic use counter (LRU order)
    upgrading: bool = False

    @property
    def plan_token(self) -> str:
        """The plan's pipeline identity (searched plans included) — what
        batch-compatibility bucketing must key on, since two plans for
        the same wisdom key stop being batchable the moment a background
        upgrade swaps a searched pipeline in under one of them."""
        try:
            return self.plan.candidate().plan_key
        except Exception:
            return self.key  # meshless plans carry no candidate identity


class PlanCache:
    """LRU plan cache keyed by the wisdom problem key.

    ``mesh=None`` serves single-device plans (nothing to tune; every
    plan is built directly and stays "warm" — there is no better plan to
    measure).  With a mesh, plans come from the tuner: cold =
    wisdom-or-model, and ``measure_after=N`` arms the background
    measurement upgrade after N dispatches of a key.
    """

    def __init__(self, mesh=None, *, max_plans: int = 16,
                 wisdom_path: Optional[str] = None,
                 measure_after: Optional[int] = None,
                 upgrade_async: bool = True,
                 tune_kw: Optional[dict] = None,
                 registry: Optional[metrics_lib.MetricsRegistry] = None):
        if max_plans < 1:
            raise ValueError("max_plans must be >= 1")
        self.mesh = mesh
        self.max_plans = max_plans
        self.wisdom_path = wisdom_path
        self.measure_after = measure_after
        self.upgrade_async = upgrade_async
        self.tune_kw = dict(tune_kw or {})
        self.stats = CacheStats()
        # lifecycle counters mirror CacheStats into the metrics registry
        # (the service passes its own registry in; standalone caches get
        # a private one so two caches never mix counts)
        self.registry = registry if registry is not None \
            else metrics_lib.MetricsRegistry()
        self._plans: dict[str, CachedPlan] = {}
        self._clock = 0
        self._lock = threading.RLock()
        self._upgrade_threads: list[threading.Thread] = []

    # -- keys ---------------------------------------------------------------
    def key_for(self, shape, dtype, problem: str) -> str:
        """The wisdom key this (shape, dtype, problem) plans under — the
        same string the tuner reads/writes, so cache misses warm-start
        from whatever wisdom previous runs persisted."""
        from repro.tuning import wisdom_key
        if self.mesh is None:
            return wisdom_key(shape, {}, jnp.dtype(dtype), "local", problem)
        return wisdom_key(shape, dict(self.mesh.shape), jnp.dtype(dtype),
                          jax.default_backend(), problem)

    def token_for(self, shape, dtype, problem: str) -> str:
        """Batch-bucket token for (shape, dtype, problem): the wisdom key
        while the plan is unbuilt (cold requests for one key can always
        bucket together — they will share whatever plan the miss builds),
        extended with the built plan's pipeline token afterwards.  The
        wisdom-key prefix keeps shape/dtype separation; the plan-token
        suffix splits buckets when an upgrade swaps in a different
        pipeline (e.g. a searched schedule), since requests batched into
        one vmapped call must share one executable."""
        key = self.key_for(shape, dtype, problem)
        with self._lock:
            cp = self._plans.get(key)
        if cp is None:
            return key
        return f"{key}@{cp.plan_token}"

    # -- lookup/build -------------------------------------------------------
    def get(self, shape, dtype=jnp.complex64, problem: str = "c2c"
            ) -> CachedPlan:
        """The plan for (shape, dtype, problem): cached, or built cold."""
        key = self.key_for(shape, dtype, problem)
        with self._lock:
            cp = self._plans.get(key)
            if cp is not None:
                self.stats.hits += 1
                self.registry.counter("plan_cache_hits").inc()
                tracer_lib.get_tracer().instant(
                    "plan:hit", "plan", {"key": key, "state": cp.state})
                self._touch(cp)
                self._maybe_upgrade(cp)
                return cp
            self.stats.misses += 1
            self.registry.counter("plan_cache_misses").inc()
            with tracer_lib.get_tracer().span("plan:build", "plan",
                                              key=key):
                cp = self._build(key, tuple(shape), jnp.dtype(dtype),
                                 problem)
            self._plans[key] = cp
            self._touch(cp)
            # _evict_lru returns False when every other plan is mid-upgrade
            # (upgrading plans are pinned); bail rather than spin — the
            # upgrade threads need this lock to finish, so looping here
            # would livelock the worker.  Temporary over-capacity drains
            # on the next miss once upgrades land.
            while (len(self._plans) > self.max_plans
                   and self._evict_lru(keep=key)):
                pass
            return cp

    def _touch(self, cp: CachedPlan) -> None:
        self._clock += 1
        cp.last_used = self._clock
        cp.hits += 1

    def _build(self, key: str, shape, dtype, problem: str) -> CachedPlan:
        from repro.core.api import Croft3D
        if self.mesh is None:
            # single device: nothing to tune, and nothing to upgrade to
            plan = Croft3D(shape, dtype=dtype, problem=problem)
            return CachedPlan(plan=plan, key=key, state="warm")
        plan = Croft3D.tuned(shape, self.mesh, mode="wisdom",
                             wisdom_path=self.wisdom_path, dtype=dtype,
                             problem=problem, **self.tune_kw)
        measured = (plan.tune_result is not None
                    and plan.tune_result.measured_s is not None)
        return CachedPlan(plan=plan, key=key,
                          state="warm" if measured else "cold")

    def _evict_lru(self, keep: str) -> bool:
        """Evict the LRU evictable plan; False if none is evictable."""
        victims = [cp for cp in self._plans.values()
                   if cp.key != keep and not cp.upgrading]
        if not victims:
            return False
        victim = min(victims, key=lambda cp: cp.last_used)
        del self._plans[victim.key]
        self.stats.evictions += 1
        self.registry.counter("plan_cache_evictions").inc()
        tracer_lib.get_tracer().instant(
            "plan:evict", "plan", {"key": victim.key, "hits": victim.hits})
        victim.plan.release()  # compile-cache hygiene
        return True

    # -- background measurement upgrade ------------------------------------
    def _maybe_upgrade(self, cp: CachedPlan) -> None:
        if (self.measure_after is None or self.mesh is None
                or cp.state != "cold" or cp.upgrading
                or cp.hits < self.measure_after):
            return
        cp.upgrading = True
        self.registry.counter("plan_cache_upgrade_starts").inc()
        tracer_lib.get_tracer().instant(
            "plan:upgrade-start", "plan", {"key": cp.key, "hits": cp.hits})
        if self.upgrade_async:
            t = threading.Thread(target=self._upgrade, args=(cp,),
                                 daemon=True, name=f"plan-upgrade-{cp.key}")
            self._upgrade_threads.append(t)
            t.start()
        else:
            self._upgrade(cp)

    def _upgrade(self, cp: CachedPlan) -> None:
        """Measure-mode re-plan of a hot key, off the request path.

        Compiles and times the model-ranked top candidates on the live
        mesh, merges the winner into the wisdom store (atomic, locked —
        see ``tuning.wisdom.merge_entries``), and swaps the measured plan
        into the cache.  In-flight dispatches keep using the old plan
        object; the swap is a reference replacement, not a mutation.
        """
        from repro.core.api import Croft3D
        tracer = tracer_lib.get_tracer()
        try:
            with tracer.span("plan:upgrade", "plan", key=cp.key):
                from repro import tuning
                result = tuning.upgrade_wisdom(
                    cp.plan.shape, self.mesh, dtype=cp.plan.dtype,
                    problem=cp.plan.problem, wisdom_path=self.wisdom_path,
                    **self.tune_kw)
                plan = Croft3D(cp.plan.shape, self.mesh, result.decomp,
                               result.opts, dtype=cp.plan.dtype,
                               problem=cp.plan.problem,
                               strategy=result.strategy,
                               schedule=getattr(result, "schedule", None))
                plan.tune_result = result
            with self._lock:
                old = self._plans.get(cp.key)
                new = CachedPlan(plan=plan, key=cp.key, state="warm",
                                 hits=cp.hits, last_used=cp.last_used)
                self._plans[cp.key] = new
                self.stats.upgrades += 1
                self.registry.counter("plan_cache_upgrades").inc()
                if old is not None and old.plan is not plan:
                    old.plan.release()
            tracer.instant("plan:upgrade-win", "plan",
                           {"key": cp.key, "plan": result.summary()})
        except Exception:
            # an upgrade failure must never take the service down; the
            # cold plan keeps serving and the next hit may retry
            tracer.instant("plan:upgrade-fail", "plan", {"key": cp.key})
            with self._lock:
                cp.upgrading = False

    def wait_idle(self, timeout: Optional[float] = None) -> None:
        """Join outstanding upgrade threads (tests and orderly shutdown)."""
        with self._lock:
            threads = list(self._upgrade_threads)
            self._upgrade_threads = [t for t in threads if t.is_alive()]
        for t in threads:
            t.join(timeout)

    # -- introspection ------------------------------------------------------
    def __len__(self) -> int:
        with self._lock:
            return len(self._plans)

    def keys(self) -> list[str]:
        with self._lock:
            return list(self._plans)

    def snapshot(self) -> dict:
        """Stats + per-key lifecycle state, for logs and the bench JSON."""
        with self._lock:
            return {
                "stats": self.stats.as_dict(),
                "plans": {k: {"state": cp.state, "hits": cp.hits}
                          for k, cp in self._plans.items()},
            }
