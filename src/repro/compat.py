"""Version-compatibility shims for JAX APIs that moved between releases.

The repo is written against the newer public surface (``jax.shard_map``,
``jax.make_mesh(..., axis_types=...)``, ``jax.sharding.AxisType``); this
module makes it run on jax 0.4.x where those live elsewhere or do not
exist yet:

  * ``shard_map``  — ``jax.shard_map`` (>= 0.6) falling back to
    ``jax.experimental.shard_map.shard_map`` (0.4.x).
  * ``make_mesh``  — drops the ``axis_types=`` kwarg on versions whose
    ``jax.make_mesh`` does not accept it (axis types default to Auto
    there, which is what every call site passes anyway).
  * ``AxisType``   — a sentinel enum standing in for
    ``jax.sharding.AxisType`` so ``axis_types=(AxisType.Auto,) * n``
    spellings keep working.

``install()`` (run on import of the ``repro`` package) additionally
patches the missing names onto ``jax`` itself, so test snippets and
examples written against the new API run unmodified on old jax.

NOTE: besides pure name aliases, ``install()`` flips
``jax_threefry_partitionable`` to True on versions where it defaults to
False.  This matches newer jax's default and is required for sharded
and single-device code to draw identical ``jax.random`` streams (which
this repo's parity tests and the tuner's measured comparisons rely on)
— but it does change RNG output of *other* code in the same process
relative to old-jax defaults.  Set it back after import if you need
the legacy streams.
"""

from __future__ import annotations

import enum
import inspect

import jax

try:  # jax >= 0.6: public name
    from jax import shard_map
except ImportError:  # jax 0.4.x
    from jax.experimental.shard_map import shard_map  # noqa: F401


class _AxisTypeShim(enum.Enum):
    Auto = "auto"
    Explicit = "explicit"
    Manual = "manual"


AxisType = getattr(jax.sharding, "AxisType", _AxisTypeShim)

_RAW_MAKE_MESH = jax.make_mesh
_MAKE_MESH_HAS_AXIS_TYPES = (
    "axis_types" in inspect.signature(_RAW_MAKE_MESH).parameters)


def make_mesh(axis_shapes, axis_names, *, devices=None, axis_types=None):
    """``jax.make_mesh`` accepting (and, on old jax, ignoring) axis_types."""
    kw = {}
    if devices is not None:
        kw["devices"] = devices
    if axis_types is not None and _MAKE_MESH_HAS_AXIS_TYPES:
        kw["axis_types"] = axis_types
    return _RAW_MAKE_MESH(axis_shapes, axis_names, **kw)


# raw targets resolved once, before install() patches our own shims in
_RAW_AXIS_SIZE = getattr(jax.lax, "axis_size", None)
_RAW_PCAST = getattr(jax.lax, "pcast", None)


def axis_size(axis_name) -> int:
    """``jax.lax.axis_size`` (>= 0.5); on older jax, ``psum(1, axis)``
    constant-folds to the same Python int inside shard_map bodies."""
    if _RAW_AXIS_SIZE is not None:
        return _RAW_AXIS_SIZE(axis_name)
    return jax.lax.psum(1, axis_name)


def pcast(x, axes, *, to):
    """``jax.lax.pcast`` (the >= 0.6 varying-manual-axes cast).

    Old shard_map has no per-value varying-axes typing, so casting a
    replicated value to "varying" is a no-op there.
    """
    if _RAW_PCAST is not None:
        return _RAW_PCAST(x, axes, to=to)
    return x


def set_mesh(mesh):
    """``jax.set_mesh`` (>= 0.6) context manager.

    On old jax a ``Mesh`` is itself a context manager entering the same
    global-mesh env, so the shim just hands the mesh back.
    """
    fn = getattr(jax, "set_mesh", None)
    if fn is not None and fn is not set_mesh:
        return fn(mesh)
    return mesh


def cost_analysis(compiled) -> dict:
    """``compiled.cost_analysis()`` as a flat dict.

    jax 0.4.x returns a single-element list of property dicts; newer jax
    returns the dict directly.
    """
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca


def install() -> None:
    """Idempotently patch moved/renamed names onto ``jax``."""
    if not hasattr(jax, "shard_map"):
        jax.shard_map = shard_map
    if not _MAKE_MESH_HAS_AXIS_TYPES:
        jax.make_mesh = make_mesh
    if not hasattr(jax.sharding, "AxisType"):
        jax.sharding.AxisType = AxisType
    if not hasattr(jax.lax, "axis_size"):
        jax.lax.axis_size = axis_size
    if not hasattr(jax.lax, "pcast"):
        jax.lax.pcast = pcast
    if not hasattr(jax, "set_mesh"):
        jax.set_mesh = set_mesh
    # newer jax defaults this to True; without it, sharded and unsharded
    # jax.random draws diverge (breaks sharded-vs-single-device parity)
    if not jax.config.jax_threefry_partitionable:
        jax.config.update("jax_threefry_partitionable", True)


install()
