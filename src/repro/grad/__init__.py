"""repro.grad — adjoint schedules: the differentiable distributed FFT.

Two layers:

``adjoint``   a pure ``Schedule -> Schedule`` transform (reverse the
              stage order, swap each transpose's split/concat axes, map
              each local FFT and packed stage op to its transpose),
              validated by the same symbolic layout propagation that
              checks forward schedules.
``vjp``       ``jax.custom_vjp`` wiring that runs the adjoint schedule
              as the backward pass of every entry point — plan-reusing,
              residual-free for the linear transforms, and the only way
              to differentiate the pairwise transpose at all (XLA has no
              rule for ``optimization_barrier``).

``fft3d``/``ifft3d``/``rfft3d``/``irfft3d`` and the ``Croft3D`` methods
pick this up automatically; nothing here needs to be called directly
unless you are composing adjoints yourself.
"""

from repro.grad.adjoint import (PackTwoT, RepackHalvesT, SplitPairsT,
                                UnpackTwoT, adjoint_ops, adjoint_schedule,
                                fold_dc_plane_t, unfold_dc_plane_t)
from repro.grad import vjp

__all__ = [
    "PackTwoT", "RepackHalvesT", "SplitPairsT", "UnpackTwoT",
    "adjoint_ops", "adjoint_schedule", "fold_dc_plane_t",
    "unfold_dc_plane_t", "vjp",
]
