"""Adjoint schedules: the pure ``Schedule -> Schedule`` transpose.

The backward pass of a distributed FFT is the same scheduled machinery
run in reverse (P3DFFT phrases forward/inverse this way; ROADMAP item 1
names the olmax ``custom_gradient``-on-``all_to_all`` idiom).  Because
every pipeline is *data* (``repro.core.schedule``), the adjoint is a
mechanical walk over the stage list:

  * stage order reverses;
  * each global transpose swaps its split/concat axes (the transpose of
    a tiled ``all_to_all`` is the ``all_to_all`` that undoes it, over
    the same communicator, K-chunked along the same uninvolved axis);
  * each local FFT keeps its axis *and its sign*: JAX's linear-transpose
    convention does not conjugate, and the DFT matrix is symmetric, so
    the transpose of an unnormalized FFT with sign s is the unnormalized
    FFT with the same sign s (verified against ``jax.vjp(jnp.fft.fft)``);
  * each packed-real stage op maps to its explicit transpose (the folded
    two-for-one unpack weights DC/Nyquist bins differently from interior
    bins, so its transpose is *not* a scaled inverse — see the ``*T``
    ops below, each pinned against ``jax.vjp`` of its forward op);
  * terminal epilogue ops (the fused k-space multiply) transpose into
    leading prologue ops — ``x -> h * x`` is its own transpose under
    JAX's unconjugated ``mul`` rule.

The result is an ordinary :class:`~repro.core.schedule.Schedule`: the
existing symbolic layout propagation runs at construction, so a
malformed adjoint fails loudly at build time, and
:func:`adjoint_schedule` additionally checks that the propagated output
layout equals the forward input layout.  The cost model, the executor's
K-chunk overlap engine, and the golden ``describe()`` snapshots all work
on adjoints unchanged.

Out-of-body transposes of the packed pipeline's DC/Nyquist plane
fold/unfold (``real.pipeline.unfold_dc_plane`` / ``fold_dc_plane``) live
here too: they run at the traced global level, outside any schedule.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.schedule import (_DIMS, PackTwo, RepackHalves, Schedule,
                                 ScheduleError, SpectralScale, SplitPairs,
                                 Stage, StageOp, UnpackTwo)
from repro.real import packing


# ---------------------------------------------------------------------------
# transposed packed-real stage ops.  Each ``FooT`` is the linear transpose
# of ``Foo`` under JAX's convention: T(complex(a,b))(ct) = (Re ct, -Im ct),
# T(real)(t) = complex(t, 0), T(imag)(t) = -i*t, T(conj) = conj,
# T(c * .) = c * . (unconjugated), T(permutation) = inverse permutation.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PackTwoT(StageOp):
    """Transpose of :class:`PackTwo`: complex cotangent -> real block,
    ``concat(Re ct, -Im ct)`` along the pair axis."""

    pair_axis: int

    def apply(self, blk, opts, ctx, off):
        ax = self.pair_axis + off
        return jnp.concatenate([jnp.real(blk), -jnp.imag(blk)], axis=ax)

    def transform(self, layout):
        if layout.real:
            raise ScheduleError("pack2T needs a complex cotangent")
        return dataclasses.replace(
            layout.with_den(self.pair_axis, div=2), real=True)

    def describe(self):
        return f"pack2T[{_DIMS[self.pair_axis]}]"


@dataclasses.dataclass(frozen=True)
class SplitPairsT(StageOp):
    """Transpose of :class:`SplitPairs`: real cotangent halves (u, v)
    along the pair axis -> ``complex(u, -v)``."""

    pair_axis: int

    def apply(self, blk, opts, ctx, off):
        ax = self.pair_axis + off
        m = blk.shape[ax]
        u = jax.lax.slice_in_dim(blk, 0, m // 2, axis=ax)
        v = jax.lax.slice_in_dim(blk, m // 2, m, axis=ax)
        return jax.lax.complex(u, -v)

    def transform(self, layout):
        if not layout.real:
            raise ScheduleError("split2T needs a real cotangent")
        return dataclasses.replace(
            layout.with_den(self.pair_axis, mul=2), real=False)

    def describe(self):
        return f"split2T[{_DIMS[self.pair_axis]}]"


@dataclasses.dataclass(frozen=True)
class UnpackTwoT(StageOp):
    """Transpose of the folded :class:`UnpackTwo`.

    The folded unpack routes (DC, Nyquist) through Re/Im extractions and
    interior bins through the 0.5-weighted Hermitian split, so its
    transpose reconstructs a full packed spectrum with per-bin rules
    (NOT a scaled repack): with the cotangent split into halves
    (a, b) of ``nz2`` bins each along the pair axis,

      Ct[0]     = complex( Re a[0], -Re b[0])
      Ct[nz2]   = complex(-Im a[0],  Im b[0])
      Ct[k]     = (a[k] - i b[k]) / 2                    k = 1..nz2-1
      Ct[n - k] = conj(a[k] + i b[k]) / 2                k = 1..nz2-1
    """

    pair_axis: int
    z_axis: int = 2
    impl_stage: int = 0

    def apply(self, blk, opts, ctx, off):
        ax = self.pair_axis + off
        m = blk.shape[ax]
        a = jax.lax.slice_in_dim(blk, 0, m // 2, axis=ax)
        b = jax.lax.slice_in_dim(blk, m // 2, m, axis=ax)
        a0, b0 = a[..., 0], b[..., 0]
        c0 = jax.lax.complex(jnp.real(a0), -jnp.real(b0))
        cn = jax.lax.complex(-jnp.imag(a0), jnp.imag(b0))
        ak, bk = a[..., 1:], b[..., 1:]
        body = 0.5 * (ak - 1j * bk)
        tail = jnp.flip(0.5 * jnp.conj(ak + 1j * bk), -1)
        return jnp.concatenate(
            [c0[..., None], body, cn[..., None], tail], axis=-1)

    def transform(self, layout):
        return layout.with_den(self.pair_axis, mul=2).with_den(
            self.z_axis, div=2)

    def describe(self):
        return f"unpack2T[{_DIMS[self.pair_axis]}]"


@dataclasses.dataclass(frozen=True)
class RepackHalvesT(StageOp):
    """Transpose of the folded :class:`RepackHalves`: full packed
    cotangent (n bins) -> folded halves (a, b), ``nz2 = n // 2`` each:

      a[0] = complex( Re Ct[0], -Re Ct[nz2])
      b[0] = complex(-Im Ct[0],  Im Ct[nz2])
      a[k] =     Ct[k] + conj(Ct[n - k])                 k = 1..nz2-1
      b[k] = i * (Ct[k] - conj(Ct[n - k]))               k = 1..nz2-1
    """

    pair_axis: int
    nz: int
    z_axis: int = 2
    impl_stage: int = 2

    def apply(self, blk, opts, ctx, off):
        ax = self.pair_axis + off
        n = blk.shape[-1]
        nz2 = n // 2
        c0, cn = blk[..., 0], blk[..., nz2]
        a0 = jax.lax.complex(jnp.real(c0), -jnp.real(cn))
        b0 = jax.lax.complex(-jnp.imag(c0), jnp.imag(cn))
        body = blk[..., 1:nz2]
        tail = jnp.conj(jnp.flip(blk[..., nz2 + 1:], -1))
        ak = body + tail
        bk = 1j * (body - tail)
        A = jnp.concatenate([a0[..., None], ak], axis=-1)
        B = jnp.concatenate([b0[..., None], bk], axis=-1)
        return jnp.concatenate([A, B], axis=ax)

    def transform(self, layout):
        return layout.with_den(self.pair_axis, div=2).with_den(
            self.z_axis, mul=2)

    def describe(self):
        return f"repack2T[{_DIMS[self.pair_axis]}]"


def adjoint_ops(op: StageOp) -> tuple:
    """The transpose of one stage op (a tuple, spliced in adjoint order)."""
    if isinstance(op, PackTwo):
        return (PackTwoT(op.pair_axis),)
    if isinstance(op, SplitPairs):
        return (SplitPairsT(op.pair_axis),)
    if isinstance(op, UnpackTwo):
        return (UnpackTwoT(op.pair_axis, op.z_axis, op.impl_stage),)
    if isinstance(op, RepackHalves):
        return (RepackHalvesT(op.pair_axis, op.nz, op.z_axis, op.impl_stage),)
    if isinstance(op, SpectralScale):
        return (op,)  # x -> alpha * h * x is its own transpose (no conj)
    if isinstance(op, PackTwoT):
        return (PackTwo(op.pair_axis),)
    if isinstance(op, SplitPairsT):
        return (SplitPairs(op.pair_axis),)
    if isinstance(op, UnpackTwoT):
        return (UnpackTwo(op.pair_axis, op.z_axis, op.impl_stage),)
    if isinstance(op, RepackHalvesT):
        return (RepackHalves(op.pair_axis, op.nz, op.z_axis, op.impl_stage),)
    raise ScheduleError(f"no adjoint rule for stage op {op.describe()}")


# ---------------------------------------------------------------------------
# the Schedule -> Schedule transform
# ---------------------------------------------------------------------------

def _renum(op: StageOp, k: int) -> StageOp:
    """Retarget an op's per-stage impl selector at its adjoint slot."""
    if hasattr(op, "impl_stage"):
        return dataclasses.replace(op, impl_stage=k)
    return op


def _chunk_hazards(unit: dict) -> set:
    """Axes a stage with this compute unit must NOT be K-chunked along.

    The executor chunks the whole prologue->fft->epilogue chain, so the
    chunk axis may not be the FFT axis, nor an axis a pack-family op
    slices/concatenates (its pair axis, and the z spectrum axis for the
    folded unpack/repack pair).  A fused k-space multiply consumes a
    full-block operand, so a stage carrying one is never chunkable.
    """
    hz = set()
    if unit["fft_axis"] is not None:
        hz.add(unit["fft_axis"])
    for op in unit["prologue"] + unit["epilogue"]:
        if isinstance(op, SpectralScale):
            hz |= {0, 1, 2}
        if hasattr(op, "pair_axis"):
            hz.add(op.pair_axis)
        if hasattr(op, "z_axis"):
            hz.add(op.z_axis)
    return hz


def adjoint_schedule(sched: Schedule) -> Schedule:
    """The linear transpose of ``sched`` as a first-class schedule.

    Maps cotangents of the forward *output* layout to cotangents of the
    forward *input* layout, reusing the forward plan's communicators,
    chunk axes and (renumbered) per-stage impl choices.  Raises
    :class:`ScheduleError` if the transposed pipeline fails layout
    propagation or does not land back on the forward input layout.
    """
    # compute unit of one forward stage, transposed: the stage chain is
    # prologue -> fft -> epilogue, so its transpose runs the transposed
    # epilogue ops (reversed) -> the same-sign fft -> the transposed
    # prologue ops (reversed).
    def compute_t(st: Stage):
        pro = []
        for op in reversed(st.epilogue):
            pro.extend(adjoint_ops(op))
        epi = []
        for op in reversed(st.prologue):
            epi.extend(adjoint_ops(op))
        if st.fft_axis is None and not pro and not epi:
            return None
        return dict(name=f"adj-{st.name}", fft_axis=st.fft_axis,
                    prologue=tuple(pro), epilogue=tuple(epi))

    def comm_t(st: Stage) -> dict:
        # transposed tiled all_to_all: same communicator, split<->concat
        # swapped; the chunk axis is uninvolved in {split, concat} (an
        # unchanged set), so it stays valid for the adjoint's K-chunking.
        # Per-stage impl/K overrides (searched schedules) ride along: the
        # adjoint of a ring stage is a ring stage over the same wire.
        return dict(comm_axis=st.comm_axis, split_axis=st.concat_axis,
                    concat_axis=st.split_axis, chunk_axis=st.chunk_axis,
                    transpose_impl=st.transpose_impl, overlap_k=st.overlap_k)

    stages = []
    # the terminal epilogue transposes into ops that run FIRST
    lead = []
    for op in reversed(sched.epilogue):
        lead.extend(adjoint_ops(op))
    pending = (dict(name="adj-epilogue", fft_axis=None,
                    prologue=tuple(lead), epilogue=())
               if lead else None)
    for st in reversed(sched.stages):
        if st.comm_axis is not None:
            # this stage's transposed comm executes before its transposed
            # compute: it terminates whatever compute is pending — unless
            # the forced chunk axis (the one axis uninvolved in the
            # transpose) is hazardous for that compute, in which case the
            # compute flushes separately and the comm rides alone
            if pending is not None and st.chunk_axis in _chunk_hazards(pending):
                stages.append(Stage(**pending))
                pending = None
            base = pending or dict(name=f"adj-comm-{st.name}", fft_axis=None,
                                   prologue=(), epilogue=())
            stages.append(Stage(**base, **comm_t(st)))
            pending = None
        unit = compute_t(st)
        if unit is not None:
            if pending is not None:
                stages.append(Stage(**pending))
            pending = unit
    if pending is not None:
        stages.append(Stage(**pending))

    # renumber fft stages 0..2 in adjoint execution order so per-stage
    # local_impl / overlap_mode tuples index naturally
    out, k = [], 0
    for st in stages:
        if st.fft_axis is not None:
            st = dataclasses.replace(
                st, impl_stage=k,
                prologue=tuple(_renum(op, k) for op in st.prologue),
                epilogue=tuple(_renum(op, k) for op in st.epilogue))
            k += 1
        out.append(st)

    extra = tuple(dataclasses.replace(ec, name=f"adj-{ec.name}")
                  for ec in sched.extra_comms)
    adj = Schedule(f"{sched.name}^T", sched.sign, sched.layout_out,
                   tuple(out), extra_comms=extra)
    if str(adj.layout_out) != str(sched.layout_in):
        raise ScheduleError(
            f"adjoint of {sched.name} does not restore the input layout: "
            f"{adj.layout_out} != {sched.layout_in}")
    return adj


# ---------------------------------------------------------------------------
# out-of-body plane transposes (packed pipeline's DC/Nyquist fold/unfold)
# ---------------------------------------------------------------------------

def _herm2(p: jax.Array) -> jax.Array:
    """0.5 * (p + conj(p[-kx, -ky])): self-transpose 2-D Hermitian part."""
    return 0.5 * (p + jnp.conj(packing.negate_freq(
        packing.negate_freq(p, -1), -2)))


def unfold_dc_plane_t(ct: jax.Array) -> jax.Array:
    """Transpose of :func:`repro.real.pipeline.unfold_dc_plane`:
    rfftn-shaped cotangent (..., Nz2 + 1) -> packed cotangent (..., Nz2)
    with bin 0 = Herm2(ct[0]) - i * Herm2(ct[Nz2])."""
    nz2 = ct.shape[-1] - 1
    g = _herm2(ct[..., 0]) - 1j * _herm2(ct[..., nz2])
    return jnp.concatenate([g[..., None], ct[..., 1:nz2]], axis=-1)


def fold_dc_plane_t(pbar: jax.Array, nz: int) -> jax.Array:
    """Transpose of :func:`repro.real.pipeline.fold_dc_plane`: packed
    cotangent (..., Nz2) -> rfftn-shaped cotangent (..., Nz2 + 1)."""
    p0 = pbar[..., 0]
    y0 = _herm2(p0)
    yn = 0.5j * (p0 - jnp.conj(packing.negate_freq(
        packing.negate_freq(p0, -1), -2)))
    return jnp.concatenate([y0[..., None], pbar[..., 1:], yn[..., None]],
                           axis=-1)
