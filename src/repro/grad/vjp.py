"""``custom_vjp`` wiring: plan-reusing backward passes for every entry point.

Without this module, ``jax.grad`` through the distributed transform would
differentiate the ``shard_map`` body op by op — impossible for the
pairwise transpose (``optimization_barrier`` has no differentiation
rule) and plan-oblivious everywhere else.  Here each entry point gets a
``jax.custom_vjp`` whose backward pass runs the *adjoint schedule*
(:func:`repro.grad.adjoint.adjoint_schedule`) under the same executor,
options, overlap engine and transpose impl as the forward — so the
backward HLO has exactly the forward schedule's collective structure,
and the tuner can price a training step as forward + adjoint.

Scaling: norm factors are real scalars, so the transpose of
``x -> scale * F x`` is ``ct -> scale * F^T ct`` — the same ``scale``
rides both directions.  All linear paths are residual-free (the vjp
closes over the plan, not activations); only the filtered transform
stores one spectrum, needed for the filter's own gradient.

Everything is cached per ``(mesh, schedule, opts, scale, nbatch)`` so
repeated calls (``Croft3D``'s jitted entry points, the tuner's
measurement loop) reuse one ``custom_vjp`` instance per plan.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import shard_map
from repro.core import schedule as schedule_lib
from repro.grad.adjoint import (adjoint_schedule, fold_dc_plane_t,
                                unfold_dc_plane_t)


def _with_batch(spec, n: int):
    if n == 0:
        return spec
    return P(*((None,) * n), *spec)


def _scaled(y: jax.Array, scale) -> jax.Array:
    return y if scale is None else y * jnp.asarray(scale, y.dtype)


def _runner(mesh, sched, opts, scale, in_spec, out_spec, operands=None):
    """shard_map(run_schedule) with the scalar norm folded in-body."""
    def body(blk, *ops_blocks):
        ctx = dict(zip(operands or (), ops_blocks))
        out = schedule_lib.run_schedule(blk, sched, opts, operands=ctx)
        return _scaled(out, scale)
    return shard_map(body, mesh=mesh, in_specs=in_spec, out_specs=out_spec)


# ---------------------------------------------------------------------------
# complex transform (distributed_fft3d's body): y = scale * F x
# ---------------------------------------------------------------------------

class LinearPlan:
    """A schedule + its adjoint as a ``custom_vjp``-wrapped callable.

    ``apply`` is the forward (identical ops to the pre-grad path, so
    primal results and HLO are unchanged); its vjp runs ``adjoint`` —
    the transposed schedule under the same options.  ``adjoint`` is also
    exposed raw for composition (the filtered transform, the tuner's
    backward-only timings).
    """

    def __init__(self, mesh: Mesh, sched: schedule_lib.Schedule, opts,
                 scale, nbatch: int):
        self.schedule = sched
        self.adjoint_schedule = adjoint_schedule(sched)
        in_spec = _with_batch(sched.layout_in.partition_spec(), nbatch)
        out_spec = _with_batch(sched.layout_out.partition_spec(), nbatch)

        def fwd(x):
            return _runner(mesh, sched, opts, scale, in_spec, out_spec)(x)

        def adj(ct):
            return _runner(mesh, self.adjoint_schedule, opts, scale,
                           out_spec, in_spec)(ct)

        f = jax.custom_vjp(fwd)
        f.defvjp(lambda x: (fwd(x), None), lambda _, ct: (adj(ct),))
        self.apply = f
        self.adjoint = adj


@functools.lru_cache(maxsize=512)
def linear_plan(mesh: Mesh, sched: schedule_lib.Schedule, opts, scale,
                nbatch: int = 0) -> LinearPlan:
    return LinearPlan(mesh, sched, opts, scale, nbatch)


@functools.lru_cache(maxsize=512)
def filtered_plan(mesh: Mesh, sched: schedule_lib.Schedule, opts, scale,
                  nbatch: int = 0):
    """``(x, h) -> scale * (h * F x)`` differentiable in both arguments.

    The primal keeps the fused in-schedule epilogue (``SpectralScale``
    as a terminal schedule op — no extra pass over the spectrum when not
    differentiating).  Under differentiation the forward runs unfused so
    the pre-filter spectrum ``s`` can be saved: the cotangent of ``x``
    is the adjoint schedule applied to ``h * ct`` (the k-space multiply
    is its own transpose under JAX's unconjugated ``mul`` rule), and the
    cotangent of ``h`` is ``s * ct``.
    """
    lin = linear_plan(mesh, sched, opts, scale, nbatch)
    fused = sched.with_epilogue(schedule_lib.SpectralScale())
    in_spec = _with_batch(sched.layout_in.partition_spec(), nbatch)
    out_spec = _with_batch(sched.layout_out.partition_spec(), nbatch)

    def primal(x, h):
        return _runner(mesh, fused, opts, scale, (in_spec, out_spec),
                       out_spec, operands=("filter",))(x, h)

    def fwd(x, h):
        from repro.kernels import spectral_scale as ss
        s = lin.apply(x)
        return ss.spectral_scale(s, h), (s, h)

    def bwd(res, ct):
        from repro.kernels import spectral_scale as ss
        s, h = res
        return lin.adjoint(ss.spectral_scale(ct, h)), ss.spectral_scale(ct, s)

    f = jax.custom_vjp(primal)
    f.defvjp(fwd, bwd)
    return f


# ---------------------------------------------------------------------------
# packed real transforms (the r2c/c2r pipelines of repro.real.pipeline)
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=512)
def packed_rfft_plan(mesh: Mesh, decomp, opts, scale, nbatch: int = 0):
    """Linear core of ``packed_rfft3d``: real x -> rfftn-style spectrum.

    Forward: packed body -> z-localizing reshard -> DC/Nyquist plane
    unfold -> norm scale.  Backward (the transpose, right to left):
    scale -> plane-unfold transpose -> reshard -> adjoint body, ending in
    the transposed pack (a real cotangent, matching the real input).
    """
    from repro.real import pipeline
    sched = pipeline.build_packed_forward(decomp)
    adj = adjoint_schedule(sched)
    in_spec = _with_batch(sched.layout_in.partition_spec(), nbatch)
    body_spec = _with_batch(sched.layout_out.partition_spec(), nbatch)
    spect_sh = NamedSharding(mesh, _with_batch(decomp.spectral_spec(), nbatch))

    def fwd(x):
        packed = pipeline.constrain_sharding(
            _runner(mesh, sched, opts, None, in_spec, body_spec)(x), spect_sh)
        y = pipeline.constrain_sharding(
            pipeline.unfold_dc_plane(packed), spect_sh)
        return _scaled(y, scale)

    def adj_fn(ct):
        ctp = unfold_dc_plane_t(
            pipeline.constrain_sharding(_scaled(ct, scale), spect_sh))
        return _runner(mesh, adj, opts, None, body_spec, in_spec)(ctp)

    f = jax.custom_vjp(fwd)
    f.defvjp(lambda x: (fwd(x), None), lambda _, ct: (adj_fn(ct),))
    return f


@functools.lru_cache(maxsize=512)
def packed_rfft_folded_plan(mesh: Mesh, decomp, opts, scale, nbatch: int = 0,
                            h_nbatch: int = 0):
    """Folded-epilogue variant: ``(x, h_packed) -> scale * unfold(h_p * body(x))``.

    The filter rides the packed half spectrum *before* the plane unfold
    (one fused in-schedule multiply on Nz/2 bins instead of a separate
    pass over Nz/2 + 1), valid when ``h(kz=0) == h(kz=Nyquist)`` and
    that plane is 2-D Hermitian.  The gradient is the gradient of this
    implemented map: ``h_packed``'s cotangent is ``body(x) * unfoldT(ct)``
    (the primal never reads the filter's Nyquist plane).
    """
    from repro.real import pipeline
    sched = pipeline.build_packed_forward(decomp)
    adj = adjoint_schedule(sched)
    fused = sched.with_epilogue(schedule_lib.SpectralScale())
    in_spec = _with_batch(sched.layout_in.partition_spec(), nbatch)
    body_spec = _with_batch(sched.layout_out.partition_spec(), nbatch)
    h_spec = _with_batch(sched.layout_out.partition_spec(), h_nbatch)
    spect_sh = NamedSharding(mesh, _with_batch(decomp.spectral_spec(), nbatch))

    def primal(x, hp):
        bf = pipeline.constrain_sharding(
            _runner(mesh, fused, opts, None, (in_spec, h_spec), body_spec,
                    operands=("filter",))(x, hp), spect_sh)
        return _scaled(pipeline.unfold_dc_plane(bf), scale)

    def fwd(x, hp):
        b = pipeline.constrain_sharding(
            _runner(mesh, sched, opts, None, in_spec, body_spec)(x), spect_sh)
        from repro.kernels import spectral_scale as ss
        y = _scaled(pipeline.unfold_dc_plane(ss.spectral_scale(b, hp)), scale)
        return y, (b, hp)

    def bwd(res, ct):
        from repro.kernels import spectral_scale as ss
        b, hp = res
        ctu = unfold_dc_plane_t(
            pipeline.constrain_sharding(_scaled(ct, scale), spect_sh))
        xb = _runner(mesh, adj, opts, None, body_spec, in_spec)(
            ss.spectral_scale(ctu, hp))
        hb = ss.spectral_scale(ctu, b)
        if h_nbatch < nbatch:  # unbatched filter over a batched field
            hb = hb.sum(axis=tuple(range(nbatch - h_nbatch)))
        return xb, hb

    f = jax.custom_vjp(primal)
    f.defvjp(fwd, bwd)
    return f


@functools.lru_cache(maxsize=512)
def packed_irfft_plan(mesh: Mesh, decomp, nz: int, opts, scale,
                      nbatch: int = 0):
    """Linear core of ``packed_irfft3d``: rfftn-style spectrum -> real x.

    Forward: DC/Nyquist plane fold -> packed inverse body -> norm scale.
    Backward: scale -> adjoint body -> plane-fold transpose.
    """
    from repro.real import pipeline
    sched = pipeline.build_packed_inverse(decomp, nz)
    adj = adjoint_schedule(sched)
    in_spec = _with_batch(sched.layout_in.partition_spec(), nbatch)
    out_spec = _with_batch(sched.layout_out.partition_spec(), nbatch)
    spect_sh = NamedSharding(mesh, _with_batch(decomp.spectral_spec(), nbatch))

    def fwd(y):
        packed = pipeline.fold_dc_plane(
            pipeline.constrain_sharding(y, spect_sh), nz)
        return _scaled(_runner(mesh, sched, opts, None, in_spec,
                               out_spec)(packed), scale)

    def adj_fn(ct):
        pbar = pipeline.constrain_sharding(
            _runner(mesh, adj, opts, None, out_spec, in_spec)(
                _scaled(ct, scale)), spect_sh)
        return pipeline.constrain_sharding(fold_dc_plane_t(pbar, nz), spect_sh)

    f = jax.custom_vjp(fwd)
    f.defvjp(lambda y: (fwd(y), None), lambda _, ct: (adj_fn(ct),))
    return f
