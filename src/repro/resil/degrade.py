"""Degradation ladders — the next-known-good plan when the best one fails.

FFTW-style planner-in-production systems treat a degraded-but-correct
fallback as a first-class citizen: a searched schedule that stops
compiling, a measured plan that keeps producing non-finite output, or a
tuner that cannot build its winner must *degrade*, not take the service
down.  The ladder (most to least sophisticated):

    searched schedule   ->  fixed tuned (same decomp/opts, no schedule)
    packed r2c          ->  embed r2c (same decomp/opts)
    any fixed plan      ->  default decomposition, alltoall, K=1

Every rung is bitwise-equal to every other on finite inputs (the
transpose-impl/K/strategy parity matrix pinned since PR 5), so walking
down trades only performance, never correctness — which is exactly what
``benchmarks/chaos_bench.py`` gates: a degraded bucket's results must
equal the direct fallback-plan transform bit for bit.

All repro imports are function-local so this module is importable from
anywhere (``repro.core`` included) without import cycles.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

#: rung names, best to worst ("primary" is whatever the tuner picked)
RUNGS = ("primary", "fixed", "embed", "default")


def bottom_candidate(shape, axis_sizes, problem: str = "c2c"):
    """The ladder's last rung: the mesh-rank default decomposition with
    the most conservative options — fused alltoall transposes, no
    overlap chunking (K=1), and the embed strategy for r2c (the packed
    pipeline is the thing being degraded away from).  None when even
    that is invalid for the shape."""
    from repro.tuning.candidates import default_candidate
    cand = default_candidate(shape, axis_sizes, problem)
    if cand is None:
        return None
    opts = dataclasses.replace(cand.opts, transpose_impl="alltoall",
                               overlap_k=1, overlap_mode="pipelined",
                               local_impl="matmul")
    strategy = "embed" if cand.problem == "r2c" else None
    return dataclasses.replace(cand, opts=opts, strategy=strategy)


def next_rung(cand, shape, axis_sizes) -> Optional[tuple]:
    """One step down from candidate ``cand``: ``(rung_name, candidate)``,
    or None when ``cand`` already is the bottom rung."""
    from repro.tuning.candidates import Candidate
    if cand is None:
        return None
    if getattr(cand, "is_schedule", False):
        # searched -> fixed: keep the data placement, drop the schedule
        fixed = Candidate(cand.decomp, cand.opts, problem=cand.problem,
                          strategy=getattr(cand, "strategy", None))
        return "fixed", fixed
    if cand.problem == "r2c" and getattr(cand, "strategy", None) == "packed":
        return "embed", dataclasses.replace(cand, strategy="embed")
    bottom = bottom_candidate(shape, axis_sizes, cand.problem)
    if bottom is None or bottom.plan_key == cand.plan_key:
        return None
    return "default", bottom


def ladder(plan) -> list:
    """Every rung strictly below ``plan``, best first, as
    ``(rung_name, candidate)`` pairs.  Meshless plans have no ladder
    (the single-device plan already is the only plan)."""
    if getattr(plan, "mesh", None) is None:
        return []
    axis_sizes = dict(plan.mesh.shape)
    out = []
    cand = plan.candidate()
    while True:
        step = next_rung(cand, plan.shape, axis_sizes)
        if step is None:
            return out
        out.append(step)
        cand = step[1]


def build_plan(plan, cand):
    """A fresh ``Croft3D`` serving ``plan``'s problem with candidate
    ``cand`` — the object a quarantine swaps in for the failed one."""
    from repro.core.api import Croft3D
    return Croft3D(plan.shape, plan.mesh, cand.decomp, cand.opts,
                   dtype=plan.dtype, problem=plan.problem,
                   strategy=getattr(cand, "strategy", None),
                   schedule=cand if getattr(cand, "is_schedule", False)
                   else None)
