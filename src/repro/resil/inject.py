"""Deterministic fault injection — the chaos plane of ``repro.resil``.

Production resilience claims are only testable if failures can be
*scripted*: a chaos gate that asserts "every injected fault maps to
exactly one quarantine/retry/shed event" needs faults that fire at
exactly the scripted call-site invocations, every run.  This module is
that script engine:

  * a :class:`FaultPlan` holds :class:`FaultSpec`\\ s — (site, which
    invocation indices fire, what kind of failure, an optional key
    filter) — plus a log of everything that actually fired, so a bench
    can diff predicted-vs-observed exactly;
  * instrumented call sites are written ``inject.fire("site", key)``
    (raising sites) or ``inject.corrupt("site", key)`` (value-poisoning
    sites).  With no plan installed both are one module-global read and
    a ``None`` check — the same zero-cost-when-disabled contract as
    ``repro.obs.tracer``, and nothing here runs inside ``jit`` except
    the trace-time ``corrupt`` check, which inserts no op when disabled
    (HLO byte-identity is pinned in tests/test_resil.py).

Named sites (grep for the string to find the call site):

  ``plan.build``         PlanCache._build — tuned plan construction
  ``plan.upgrade``       PlanCache._upgrade — background measure re-plan
  ``serve.dispatch``     TransformService batch dispatch (keyed by bucket)
  ``wisdom.write.crash`` Wisdom.save, between temp-write and atomic rename
  ``tune.measure``       tuning.measure.measure_candidate timing run
  ``exec.output``        run_schedule output poisoning (trace-time: only
                         executables *compiled while armed* are affected)

Determinism: explicit ``times`` tuples are exact by construction; for
randomized scripts, :func:`seeded_times` derives the firing indices from
``(seed, site)`` so a bench can compute its prediction from the same
seed it arms the plan with.
"""

from __future__ import annotations

import contextlib
import dataclasses
import random
import threading
from typing import Optional, Sequence

SITES = ("plan.build", "plan.upgrade", "serve.dispatch",
         "wisdom.write.crash", "tune.measure", "exec.output")


class InjectedFault(RuntimeError):
    """A scripted fault fired at a named site."""

    def __init__(self, site: str, key: str = "", index: int = 0):
        super().__init__(f"injected fault at {site}"
                         + (f" [{key}]" if key else "") + f" #{index}")
        self.site = site
        self.key = key
        self.index = index


class TransientFault(InjectedFault):
    """A retryable fault: the dispatch retry loop may re-attempt."""


class CrashMidWrite(InjectedFault):
    """The process 'dies' between temp-write and atomic rename."""


_KIND_EXC = {"error": InjectedFault, "transient": TransientFault,
             "crash": CrashMidWrite, "nan": InjectedFault}
KINDS = tuple(_KIND_EXC)


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One scripted failure: where, when, what, and to whom.

    ``times`` are 0-based indices into the stream of *this spec's
    matching invocations* of ``site`` (``match`` filters first, then the
    index counts) — ``None`` means every matching invocation fires.
    ``kind`` picks the failure mode: "error" (InjectedFault), "transient"
    (TransientFault, retryable), "crash" (CrashMidWrite), "nan" (value
    poisoning — only meaningful at ``corrupt`` sites).
    """

    site: str
    times: Optional[tuple] = None
    kind: str = "error"
    match: Optional[str] = None

    def __post_init__(self):
        if self.kind not in _KIND_EXC:
            raise ValueError(f"kind must be one of {KINDS}, "
                             f"got {self.kind!r}")
        if self.times is not None:
            object.__setattr__(self, "times",
                               tuple(int(t) for t in self.times))


class FaultPlan:
    """A set of :class:`FaultSpec`\\ s plus exact firing bookkeeping.

    Thread-safe: the serve worker, upgrade threads and client threads
    may all consult sites concurrently; per-spec invocation counters and
    the fired log are guarded by one lock (sites with no spec never take
    it).
    """

    def __init__(self, specs: Sequence[FaultSpec] = (), seed: int = 0):
        self.seed = int(seed)
        self.specs = tuple(specs)
        self._by_site: dict[str, list[tuple[int, FaultSpec]]] = {}
        for i, s in enumerate(self.specs):
            self._by_site.setdefault(s.site, []).append((i, s))
        self._spec_counts: dict[int, int] = {}
        #: (site, key, index, kind) tuples, in firing order
        self.fired: list[tuple] = []
        self._lock = threading.Lock()

    def check(self, site: str, key: str = "") -> Optional[tuple]:
        """Count one invocation of ``site``; return ``(spec, index)`` if
        a spec fires on it, else None.  Sites with no spec return None
        without taking the lock (zero bookkeeping off-script)."""
        specs = self._by_site.get(site)
        if not specs:
            return None
        with self._lock:
            for spec_id, spec in specs:
                if spec.match is not None and spec.match not in key:
                    continue
                idx = self._spec_counts.get(spec_id, 0)
                self._spec_counts[spec_id] = idx + 1
                if spec.times is not None and idx not in spec.times:
                    continue
                self.fired.append((site, key, idx, spec.kind))
                return spec, idx
        return None

    def fired_counts(self) -> dict:
        """Observed firings per site — what a chaos gate diffs against
        :meth:`predicted_counts`."""
        out: dict[str, int] = {}
        with self._lock:
            for site, _key, _idx, _kind in self.fired:
                out[site] = out.get(site, 0) + 1
        return out

    def predicted_counts(self) -> dict:
        """Scripted firings per site (specs with ``times=None`` fire an
        input-dependent number of times and predict ``None``)."""
        out: dict = {}
        for s in self.specs:
            if s.times is None or out.get(s.site, 0) is None:
                out[s.site] = None
            else:
                out[s.site] = out.get(s.site, 0) + len(s.times)
        return out


def seeded_times(seed: int, site: str, n_invocations: int,
                 n_faults: int) -> tuple:
    """Deterministically pick ``n_faults`` firing indices out of
    ``n_invocations`` from ``(seed, site)`` — the bench computes its
    prediction from the same call it builds the script with."""
    rng = random.Random(f"{int(seed)}:{site}")
    return tuple(sorted(rng.sample(range(n_invocations), n_faults)))


# -- module slot (mirrors repro.obs.tracer's global-tracer pattern) ----------

_plan: Optional[FaultPlan] = None
_plan_lock = threading.Lock()


def get_plan() -> Optional[FaultPlan]:
    return _plan


def install(plan: Optional[FaultPlan]) -> None:
    global _plan
    with _plan_lock:
        _plan = plan


def clear() -> None:
    install(None)


@contextlib.contextmanager
def injection(specs_or_plan, seed: int = 0):
    """Arm a fault plan for the scope; always disarms on exit."""
    plan = (specs_or_plan if isinstance(specs_or_plan, FaultPlan)
            else FaultPlan(specs_or_plan, seed=seed))
    install(plan)
    try:
        yield plan
    finally:
        clear()


def _count(site: str) -> None:
    # lazy import: inject must be importable from anywhere (core included)
    # without dragging repro.obs in at module-import time
    from repro.obs import metrics as metrics_lib
    reg = metrics_lib.get_registry()
    reg.counter("faults_injected").inc()
    reg.counter("fault_" + site.replace(".", "_")).inc()


def fire(site: str, key: str = "") -> None:
    """Raising site: no-op unless an armed spec matches this invocation,
    in which case the spec's exception type is raised."""
    plan = _plan
    if plan is None:
        return
    hit = plan.check(site, str(key))
    if hit is None:
        return
    spec, idx = hit
    _count(site)
    raise _KIND_EXC[spec.kind](site, str(key), idx)


def corrupt(site: str, key: str = "") -> bool:
    """Value-poisoning site: True when the armed plan says this
    invocation's output should be corrupted (the call site applies the
    poison — e.g. a NaN multiply at trace time)."""
    plan = _plan
    if plan is None:
        return False
    hit = plan.check(site, str(key))
    if hit is None:
        return False
    _count(site)
    return True
