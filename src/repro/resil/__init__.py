"""repro.resil — seeded fault injection and degradation ladders.

The robustness layer (ISSUE 10): production serving must survive failed
plan builds, corrupt wisdom files, transient dispatch errors and
poisoned payloads without hanging a single future — and CI must be able
to *prove* it, deterministically.  Two pieces:

  * :mod:`repro.resil.inject` — a scripted, seeded fault-injection
    plane with named sites threaded through plan build, batch dispatch,
    wisdom IO, measure mode and executor outputs.  Zero-cost no-op when
    disarmed (the ``repro.obs`` tracer contract: enabling cannot change
    compiled HLO, pinned in tests).
  * :mod:`repro.resil.degrade` — the plan degradation ladder (searched
    schedule -> fixed tuned -> default/alltoall/K1; packed r2c ->
    embed).  ``PlanCache`` walks it when a plan's build fails or its
    dispatches keep failing (quarantine), and every rung stays bitwise
    equal on finite inputs.

``benchmarks/chaos_bench.py`` drives a seeded fault script through the
transform service and gates ``BENCH_chaos.json`` on exact counter
equality: every injected fault maps to exactly one observed
quarantine / retry / shed / degradation event.
"""

from repro.resil import degrade, inject  # noqa: F401
from repro.resil.inject import (CrashMidWrite, FaultPlan,  # noqa: F401
                                FaultSpec, InjectedFault, TransientFault,
                                injection, seeded_times)

__all__ = [
    "CrashMidWrite", "FaultPlan", "FaultSpec", "InjectedFault",
    "TransientFault", "degrade", "inject", "injection", "seeded_times",
]
