"""repro: CROFT-style distributed 3-D FFT reproduction on JAX.

Importing the package installs the JAX version-compat shims (see
``repro.compat``) so every subpackage, test snippet, and example can be
written against the newer jax surface.
"""

from repro import compat as _compat

_compat.install()
