"""Metrics registry: counters, gauges, log-bucketed histograms.

One named registry per subsystem (the transform service owns one; the
tuner and benchmarks share the process-default one).  Two export
formats from the same objects:

  * :meth:`MetricsRegistry.snapshot` — JSON-able dict, embedded into
    ``BENCH_*.json`` so bench artifacts and live metrics share a schema;
  * :meth:`MetricsRegistry.to_prometheus` — Prometheus text exposition
    (``# TYPE`` headers, ``_bucket{le=...}`` cumulative histograms) for
    scraping a long-running service.

Histograms are log-bucketed by default (geometric bucket edges, so the
p99 of a microsecond-to-second latency range costs ~100 buckets, not
10^6) with interpolated quantile estimation — accuracy is bounded by
the bucket growth factor, pinned against numpy in tests/test_obs.py.
Exact small-integer distributions (batch sizes) use explicit ``bounds``
instead.
"""

from __future__ import annotations

import bisect
import json
import math
import re
import threading
from typing import Optional, Sequence

_NAME_RE = re.compile(r"[^a-zA-Z0-9_:]")


def _prom_name(name: str) -> str:
    return _NAME_RE.sub("_", name)


class Counter:
    """Monotonic counter."""

    kind = "counter"

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self._value = 0.0
        self._lock = threading.Lock()

    def inc(self, n: float = 1.0) -> None:
        if n < 0:
            raise ValueError("counters only go up")
        with self._lock:
            self._value += n

    @property
    def value(self) -> float:
        return self._value

    def snapshot(self) -> dict:
        return {"type": "counter", "value": self._value}


class Gauge:
    """Last-value gauge."""

    kind = "gauge"

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self._value = 0.0
        self._lock = threading.Lock()

    def set(self, v: float) -> None:
        with self._lock:
            self._value = float(v)

    def inc(self, n: float = 1.0) -> None:
        with self._lock:
            self._value += n

    def dec(self, n: float = 1.0) -> None:
        self.inc(-n)

    @property
    def value(self) -> float:
        return self._value

    def snapshot(self) -> dict:
        return {"type": "gauge", "value": self._value}


class Histogram:
    """Log-bucketed histogram with interpolated quantiles.

    Default buckets are geometric: edge ``i`` is ``lo * growth**i`` (64
    of them span ``[1us, ~1000s]`` at the default growth of 1.4), so a
    quantile estimate is exact to within one growth factor — the
    linear interpolation inside the winning bucket cuts that further.
    ``bounds`` overrides with explicit edges (exact integer histograms
    like batch sizes: ``bounds=range(1, max_batch + 1)``).
    """

    kind = "histogram"

    def __init__(self, name: str, help: str = "", lo: float = 1e-6,
                 growth: float = 1.4, n_buckets: int = 64,
                 bounds: Optional[Sequence[float]] = None):
        self.name = name
        self.help = help
        if bounds is not None:
            self.bounds = [float(b) for b in bounds]
            if self.bounds != sorted(self.bounds):
                raise ValueError("bounds must be sorted")
        else:
            if lo <= 0 or growth <= 1:
                raise ValueError("need lo > 0 and growth > 1")
            self.bounds = [lo * growth ** i for i in range(n_buckets)]
        self._counts = [0] * (len(self.bounds) + 1)  # last = +Inf overflow
        self._count = 0
        self._sum = 0.0
        self._min = math.inf
        self._max = -math.inf
        self._lock = threading.Lock()

    def observe(self, v: float) -> None:
        v = float(v)
        i = bisect.bisect_left(self.bounds, v)
        with self._lock:
            self._counts[i] += 1
            self._count += 1
            self._sum += v
            if v < self._min:
                self._min = v
            if v > self._max:
                self._max = v

    # -- reads ----------------------------------------------------------
    @property
    def count(self) -> int:
        return self._count

    @property
    def sum(self) -> float:
        return self._sum

    @property
    def mean(self) -> float:
        return self._sum / self._count if self._count else 0.0

    def buckets(self) -> list:
        """[(upper_edge, cumulative_count)] including the +Inf bucket."""
        with self._lock:
            counts = list(self._counts)
        out, cum = [], 0
        for edge, c in zip(self.bounds, counts):
            cum += c
            out.append((edge, cum))
        out.append((math.inf, cum + counts[-1]))
        return out

    def quantile(self, q: float) -> Optional[float]:
        """Interpolated quantile estimate (None when empty).

        Rank ``q * count`` is located in the cumulative bucket counts;
        the estimate interpolates linearly across the winning bucket's
        [lower, upper) edge range, clamped to the observed min/max so
        single-bucket distributions report honest extremes.
        """
        with self._lock:
            counts = list(self._counts)
            count, vmin, vmax = self._count, self._min, self._max
        if not count:
            return None
        q = min(1.0, max(0.0, q))
        rank = q * count
        cum = 0.0
        for i, c in enumerate(counts):
            if c == 0:
                continue
            if cum + c >= rank:
                lower = self.bounds[i - 1] if i > 0 else min(
                    vmin, self.bounds[0])
                upper = self.bounds[i] if i < len(self.bounds) else vmax
                frac = (rank - cum) / c
                est = lower + frac * (upper - lower)
                return min(max(est, vmin), vmax)
            cum += c
        return vmax

    def snapshot(self) -> dict:
        with self._lock:
            counts = list(self._counts)
            count, total = self._count, self._sum
            vmin = self._min if count else None
            vmax = self._max if count else None
        # sparse bucket map (log histograms are mostly empty)
        nonzero = {("+Inf" if i == len(self.bounds) else repr(self.bounds[i])):
                   c for i, c in enumerate(counts) if c}
        return {"type": "histogram", "count": count, "sum": total,
                "min": vmin, "max": vmax, "buckets": nonzero,
                "p50": self.quantile(0.50), "p90": self.quantile(0.90),
                "p99": self.quantile(0.99)}


class MetricsRegistry:
    """Named metric store: get-or-create, snapshot, Prometheus text."""

    def __init__(self):
        self._metrics: dict = {}
        self._lock = threading.Lock()

    def _get(self, name: str, factory):
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = self._metrics[name] = factory()
            return m

    def counter(self, name: str, help: str = "") -> Counter:
        m = self._get(name, lambda: Counter(name, help))
        if not isinstance(m, Counter):
            raise TypeError(f"{name!r} is a {m.kind}, not a counter")
        return m

    def gauge(self, name: str, help: str = "") -> Gauge:
        m = self._get(name, lambda: Gauge(name, help))
        if not isinstance(m, Gauge):
            raise TypeError(f"{name!r} is a {m.kind}, not a gauge")
        return m

    def histogram(self, name: str, help: str = "", **kw) -> Histogram:
        m = self._get(name, lambda: Histogram(name, help, **kw))
        if not isinstance(m, Histogram):
            raise TypeError(f"{name!r} is a {m.kind}, not a histogram")
        return m

    def get(self, name: str):
        with self._lock:
            return self._metrics.get(name)

    def names(self) -> list:
        with self._lock:
            return sorted(self._metrics)

    # -- export ---------------------------------------------------------
    def snapshot(self) -> dict:
        with self._lock:
            metrics = dict(self._metrics)
        return {name: m.snapshot() for name, m in sorted(metrics.items())}

    def snapshot_json(self, **kw) -> str:
        return json.dumps(self.snapshot(), **kw)

    def to_prometheus(self) -> str:
        """Prometheus text exposition format (histograms cumulative)."""
        with self._lock:
            metrics = dict(self._metrics)
        lines = []
        for name, m in sorted(metrics.items()):
            pname = _prom_name(name)
            if m.help:
                lines.append(f"# HELP {pname} {m.help}")
            lines.append(f"# TYPE {pname} {m.kind}")
            if isinstance(m, (Counter, Gauge)):
                lines.append(f"{pname} {m.value:g}")
            else:
                for edge, cum in m.buckets():
                    le = "+Inf" if math.isinf(edge) else f"{edge:g}"
                    lines.append(f'{pname}_bucket{{le="{le}"}} {cum}')
                lines.append(f"{pname}_sum {m.sum:g}")
                lines.append(f"{pname}_count {m.count}")
        return "\n".join(lines) + "\n"


# ---------------------------------------------------------------------------
# process-default registry (the tuner, benches, and CLIs share it; the
# transform service owns its own so two services never mix counters)
# ---------------------------------------------------------------------------

_default = MetricsRegistry()
_default_lock = threading.Lock()


def get_registry() -> MetricsRegistry:
    return _default


def set_registry(reg: MetricsRegistry) -> None:
    global _default
    with _default_lock:
        _default = reg
