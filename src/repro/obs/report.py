"""Attribution report: join traced timings against the analytic model.

    python -m repro.obs.report trace.json [--json]

Reads a Chrome-trace JSON produced by :mod:`repro.obs.tracer` (the
``metadata.attribution`` entries that :func:`repro.obs.instrument.
trace_forward` attaches carry the per-stage measured/model rows) and
prints, per plan, a model-vs-measured table:

  * measured wall / fft-leg / collective-leg seconds per stage,
  * the model's predicted compute/collective split for the same stage
    (``tuning.cost_model.per_stage_costs``),
  * the **overlap efficiency** — fraction of collective time hidden
    under compute — measured vs modeled, per stage and overall (the
    paper's 42-51% claim, per stage).

Traces without attribution metadata (e.g. a serve run) still get a
per-category wall-time rollup from the raw span stream.
"""

from __future__ import annotations

import argparse
import json
import sys


def _fmt_s(v) -> str:
    if v is None:
        return "-"
    if v >= 0.1:
        return f"{v:8.3f}s"
    if v >= 1e-4:
        return f"{v * 1e3:7.3f}ms"
    return f"{v * 1e6:7.3f}us"


def _fmt_pct(v) -> str:
    return "-" if v is None else f"{100.0 * v:5.1f}%"


def category_rollup(events) -> dict:
    """Total wall microseconds per span category ("X" events only)."""
    out: dict = {}
    for ev in events:
        if ev.get("ph") != "X":
            continue
        cat = ev.get("cat", "?")
        out[cat] = out.get(cat, 0.0) + float(ev.get("dur", 0.0))
    return dict(sorted(out.items(), key=lambda kv: -kv[1]))


def render_plan(summary) -> str:
    shape = "x".join(str(n) for n in summary.get("shape", []))
    lines = [f"plan {summary['plan']}  shape {shape}  "
             f"transpose={summary.get('transpose_impl')} "
             f"K={summary.get('overlap_k')}  e2e {_fmt_s(summary['e2e_s'])}"]
    if summary.get("note"):
        lines.append(f"  note: {summary['note']}")
    stages = summary.get("stages") or []
    if stages:
        hdr = (f"  {'stage':<14} {'cat':<10} {'k':>2} {'wall':>10} "
               f"{'fft':>10} {'comm':>10} {'mdl comp':>10} {'mdl coll':>10} "
               f"{'eff meas':>8} {'eff mdl':>8}")
        lines.append(hdr)
        lines.append("  " + "-" * (len(hdr) - 2))
    for row in stages:
        model = row.get("model") or {}
        lines.append(
            f"  {row['name']:<14} {row['category']:<10} {row['k_eff']:>2} "
            f"{_fmt_s(row.get('wall_s')):>10} {_fmt_s(row.get('fft_s')):>10} "
            f"{_fmt_s(row.get('comm_s')):>10} "
            f"{_fmt_s(model.get('compute_s')):>10} "
            f"{_fmt_s(model.get('collective_s')):>10} "
            f"{_fmt_pct(row.get('measured_efficiency')):>8} "
            f"{_fmt_pct(model.get('predicted_efficiency')):>8}")
    overall = summary.get("overall")
    if overall:
        model_rows = [r.get("model") or {} for r in stages]
        mc = sum(m.get("collective_s") or 0.0 for m in model_rows)
        mh = sum(m.get("hidden_s") or 0.0 for m in model_rows)
        lines.append(
            f"  overall: collective {_fmt_s(overall['collective_s'])}, "
            f"hidden {_fmt_s(overall['hidden_s'])}, "
            f"overlap efficiency {_fmt_pct(overall['efficiency'])} measured"
            f" vs {_fmt_pct(mh / mc if mc else None)} modeled")
    return "\n".join(lines)


def build_report(doc: dict) -> dict:
    meta = doc.get("metadata") or {}
    events = doc.get("traceEvents") or []
    return {
        "plans": meta.get("attribution") or [],
        "categories_us": category_rollup(events),
        "n_events": len(events),
        "dropped_events": meta.get("dropped_events", 0),
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.report",
        description="model-vs-measured attribution from a repro trace")
    ap.add_argument("trace", help="Chrome-trace JSON written by repro.obs")
    ap.add_argument("--json", action="store_true",
                    help="emit the report as JSON instead of a table")
    args = ap.parse_args(argv)

    with open(args.trace) as f:
        doc = json.load(f)
    report = build_report(doc)

    if args.json:
        json.dump(report, sys.stdout, indent=2, default=str)
        print()
        return 0

    for summary in report["plans"]:
        print(render_plan(summary))
        print()
    if not report["plans"]:
        print("no attribution metadata in trace (raw span rollup only)")
    print(f"span categories ({report['n_events']} events, "
          f"{report['dropped_events']} dropped):")
    for cat, us in report["categories_us"].items():
        print(f"  {cat:<12} {_fmt_s(us / 1e6):>10}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
