"""repro.obs — stage-level tracing, metrics, and overlap attribution.

Three pieces (ISSUE 7):

  * :mod:`repro.obs.tracer` — thread-safe span tracer with Chrome-trace
    JSON export (``chrome://tracing`` / Perfetto) and an in-process ring
    buffer; a no-op tracer is the process default so instrumentation is
    zero-cost until :func:`enable` / :func:`tracing` installs a real one.
  * :mod:`repro.obs.metrics` — named counters, gauges, and log-bucketed
    histograms with quantile estimation; JSON snapshots and Prometheus
    text exposition.
  * :mod:`repro.obs.instrument` / :mod:`repro.obs.report` — re-drive a
    plan's schedule stage by stage with host-side timing shims, attach
    HLO cost attribution, and join measured per-stage timings against
    the analytic cost model (``python -m repro.obs.report trace.json``)
    to produce the overlap-efficiency table the paper's 42–51% hiding
    claim is about.
"""

from repro.obs.tracer import (  # noqa: F401
    CATEGORIES,
    NOOP,
    NoopTracer,
    Tracer,
    current_tags,
    disable,
    enable,
    get_tracer,
    set_tracer,
    tag_scope,
    tracing,
)
from repro.obs.metrics import (  # noqa: F401
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    get_registry,
    set_registry,
)

__all__ = [
    "CATEGORIES", "NOOP", "NoopTracer", "Tracer", "current_tags",
    "disable", "enable", "get_tracer", "set_tracer", "tag_scope",
    "tracing", "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "get_registry", "set_registry",
]
