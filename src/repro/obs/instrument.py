"""Per-stage traced execution: re-drive a plan's schedule with timing shims.

Nothing can be timed *inside* ``jit`` (XLA fuses and reorders; a timer
in the traced body would change the compiled HLO — the zero-cost
guarantee this subsystem pins in tests).  So attribution works by
re-driving the plan's :class:`~repro.core.schedule.Schedule` stage by
stage OUTSIDE the production jit: each stage becomes its own
``jit(shard_map(run_stage))`` whose in/out specs come from the
schedule's symbolic layouts, and the host clocks each dispatch +
``block_until_ready``.  For stages with a collective, the compute leg
(:func:`~repro.core.schedule.stage_pre`) and the collective leg
(:func:`~repro.core.schedule.stage_comm`) are additionally compiled
per K-chunk, so the serialized leg times F (fft) and C (collective)
are real measurements, not model splits.

The **measured overlap efficiency** of a comm stage then falls out of
three wall clocks: with F = serialized compute leg, C = serialized
collective leg, and W = the pipelined full stage,

    hidden = clamp(F + C - W, 0, C)        efficiency = hidden / C

i.e. the fraction of collective time that did NOT extend the stage's
critical path — the per-stage measured form of the paper's 42-51%
hiding claim, joined against ``tuning.cost_model.per_stage_costs``'s
predicted split by ``python -m repro.obs.report``.

Scope: c2c plans on a mesh (the packed real pipeline's stages carry
``den`` factors whose chunk shapes this re-driver does not reproduce;
r2c plans fall back to a single end-to-end span).
"""

from __future__ import annotations

import statistics
import time
from typing import Optional

import jax
from jax.sharding import NamedSharding

from repro.compat import shard_map
from repro.core import schedule as schedule_lib
from repro.launch import hlo_cost
from repro.obs import tracer as tracer_lib


def _timed(tracer, exe, args, name, cat, iters, span_args):
    """Median wall time of ``exe(*args)`` over ``iters`` timed runs (one
    untimed warmup), one span per run; returns (median_s, last_output)."""
    out = exe(*args)
    jax.block_until_ready(out)
    times = []
    for n in range(iters):
        t0 = time.monotonic()
        out = exe(*args)
        jax.block_until_ready(out)
        t1 = time.monotonic()
        times.append(t1 - t0)
        tracer.complete(name, cat, t0, t1, dict(span_args, iter=n))
    return statistics.median(times), out


def _compile(tracer, fn, sds, name):
    with tracer.span(f"compile:{name}", "plan"):
        return jax.jit(fn).lower(sds).compile()


def _sds(mesh, shape, dtype, layout):
    return jax.ShapeDtypeStruct(
        shape, dtype, sharding=NamedSharding(mesh, layout.partition_spec()))


def trace_forward(plan, x, tracer=None, iters: int = 3,
                  label: Optional[str] = None) -> tuple:
    """Run ``plan.forward(x)`` with per-stage/per-chunk attribution.

    Emits spans into ``tracer`` (the process tracer by default), returns
    ``(y, summary)`` where ``y`` is the production ``plan.forward``
    output and ``summary`` the per-stage model-vs-measured rows
    (also attached to the trace metadata under ``"attribution"`` for
    ``repro.obs.report``).  ``x`` must be placed with
    ``plan.input_sharding``.
    """
    if tracer is None:
        tracer = tracer_lib.get_tracer()
    # plan.candidate() — not a hand-built Candidate — so searched
    # schedules attribute under their own pipeline identity/model rows
    cand = plan.candidate() if plan.decomp is not None else None
    label = label or (cand.label if cand is not None else "meshless")

    with tracer.span("e2e", "plan", plan=label):
        t0 = time.monotonic()
        y = plan.forward(x)
        jax.block_until_ready(y)
        e2e_s = time.monotonic() - t0

    summary = {
        "plan": label,
        "plan_key": cand.plan_key if cand is not None else None,
        "shape": list(plan.shape),
        "transpose_impl": plan.opts.transpose_impl,
        "overlap_k": plan.opts.overlap_k,
        "e2e_s": e2e_s,
        "stages": [],
        "overall": None,
    }
    if plan.mesh is None or plan.problem != "c2c":
        summary["note"] = ("per-stage attribution covers c2c mesh plans; "
                           "only the e2e span was recorded")
        _attach(tracer, summary)
        return y, summary

    mesh = plan.mesh
    opts = plan.opts
    axis_sizes = dict(mesh.shape)
    sched = plan._forward_schedule()
    from repro.tuning.cost_model import per_stage_costs
    model_rows = {r["stage"]: r for r in per_stage_costs(
        plan.shape, cand, axis_sizes, plan.dtype)}
    k_effs = dict(zip((i for i, _ in sched.comm_stages()),
                      sched.effective_k(plan.shape, axis_sizes,
                                        opts.overlap_k)))

    cur = x.astype(plan.dtype)
    total_c = total_hidden = 0.0
    for i, (st, pts) in enumerate(zip(sched.stages, sched.points)):
        cat = schedule_lib.stage_category(st)
        in_sds = _sds(mesh, cur.shape, plan.dtype, pts.entry)

        def full(blk, st=st):
            return schedule_lib.run_stage(blk, st, sched.sign, opts)

        exe = _compile(
            tracer, shard_map(full, mesh=mesh,
                              in_specs=pts.entry.partition_spec(),
                              out_specs=pts.out.partition_spec()),
            in_sds, f"s{i}:{st.name}")
        hlo = hlo_cost.summarize(hlo_cost.analyze_compiled(exe))

        row = dict(stage=i, name=st.name, category=cat,
                   k_eff=k_effs.get(i, 1), model=model_rows.get(i),
                   hlo=hlo)
        span_args = {"stage": i, "plan": label, "part": "stage",
                     "k_eff": row["k_eff"], **hlo}
        wall, out = _timed(tracer, exe, (cur,), f"s{i}:{st.name}", cat,
                           iters, span_args)
        row["wall_s"] = wall

        if st.comm_axis is not None:
            fft_s, comm_s, rounds = _split_legs(
                tracer, plan, sched, i, st, pts, cur, row["k_eff"], iters,
                label)
            hidden = min(max(fft_s + comm_s - wall, 0.0), comm_s)
            row.update(fft_s=fft_s, comm_s=comm_s, hidden_s=hidden,
                       measured_efficiency=(hidden / comm_s if comm_s
                                            else None))
            if rounds:
                row["rounds"] = rounds
            total_c += comm_s
            total_hidden += hidden
        else:
            row.update(fft_s=wall, comm_s=0.0, hidden_s=0.0,
                       measured_efficiency=None)
        summary["stages"].append(row)
        cur = out

    if total_c:
        summary["overall"] = {"collective_s": total_c,
                              "hidden_s": total_hidden,
                              "efficiency": total_hidden / total_c}
    _attach(tracer, summary)
    return y, summary


def _split_legs(tracer, plan, sched, i, st, pts, cur, k, iters, label):
    """Serialized compute/collective leg times of comm stage ``i``:
    per-K-chunk executables for :func:`stage_pre` / :func:`stage_comm`
    (chunking is local, exactly as the executor slices), summed over
    chunks.  For ring/pairwise stages the collective leg is additionally
    split into its P-1 ppermute rounds (:func:`schedule.ring_round`,
    chunk 0 only), so the trace shows where inside the ring the stage's
    wall time goes; returns ``(fft_s, comm_s, rounds)``."""
    mesh, opts = plan.mesh, plan.opts
    axis_sizes = dict(mesh.shape)
    ax = st.chunk_axis
    ext = pts.entry.local_shape(plan.shape, axis_sizes)[ax]
    ck = ext // k
    in_sds = _sds(mesh, cur.shape, plan.dtype, pts.entry)
    chunk_shape = list(cur.shape)
    chunk_shape[ax] = cur.shape[ax] // k

    fft_s = comm_s = 0.0
    rounds = []
    for j in range(k):
        def pre_j(blk, st=st, j=j):
            c = jax.lax.slice_in_dim(blk, j * ck, (j + 1) * ck, axis=ax)
            return schedule_lib.stage_pre(c, st, sched.sign, opts)

        exe_pre = _compile(
            tracer, shard_map(pre_j, mesh=mesh,
                              in_specs=pts.entry.partition_spec(),
                              out_specs=pts.comm.partition_spec()),
            in_sds, f"s{i}:{st.name}:fft[{j}]")
        dt, pre_out = _timed(
            tracer, exe_pre, (cur,), f"s{i}:{st.name}:fft", "fft", iters,
            {"stage": i, "plan": label, "part": "fft", "chunk": j, "k": k})
        fft_s += dt

        def comm_j(blk, st=st):
            return schedule_lib.stage_comm(blk, st, opts)

        exe_comm = _compile(
            tracer, shard_map(comm_j, mesh=mesh,
                              in_specs=pts.comm.partition_spec(),
                              out_specs=pts.out.partition_spec()),
            _sds(mesh, tuple(chunk_shape), plan.dtype, pts.comm),
            f"s{i}:{st.name}:comm[{j}]")
        dt, _ = _timed(
            tracer, exe_comm, (pre_out,), f"s{i}:{st.name}:comm",
            "collective", iters,
            {"stage": i, "plan": label, "part": "comm", "chunk": j, "k": k})
        comm_s += dt

        impl = schedule_lib.stage_transpose_impl(st, opts)
        p = 1
        for n in schedule_lib._flat(st.comm_axis):
            p *= axis_sizes[n]
        if j == 0 and impl in ("ring", "pairwise") and p > 1:
            for rnd in range(1, p):
                def round_r(blk, st=st, rnd=rnd):
                    return schedule_lib.ring_round(blk, st, opts, rnd)

                exe_round = _compile(
                    tracer, shard_map(round_r, mesh=mesh,
                                      in_specs=pts.comm.partition_spec(),
                                      out_specs=pts.comm.partition_spec()),
                    _sds(mesh, tuple(chunk_shape), plan.dtype, pts.comm),
                    f"s{i}:{st.name}:round[{rnd}]")
                rdt, _ = _timed(
                    tracer, exe_round, (pre_out,),
                    f"s{i}:{st.name}:round[{rnd}]", "collective", iters,
                    {"stage": i, "plan": label, "part": "round",
                     "round": rnd, "p": p})
                rounds.append({"round": rnd, "wall_s": rdt})
    return fft_s, comm_s, rounds


def _attach(tracer, summary) -> None:
    if not tracer.enabled:
        return
    attrib = tracer.meta().get("attribution", [])
    tracer.add_meta("attribution", attrib + [summary])
