"""Span tracer: thread-safe, nestable, Chrome-trace-event export.

The observability contract of the repo (ISSUE 7): every measured claim
about *where time goes* — the paper's FFT-hides-MPI overlap story, the
serving layer's queue/dispatch pipeline, the tuner's measurement
traffic — flows through one tracer so a single ``trace.json`` can be
dropped into ``chrome://tracing`` / Perfetto and joined against the
analytic cost model by ``python -m repro.obs.report``.

Design constraints:

  * **zero-cost when disabled** — the default tracer is a
    :class:`NoopTracer` whose ``span()`` returns one shared null context
    manager (no allocation per call), and nothing here ever runs inside
    ``jit`` (enabling tracing cannot change compiled HLO — pinned in
    tests/test_obs.py);
  * **thread-safe** — the serve worker, plan-cache upgrade threads, and
    client threads emit concurrently into one lock-guarded ring buffer
    (``maxlen`` bounds memory under continuous serving);
  * **retroactive spans** — cross-thread phases (a request's queue wait
    starts on the client thread, ends on the worker) are recorded with
    :meth:`Tracer.complete` from explicit ``time.monotonic()``
    timestamps, the same clock ``TransformRequest.t_submit`` uses.

Span categories (the ``cat`` field, filterable in Perfetto):

  ``plan``        planning / compile / whole-transform anchors
  ``pack``        prologue packing (PackTwo, stack-and-pad, ...)
  ``fft``         local FFT compute legs
  ``collective``  global transposes (all_to_all / ppermute rounds)
  ``unpack``      epilogue unpacking (UnpackTwo, SplitPairs, ...)
  ``epilogue``    terminal schedule epilogues (fused k-space multiply)
  ``queue``       serve-side waits (queue, batch assembly)
  ``h2d/d2h``     host<->device payload hops
"""

from __future__ import annotations

import collections
import contextlib
import json
import os
import threading
import time
from typing import Optional

CATEGORIES = ("plan", "pack", "fft", "collective", "unpack", "epilogue",
              "queue", "h2d/d2h")

_PID = os.getpid()

# thread-local ambient tags (see tag_scope): merged into every span's args
# so e.g. tuner-issued transforms are distinguishable from serving traffic
_local = threading.local()


def current_tags() -> dict:
    stack = getattr(_local, "tags", None)
    return dict(stack[-1]) if stack else {}


@contextlib.contextmanager
def tag_scope(**tags):
    """Attach ``tags`` to every span/event emitted by this thread inside
    the scope (``tuning.measure`` wraps its timing runs in
    ``tag_scope(traffic="tuning")`` so tuner traffic never masquerades
    as serving traffic in a shared trace)."""
    stack = getattr(_local, "tags", None)
    if stack is None:
        stack = _local.tags = []
    merged = dict(stack[-1]) if stack else {}
    merged.update(tags)
    stack.append(merged)
    try:
        yield
    finally:
        stack.pop()


class _NullSpan:
    """Shared no-op context manager (one instance, zero per-call cost)."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class NoopTracer:
    """The default tracer: every method is a no-op.

    Instrumented call sites are written ``get_tracer().span(...)``; with
    this tracer installed that is one attribute lookup and a shared null
    context manager — nothing allocated, nothing recorded, and (because
    no instrumentation lives inside ``jit``) nothing in the compiled
    HLO.
    """

    enabled = False

    def span(self, name: str, cat: str = "plan", **args):
        return _NULL_SPAN

    def complete(self, name, cat, t_start, t_end, args=None):
        pass

    def instant(self, name, cat="plan", args=None):
        pass

    def add_meta(self, key, value):
        pass

    def events(self):
        return []


NOOP = NoopTracer()


class _SpanCtx:
    """Context manager recording one complete ("X") span on exit."""

    __slots__ = ("tracer", "name", "cat", "args", "t0")

    def __init__(self, tracer, name, cat, args):
        self.tracer = tracer
        self.name = name
        self.cat = cat
        self.args = args
        self.t0 = 0.0

    def __enter__(self):
        self.t0 = time.monotonic()
        return self

    def set(self, **kw):
        """Attach result attributes discovered while the span is open."""
        self.args.update(kw)
        return self

    def __exit__(self, exc_type, exc, tb):
        if exc_type is not None:
            self.args.setdefault("error", exc_type.__name__)
        self.tracer.complete(self.name, self.cat, self.t0, time.monotonic(),
                             self.args)
        return False


class Tracer:
    """Thread-safe span recorder over a bounded ring buffer.

    Events are Chrome-trace dicts (``ph``: "X" complete spans, "i"
    instants, with ``ts``/``dur`` in microseconds on the
    ``time.monotonic()`` clock re-based to the tracer's creation).
    ``save(path)`` writes the ``{"traceEvents": [...]}`` JSON object
    form that chrome://tracing and Perfetto load directly.
    """

    enabled = True

    def __init__(self, capacity: int = 65536):
        self.t0 = time.monotonic()
        self._events = collections.deque(maxlen=capacity)
        self._meta: dict = {}
        self._lock = threading.Lock()
        self.dropped = 0

    # -- emission -------------------------------------------------------
    def span(self, name: str, cat: str = "plan", **args):
        merged = current_tags()
        merged.update(args)
        return _SpanCtx(self, name, cat, merged)

    def complete(self, name: str, cat: str, t_start: float, t_end: float,
                 args: Optional[dict] = None) -> None:
        """Record a finished span from explicit monotonic timestamps
        (cross-thread phases: queue wait starts on the submitting
        thread, ends on the worker)."""
        merged = current_tags()
        if args:
            merged.update(args)
        ev = {"name": name, "cat": cat, "ph": "X", "pid": _PID,
              "tid": threading.get_ident(),
              "ts": (t_start - self.t0) * 1e6,
              "dur": max(0.0, (t_end - t_start)) * 1e6,
              "args": merged}
        with self._lock:
            if len(self._events) == self._events.maxlen:
                self.dropped += 1
            self._events.append(ev)

    def instant(self, name: str, cat: str = "plan",
                args: Optional[dict] = None) -> None:
        merged = current_tags()
        if args:
            merged.update(args)
        ev = {"name": name, "cat": cat, "ph": "i", "s": "t", "pid": _PID,
              "tid": threading.get_ident(),
              "ts": (time.monotonic() - self.t0) * 1e6, "args": merged}
        with self._lock:
            if len(self._events) == self._events.maxlen:
                self.dropped += 1
            self._events.append(ev)

    def add_meta(self, key: str, value) -> None:
        """Attach trace-level metadata (plan descriptions, model
        predictions) — what ``repro.obs.report`` joins spans against."""
        with self._lock:
            self._meta[key] = value

    # -- export ---------------------------------------------------------
    def events(self) -> list:
        with self._lock:
            return list(self._events)

    def meta(self) -> dict:
        with self._lock:
            return dict(self._meta)

    def to_chrome(self) -> dict:
        """The chrome://tracing / Perfetto JSON object form."""
        with self._lock:
            return {
                "traceEvents": list(self._events),
                "displayTimeUnit": "ms",
                "metadata": dict(self._meta, dropped_events=self.dropped),
            }

    def save(self, path: str) -> str:
        doc = self.to_chrome()
        with open(path, "w") as f:
            json.dump(doc, f, default=str)
        return path


# ---------------------------------------------------------------------------
# global tracer slot
# ---------------------------------------------------------------------------

_tracer: "NoopTracer | Tracer" = NOOP
_tracer_lock = threading.Lock()


def get_tracer():
    """The process-wide tracer (the :data:`NOOP` singleton by default)."""
    return _tracer


def set_tracer(tracer) -> None:
    global _tracer
    with _tracer_lock:
        _tracer = tracer if tracer is not None else NOOP


def enable(capacity: int = 65536) -> Tracer:
    """Install (and return) a recording tracer; idempotent if one is
    already installed."""
    global _tracer
    with _tracer_lock:
        if not _tracer.enabled:
            _tracer = Tracer(capacity)
        return _tracer


def disable() -> None:
    set_tracer(NOOP)


@contextlib.contextmanager
def tracing(path: Optional[str] = None, capacity: int = 65536):
    """Scope with a fresh recording tracer installed globally; on exit
    the previous tracer is restored and, when ``path`` is given, the
    trace is saved there.

        with obs.tracing("trace.json") as tr:
            plan.forward(x)           # host-side spans land in tr
    """
    global _tracer
    with _tracer_lock:
        prev = _tracer
        tr = Tracer(capacity)
        _tracer = tr
    try:
        yield tr
    finally:
        with _tracer_lock:
            _tracer = prev
        if path is not None:
            tr.save(path)
