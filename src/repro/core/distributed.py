"""Distributed 3-D FFT entry points: build a stage schedule, run it.

Mapping from the paper's MPI+OpenMP design to JAX/XLA (DESIGN.md §2):

  row/column MPI communicators  ->  mesh axes inside ``shard_map``
  MPI_Alltoall                  ->  ``jax.lax.all_to_all`` (split/concat axes
                                    express the pack/unpack steps 2,4,6,8)
  OpenMP comm thread + K chunks ->  K independent (FFT chunk -> transpose)
                                    chains, emitted as a depth-1 software
                                    pipeline (``overlap_mode="pipelined"``:
                                    chunk i+1's FFT precedes chunk i's
                                    collective in program order, so overlap
                                    is structural, not a scheduling
                                    accident).  K=1 reproduces options 1/2
                                    (no overlap), K>=2 options 3/4 (CROFT
                                    default K=2, paper §5.1).
                                    ``transpose_impl="ring"`` additionally
                                    decomposes each transpose into P-1
                                    independent ppermute rounds with fused
                                    Pallas pack/unpack
                                    (``kernels/transpose_pack.py``) — the
                                    explicit pack->send->unpack pipeline.
  FFTW plan reuse               ->  plan-constant caching (plan.py); disabled
                                    = "multiple plans" options 1/3.

Since the schedule refactor the pipeline itself is *data*, not code: the
pencil / slab / cell bodies are built by ``repro.core.schedule.build_c2c``
(a pure ``Decomposition -> Schedule`` function), executed by the single
``schedule.run_schedule`` executor (which owns K-chunked overlap,
per-stage ``local_impl`` and batch-axis offsetting), and *the same
objects* are walked by the autotuner's cost model — see ``schedule.py``
for the IR and the README "Architecture" section for the data flow.
This module keeps the user-facing knobs (:class:`FFTOptions`) and the
``shard_map`` wrappers (sharding specs are derived from the schedule's
symbolic layouts).

The FFTW3 baseline the paper benchmarks against is represented two ways:
slab decomposition (its scaling model) and ``transpose_impl="pairwise"``
(its communication pattern: P-1 *blocking* sendrecv exchanges placed
through a serial chain, reproducing the "864 calls vs 64 calls" profile
of figs 12-15).  ``benchmarks/overlap_bench.py`` sweeps all three
transpose impls x K and gates ring at parity-or-better.
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Callable, Optional, Sequence, Union

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import shard_map

from repro.core import local_fft
from repro.core import schedule as schedule_lib
from repro.core.decomposition import Decomposition

AxisName = Union[str, tuple]

# re-exports: the executor primitives moved into the schedule IR but remain
# addressable here (models/ and older call sites import them from this module)
_axis_size = schedule_lib._axis_size
_all_to_all = schedule_lib._all_to_all
_fft_along = schedule_lib._fft_along


@dataclasses.dataclass(frozen=True)
class FFTOptions:
    """Knobs reproducing the paper's option matrix (§5.1) plus extensions.

    overlap_k      CROFT's K: chunks per (FFT -> all_to_all) stage. 1 = no
                   overlap (options 1/2); 2 = CROFT's shipped default.
    plan_cache     True = "single plan" (options 2/4); False = re-materialize
                   twiddles per call ("multiple plans", options 1/3).
    local_impl     "matmul" (four-step, MXU-native) | "stockham" | "xla"
                   | "pallas" (four-step Pallas kernel); or a 3-tuple of
                   those, one per pipeline stage in execution order (the
                   i-th 1-D FFT of the pipeline uses local_impl[i] — e.g.
                   matmul on the contiguous first axis, Stockham on the
                   strided later ones).  A homogeneous tuple collapses to
                   its single value (canonical form for wisdom keys).
    output_layout  "natural" (paper: restore the input pencil layout with two
                   reverse transposes) | "spectral" (beyond-paper: stay in
                   z-pencil layout, halving collective bytes).
    transpose_impl "alltoall" (one fused collective) | "ring" (P-1
                   independent ppermute rounds with fused Pallas
                   pack/unpack — the explicit overlap pipeline) |
                   "pairwise" (FFTW3-style serial-chain emulation).
                   ring/pairwise ppermute over single mesh axes only —
                   folded axes and the cell regroup communicator are
                   rejected by ``Decomposition.validate``.
    overlap_mode   how K >= 2 chunks are emitted: "pipelined" (staged
                   software pipeline — chunk i+1's FFT precedes chunk
                   i's collective in program order, the explicit overlap
                   engine) | "unrolled" (legacy chunk-after-chunk
                   emission, overlap left to XLA's async scheduler); or
                   a 3-tuple of those, one per pipeline stage (indexed
                   like ``local_impl``).  Both orders run identical ops,
                   so results are bitwise equal.
    """

    overlap_k: int = 2
    plan_cache: bool = True
    local_impl: Union[str, tuple] = "matmul"
    output_layout: str = "natural"
    transpose_impl: str = "alltoall"
    overlap_mode: Union[str, tuple] = "pipelined"

    TRANSPOSE_IMPLS = ("alltoall", "ring", "pairwise")
    OVERLAP_MODES = ("pipelined", "unrolled")

    def __post_init__(self):
        object.__setattr__(self, "local_impl",
                           _canon_stage_tuple("local_impl", self.local_impl))
        om = _canon_stage_tuple("overlap_mode", self.overlap_mode)
        for m in (om if isinstance(om, tuple) else (om,)):
            if m not in self.OVERLAP_MODES:
                raise ValueError(f"overlap_mode must be one of "
                                 f"{self.OVERLAP_MODES}, got {m!r}")
        object.__setattr__(self, "overlap_mode", om)
        if self.transpose_impl not in self.TRANSPOSE_IMPLS:
            raise ValueError(f"transpose_impl must be one of "
                             f"{self.TRANSPOSE_IMPLS}, got "
                             f"{self.transpose_impl!r}")

    # -- canonical string form (plan-cache / wisdom keys) -------------------
    def to_token(self) -> str:
        """Canonical string form covering EVERY knob that changes the
        compiled executable — the plan-cache key fragment.  Per-stage
        3-tuples join with ``-`` (impl/mode names contain no ``-``), e.g.
        ``k2/matmul-stockham-xla/natural/ring/pipelined-unrolled-unrolled``
        with ``/noplan`` appended when ``plan_cache=False``.  Round trips
        through :meth:`from_token` (``__post_init__`` re-canonicalizes,
        so token -> options -> token is the identity)."""
        def join(v):
            return "-".join(v) if isinstance(v, tuple) else v
        tok = (f"k{self.overlap_k}/{join(self.local_impl)}/"
               f"{self.output_layout}/{self.transpose_impl}/"
               f"{join(self.overlap_mode)}")
        if not self.plan_cache:
            tok += "/noplan"
        return tok

    @classmethod
    def from_token(cls, token: str) -> "FFTOptions":
        """Inverse of :meth:`to_token`."""
        parts = token.split("/")
        plan_cache = True
        if parts and parts[-1] == "noplan":
            plan_cache = False
            parts = parts[:-1]
        if len(parts) != 5 or not parts[0].startswith("k"):
            raise ValueError(f"malformed FFTOptions token {token!r}")

        def split(v):
            items = v.split("-")
            return tuple(items) if len(items) > 1 else v
        return cls(overlap_k=int(parts[0][1:]), local_impl=split(parts[1]),
                   output_layout=parts[2], transpose_impl=parts[3],
                   overlap_mode=split(parts[4]), plan_cache=plan_cache)

    def stage_impl(self, stage: int) -> str:
        """Local 1-D implementation for the given pipeline stage."""
        if isinstance(self.local_impl, tuple):
            return self.local_impl[stage]
        return self.local_impl

    def stage_overlap(self, stage: int) -> str:
        """Chunk emission mode for the given pipeline stage."""
        if isinstance(self.overlap_mode, tuple):
            return self.overlap_mode[stage]
        return self.overlap_mode

    @classmethod
    def paper_option(cls, opt: int, **kw) -> "FFTOptions":
        """CROFT paper options 1-4 (§5.1)."""
        table = {
            1: dict(overlap_k=1, plan_cache=False),
            2: dict(overlap_k=1, plan_cache=True),
            3: dict(overlap_k=2, plan_cache=False),
            4: dict(overlap_k=2, plan_cache=True),  # shipped CROFT
        }
        return cls(**{**table[opt], **kw})


def _canon_stage_tuple(name: str, value: Union[str, tuple]) -> Union[str, tuple]:
    """Canonicalize a per-stage knob: 3-tuples collapse to their single
    value when homogeneous (the canonical form for wisdom keys)."""
    if isinstance(value, (list, tuple)):
        value = tuple(value)
        if len(value) != 3:
            raise ValueError(
                f"per-stage {name} needs exactly 3 entries, got {value}")
        if len(set(value)) == 1:
            value = value[0]
    return value


def _stage(blk: jax.Array, *, fft_axis: Optional[int], comm_axis: Optional[AxisName],
           split_axis: int, concat_axis: int, chunk_axis: int, sign: int,
           opts: FFTOptions, stage: int = 0) -> jax.Array:
    """One ad-hoc pipeline stage (K-chunked FFT -> all_to_all).

    Thin shim over :func:`repro.core.schedule.run_stage` kept for callers
    that use the CROFT overlap pattern outside a full 3-D schedule
    (``models/spectral.py`` sequence FFTs, ``models/moe_sharded.py``
    expert dispatch).
    """
    st = schedule_lib.Stage("ad-hoc", fft_axis=fft_axis, comm_axis=comm_axis,
                            split_axis=split_axis, concat_axis=concat_axis,
                            chunk_axis=chunk_axis, impl_stage=stage)
    return schedule_lib.run_stage(blk, st, sign, opts)


# ---------------------------------------------------------------------------
# public entry points
# ---------------------------------------------------------------------------

def _norm_scale(shape: Sequence[int], sign: int,
                norm: Optional[str]) -> Optional[float]:
    """Global normalization factor (None = no scaling at this call)."""
    nxyz = shape[-3] * shape[-2] * shape[-1]
    if norm == "ortho":
        return 1.0 / math.sqrt(nxyz)
    if (norm is None or norm == "backward") and sign == +1:
        return 1.0 / nxyz
    return None


def build_schedule(decomp: Decomposition, opts: FFTOptions,
                   sign: int = -1) -> schedule_lib.Schedule:
    """The c2c schedule ``distributed_fft3d`` will run for this plan
    (public hook for golden tests / inspection / the cost model)."""
    from_spectral = opts.output_layout == "spectral" and sign == +1
    return schedule_lib.build_c2c(decomp, sign=sign,
                                  output_layout=opts.output_layout,
                                  from_spectral=from_spectral)


def inverse_schedule(sched: schedule_lib.Schedule) -> schedule_lib.Schedule:
    """The unnormalized inverse of a pure c2c schedule.

    The adjoint transform reverses the pipeline (every transpose swaps
    split/concat; per-stage impl/K overrides ride along) and a 1-D DFT
    matrix is symmetric, so the adjoint with the sign flipped *is* the
    inverse up to the 1/N factor the caller applies via ``norm``.  This
    is how searched schedules — which have no fixed inverse builder —
    get their ``ifft``.  Restricted to pure complex pipelines: packing
    prologues/epilogues and out-of-body reshards are not sign-symmetric.
    """
    if any(st.prologue or st.epilogue for st in sched.stages) \
            or sched.epilogue or sched.extra_comms:
        raise ValueError("inverse_schedule covers pure c2c schedules only")
    from repro.grad.adjoint import adjoint_schedule
    adj = adjoint_schedule(sched)
    return dataclasses.replace(adj, name=f"{sched.name}^-1",
                               sign=-sched.sign, points=None)


def scheduled_fft3d(x: jax.Array, mesh: Mesh,
                    sched: schedule_lib.Schedule,
                    opts: Optional[FFTOptions] = None,
                    norm: Optional[str] = None,
                    kspace_filter: Optional[jax.Array] = None) -> jax.Array:
    """Run a prebuilt :class:`~repro.core.schedule.Schedule` — the entry
    point for searched pipelines, which exist only as schedule objects.

    Same contract as :func:`distributed_fft3d` (vjp-routed, plan-cached
    via ``grad_vjp.linear_plan``, optional fused k-space filter), minus
    the fixed-builder step: shardings come from the schedule's own
    symbolic layouts.
    """
    if opts is None:
        opts = FFTOptions()
    if x.ndim != 3:
        raise ValueError("scheduled_fft3d expects a rank-3 (Nx,Ny,Nz) array; "
                         "vmap for batches")
    scale = _norm_scale(x.shape, sched.sign, norm)
    from repro.grad import vjp as grad_vjp
    if kspace_filter is None:
        return grad_vjp.linear_plan(mesh, sched, opts, scale).apply(x)
    plan = grad_vjp.filtered_plan(mesh, sched, opts, scale)
    return plan(x, kspace_filter.astype(x.dtype))


def distributed_fft3d(x: jax.Array, mesh: Mesh, decomp: Decomposition,
                      sign: int = -1, opts: Optional[FFTOptions] = None,
                      norm: Optional[str] = None,
                      kspace_filter: Optional[jax.Array] = None) -> jax.Array:
    """3-D FFT of a globally-sharded (Nx, Ny, Nz) array.

    Builds the decomposition's :class:`~repro.core.schedule.Schedule` and
    runs it under ``shard_map``; in/out shardings come from the schedule's
    symbolic layouts.  ``kspace_filter`` fuses a pointwise k-space
    multiply into the transform as a terminal schedule epilogue (the
    filter must be shaped/sharded like the output spectrum).
    """
    if opts is None:
        opts = FFTOptions()
    if x.ndim != 3:
        raise ValueError("distributed_fft3d expects a rank-3 (Nx,Ny,Nz) array; "
                         "vmap for batches")
    decomp.validate(x.shape, mesh, opts.overlap_k, opts.transpose_impl)

    sched = build_schedule(decomp, opts, sign)
    # normalization uses *global* sizes; the vjp plan folds the scalar in
    # on local blocks (and reuses the same scale for the backward pass)
    scale = _norm_scale(x.shape, sign, norm)

    # route through repro.grad so jax.grad runs the adjoint schedule
    # instead of XLA differentiating the shard_map body; primal ops are
    # identical to running the schedule directly
    from repro.grad import vjp as grad_vjp
    if kspace_filter is None:
        return grad_vjp.linear_plan(mesh, sched, opts, scale).apply(x)
    plan = grad_vjp.filtered_plan(mesh, sched, opts, scale)
    return plan(x, kspace_filter.astype(x.dtype))


def fft3d(x, mesh=None, decomp=None, opts: Optional[FFTOptions] = None,
          norm: Optional[str] = None,
          kspace_filter: Optional[jax.Array] = None):
    """Forward 3-D FFT; single-device fallback when no mesh is given."""
    if opts is None:
        opts = FFTOptions()
    if mesh is None or math.prod(mesh.devices.shape) == 1:
        y = local_fft.fft3d_local(x, -1, impl=opts.local_impl,
                                  plan_cache=opts.plan_cache, norm=norm)
        if kspace_filter is not None:
            from repro.kernels import spectral_scale as ss
            y = ss.spectral_scale(y, kspace_filter.astype(y.dtype))
        return y
    return distributed_fft3d(x, mesh, decomp, -1, opts, norm, kspace_filter)


def ifft3d(x, mesh=None, decomp=None, opts: Optional[FFTOptions] = None,
           norm: Optional[str] = "backward"):
    """Inverse 3-D FFT (paper eq. 2: 1/(NxNyNz) normalization)."""
    if opts is None:
        opts = FFTOptions()
    if mesh is None or math.prod(mesh.devices.shape) == 1:
        return local_fft.fft3d_local(x, +1, impl=opts.local_impl,
                                     plan_cache=opts.plan_cache, norm=norm)
    return distributed_fft3d(x, mesh, decomp, +1, opts, norm)
