"""Distributed 3-D FFT: pencil / slab / cell decompositions with K-chunked
compute-communication overlap (the paper's core contribution, §4-§5).

Mapping from the paper's MPI+OpenMP design to JAX/XLA (DESIGN.md §2):

  row/column MPI communicators  ->  mesh axes inside ``shard_map``
  MPI_Alltoall                  ->  ``jax.lax.all_to_all`` (split/concat axes
                                    express the pack/unpack steps 2,4,6,8)
  OpenMP comm thread + K chunks ->  K independent (FFT chunk -> all_to_all)
                                    chains; chunk i's collective has no data
                                    dependence on chunk i+1's FFT, so XLA's
                                    async collective scheduler overlaps them.
                                    K=1 reproduces options 1/2 (no overlap),
                                    K>=2 reproduces options 3/4 (CROFT default
                                    K=2, paper §5.1).
  FFTW plan reuse               ->  plan-constant caching (plan.py); disabled
                                    = "multiple plans" options 1/3.

The FFTW3 baseline the paper benchmarks against is represented two ways:
slab decomposition (its scaling model) and ``transpose_impl="pairwise"``
(its communication pattern: P-1 pairwise exchanges standing in for
MPI_Sendrecv, reproducing the "864 calls vs 64 calls" profile of figs 12-15).
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Callable, Optional, Sequence, Union

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import axis_size, shard_map

from repro.core import local_fft
from repro.core.decomposition import Decomposition

AxisName = Union[str, tuple]


def _axis_size(axis: AxisName) -> int:
    """Size of a (possibly folded) mesh axis from inside shard_map."""
    if isinstance(axis, tuple):
        return math.prod(axis_size(a) for a in axis)
    return axis_size(axis)


def _all_to_all(blk: jax.Array, axis: AxisName, split_axis: int,
                concat_axis: int, impl: str = "alltoall") -> jax.Array:
    """Global transpose along one communicator.

    ``impl="alltoall"``  one fused collective (CROFT's MPI_Alltoall).
    ``impl="pairwise"``  P-1 ppermute exchanges (FFTW3's MPI_Sendrecv
                         pattern) — numerically identical, many more
                         collective ops; used for the figs 12-15 benchmark.
    """
    if impl == "alltoall":
        return jax.lax.all_to_all(blk, axis, split_axis=split_axis,
                                  concat_axis=concat_axis, tiled=True)
    if impl != "pairwise":
        raise ValueError(f"unknown transpose impl {impl!r}")
    if isinstance(axis, tuple):
        raise ValueError("pairwise transpose supports single mesh axes only")
    p = axis_size(axis)
    idx = jax.lax.axis_index(axis)
    n_split = blk.shape[split_axis] // p
    n_cat = blk.shape[concat_axis]
    out_shape = list(blk.shape)
    out_shape[split_axis] = n_split
    out_shape[concat_axis] = n_cat * p
    out = jnp.zeros(out_shape, blk.dtype)
    mine = jax.lax.dynamic_slice_in_dim(blk, idx * n_split, n_split, split_axis)
    out = jax.lax.dynamic_update_slice_in_dim(out, mine, idx * n_cat, concat_axis)
    for s in range(1, p):
        perm = [(i, (i + s) % p) for i in range(p)]
        dest = (idx + s) % p
        piece = jax.lax.dynamic_slice_in_dim(blk, dest * n_split, n_split, split_axis)
        recv = jax.lax.ppermute(piece, axis, perm)
        src = (idx - s) % p
        out = jax.lax.dynamic_update_slice_in_dim(out, recv, src * n_cat, concat_axis)
    return out


@dataclasses.dataclass(frozen=True)
class FFTOptions:
    """Knobs reproducing the paper's option matrix (§5.1) plus extensions.

    overlap_k      CROFT's K: chunks per (FFT -> all_to_all) stage. 1 = no
                   overlap (options 1/2); 2 = CROFT's shipped default.
    plan_cache     True = "single plan" (options 2/4); False = re-materialize
                   twiddles per call ("multiple plans", options 1/3).
    local_impl     "matmul" (four-step, MXU-native) | "stockham" | "xla"
                   | "pallas" (four-step Pallas kernel); or a 3-tuple of
                   those, one per pipeline stage in execution order (the
                   i-th 1-D FFT of the pipeline uses local_impl[i] — e.g.
                   matmul on the contiguous first axis, Stockham on the
                   strided later ones).  A homogeneous tuple collapses to
                   its single value (canonical form for wisdom keys).
    output_layout  "natural" (paper: restore the input pencil layout with two
                   reverse transposes) | "spectral" (beyond-paper: stay in
                   z-pencil layout, halving collective bytes).
    transpose_impl "alltoall" | "pairwise" (FFTW3-style emulation).
    """

    overlap_k: int = 2
    plan_cache: bool = True
    local_impl: Union[str, tuple] = "matmul"
    output_layout: str = "natural"
    transpose_impl: str = "alltoall"

    def __post_init__(self):
        li = self.local_impl
        if isinstance(li, (list, tuple)):
            li = tuple(li)
            if len(li) != 3:
                raise ValueError(
                    f"per-stage local_impl needs exactly 3 entries, got {li}")
            if len(set(li)) == 1:
                li = li[0]
            object.__setattr__(self, "local_impl", li)

    def stage_impl(self, stage: int) -> str:
        """Local 1-D implementation for the given pipeline stage."""
        if isinstance(self.local_impl, tuple):
            return self.local_impl[stage]
        return self.local_impl

    @classmethod
    def paper_option(cls, opt: int, **kw) -> "FFTOptions":
        """CROFT paper options 1-4 (§5.1)."""
        table = {
            1: dict(overlap_k=1, plan_cache=False),
            2: dict(overlap_k=1, plan_cache=True),
            3: dict(overlap_k=2, plan_cache=False),
            4: dict(overlap_k=2, plan_cache=True),  # shipped CROFT
        }
        return cls(**{**table[opt], **kw})


def _fft_along(blk: jax.Array, axis: int, sign: int, opts: FFTOptions,
               stage: int = 0) -> jax.Array:
    return local_fft.fft_1d(blk, axis, sign, impl=opts.stage_impl(stage),
                            plan_cache=opts.plan_cache)


def _stage(blk: jax.Array, *, fft_axis: Optional[int], comm_axis: Optional[AxisName],
           split_axis: int, concat_axis: int, chunk_axis: int, sign: int,
           opts: FFTOptions, stage: int = 0) -> jax.Array:
    """One pipeline stage: local FFT along ``fft_axis`` overlapped with the
    global transpose over ``comm_axis`` (paper steps {1,2,3}, {5,6,7}).

    The local block is split into K chunks along ``chunk_axis`` (an axis not
    involved in the transpose).  Chunk i's all_to_all is independent of chunk
    i+1's FFT — the overlap the paper implements with its second OpenMP
    thread, here left to the XLA async-collective scheduler.

    ``stage`` is the pipeline-order index of this 1-D FFT, selecting the
    per-stage implementation when ``opts.local_impl`` is a 3-tuple.
    """
    k = opts.overlap_k
    if comm_axis is None:  # final stage: FFT only
        return _fft_along(blk, fft_axis, sign, opts, stage)
    if k <= 1 or blk.shape[chunk_axis] % k != 0:
        y = (_fft_along(blk, fft_axis, sign, opts, stage)
             if fft_axis is not None else blk)
        return _all_to_all(y, comm_axis, split_axis, concat_axis,
                           opts.transpose_impl)
    chunks = jnp.split(blk, k, axis=chunk_axis)
    outs = []
    for c in chunks:
        y = (_fft_along(c, fft_axis, sign, opts, stage)
             if fft_axis is not None else c)
        outs.append(_all_to_all(y, comm_axis, split_axis, concat_axis,
                                opts.transpose_impl))
    return jnp.concatenate(outs, axis=chunk_axis)


# ---------------------------------------------------------------------------
# shard_map bodies.  Local block axis order is always (x, y, z).
# ---------------------------------------------------------------------------

def _pencil_body(blk: jax.Array, *, ax_y: AxisName, ax_z: AxisName, sign: int,
                 opts: FFTOptions) -> jax.Array:
    """Forward pencil pipeline, paper §4.1 steps 1-9 (+ optional restore).

    in : x-pencils (Nx, Ny/Py, Nz/Pz)
    out: natural   -> same layout;  spectral -> z-pencils (Nx/Py, Ny/Pz, Nz)
    """
    # steps 1-4: FFT along x, transpose x<->y in the column communicator
    blk = _stage(blk, fft_axis=0, comm_axis=ax_y, split_axis=0, concat_axis=1,
                 chunk_axis=2, sign=sign, opts=opts, stage=0)  # (Nx/Py, Ny, Nz/Pz)
    # steps 5-8: FFT along y, transpose y<->z in the row communicator
    blk = _stage(blk, fft_axis=1, comm_axis=ax_z, split_axis=1, concat_axis=2,
                 chunk_axis=0, sign=sign, opts=opts, stage=1)  # (Nx/Py, Ny/Pz, Nz)
    # step 9: FFT along z
    blk = _stage(blk, fft_axis=2, comm_axis=None, split_axis=0, concat_axis=0,
                 chunk_axis=0, sign=sign, opts=opts, stage=2)
    if opts.output_layout == "spectral":
        return blk
    # restore: reverse YZ then XY transposes (paper §5.2, also overlapped)
    blk = _stage(blk, fft_axis=None, comm_axis=ax_z, split_axis=2, concat_axis=1,
                 chunk_axis=0, sign=sign, opts=opts)      # (Nx/Py, Ny, Nz/Pz)
    blk = _stage(blk, fft_axis=None, comm_axis=ax_y, split_axis=1, concat_axis=0,
                 chunk_axis=2, sign=sign, opts=opts)      # (Nx, Ny/Py, Nz/Pz)
    return blk


def _pencil_body_from_spectral(blk: jax.Array, *, ax_y: AxisName,
                               ax_z: AxisName, sign: int,
                               opts: FFTOptions) -> jax.Array:
    """Reversed pencil pipeline: spectral (z-pencil) input -> natural output.

    Used by the inverse transform when the forward ran with
    ``output_layout='spectral'`` (beyond-paper path: the forward's two
    restoring transposes and the inverse's two leading transposes cancel).
    """
    # FFT along z while z is local, then hand z back to the row communicator
    blk = _stage(blk, fft_axis=2, comm_axis=ax_z, split_axis=2, concat_axis=1,
                 chunk_axis=0, sign=sign, opts=opts, stage=0)  # (Nx/Py, Ny, Nz/Pz)
    blk = _stage(blk, fft_axis=1, comm_axis=ax_y, split_axis=1, concat_axis=0,
                 chunk_axis=2, sign=sign, opts=opts, stage=1)  # (Nx, Ny/Py, Nz/Pz)
    blk = _stage(blk, fft_axis=0, comm_axis=None, split_axis=0, concat_axis=0,
                 chunk_axis=0, sign=sign, opts=opts, stage=2)
    return blk


def _slab_body_from_spectral(blk: jax.Array, *, ax_z: AxisName, sign: int,
                             opts: FFTOptions) -> jax.Array:
    blk = _fft_along(blk, 1, sign, opts, stage=0)
    blk = _stage(blk, fft_axis=2, comm_axis=ax_z, split_axis=2, concat_axis=0,
                 chunk_axis=1, sign=sign, opts=opts, stage=1)  # (Nx, Ny, Nz/P)
    blk = _fft_along(blk, 0, sign, opts, stage=2)
    return blk


def _slab_body(blk: jax.Array, *, ax_z: AxisName, sign: int,
               opts: FFTOptions) -> jax.Array:
    """Slab (1-D) pipeline — the FFTW3-MPI scaling model (§2.2.1).

    in: (Nx, Ny, Nz/P) -> local 2-D FFT over (x, y), one global transpose,
    FFT along z.  P <= Nz is the scaling wall the paper's tables 1/3 show.
    """
    blk = _fft_along(blk, 1, sign, opts, stage=0)  # y is free on both layouts
    blk = _stage(blk, fft_axis=0, comm_axis=ax_z, split_axis=0, concat_axis=2,
                 chunk_axis=1, sign=sign, opts=opts, stage=1)  # (Nx/P, Ny, Nz)
    blk = _fft_along(blk, 2, sign, opts, stage=2)
    if opts.output_layout == "spectral":
        return blk                                          # z-slabs over x
    blk = _stage(blk, fft_axis=None, comm_axis=ax_z, split_axis=2, concat_axis=0,
                 chunk_axis=1, sign=sign, opts=opts)
    return blk


def _cell_body(blk: jax.Array, *, ax_x: AxisName, ax_y: AxisName,
               ax_z: AxisName, sign: int, opts: FFTOptions) -> jax.Array:
    """Cell (3-D) pipeline (§2.2.3): regroup to x-pencils over the folded
    (y, x) communicator, then run the pencil pipeline.
    """
    fold_y = (ax_y, ax_x) if not isinstance(ax_y, tuple) else tuple(ax_y) + (ax_x,)
    # regroup: gather x locally, splitting y further across the x axis
    blk = _stage(blk, fft_axis=None, comm_axis=ax_x, split_axis=1, concat_axis=0,
                 chunk_axis=2, sign=sign, opts=opts)  # (Nx, Ny/(Py*Px), Nz/Pz)
    blk = _pencil_body(blk, ax_y=fold_y, ax_z=ax_z, sign=sign,
                       opts=dataclasses.replace(opts, output_layout="natural"))
    # scatter x back out to cells
    blk = _stage(blk, fft_axis=None, comm_axis=ax_x, split_axis=0, concat_axis=1,
                 chunk_axis=2, sign=sign, opts=opts)
    return blk


# ---------------------------------------------------------------------------
# public entry points
# ---------------------------------------------------------------------------

def distributed_fft3d(x: jax.Array, mesh: Mesh, decomp: Decomposition,
                      sign: int = -1, opts: Optional[FFTOptions] = None,
                      norm: Optional[str] = None) -> jax.Array:
    """3-D FFT of a globally-sharded (..., Nx, Ny, Nz) array.

    Leading batch axes are carried along unsharded (the local block sees
    them; FFT/chunk axis indices below are offset accordingly).
    """
    if opts is None:
        opts = FFTOptions()
    if x.ndim != 3:
        raise ValueError("distributed_fft3d expects a rank-3 (Nx,Ny,Nz) array; "
                         "vmap for batches")
    decomp.validate(x.shape, mesh, opts.overlap_k)

    # A "spectral"-layout inverse consumes z-pencils and emits the natural
    # layout (the forward's restoring transposes and the inverse's leading
    # transposes cancel — that is the point of the optimization).
    from_spectral = opts.output_layout == "spectral" and sign == +1

    if decomp.kind == "pencil":
        ax_y, ax_z = decomp.axes
        fn_body = _pencil_body_from_spectral if from_spectral else _pencil_body
        body = functools.partial(fn_body, ax_y=ax_y, ax_z=ax_z,
                                 sign=sign, opts=opts)
    elif decomp.kind == "slab":
        (ax_z,) = decomp.axes
        fn_body = _slab_body_from_spectral if from_spectral else _slab_body
        body = functools.partial(fn_body, ax_z=ax_z, sign=sign, opts=opts)
    else:
        ax_x, ax_y, ax_z = decomp.axes
        if opts.output_layout == "spectral":
            raise ValueError("cell decomposition returns natural layout only")
        body = functools.partial(_cell_body, ax_x=ax_x, ax_y=ax_y, ax_z=ax_z,
                                 sign=sign, opts=opts)

    if from_spectral:
        in_spec, out_spec = decomp.spectral_spec(), decomp.partition_spec()
    else:
        in_spec = decomp.partition_spec()
        out_spec = (decomp.partition_spec() if opts.output_layout == "natural"
                    else decomp.spectral_spec())

    # normalization uses *global* sizes; fold the scalar in on local blocks
    nxyz = x.shape[-3] * x.shape[-2] * x.shape[-1]
    if norm == "ortho":
        scale = 1.0 / math.sqrt(nxyz)
    elif (norm is None or norm == "backward") and sign == +1:
        scale = 1.0 / nxyz
    else:
        scale = None

    def wrapped(blk):
        out = body(blk)
        return out if scale is None else out * jnp.asarray(scale, out.dtype)

    fn = shard_map(wrapped, mesh=mesh, in_specs=in_spec, out_specs=out_spec)
    return fn(x)


def fft3d(x, mesh=None, decomp=None, opts: Optional[FFTOptions] = None,
          norm: Optional[str] = None):
    """Forward 3-D FFT; single-device fallback when no mesh is given."""
    if opts is None:
        opts = FFTOptions()
    if mesh is None or math.prod(mesh.devices.shape) == 1:
        return local_fft.fft3d_local(x, -1, impl=opts.local_impl,
                                     plan_cache=opts.plan_cache, norm=norm)
    return distributed_fft3d(x, mesh, decomp, -1, opts, norm)


def ifft3d(x, mesh=None, decomp=None, opts: Optional[FFTOptions] = None,
           norm: Optional[str] = "backward"):
    """Inverse 3-D FFT (paper eq. 2: 1/(NxNyNz) normalization)."""
    if opts is None:
        opts = FFTOptions()
    if mesh is None or math.prod(mesh.devices.shape) == 1:
        return local_fft.fft3d_local(x, +1, impl=opts.local_impl,
                                     plan_cache=opts.plan_cache, norm=norm)
    return distributed_fft3d(x, mesh, decomp, +1, opts, norm)
