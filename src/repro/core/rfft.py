"""Real-to-complex / complex-to-real 3-D transforms.

The paper lists r2c/c2r as future work (§8).  Two strategies, dispatched
here (the stable entry points) and implemented in ``repro.real``:

``strategy="packed"``   the native path: two real z-pencils share one
    complex transform (two-for-one), the spectrum travels as exactly
    Nz/2 shard-aligned complex bins (Nyquist folded into DC), and every
    stage computes/moves half of what the c2c pipeline would.  See
    ``repro.real.pipeline`` for the layout contract (distributed input
    is *z-pencils*, ``Decomposition.spectral_spec()``).

``strategy="embed"``    cast to complex, run c2c, keep the non-redundant
    half of the last axis.  2x first-stage bandwidth waste, but valid
    for every decomposition/shape — the fallback and numerical oracle.

``strategy="auto"`` (default) picks packed wherever it is supported.
Both match ``numpy.fft.rfftn`` / ``irfftn`` semantics with axes in
(x, y, z) order.
"""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import distributed, local_fft
from repro.core.decomposition import Decomposition
from repro.core.distributed import FFTOptions
from repro import real as real_lib
# submodule-import form: resolves even while repro.real's own __init__ is
# still running (e.g. `import repro.real` pulls repro.core, which pulls
# this module, before repro.real has bound its `packing` attribute)
from repro.real import packing as _real_packing


def _is_multidevice(mesh) -> bool:
    return mesh is not None and math.prod(mesh.devices.shape) > 1


def _z_shard_count(decomp: Decomposition, mesh, layout: str) -> int:
    """How many ways the (global) z axis is sharded in the given layout."""
    spec = (decomp.partition_spec() if layout == "natural"
            else decomp.spectral_spec())
    entry = spec[2]
    if entry is None:
        return 1
    sizes = dict(mesh.shape)
    if isinstance(entry, tuple):
        return math.prod(sizes[a] for a in entry)
    return sizes[entry]


def _guarded_half_slice(y: jax.Array, nz: int, mesh, decomp, opts) -> jax.Array:
    """``y[..., : nz//2 + 1]`` that never materializes a cross-shard slice.

    In the natural output layout z is sharded, and the odd-sized half
    spectrum cannot tile those shards: silently slicing would make XLA
    gather (or unevenly pad) the spectrum.  Instead we reshard z to be
    local first (an all-to-all shuffle, no gather) and slice locally —
    which also honors ``Croft3D.output_sharding``'s contract that every
    r2c spectrum comes back in the z-local layout.
    """
    nh = nz // 2 + 1
    if not _is_multidevice(mesh) or decomp is None:
        return y[..., :nh]
    if _z_shard_count(decomp, mesh, opts.output_layout) == 1:
        return y[..., :nh]
    if decomp.kind in ("pencil", "slab"):
        target = decomp.spectral_spec()        # z local, x/y take the shards
    else:  # cell: no 3-axis layout keeps z local; replicate over the z axis
        target = P(decomp.axes[0], decomp.axes[1], None)
    return real_lib.constrain_sharding(y, NamedSharding(mesh, target))[..., :nh]


def rfft3d(x: jax.Array, mesh=None, decomp: Optional[Decomposition] = None,
           opts: Optional[FFTOptions] = None,
           strategy: str = "auto", norm: Optional[str] = None,
           kspace_filter: Optional[jax.Array] = None,
           fold_filter: bool = False) -> jax.Array:
    """Real input (Nx, Ny, Nz) -> complex (Nx, Ny, Nz//2 + 1).

    Matches ``jnp.fft.rfftn`` with axes in (x, y, z) order (z contiguous,
    halved).  ``strategy``: "packed" | "embed" | "auto" (see module doc).
    ``norm``: None/"backward" (unscaled forward) | "ortho" (1/sqrt(N)).
    ``kspace_filter`` (shaped like the half spectrum) fuses a k-space
    multiply into the transform — the packed pipeline applies it right
    after the DC/Nyquist unfold, inside the same jit.  ``fold_filter``
    (packed distributed path only) moves the multiply *before* the
    unfold, onto the packed half spectrum inside the schedule — valid
    for filters with ``h(kz=0) == h(kz=Nyquist)``, that plane real and
    2-D-even (see ``repro.real.pipeline.packed_rfft3d``).
    NOTE the packed distributed input layout is the *spectral* layout
    (``decomp.spectral_spec()``: z-pencils / z-slabs), not the c2c
    natural layout.
    """
    if opts is None:
        opts = FFTOptions()
    if jnp.iscomplexobj(x):
        raise ValueError("rfft3d expects a real array")
    resolved = real_lib.resolve_strategy(strategy, x.shape, mesh, decomp, opts)
    if fold_filter and not (resolved == "packed" and _is_multidevice(mesh)
                            and kspace_filter is not None):
        raise ValueError("fold_filter=True needs a kspace_filter on the "
                         "distributed packed path (it folds the multiply "
                         "into the packed schedule)")
    if resolved == "packed":
        if not _is_multidevice(mesh):
            y = real_lib.local_rfft3d_packed(x, opts, norm=norm)
        else:
            return real_lib.packed_rfft3d(x, mesh, decomp, opts, norm=norm,
                                          kspace_filter=kspace_filter,
                                          fold_filter=fold_filter)
    else:
        nz = x.shape[-1]
        xc = x.astype(jnp.complex64 if x.dtype != jnp.float64
                      else jnp.complex128)
        y = distributed.fft3d(xc, mesh, decomp, opts, norm=norm)
        y = _guarded_half_slice(y, nz, mesh, decomp, opts)
    if kspace_filter is not None:
        from repro.kernels import spectral_scale as ss
        y = ss.spectral_scale(y, kspace_filter.astype(y.dtype))
    return y


_negate_freq = _real_packing.negate_freq  # k -> (-k) mod N index map


def irfft3d(y: jax.Array, nz: int, mesh=None,
            decomp: Optional[Decomposition] = None,
            opts: Optional[FFTOptions] = None,
            strategy: str = "auto", norm: Optional[str] = None) -> jax.Array:
    """Inverse of :func:`rfft3d`; reconstructs the Hermitian half.

    F[kx, ky, kz] = conj(F[-kx mod Nx, -ky mod Ny, nz - kz]) for the
    missing bins kz in [nz//2 + 1, nz - 1].  ``norm``: None/"backward"
    (1/N) | "ortho" (1/sqrt(N)), matching :func:`rfft3d`.
    """
    if opts is None:
        opts = FFTOptions()
    shape = (y.shape[-3], y.shape[-2], nz)
    resolved = real_lib.resolve_strategy(strategy, shape, mesh, decomp, opts)
    if resolved == "packed":
        if not _is_multidevice(mesh):
            return real_lib.local_irfft3d_packed(y, nz, opts, norm=norm)
        return real_lib.packed_irfft3d(y, nz, mesh, decomp, opts, norm=norm)
    body = y[..., 1: (nz + 1) // 2]           # kz' = 1 .. ceil(nz/2)-1
    tail = jnp.conj(body)
    tail = _negate_freq(tail, -3)             # -kx mod Nx
    tail = _negate_freq(tail, -2)             # -ky mod Ny
    tail = jnp.flip(tail, -1)                 # ascending kz = nz-kz' order
    full = jnp.concatenate([y, tail], axis=-1)
    assert full.shape[-1] == nz, (full.shape, nz)
    x = distributed.ifft3d(full, mesh, decomp, opts, norm=norm)
    return jnp.real(x)


def rfft3d_local(x: jax.Array) -> jax.Array:
    """Single-device r2c via the plan-based local transform (z-axis halved)."""
    return rfft3d(x, mesh=None)
