"""Real-to-complex / complex-to-real 3-D transforms.

The paper lists r2c/c2r as future work (§8); we implement them on top of the
c2c pipeline.  The distributed path is the straightforward embedding (cast,
c2c, keep the non-redundant half of the last axis); the packed two-for-one
real trick is a documented follow-on optimization (DESIGN.md §2) — the
embedding is bandwidth-suboptimal by 2x on the first stage but exactly
matches ``numpy.fft.rfftn`` semantics, which is what the verification needs.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import distributed, local_fft
from repro.core.decomposition import Decomposition
from repro.core.distributed import FFTOptions


def rfft3d(x: jax.Array, mesh=None, decomp: Optional[Decomposition] = None,
           opts: Optional[FFTOptions] = None) -> jax.Array:
    """Real input (Nx, Ny, Nz) -> complex (Nx, Ny, Nz//2 + 1).

    Matches ``jnp.fft.rfftn`` with axes in (x, y, z) order (z contiguous,
    halved — the axis that stays local at the end of the pencil pipeline, so
    the truncation never crosses a shard boundary in spectral layout).
    """
    if opts is None:
        opts = FFTOptions()
    if jnp.iscomplexobj(x):
        raise ValueError("rfft3d expects a real array")
    nz = x.shape[-1]
    xc = x.astype(jnp.complex64 if x.dtype != jnp.float64 else jnp.complex128)
    y = distributed.fft3d(xc, mesh, decomp, opts)
    # non-redundant half along z; in natural layout z is sharded, so slice
    # globally (XLA turns this into a shard-local slice when divisible)
    return y[..., : nz // 2 + 1]


def _negate_freq(a: jax.Array, axis: int) -> jax.Array:
    """Index map k -> (-k) mod N along ``axis``: [0, N-1, N-2, ..., 1]."""
    return jnp.roll(jnp.flip(a, axis), 1, axis)


def irfft3d(y: jax.Array, nz: int, mesh=None,
            decomp: Optional[Decomposition] = None,
            opts: Optional[FFTOptions] = None) -> jax.Array:
    """Inverse of :func:`rfft3d`; reconstructs the Hermitian half.

    F[kx, ky, kz] = conj(F[-kx mod Nx, -ky mod Ny, nz - kz]) for the
    missing bins kz in [nz//2 + 1, nz - 1].
    """
    if opts is None:
        opts = FFTOptions()
    body = y[..., 1: (nz + 1) // 2]           # kz' = 1 .. ceil(nz/2)-1
    tail = jnp.conj(body)
    tail = _negate_freq(tail, -3)             # -kx mod Nx
    tail = _negate_freq(tail, -2)             # -ky mod Ny
    tail = jnp.flip(tail, -1)                 # ascending kz = nz-kz' order
    full = jnp.concatenate([y, tail], axis=-1)
    assert full.shape[-1] == nz, (full.shape, nz)
    x = distributed.ifft3d(full, mesh, decomp, opts)
    return jnp.real(x)


def rfft3d_local(x: jax.Array) -> jax.Array:
    """Single-device r2c via the plan-based local transform (z-axis halved)."""
    return rfft3d(x, mesh=None)
