"""Public CROFT API: plan-style handle over the distributed 3-D FFT.

``Croft3D`` is the analogue of ``croft_parallel3d`` plus FFTW's plan object:
it binds (grid shape, mesh, decomposition, options) once, validates, and
exposes jit-compiled forward/inverse transforms.

Problem classes (FFTW-style): ``problem="c2c"`` (default) plans the
complex transform; ``problem="r2c"`` plans a real-input transform whose
forward matches ``numpy.fft.rfftn`` and whose inverse is the exact c2r
— backed by either the packed two-for-one pipeline or the embedding
fallback (``repro.real``, ``strategy=``).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import distributed, local_fft
from repro.core.decomposition import Decomposition, pencil_grid_for
from repro.core.distributed import FFTOptions


@dataclasses.dataclass
class Croft3D:
    """A planned distributed 3-D FFT.

    >>> plan = Croft3D((1024, 1024, 1024), mesh,
    ...                Decomposition("pencil", ("data", "model")))
    >>> y = plan.forward(x)        # x sharded with plan.input_sharding
    >>> x2 = plan.inverse(y)       # == x up to dtype tolerance

    Real transforms: ``Croft3D(shape, mesh, dec, problem="r2c")`` plans
    r2c/c2r.  ``forward`` then takes a real array (see ``input_dtype`` /
    ``input_sharding`` — the packed strategy wants z-pencils) and returns
    the (Nx, Ny, Nz//2 + 1) half spectrum; ``inverse`` returns the real
    field.  ``strategy`` picks "packed" / "embed" ("auto" = packed where
    supported).
    """

    shape: tuple[int, int, int]
    mesh: Optional[Mesh] = None
    decomp: Optional[Decomposition] = None
    opts: FFTOptions = dataclasses.field(default_factory=FFTOptions)
    dtype: jnp.dtype = jnp.complex64
    #: problem class: "c2c" | "r2c" (``dtype`` is always the spectrum dtype)
    problem: str = "c2c"
    #: r2c only: "packed" | "embed" | None (= auto); resolved in __post_init__
    strategy: Optional[str] = None
    #: autotune mode ("wisdom" | "model" | "measure"); when set, the
    #: planner overrides ``decomp``/``opts`` (see ``repro.tuning``)
    tune: Optional[str] = None
    #: tune for a *training step*: the planner prices forward + adjoint
    #: schedule (problem axis "c2c_grad"/"r2c_grad") instead of forward
    #: only.  Transforms themselves are identical — gradients work on
    #: every plan (repro.grad); this only changes which plan wins.
    grad: bool = False
    wisdom_path: Optional[str] = None
    #: extra keyword arguments for ``tuning.tune`` (top_k, measure_iters, ...)
    tune_kw: Optional[dict] = None
    #: searched pipeline (``tuning.candidates.ScheduleCandidate``): when
    #: set, forward/inverse run this explicit stage list (per-stage
    #: transpose impls / K) instead of the fixed builders; ``decomp`` and
    #: ``opts`` are taken from it.  c2c only.  Set directly, or by the
    #: tune path when the planner's schedule search picks one.
    schedule: Optional[object] = None
    tune_result = None  # TuneResult when the planner picked the plan

    def __post_init__(self):
        if self.problem not in ("c2c", "r2c"):
            hint = ("; grad-aware tuning is selected with grad=True "
                    "(Croft3D.tuned(..., grad=True)), not a problem suffix"
                    if str(self.problem).endswith("_grad") else "")
            raise ValueError(f"problem must be 'c2c' or 'r2c', got "
                             f"{self.problem!r}{hint}")
        if self.tune is not None and self.mesh is None:
            raise ValueError("tune= needs a mesh (single-device plans have "
                             "nothing to tune)")
        if self.tune is not None:
            from repro import tuning
            tune_problem = self.problem + ("_grad" if self.grad else "")
            result = tuning.tune(self.shape, self.mesh, mode=self.tune,
                                 dtype=self.dtype, problem=tune_problem,
                                 wisdom_path=self.wisdom_path,
                                 **(self.tune_kw or {}))
            self.decomp, self.opts = result.decomp, result.opts
            if self.problem == "r2c":
                self.strategy = result.strategy
            self.schedule = getattr(result, "schedule", None)
            self.tune_result = result
        if self.schedule is not None:
            if self.problem != "c2c":
                raise ValueError("schedule= (a searched pipeline) plans "
                                 "the c2c problem only")
            if self.mesh is None:
                raise ValueError("schedule= needs a mesh")
            self.decomp, self.opts = self.schedule.decomp, self.schedule.opts
        if self.mesh is not None:
            if self.decomp is None:
                raise ValueError("a mesh requires a Decomposition")
            if self.schedule is not None:
                # basic mesh/axis checks at the weakest fixed-builder
                # settings, then the searched pipeline's own shape checks
                # (its transpose orders chunk along different axes than
                # the fixed pipelines, so the fixed K rules don't apply)
                self.decomp.validate(self.shape, self.mesh, 1, "alltoall")
                self.schedule.validate(self.shape, dict(self.mesh.shape))
            else:
                self.decomp.validate(self.shape, self.mesh,
                                     self.opts.overlap_k,
                                     self.opts.transpose_impl)
        if self.problem == "r2c":
            from repro import real as real_lib
            from repro.core import rfft
            self.strategy = real_lib.resolve_strategy(
                self.strategy, self.shape, self.mesh, self.decomp, self.opts)
            strat, nz = self.strategy, self.shape[-1]
            self._fwd = jax.jit(lambda v: rfft.rfft3d(
                v, self.mesh, self.decomp, self.opts, strategy=strat))
            self._inv = jax.jit(lambda v: rfft.irfft3d(
                v, nz, self.mesh, self.decomp, self.opts, strategy=strat))
        elif self.schedule is not None:
            fsched = self.schedule.build_schedule()
            isched = distributed.inverse_schedule(fsched)
            self._sched_fwd = fsched
            mesh, opts = self.mesh, self.opts
            self._fwd = jax.jit(lambda v: distributed.scheduled_fft3d(
                v, mesh, fsched, opts))
            self._inv = jax.jit(lambda v: distributed.scheduled_fft3d(
                v, mesh, isched, opts, norm="backward"))
        else:
            self._fwd = jax.jit(lambda v: distributed.fft3d(
                v, self.mesh, self.decomp, self.opts))
            self._inv = jax.jit(lambda v: distributed.ifft3d(
                v, self.mesh, self.decomp, self.opts))

    # -- dtypes / shapes -----------------------------------------------------
    @property
    def input_dtype(self) -> jnp.dtype:
        """What ``forward`` consumes: real for r2c, ``dtype`` for c2c."""
        if self.problem == "r2c":
            from repro.real.packing import real_dtype_for
            return jnp.dtype(real_dtype_for(self.dtype))
        return jnp.dtype(self.dtype)

    @property
    def spectrum_shape(self) -> tuple[int, int, int]:
        """Global shape of ``forward``'s output."""
        if self.problem == "r2c":
            return self.shape[:-1] + (self.shape[-1] // 2 + 1,)
        return self.shape

    # -- shardings ---------------------------------------------------------
    @property
    def input_sharding(self) -> Optional[NamedSharding]:
        if self.mesh is None:
            return None
        if self.problem == "r2c" and self.strategy == "packed":
            # packed real input is z-pencils: the r2c stage runs first,
            # so the pipeline starts where the c2c pipeline ends
            return NamedSharding(self.mesh, self.decomp.spectral_spec())
        if self.schedule is not None:
            return NamedSharding(self.mesh,
                                 self._sched_fwd.layout_in.partition_spec())
        return self.decomp.sharding(self.mesh, "natural")

    @property
    def output_sharding(self) -> Optional[NamedSharding]:
        if self.mesh is None:
            return None
        if self.problem == "r2c":
            # the (Nx, Ny, Nh) half spectrum keeps Nh = Nz//2 + 1 local
            # (it never divides the z shards); both strategies emit a
            # z-local layout, so solvers see kz unsharded.  For cell the
            # spectral spec still shards z, so mirror the guarded
            # slice's choice: x/y sharded, z replicated.
            if self.decomp.kind == "cell":
                return NamedSharding(self.mesh, P(
                    self.decomp.axes[0], self.decomp.axes[1], None))
            return NamedSharding(self.mesh, self.decomp.spectral_spec())
        if self.schedule is not None:
            # searched transpose orders can end on layouts no fixed spec
            # names (e.g. x sharded by the z communicator) — the
            # schedule's own symbolic output layout is the truth
            return NamedSharding(self.mesh,
                                 self._sched_fwd.layout_out.partition_spec())
        return self.decomp.sharding(self.mesh, self.opts.output_layout)

    def local_shape(self) -> tuple[int, ...]:
        if self.mesh is None:
            return self.shape
        return self.decomp.local_shape(self.shape, self.mesh)

    # -- transforms ----------------------------------------------------------
    def forward(self, x: jax.Array) -> jax.Array:
        return self._fwd(x)

    def inverse(self, y: jax.Array) -> jax.Array:
        return self._inv(y)

    _fwd_filtered = None

    def _filtered_fn(self, fold: bool = False):
        """The jitted (x, h) -> filtered-spectrum callable (lazy; shared
        by :meth:`forward_filtered` and the batched dispatch path)."""
        if self._fwd_filtered is None:
            self._fwd_filtered = {}
        fn = self._fwd_filtered.get(fold)
        if fn is None:
            if self.problem == "r2c":
                from repro.core import rfft
                strat = self.strategy
                fn = jax.jit(lambda v, hh: rfft.rfft3d(
                    v, self.mesh, self.decomp, self.opts, strategy=strat,
                    kspace_filter=hh, fold_filter=fold))
            elif fold:
                raise ValueError("fold=True is the packed r2c folded "
                                 "epilogue; c2c filters are always fused "
                                 "in-schedule")
            elif self.schedule is not None:
                mesh, opts, fsched = self.mesh, self.opts, self._sched_fwd
                fn = jax.jit(lambda v, hh: distributed.scheduled_fft3d(
                    v, mesh, fsched, opts, kspace_filter=hh))
            else:
                fn = jax.jit(lambda v, hh: distributed.fft3d(
                    v, self.mesh, self.decomp, self.opts, kspace_filter=hh))
            self._fwd_filtered[fold] = fn
        return fn

    def forward_filtered(self, x: jax.Array, h: jax.Array,
                         alpha: float = 1.0, fold: bool = False) -> jax.Array:
        """``forward`` with the k-space multiply ``alpha * h`` fused in.

        The multiply rides as a schedule epilogue (c2c: attached to the
        last stage via ``Schedule.with_epilogue``; packed r2c: fused
        right after the DC/Nyquist plane unfold) through the
        ``kernels/spectral_scale.py`` path — one jit dispatch and no
        extra HBM round trip over the spectrum.  ``h`` must be shaped
        like ``spectrum_shape`` and placed with ``output_sharding``.

        ``fold=True`` (packed r2c only) moves the multiply *before* the
        DC/Nyquist unfold, onto the packed half spectrum inside the
        schedule — one fewer pass over the spectrum, valid for filters
        with ``h(kz=0) == h(kz=Nyquist)``, that plane real and 2-D-even
        (e.g. a kz-independent low-pass over (kx, ky), or any filter
        whose DC and Nyquist kz-planes coincide).
        """
        hh = h if alpha == 1.0 else h * jnp.asarray(alpha, h.dtype)
        return self._filtered_fn(fold)(x, hh)

    # -- batched dispatch (the serving path) ---------------------------------
    #
    # One executable per (plan, batch-size-bucket) moving B stacked fields
    # through the SAME collective count as B=1: the packed r2c pipeline
    # takes leading batch axes natively (its executor offsets every axis
    # index by the batch rank), everything else vmaps — under vmap the
    # per-stage all_to_alls batch into single collectives.  The c2c
    # entries donate the stacked input buffer (complex in, complex out,
    # same shape: XLA aliases it for the first stage's scratch).

    _batched = None  # lazy {(kind): jitted fn}

    def _batched_fn(self, kind: str):
        if self._batched is None:
            self._batched = {}
        fn = self._batched.get(kind)
        if fn is not None:
            return fn
        native_packed = self.problem == "r2c" and self.strategy == "packed"
        if kind == "forward":
            if native_packed:
                from repro.core import rfft
                strat = self.strategy
                fn = jax.jit(lambda v: rfft.rfft3d(
                    v, self.mesh, self.decomp, self.opts, strategy=strat))
            else:
                donate = (0,) if self.problem == "c2c" else ()
                fn = jax.jit(jax.vmap(self._fwd), donate_argnums=donate)
        elif kind == "inverse":
            if native_packed:
                from repro.core import rfft
                strat, nz = self.strategy, self.shape[-1]
                fn = jax.jit(lambda v: rfft.irfft3d(
                    v, nz, self.mesh, self.decomp, self.opts,
                    strategy=strat))
            else:
                donate = (0,) if self.problem == "c2c" else ()
                fn = jax.jit(jax.vmap(self._inv), donate_argnums=donate)
        elif kind == "filtered":
            donate = (0,) if self.problem == "c2c" else ()
            fn = jax.jit(jax.vmap(self._filtered_fn()),
                         donate_argnums=donate)
        else:
            raise ValueError(f"unknown batched kind {kind!r}")
        self._batched[kind] = fn
        return fn

    def forward_batched(self, x: jax.Array) -> jax.Array:
        """``forward`` over a (B, Nx, Ny, Nz) stack — same per-stage
        collective count as B=1, results bitwise equal to B calls of
        :meth:`forward`.  c2c donates ``x``."""
        return self._batched_fn("forward")(x)

    def inverse_batched(self, y: jax.Array) -> jax.Array:
        """``inverse`` over a (B, ...) spectrum stack (see
        :meth:`forward_batched`)."""
        return self._batched_fn("inverse")(y)

    def forward_filtered_batched(self, x: jax.Array,
                                 h: jax.Array) -> jax.Array:
        """:meth:`forward_filtered` over (B, ...) field and filter stacks
        (each request brings its own ``h``)."""
        return self._batched_fn("filtered")(x, h)

    def batched_sharding(self, which: str = "input"):
        """``input_sharding``/``output_sharding`` widened with a leading
        replicated batch axis (how the service places stacked payloads)."""
        base = (self.input_sharding if which == "input"
                else self.output_sharding)
        if base is None:
            return None
        return NamedSharding(self.mesh, P(None, *base.spec))

    def release(self) -> None:
        """Drop this plan's compiled executables (compile-cache hygiene:
        the serving plan cache calls this on eviction so shape diversity
        cannot grow XLA's live-executable set without bound)."""
        fns = [self._fwd, self._inv]
        fns += list((self._fwd_filtered or {}).values())
        fns += list((self._batched or {}).values())
        for fn in fns:
            clear = getattr(fn, "clear_cache", None)
            if clear is not None:
                try:
                    clear()
                except Exception:
                    pass  # best effort: an evicted plan must never raise
        self._fwd_filtered = None
        self._batched = None

    # -- autotuning ----------------------------------------------------------
    @classmethod
    def tuned(cls, shape, mesh: Mesh, *, mode: str = "model",
              wisdom_path: Optional[str] = None, dtype=jnp.complex64,
              problem: str = "c2c", batch: int = 1, grad: bool = False,
              **tune_kw) -> "Croft3D":
        """Plan via the autotuner (``repro.tuning``) instead of hand-picked
        (decomp, opts).

        ``mode="model"`` is FFTW ESTIMATE (analytic, zero execution),
        ``mode="measure"`` is PATIENT (times the top candidates on the
        mesh), ``mode="wisdom"`` reuses a stored plan from
        ``wisdom_path`` (or $CROFT_WISDOM).  ``problem="r2c"`` plans the
        real transform (the planner also chooses the packed/embed
        strategy).  ``batch=B`` plans for B vmapped fields: the cost
        model scales volume terms by B, ``mode="measure"`` times the
        *vmapped* transform over B stacked fields, and the wisdom key
        gains a ``|b{B}`` dimension (B=1 keeps the legacy key format).
        ``grad=True`` prices a *training step*: the cost model sums the
        forward schedule and its adjoint (``repro.grad``), measurement
        times ``jax.value_and_grad`` of a scalar loss through the
        transform, and the wisdom key gains a ``|grad`` dimension — the
        chosen plan is optimal for fwd+bwd, not just inference.
        The chosen plan's provenance is on ``plan.tune_result``.
        """
        if batch != 1:
            tune_kw = dict(tune_kw, batch=batch)
        return cls(tuple(shape), mesh, dtype=jnp.dtype(dtype), tune=mode,
                   problem=problem, grad=grad, wisdom_path=wisdom_path,
                   tune_kw=tune_kw or None)

    # -- AOT artifacts for the dry-run / roofline ----------------------------
    def lower_forward(self):
        spec = jax.ShapeDtypeStruct(self.shape, self.input_dtype,
                                    sharding=self.input_sharding)
        return self._fwd.lower(spec)

    def candidate(self):
        """This plan's tuner-space identity: the searched
        ``ScheduleCandidate`` when one was picked, else the
        (decomp, opts) ``Candidate`` — the object the cost model, the
        tracer attribution and the serve bucket keys all read."""
        from repro.tuning.candidates import Candidate
        if self.schedule is not None:
            if self.schedule.problem == self.problem:
                return self.schedule
            return dataclasses.replace(self.schedule, problem=self.problem)
        return Candidate(self.decomp, self.opts, problem=self.problem,
                         strategy=self.strategy)

    def _forward_schedule(self):
        """The stage schedule ``forward`` executes (None when meshless) —
        the tuner's ``cost_model.schedule_for``, so this plan's roofline
        numbers and the planner's ranking read the identical object
        (including out-of-body reshards like the embedding's guarded
        half-slice)."""
        if self.mesh is None or self.decomp is None:
            return None
        from repro.tuning.cost_model import schedule_for
        return schedule_for(self.shape, self.candidate())

    def flops_model(self) -> float:
        """Analytic 5 N log2 N FLOP count for the full 3-D transform,
        summed over the schedule's local-FFT events (so the packed real
        pipeline's halved stages are charged at their true sizes)."""
        sched = self._forward_schedule()
        if sched is None:
            n_total = math.prod(self.shape)
            flops = 5.0 * n_total * sum(math.log2(s) for s in self.shape)
            if self.problem == "r2c" and self.strategy == "packed":
                flops *= 0.5
            return flops
        sizes = dict(self.mesh.shape)
        per_device = sum(5.0 * elems * math.log2(n) for _, elems, n
                         in sched.fft_events(self.shape, sizes))
        return per_device * self.decomp.n_procs(sizes)

    def comm_bytes_model(self) -> float:
        """Bytes each chip injects per transform: the sum of the
        schedule's per-stage transpose volumes plus its out-of-body
        reshards (e.g. the packed pipeline's half-volume z-localizing
        epilogue) — read from the same ``Schedule`` the executor runs."""
        sched = self._forward_schedule()
        if sched is None:
            return 0.0
        itemsize = jnp.dtype(self.dtype).itemsize
        events = sched.comm_events(self.shape, dict(self.mesh.shape),
                                   itemsize)
        return float(sum(ev["bytes"] for ev in events))


def auto_pencil(shape: Sequence[int], mesh: Mesh,
                axes: Sequence[str] = ("data", "model")) -> Decomposition:
    """Pencil decomposition over the given mesh axes (fig. 5 virtual grid)."""
    return Decomposition("pencil", tuple(axes))


def poisson_solve(rhs: jax.Array, plan: Croft3D, box: float = 2 * math.pi):
    """Spectral Poisson solve  ∇²u = f  on a periodic box (example app).

    Works with both problem classes: a c2c plan sees the full spectrum, an
    r2c plan the Hermitian half (kz from ``rfftfreq``) — the real path
    demonstrates the packed pipeline's halved round trip.  The 1/(-k²)
    multiplier is *fused* into the forward transform as a schedule
    epilogue (``plan.forward_filtered``): one dispatch, no separate pass
    over the spectrum.
    """
    nx, ny, nz = plan.shape
    kx = jnp.fft.fftfreq(nx, d=box / (2 * math.pi * nx))
    ky = jnp.fft.fftfreq(ny, d=box / (2 * math.pi * ny))
    if plan.problem == "r2c":
        kz = jnp.fft.rfftfreq(nz, d=box / (2 * math.pi * nz))
    else:
        kz = jnp.fft.fftfreq(nz, d=box / (2 * math.pi * nz))
    k2 = (kx[:, None, None] ** 2 + ky[None, :, None] ** 2
          + kz[None, None, :] ** 2)
    inv_k2 = jnp.where(k2 == 0, 0.0, -1.0 / jnp.where(k2 == 0, 1.0, k2))
    inv_k2 = inv_k2.astype(plan.dtype)
    if plan.mesh is not None:
        inv_k2 = jax.device_put(inv_k2, NamedSharding(
            plan.mesh, plan.output_sharding.spec))
    u_hat = plan.forward_filtered(rhs.astype(plan.input_dtype), inv_k2)
    return plan.inverse(u_hat)
