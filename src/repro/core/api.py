"""Public CROFT API: plan-style handle over the distributed 3-D FFT.

``Croft3D`` is the analogue of ``croft_parallel3d`` plus FFTW's plan object:
it binds (grid shape, mesh, decomposition, options) once, validates, and
exposes jit-compiled forward/inverse transforms.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import distributed, local_fft
from repro.core.decomposition import Decomposition, pencil_grid_for
from repro.core.distributed import FFTOptions


@dataclasses.dataclass
class Croft3D:
    """A planned distributed 3-D FFT.

    >>> plan = Croft3D((1024, 1024, 1024), mesh,
    ...                Decomposition("pencil", ("data", "model")))
    >>> y = plan.forward(x)        # x sharded with plan.input_sharding
    >>> x2 = plan.inverse(y)       # == x up to dtype tolerance
    """

    shape: tuple[int, int, int]
    mesh: Optional[Mesh] = None
    decomp: Optional[Decomposition] = None
    opts: FFTOptions = dataclasses.field(default_factory=FFTOptions)
    dtype: jnp.dtype = jnp.complex64
    #: autotune mode ("wisdom" | "model" | "measure"); when set, the
    #: planner overrides ``decomp``/``opts`` (see ``repro.tuning``)
    tune: Optional[str] = None
    wisdom_path: Optional[str] = None
    #: extra keyword arguments for ``tuning.tune`` (top_k, measure_iters, ...)
    tune_kw: Optional[dict] = None
    tune_result = None  # TuneResult when the planner picked the plan

    def __post_init__(self):
        if self.tune is not None and self.mesh is None:
            raise ValueError("tune= needs a mesh (single-device plans have "
                             "nothing to tune)")
        if self.tune is not None:
            from repro import tuning
            result = tuning.tune(self.shape, self.mesh, mode=self.tune,
                                 dtype=self.dtype,
                                 wisdom_path=self.wisdom_path,
                                 **(self.tune_kw or {}))
            self.decomp, self.opts = result.decomp, result.opts
            self.tune_result = result
        if self.mesh is not None:
            if self.decomp is None:
                raise ValueError("a mesh requires a Decomposition")
            self.decomp.validate(self.shape, self.mesh, self.opts.overlap_k)
        self._fwd = jax.jit(
            lambda v: distributed.fft3d(v, self.mesh, self.decomp, self.opts))
        self._inv = jax.jit(
            lambda v: distributed.ifft3d(v, self.mesh, self.decomp, self.opts))

    # -- shardings ---------------------------------------------------------
    @property
    def input_sharding(self) -> Optional[NamedSharding]:
        if self.mesh is None:
            return None
        return self.decomp.sharding(self.mesh, "natural")

    @property
    def output_sharding(self) -> Optional[NamedSharding]:
        if self.mesh is None:
            return None
        return self.decomp.sharding(self.mesh, self.opts.output_layout)

    def local_shape(self) -> tuple[int, ...]:
        if self.mesh is None:
            return self.shape
        return self.decomp.local_shape(self.shape, self.mesh)

    # -- transforms ----------------------------------------------------------
    def forward(self, x: jax.Array) -> jax.Array:
        return self._fwd(x)

    def inverse(self, y: jax.Array) -> jax.Array:
        return self._inv(y)

    # -- autotuning ----------------------------------------------------------
    @classmethod
    def tuned(cls, shape, mesh: Mesh, *, mode: str = "model",
              wisdom_path: Optional[str] = None, dtype=jnp.complex64,
              **tune_kw) -> "Croft3D":
        """Plan via the autotuner (``repro.tuning``) instead of hand-picked
        (decomp, opts).

        ``mode="model"`` is FFTW ESTIMATE (analytic, zero execution),
        ``mode="measure"`` is PATIENT (times the top candidates on the
        mesh), ``mode="wisdom"`` reuses a stored plan from
        ``wisdom_path`` (or $CROFT_WISDOM).  The chosen plan's provenance
        is on ``plan.tune_result``.
        """
        return cls(tuple(shape), mesh, dtype=jnp.dtype(dtype), tune=mode,
                   wisdom_path=wisdom_path, tune_kw=tune_kw or None)

    # -- AOT artifacts for the dry-run / roofline ----------------------------
    def lower_forward(self):
        spec = jax.ShapeDtypeStruct(self.shape, self.dtype,
                                    sharding=self.input_sharding)
        return self._fwd.lower(spec)

    def flops_model(self) -> float:
        """Analytic 5 N log2 N FLOP count for the full c2c 3-D transform."""
        n_total = math.prod(self.shape)
        logn = sum(math.log2(s) for s in self.shape)
        return 5.0 * n_total * logn

    def comm_bytes_model(self) -> float:
        """Bytes each chip injects per transform (both transposes, natural
        layout doubles it; paper §4.1 transposes are full-volume shuffles)."""
        if self.mesh is None:
            return 0.0
        itemsize = jnp.dtype(self.dtype).itemsize
        n_local = math.prod(self.local_shape()) * itemsize
        n_transposes = {"slab": 1, "pencil": 2, "cell": 3}[self.decomp.kind]
        if self.opts.output_layout == "natural" and self.decomp.kind != "cell":
            n_transposes *= 2
        elif self.decomp.kind == "cell":
            n_transposes = 4 * 2  # regroup + pencil(2) + scatter, both ways
        return n_local * n_transposes


def auto_pencil(shape: Sequence[int], mesh: Mesh,
                axes: Sequence[str] = ("data", "model")) -> Decomposition:
    """Pencil decomposition over the given mesh axes (fig. 5 virtual grid)."""
    return Decomposition("pencil", tuple(axes))


def poisson_solve(rhs: jax.Array, plan: Croft3D, box: float = 2 * math.pi):
    """Spectral Poisson solve  ∇²u = f  on a periodic box (example app).

    Demonstrates the spectral-layout optimization: with
    ``opts.output_layout='spectral'`` the two restoring transposes of the
    forward and the two leading transposes of the inverse are all skipped.
    """
    nx, ny, nz = plan.shape
    f_hat = plan.forward(rhs.astype(plan.dtype))
    kx = jnp.fft.fftfreq(nx, d=box / (2 * math.pi * nx))
    ky = jnp.fft.fftfreq(ny, d=box / (2 * math.pi * ny))
    kz = jnp.fft.fftfreq(nz, d=box / (2 * math.pi * nz))
    k2 = (kx[:, None, None] ** 2 + ky[None, :, None] ** 2
          + kz[None, None, :] ** 2)
    inv_k2 = jnp.where(k2 == 0, 0.0, -1.0 / jnp.where(k2 == 0, 1.0, k2))
    if plan.mesh is not None:
        inv_k2 = jax.device_put(inv_k2, NamedSharding(
            plan.mesh, plan.output_sharding.spec))
    u_hat = f_hat * inv_k2.astype(plan.dtype)
    return plan.inverse(u_hat)
