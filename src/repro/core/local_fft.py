"""Local (single-device) 1-D FFT building blocks.

CROFT calls FFTW's 1-D routine along each axis; on TPU the idiomatic
equivalent is the four-step (Bailey) factorization applied as MXU matmuls
(see DESIGN.md §2).  Three interchangeable implementations:

- ``fft_matmul``   four-step via einsum (lowers everywhere; what the
                   distributed transform uses by default, and the oracle the
                   Pallas kernel is checked against)
- ``fft_stockham`` radix-2 decimation-in-time, vectorized (VPU-style)
- ``fft_xla``      ``jnp.fft.fft`` (XLA's FFT HLO; reference)

All operate along the *last* axis; callers move axes.  Forward sign=-1,
inverse sign=+1 unnormalized (normalization applied at the 3-D level, eq. (2)
of the paper).
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import plan as plan_lib


def fft_xla(x: jax.Array, sign: int = -1) -> jax.Array:
    return jnp.fft.fft(x) if sign == -1 else jnp.fft.ifft(x) * x.shape[-1]


def _apply_dft_matrix(x: jax.Array, w: jax.Array) -> jax.Array:
    # x (..., n), w (n, k): complex matmul on the MXU (XLA decomposes to
    # real dots); contraction over the last axis.
    return jnp.einsum("...n,nk->...k", x, w, precision=jax.lax.Precision.HIGHEST)


def fft_matmul(x: jax.Array, sign: int = -1, *, plan_cache: bool = True,
               max_radix: int = plan_lib.MAX_RADIX) -> jax.Array:
    """Four-step FFT along the last axis.  Supports any power-of-two size.

    n <= max_radix           : single DFT matmul
    n <= max_radix**2        : reshape (n1, n2); DFT(n1) matmul; twiddle;
                               DFT(n2) matmul; transpose  (the Pallas kernel
                               implements exactly this path)
    larger                   : six-step recursion on the n2 axis
    """
    n = x.shape[-1]
    plan = plan_lib.make_plan(n, sign, str(x.dtype), max_radix)
    w1, w2, tw = plan.constants_jnp(rematerialize=not plan_cache)
    if plan.n2 == 1:
        return _apply_dft_matrix(x, w1)

    batch = x.shape[:-1]
    n1, n2 = plan.n1, plan.n2
    # n = n2*j1 + j2  (row-major reshape)
    xr = x.reshape(batch + (n1, n2))
    # stage 1: DFT over j1 -> (..., n2, k1)
    y = jnp.einsum("...jt,jk->...tk", xr, w1,
                   precision=jax.lax.Precision.HIGHEST)
    # stage 2: twiddles T[j2, k1]
    y = y * tw
    if n2 <= max_radix:
        # stage 3: DFT over j2 -> (..., k1, k2): contract the t axis
        z = jnp.einsum("...tk,ts->...ks", y, w2,
                       precision=jax.lax.Precision.HIGHEST)
    else:
        # six-step: recurse along the n2 axis (currently axis -2); move it
        # last, recurse, move back
        y = jnp.swapaxes(y, -1, -2)  # (..., k1, n2)
        z = fft_matmul(y, sign, plan_cache=plan_cache, max_radix=max_radix)
        # z[..., k1, k2] already
    # output index k = k1 + n1*k2  -> lay out (..., k2, k1) then ravel
    z = jnp.swapaxes(z, -1, -2)
    return z.reshape(batch + (n,))


def fft_stockham(x: jax.Array, sign: int = -1, *, plan_cache: bool = True) -> jax.Array:
    """Radix-2 DIT FFT along the last axis (power-of-two sizes).

    Vectorized butterflies; the per-stage twiddles are plan constants.  This
    is the "CPU-shaped" algorithm kept for contrast with the matmul path.
    """
    n = x.shape[-1]
    if not plan_lib._is_pow2(n):
        raise ValueError(f"power-of-two sizes only, got {n}")
    stages = int(math.log2(n))
    # bit-reversal permutation as a static gather
    idx = np.arange(n)
    rev = np.zeros(n, dtype=np.int32)
    for b in range(stages):
        rev |= ((idx >> b) & 1) << (stages - 1 - b)
    y = x[..., rev]
    for s in range(stages):
        m = 1 << (s + 1)  # butterfly span
        half = m // 2
        if plan_cache:
            tw_np = np.exp(sign * 2j * np.pi * np.arange(half) / m).astype(
                np.dtype(str(x.dtype)))
            tw = jnp.asarray(tw_np)
        else:
            k = jnp.arange(half, dtype=jnp.float32)
            ang = (sign * 2.0 * jnp.pi / m) * k
            tw = jax.lax.complex(jnp.cos(ang), jnp.sin(ang)).astype(x.dtype)
        yr = y.reshape(y.shape[:-1] + (n // m, m))
        even, odd = yr[..., :half], yr[..., half:]
        t = odd * tw
        y = jnp.concatenate([even + t, even - t], axis=-1).reshape(y.shape)
    return y


_IMPLS = {"matmul": fft_matmul, "stockham": fft_stockham, "xla": fft_xla}


def fft_1d(x: jax.Array, axis: int, sign: int = -1, *, impl: str = "matmul",
           plan_cache: bool = True) -> jax.Array:
    """1-D FFT along ``axis`` with the chosen implementation."""
    if impl == "pallas":
        from repro.kernels import ops as kernel_ops  # lazy: optional dep path
        fn = lambda v: kernel_ops.fft_matmul_1d(v, sign=sign)
    elif impl == "xla":
        fn = lambda v: fft_xla(v, sign)
    else:
        base = _IMPLS[impl]
        fn = lambda v: base(v, sign, plan_cache=plan_cache)
    x = jnp.moveaxis(x, axis, -1)
    y = fn(x)
    return jnp.moveaxis(y, -1, axis)


def fft3d_local(x: jax.Array, sign: int = -1, *, impl="matmul",
                plan_cache: bool = True, norm: Optional[str] = None) -> jax.Array:
    """Single-device 3-D FFT over the last three axes (x, y, z order).

    ``impl`` may be a 3-tuple of implementations, one per axis in
    transform order (x, y, z) — the per-stage form of
    ``FFTOptions.local_impl``.
    """
    assert x.ndim >= 3
    for stage, ax in enumerate((-3, -2, -1)):
        stage_impl = impl[stage] if isinstance(impl, (tuple, list)) else impl
        x = fft_1d(x, ax, sign, impl=stage_impl, plan_cache=plan_cache)
    return apply_norm(x, sign, norm)


def apply_norm(x: jax.Array, sign: int, norm: Optional[str]) -> jax.Array:
    """Paper convention (eq. 2): forward unnormalized, inverse 1/(NxNyNz)."""
    nxyz = x.shape[-3] * x.shape[-2] * x.shape[-1]
    if norm is None or norm == "backward":
        return x / nxyz if sign == +1 else x
    if norm == "ortho":
        return x / jnp.sqrt(jnp.asarray(nxyz, x.dtype))
    if norm == "none":
        return x
    raise ValueError(f"unknown norm {norm!r}")
