"""CROFT core: pencil-decomposed distributed 3-D FFT (paper's contribution)."""

from repro.core.api import Croft3D, auto_pencil, poisson_solve
from repro.core.decomposition import Decomposition, pencil_grid_for
from repro.core.distributed import (FFTOptions, distributed_fft3d, fft3d,
                                    ifft3d)
from repro.core.local_fft import (fft3d_local, fft_1d, fft_matmul,
                                  fft_stockham, fft_xla)
from repro.core.plan import FFTPlan, clear_plan_cache, make_plan
from repro.core.rfft import irfft3d, rfft3d  # after the above: pulls repro.real

__all__ = [
    "Croft3D", "Decomposition", "FFTOptions", "FFTPlan", "auto_pencil",
    "clear_plan_cache", "distributed_fft3d", "fft3d", "fft3d_local", "fft_1d",
    "fft_matmul", "fft_stockham", "fft_xla", "ifft3d", "irfft3d", "make_plan",
    "pencil_grid_for", "poisson_solve", "rfft3d",
]
