"""Decomposition descriptors: slab (1-D), pencil (2-D), cell (3-D).

Paper §2.2.  A descriptor binds the decomposition kind to mesh axis names and
validates the divisibility/scaling constraints the paper derives:

  slab    P_max = Nz                (FFTW3's limitation, §2.2.1 / §3.1)
  pencil  P_max = Ny * Nz           (CROFT, P3DFFT, 2DECOMP&FFT)
  cell    P_max = Nx * Ny * Nz      (rarely used; highest comm volume)
"""

from __future__ import annotations

import dataclasses
import math
from typing import Mapping, Optional, Sequence, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

MeshLike = Union[Mesh, Mapping[str, int]]


def _mesh_axis_sizes(mesh: MeshLike) -> Mapping[str, int]:
    """Axis-name -> size mapping from a Mesh or a plain mapping.

    Accepting a mapping lets the tuning planner validate and score
    candidate decompositions without constructing devices (zero-execution
    ``mode="model"``).  Anything with a ``.shape`` name->size mapping
    (a real Mesh, or the tests' fakes) counts as a mesh."""
    shape = getattr(mesh, "shape", None)
    if shape is not None:
        return dict(shape)
    return dict(mesh)


@dataclasses.dataclass(frozen=True)
class Decomposition:
    """How an (Nx, Ny, Nz) grid maps onto mesh axes.

    ``axes`` are mesh axis names, one per decomposed grid dimension:
      slab:   (z_axis,)                 grid dim 2 sharded
      pencil: (y_axis, z_axis)          grid dims 1, 2 sharded (x-pencils)
      cell:   (x_axis, y_axis, z_axis)  all three sharded
    Each entry may itself be a tuple of mesh axes (folded, e.g. ("pod","data")).
    """

    kind: str  # "slab" | "pencil" | "cell"
    axes: tuple  # of str or tuple[str, ...]

    def __post_init__(self):
        # canonicalize lists (e.g. from JSON round trips) to tuples so
        # every Decomposition is hashable and two equal plans hash equal
        # — plan-cache keys depend on this
        object.__setattr__(self, "axes", tuple(
            tuple(a) if isinstance(a, list) else a for a in self.axes))
        expect = {"slab": 1, "pencil": 2, "cell": 3}
        if self.kind not in expect:
            raise ValueError(f"unknown decomposition kind {self.kind!r}")
        if len(self.axes) != expect[self.kind]:
            raise ValueError(
                f"{self.kind} needs {expect[self.kind]} mesh axes, got {self.axes}")

    # -- canonical string form (plan-cache / wisdom keys) -------------------
    def to_token(self) -> str:
        """Canonical string form, e.g. ``pencil[y,z]`` / ``pencil[pod+data,z]``
        (folded axis groups join with ``+``).  Round trips through
        :meth:`from_token`; mesh axis names must avoid ``[ ] , +``."""
        def axis_s(a):
            return "+".join(a) if isinstance(a, tuple) else a
        return f"{self.kind}[{','.join(axis_s(a) for a in self.axes)}]"

    @classmethod
    def from_token(cls, token: str) -> "Decomposition":
        """Inverse of :meth:`to_token`."""
        if not token.endswith("]") or "[" not in token:
            raise ValueError(f"malformed decomposition token {token!r}")
        kind, _, axes_s = token[:-1].partition("[")
        axes = []
        for part in axes_s.split(","):
            if not part:
                raise ValueError(f"malformed decomposition token {token!r}")
            groups = part.split("+")
            axes.append(tuple(groups) if len(groups) > 1 else groups[0])
        return cls(kind, tuple(axes))

    def axis_sizes(self, mesh: MeshLike) -> tuple[int, ...]:
        sizes = _mesh_axis_sizes(mesh)

        def size(a):
            if isinstance(a, tuple):
                return math.prod(sizes[x] for x in a)
            return sizes[a]
        return tuple(size(a) for a in self.axes)

    def n_procs(self, mesh: MeshLike) -> int:
        return math.prod(self.axis_sizes(mesh))

    def partition_spec(self) -> P:
        """Input/output PartitionSpec for the natural (x-aligned) layout."""
        if self.kind == "slab":
            return P(None, None, self.axes[0])
        if self.kind == "pencil":
            return P(None, self.axes[0], self.axes[1])
        return P(self.axes[0], self.axes[1], self.axes[2])

    def spectral_spec(self) -> P:
        """Output layout when the restoring transposes are skipped.

        pencil: z-pencils — x sharded over the y-communicator axes, y over
        the z-communicator axes (P3DFFT-style spectral layout).
        """
        if self.kind == "slab":
            return P(self.axes[0], None, None)
        if self.kind == "pencil":
            return P(self.axes[0], self.axes[1], None)
        return P(self.axes[0], self.axes[1], self.axes[2])

    def validate(self, shape: Sequence[int], mesh: MeshLike,
                 overlap_k: int = 1,
                 transpose_impl: str = "alltoall") -> None:
        nx, ny, nz = shape[-3], shape[-2], shape[-1]
        if transpose_impl in ("pairwise", "ring"):
            # both ppermute-based transposes (ring pipeline, FFTW3-style
            # MPI_Sendrecv emulation) exchange over ONE mesh axis; a
            # folded communicator would otherwise fail deep inside
            # shard_map with an opaque tracer error
            if any(isinstance(a, tuple) for a in self.axes):
                raise ValueError(
                    f"transpose_impl='{transpose_impl}' supports single "
                    f"mesh axes only; {self.kind} decomposition folds "
                    f"{self.axes}")
            if self.kind == "cell":
                raise ValueError(
                    f"transpose_impl='{transpose_impl}' is incompatible "
                    "with the cell decomposition: its x-regroup runs the "
                    "pencil pipeline over a folded (y, x) communicator")
        sizes = self.axis_sizes(mesh)
        if self.kind == "slab":
            (pz,) = sizes
            if pz > nz:
                raise ValueError(
                    f"slab decomposition limited to P <= Nz: P={pz} > Nz={nz} "
                    "(the FFTW3 scaling wall, paper table 1)")
            _check_div("Nz", nz, pz)
            _check_div("Nx", nx, pz)  # needed by the x<->z transpose
            if overlap_k > 1:
                _check_div("Ny (overlap chunks)", ny, overlap_k)
        elif self.kind == "pencil":
            py, pz = sizes
            if py * pz > ny * nz:
                raise ValueError(f"pencil needs P <= Ny*Nz, got {py*pz} > {ny*nz}")
            _check_div("Ny", ny, py)
            _check_div("Nz", nz, pz)
            _check_div("Nx", nx, py)   # x<->y transpose
            _check_div("Ny", ny, pz)   # y<->z transpose
            if overlap_k > 1:
                _check_div("Nz/Pz (stage-1 chunks)", nz // pz, overlap_k)
                _check_div("Nx/Py (stage-2 chunks)", nx // py, overlap_k)
        else:  # cell
            px, py, pz = sizes
            _check_div("Nx", nx, px * py)
            _check_div("Ny", ny, py)
            _check_div("Nz", nz, pz)

    def sharding(self, mesh: Mesh, layout: str = "natural") -> NamedSharding:
        spec = self.partition_spec() if layout == "natural" else self.spectral_spec()
        return NamedSharding(mesh, spec)

    def is_valid(self, shape: Sequence[int], mesh: MeshLike,
                 overlap_k: int = 1,
                 transpose_impl: str = "alltoall") -> bool:
        """Non-raising :meth:`validate` (used by the tuning planner)."""
        try:
            self.validate(shape, mesh, overlap_k, transpose_impl)
        except (ValueError, KeyError):
            return False
        return True

    def local_shape(self, shape: Sequence[int], mesh: MeshLike) -> tuple[int, ...]:
        nx, ny, nz = shape[-3], shape[-2], shape[-1]
        sizes = self.axis_sizes(mesh)
        if self.kind == "slab":
            return (nx, ny, nz // sizes[0])
        if self.kind == "pencil":
            return (nx, ny // sizes[0], nz // sizes[1])
        return (nx // sizes[0], ny // sizes[1], nz // sizes[2])


def _check_div(name: str, n: int, p: int) -> None:
    if n % p != 0:
        raise ValueError(f"{name}={n} not divisible by {p}")


def pencil_grid_for(n_procs: int, ny: int, nz: int) -> tuple[int, int]:
    """Pick a near-square Py x Pz = n_procs factorization (paper fig. 5).

    Prefers Py <= Pz and respects Py | Ny, Pz | Nz.
    """
    best = None
    for py in range(1, n_procs + 1):
        if n_procs % py:
            continue
        pz = n_procs // py
        if ny % py or nz % pz:
            continue
        score = abs(math.log2(py) - math.log2(pz))
        if best is None or score < best[0]:
            best = (score, py, pz)
    if best is None:
        raise ValueError(f"no valid pencil grid for P={n_procs}, Ny={ny}, Nz={nz}")
    return best[1], best[2]
