"""FFT plans: precomputed DFT matrices and twiddle factors.

CROFT's "option 2/4 — single FFTW3 plan" amortizes plan creation across all
1-D transforms.  The XLA analogue of an FFTW plan is the set of *constants*
a transform needs — DFT matrices for the four-step (Bailey) factorization and
twiddle factors — plus the static factorization decision itself.  A cached
:class:`FFTPlan` makes these compile-time constants (planned once, reused for
every 1-D FFT in the 3-D transform); ``plan_cache=False`` reproduces CROFT's
"multiple plans" options 1/3 by re-materializing the constants with runtime
ops inside every call, so the extra work is visible in the lowered HLO
exactly like repeated ``fftw_plan_dft_1d`` calls are visible in an MPI trace.
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

# Largest DFT applied as a single matmul.  64 keeps the stacked-real complex
# matmul at exactly 128x128 — one MXU tile on TPU.
MAX_RADIX = 64
# Largest 1-D size handled by a single two-level four-step plan (the Pallas
# kernel path).  Larger sizes recurse (six-step) on the jnp path.
MAX_TWO_LEVEL = MAX_RADIX * MAX_RADIX


def _is_pow2(n: int) -> bool:
    return n >= 1 and (n & (n - 1)) == 0


def split_factors(n: int, max_radix: int = MAX_RADIX) -> tuple[int, int]:
    """Balanced n = n1 * n2 split with n1 <= max_radix, n1 >= n2 bias.

    Power-of-two sizes only (the paper's own restriction: N = 2^n).
    """
    if not _is_pow2(n):
        raise ValueError(f"CROFT requires power-of-two sizes, got {n}")
    if n <= max_radix:
        return n, 1
    p = int(math.log2(n))
    p1 = min(int(math.log2(max_radix)), (p + 1) // 2)
    # bias n1 up to max_radix so the matmul dimension stays MXU-sized
    p1 = min(int(math.log2(max_radix)), max(p1, p - int(math.log2(max_radix))))
    # ensure n2 = n / n1 also recursable
    return 2 ** p1, 2 ** (p - p1)


def dft_matrix(n: int, sign: int, dtype=np.complex64) -> np.ndarray:
    """Dense DFT matrix W[j, k] = exp(sign * 2πi * j * k / n)."""
    jk = np.outer(np.arange(n), np.arange(n))
    return np.exp(sign * 2j * np.pi * jk / n).astype(dtype)


def twiddle_matrix(n1: int, n2: int, sign: int, dtype=np.complex64) -> np.ndarray:
    """Four-step inter-stage twiddles T[n2, k1] = exp(sign*2πi*k1*n2/(n1*n2)).

    Laid out (n2, k1) to match the kernel's post-stage-1 operand layout.
    """
    k1 = np.arange(n1)
    j2 = np.arange(n2)
    return np.exp(sign * 2j * np.pi * np.outer(j2, k1) / (n1 * n2)).astype(dtype)


def stacked_real(w: np.ndarray) -> np.ndarray:
    """Complex (n, n) matrix -> stacked-real (2n, 2n) for one-dot complex matmul.

    [xr xi] @ [[Wr, Wi], [-Wi, Wr]] == [Re(x@W), Im(x@W)].
    """
    wr, wi = w.real.astype(np.float32), w.imag.astype(np.float32)
    top = np.concatenate([wr, wi], axis=1)
    bot = np.concatenate([-wi, wr], axis=1)
    return np.concatenate([top, bot], axis=0)


@dataclasses.dataclass(frozen=True)
class FFTPlan:
    """Plan for a 1-D FFT of power-of-two size ``n`` (four-step factorized).

    Holds numpy constants; they become XLA constants when closed over in a
    jitted function (the "planned" path) or are rebuilt with runtime ops when
    the plan cache is disabled.
    """

    n: int
    n1: int
    n2: int
    sign: int  # -1 forward, +1 inverse
    dtype: np.dtype
    w1: np.ndarray  # (n1, n1) complex DFT matrix
    w2: Optional[np.ndarray]  # (n2, n2) or None when n2 == 1
    tw: Optional[np.ndarray]  # (n2, n1) twiddles or None when n2 == 1
    w1_stacked: np.ndarray  # (2*n1, 2*n1) float32
    w2_stacked: Optional[np.ndarray]

    @property
    def two_level(self) -> bool:
        return self.n2 <= MAX_RADIX

    def constants_jnp(self, rematerialize: bool = False):
        """Return (w1, w2, tw) as jnp complex arrays.

        With ``rematerialize=True`` ("multiple plans" mode, CROFT options
        1/3) the constants are recomputed with runtime jnp ops on every call
        instead of being baked in as literals.
        """
        if not rematerialize:
            return (jnp.asarray(self.w1),
                    None if self.w2 is None else jnp.asarray(self.w2),
                    None if self.tw is None else jnp.asarray(self.tw))
        # runtime re-planning: iota/outer/exp show up in the HLO per call
        sign = self.sign

        def _dft(n):
            j = jnp.arange(n, dtype=jnp.float32)
            ang = (sign * 2.0 * jnp.pi / n) * jnp.outer(j, j)
            return jax.lax.complex(jnp.cos(ang), jnp.sin(ang)).astype(self.dtype)

        w1 = _dft(self.n1)
        w2 = _dft(self.n2) if self.n2 > 1 else None
        if self.n2 > 1:
            k1 = jnp.arange(self.n1, dtype=jnp.float32)
            j2 = jnp.arange(self.n2, dtype=jnp.float32)
            ang = (sign * 2.0 * jnp.pi / self.n) * jnp.outer(j2, k1)
            tw = jax.lax.complex(jnp.cos(ang), jnp.sin(ang)).astype(self.dtype)
        else:
            tw = None
        return w1, w2, tw


@functools.lru_cache(maxsize=256)
def make_plan(n: int, sign: int = -1, dtype_name: str = "complex64",
              max_radix: int = MAX_RADIX) -> FFTPlan:
    """The cached planner — CROFT's "single plan" path."""
    dtype = np.dtype(dtype_name)
    n1, n2 = split_factors(n, max_radix)
    w1 = dft_matrix(n1, sign, dtype)
    if n2 > 1:
        # w2 used only on the two-level path; recursion re-plans for n2>MAX
        w2_size = n2 if n2 <= max_radix else None
        w2 = dft_matrix(n2, sign, dtype) if w2_size else None
        tw = twiddle_matrix(n1, n2, sign, dtype)
    else:
        w2, tw = None, None
    return FFTPlan(
        n=n, n1=n1, n2=n2, sign=sign, dtype=dtype,
        w1=w1, w2=w2, tw=tw,
        w1_stacked=stacked_real(w1),
        w2_stacked=None if w2 is None else stacked_real(w2),
    )


def plan_cache_info():
    return make_plan.cache_info()


def clear_plan_cache():
    make_plan.cache_clear()
