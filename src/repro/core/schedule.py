"""Stage-schedule IR: one declarative representation of the FFT pipeline.

The paper's pipeline (§4.1 steps 1-9, overlapped via K chunks) used to be
hardcoded per decomposition in ``core/distributed.py``, again for the
packed real transform in ``real/pipeline.py``, and shadow-modeled a third
time by the tuner's cost model.  P3DFFT treats decomposition pipelines as
*data* — a framework enumerating layouts and exchange sequences — and
OpenFFT tunes exactly such schedule-level choices per problem.  This
module does the same for the JAX port:

  ``Stage``      one pipeline step: optional prologue ops, an optional
                 local 1-D FFT, optional epilogue ops, and an optional
                 global transpose (all_to_all over one communicator),
                 K-chunked along an uninvolved axis for overlap.
  ``Layout``     symbolic local-block layout: which mesh axes shard each
                 grid dimension, static divisors (the packed half
                 spectrum), and real/complex dtype class.  Schedules
                 propagate layouts through every stage at build time, so
                 malformed pipelines fail *before* tracing and the cost
                 model can read per-stage bytes without re-deriving
                 stage structure from ``Decomposition.kind``.
  ``Schedule``   an ordered stage list + terminal epilogue ops (e.g. the
                 fused k-space multiply, ``with_epilogue``) + metadata
                 for collectives that happen outside the shard_map body
                 (the packed pipeline's z-localizing reshard).
  ``run_schedule``  the single executor: owns K-chunked overlap, the
                 chunk-indivisible fallback (``effective_k``), per-stage
                 ``local_impl`` selection, and batch-axis offsetting
                 (leading unsharded batch dims shift every axis index).

Builders are pure functions ``Decomposition x problem x layout ->
Schedule``: :func:`build_c2c` here covers every complex pipeline
(pencil / slab / cell, natural / spectral, forward / from-spectral);
``repro.real.pipeline.build_packed_forward/inverse`` build the packed
two-for-one real pipelines (pencil and slab) on the same IR.  The tuner
(``repro.tuning.cost_model``) walks these same objects, so candidate
scoring and execution can never drift apart.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence, Union

import jax
import jax.numpy as jnp

from repro.compat import axis_size
from repro.core import local_fft

AxisName = Union[str, tuple]

_DIMS = ("x", "y", "z")


class ScheduleError(ValueError):
    """A builder produced an inconsistent pipeline (caught at build time)."""


def _flat(axis) -> tuple:
    """Flatten a (possibly nested-folded) mesh axis spec to bare names."""
    if isinstance(axis, tuple):
        out = []
        for a in axis:
            out.extend(_flat(a))
        return tuple(out)
    return (axis,)


def _axis_size(axis: AxisName) -> int:
    """Size of a (possibly folded) mesh axis from inside shard_map."""
    if isinstance(axis, tuple):
        return math.prod(axis_size(a) for a in _flat(axis))
    return axis_size(axis)


def _axis_str(axis: AxisName) -> str:
    return "+".join(_flat(axis))


# ---------------------------------------------------------------------------
# symbolic layouts
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class LayoutAxis:
    """One grid dimension of a local block.

    local extent = shape[dim] / prod(mesh axis sizes of ``shards``) / den
    (``den`` is the static divisor of e.g. the packed Nz/2 half spectrum
    or the paired axis while two pencils share one complex transform).
    """

    dim: str                      # "x" | "y" | "z"
    shards: tuple = ()            # flat mesh-axis names sharding this dim
    den: int = 1

    def local_extent(self, n: int, sizes) -> int:
        return n // math.prod(sizes[s] for s in self.shards) // self.den

    def __str__(self) -> str:
        s = f"N{self.dim}"
        if self.den != 1:
            s += f":{self.den}"
        for name in self.shards:
            s += f"/{name}"
        return s


@dataclasses.dataclass(frozen=True)
class Layout:
    """Symbolic local-block layout (three grid dims + dtype class)."""

    axes: tuple                   # (LayoutAxis, LayoutAxis, LayoutAxis)
    real: bool = False

    def local_shape(self, shape: Sequence[int], axis_sizes) -> tuple:
        sizes = dict(axis_sizes)
        return tuple(a.local_extent(n, sizes)
                     for a, n in zip(self.axes, shape[-3:]))

    def elems(self, shape: Sequence[int], axis_sizes) -> int:
        return math.prod(self.local_shape(shape, axis_sizes))

    def bytes(self, shape: Sequence[int], axis_sizes,
              complex_itemsize: int = 8) -> int:
        item = complex_itemsize // 2 if self.real else complex_itemsize
        return self.elems(shape, axis_sizes) * item

    def partition_spec(self):
        from jax.sharding import PartitionSpec as P
        entries = []
        for a in self.axes:
            if not a.shards:
                entries.append(None)
            elif len(a.shards) == 1:
                entries.append(a.shards[0])
            else:
                entries.append(tuple(a.shards))
        return P(*entries)

    # -- transforms used by the schedule propagation ------------------------
    def after_all_to_all(self, comm_axis: AxisName, split_axis: int,
                         concat_axis: int) -> "Layout":
        """The concat dim loses the communicator's shards (its local extent
        grows), the split dim gains them — a global transpose."""
        names = _flat(comm_axis)
        axes = list(self.axes)
        cat = axes[concat_axis]
        missing = [n for n in names if n not in cat.shards]
        if missing:
            raise ScheduleError(
                f"all_to_all over {names} concatenates dim {cat.dim!r} which "
                f"is not sharded by {missing} (layout {self})")
        axes[concat_axis] = dataclasses.replace(
            cat, shards=tuple(s for s in cat.shards if s not in names))
        spl = axes[split_axis]
        axes[split_axis] = dataclasses.replace(spl, shards=spl.shards + names)
        return dataclasses.replace(self, axes=tuple(axes))

    def with_den(self, axis: int, mul: int = 1, div: int = 1) -> "Layout":
        axes = list(self.axes)
        a = axes[axis]
        den = a.den * mul
        if den % div:
            raise ScheduleError(f"cannot divide den={den} of {a} by {div}")
        axes[axis] = dataclasses.replace(a, den=den // div)
        return dataclasses.replace(self, axes=tuple(axes))

    def check_fft_axis(self, axis: int) -> None:
        a = self.axes[axis]
        if a.shards:
            raise ScheduleError(
                f"FFT along dim {a.dim!r} while it is sharded by {a.shards} "
                f"(layout {self})")

    def __str__(self) -> str:
        tag = "R" if self.real else "C"
        return tag + "(" + ", ".join(str(a) for a in self.axes) + ")"


def layout_for(decomp, which: str = "natural", real: bool = False) -> Layout:
    """The :class:`Layout` of a decomposition's natural/spectral spec."""
    spec = (decomp.partition_spec() if which == "natural"
            else decomp.spectral_spec())
    axes = tuple(
        LayoutAxis(dim, () if entry is None else _flat(entry))
        for dim, entry in zip(_DIMS, spec))
    return Layout(axes, real=real)


# ---------------------------------------------------------------------------
# stage ops (prologue/epilogue): declarative, layout-aware
# ---------------------------------------------------------------------------

class StageOp:
    """Protocol for prologue/epilogue ops.

    ``apply`` runs inside the executor (per K-chunk for chunked stages);
    ``transform`` propagates the symbolic layout; ``describe`` renders the
    op for golden snapshots.  Heavy imports happen lazily inside ``apply``
    so the IR stays importable from anywhere (core <-> real <-> kernels).
    """

    def apply(self, blk, opts, ctx, off: int):
        raise NotImplementedError

    def transform(self, layout: Layout) -> Layout:
        return layout

    def describe(self) -> str:
        return type(self).__name__


@dataclasses.dataclass(frozen=True)
class PackTwo(StageOp):
    """Pair two real pencils along ``pair_axis`` into one complex block."""

    pair_axis: int

    def apply(self, blk, opts, ctx, off):
        from repro.real import packing
        return packing.pack_two(blk, self.pair_axis + off)

    def transform(self, layout):
        if not layout.real:
            raise ScheduleError("pack2 needs a real block")
        return dataclasses.replace(
            layout.with_den(self.pair_axis, mul=2), real=False)

    def describe(self):
        return f"pack2[{_DIMS[self.pair_axis]}]"


@dataclasses.dataclass(frozen=True)
class UnpackTwo(StageOp):
    """Split the packed z spectrum into two folded half spectra (the
    shard-aligned Nz/2-bin layout, Nyquist folded into DC)."""

    pair_axis: int
    z_axis: int = 2
    impl_stage: int = 0

    def apply(self, blk, opts, ctx, off):
        from repro.real import packing
        use_pallas = opts.stage_impl(self.impl_stage) == "pallas"
        return packing.unpack_two(blk, self.pair_axis + off, fold=True,
                                  use_pallas=use_pallas)

    def transform(self, layout):
        return layout.with_den(self.pair_axis, div=2).with_den(
            self.z_axis, mul=2)

    def describe(self):
        return f"unpack2[{_DIMS[self.pair_axis]}]"


@dataclasses.dataclass(frozen=True)
class RepackHalves(StageOp):
    """Inverse of :class:`UnpackTwo`: rebuild the full packed z spectrum."""

    pair_axis: int
    nz: int
    z_axis: int = 2
    impl_stage: int = 2

    def apply(self, blk, opts, ctx, off):
        from repro.real import packing
        use_pallas = opts.stage_impl(self.impl_stage) == "pallas"
        return packing.repack_halves(blk, self.pair_axis + off, self.nz,
                                     folded=True, use_pallas=use_pallas)

    def transform(self, layout):
        return layout.with_den(self.pair_axis, mul=2).with_den(
            self.z_axis, div=2)

    def describe(self):
        return f"repack2[{_DIMS[self.pair_axis]}]"


@dataclasses.dataclass(frozen=True)
class SplitPairs(StageOp):
    """Complex block -> real block, doubled along ``pair_axis``."""

    pair_axis: int

    def apply(self, blk, opts, ctx, off):
        from repro.real import packing
        return packing.split_pairs(blk, self.pair_axis + off)

    def transform(self, layout):
        if layout.real:
            raise ScheduleError("split2 needs a complex block")
        return dataclasses.replace(
            layout.with_den(self.pair_axis, div=2), real=True)

    def describe(self):
        return f"split2[{_DIMS[self.pair_axis]}]"


@dataclasses.dataclass(frozen=True)
class SpectralScale(StageOp):
    """Fused k-space multiply: ``blk * alpha * operands[key]``.

    Attached via :meth:`Schedule.with_epilogue`; the filter block arrives
    through the executor's ``operands`` mapping sharded like the layout at
    the attachment point (``Schedule.layout_out`` for terminal epilogues).
    """

    key: str = "filter"
    alpha: float = 1.0

    def apply(self, blk, opts, ctx, off):
        if self.key not in ctx:
            raise ScheduleError(
                f"schedule epilogue needs operand {self.key!r}; pass it via "
                "run_schedule(..., operands={...})")
        from repro.kernels import spectral_scale as ss
        return ss.spectral_scale(blk, ctx[self.key], self.alpha)

    def describe(self):
        return f"kscale[{self.key}]"


# ---------------------------------------------------------------------------
# stages and schedules
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Stage:
    """One pipeline step (paper steps {1,2,3} / {5,6,7} as one unit).

    Executed as: prologue ops -> local FFT along ``fft_axis`` (if any,
    using ``opts.stage_impl(impl_stage)``) -> epilogue ops -> all_to_all
    over ``comm_axis`` (if any).  When a communicator is present the whole
    chain is split into K chunks along ``chunk_axis`` (an axis not
    involved in the transpose): chunk i's collective has no data
    dependence on chunk i+1's FFT, so XLA's async collective scheduler
    overlaps them — the paper's second OpenMP thread.

    ``transpose_impl`` / ``overlap_k`` are *per-stage* overrides of the
    same-named :class:`FFTOptions` knobs (None = inherit).  They are what
    the schedule-space search tunes: ring on the small communicator,
    alltoall on the large one, different K per stage — OpenFFT's
    per-exchange pattern choice, expressed in the IR the executor runs.
    """

    name: str
    fft_axis: Optional[int] = None
    comm_axis: Optional[AxisName] = None
    split_axis: int = 0
    concat_axis: int = 0
    chunk_axis: int = 0
    impl_stage: int = 0
    prologue: tuple = ()
    epilogue: tuple = ()
    transpose_impl: Optional[str] = None
    overlap_k: Optional[int] = None


def stage_transpose_impl(st: Stage, opts) -> str:
    """The transpose implementation this stage actually runs (its own
    override when set, else the plan-wide ``opts.transpose_impl``)."""
    return st.transpose_impl if st.transpose_impl is not None \
        else opts.transpose_impl


def stage_overlap_k(st: Stage, opts) -> int:
    """The chunk count this stage actually targets (its own override when
    set, else the plan-wide ``opts.overlap_k``)."""
    return st.overlap_k if st.overlap_k is not None else opts.overlap_k


@dataclasses.dataclass(frozen=True)
class StagePoints:
    """Layouts at the four observation points of one stage."""

    entry: Layout                 # stage input (what gets K-chunked)
    fft: Layout                   # after prologue (the FFT operand)
    comm: Layout                  # after epilogue (what the a2a moves)
    out: Layout                   # after the a2a


@dataclasses.dataclass(frozen=True)
class ExtraComm:
    """A collective outside the shard_map body (metadata for the cost
    model): e.g. the packed pipeline's z-localizing epilogue reshard —
    one fused all-to-all of the half volume, never K-chunked."""

    name: str
    layout: Layout


@dataclasses.dataclass(frozen=True)
class Schedule:
    """A fully-specified pipeline: stages + terminal epilogue + metadata.

    Layouts are propagated through every stage at construction; an
    inconsistent builder (FFT along a sharded axis, transpose over a
    communicator the concat dim is not sharded by, ...) raises
    :class:`ScheduleError` immediately.
    """

    name: str
    sign: int
    layout_in: Layout
    stages: tuple
    epilogue: tuple = ()          # terminal ops, run once (never chunked)
    extra_comms: tuple = ()       # out-of-body collectives (metadata only)
    points: tuple = None          # derived; do not pass

    def __post_init__(self):
        points = []
        cur = self.layout_in
        for st in self.stages:
            entry = cur
            for op in st.prologue:
                cur = op.transform(cur)
            if st.fft_axis is not None:
                cur.check_fft_axis(st.fft_axis)
            fft = cur
            for op in st.epilogue:
                cur = op.transform(cur)
            comm = cur
            if st.comm_axis is not None:
                cur = cur.after_all_to_all(st.comm_axis, st.split_axis,
                                           st.concat_axis)
            points.append(StagePoints(entry, fft, comm, cur))
        for op in self.epilogue:
            cur = op.transform(cur)
        object.__setattr__(self, "points", tuple(points))
        object.__setattr__(self, "_layout_out", cur)

    @property
    def layout_out(self) -> Layout:
        return self._layout_out

    def with_epilogue(self, op: StageOp) -> "Schedule":
        """Attach a terminal epilogue op to the last stage (run once on the
        final block, after its collective — never per-chunk)."""
        return dataclasses.replace(self, epilogue=self.epilogue + (op,),
                                   points=None)

    # -- introspection (cost model, golden tests, effective_k) --------------
    def comm_stages(self) -> list:
        return [(i, st) for i, st in enumerate(self.stages)
                if st.comm_axis is not None]

    def transpose_count(self) -> int:
        """Global transposes per transform, including out-of-body reshards
        (the single source the tuner and ``Croft3D`` both read)."""
        return len(self.comm_stages()) + len(self.extra_comms)

    def effective_k(self, shape: Sequence[int], axis_sizes,
                    overlap_k: int) -> tuple:
        """Per-comm-stage chunk count the executor will actually use: K
        where the stage-entry extent of ``chunk_axis`` divides, else the
        silent fallback to 1 (no overlap for that stage)."""
        out = []
        for i, st in self.comm_stages():
            ext = self.points[i].entry.local_shape(shape, axis_sizes)[
                st.chunk_axis]
            k = st.overlap_k if st.overlap_k is not None else overlap_k
            out.append(k if k > 1 and ext % k == 0 else 1)
        return tuple(out)

    def fft_events(self, shape: Sequence[int], axis_sizes) -> list:
        """(impl_stage, local_elems, transform_size) per local FFT, in
        pipeline order — what the cost model charges compute for."""
        out = []
        for st, pts in zip(self.stages, self.points):
            if st.fft_axis is None:
                continue
            loc = pts.fft.local_shape(shape, axis_sizes)
            out.append((st.impl_stage, math.prod(loc), loc[st.fft_axis]))
        return out

    def comm_events(self, shape: Sequence[int], axis_sizes,
                    complex_itemsize: int = 8) -> list:
        """One dict per collective: bytes each chip injects, communicator
        size, chunkability — in-body transposes first, then out-of-body
        reshards (one fused all-to-all each, never chunked)."""
        sizes = dict(axis_sizes)
        out = []
        for i, st in self.comm_stages():
            pts = self.points[i]
            csize = math.prod(sizes[n] for n in _flat(st.comm_axis))
            out.append({
                "name": st.name,
                "bytes": pts.comm.bytes(shape, axis_sizes, complex_itemsize),
                "comm_size": csize,
                "chunkable": True,
                "chunk_extent": pts.entry.local_shape(shape, axis_sizes)[
                    st.chunk_axis],
            })
        for ec in self.extra_comms:
            out.append({
                "name": ec.name,
                "bytes": ec.layout.bytes(shape, axis_sizes, complex_itemsize),
                "comm_size": 1,
                "chunkable": False,
                "chunk_extent": 1,
            })
        return out

    def describe(self) -> str:
        """Stable text rendering (the golden-snapshot format)."""
        lines = [f"schedule {self.name} sign={self.sign:+d}",
                 f"  in : {self.layout_in}"]
        for i, (st, pts) in enumerate(zip(self.stages, self.points)):
            parts = [op.describe() for op in st.prologue]
            if st.fft_axis is not None:
                parts.append(f"fft[{_DIMS[st.fft_axis]}]@s{st.impl_stage}")
            parts.extend(op.describe() for op in st.epilogue)
            if st.comm_axis is not None:
                a2a = (f"a2a[{_axis_str(st.comm_axis)}] split={st.split_axis} "
                       f"concat={st.concat_axis} chunk={st.chunk_axis}")
                if st.transpose_impl is not None:
                    a2a += f" impl={st.transpose_impl}"
                if st.overlap_k is not None:
                    a2a += f" K={st.overlap_k}"
                parts.append(a2a)
            lines.append(f"  {i} {st.name}: " + " | ".join(parts)
                         + f" -> {pts.out}")
        for op in self.epilogue:
            lines.append(f"  + epilogue {op.describe()}")
        for ec in self.extra_comms:
            lines.append(f"  + reshard {ec.name}: {ec.layout} "
                         "(one fused all-to-all)")
        lines.append(f"  out: {self.layout_out}")
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# the executor
# ---------------------------------------------------------------------------

def _fft_along(blk: jax.Array, axis: int, sign: int, opts,
               stage: int = 0) -> jax.Array:
    return local_fft.fft_1d(blk, axis, sign, impl=opts.stage_impl(stage),
                            plan_cache=opts.plan_cache)


def _pack_pieces(blk: jax.Array, axis: AxisName, split_axis: int) -> list:
    """Rotated-block pack shared by the ring and pairwise transposes.

    One fused pass (``kernels/transpose_pack.rotate_blocks``) rotates the
    P send blocks of ``split_axis`` by this rank's index, after which
    piece s — the block bound for rank ``(idx + s) % P`` — is a *static*
    slice, replacing the per-round ``dynamic_slice`` of the old path.
    """
    from repro.kernels import transpose_pack
    p = axis_size(axis)
    idx = jax.lax.axis_index(axis)
    return transpose_pack.pack_pieces(blk, split_axis, idx, p)


def _ring_transpose(blk: jax.Array, axis: AxisName, split_axis: int,
                    concat_axis: int, round_cb=None) -> jax.Array:
    """P-1-round ring transpose: pack -> send -> unpack, no serial chain.

    The rounds are structurally independent (each ppermute consumes its
    own packed piece and feeds only the final concatenate), so XLA's
    async scheduler — and the staged chunk pipeline of
    :func:`run_stage` — can run round s's send while other rounds pack
    or other chunks run their local FFTs: the explicit form of the
    paper's dedicated communication thread, and the pack->send->unpack
    pipeline of Verma et al.'s multi-node GPU FFT.  Received pieces are
    reassembled with one fused rotation instead of the P-1 full-size
    ``dynamic_update_slice`` writes the pairwise emulation pays.
    """
    from repro.kernels import transpose_pack
    p = axis_size(axis)
    idx = jax.lax.axis_index(axis)
    pieces = _pack_pieces(blk, axis, split_axis)
    recv = [pieces[0]]                      # round 0: my own block, no comm
    for s in range(1, p):
        perm = [(i, (i + s) % p) for i in range(p)]
        piece = jax.lax.ppermute(pieces[s], axis, perm)
        if round_cb is not None:
            # round-indexed observability hook (repro.obs): must return the
            # piece (possibly wrapped); the default None emits identical HLO
            piece = round_cb(s, piece)
        recv.append(piece)
    # concat order [round 0, round P-1, ..., round 1] puts the piece from
    # src (idx + m) % P at block m; rotating by -idx restores src order.
    ordered = [recv[0]] + recv[:0:-1]
    return transpose_pack.unpack_pieces(ordered, concat_axis, -idx)


def _pairwise_transpose(blk: jax.Array, axis: AxisName, split_axis: int,
                        concat_axis: int) -> jax.Array:
    """FFTW3-style emulation: P-1 *blocking* sendrecv rounds — round
    s+1's exchange is ordered after round s's completes (an
    ``optimization_barrier``, the data-flow form of MPI_Sendrecv's
    blocking semantics), and each received piece lands through a serial
    ``dynamic_update_slice`` chain.  Numerically identical to the other
    impls; this is the baseline whose serialized rounds the ring
    pipeline exists to avoid (figs 12-15).  The send side shares the
    fused rotated pack."""
    p = axis_size(axis)
    idx = jax.lax.axis_index(axis)
    n_cat = blk.shape[concat_axis]
    pieces = _pack_pieces(blk, axis, split_axis)
    out_shape = list(blk.shape)
    out_shape[split_axis] = pieces[0].shape[split_axis]
    out_shape[concat_axis] = n_cat * p
    out = jnp.zeros(out_shape, blk.dtype)
    out = jax.lax.dynamic_update_slice_in_dim(out, pieces[0], idx * n_cat,
                                              concat_axis)
    for s in range(1, p):
        perm = [(i, (i + s) % p) for i in range(p)]
        recv = jax.lax.ppermute(pieces[s], axis, perm)
        if s + 1 < p:
            # blocking round: the next send may not start until this
            # round's receive has completed
            pieces[s + 1], _ = jax.lax.optimization_barrier(
                (pieces[s + 1], recv))
        src = (idx - s) % p
        out = jax.lax.dynamic_update_slice_in_dim(out, recv, src * n_cat,
                                                  concat_axis)
    return out


def _all_to_all(blk: jax.Array, axis: AxisName, split_axis: int,
                concat_axis: int, impl: str = "alltoall",
                ring_round_cb=None) -> jax.Array:
    """Global transpose along one communicator.

    ``impl="alltoall"``  one fused collective (CROFT's MPI_Alltoall).
    ``impl="ring"``      P-1 independent ppermute rounds with fused
                         Pallas pack/unpack — the explicit overlap
                         pipeline (see :func:`_ring_transpose`).
    ``impl="pairwise"``  P-1 ppermute exchanges through a serial update
                         chain (FFTW3's MPI_Sendrecv pattern) —
                         numerically identical, many more collective
                         ops; used for the figs 12-15 benchmark.
    """
    if impl == "alltoall":
        return jax.lax.all_to_all(blk, axis, split_axis=split_axis,
                                  concat_axis=concat_axis, tiled=True)
    if impl not in ("ring", "pairwise"):
        raise ValueError(f"unknown transpose impl {impl!r}")
    if isinstance(axis, tuple):
        raise ValueError(f"{impl} transpose supports single mesh axes only")
    if impl == "ring":
        return _ring_transpose(blk, axis, split_axis, concat_axis,
                               round_cb=ring_round_cb)
    return _pairwise_transpose(blk, axis, split_axis, concat_axis)


def stage_pre(blk: jax.Array, st: Stage, sign: int, opts, off: int = 0,
              ctx=None) -> jax.Array:
    """The compute leg of one stage: prologue ops -> local FFT ->
    epilogue ops, on one (chunk of a) local block.  Module-level so the
    tracer's per-stage attribution (``repro.obs.instrument``) can build
    a compute-only executable from the exact emission ``run_stage``
    uses."""
    ctx = ctx or {}
    for op in st.prologue:
        blk = op.apply(blk, opts, ctx, off)
    if st.fft_axis is not None:
        blk = _fft_along(blk, st.fft_axis + off, sign, opts, st.impl_stage)
    for op in st.epilogue:
        blk = op.apply(blk, opts, ctx, off)
    return blk


def stage_comm(blk: jax.Array, st: Stage, opts, off: int = 0,
               ring_round_cb=None) -> jax.Array:
    """The collective leg of one stage (the global transpose); the
    counterpart of :func:`stage_pre`.  ``ring_round_cb(round, piece)``,
    when given and the stage resolves to the ring impl, is invoked on each
    of the P-1 received pieces so ``repro.obs`` can tag per-round spans."""
    return _all_to_all(blk, st.comm_axis, st.split_axis + off,
                       st.concat_axis + off, stage_transpose_impl(st, opts),
                       ring_round_cb=ring_round_cb)


def ring_round(blk: jax.Array, st: Stage, opts, rnd: int,
               off: int = 0) -> jax.Array:
    """One ring-transpose round of a comm stage, as a standalone jittable
    unit: the fused rotated pack plus round ``rnd``'s single ppermute
    (round 0 is the rank's own piece — no wire traffic).  Returns the
    received piece without placing it; production execution stays in
    :func:`stage_comm`.  Used by ``repro.obs.instrument`` to time ring
    stages round by round."""
    axis = st.comm_axis
    pieces = _pack_pieces(blk, axis, st.split_axis + off)
    if rnd == 0:
        return pieces[0]
    p = axis_size(axis)
    perm = [(i, (i + rnd) % p) for i in range(p)]
    return jax.lax.ppermute(pieces[rnd], axis, perm)


def stage_category(st: Stage) -> str:
    """The dominant tracer category of a stage (``repro.obs.CATEGORIES``)."""
    if st.fft_axis is not None:
        return "fft"
    if st.comm_axis is not None:
        return "collective"
    if st.prologue:
        return "pack"
    return "unpack" if st.epilogue else "epilogue"


def run_stage(blk: jax.Array, st: Stage, sign: int, opts, off: int = 0,
              ctx=None, ring_round_cb=None) -> jax.Array:
    """Execute one stage on a local block (axis indices offset by ``off``
    for leading batch dims).  Owns the K-chunked overlap and the silent
    fallback to one chunk when ``chunk_axis`` is not divisible by K.

    With K >= 2 chunks the stage runs as a depth-1 *software pipeline*
    (``opts.stage_overlap``: "pipelined", the default): chunk i+1's
    prologue/FFT is emitted *before* chunk i's collective, so the
    overlap is a structural property of the program order — chunk i's
    transpose has no consumer between it and chunk i+1's FFT — rather
    than a scheduling accident.  ``"unrolled"`` keeps the legacy
    chunk-after-chunk emission (chunk i's collective precedes chunk
    i+1's FFT only in the dependence graph, relying on XLA's async
    collective scheduler to interleave them).  Both modes run the same
    ops on the same chunks, so their outputs are bitwise identical.
    """
    ctx = ctx or {}

    def pre(c):
        return stage_pre(c, st, sign, opts, off, ctx)

    def comm(c):
        return stage_comm(c, st, opts, off, ring_round_cb=ring_round_cb)

    if st.comm_axis is None:
        return pre(blk)  # nothing to overlap with: never chunked
    k = stage_overlap_k(st, opts)
    if k <= 1 or blk.shape[st.chunk_axis + off] % k:
        return comm(pre(blk))
    ax = st.chunk_axis + off
    chunks = jnp.split(blk, k, axis=ax)
    if opts.stage_overlap(st.impl_stage) == "unrolled":
        return jnp.concatenate([comm(pre(c)) for c in chunks], axis=ax)
    # pipelined: double-buffered staged unroll — while chunk i is on the
    # wire, chunk i+1 is in the FFT (the paper's second OpenMP thread)
    outs = []
    inflight = pre(chunks[0])
    for i in range(k):
        nxt = pre(chunks[i + 1]) if i + 1 < k else None
        outs.append(comm(inflight))
        inflight = nxt
    return jnp.concatenate(outs, axis=ax)


def run_schedule(blk: jax.Array, sched: Schedule, opts,
                 operands=None, ring_round_cb=None) -> jax.Array:
    """Execute a schedule on a local (shard_map) block.

    Leading batch axes are carried along unsharded: every axis index in
    the schedule is offset by ``blk.ndim - 3``.  ``operands`` supplies
    named blocks to ops that need them (e.g. the fused k-space filter).
    ``ring_round_cb(round, piece)`` is the observability hook threaded to
    every ring-impl transpose (see :func:`stage_comm`).
    """
    off = blk.ndim - 3
    ctx = dict(operands or {})
    for st in sched.stages:
        blk = run_stage(blk, st, sched.sign, opts, off, ctx,
                        ring_round_cb=ring_round_cb)
    for op in sched.epilogue:
        blk = op.apply(blk, opts, ctx, off)
    # Fault plane: trace-time output poisoning.  ``corrupt`` is decided
    # while tracing, so an unarmed (or unmatched) injector contributes
    # zero ops — the compiled HLO is byte-identical to a build with no
    # injector installed (pinned in tests/test_resil.py).
    from repro.resil import inject
    if inject.corrupt("exec.output", sched.name):
        blk = blk * jnp.asarray(jnp.nan, dtype=blk.dtype)
    return blk


# ---------------------------------------------------------------------------
# complex-transform builders (pencil / slab / cell)
# ---------------------------------------------------------------------------

def _pencil_stages(ax_y: AxisName, ax_z: AxisName,
                   output_layout: str) -> list:
    """Forward pencil pipeline, paper §4.1 steps 1-9 (+ optional restore)."""
    stages = [
        # steps 1-4: FFT along x, transpose x<->y in the column communicator
        Stage("x-fft+xy", fft_axis=0, impl_stage=0, comm_axis=ax_y,
              split_axis=0, concat_axis=1, chunk_axis=2),
        # steps 5-8: FFT along y, transpose y<->z in the row communicator
        Stage("y-fft+yz", fft_axis=1, impl_stage=1, comm_axis=ax_z,
              split_axis=1, concat_axis=2, chunk_axis=0),
        # step 9: FFT along z
        Stage("z-fft", fft_axis=2, impl_stage=2),
    ]
    if output_layout == "natural":
        # restore: reverse YZ then XY transposes (paper §5.2, overlapped)
        stages += [
            Stage("restore-yz", comm_axis=ax_z, split_axis=2, concat_axis=1,
                  chunk_axis=0),
            Stage("restore-xy", comm_axis=ax_y, split_axis=1, concat_axis=0,
                  chunk_axis=2),
        ]
    return stages


def build_c2c(decomp, *, sign: int = -1, output_layout: str = "natural",
              from_spectral: bool = False) -> Schedule:
    """Schedule for the complex 3-D transform of one decomposition.

    ``from_spectral`` builds the reversed pipeline consuming the spectral
    (z-local) layout and emitting the natural one — used by the inverse
    when the forward ran with ``output_layout="spectral"`` (the forward's
    restoring transposes and the inverse's leading transposes cancel).
    """
    kind = decomp.kind
    if from_spectral:
        if kind == "pencil":
            ax_y, ax_z = decomp.axes
            stages = [
                Stage("z-fft+zy", fft_axis=2, impl_stage=0, comm_axis=ax_z,
                      split_axis=2, concat_axis=1, chunk_axis=0),
                Stage("y-fft+yx", fft_axis=1, impl_stage=1, comm_axis=ax_y,
                      split_axis=1, concat_axis=0, chunk_axis=2),
                Stage("x-fft", fft_axis=0, impl_stage=2),
            ]
        elif kind == "slab":
            (ax_z,) = decomp.axes
            stages = [
                Stage("y-fft", fft_axis=1, impl_stage=0),
                Stage("z-fft+zx", fft_axis=2, impl_stage=1, comm_axis=ax_z,
                      split_axis=2, concat_axis=0, chunk_axis=1),
                Stage("x-fft", fft_axis=0, impl_stage=2),
            ]
        else:
            raise ScheduleError("cell has no spectral layout to start from")
        return Schedule(f"{kind}/c2c/from-spectral", sign,
                        layout_for(decomp, "spectral"), tuple(stages))

    if kind == "pencil":
        ax_y, ax_z = decomp.axes
        stages = _pencil_stages(ax_y, ax_z, output_layout)
    elif kind == "slab":
        (ax_z,) = decomp.axes
        stages = [
            Stage("y-fft", fft_axis=1, impl_stage=0),  # y free on both layouts
            Stage("x-fft+xz", fft_axis=0, impl_stage=1, comm_axis=ax_z,
                  split_axis=0, concat_axis=2, chunk_axis=1),
            Stage("z-fft", fft_axis=2, impl_stage=2),
        ]
        if output_layout == "natural":
            stages.append(Stage("restore-zx", comm_axis=ax_z, split_axis=2,
                                concat_axis=0, chunk_axis=1))
    else:  # cell: regroup to x-pencils over the folded (y, x) communicator
        if output_layout == "spectral":
            raise ScheduleError("cell decomposition returns natural layout "
                                "only")
        ax_x, ax_y, ax_z = decomp.axes
        fold_y = (tuple(ax_y) + _flat(ax_x) if isinstance(ax_y, tuple)
                  else (ax_y,) + _flat(ax_x))
        if len(fold_y) == 1:
            fold_y = fold_y[0]
        stages = [Stage("regroup-x", comm_axis=ax_x, split_axis=1,
                        concat_axis=0, chunk_axis=2)]
        stages += _pencil_stages(fold_y, ax_z, "natural")
        stages += [Stage("scatter-x", comm_axis=ax_x, split_axis=0,
                         concat_axis=1, chunk_axis=2)]
    return Schedule(f"{kind}/c2c/{output_layout}", sign,
                    layout_for(decomp, "natural"), tuple(stages))
