"""deepseek-v2-236b [moe] — 60L d_model=5120 128H (MLA kv_lora=512)
d_ff(expert)=1536 vocab=102400, MoE 2 shared + 160 routed top-6; first
layer dense.  [arXiv:2405.04434; hf]
"""

from repro.models.config import (AttentionSpec, LayerSpec, ModelConfig,
                                 MoESpec, Stage)

MLA = dict(kind="mla", n_heads=128, n_kv_heads=128, head_dim=192,
           q_lora_rank=1536, kv_lora_rank=512, qk_nope_dim=128,
           qk_rope_dim=64, v_head_dim=128)


def full() -> ModelConfig:
    attn = AttentionSpec(**MLA)
    dense = LayerSpec(mixer="attn", attn=attn, ffn="swiglu")
    moe = LayerSpec(
        mixer="attn", attn=attn, ffn="moe",
        moe=MoESpec(n_experts=160, top_k=6, n_shared=2, d_ff_expert=1536),
    )
    return ModelConfig(
        name="deepseek-v2-236b", family="moe",
        d_model=5120, d_ff=12288, vocab=102400,  # d_ff: dense layer 0
        stages=(Stage((dense,), 1), Stage((moe,), 59)),
        supports_long=False,  # full attention (MLA): skip long_500k
    )


def smoke() -> ModelConfig:
    attn = AttentionSpec(kind="mla", n_heads=4, n_kv_heads=4, head_dim=24,
                         q_lora_rank=32, kv_lora_rank=16, qk_nope_dim=16,
                         qk_rope_dim=8, v_head_dim=16)
    dense = LayerSpec(mixer="attn", attn=attn, ffn="swiglu")
    moe = LayerSpec(mixer="attn", attn=attn, ffn="moe",
                    moe=MoESpec(n_experts=8, top_k=2, n_shared=1,
                                d_ff_expert=32, capacity_factor=2.0))
    return ModelConfig(
        name="deepseek-v2-236b-smoke", family="moe",
        d_model=64, d_ff=128, vocab=256,
        stages=(Stage((dense,), 1), Stage((moe,), 2)),
        supports_long=False,
    )
