"""h2o-danube-3-4b [dense] — 24L d_model=3840 32H (GQA kv=8) d_ff=10240
vocab=32000 — llama+mistral mix with sliding-window attention.
[arXiv:2401.16818; unverified]
"""

from repro.models.config import (AttentionSpec, LayerSpec, ModelConfig,
                                 simple_stack)


def full() -> ModelConfig:
    spec = LayerSpec(
        mixer="attn",
        attn=AttentionSpec(kind="gqa", n_heads=32, n_kv_heads=8,
                           head_dim=120, window=4096),
        ffn="swiglu",
    )
    return ModelConfig(
        name="h2o-danube-3-4b", family="dense",
        d_model=3840, d_ff=10240, vocab=32000,
        stages=simple_stack(24, spec),
        supports_long=True,  # SWA
    )


def smoke() -> ModelConfig:
    spec = LayerSpec(
        mixer="attn",
        attn=AttentionSpec(kind="gqa", n_heads=4, n_kv_heads=2, head_dim=16,
                           window=32),
        ffn="swiglu",
    )
    return ModelConfig(
        name="h2o-danube-3-4b-smoke", family="dense",
        d_model=64, d_ff=128, vocab=256,
        stages=simple_stack(2, spec),
        supports_long=True,
    )
