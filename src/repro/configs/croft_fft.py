"""The paper's own workload configs: 3-D FFT grids and option matrix.

``croft-<N>`` names select a grid; options mirror §5.1 of the paper.
"""

from __future__ import annotations

import dataclasses

from repro.core.distributed import FFTOptions


@dataclasses.dataclass(frozen=True)
class CroftConfig:
    name: str
    grid: tuple[int, int, int]
    decomposition: str = "pencil"       # "pencil" | "slab" | "cell"
    opts: FFTOptions = dataclasses.field(default_factory=FFTOptions)
    dtype: str = "complex64"            # paper uses c128; c64 is the bf16-era
                                        # default, c128 selectable


def croft_128(**kw) -> CroftConfig:
    return CroftConfig("croft-128", (128, 128, 128), **kw)


def croft_1024(**kw) -> CroftConfig:
    return CroftConfig("croft-1024", (1024, 1024, 1024), **kw)


def croft_4096(**kw) -> CroftConfig:
    return CroftConfig("croft-4096", (4096, 4096, 4096), **kw)


def paper_option(cfg: CroftConfig, opt: int) -> CroftConfig:
    return dataclasses.replace(cfg, opts=FFTOptions.paper_option(opt))
