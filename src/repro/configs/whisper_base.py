"""whisper-base [audio] — 6L enc + 6L dec, d_model=512 8H d_ff=2048
vocab=51865, encoder-decoder with conv frontend STUB (``input_specs``
provides precomputed frame embeddings).  [arXiv:2212.04356; unverified]

Backbone-only per the assignment: the mel-spectrogram conv stem is stubbed;
decoder self-attention uses RoPE in place of Whisper's learned positions
(documented hardware-era substitution — the assignment pins the transformer
backbone dims, not the positional scheme).
"""

from repro.models.config import (AttentionSpec, EncoderConfig, LayerSpec,
                                 ModelConfig, simple_stack)

N_FRAMES = 1500  # whisper 30 s window after 2x conv downsampling


def full() -> ModelConfig:
    dec = LayerSpec(
        mixer="attn",
        attn=AttentionSpec(kind="gqa", n_heads=8, n_kv_heads=8, head_dim=64),
        ffn="gelu",
        cross_attn=True,
    )
    enc = LayerSpec(
        mixer="attn",
        attn=AttentionSpec(kind="gqa", n_heads=8, n_kv_heads=8, head_dim=64,
                           causal=False, use_rope=False),
        ffn="gelu",
    )
    return ModelConfig(
        name="whisper-base", family="audio",
        d_model=512, d_ff=2048, vocab=51865,
        stages=simple_stack(6, dec),
        norm="layernorm",
        encoder=EncoderConfig(n_layers=6, layer=enc, max_positions=N_FRAMES),
        frontend="audio", n_frontend_tokens=N_FRAMES,
        supports_long=False,
    )


def smoke() -> ModelConfig:
    dec = LayerSpec(
        mixer="attn",
        attn=AttentionSpec(kind="gqa", n_heads=4, n_kv_heads=4, head_dim=16),
        ffn="gelu",
        cross_attn=True,
    )
    enc = LayerSpec(
        mixer="attn",
        attn=AttentionSpec(kind="gqa", n_heads=4, n_kv_heads=4, head_dim=16,
                           causal=False, use_rope=False),
        ffn="gelu",
    )
    return ModelConfig(
        name="whisper-base-smoke", family="audio",
        d_model=64, d_ff=128, vocab=256,
        stages=simple_stack(2, dec),
        norm="layernorm",
        encoder=EncoderConfig(n_layers=2, layer=enc, max_positions=32),
        frontend="audio", n_frontend_tokens=32,
        supports_long=False,
    )
