"""fnet-350m [bonus, spectral] — 24L d_model=1024 d_ff=4096 vocab=32768.

Not part of the assigned pool: this is the LM-side consumer of the paper's
technique (DESIGN.md §5) — token mixing by Fourier transform (FNet,
arXiv:2105.03824), with the sequence-axis FFT running CROFT's distributed
transpose machinery when the sequence is sharded.
"""

from repro.models.config import LayerSpec, ModelConfig, RecurrentSpec, simple_stack


def full() -> ModelConfig:
    spec = LayerSpec(mixer="spectral", ffn="gelu")
    return ModelConfig(
        name="fnet-350m", family="spectral",
        d_model=1024, d_ff=4096, vocab=32768,
        stages=simple_stack(24, spec),
        norm="layernorm",
        supports_decode=False,  # FNet mixing is not causal: encoder-only
        supports_long=False,
    )


def smoke() -> ModelConfig:
    spec = LayerSpec(mixer="spectral", ffn="gelu")
    return ModelConfig(
        name="fnet-350m-smoke", family="spectral",
        d_model=64, d_ff=128, vocab=256,
        stages=simple_stack(2, spec),
        norm="layernorm",
        supports_decode=False,
        supports_long=False,
    )
