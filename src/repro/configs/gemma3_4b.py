"""gemma3-4b [dense] — 34L d_model=2560 8H (GQA kv=4) d_ff=10240
vocab=262144, 5 local (window 1024) : 1 global pattern, 128k context.
[hf:google/gemma-3-1b-pt; unverified]
"""

from repro.models.config import (AttentionSpec, LayerSpec, ModelConfig,
                                 pattern_stack)

LOCAL_WINDOW = 1024


def full() -> ModelConfig:
    local = LayerSpec(
        mixer="attn",
        attn=AttentionSpec(kind="gqa", n_heads=8, n_kv_heads=4, head_dim=256,
                           window=LOCAL_WINDOW, rope_theta=10_000.0),
        ffn="geglu",
    )
    glob = LayerSpec(
        mixer="attn",
        attn=AttentionSpec(kind="gqa", n_heads=8, n_kv_heads=4, head_dim=256,
                           window=None, rope_theta=1_000_000.0),
        ffn="geglu",
    )
    return ModelConfig(
        name="gemma3-4b", family="dense",
        d_model=2560, d_ff=10240, vocab=262144,
        stages=pattern_stack(34, [local] * 5 + [glob]),
        tie_embeddings=True, emb_scale_by_dim=True,
        supports_long=True,  # dominated by local layers; global layers are
                             # O(S) per decoded token with a seq-sharded cache
    )


def smoke() -> ModelConfig:
    local = LayerSpec(
        mixer="attn",
        attn=AttentionSpec(kind="gqa", n_heads=4, n_kv_heads=2, head_dim=16,
                           window=16),
        ffn="geglu",
    )
    glob = LayerSpec(
        mixer="attn",
        attn=AttentionSpec(kind="gqa", n_heads=4, n_kv_heads=2, head_dim=16),
        ffn="geglu",
    )
    return ModelConfig(
        name="gemma3-4b-smoke", family="dense",
        d_model=64, d_ff=128, vocab=256,
        stages=pattern_stack(4, [local, local, glob]),
        tie_embeddings=True, emb_scale_by_dim=True,
        supports_long=True,
    )
