"""Config registry: ``get_config("<arch>")`` / ``--arch`` lookup.

Ten assigned architectures + the paper's own FFT workloads + one bonus
spectral LM.  Each module exposes ``full()`` and ``smoke()``.
"""

from __future__ import annotations

import importlib

from repro.configs.shapes import (FFT_SHAPES, SHAPES, FFTShape, ShapeSpec,
                                  shape_supported)

ARCHS = {
    "mixtral-8x22b": "repro.configs.mixtral_8x22b",
    "deepseek-v2-236b": "repro.configs.deepseek_v2_236b",
    "h2o-danube-3-4b": "repro.configs.h2o_danube3_4b",
    "gemma3-4b": "repro.configs.gemma3_4b",
    "yi-34b": "repro.configs.yi_34b",
    "yi-9b": "repro.configs.yi_9b",
    "whisper-base": "repro.configs.whisper_base",
    "recurrentgemma-9b": "repro.configs.recurrentgemma_9b",
    "rwkv6-3b": "repro.configs.rwkv6_3b",
    "paligemma-3b": "repro.configs.paligemma_3b",
    # bonus (beyond the assigned pool)
    "fnet-350m": "repro.configs.fnet_350m",
}

ASSIGNED = [a for a in ARCHS if a != "fnet-350m"]


def get_config(arch: str, smoke: bool = False):
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(ARCHS)}")
    mod = importlib.import_module(ARCHS[arch])
    return mod.smoke() if smoke else mod.full()


__all__ = ["ARCHS", "ASSIGNED", "FFT_SHAPES", "SHAPES", "FFTShape",
           "ShapeSpec", "get_config", "shape_supported"]
