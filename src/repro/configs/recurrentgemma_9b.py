"""recurrentgemma-9b [hybrid] — 38L d_model=4096 16H (GQA kv=1) d_ff=12288
vocab=256000 — Griffin: RG-LRU + local attention, 2 recurrent : 1 attention.
[arXiv:2402.19427; unverified]
"""

from repro.models.config import (AttentionSpec, LayerSpec, ModelConfig,
                                 RecurrentSpec, pattern_stack)

LOCAL_WINDOW = 2048


def full() -> ModelConfig:
    rec = LayerSpec(
        mixer="rglru",
        recurrent=RecurrentSpec(kind="rglru", d_state=4096, conv_width=4),
        ffn="geglu",
    )
    att = LayerSpec(
        mixer="attn",
        attn=AttentionSpec(kind="gqa", n_heads=16, n_kv_heads=1,
                           head_dim=256, window=LOCAL_WINDOW),
        ffn="geglu",
    )
    return ModelConfig(
        name="recurrentgemma-9b", family="hybrid",
        d_model=4096, d_ff=12288, vocab=256000,
        stages=pattern_stack(38, [rec, rec, att]),
        tie_embeddings=True, emb_scale_by_dim=True,
        supports_long=True,
    )


def smoke() -> ModelConfig:
    rec = LayerSpec(
        mixer="rglru",
        recurrent=RecurrentSpec(kind="rglru", d_state=64, conv_width=4,
                                chunk=16),
        ffn="geglu",
    )
    att = LayerSpec(
        mixer="attn",
        attn=AttentionSpec(kind="gqa", n_heads=4, n_kv_heads=1, head_dim=16,
                           window=16),
        ffn="geglu",
    )
    return ModelConfig(
        name="recurrentgemma-9b-smoke", family="hybrid",
        d_model=64, d_ff=128, vocab=256,
        stages=pattern_stack(4, [rec, rec, att]),
        tie_embeddings=True, emb_scale_by_dim=True,
        supports_long=True,
    )
