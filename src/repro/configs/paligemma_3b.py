"""paligemma-3b [vlm] — 18L d_model=2048 8H (GQA kv=1) d_ff=16384
vocab=257216 — SigLIP patch embeddings (STUB) + gemma decoder, prefix-LM
attention over the image tokens.  [arXiv:2407.07726; hf]
"""

from repro.models.config import (AttentionSpec, LayerSpec, ModelConfig,
                                 simple_stack)

N_PATCHES = 256  # 224px / 14 squared — SigLIP-So400m stub token count


def full() -> ModelConfig:
    spec = LayerSpec(
        mixer="attn",
        attn=AttentionSpec(kind="gqa", n_heads=8, n_kv_heads=1,
                           head_dim=256),
        ffn="geglu",
    )
    return ModelConfig(
        name="paligemma-3b", family="vlm",
        d_model=2048, d_ff=16384, vocab=257216,
        stages=simple_stack(18, spec),
        tie_embeddings=True, emb_scale_by_dim=True,
        frontend="vision", n_frontend_tokens=N_PATCHES, prefix_lm=True,
        supports_long=False,
    )


def smoke() -> ModelConfig:
    spec = LayerSpec(
        mixer="attn",
        attn=AttentionSpec(kind="gqa", n_heads=4, n_kv_heads=1, head_dim=16),
        ffn="geglu",
    )
    return ModelConfig(
        name="paligemma-3b-smoke", family="vlm",
        d_model=64, d_ff=128, vocab=256,
        stages=simple_stack(2, spec),
        tie_embeddings=True, emb_scale_by_dim=True,
        frontend="vision", n_frontend_tokens=8, prefix_lm=True,
        supports_long=False,
    )
