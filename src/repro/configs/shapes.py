"""Assigned input-shape set (same four cells for every LM arch) plus the
paper's own FFT grid shapes."""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str            # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int

    @property
    def lowers_serve_step(self) -> bool:
        return self.kind in ("prefill", "decode")


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524_288, 1),
}


@dataclasses.dataclass(frozen=True)
class FFTShape:
    name: str
    grid: tuple[int, int, int]
    dtype: str = "complex64"


FFT_SHAPES = {
    # the paper's two benchmark grids (tables 1-3) + a scale-up cell
    "fft_128": FFTShape("fft_128", (128, 128, 128)),
    "fft_1024": FFTShape("fft_1024", (1024, 1024, 1024)),
    "fft_4096": FFTShape("fft_4096", (4096, 4096, 4096)),
}


def shape_supported(cfg, shape: ShapeSpec) -> tuple[bool, str]:
    """Skip rules from DESIGN.md §5 (long_500k needs sub-quadratic attn;
    decode needs a decoder)."""
    if shape.kind == "decode" and not cfg.supports_decode:
        return False, "encoder-only arch has no decode step"
    if shape.name == "long_500k" and not cfg.supports_long:
        return False, ("pure full-attention arch: 500k dense decode "
                       "out of scope (DESIGN.md §5 skip list)")
    return True, ""
