"""mixtral-8x22b [moe] — 56L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=32768, MoE 8 experts top-2, sliding-window attention.
[arXiv:2401.04088; hf]
"""

from repro.models.config import (AttentionSpec, LayerSpec, ModelConfig,
                                 MoESpec, simple_stack)

SWA_WINDOW = 4096  # Mixtral-family sliding window


def full() -> ModelConfig:
    spec = LayerSpec(
        mixer="attn",
        attn=AttentionSpec(kind="gqa", n_heads=48, n_kv_heads=8,
                           head_dim=128, window=SWA_WINDOW,
                           rope_theta=1_000_000.0),
        ffn="moe",
        moe=MoESpec(n_experts=8, top_k=2, d_ff_expert=16384),
    )
    return ModelConfig(
        name="mixtral-8x22b", family="moe",
        d_model=6144, d_ff=16384, vocab=32768,
        stages=simple_stack(56, spec),
        supports_long=True,   # SWA => sub-quadratic long decode
    )


def smoke() -> ModelConfig:
    spec = LayerSpec(
        mixer="attn",
        attn=AttentionSpec(kind="gqa", n_heads=4, n_kv_heads=2, head_dim=16,
                           window=32),
        ffn="moe",
        moe=MoESpec(n_experts=4, top_k=2, d_ff_expert=64,
                    capacity_factor=2.0),
    )
    return ModelConfig(
        name="mixtral-8x22b-smoke", family="moe",
        d_model=64, d_ff=64, vocab=256,
        stages=simple_stack(2, spec),
        supports_long=True,
    )
