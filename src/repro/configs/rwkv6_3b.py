"""rwkv6-3b [ssm] — 32L d_model=2560 (attention-free) d_ff=8960 vocab=65536
— Finch: data-dependent decay, token-shift LoRAs, matrix-valued state.
[arXiv:2404.05892; hf]
"""

from repro.models.config import (LayerSpec, ModelConfig, RecurrentSpec,
                                 simple_stack)


def full() -> ModelConfig:
    spec = LayerSpec(
        mixer="rwkv6",
        recurrent=RecurrentSpec(kind="rwkv6", n_heads=40, chunk=64),
        ffn="rwkv_cm",
    )
    return ModelConfig(
        name="rwkv6-3b", family="ssm",
        d_model=2560, d_ff=8960, vocab=65536,
        stages=simple_stack(32, spec),
        norm="layernorm",
        supports_long=True,  # O(1) state decode
    )


def smoke() -> ModelConfig:
    spec = LayerSpec(
        mixer="rwkv6",
        recurrent=RecurrentSpec(kind="rwkv6", n_heads=4, chunk=8),
        ffn="rwkv_cm",
    )
    return ModelConfig(
        name="rwkv6-3b-smoke", family="ssm",
        d_model=64, d_ff=128, vocab=256,
        stages=simple_stack(2, spec),
        norm="layernorm",
        supports_long=True,
    )
