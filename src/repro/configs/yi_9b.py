"""yi-9b [dense] — 48L d_model=4096 32H (GQA kv=4) d_ff=11008 vocab=64000,
llama-architecture GQA.  [arXiv:2403.04652; hf]
"""

from repro.models.config import (AttentionSpec, LayerSpec, ModelConfig,
                                 simple_stack)


def full() -> ModelConfig:
    spec = LayerSpec(
        mixer="attn",
        attn=AttentionSpec(kind="gqa", n_heads=32, n_kv_heads=4,
                           head_dim=128, rope_theta=10_000.0),
        ffn="swiglu",
    )
    return ModelConfig(
        name="yi-9b", family="dense",
        d_model=4096, d_ff=11008, vocab=64000,
        stages=simple_stack(48, spec),
        supports_long=False,
    )


def smoke() -> ModelConfig:
    spec = LayerSpec(
        mixer="attn",
        attn=AttentionSpec(kind="gqa", n_heads=4, n_kv_heads=1, head_dim=16),
        ffn="swiglu",
    )
    return ModelConfig(
        name="yi-9b-smoke", family="dense",
        d_model=64, d_ff=128, vocab=256,
        stages=simple_stack(2, spec),
        supports_long=False,
    )
