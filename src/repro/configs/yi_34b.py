"""yi-34b [dense] — 60L d_model=7168 56H (GQA kv=8) d_ff=20480 vocab=64000,
llama-architecture GQA.  [arXiv:2403.04652; hf]
"""

from repro.models.config import (AttentionSpec, LayerSpec, ModelConfig,
                                 simple_stack)


def full() -> ModelConfig:
    spec = LayerSpec(
        mixer="attn",
        attn=AttentionSpec(kind="gqa", n_heads=56, n_kv_heads=8,
                           head_dim=128, rope_theta=5_000_000.0),
        ffn="swiglu",
    )
    return ModelConfig(
        name="yi-34b", family="dense",
        d_model=7168, d_ff=20480, vocab=64000,
        stages=simple_stack(60, spec),
        supports_long=False,  # pure full attention: long_500k skipped
    )


def smoke() -> ModelConfig:
    spec = LayerSpec(
        mixer="attn",
        attn=AttentionSpec(kind="gqa", n_heads=4, n_kv_heads=2, head_dim=16),
        ffn="swiglu",
    )
    return ModelConfig(
        name="yi-34b-smoke", family="dense",
        d_model=64, d_ff=128, vocab=256,
        stages=simple_stack(2, spec),
        supports_long=False,
    )
