"""GPipe-style pipeline parallelism over the ``pod`` axis.

The production meshes run the pod axis as pure data parallelism (DESIGN.md
§4); at >2 pods or when per-pod memory is the binding constraint, pipeline
staging is the alternative.  This module provides the schedule as a
self-contained, tested substrate component:

  * stage p holds layers [p·L/P, (p+1)·L/P) — params sharded over ``pod``
    on the stacked layer axis;
  * microbatches flow through a ``shard_map`` ppermute ring with the GPipe
    schedule: step t processes microbatch (t - stage) at each stage, so a
    P-stage pipeline with M microbatches takes M + P - 1 steps
    (bubble fraction (P-1)/(M+P-1));
  * autodiff flows through ``ppermute`` natively, so ``jax.grad`` of the
    pipelined forward is the pipelined backward.

``pipeline_apply`` is deliberately model-agnostic: it pipelines any
``layer_fn(params_slice, x) -> x`` whose stacked params divide across
stages.  Equivalence to sequential execution is asserted in
``tests/test_pipeline.py``.
"""

from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from repro.compat import pcast, shard_map
from jax.sharding import Mesh, PartitionSpec as P


def bubble_fraction(n_stages: int, n_micro: int) -> float:
    return (n_stages - 1) / (n_micro + n_stages - 1)


def pipeline_apply(layer_fn: Callable, stacked_params, x, *, mesh: Mesh,
                   stage_axis: str, n_micro: int):
    """Run ``x`` through all stacked layers, pipelined over ``stage_axis``.

    layer_fn(params_t, h) -> h applies ONE layer.
    stacked_params: pytree with leading layer axis L (L % n_stages == 0),
    sharded (or shardable) over ``stage_axis``.
    x: (B, ...) global batch; B % n_micro == 0.
    """
    n_stages = mesh.shape[stage_axis]
    lead = jax.tree.leaves(stacked_params)[0].shape[0]
    assert lead % n_stages == 0, (lead, n_stages)
    b = x.shape[0]
    assert b % n_micro == 0, (b, n_micro)
    mb = b // n_micro

    p_spec = jax.tree.map(lambda _: P(stage_axis), stacked_params)
    x_spec = P(*([None] * x.ndim))

    def body(params_loc, x_all):
        # params_loc: (L/P, ...) this stage's layers; x_all replicated
        stage = jax.lax.axis_index(stage_axis)
        x_all = pcast(x_all, (stage_axis,), to="varying")
        micro = x_all.reshape((n_micro, mb) + x_all.shape[1:])

        def run_stage(h):
            def one(carry, p_t):
                return layer_fn(p_t, carry), None
            h, _ = jax.lax.scan(one, h, params_loc)
            return h

        n_steps = n_micro + n_stages - 1
        outputs = jnp.zeros_like(micro)
        buf = pcast(
            jnp.zeros((mb,) + x_all.shape[1:], x_all.dtype),
            (stage_axis,), to="varying")
        fwd_perm = [(i, i + 1) for i in range(n_stages - 1)]

        def step(t, carry):
            buf, outputs = carry
            # stage 0 injects microbatch t; others take the ppermuted input
            inject = jax.lax.dynamic_slice_in_dim(
                micro, jnp.clip(t, 0, n_micro - 1), 1, 0)[0]
            h_in = jnp.where(stage == 0, inject, buf)
            h_out = run_stage(h_in)
            # last stage commits microbatch (t - (P-1)) when valid
            out_idx = t - (n_stages - 1)
            commit = (stage == n_stages - 1) & (out_idx >= 0)
            upd = jax.lax.dynamic_update_slice_in_dim(
                outputs, h_out[None], jnp.maximum(out_idx, 0), 0)
            outputs = jnp.where(commit, upd, outputs)
            buf = jax.lax.ppermute(h_out, stage_axis, fwd_perm)
            return buf, outputs

        buf, outputs = jax.lax.fori_loop(0, n_steps, step, (buf, outputs))
        # result lives on the last stage; broadcast it (psum of masked)
        outputs = jax.lax.psum(
            jnp.where(stage == n_stages - 1, outputs,
                      jnp.zeros_like(outputs)), stage_axis)
        return outputs.reshape(x_all.shape)

    fn = shard_map(body, mesh=mesh, in_specs=(p_spec, x_spec),
                   out_specs=x_spec)
    return fn(stacked_params, x)
