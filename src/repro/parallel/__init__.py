"""Distribution substrate: sharding rules, chunked loss, sequence-parallel
scans, cross-pod gradient compression."""

from repro.parallel.sharding import (MeshAxes, cache_specs, param_shardings,
                                     param_specs)
from repro.parallel.loss import chunked_cross_entropy

__all__ = ["MeshAxes", "cache_specs", "chunked_cross_entropy",
           "param_shardings", "param_specs"]
