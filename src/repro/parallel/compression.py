"""Gradient compression for the cross-pod (slow-link) reduction.

Within a pod the ICI fabric makes full-precision reduce-scatter cheap; the
pod-to-pod hop is the bandwidth cliff, so the ``pod`` axis reduction can be
run through int8 error-feedback compression: quantize (per-tensor scale),
psum the int8 payload (widened to int32 for the reduction), dequantize, and
carry the quantization residual into the next step's gradients (EF-SGD,
Karimireddy et al. 2019 — keeps convergence unbiased to first order).

8x less cross-pod traffic for the gradient all-reduce.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array):
    """Per-tensor symmetric int8. Returns (q, scale)."""
    amax = jnp.max(jnp.abs(x))
    scale = jnp.where(amax > 0, amax / 127.0, 1.0).astype(jnp.float32)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_residual(x: jax.Array, residual: Optional[jax.Array]):
    """Error-feedback step: add carried residual, quantize, compute new
    residual.  Returns (q, scale, new_residual)."""
    xf = x.astype(jnp.float32)
    if residual is not None:
        xf = xf + residual
    q, scale = quantize_int8(xf)
    new_residual = xf - dequantize_int8(q, scale)
    return q, scale, new_residual


def compressed_psum(tree, axis_name: str, residuals=None):
    """int8 error-feedback psum over ``axis_name`` (inside shard_map).

    Returns (reduced_tree, new_residuals).  Scales are reduced with pmax so
    dequantization is consistent across members; payload widened to int32
    for the reduction (wire format is int8 + one f32 per tensor).
    """
    if residuals is None:
        residuals = jax.tree.map(lambda _: None, tree,
                                 is_leaf=lambda x: x is None)

    def one(x, res):
        xf = x.astype(jnp.float32)
        if res is not None:
            xf = xf + res
        # consistent per-tensor scale across participants
        amax = jax.lax.pmax(jnp.max(jnp.abs(xf)), axis_name)
        scale = jnp.where(amax > 0, amax / 127.0, 1.0)
        q = jnp.clip(jnp.round(xf / scale), -127, 127)
        summed = jax.lax.psum(q.astype(jnp.int32), axis_name)
        out = summed.astype(jnp.float32) * scale
        new_res = xf - q * scale
        return out.astype(x.dtype), new_res

    outs = jax.tree.map(one, tree, residuals,
                        is_leaf=lambda x: x is None)
    reduced = jax.tree.map(lambda t: t[0], outs,
                           is_leaf=lambda x: isinstance(x, tuple))
    new_res = jax.tree.map(lambda t: t[1], outs,
                           is_leaf=lambda x: isinstance(x, tuple))
    return reduced, new_res


def topk_sparsify(x: jax.Array, frac: float = 0.01):
    """Top-k magnitude sparsification (alternative compressor): returns
    (values, flat_indices) of the largest-|x| fraction."""
    flat = x.reshape(-1)
    k = max(1, int(flat.size * frac))
    vals, idx = jax.lax.top_k(jnp.abs(flat), k)
    return flat[idx], idx


def topk_densify(values: jax.Array, idx: jax.Array, shape) -> jax.Array:
    out = jnp.zeros(int(jnp.prod(jnp.asarray(shape))), values.dtype)
    return out.at[idx].set(values).reshape(shape)
