"""LASP-style sequence-parallel linear recurrences.

When the sequence axis is sharded over the ``model`` mesh axis (context
parallelism), a linear recurrence needs its state threaded across shards.
Both RG-LRU (vector state) and RWKV-6 (matrix state) updates are affine
maps, so shard composition is associative and the cross-shard prefix is a
log-depth Hillis-Steele scan over ``ppermute`` steps (4 hops on a 16-way
axis) — the distributed analogue of the chunked scans in
``models/recurrent.py``, and the sequence-domain cousin of CROFT's
transpose pipeline (DESIGN.md §4).

Each wrapper: one local pass (state starting from zero), a log-depth
exclusive prefix of (total_decay, contribution) across shards, then a cheap
local correction term — no second full pass.
"""

from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from repro.compat import axis_size, pcast, shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.models import recurrent as rec


def _prefix_scan(pairs_combine: Callable, identity, local, axis_name: str):
    """Hillis-Steele inclusive scan over the mesh axis, then shift by one
    rank to make it exclusive (rank 0 receives ``identity``)."""
    n = axis_size(axis_name)
    idx = jax.lax.axis_index(axis_name)
    acc = local
    d = 1
    while d < n:
        perm = [(i, i + d) for i in range(n - d)]
        incoming = jax.tree.map(
            lambda x: jax.lax.ppermute(x, axis_name, perm), acc)
        combined = pairs_combine(incoming, acc)   # incoming applied first
        acc = jax.tree.map(
            lambda c, a: jnp.where(idx >= d, c, a), combined, acc)
        d *= 2
    # shift: rank i gets rank i-1's inclusive value
    perm1 = [(i, i + 1) for i in range(n - 1)]
    shifted = jax.tree.map(lambda x: jax.lax.ppermute(x, axis_name, perm1), acc)
    return jax.tree.map(
        lambda s, ident: jnp.where(idx == 0, ident, s), shifted, identity)


def cp_vector_recurrence(log_a, b, h0, *, mesh: Mesh, cp_axis: str,
                         batch_spec, chunk: int = 256):
    """Distributed ``rec.vector_recurrence``: (B, T, D) with T sharded over
    ``cp_axis``.  h0 (B, D) replicated along cp_axis."""

    spec_t = P(batch_spec, cp_axis, None)
    spec_b = P(batch_spec, None)

    def body(la_loc, b_loc, h0_loc):
        # replicated operands must be marked varying before mixing with
        # shard-local values inside scans (shard_map vma typing)
        h0_loc = pcast(h0_loc, (cp_axis,), to="varying")
        # local pass from zero state
        h_loc, h_last = rec.vector_recurrence(
            la_loc, b_loc, jnp.zeros_like(h0_loc), chunk)
        l_tot = jnp.sum(la_loc, axis=1)                     # (B, D)

        def combine(first, second):
            lf, cf = first
            ls, cs = second
            return lf + ls, jnp.exp(ls) * cf + cs

        ident = (jnp.zeros_like(l_tot), jnp.zeros_like(h_last))
        l_ex, c_ex = _prefix_scan(combine, ident, (l_tot, h_last), cp_axis)
        h_in = jnp.exp(l_ex) * h0_loc + c_ex                # state entering shard
        # correction: h_t += exp(cum log_a through t) * h_in
        a_cum = jnp.cumsum(la_loc, axis=1)
        h = h_loc + jnp.exp(a_cum) * h_in[:, None, :]
        # global final state lives on the last rank; broadcast via psum
        idx = jax.lax.axis_index(cp_axis)
        n = axis_size(cp_axis)
        h_out_last = jax.lax.psum(
            jnp.where(idx == n - 1, h[:, -1], jnp.zeros_like(h[:, -1])),
            cp_axis)
        return h, h_out_last

    fn = shard_map(body, mesh=mesh,
                   in_specs=(spec_t, spec_t, spec_b),
                   out_specs=(spec_t, spec_b))
    return fn(log_a, b, h0)


def cp_matrix_recurrence(log_w, k, v, r, u, s0, *, mesh: Mesh, cp_axis: str,
                         batch_spec, chunk: int = 64):
    """Distributed ``rec.matrix_recurrence``: (B, T, H, *) with T sharded
    over ``cp_axis``; s0 (B, H, K, V) replicated along it."""

    spec_t = P(batch_spec, cp_axis, None, None)
    spec_s = P(batch_spec, None, None, None)
    spec_u = P(None, None)

    def body(lw_loc, k_loc, v_loc, r_loc, u_loc, s0_loc):
        s0_loc = pcast(s0_loc, (cp_axis,), to="varying")
        u_loc = pcast(u_loc, (cp_axis,), to="varying")
        o_loc, s_loc = rec.matrix_recurrence(
            lw_loc, k_loc, v_loc, r_loc, u_loc,
            jnp.zeros_like(s0_loc), chunk)
        l_tot = jnp.sum(lw_loc, axis=1)                     # (B, H, K)

        def combine(first, second):
            lf, cf = first
            ls, cs = second
            return lf + ls, jnp.exp(ls)[..., None] * cf + cs

        ident = (jnp.zeros_like(l_tot), jnp.zeros_like(s_loc))
        l_ex, c_ex = _prefix_scan(combine, ident, (l_tot, s_loc), cp_axis)
        s_in = jnp.exp(l_ex)[..., None] * s0_loc + c_ex
        # correction: o_t += (r_t ⊙ exp(cum log_w through t-1)) · s_in
        dcum = jnp.cumsum(lw_loc, axis=1)
        d_prev = dcum - lw_loc
        o = o_loc + jnp.einsum("bthk,bhkv->bthv",
                               r_loc * jnp.exp(d_prev), s_in)
        d_last = dcum[:, -1]
        s_out = jnp.exp(d_last)[..., None] * s_in + s_loc
        idx = jax.lax.axis_index(cp_axis)
        n = axis_size(cp_axis)
        s_out = jax.lax.psum(
            jnp.where(idx == n - 1, s_out, jnp.zeros_like(s_out)), cp_axis)
        return o, s_out

    fn = shard_map(body, mesh=mesh,
                   in_specs=(spec_t, spec_t, spec_t, spec_t, spec_u, spec_s),
                   out_specs=(spec_t, spec_s))
    return fn(log_w, k, v, r, u, s0)
