"""Chunked fused softmax-cross-entropy.

Never materializes the (B, S, vocab) logits and never re-shards the
activations: the (B, S) token structure is kept — batch stays on the data
axes, sequence stays on the model axis (context parallelism), and the vocab
dim of each chunk's logits is sharded over the model axis.  The only
collectives the loss adds are the tiny per-chunk log-sum-exp/label psums
over the model axis (GSPMD partial reductions).  Sequence chunking bounds
peak logits memory to (B_local * Sc_local * V_local).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.parallel.sharding import MeshAxes, logical_constraint


def chunked_cross_entropy(hidden: jax.Array, labels: jax.Array,
                          head_w: jax.Array, *, n_chunks: int = 8,
                          axes: Optional[MeshAxes] = None,
                          softcap: float = 0.0, z_loss: float = 0.0,
                          label_smoothing: float = 0.0):
    """hidden (B,S,D), labels (B,S) -> (mean_nll, metrics dict).

    ``head_w`` (D, V).  Ignores label == -1 (padding).
    """
    b, s, d = hidden.shape
    v = head_w.shape[-1]
    if axes is not None:
        hidden = logical_constraint(hidden, P(axes.dp_axes, axes.tp, None))
        labels = logical_constraint(labels, P(axes.dp_axes, axes.tp))
        head_w = logical_constraint(head_w, P(None, axes.tp))
    nc = min(n_chunks, s)
    while s % nc:
        nc -= 1
    sc = s // nc

    def chunk(carry, ci):
        nll_sum, z_sum, cnt, correct = carry
        # static shard-aligned slices: a scan-xs reshape of the
        # (model-axis-)sharded S dim makes GSPMD gather the full hidden
        # (210 GiB on yi-34b train — §Perf); static slicing stays local
        xi = jax.lax.dynamic_slice_in_dim(hidden, ci * sc, sc, 1)
        yi = jax.lax.dynamic_slice_in_dim(labels, ci * sc, sc, 1)
        logits = (xi @ head_w).astype(jnp.float32)          # (B, Sc, V)
        if softcap:
            logits = jnp.tanh(logits / softcap) * softcap
        lse = jax.nn.logsumexp(logits, axis=-1)             # psum over tp
        onehot = (jnp.arange(v)[None, None, :] == yi[..., None])
        lab_logit = jnp.sum(jnp.where(onehot, logits, 0.0), axis=-1)
        nll = lse - lab_logit
        if label_smoothing:
            mean_logit = jnp.mean(logits, axis=-1)
            nll = (1 - label_smoothing) * nll \
                + label_smoothing * (lse - mean_logit)
        valid = (yi >= 0)
        nll = jnp.where(valid, nll, 0.0)
        pred = jnp.argmax(logits, axis=-1)
        correct += jnp.sum(jnp.where(valid, pred == yi, False))
        z = jnp.where(valid, lse, 0.0)
        return (nll_sum + jnp.sum(nll), z_sum + jnp.sum(jnp.square(z)),
                cnt + jnp.sum(valid), correct), None

    carry = (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32),
             jnp.zeros((), jnp.int32), jnp.zeros((), jnp.int32))
    for ci in range(nc):  # static unroll: slice offsets stay shard-aligned
        carry, _ = chunk(carry, ci)
    nll_sum, z_sum, cnt, correct = carry
    denom = jnp.maximum(cnt, 1).astype(jnp.float32)
    loss = nll_sum / denom
    if z_loss:
        loss = loss + z_loss * z_sum / denom
    metrics = {"nll": nll_sum / denom, "n_tokens": cnt,
               "accuracy": correct / denom}
    return loss, metrics
