"""Name-based parameter sharding rules (t5x/MaxText-style partition rules).

Every parameter path is matched against ordered regex rules; each rule lists
candidate PartitionSpecs in preference order and the first one whose sharded
dims divide evenly is taken (so e.g. Mixtral's 8-expert tensors fall back
from expert-parallel to per-expert tensor-parallel on a 16-way axis, and
gemma3's 8 heads fall back from head-sharding to head-dim-sharding).

Logical axes:  fsdp -> "data"   tp -> "model"   (pod stays a pure data axis
unless ``shard_over_pod`` — ZeRO across pods — is requested).
"""

from __future__ import annotations

import dataclasses
import math
import re
from typing import Optional, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class MeshAxes:
    fsdp: str = "data"
    tp: str = "model"
    pod: Optional[str] = None          # present on the multi-pod mesh
    shard_params_over_pod: bool = False

    @property
    def fsdp_axes(self):
        if self.pod is not None and self.shard_params_over_pod:
            return (self.pod, self.fsdp)
        return self.fsdp

    @property
    def dp_axes(self):
        """Batch axes (activations)."""
        return (self.pod, self.fsdp) if self.pod is not None else (self.fsdp,)


# Each entry: (path regex, [candidate spec templates]); templates use the
# placeholders "fsdp"/"tp"; None = replicated dim.  First divisible wins.
PARAM_RULES: list[tuple[str, list[tuple]]] = [
    # embeddings
    (r"embed/tok$", [("tp", "fsdp"), (None, "fsdp"), (None, None)]),
    (r"embed/head$", [("fsdp", "tp"), ("fsdp", None), (None, None)]),
    # attention (D, H, hd) / (H, hd, D)
    (r"(mixer|cross)/wq$", [("fsdp", "tp", None), ("fsdp", None, "tp"),
                            ("fsdp", None, None)]),
    (r"(mixer|cross)/wk$", [("fsdp", "tp", None), ("fsdp", None, "tp"),
                            ("fsdp", None, None)]),
    (r"(mixer|cross)/wv$", [("fsdp", "tp", None), ("fsdp", None, "tp"),
                            ("fsdp", None, None)]),
    (r"(mixer|cross)/wo$", [("tp", None, "fsdp"), (None, "tp", "fsdp"),
                            (None, None, "fsdp")]),
    # MLA
    (r"mixer/w_dkv$", [("fsdp", "tp"), ("fsdp", None)]),
    (r"mixer/w_dq$", [("fsdp", "tp"), ("fsdp", None)]),
    (r"mixer/w_uq$", [("fsdp", "tp", None), ("fsdp", None, "tp"),
                      ("fsdp", None, None)]),
    (r"mixer/w_uk$", [("fsdp", "tp", None), ("fsdp", None, "tp"),
                      ("fsdp", None, None)]),
    (r"mixer/w_uv$", [("fsdp", "tp", None), ("fsdp", None, "tp"),
                      ("fsdp", None, None)]),
    # MoE (E, D, F) — expert-parallel first, then intra-expert TP
    (r"ffn/w_gate$", [("tp", "fsdp", None), (None, "fsdp", "tp"),
                      ("fsdp", "tp"), ("fsdp", None)]),
    (r"ffn/w_up$", [("tp", "fsdp", None), (None, "fsdp", "tp"),
                    ("fsdp", "tp"), ("fsdp", None)]),
    (r"ffn/w_down$", [("tp", None, "fsdp"), (None, "tp", "fsdp"),
                      ("tp", "fsdp"), (None, "fsdp")]),
    (r"ffn/router$", [("fsdp", None)]),
    (r"ffn/shared/", [("fsdp", "tp"), ("tp", "fsdp"), ("fsdp", None)]),
    # dense ffn two-dim fallbacks are covered above (w_gate/w_up/w_down)
    (r"ffn/(w_k|w_r)$", [("fsdp", "tp"), ("fsdp", None)]),
    (r"ffn/w_v$", [("tp", "fsdp"), (None, "fsdp")]),
    (r"ffn/b_(up|down)$", [(None,)]),
    # RG-LRU
    (r"mixer/w_(in|gate)$", [("fsdp", "tp"), ("fsdp", None)]),
    (r"mixer/w_out$", [("tp", "fsdp"), (None, "fsdp")]),
    (r"mixer/w_(rg|ig)$", [("fsdp", "tp"), ("fsdp", None)]),
    (r"mixer/conv_w$", [(None, "tp"), (None, None)]),
    # RWKV-6
    (r"mixer/w_[rkvgo]$", [("fsdp", "tp"), ("fsdp", None)]),
    (r"mixer/lora_a$", [("fsdp", None)]),
    (r"mixer/lora_b$", [(None, None, "fsdp")]),
    (r"mixer/decay_a$", [("fsdp", None)]),
    (r"mixer/decay_b$", [(None, "fsdp")]),
    # small vectors: shard over fsdp when divisible, else replicate
    (r"(scale|bias|lam|b_rg|b_ig|mu_\w+|decay_base)$", [("fsdp",), (None,)]),
    (r"(bonus_u|ln_scale)$", [(None, None)]),
    (r".*", [None]),  # fallback: replicate
]


def _resolve(template, axes: MeshAxes):
    if template is None:
        return P()
    out = []
    for t in template:
        if t == "fsdp":
            out.append(axes.fsdp_axes)
        elif t == "tp":
            out.append(axes.tp)
        else:
            out.append(None)
    return P(*out)


def _axis_size(mesh: Mesh, entry) -> int:
    if entry is None:
        return 1
    if isinstance(entry, (tuple, list)):
        return math.prod(mesh.shape[a] for a in entry)
    return mesh.shape[entry]


def _divisible(shape, spec: P, mesh: Mesh) -> bool:
    for dim, entry in zip(shape, spec):
        if dim % _axis_size(mesh, entry):
            return False
    return True


def spec_for(path: str, shape, mesh: Mesh, axes: MeshAxes,
             stacked: bool) -> P:
    """Resolve the PartitionSpec for one parameter."""
    for pattern, candidates in PARAM_RULES:
        if re.search(pattern, path):
            for cand in candidates:
                spec = _resolve(cand, axes)
                core = shape[1:] if stacked else shape
                if len(spec) not in (0, len(core)):
                    continue
                padded = P(*(list(spec) + [None] * (len(core) - len(spec))))
                if _divisible(core, padded, mesh):
                    return P(None, *padded) if stacked else padded
            break
    return P(*([None] * len(shape)))


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def param_specs(params, mesh: Mesh, axes: MeshAxes):
    """Pytree of PartitionSpecs matching ``params``.

    Parameters under ``stages`` or ``encoder/layers`` carry a leading
    stacked-repeat dim that is never sharded.
    """
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    specs = []
    for path, leaf in flat:
        p = _path_str(path)
        stacked = p.startswith("stages/") or p.startswith("encoder/layers/")
        specs.append(spec_for(p, np.shape(leaf), mesh, axes, stacked))
    return jax.tree_util.tree_unflatten(treedef, specs)


def param_shardings(params, mesh: Mesh, axes: MeshAxes):
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        param_specs(params, mesh, axes),
                        is_leaf=lambda x: isinstance(x, P))


def cache_specs(caches, mesh: Mesh, axes: MeshAxes):
    """KV caches: batch -> dp axes, slot/seq axis -> tp (flash-decoding
    layout).  Cache leaves are stacked over the stage-repeat dim (leading).

    Shapes: k/v (L, B, S, KV, hd); latent (L, B, S, R); pos (L, S);
    recurrent state (L, B, ...) — state stays batch-sharded only.
    """
    dp = axes.dp_axes

    def one(path, leaf):
        p = _path_str(path)
        shape = np.shape(leaf)
        if p.endswith("/pos"):
            return P(None, None)
        name = p.rsplit("/", 1)[-1]
        if name in ("k", "v"):
            spec = [None, dp, axes.tp] + [None] * (len(shape) - 3)
        elif name == "latent":
            spec = [None, dp, axes.tp] + [None] * (len(shape) - 3)
        else:  # recurrent state h/conv/s/x_prev...
            spec = [None, dp] + [None] * (len(shape) - 2)
        # drop shardings that don't divide
        fixed = []
        for dim, entry in zip(shape, spec):
            fixed.append(entry if dim % _axis_size(mesh, entry) == 0 else None)
        return P(*fixed)

    return jax.tree_util.tree_map_with_path(one, caches)


def logical_constraint(x, spec: P):
    """with_sharding_constraint that tolerates a missing mesh context."""
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except (ValueError, RuntimeError):
        return x
