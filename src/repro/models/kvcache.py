"""KV-cache structures: full, ring (sliding-window), MLA-latent, recurrent.

All caches carry an explicit per-slot global-position vector ``pos``
(-1 = empty); attention masks are evaluated from it, so full and ring
caches share the attention code path.  ``pos`` is batch-agnostic (the serve
loop decodes in lock-step).

Layout contract (DESIGN.md §4): the slot axis is sharded over the ``model``
mesh axis at serve time; batch over ``data``.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.config import AttentionSpec, LayerSpec, RecurrentSpec


def init_attn_cache(spec: AttentionSpec, batch: int, max_len: int, dtype):
    """Allocate an empty attention cache for one layer."""
    n_slots = min(max_len, spec.window) if spec.window else max_len
    if spec.kind == "mla":
        width = spec.kv_lora_rank + spec.qk_rope_dim
        return {
            "latent": jnp.zeros((batch, n_slots, width), dtype),
            "pos": jnp.full((n_slots,), -1, jnp.int32),
        }
    return {
        "k": jnp.zeros((batch, n_slots, spec.n_kv_heads, spec.head_dim), dtype),
        "v": jnp.zeros((batch, n_slots, spec.n_kv_heads, spec.head_dim), dtype),
        "pos": jnp.full((n_slots,), -1, jnp.int32),
    }


def init_recurrent_cache(spec: RecurrentSpec, d_model: int, batch: int, dtype):
    if spec.kind == "rglru":
        ds = spec.d_state or d_model
        return {
            "h": jnp.zeros((batch, ds), jnp.float32),
            "conv": jnp.zeros((batch, spec.conv_width - 1, ds), dtype),
            "x_prev_ffn": jnp.zeros((batch, d_model), dtype),
        }
    n_heads = spec.n_heads or d_model // 64
    dk = d_model // n_heads
    return {
        "s": jnp.zeros((batch, n_heads, dk, dk), jnp.float32),
        "x_prev": jnp.zeros((batch, d_model), dtype),
        "x_prev_ffn": jnp.zeros((batch, d_model), dtype),
    }


def init_layer_cache(spec: LayerSpec, d_model: int, batch: int, max_len: int,
                     enc_len: int, enc_kv_heads: int, dtype):
    cache = {}
    if spec.mixer == "attn":
        cache["self"] = init_attn_cache(spec.attn, batch, max_len, dtype)
    elif spec.mixer in ("rglru", "rwkv6"):
        cache["rec"] = init_recurrent_cache(spec.recurrent, d_model, batch, dtype)
    if spec.ffn == "rwkv_cm":
        cache.setdefault("rec", {})  # x_prev_ffn lives with the rec cache
    if spec.cross_attn:
        a = spec.attn
        cache["cross"] = {
            "k": jnp.zeros((batch, enc_len, a.n_kv_heads, a.head_dim), dtype),
            "v": jnp.zeros((batch, enc_len, a.n_kv_heads, a.head_dim), dtype),
            "pos": jnp.arange(enc_len, dtype=jnp.int32),
        }
    return cache


def write_attn_cache(cache: dict, k_new, v_new, start: jax.Array):
    """Insert a segment of S_new tokens at global positions
    [start, start+S_new) (S_new static; start may be traced).

    Full cache: slots == positions.  Ring cache of W slots: slot = pos % W;
    for segments longer than W only the last W entries land (their slots
    form exactly one wrap-around window).
    """
    n_slots = cache["k"].shape[1]
    s_new = k_new.shape[1]
    if s_new > n_slots:  # only the trailing window survives
        k_new = k_new[:, -n_slots:]
        v_new = v_new[:, -n_slots:]
        start = start + (s_new - n_slots)
        s_new = n_slots
    positions = start + jnp.arange(s_new, dtype=jnp.int32)
    slots = positions % n_slots
    # rotate the segment so slot i holds the entry with pos % n_slots == i
    roll = start % n_slots
    k_seg = jnp.roll(k_new, roll, axis=1)
    v_seg = jnp.roll(v_new, roll, axis=1)
    pos_seg = jnp.roll(positions, roll)
    if s_new == n_slots:
        return {"k": k_seg, "v": v_seg, "pos": pos_seg}
    if s_new == 1:
        # decode: one-hot masked write.  A dynamic-update-slice at a traced
        # index on the (model-axis-)sharded slot dim makes GSPMD gather the
        # whole cache (49 GiB/token on yi-9b decode_32k — §Perf); the
        # one-hot select is elementwise, stays sharded, and reads/writes
        # only cache-sized traffic.
        hit = (jnp.arange(n_slots, dtype=jnp.int32) == slots[0])
        k = jnp.where(hit[None, :, None, None], k_new.astype(cache["k"].dtype),
                      cache["k"])
        v = jnp.where(hit[None, :, None, None], v_new.astype(cache["v"].dtype),
                      cache["v"])
        pos = jnp.where(hit, positions[0], cache["pos"])
        return {"k": k, "v": v, "pos": pos}
    # non-wrapping multi-token segment (prefill shorter than the window)
    k = jax.lax.dynamic_update_slice_in_dim(cache["k"], k_new, slots[0], 1)
    v = jax.lax.dynamic_update_slice_in_dim(cache["v"], v_new, slots[0], 1)
    pos = jax.lax.dynamic_update_slice_in_dim(cache["pos"], positions, slots[0], 0)
    return {"k": k, "v": v, "pos": pos}


def write_latent_cache(cache: dict, latent_new, start: jax.Array):
    n_slots = cache["latent"].shape[1]
    s_new = latent_new.shape[1]
    assert s_new <= n_slots
    positions = start + jnp.arange(s_new, dtype=jnp.int32)
    if s_new == 1:  # decode: one-hot masked write (see write_attn_cache)
        hit = (jnp.arange(n_slots, dtype=jnp.int32) == positions[0] % n_slots)
        lat = jnp.where(hit[None, :, None],
                        latent_new.astype(cache["latent"].dtype),
                        cache["latent"])
        pos = jnp.where(hit, positions[0], cache["pos"])
        return {"latent": lat, "pos": pos}
    lat = jax.lax.dynamic_update_slice_in_dim(cache["latent"], latent_new,
                                              positions[0] % n_slots, 1)
    pos = jax.lax.dynamic_update_slice_in_dim(cache["pos"], positions,
                                              positions[0] % n_slots, 0)
    return {"latent": lat, "pos": pos}
