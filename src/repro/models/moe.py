"""Mixture-of-Experts FFN: top-k routing with sort-based capacity dispatch.

Covers Mixtral (8e top-2) and DeepSeek-V2 (2 shared + 160 routed top-6).
Dispatch is the sort/scatter formulation (no O(T·E·C) dense dispatch
tensors): flatten (token, choice) pairs, order by expert, rank within
expert, drop beyond capacity, gather into an (E, C, D) buffer, batched
expert matmul, weighted scatter back.

Sharding: the (E, C, D) buffer is constrained expert-dim -> ``model`` when
E divides the axis (expert parallelism: GSPMD inserts the dispatch
all-to-all), otherwise the per-expert ffn dim is sharded (tensor parallelism
inside each expert) — DESIGN.md §4.
"""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.config import MoESpec
from repro.models.layers import init_ffn, ffn_fwd, truncated_normal


def init_moe(key, d: int, m: MoESpec):
    ks = jax.random.split(key, 5)
    e, f = m.n_experts, m.d_ff_expert
    std_in, std_out = d ** -0.5, f ** -0.5
    p = {
        "router": truncated_normal(ks[0], (d, e), std_in),
        "w_gate": truncated_normal(ks[1], (e, d, f), std_in),
        "w_up": truncated_normal(ks[2], (e, d, f), std_in),
        "w_down": truncated_normal(ks[3], (e, f, d), std_out),
    }
    if m.n_shared:
        p["shared"] = init_ffn(ks[4], d, m.n_shared * f, "swiglu")
    return p


def _capacity(n_tokens: int, m: MoESpec) -> int:
    c = int(math.ceil(n_tokens * m.top_k * m.capacity_factor / m.n_experts))
    return max(8, -(-c // 8) * 8)  # round up to 8 for clean tiling


def moe_fwd(params, x, m: MoESpec, *, expert_axis: Optional[str] = None,
            router_dtype=jnp.float32):
    """x (B, S, D) -> (B, S, D).  ``expert_axis``: mesh axis for the expert
    dimension of the dispatch buffer (None = let GSPMD decide)."""
    b, s, d = x.shape
    t = b * s
    e, k = m.n_experts, m.top_k
    xt = x.reshape(t, d)

    # --- routing (fp32 for a stable softmax) ------------------------------
    logits = (xt.astype(router_dtype)
              @ params["router"].astype(router_dtype))        # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, topk_idx = jax.lax.top_k(probs, k)              # (T, k)
    gate_vals = gate_vals / jnp.clip(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)      # renormalize

    # --- dispatch: order (token, choice) pairs by expert -------------------
    cap = _capacity(t, m)
    flat_e = topk_idx.reshape(-1)                              # (T*k,)
    order = jnp.argsort(flat_e)                                # stable
    sorted_e = flat_e[order]
    counts = jnp.bincount(flat_e, length=e)                    # (E,)
    starts = jnp.cumsum(counts) - counts
    pos_in_e = jnp.arange(t * k) - starts[sorted_e]            # rank in expert
    keep = pos_in_e < cap
    slot = sorted_e * cap + jnp.clip(pos_in_e, 0, cap - 1)     # (T*k,)
    token_of = order // k                                      # source token

    buf = jnp.zeros((e * cap, d), x.dtype)
    buf = buf.at[jnp.where(keep, slot, e * cap)].set(
        xt[token_of], mode="drop")                             # dropped rows: no-op
    buf = buf.reshape(e, cap, d)
    if expert_axis is not None:
        from jax.sharding import PartitionSpec as P
        buf = jax.lax.with_sharding_constraint(
            buf, P(expert_axis, None, None))

    # --- expert computation (batched SwiGLU) -------------------------------
    dt = x.dtype
    g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, params["w_gate"].astype(dt)))
    u = jnp.einsum("ecd,edf->ecf", buf, params["w_up"].astype(dt))
    y = jnp.einsum("ecf,efd->ecd", g * u, params["w_down"].astype(dt))
    y = y.reshape(e * cap, d)

    # --- combine: gather back, weight by gate, sum the k choices ----------
    gathered = jnp.where(keep[:, None], y[slot], 0.0)          # (T*k, D)
    w = gate_vals.reshape(-1)[order].astype(dt)                # gate per pair
    out = jnp.zeros((t, d), dt).at[token_of].add(gathered * w[:, None])

    if m.n_shared:
        out = out + ffn_fwd(params["shared"], xt, "swiglu")
    return out.reshape(b, s, d)


def aux_load_balance_loss(params, x, m: MoESpec):
    """Switch-style load-balance auxiliary loss (fraction * probability)."""
    b, s, d = x.shape
    xt = x.reshape(-1, d).astype(jnp.float32)
    probs = jax.nn.softmax(xt @ params["router"].astype(jnp.float32), -1)
    _, topk_idx = jax.lax.top_k(probs, m.top_k)
    hits = jnp.zeros((m.n_experts,), jnp.float32).at[topk_idx.reshape(-1)].add(1.0)
    frac_tokens = hits / jnp.sum(hits)
    frac_prob = jnp.mean(probs, axis=0)
    return m.n_experts * jnp.sum(frac_tokens * frac_prob)
