"""Shared layer primitives: norms, RoPE, dense FFNs, embeddings.

Functional style: ``init_*`` builds a param pytree (fp32 masters); ``*_fwd``
consumes activations in the compute dtype.  Parameter tensors keep semantic
axis order so the name-based sharding rules in ``parallel/sharding.py``
stay simple.
"""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


def truncated_normal(key, shape, std: float, dtype=jnp.float32):
    return std * jax.random.truncated_normal(key, -2.0, 2.0, shape, dtype)


# --------------------------------------------------------------------------
# norms
# --------------------------------------------------------------------------

def init_norm(kind: str, d: int):
    if kind == "rmsnorm":
        return {"scale": jnp.ones((d,), jnp.float32)}
    return {"scale": jnp.ones((d,), jnp.float32),
            "bias": jnp.zeros((d,), jnp.float32)}


def norm_fwd(params, x, kind: str = "rmsnorm", eps: float = 1e-6):
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        out = xf * jax.lax.rsqrt(var + eps) * params["scale"]
    else:
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        out = (xf - mu) * jax.lax.rsqrt(var + eps) * params["scale"] \
            + params["bias"]
    return out.astype(dtype)


# --------------------------------------------------------------------------
# rotary position embeddings
# --------------------------------------------------------------------------

def rope_angles(positions: jax.Array, head_dim: int, theta: float):
    """positions (...,) int32 -> (cos, sin) of shape (..., head_dim//2)."""
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array):
    """x (..., S, n, head_dim); cos/sin (..., S, head_dim//2) broadcast over n."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[..., None, :]
    s = sin[..., None, :]
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s],
                           axis=-1).astype(x.dtype)


# --------------------------------------------------------------------------
# dense FFNs
# --------------------------------------------------------------------------

def init_ffn(key, d: int, d_ff: int, kind: str):
    ks = jax.random.split(key, 3)
    std_in = d ** -0.5
    std_out = d_ff ** -0.5
    if kind in ("swiglu", "geglu"):
        return {"w_gate": truncated_normal(ks[0], (d, d_ff), std_in),
                "w_up": truncated_normal(ks[1], (d, d_ff), std_in),
                "w_down": truncated_normal(ks[2], (d_ff, d), std_out)}
    if kind == "gelu":
        return {"w_up": truncated_normal(ks[0], (d, d_ff), std_in),
                "b_up": jnp.zeros((d_ff,), jnp.float32),
                "w_down": truncated_normal(ks[1], (d_ff, d), std_out),
                "b_down": jnp.zeros((d,), jnp.float32)}
    if kind == "rwkv_cm":
        # RWKV-6 channel mix: token-shift mix + squared-relu gate
        return {"mu_k": 0.5 * jnp.ones((d,), jnp.float32),
                "mu_r": 0.5 * jnp.ones((d,), jnp.float32),
                "w_k": truncated_normal(ks[0], (d, d_ff), std_in),
                "w_v": truncated_normal(ks[1], (d_ff, d), std_out),
                "w_r": truncated_normal(ks[2], (d, d), std_in)}
    raise ValueError(kind)


def ffn_fwd(params, x, kind: str, x_prev: Optional[jax.Array] = None):
    """x (B, S, D).  ``x_prev`` is the token-shift input for rwkv_cm:
    x shifted right by one along S (zeros or cache at position 0)."""
    if kind in ("swiglu", "geglu"):
        act = jax.nn.silu if kind == "swiglu" else jax.nn.gelu
        g = act(x @ params["w_gate"].astype(x.dtype))
        u = x @ params["w_up"].astype(x.dtype)
        return (g * u) @ params["w_down"].astype(x.dtype)
    if kind == "gelu":
        h = jax.nn.gelu(x @ params["w_up"].astype(x.dtype)
                        + params["b_up"].astype(x.dtype))
        return h @ params["w_down"].astype(x.dtype) \
            + params["b_down"].astype(x.dtype)
    if kind == "rwkv_cm":
        assert x_prev is not None
        mk = params["mu_k"].astype(x.dtype)
        mr = params["mu_r"].astype(x.dtype)
        xk = x * mk + x_prev * (1 - mk)
        xr = x * mr + x_prev * (1 - mr)
        k = jnp.square(jax.nn.relu(xk @ params["w_k"].astype(x.dtype)))
        r = jax.nn.sigmoid(xr @ params["w_r"].astype(x.dtype))
        return r * (k @ params["w_v"].astype(x.dtype))
    raise ValueError(kind)


def token_shift(x: jax.Array, prev: Optional[jax.Array] = None):
    """x shifted one step right along S; position 0 filled from ``prev``
    (B, D) (decode cache) or zeros."""
    shifted = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    if prev is not None:
        shifted = shifted.at[:, 0].set(prev.astype(x.dtype))
    return shifted


# --------------------------------------------------------------------------
# embeddings / logits
# --------------------------------------------------------------------------

def init_embedding(key, vocab: int, d: int, tie: bool):
    ks = jax.random.split(key, 2)
    # 1/sqrt(d): with sqrt(d) embedding scaling (gemma) activations are
    # unit-ish, and tied logits stay O(1) after the final norm
    p = {"tok": truncated_normal(ks[0], (vocab, d), d ** -0.5)}
    if not tie:
        p["head"] = truncated_normal(ks[1], (d, vocab), d ** -0.5)
    return p


def embed_fwd(params, tokens, dtype, scale_by_dim: bool):
    x = params["tok"].astype(dtype)[tokens]
    if scale_by_dim:
        x = x * jnp.asarray(math.sqrt(x.shape[-1]), dtype)
    return x


def logits_fwd(params, x, softcap: float = 0.0):
    w = params.get("head")
    if w is None:
        w = params["tok"].T
    logits = x @ w.astype(x.dtype)
    if softcap:
        logits = jnp.tanh(logits / softcap) * softcap
    return logits
