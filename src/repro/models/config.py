"""Model configuration schema covering all assigned architecture families.

A model is a list of **stages**; a stage is a repeated **pattern** of layer
specs scanned with stacked parameters (HLO size stays O(pattern), not
O(n_layers)).  Heterogeneous stacks (gemma3's 5 local : 1 global, Griffin's
2 RG-LRU : 1 local-attn) become multi-layer patterns; stacks with a odd
prefix (DeepSeek's dense layer 0) become an extra stage.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence


@dataclasses.dataclass(frozen=True)
class AttentionSpec:
    kind: str = "gqa"            # "gqa" | "mla"
    n_heads: int = 8
    n_kv_heads: int = 8
    head_dim: int = 128
    window: Optional[int] = None  # sliding-window size; None = full
    causal: bool = True
    rope_theta: float = 10_000.0
    use_rope: bool = True
    # MLA (DeepSeek-V2) dims
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0
    # MLA W_uk/W_uv absorption: "always" | "never" | "decode" (serve-style:
    # absorbed for 1-token reads, decompressed for multi-token passes)
    mla_absorb: str = "always"
    # softmax scale override (MLA uses qk_nope+qk_rope dims)
    scale: Optional[float] = None


@dataclasses.dataclass(frozen=True)
class MoESpec:
    n_experts: int = 8
    top_k: int = 2
    n_shared: int = 0            # DeepSeek shared experts
    d_ff_expert: int = 0         # per-expert hidden dim
    capacity_factor: float = 1.25
    router_noise: float = 0.0


@dataclasses.dataclass(frozen=True)
class RecurrentSpec:
    kind: str = "rglru"          # "rglru" | "rwkv6"
    d_state: int = 0             # rglru recurrent width (0 -> d_model)
    n_heads: int = 0             # rwkv6 heads (head k/v dim = d/heads)
    conv_width: int = 4          # rglru temporal conv
    chunk: int = 128             # chunked-scan length


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    """One layer of a pattern: a token mixer + a channel mixer."""
    mixer: str = "attn"          # "attn" | "rglru" | "rwkv6"
    attn: Optional[AttentionSpec] = None
    recurrent: Optional[RecurrentSpec] = None
    ffn: str = "swiglu"          # "swiglu" | "geglu" | "gelu" | "rwkv_cm" | "moe"
    moe: Optional[MoESpec] = None
    cross_attn: bool = False     # decoder cross-attention (enc-dec)


@dataclasses.dataclass(frozen=True)
class Stage:
    pattern: tuple[LayerSpec, ...]
    repeat: int

    @property
    def n_layers(self) -> int:
        return len(self.pattern) * self.repeat


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    d_model: int
    d_ff: int
    vocab: int
    stages: tuple[Stage, ...]
    norm: str = "rmsnorm"        # "rmsnorm" | "layernorm"
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    logit_softcap: float = 0.0
    emb_scale_by_dim: bool = False   # gemma-style sqrt(d) embedding scale
    # encoder-decoder (whisper)
    encoder: Optional["EncoderConfig"] = None
    # modality frontend stub: extra embedded tokens prepended to text
    frontend: str = "none"       # "none" | "audio" | "vision"
    n_frontend_tokens: int = 0   # patches / frames per example
    prefix_lm: bool = False      # bidirectional attention over the prefix
    dtype: str = "bfloat16"
    # which shapes this arch supports (skip rules per DESIGN §5)
    supports_decode: bool = True
    supports_long: bool = False
    # family tag from the assignment ([moe] [dense] [audio] ...)
    family: str = "dense"

    @property
    def n_layers(self) -> int:
        return sum(s.n_layers for s in self.stages)

    def param_count(self) -> int:
        """Analytic parameter count (embedding + per-layer)."""
        d, total = self.d_model, 0
        total += self.vocab * d                      # tok embedding
        if not self.tie_embeddings:
            total += self.vocab * d                  # lm head
        for stage in self.stages:
            for spec in stage.pattern:
                total += stage.repeat * _layer_params(self, spec)
        if self.encoder is not None:
            e = self.encoder
            per = _layer_params(self, e.layer)
            total += e.n_layers * per + e.max_positions * d
        return total

    def active_param_count(self) -> int:
        """MoE: params touched per token (6*N_active*D convention)."""
        d, total = self.d_model, 0
        total += self.vocab * d
        if not self.tie_embeddings:
            total += self.vocab * d
        for stage in self.stages:
            for spec in stage.pattern:
                total += stage.repeat * _layer_params(self, spec, active=True)
        if self.encoder is not None:
            e = self.encoder
            total += e.n_layers * _layer_params(self, e.layer) \
                + e.max_positions * d
        return total


@dataclasses.dataclass(frozen=True)
class EncoderConfig:
    n_layers: int
    layer: LayerSpec
    max_positions: int = 1500    # whisper-base frame positions


def _attn_params(d: int, a: AttentionSpec) -> int:
    if a.kind == "mla":
        qd = a.qk_nope_dim + a.qk_rope_dim
        n = 0
        if a.q_lora_rank:
            n += d * a.q_lora_rank + a.q_lora_rank * a.n_heads * qd
        else:
            n += d * a.n_heads * qd
        n += d * (a.kv_lora_rank + a.qk_rope_dim)
        n += a.kv_lora_rank * a.n_heads * (a.qk_nope_dim + a.v_head_dim)
        n += a.n_heads * a.v_head_dim * d
        return n
    hd = a.head_dim
    return (d * a.n_heads * hd + 2 * d * a.n_kv_heads * hd
            + a.n_heads * hd * d)


def _ffn_params(cfg: "ModelConfig", spec: LayerSpec, active: bool) -> int:
    d = cfg.d_model
    if spec.ffn == "moe":
        m = spec.moe
        e_count = (m.top_k + m.n_shared) if active else (m.n_experts + m.n_shared)
        return e_count * 3 * d * m.d_ff_expert + d * m.n_experts  # + router
    if spec.ffn in ("swiglu", "geglu"):
        return 3 * d * cfg.d_ff
    if spec.ffn == "gelu":
        return 2 * d * cfg.d_ff
    if spec.ffn == "rwkv_cm":
        return 2 * d * cfg.d_ff + d * d + 2 * d
    raise ValueError(spec.ffn)


def _mixer_params(cfg: "ModelConfig", spec: LayerSpec) -> int:
    d = cfg.d_model
    if spec.mixer == "attn":
        return _attn_params(d, spec.attn)
    if spec.mixer == "spectral":
        return 0  # parameter-free Fourier mixing
    r = spec.recurrent
    if r.kind == "rglru":
        ds = r.d_state or d
        return 2 * d * ds + ds * d + 2 * ds + r.conv_width * ds + 2 * d * ds
    if r.kind == "rwkv6":
        # r,k,v,g,o projections + token-shift/decay LoRAs + per-head params
        return 5 * d * d + (160 + 160 + 64 + 64) * d + 8 * d
    raise ValueError(r.kind)


def _layer_params(cfg: "ModelConfig", spec: LayerSpec, active: bool = False) -> int:
    n = _mixer_params(cfg, spec) + _ffn_params(cfg, spec, active)
    if spec.cross_attn:
        n += _attn_params(cfg.d_model, spec.attn)
    n += 2 * cfg.d_model  # two norms
    return n


def simple_stack(n_layers: int, spec: LayerSpec) -> tuple[Stage, ...]:
    return (Stage(pattern=(spec,), repeat=n_layers),)


def pattern_stack(n_layers: int, pattern: Sequence[LayerSpec]) -> tuple[Stage, ...]:
    """Repeat ``pattern`` as far as it divides, put the remainder in a tail
    stage (e.g. 34 layers of 5:1 local:global -> 5 full groups + 4 tail)."""
    p = len(pattern)
    groups, tail = divmod(n_layers, p)
    stages = []
    if groups:
        stages.append(Stage(pattern=tuple(pattern), repeat=groups))
    if tail:
        stages.append(Stage(pattern=tuple(pattern[:tail]), repeat=1))
    return tuple(stages)
