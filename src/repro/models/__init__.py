"""LM substrate: configs, layers, and the staged scan model."""

from repro.models.config import (AttentionSpec, EncoderConfig, LayerSpec,
                                 ModelConfig, MoESpec, RecurrentSpec, Stage,
                                 pattern_stack, simple_stack)
from repro.models.model import (encode, forward, init_caches, init_params)

__all__ = [
    "AttentionSpec", "EncoderConfig", "LayerSpec", "ModelConfig", "MoESpec",
    "RecurrentSpec", "Stage", "encode", "forward", "init_caches",
    "init_params", "pattern_stack", "simple_stack",
]
