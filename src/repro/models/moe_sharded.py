"""Sharding-explicit MoE dispatch (the §Perf fix for the MoE cells).

GSPMD resolves the global sort/scatter dispatch of ``moe.moe_fwd`` by
replicating the (E·C, D) buffers and all-reducing them — 60 TB/device/step
on deepseek-v2 train_4k (EXPERIMENTS.md §Perf).  This module pins the
communication pattern down with ``shard_map``:

  mode "ep"  (E divisible by the model axis — DeepSeek 160e/16):
      tokens stay (data x model)-sharded; each shard dispatches its local
      tokens into a local (E, C_loc, D) buffer; ONE all-to-all over the
      model axis swaps the expert dim for the capacity dim (exactly a CROFT
      pencil transpose, reusing the K-chunked overlap machinery); experts
      compute on their shard; the reverse all-to-all restores token layout.

  mode "tp"  (E not divisible — Mixtral 8e/16):
      no token movement at all: every shard dispatches locally and computes
      ALL experts on its local tokens with ffn-dim-sharded weights; the
      only collective is the psum of the down-projection output.

Both modes keep the router numerics of the reference implementation
(tests assert equality vs ``moe.moe_fwd``).
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from repro.compat import pcast, shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.distributed import _stage, FFTOptions
from repro.models.config import MoESpec
from repro.models.layers import ffn_fwd


def _local_dispatch(xt, router_w, m: MoESpec, cap: int):
    """Shared shard-local dispatch: tokens (T,D) -> buf (E, C, D) + combine
    metadata.  Identical numerics to moe.moe_fwd's global dispatch, applied
    to the shard's local tokens."""
    t, d = xt.shape
    e, k = m.n_experts, m.top_k
    logits = xt.astype(jnp.float32) @ router_w.astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, topk_idx = jax.lax.top_k(probs, k)
    gate_vals = gate_vals / jnp.clip(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)
    flat_e = topk_idx.reshape(-1)
    order = jnp.argsort(flat_e)
    sorted_e = flat_e[order]
    counts = jnp.bincount(flat_e, length=e)
    starts = jnp.cumsum(counts) - counts
    pos_in_e = jnp.arange(t * k) - starts[sorted_e]
    keep = pos_in_e < cap
    slot = sorted_e * cap + jnp.clip(pos_in_e, 0, cap - 1)
    token_of = order // k
    buf = jnp.zeros((e * cap, d), xt.dtype)
    buf = buf.at[jnp.where(keep, slot, e * cap)].set(
        xt[token_of], mode="drop")
    return buf.reshape(e, cap, d), (keep, slot, token_of, gate_vals, order)


def _local_combine(y, meta, t, d, dtype):
    keep, slot, token_of, gate_vals, order = meta
    e_cap = y.shape[0] * y.shape[1]
    y = y.reshape(e_cap, d)
    gathered = jnp.where(keep[:, None], y[slot], 0.0)
    w = gate_vals.reshape(-1)[order].astype(dtype)
    return jnp.zeros((t, d), dtype).at[token_of].add(gathered * w[:, None])


def _experts_swiglu(buf, w_gate, w_up, w_down):
    dt = buf.dtype
    g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, w_gate.astype(dt)))
    u = jnp.einsum("ecd,edf->ecf", buf, w_up.astype(dt))
    return jnp.einsum("ecf,efd->ecd", g * u, w_down.astype(dt))


def moe_fwd_sharded(params, x, m: MoESpec, *, mesh: Mesh, dp, cp_axis,
                    tp_axis: str, overlap_k: int = 2):
    """x (B, S, D) sharded P(dp, cp_axis, None) -> same.

    Chooses "ep" when E % |tp| == 0 else "tp".  The ep-mode dispatch
    all-to-all runs through CROFT's K-chunked overlap stage.
    """
    b, s, d = x.shape
    tp = mesh.shape[tp_axis]
    e, k = m.n_experts, m.top_k
    # ep needs the expert dim to divide the axis AND sequence-sharded tokens
    # (decode segments are too small to shuffle); tp needs the ffn dim to
    # divide (true for every assigned config)
    mode = "ep" if (e % tp == 0 and cp_axis is not None) else "tp"
    if mode == "tp":
        assert m.d_ff_expert % tp == 0, (m.d_ff_expert, tp)

    # shard-local token count and capacity (identical statistics to the
    # global dispatch when tokens are iid-routed)
    cp = mesh.shape[cp_axis] if (cp_axis and mode == "ep") else 1
    dp_size = 1
    if dp is not None:
        dp_size = math.prod(
            mesh.shape[a] for a in (dp if isinstance(dp, tuple) else (dp,)))
    t_loc = (b // dp_size) * (s // cp)
    cap = max(8, -(-int(math.ceil(t_loc * k * m.capacity_factor / e)) // 8) * 8)

    x_spec = P(dp, cp_axis, None)
    fft_opts = FFTOptions(overlap_k=overlap_k)

    if mode == "ep":
        w_spec = P(tp_axis, None, None)           # experts sharded
        e_loc = e // tp

        def body(x_loc, router_w, w_gate, w_up, w_down):
            bb, ss, _ = x_loc.shape
            xt = x_loc.reshape(bb * ss, d)
            buf, meta = _local_dispatch(xt, router_w, m, cap)  # (E, C, D)
            # CROFT transpose: expert dim scattered out, capacity gathered
            # (E, C, D) -> (E/tp, C*tp, D); chunked for comm/compute overlap
            buf = _stage(buf, fft_axis=None, comm_axis=tp_axis,
                         split_axis=0, concat_axis=1, chunk_axis=2,
                         sign=-1, opts=fft_opts)
            y = _experts_swiglu(buf, w_gate, w_up, w_down)
            y = _stage(y, fft_axis=None, comm_axis=tp_axis,
                       split_axis=1, concat_axis=0, chunk_axis=2,
                       sign=-1, opts=fft_opts)
            out = _local_combine(y, meta, bb * ss, d, x_loc.dtype)
            return out.reshape(bb, ss, d)

        fn = shard_map(body, mesh=mesh,
                       in_specs=(x_spec, P(None, None), w_spec, w_spec,
                                 P(tp_axis, None, None)),
                       out_specs=x_spec)
        out = fn(x, params["router"], params["w_gate"], params["w_up"],
                 params["w_down"])
    else:
        # tokens replicated along tp (every shard must hold the SAME tokens
        # so the ffn-dim partial sums line up); sharded over dp only
        x_spec_tp = P(dp, None, None)
        w_spec = P(None, None, tp_axis)           # ffn dim sharded
        wd_spec = P(None, tp_axis, None)

        def body(x_loc, router_w, w_gate, w_up, w_down):
            bb, ss, _ = x_loc.shape
            xt = x_loc.reshape(bb * ss, d)
            buf, meta = _local_dispatch(xt, router_w, m, cap)
            buf = pcast(buf, (tp_axis,), to="varying")
            y = _experts_swiglu(buf, w_gate, w_up, w_down)
            # combine is linear in y: psum AFTER combining so the wire
            # carries (T, D) tokens, not the k*capacity-padded buffer
            out = _local_combine(y, meta, bb * ss, d, x_loc.dtype)
            out = jax.lax.psum(out, tp_axis)      # down-proj partial sums
            return out.reshape(bb, ss, d)

        fn = shard_map(body, mesh=mesh,
                       in_specs=(x_spec_tp, P(None, None), w_spec, w_spec,
                                 wd_spec),
                       out_specs=x_spec_tp)
        out = fn(x, params["router"], params["w_gate"], params["w_up"],
                 params["w_down"])

    if m.n_shared:
        out = out + ffn_fwd(params["shared"], x.reshape(-1, d),
                            "swiglu").reshape(b, s, d)
    return out
