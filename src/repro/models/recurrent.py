"""Linear-recurrence token mixers: Griffin RG-LRU and RWKV-6 (Finch).

Both are chunked scans built on numerically-safe decay algebra: within a
chunk every exponential is of a **non-positive** quantity (cumulative
log-decays are non-increasing), so nothing overflows regardless of decay
magnitude; across chunks a small sequential ``lax.scan`` carries the state.

  RG-LRU  vector state  h_t = a_t ⊙ h_{t-1} + √(1-a_t²) i_t ξ_t
  RWKV-6  matrix state  S_t = diag(w_t) S_{t-1} + k_tᵀ v_t,
                        o_t = r_t · (S_{t-1} + diag(u) k_tᵀ v_t)

Decode (S=1) degenerates to the plain one-step update.
"""

from __future__ import annotations

import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.config import RecurrentSpec
from repro.models.layers import truncated_normal, token_shift


# --------------------------------------------------------------------------
# generic chunked scans
# --------------------------------------------------------------------------

def vector_recurrence(log_a: jax.Array, b: jax.Array, h0: jax.Array,
                      chunk: int = 256):
    """h_t = exp(log_a_t) ⊙ h_{t-1} + b_t over (B, T, D); h0 (B, D).

    Returns (h (B,T,D), h_last (B,D)).  Within-chunk via associative scan,
    across chunks via sequential scan.
    """
    bsz, t, d = b.shape
    c = min(chunk, t)
    while t % c:
        c -= 1
    nc = t // c
    la = log_a.reshape(bsz, nc, c, d)
    bb = b.reshape(bsz, nc, c, d)

    def assoc(e1, e2):
        (l1, b1), (l2, b2) = e1, e2
        return l1 + l2, jnp.exp(l2) * b1 + b2

    def chunk_step(h, xs):
        la_c, b_c = xs                                  # (B, C, D)
        l_in, b_in = jax.lax.associative_scan(assoc, (la_c, b_c), axis=1)
        h_t = jnp.exp(l_in) * h[:, None, :] + b_in      # (B, C, D)
        return h_t[:, -1], h_t

    h_last, h_all = jax.lax.scan(
        chunk_step, h0, (jnp.moveaxis(la, 1, 0), jnp.moveaxis(bb, 1, 0)))
    h_all = jnp.moveaxis(h_all, 0, 1).reshape(bsz, t, d)
    return h_all, h_last


def matrix_recurrence(log_w, k, v, r, u, s0, chunk: int = 64):
    """RWKV-style matrix-state scan.

    log_w, k, r : (B, T, H, K)   v : (B, T, H, V)   u : (H, K)
    s0          : (B, H, K, V)
    Returns (o (B,T,H,V), s_last).  All decay exponentials are ≤ 0.
    """
    bsz, t, h, dk = k.shape
    dv = v.shape[-1]
    c = min(chunk, t)
    while t % c:
        c -= 1
    nc = t // c

    def reshape(x):
        return jnp.moveaxis(x.reshape(bsz, nc, c, *x.shape[2:]), 1, 0)

    lw_c, k_c, v_c, r_c = map(reshape, (log_w, k, v, r))

    def chunk_step(s, xs):
        lw, kk, vv, rr = xs                      # (B, C, H, K) / (B,C,H,V)
        dcum = jnp.cumsum(lw, axis=1)            # non-increasing in t
        d_prev = dcum - lw                       # cum through t-1
        # state readout: o_state[t] = (r_t ⊙ exp(d_prev[t])) · S_entry
        q_dec = rr * jnp.exp(d_prev)
        o_state = jnp.einsum("bthk,bhkv->bthv", q_dec, s)
        # intra-chunk: scores[t,s] = Σ_K r_t exp(d_prev[t]-dcum[s]) k_s, s<t
        expdiff = jnp.exp(d_prev[:, :, None] - dcum[:, None, :, :])  # (B,C,C,H,K)
        scores = jnp.einsum("bthk,btshk,bshk->bths", rr, expdiff, kk)
        mask = jnp.tril(jnp.ones((c, c), bool), -1)     # strict s < t
        scores = jnp.where(mask[None, :, None, :], scores, 0.0)
        o_intra = jnp.einsum("bths,bshv->bthv", scores, vv)
        # current-token bonus u:  o += Σ_K (r_t ⊙ u ⊙ k_t) v_t
        o_bonus = jnp.einsum("bthk,bthv->bthv", rr * u[None, None] * kk, vv)
        o = o_state + o_intra + o_bonus
        # state update: S_exit = diag(exp(dcum[-1])) S + Σ_t exp(dcum[-1]-dcum[t]) k v
        d_last = dcum[:, -1]                     # (B, H, K)
        k_dec = kk * jnp.exp(d_last[:, None] - dcum)
        s_new = jnp.exp(d_last)[..., None] * s \
            + jnp.einsum("bthk,bthv->bhkv", k_dec, vv)
        return s_new, o

    s_last, o_all = jax.lax.scan(chunk_step, s0, (lw_c, k_c, v_c, r_c))
    o_all = jnp.moveaxis(o_all, 0, 1).reshape(bsz, t, h, dv)
    return o_all, s_last


# --------------------------------------------------------------------------
# Griffin RG-LRU block (recurrentgemma)
# --------------------------------------------------------------------------

RGLRU_C = 8.0


def init_rglru(key, d: int, r: RecurrentSpec):
    ds = r.d_state or d
    ks = jax.random.split(key, 7)
    std = d ** -0.5
    return {
        "w_in": truncated_normal(ks[0], (d, ds), std),
        "w_gate": truncated_normal(ks[1], (d, ds), std),
        "w_out": truncated_normal(ks[2], (ds, d), ds ** -0.5),
        "conv_w": truncated_normal(ks[3], (r.conv_width, ds), 0.1),
        "w_rg": truncated_normal(ks[4], (ds, ds), ds ** -0.5),
        "w_ig": truncated_normal(ks[5], (ds, ds), ds ** -0.5),
        "lam": jax.random.uniform(ks[6], (ds,), jnp.float32, 2.0, 6.0),
        "b_rg": jnp.zeros((ds,), jnp.float32),
        "b_ig": jnp.zeros((ds,), jnp.float32),
    }


class RGLRUState(NamedTuple):
    h: jax.Array          # (B, Ds)
    conv: jax.Array       # (B, W-1, Ds) trailing inputs


def rglru_init_state(batch: int, d_state: int, conv_width: int, dtype):
    return RGLRUState(h=jnp.zeros((batch, d_state), jnp.float32),
                      conv=jnp.zeros((batch, conv_width - 1, d_state), dtype))


def _causal_conv(x, w, prev):
    """Depthwise causal conv along T: x (B,T,Ds), w (W,Ds), prev (B,W-1,Ds)."""
    width = w.shape[0]
    xp = jnp.concatenate([prev.astype(x.dtype), x], axis=1)
    out = jnp.zeros_like(x)
    t = x.shape[1]
    for i in range(width):
        out = out + xp[:, i: i + t] * w[width - 1 - i].astype(x.dtype)
    return out


def rglru_fwd(params, x, r: RecurrentSpec, state: Optional[RGLRUState],
              chunk: Optional[int] = None, cp=None):
    """Griffin recurrent block: x (B,T,D) -> (B,T,D), new state.

    ``cp`` = (mesh, cp_axis, batch_spec): run the scan sequence-parallel
    (parallel/seqscan.py) when T is sharded."""
    dt = x.dtype
    ds = params["w_in"].shape[1]
    bsz, t, _ = x.shape
    if state is None:
        state = rglru_init_state(bsz, ds, r.conv_width, dt)
    gate = jax.nn.gelu(x @ params["w_gate"].astype(dt))
    xi = x @ params["w_in"].astype(dt)
    xc = _causal_conv(xi, params["conv_w"], state.conv)
    # RG-LRU gates (fp32 for the decay math)
    xf = xc.astype(jnp.float32)
    rg = jax.nn.sigmoid(xf @ params["w_rg"] + params["b_rg"])
    ig = jax.nn.sigmoid(xf @ params["w_ig"] + params["b_ig"])
    log_a = -RGLRU_C * jax.nn.softplus(params["lam"]) * rg      # ≤ 0
    gated_x = ig * xf
    b = jnp.sqrt(jnp.clip(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * gated_x
    if cp is not None:
        from repro.parallel.seqscan import cp_vector_recurrence
        mesh, cp_axis, batch_spec = cp
        h, h_last = cp_vector_recurrence(
            log_a, b, state.h, mesh=mesh, cp_axis=cp_axis,
            batch_spec=batch_spec, chunk=chunk or r.chunk or 256)
    else:
        h, h_last = vector_recurrence(log_a, b, state.h,
                                      chunk or r.chunk or 256)
    new_conv = jnp.concatenate([state.conv.astype(dt), xi], axis=1)[:, -(r.conv_width - 1):]
    y = (h.astype(dt) * gate) @ params["w_out"].astype(dt)
    return y, RGLRUState(h=h_last, conv=new_conv)


# --------------------------------------------------------------------------
# RWKV-6 time-mix block (Finch)
# --------------------------------------------------------------------------

RWKV_LORA = 32


def init_rwkv6(key, d: int, r: RecurrentSpec):
    n_heads = r.n_heads or d // 64
    dk = d // n_heads
    ks = jax.random.split(key, 12)
    std = d ** -0.5
    return {
        "mu_base": 0.5 * jnp.ones((d,), jnp.float32),
        "mu_rkvwg": 0.5 * jnp.ones((5, d), jnp.float32),
        "lora_a": truncated_normal(ks[0], (d, 5 * RWKV_LORA), std),
        "lora_b": truncated_normal(ks[1], (5, RWKV_LORA, d), RWKV_LORA ** -0.5),
        "w_r": truncated_normal(ks[2], (d, d), std),
        "w_k": truncated_normal(ks[3], (d, d), std),
        "w_v": truncated_normal(ks[4], (d, d), std),
        "w_g": truncated_normal(ks[5], (d, d), std),
        "w_o": truncated_normal(ks[6], (d, d), std),
        "decay_base": jnp.full((d,), -1.5, jnp.float32),
        "decay_a": truncated_normal(ks[7], (d, RWKV_LORA * 2), std),
        "decay_b": truncated_normal(ks[8], (RWKV_LORA * 2, d),
                                    (RWKV_LORA * 2) ** -0.5),
        "bonus_u": truncated_normal(ks[9], (n_heads, dk), 0.3),
        "ln_scale": jnp.ones((n_heads, dk), jnp.float32),
    }


class RWKVState(NamedTuple):
    s: jax.Array          # (B, H, K, V)
    x_prev: jax.Array     # (B, D) last input (token shift)


def rwkv6_init_state(batch: int, d: int, n_heads: int, dtype):
    dk = d // n_heads
    return RWKVState(s=jnp.zeros((batch, n_heads, dk, dk), jnp.float32),
                     x_prev=jnp.zeros((batch, d), dtype))


def rwkv6_fwd(params, x, r: RecurrentSpec, state: Optional[RWKVState],
              chunk: Optional[int] = None, cp=None):
    """RWKV-6 time mix: x (B,T,D) -> (B,T,D), new state.

    ``cp`` = (mesh, cp_axis, batch_spec) enables the sequence-parallel
    scan."""
    dt = x.dtype
    bsz, t, d = x.shape
    n_heads = r.n_heads or d // 64
    dk = d // n_heads
    if state is None:
        state = rwkv6_init_state(bsz, d, n_heads, dt)

    xx = token_shift(x, state.x_prev)
    # data-dependent token-shift mixing (5-way LoRA)
    base = x + (xx - x) * params["mu_base"].astype(dt)
    z = jnp.tanh(base @ params["lora_a"].astype(dt))
    z = z.reshape(bsz, t, 5, RWKV_LORA)
    mix = params["mu_rkvwg"].astype(dt)[None, None] \
        + jnp.einsum("btfl,fld->btfd", z, params["lora_b"].astype(dt))
    xr, xk, xv, xw, xg = [x + (xx - x) * mix[:, :, i] for i in range(5)]

    rr = (xr @ params["w_r"].astype(dt)).reshape(bsz, t, n_heads, dk)
    kk = (xk @ params["w_k"].astype(dt)).reshape(bsz, t, n_heads, dk)
    vv = (xv @ params["w_v"].astype(dt)).reshape(bsz, t, n_heads, dk)
    g = jax.nn.silu(xg @ params["w_g"].astype(dt))

    # data-dependent decay (fp32, log-space): log w = -exp(...)  ≤ 0
    dec = params["decay_base"] + jnp.tanh(
        xw.astype(jnp.float32) @ params["decay_a"]) @ params["decay_b"]
    log_w = -jnp.exp(dec).reshape(bsz, t, n_heads, dk)

    if cp is not None:
        from repro.parallel.seqscan import cp_matrix_recurrence
        mesh, cp_axis, batch_spec = cp
        o, s_last = cp_matrix_recurrence(
            log_w, kk.astype(jnp.float32), vv.astype(jnp.float32),
            rr.astype(jnp.float32), params["bonus_u"], state.s,
            mesh=mesh, cp_axis=cp_axis, batch_spec=batch_spec,
            chunk=chunk or r.chunk or 64)
    else:
        o, s_last = matrix_recurrence(
            log_w, kk.astype(jnp.float32), vv.astype(jnp.float32),
            rr.astype(jnp.float32), params["bonus_u"], state.s,
            chunk or r.chunk or 64)

    # per-head RMS norm (GroupNorm analogue) + gate + out proj
    var = jnp.mean(jnp.square(o), axis=-1, keepdims=True)
    o = o * jax.lax.rsqrt(var + 1e-6) * params["ln_scale"][None, None]
    y = (o.reshape(bsz, t, d).astype(dt) * g) @ params["w_o"].astype(dt)
    return y, RWKVState(s=s_last, x_prev=x[:, -1].astype(dt))
