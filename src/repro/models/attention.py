"""Attention family: GQA/MQA, sliding-window, local:global, prefix-LM,
cross-attention, and DeepSeek-style MLA — one blockwise online-softmax core.

Distribution contract (DESIGN.md §4): under GSPMD the query sequence axis is
sharded over the ``model`` mesh axis (context parallelism) while K/V are
constrained replicated along it (cheap: GQA KV is small).  Head counts
therefore never need to divide the mesh.  At decode time the KV *cache*
stays sequence-sharded; the softmax/contract reductions over the sharded
axis lower to partial-reduce collectives — GSPMD-native flash-decoding.

Masks are evaluated from explicit global position vectors, so full caches,
ring (sliding-window) caches and offset decode queries all share one code
path: empty cache slots carry position -1 and mask themselves out.
"""

from __future__ import annotations

import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.config import AttentionSpec
from repro.models.layers import apply_rope, rope_angles, truncated_normal

NEG_INF = -2.0 ** 30  # large-but-finite: keeps fully-masked rows NaN-free


class MaskSpec(NamedTuple):
    causal: bool = True
    window: Optional[int] = None     # sliding window (tokens back)
    prefix_len: int = 0              # prefix-LM: bidirectional first P tokens


def _mask_block(ms: MaskSpec, q_pos: jax.Array, k_pos: jax.Array):
    """(Sq, Sk) boolean mask from global positions (k_pos < 0 = empty)."""
    qi = q_pos[:, None]
    ki = k_pos[None, :]
    ok = ki >= 0
    if ms.causal:
        allowed = ki <= qi
        if ms.prefix_len:
            allowed = allowed | (ki < ms.prefix_len)
        ok = ok & allowed
    if ms.window is not None:
        ok = ok & (qi - ki < ms.window)
    return ok


def init_gqa(key, d: int, a: AttentionSpec):
    ks = jax.random.split(key, 4)
    std = d ** -0.5
    std_o = (a.n_heads * a.head_dim) ** -0.5
    return {
        "wq": truncated_normal(ks[0], (d, a.n_heads, a.head_dim), std),
        "wk": truncated_normal(ks[1], (d, a.n_kv_heads, a.head_dim), std),
        "wv": truncated_normal(ks[2], (d, a.n_kv_heads, a.head_dim), std),
        "wo": truncated_normal(ks[3], (a.n_heads, a.head_dim, d), std_o),
    }


def init_mla(key, d: int, a: AttentionSpec):
    ks = jax.random.split(key, 7)
    std = d ** -0.5
    qd = a.qk_nope_dim + a.qk_rope_dim
    p = {
        "w_dkv": truncated_normal(ks[0], (d, a.kv_lora_rank + a.qk_rope_dim), std),
        "w_uk": truncated_normal(ks[1], (a.kv_lora_rank, a.n_heads, a.qk_nope_dim),
                                 a.kv_lora_rank ** -0.5),
        "w_uv": truncated_normal(ks[2], (a.kv_lora_rank, a.n_heads, a.v_head_dim),
                                 a.kv_lora_rank ** -0.5),
        "wo": truncated_normal(ks[3], (a.n_heads, a.v_head_dim, d),
                               (a.n_heads * a.v_head_dim) ** -0.5),
    }
    if a.q_lora_rank:
        p["w_dq"] = truncated_normal(ks[4], (d, a.q_lora_rank), std)
        p["w_uq"] = truncated_normal(ks[5], (a.q_lora_rank, a.n_heads, qd),
                                     a.q_lora_rank ** -0.5)
    else:
        p["wq"] = truncated_normal(ks[6], (d, a.n_heads, qd), std)
    return p


def init_attention(key, d: int, a: AttentionSpec):
    return init_mla(key, d, a) if a.kind == "mla" else init_gqa(key, d, a)


# --------------------------------------------------------------------------
# blockwise online-softmax core
# --------------------------------------------------------------------------

def blockwise_attention(q, k, v, ms: MaskSpec, q_pos, k_pos, *,
                        kv_block: int = 1024, remat_step: bool = True):
    """q (B,Sq,H,hd) · k,v (B,Sk,KV,hd) -> (B,Sq,H,hd_v).

    Online softmax over kv blocks (flash pattern at the XLA level; peak
    score memory O(Sq * kv_block)).  GQA grouping by reshaping q to
    (…, KV, G, hd).  ``q_pos`` (Sq,) / ``k_pos`` (Sk,) are global indices.

    ``remat_step``: checkpoint each kv-block step so the scan's backward
    recomputes the (Sq x blk) probabilities instead of stacking them as
    f32 residuals — the flash-backward memory trade (§Perf).
    """
    b, sq, h, hd = q.shape
    _, sk, kv_heads, hd_v = v.shape
    g = h // kv_heads
    qg = q.reshape(b, sq, kv_heads, g, hd)
    blk = min(kv_block, sk)
    while sk % blk:            # largest divisor of sk not exceeding kv_block
        blk -= 1
    nblk = sk // blk

    kb = jnp.moveaxis(k.reshape(b, nblk, blk, kv_heads, hd), 1, 0)
    vb = jnp.moveaxis(v.reshape(b, nblk, blk, kv_heads, hd_v), 1, 0)
    kpb = k_pos.reshape(nblk, blk)

    def step(carry, blk_in):
        m_prev, l_prev, acc = carry
        kj, vj, kp = blk_in
        s = jnp.einsum("bqkgd,bckd->bqkgc", qg, kj,
                       preferred_element_type=jnp.float32)
        mask = _mask_block(ms, q_pos, kp)
        s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
        m_cur = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new[..., None])
        scale_prev = jnp.exp(m_prev - m_new)
        l_new = l_prev * scale_prev + jnp.sum(p, axis=-1)
        acc = acc * scale_prev[..., None] \
            + jnp.einsum("bqkgc,bckd->bqkgd", p.astype(vj.dtype), vj,
                         preferred_element_type=jnp.float32)
        return (m_new, l_new, acc), None

    m0 = jnp.full((b, sq, kv_heads, g), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, sq, kv_heads, g), jnp.float32)
    a0 = jnp.zeros((b, sq, kv_heads, g, hd_v), jnp.float32)
    if nblk == 1:
        (m, l, acc), _ = step((m0, l0, a0), (kb[0], vb[0], kpb[0]))
    else:
        step_fn = jax.checkpoint(step) if remat_step else step
        (m, l, acc), _ = jax.lax.scan(step_fn, (m0, l0, a0), (kb, vb, kpb))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(b, sq, h, hd_v)


# --------------------------------------------------------------------------
# layer forwards.  Contract:
#   attention_fwd(params, x, a, ms, q_pos, kv=None, k_pos=None, ...)
#     -> (y, new_kv)
#   kv is None        : self-attention over x (train / prefill);
#                       new_kv = this segment's (k, v) (or MLA latent)
#   kv = (k_buf,v_buf): attend over the provided buffers (decode cache with
#                       the current token already written, or cross-attn
#                       memory); new_kv echoes them back
# --------------------------------------------------------------------------

def gqa_project_kv(params, x, a: AttentionSpec, positions):
    """Project (and rope) this segment's k/v — used to fill decode caches."""
    dt = x.dtype
    k = jnp.einsum("bsd,dgk->bsgk", x, params["wk"].astype(dt))
    v = jnp.einsum("bsd,dgk->bsgk", x, params["wv"].astype(dt))
    if a.use_rope:
        cos, sin = rope_angles(positions, a.head_dim, a.rope_theta)
        k = apply_rope(k, cos, sin)
    return k, v


def gqa_fwd(params, x, a: AttentionSpec, ms: MaskSpec, q_pos, kv=None,
            k_pos=None, *, kv_block: int = 1024, kv_spec=None,
            kv_local_spec=None):
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(dt))
    if a.use_rope:
        cos, sin = rope_angles(q_pos, a.head_dim, a.rope_theta)
        q = apply_rope(q, cos, sin)
    if kv is None:
        k, v = gqa_project_kv(params, x, a, q_pos)
        k_pos = q_pos
        if kv_spec is not None and kv_local_spec is not None:
            # pin the projection output to the sequence-sharded layout so
            # GSPMD projects from LOCAL x; without this it gathers the
            # (B,S,D) activations before the einsum — 36x more bytes than
            # gathering the GQA-narrow K/V after it (§Perf, yi-34b)
            k = jax.lax.with_sharding_constraint(k, kv_local_spec)
            v = jax.lax.with_sharding_constraint(v, kv_local_spec)
    else:
        k, v = kv
    if kv_spec is not None:
        # gather K/V along the context-parallel axis (queries stay sharded)
        k = jax.lax.with_sharding_constraint(k, kv_spec)
        v = jax.lax.with_sharding_constraint(v, kv_spec)
    scale = a.scale or a.head_dim ** -0.5
    o = blockwise_attention(q * scale, k, v, ms, q_pos, k_pos,
                            kv_block=kv_block)
    y = jnp.einsum("bshk,hkd->bsd", o.astype(dt), params["wo"].astype(dt))
    return y, (k, v)


def mla_project_latent(params, x, a: AttentionSpec):
    """Joint latent [c_kv | k_rope_unrotated] — the cached quantity."""
    return x @ params["w_dkv"].astype(x.dtype)


def mla_fwd(params, x, a: AttentionSpec, ms: MaskSpec, q_pos, kv=None,
            k_pos=None, *, kv_block: int = 1024, kv_spec=None,
            kv_local_spec=None, absorbed=None):
    """DeepSeek-V2 MLA.  Cache = joint latent (B, S, kv_lora+rope);
    k_rope rotation is applied at read time from absolute k positions, so
    the cached latent is position-free.

    ``absorbed=True`` (default, §Perf): W_uk/W_uv are absorbed into the
    query/output sides, turning attention into **MQA over the latent** —
    K = [c_kv | k_rope] (one 576-wide kv head), V = c_kv.  No per-token
    decompression: the context-parallel KV gather carries 75 MB instead of
    the 10.7 GB of materialized 128-head K/V per layer (the memory cliff of
    the baseline deepseek-v2 train_4k cell).  ``absorbed=False`` keeps the
    paper-literal decompression path (tests assert both agree).
    """
    dt = x.dtype
    if absorbed is None:
        absorbed = {"always": True, "never": False,
                    "decode": x.shape[1] == 1}[a.mla_absorb]
    qd = a.qk_nope_dim + a.qk_rope_dim
    if a.q_lora_rank:
        cq = x @ params["w_dq"].astype(dt)
        q = jnp.einsum("bsr,rhk->bshk", cq, params["w_uq"].astype(dt))
    else:
        q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(dt))
    q_nope, q_rope = q[..., : a.qk_nope_dim], q[..., a.qk_nope_dim:]
    cos_q, sin_q = rope_angles(q_pos, a.qk_rope_dim, a.rope_theta)
    q_rope = apply_rope(q_rope, cos_q, sin_q)

    if kv is None:
        latent = mla_project_latent(params, x, a)
        k_pos = q_pos
        if kv_spec is not None and kv_local_spec is not None:
            latent = jax.lax.with_sharding_constraint(
                latent,
                jax.sharding.PartitionSpec(*kv_local_spec[:2], None))
    else:
        latent = kv
    if kv_spec is not None:
        latent = jax.lax.with_sharding_constraint(
            latent, jax.sharding.PartitionSpec(*kv_spec[:1], None, None))
    c_kv = latent[..., : a.kv_lora_rank]
    k_rope = latent[..., a.kv_lora_rank:]
    cos_k, sin_k = rope_angles(k_pos, a.qk_rope_dim, a.rope_theta)
    # rope at stored absolute positions; invalid (-1) rows are masked later
    k_rope = apply_rope(k_rope[..., None, :], cos_k, sin_k)  # (B,T,1,rope)
    scale = a.scale or qd ** -0.5

    if absorbed:
        # q_lat[h] = q_nope[h] @ W_uk[:,h,:]^T  — score side absorption
        q_lat = jnp.einsum("bshn,rhn->bshr", q_nope,
                           params["w_uk"].astype(dt))
        q_full = jnp.concatenate([q_lat, q_rope], axis=-1)  # (B,S,H,R+rope)
        k_full = jnp.concatenate([c_kv[..., None, :], k_rope], axis=-1)
        v_lat = c_kv[..., None, :]                          # (B,T,1,R)
        o_lat = blockwise_attention(q_full * scale, k_full, v_lat, ms,
                                    q_pos, k_pos, kv_block=kv_block)
        # output side absorption: o[h] = o_lat[h] @ W_uv[:,h,:]
        o = jnp.einsum("bshr,rhv->bshv", o_lat.astype(dt),
                       params["w_uv"].astype(dt))
    else:
        k_nope = jnp.einsum("bsr,rhk->bshk", c_kv, params["w_uk"].astype(dt))
        v = jnp.einsum("bsr,rhk->bshk", c_kv, params["w_uv"].astype(dt))
        k = jnp.concatenate(
            [k_nope,
             jnp.broadcast_to(k_rope, k_nope.shape[:-1] + (a.qk_rope_dim,))],
            axis=-1)
        q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
        o = blockwise_attention(q_full * scale, k, v, ms, q_pos, k_pos,
                                kv_block=kv_block)
    y = jnp.einsum("bshk,hkd->bsd", o.astype(dt), params["wo"].astype(dt))
    return y, latent


def attention_fwd(params, x, a: AttentionSpec, ms: MaskSpec, q_pos, kv=None,
                  k_pos=None, *, kv_block: int = 1024, kv_spec=None,
                  kv_local_spec=None):
    fn = mla_fwd if a.kind == "mla" else gqa_fwd
    return fn(params, x, a, ms, q_pos, kv, k_pos, kv_block=kv_block,
              kv_spec=kv_spec, kv_local_spec=kv_local_spec)
