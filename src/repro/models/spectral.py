"""Spectral token mixer (FNet-style) — the LM-side consumer of CROFT.

y = Re( FFT_seq( FFT_model(x) ) )   (FNet, arXiv:2105.03824)

The model-dim FFT is always local.  The sequence-dim FFT, when the sequence
axis is sharded (context parallelism over the ``model`` mesh axis), runs the
paper's transpose pattern: all-to-all the hidden axis out / sequence axis in,
local FFT, all-to-all back — one round of CROFT's pencil machinery with the
same K-chunked overlap knob.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from repro.compat import shard_map
from jax.sharding import PartitionSpec as P

from repro.core import local_fft
from repro.core.distributed import _stage  # K-chunked (fft -> all_to_all)


def _fft_last(x: jax.Array) -> jax.Array:
    return local_fft.fft_matmul(x, sign=-1)


def spectral_mixer(x: jax.Array, *, seq_axis_name: Optional[str] = None,
                   mesh=None, batch_spec=None, overlap_k: int = 2):
    """x (B, S, D) real -> (B, S, D) real.

    ``seq_axis_name``: mesh axis the sequence is sharded over (None = local).
    """
    xc = x.astype(jnp.complex64)
    xc = _fft_last(xc)                      # hidden-dim FFT, always local
    if seq_axis_name is None:
        y = jnp.swapaxes(_fft_last(jnp.swapaxes(xc, 1, 2)), 1, 2)
    else:
        y = distributed_seq_fft(xc, seq_axis_name, mesh, batch_spec,
                                overlap_k)
    return jnp.real(y).astype(x.dtype)


def distributed_seq_fft(xc: jax.Array, axis_name: str, mesh, batch_spec,
                        overlap_k: int = 2) -> jax.Array:
    """FFT along a sharded sequence axis via the CROFT transpose pattern.

    local (B, S/P, D) --a2a--> (B, S, D/P) --fft(S)--> --a2a--> (B, S/P, D)
    """
    from repro.core.distributed import FFTOptions

    opts = FFTOptions(overlap_k=overlap_k)

    def body(blk):  # (B, S/P, D)
        blk = _stage(blk, fft_axis=None, comm_axis=axis_name, split_axis=2,
                     concat_axis=1, chunk_axis=0, sign=-1, opts=opts)
        blk = jnp.moveaxis(_fft_last(jnp.moveaxis(blk, 1, -1)), -1, 1)
        blk = _stage(blk, fft_axis=None, comm_axis=axis_name, split_axis=1,
                     concat_axis=2, chunk_axis=0, sign=-1, opts=opts)
        return blk

    spec = P(batch_spec, axis_name, None)
    return shard_map(body, mesh=mesh, in_specs=spec, out_specs=spec)(xc)
