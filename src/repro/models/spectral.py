"""Spectral token mixer (FNet-style) — the LM-side consumer of CROFT.

y = Re( FFT_seq( FFT_model(x) ) )   (FNet, arXiv:2105.03824)

The model-dim FFT is always local.  The sequence-dim FFT, when the sequence
axis is sharded (context parallelism over the ``model`` mesh axis), runs the
paper's transpose pattern: all-to-all the hidden axis out / sequence axis in,
local FFT, all-to-all back — one round of CROFT's pencil machinery with the
same K-chunked overlap knob.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from repro.compat import shard_map
from jax.sharding import PartitionSpec as P

from repro.core import local_fft
from repro.core.distributed import _stage  # K-chunked (fft -> all_to_all)


def _fft_last(x: jax.Array) -> jax.Array:
    return local_fft.fft_matmul(x, sign=-1)


def spectral_mixer(x: jax.Array, *, seq_axis_name: Optional[str] = None,
                   mesh=None, batch_spec=None, overlap_k: int = 2):
    """x (B, S, D) real -> (B, S, D) real.

    ``seq_axis_name``: mesh axis the sequence is sharded over (None = local).
    """
    xc = x.astype(jnp.complex64)
    xc = _fft_last(xc)                      # hidden-dim FFT, always local
    if seq_axis_name is None:
        y = jnp.swapaxes(_fft_last(jnp.swapaxes(xc, 1, 2)), 1, 2)
    else:
        y = distributed_seq_fft(xc, seq_axis_name, mesh, batch_spec,
                                overlap_k)
    return jnp.real(y).astype(x.dtype)


def distributed_seq_fft(xc: jax.Array, axis_name: str, mesh, batch_spec,
                        overlap_k: int = 2) -> jax.Array:
    """FFT along a sharded sequence axis via the CROFT transpose pattern.

    local (B, S/P, D) --a2a--> (B, S, D/P) --fft(S)--> --a2a--> (B, S/P, D)
    """
    from repro.core.distributed import FFTOptions

    opts = FFTOptions(overlap_k=overlap_k)

    def body(blk):  # (B, S/P, D)
        blk = _stage(blk, fft_axis=None, comm_axis=axis_name, split_axis=2,
                     concat_axis=1, chunk_axis=0, sign=-1, opts=opts)
        blk = jnp.moveaxis(_fft_last(jnp.moveaxis(blk, 1, -1)), -1, 1)
        blk = _stage(blk, fft_axis=None, comm_axis=axis_name, split_axis=1,
                     concat_axis=2, chunk_axis=0, sign=-1, opts=opts)
        return blk

    spec = P(batch_spec, axis_name, None)
    return shard_map(body, mesh=mesh, in_specs=spec, out_specs=spec)(xc)


# --------------------------------------------------------------------------
# Learned spectral filter — the CROFT-side training workload
# --------------------------------------------------------------------------
#
# A two-parameter "spectral layer" over a distributed 3-D field:
#
#     y_hat(theta; x) = F( gate . x ) . filter
#
# with a learnable real-space gate (full grid) and a learnable k-space
# filter (half spectrum for r2c plans, full for c2c).  The transform is
# a planned Croft3D: the k-space multiply fuses as the plan's spectral
# epilogue (``forward_filtered``) and gradients replay the *adjoint
# schedule* (``repro.grad``) instead of XLA differentiating through
# shard_map collectives.  This is the workload ``tuned(grad=True)``
# plans for and ``benchmarks/train_bench.py`` gates.


def spectral_filter_shapes(plan) -> tuple:
    """(gate shape, filter shape) for a plan's learned spectral layer."""
    return tuple(plan.shape), tuple(plan.spectrum_shape)


def init_spectral_filter_params(key, plan, scale: float = 0.0,
                                dtype=jnp.float32):
    """Near-identity init: gate = 1 + scale*eps, filter = 1 + scale*eps.

    Real parameters in both domains (a real filter is the common
    physical case — attenuation per mode); ``scale=0`` gives the exact
    identity layer, useful as a deterministic oracle start.
    """
    gshape, fshape = spectral_filter_shapes(plan)
    kg, kf = jax.random.split(key)
    dt = jnp.dtype(dtype)
    return {
        "gate": (jnp.ones(gshape, dt)
                 + scale * jax.random.normal(kg, gshape, dt)),
        "filter": (jnp.ones(fshape, dt)
                   + scale * jax.random.normal(kf, fshape, dt)),
    }


def place_spectral_filter_params(plan, params):
    """Shard the layer's params the way the plan wants its operands: the
    gate with the input field, the filter with the output spectrum."""
    if plan.mesh is None:
        return params
    return {
        "gate": jax.device_put(params["gate"], plan.input_sharding),
        "filter": jax.device_put(params["filter"], plan.output_sharding),
    }


def spectral_filter_apply(plan, params, x: jax.Array) -> jax.Array:
    """``F(gate . x) . filter`` through the plan's fused epilogue."""
    gated = (params["gate"] * x).astype(plan.input_dtype)
    h = params["filter"].astype(plan.dtype)
    return plan.forward_filtered(gated, h)
