"""Model assembly: embedding -> stages of scanned layer patterns -> logits.

A stage scans ``repeat`` groups; each group applies its ``pattern`` of layer
specs sequentially (HLO size = O(|pattern|), compile time independent of
depth).  Parameters and caches are stacked along the leading repeat axis.

Three modes share one layer implementation:
  train    full-sequence teacher forcing, no cache I/O, remat-wrapped
  prefill  full sequence + writes KV/recurrent caches (serving cold start)
  decode   single token against the caches (serving steady state)
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models import attention as attn_lib
from repro.models import kvcache as kc
from repro.models import layers as L
from repro.models import moe as moe_lib
from repro.models import recurrent as rec_lib
from repro.models.attention import MaskSpec
from repro.models.config import (AttentionSpec, LayerSpec, ModelConfig, Stage)

CROSS_ATTN_SPEC_OVERRIDES = dict(use_rope=False, causal=False, window=None)


class ShardCtx(NamedTuple):
    """Distribution context (DESIGN.md §4): batch over ``dp`` axes, sequence
    over ``cp_axis`` (context parallelism), weights' TP axis ``tp``."""
    mesh: Any
    dp: Any                        # batch spec entry (axis, tuple, or None)
    cp_axis: Optional[str]         # sequence axis (None = unsharded seq)
    tp: Optional[str]              # model/tensor axis

    def act_spec(self):
        from jax.sharding import PartitionSpec as P
        return P(self.dp, self.cp_axis, None)

    def kv_spec(self, rank: int = 4):
        from jax.sharding import PartitionSpec as P
        return P(self.dp, *([None] * (rank - 1)))


class Ctx(NamedTuple):
    """Per-call context threaded through the layer stack."""
    mode: str                      # "train" | "prefill" | "decode"
    q_pos: jax.Array               # (S,) global positions of this segment
    start: Any                     # scalar: global position of q_pos[0]
    prefix_len: int                # prefix-LM bidirectional span
    enc_out: Optional[jax.Array]   # encoder output (cross-attention source)
    kv_block: int
    scan_chunk: Optional[int]      # recurrent chunk override
    shard: Optional[ShardCtx] = None


def _cross_spec(a: AttentionSpec) -> AttentionSpec:
    return dataclasses.replace(a, **CROSS_ATTN_SPEC_OVERRIDES)


# --------------------------------------------------------------------------
# per-layer init / forward
# --------------------------------------------------------------------------

def init_layer(key, cfg: ModelConfig, spec: LayerSpec):
    ks = jax.random.split(key, 6)
    d = cfg.d_model
    p: dict = {"ln1": L.init_norm(cfg.norm, d), "ln2": L.init_norm(cfg.norm, d)}
    if spec.mixer == "attn":
        p["mixer"] = attn_lib.init_attention(ks[0], d, spec.attn)
    elif spec.mixer == "rglru":
        p["mixer"] = rec_lib.init_rglru(ks[0], d, spec.recurrent)
    elif spec.mixer == "rwkv6":
        p["mixer"] = rec_lib.init_rwkv6(ks[0], d, spec.recurrent)
    elif spec.mixer == "spectral":
        pass  # parameter-free Fourier mixing (FNet)
    else:
        raise ValueError(spec.mixer)
    if spec.cross_attn:
        p["ln_cross"] = L.init_norm(cfg.norm, d)
        p["cross"] = attn_lib.init_attention(ks[1], d, _cross_spec(spec.attn))
    if spec.ffn == "moe":
        p["ffn"] = moe_lib.init_moe(ks[2], d, spec.moe)
    else:
        p["ffn"] = L.init_ffn(ks[2], d, cfg.d_ff, spec.ffn)
    return p


def _self_attention(p, h, spec: LayerSpec, cfg: ModelConfig, ctx: Ctx, cache):
    a = spec.attn
    ms = MaskSpec(causal=a.causal,
                  window=a.window,
                  prefix_len=ctx.prefix_len if cfg.prefix_lm else 0)
    # context parallelism: queries stay sequence-sharded; (small, GQA) K/V
    # are projected from LOCAL x, then gathered along the cp axis
    # (DESIGN.md §4, §Perf)
    kv_spec = kv_local = None
    if ctx.shard is not None and ctx.mode != "decode":
        from jax.sharding import PartitionSpec as P
        kv_spec = ctx.shard.kv_spec()
        kv_local = P(ctx.shard.dp, ctx.shard.cp_axis, None, None)
    if ctx.mode == "train":
        y, _ = attn_lib.attention_fwd(p["mixer"], h, a, ms, ctx.q_pos,
                                      kv_block=ctx.kv_block, kv_spec=kv_spec,
                                      kv_local_spec=kv_local)
        return y, cache
    if ctx.mode == "prefill":
        y, kv = attn_lib.attention_fwd(p["mixer"], h, a, ms, ctx.q_pos,
                                       kv_block=ctx.kv_block, kv_spec=kv_spec,
                                       kv_local_spec=kv_local)
        if a.kind == "mla":
            cache = {**cache, "self": kc.write_latent_cache(
                cache["self"], kv, ctx.start)}
        else:
            cache = {**cache, "self": kc.write_attn_cache(
                cache["self"], kv[0], kv[1], ctx.start)}
        return y, cache
    # decode: project this token, write, attend over the cache.  The whole
    # (sequence-sharded) cache is consumed in ONE blockwise step: a scan
    # over blocks of a sharded axis would force per-step gathers, whereas
    # the single-step path lowers to GSPMD partial-softmax reductions
    # (flash-decoding; EXPERIMENTS.md §Perf).
    c = cache["self"]
    if a.kind == "mla":
        latent_new = attn_lib.mla_project_latent(p["mixer"], h, a)
        c = kc.write_latent_cache(c, latent_new, ctx.start)
        y, _ = attn_lib.attention_fwd(p["mixer"], h, a, ms, ctx.q_pos,
                                      kv=c["latent"], k_pos=c["pos"],
                                      kv_block=c["latent"].shape[1])
    else:
        k_new, v_new = attn_lib.gqa_project_kv(p["mixer"], h, a, ctx.q_pos)
        c = kc.write_attn_cache(c, k_new, v_new, ctx.start)
        y, _ = attn_lib.attention_fwd(p["mixer"], h, a, ms, ctx.q_pos,
                                      kv=(c["k"], c["v"]), k_pos=c["pos"],
                                      kv_block=c["k"].shape[1])
    return y, {**cache, "self": c}


def _cross_attention(p, h, spec: LayerSpec, cfg: ModelConfig, ctx: Ctx, cache):
    a = _cross_spec(spec.attn)
    ms = MaskSpec(causal=False)
    if ctx.mode == "decode":
        c = cache["cross"]
        y, _ = attn_lib.attention_fwd(p["cross"], h, a, ms, ctx.q_pos,
                                      kv=(c["k"], c["v"]), k_pos=c["pos"],
                                      kv_block=ctx.kv_block)
        return y, cache
    enc = ctx.enc_out.astype(h.dtype)
    enc_pos = jnp.arange(enc.shape[1], dtype=jnp.int32)
    k_enc, v_enc = attn_lib.gqa_project_kv(p["cross"], enc, a, enc_pos)
    y, _ = attn_lib.attention_fwd(p["cross"], h, a, ms, ctx.q_pos,
                                  kv=(k_enc, v_enc), k_pos=enc_pos,
                                  kv_block=ctx.kv_block)
    if ctx.mode == "prefill":
        cache = {**cache, "cross": {"k": k_enc, "v": v_enc, "pos": enc_pos}}
    return y, cache


def _recurrent(p, h, spec: LayerSpec, cfg: ModelConfig, ctx: Ctx, cache):
    r = spec.recurrent
    rc = cache.get("rec") if cache is not None else None
    # context parallelism: cross-shard affine prefix scan (LASP-style) when
    # the sequence axis is sharded and this is a multi-token pass
    cp = None
    if (ctx.shard is not None and ctx.shard.cp_axis is not None
            and h.shape[1] > 1):
        cp = (ctx.shard.mesh, ctx.shard.cp_axis, ctx.shard.dp)
    if r.kind == "rglru":
        state = None if ctx.mode == "train" else rec_lib.RGLRUState(
            h=rc["h"], conv=rc["conv"])
        y, new = rec_lib.rglru_fwd(p["mixer"], h, r, state, ctx.scan_chunk,
                                   cp=cp)
        if ctx.mode != "train":
            cache = {**cache, "rec": {**rc, "h": new.h, "conv": new.conv}}
    else:
        state = None if ctx.mode == "train" else rec_lib.RWKVState(
            s=rc["s"], x_prev=rc["x_prev"])
        y, new = rec_lib.rwkv6_fwd(p["mixer"], h, r, state, ctx.scan_chunk,
                                   cp=cp)
        if ctx.mode != "train":
            cache = {**cache, "rec": {**rc, "s": new.s, "x_prev": new.x_prev}}
    return y, cache


def layer_fwd(p, x, spec: LayerSpec, cfg: ModelConfig, ctx: Ctx, cache):
    h = L.norm_fwd(p["ln1"], x, cfg.norm, cfg.norm_eps)
    if spec.mixer == "attn":
        y, cache = _self_attention(p, h, spec, cfg, ctx, cache)
    elif spec.mixer == "spectral":
        from repro.models.spectral import spectral_mixer
        if ctx.shard is not None and ctx.shard.cp_axis is not None:
            y = spectral_mixer(h, seq_axis_name=ctx.shard.cp_axis,
                               mesh=ctx.shard.mesh, batch_spec=ctx.shard.dp)
        else:
            y = spectral_mixer(h)
    else:
        y, cache = _recurrent(p, h, spec, cfg, ctx, cache)
    x = x + y
    if spec.cross_attn:
        hc = L.norm_fwd(p["ln_cross"], x, cfg.norm, cfg.norm_eps)
        y, cache = _cross_attention(p, hc, spec, cfg, ctx, cache)
        x = x + y
    h2 = L.norm_fwd(p["ln2"], x, cfg.norm, cfg.norm_eps)
    aux = jnp.zeros((), jnp.float32)
    if spec.ffn == "moe":
        if ctx.mode == "train":
            aux = moe_lib.aux_load_balance_loss(p["ffn"], h2, spec.moe)
        if (ctx.shard is not None and ctx.shard.tp is not None
                and h2.shape[1] > 1):
            # sharding-explicit dispatch (ep / tp modes) — GSPMD's global
            # scatter resolution all-reduces the dispatch buffers
            # (EXPERIMENTS.md §Perf A1/B1).  Decode (S=1) keeps the plain
            # path: its scatter is token-proportional and tiny, whereas the
            # tp-mode weight gather is weight-proportional (§Perf B3).
            from repro.models.moe_sharded import moe_fwd_sharded
            y = moe_fwd_sharded(p["ffn"], h2, spec.moe,
                                mesh=ctx.shard.mesh, dp=ctx.shard.dp,
                                cp_axis=ctx.shard.cp_axis,
                                tp_axis=ctx.shard.tp)
        else:
            y = moe_lib.moe_fwd(p["ffn"], h2, spec.moe)
    elif spec.ffn == "rwkv_cm":
        if ctx.mode == "train":
            prev = None
        else:
            prev = cache["rec"]["x_prev_ffn"]
        y = L.ffn_fwd(p["ffn"], h2, "rwkv_cm", x_prev=L.token_shift(h2, prev))
        if ctx.mode != "train":
            cache = {**cache,
                     "rec": {**cache["rec"], "x_prev_ffn": h2[:, -1]}}
    else:
        y = L.ffn_fwd(p["ffn"], h2, spec.ffn)
    return x + y, cache, aux


# --------------------------------------------------------------------------
# whole-model init
# --------------------------------------------------------------------------

def init_params(key, cfg: ModelConfig):
    ks = jax.random.split(key, 3 + len(cfg.stages))
    params: dict = {
        "embed": L.init_embedding(ks[0], cfg.vocab, cfg.d_model,
                                  cfg.tie_embeddings),
        "final_norm": L.init_norm(cfg.norm, cfg.d_model),
    }
    stages = []
    for si, stage in enumerate(cfg.stages):
        skeys = jax.random.split(ks[2 + si], stage.repeat)
        stage_p = {}
        for pi, spec in enumerate(stage.pattern):
            stage_p[f"p{pi}"] = jax.vmap(
                lambda k, s=spec: init_layer(k, cfg, s))(
                    jax.vmap(lambda k, i=pi: jax.random.fold_in(k, i))(skeys))
        stages.append(stage_p)
    params["stages"] = stages
    if cfg.encoder is not None:
        e = cfg.encoder
        ekeys = jax.random.split(ks[1], e.n_layers)
        params["encoder"] = {
            "layers": jax.vmap(lambda k: init_layer(k, cfg, e.layer))(ekeys),
            "final_norm": L.init_norm(cfg.norm, cfg.d_model),
        }
    return params


def init_caches(cfg: ModelConfig, batch: int, max_len: int, enc_len: int = 0,
                dtype=jnp.bfloat16):
    """Stacked caches mirroring the stage structure."""
    caches = []
    for stage in cfg.stages:
        stage_c = {}
        for pi, spec in enumerate(stage.pattern):
            def one(_, s=spec):
                return kc.init_layer_cache(
                    s, cfg.d_model, batch, max_len, enc_len,
                    s.attn.n_kv_heads if s.attn else 0, dtype)
            stage_c[f"p{pi}"] = jax.vmap(one)(jnp.arange(stage.repeat))
        caches.append(stage_c)
    return caches


# --------------------------------------------------------------------------
# forward
# --------------------------------------------------------------------------

REMAT_POLICIES = {
    "nothing": jax.checkpoint_policies.nothing_saveable,
    "dots": jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
}


def _stage_scan(stage_p, stage: Stage, x, cfg, ctx: Ctx, stage_cache,
                remat: bool, remat_policy: str = "nothing"):
    """Scan the repeat axis of one stage."""

    def body(carry, xs):
        h, aux_sum = carry
        params_t, cache_t = xs
        new_cache_t = {}
        for pi, spec in enumerate(stage.pattern):
            cache_i = cache_t[f"p{pi}"] if cache_t is not None else None
            h, cache_i, aux = layer_fwd(params_t[f"p{pi}"], h, spec, cfg,
                                        ctx, cache_i)
            new_cache_t[f"p{pi}"] = cache_i
            aux_sum = aux_sum + aux
        return (h, aux_sum), (new_cache_t if stage_cache is not None else None)

    if remat:
        body = jax.checkpoint(body, policy=REMAT_POLICIES[remat_policy])
    (x, aux_sum), new_cache = jax.lax.scan(
        body, (x, jnp.zeros((), jnp.float32)), (stage_p, stage_cache))
    return x, new_cache, aux_sum


def encode(params, cfg: ModelConfig, frames: jax.Array, kv_block: int = 1024):
    """Encoder stack (whisper): stub frame embeddings -> memory."""
    e = cfg.encoder
    x = frames.astype(jnp.dtype(cfg.dtype))
    pos = jnp.arange(x.shape[1], dtype=jnp.int32)
    ctx = Ctx(mode="train", q_pos=pos, start=0, prefix_len=0, enc_out=None,
              kv_block=kv_block, scan_chunk=None)

    def body(h, p_t):
        h, _, _ = layer_fwd(p_t, h, e.layer, cfg, ctx, None)
        return h, None

    x, _ = jax.lax.scan(body, x, params["encoder"]["layers"])
    return L.norm_fwd(params["encoder"]["final_norm"], x, cfg.norm,
                      cfg.norm_eps)


def forward(params, cfg: ModelConfig, tokens: Optional[jax.Array] = None, *,
            mode: str = "train", caches=None, start=0,
            prefix_embeds: Optional[jax.Array] = None,
            enc_out: Optional[jax.Array] = None,
            kv_block: int = 1024, scan_chunk: Optional[int] = None,
            remat: Optional[bool] = None, return_hidden: bool = False,
            shard: Optional[ShardCtx] = None, remat_policy: str = "nothing"):
    """Token ids (B, S) -> logits (B, S', vocab).

    ``prefix_embeds`` (B, P, D): modality-stub embeddings prepended to the
    token embeddings (paligemma patches / stand-alone whisper frames go to
    ``encode`` instead); emitted logits cover only the token positions.
    ``start``: global position of tokens[0] (decode step index).
    ``shard``: distribution context (constraints applied at stage
    boundaries; None = single-device semantics).
    Returns (logits, caches) — caches is None in train mode.
    """
    dtype = jnp.dtype(cfg.dtype)
    remat = (mode == "train") if remat is None else remat
    x = L.embed_fwd(params["embed"], tokens, dtype, cfg.emb_scale_by_dim)
    n_prefix = 0
    if prefix_embeds is not None:
        n_prefix = prefix_embeds.shape[1]
        x = jnp.concatenate([prefix_embeds.astype(dtype), x], axis=1)
    s = x.shape[1]
    q_pos = jnp.asarray(start, jnp.int32) + jnp.arange(s, dtype=jnp.int32)
    ctx = Ctx(mode=mode, q_pos=q_pos, start=jnp.asarray(start, jnp.int32),
              prefix_len=n_prefix if cfg.prefix_lm else 0,
              enc_out=enc_out, kv_block=kv_block, scan_chunk=scan_chunk,
              shard=shard)

    def constrain(h):
        if shard is None or mode == "decode":
            return h
        return jax.lax.with_sharding_constraint(h, shard.act_spec())

    x = constrain(x)
    new_caches = []
    aux_total = jnp.zeros((), jnp.float32)
    for si, stage in enumerate(cfg.stages):
        stage_cache = caches[si] if caches is not None else None
        x, nc, aux = _stage_scan(params["stages"][si], stage, x, cfg, ctx,
                                 stage_cache, remat, remat_policy)
        aux_total = aux_total + aux
        x = constrain(x)
        new_caches.append(nc)
    x = L.norm_fwd(params["final_norm"], x, cfg.norm, cfg.norm_eps)
    if n_prefix:
        x = x[:, n_prefix:]
    out_caches = new_caches if caches is not None else None
    if return_hidden:
        if mode == "train":
            return x, out_caches, aux_total
        return x, out_caches
    logits = L.logits_fwd(params["embed"], x, cfg.logit_softcap)
    return logits, out_caches
