"""Deterministic synthetic data pipeline.

Tokens are generated from a counter-based hash of (seed, step, position) —
no stored state, so any host can regenerate any shard of any step: restarts,
elastic re-sharding, and straggler re-assignment all replay identically
(DESIGN.md §4 fault tolerance).  Distribution is Zipf-ish over the vocab to
keep the loss landscape non-degenerate, with a Markov-ish second-order blend
so models actually have something to learn.

A background prefetch thread keeps ``depth`` batches in flight.
"""

from __future__ import annotations

import queue
import threading
from typing import Iterator, Optional

import jax
import numpy as np


def _splitmix64(x: np.ndarray) -> np.ndarray:
    x = (x + np.uint64(0x9E3779B97F4A7C15))
    z = x
    z = (z ^ (z >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
    z = (z ^ (z >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
    return z ^ (z >> np.uint64(31))


def synth_tokens(seed: int, step: int, batch: int, seq_len: int,
                 vocab: int) -> np.ndarray:
    """(batch, seq_len) int32 tokens, deterministic in (seed, step)."""
    with np.errstate(over="ignore"):
        base = np.uint64(seed) * np.uint64(0x100000001B3) + np.uint64(step)
        idx = np.arange(batch * seq_len, dtype=np.uint64).reshape(batch, seq_len)
        h = _splitmix64(base + idx * np.uint64(0x9E3779B97F4A7C15))
        u = (h >> np.uint64(11)).astype(np.float64) / float(1 << 53)
    # Zipf-ish: token = floor(vocab^u) - 1 biases mass to small ids
    tok = np.floor(np.power(float(vocab), u)).astype(np.int64) - 1
    # second-order structure: every other token repeats its left neighbour
    # (hashed choice), giving the model learnable bigram statistics
    with np.errstate(over="ignore"):
        rep = (_splitmix64(h) & np.uint64(3)) == 0
    tok[:, 1:] = np.where(rep[:, 1:], tok[:, :-1], tok[:, 1:])
    return np.clip(tok, 0, vocab - 1).astype(np.int32)


class SyntheticDataset:
    """Iterator of train batches, optionally device-put with a sharding."""

    def __init__(self, vocab: int, seq_len: int, global_batch: int,
                 seed: int = 0, sharding=None, start_step: int = 0,
                 extra: Optional[dict] = None):
        self.vocab = vocab
        self.seq_len = seq_len
        self.global_batch = global_batch
        self.seed = seed
        self.sharding = sharding
        self.step = start_step
        self.extra = extra or {}

    def batch_at(self, step: int) -> dict:
        tokens = synth_tokens(self.seed, step, self.global_batch,
                              self.seq_len + 1, self.vocab)
        batch = {"tokens": tokens}
        for name, (shape, dtype) in self.extra.items():
            rng = np.random.default_rng(self.seed * 1_000_003 + step)
            batch[name] = rng.standard_normal(
                (self.global_batch, *shape)).astype(dtype)
        if self.sharding is not None:
            batch = {k: jax.device_put(v, self.sharding.get(k))
                     if self.sharding.get(k) is not None else v
                     for k, v in batch.items()}
        return batch

    def __iter__(self) -> Iterator[dict]:
        return self

    def __next__(self) -> dict:
        b = self.batch_at(self.step)
        self.step += 1
        return b


class Prefetcher:
    """Background-thread prefetch (the pipeline's memory-I/O overlap —
    same spirit as the paper's comm/compute overlap, at the input layer)."""

    def __init__(self, it: Iterator[dict], depth: int = 2):
        self._it = it
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._done = object()
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        try:
            for item in self._it:
                self._q.put(item)
        finally:
            self._q.put(self._done)

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if item is self._done:
            raise StopIteration
        return item
