"""AdamW with warmup-cosine schedule, global-norm clipping, decoupled weight
decay (masked off 1-D params), and low-precision moment options.

Moment dtype ``bfloat16`` halves optimizer memory (the DeepSeek-236B cell
needs it to fit 256 chips — DESIGN.md §4); moments are stored in the chosen
dtype and upcast inside the update.  Optimizer state inherits the parameter
sharding (ZeRO-3 semantics come for free from the 2-D param sharding).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    warmup_steps: int = 200
    decay_steps: int = 10_000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    moment_dtype: str = "float32"    # "float32" | "bfloat16"


def schedule(cfg: OptConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, step / jnp.maximum(1, cfg.warmup_steps))
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(1, cfg.decay_steps - cfg.warmup_steps), 0, 1)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def _decay_mask(params):
    """True where weight decay applies: >=2-D tensors (norms/biases spared)."""
    return jax.tree.map(lambda p: p.ndim >= 2, params)


def init_opt_state(params, cfg: OptConfig):
    mdt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, mdt)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(params, grads, state, cfg: OptConfig):
    """One AdamW step.  Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    lr = schedule(cfg, step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9)) \
        if cfg.clip_norm else 1.0
    mask = _decay_mask(params)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)
    mdt = jnp.dtype(cfg.moment_dtype)

    def upd(p, g, m, v, decay):
        g = g.astype(jnp.float32) * scale
        m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g
        v32 = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(g)
        mhat = m32 / bc1
        vhat = v32 / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if cfg.weight_decay:
            delta = delta + jnp.where(decay, cfg.weight_decay, 0.0) \
                * p.astype(jnp.float32)
        new_p = p.astype(jnp.float32) - lr * delta
        return new_p.astype(p.dtype), m32.astype(mdt), v32.astype(mdt)

    out = jax.tree.map(upd, params, grads, state["m"], state["v"], mask)
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_state = {"m": new_m, "v": new_v, "step": step}
    metrics = {"lr": lr, "grad_norm": gnorm, "clip_scale": scale}
    return new_params, new_state, metrics
