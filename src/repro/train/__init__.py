"""Training/serving substrate: optimizer, steps, data, checkpoints, fault
tolerance."""

from repro.train.optimizer import OptConfig, adamw_update, init_opt_state
from repro.train.train_step import (init_train_state, loss_fn,
                                    make_serve_steps, make_shard_ctx,
                                    make_spectral_train_step,
                                    make_train_step, spectral_loss_fn)

__all__ = ["OptConfig", "adamw_update", "init_opt_state", "init_train_state",
           "loss_fn", "make_serve_steps", "make_shard_ctx",
           "make_spectral_train_step", "make_train_step", "spectral_loss_fn"]
