"""Jitted train / serve steps with full distribution plumbing.

``make_train_state`` + ``make_train_step`` give the production path:
fp32 master params (2-D sharded), bf16 compute cast, chunked fused loss,
AdamW, donated state.  ``make_serve_steps`` builds the prefill/decode pair
with sequence-sharded caches (flash-decoding layout).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models import model as model_lib
from repro.models.config import ModelConfig
from repro.models.model import ShardCtx
from repro.parallel import sharding as sh
from repro.parallel.loss import chunked_cross_entropy
from repro.train import optimizer as opt_lib


def make_shard_ctx(mesh: Optional[Mesh], global_batch: int,
                   multi_pod: bool = False) -> Optional[ShardCtx]:
    if mesh is None:
        return None
    axes = sh.MeshAxes(pod="pod" if multi_pod else None)
    dp_axes = axes.dp_axes
    import math
    dp_size = math.prod(mesh.shape[a] for a in dp_axes)
    dp = dp_axes if global_batch % dp_size == 0 else None
    if dp is not None and len(dp) == 1:
        dp = dp[0]
    return ShardCtx(mesh=mesh, dp=dp, cp_axis="model", tp="model")


def cast_to_compute(params, dtype):
    dt = jnp.dtype(dtype)
    return jax.tree.map(
        lambda p: p.astype(dt) if p.dtype == jnp.float32 and p.ndim >= 2
        else p, params)


def loss_fn(params, cfg: ModelConfig, batch, shard: Optional[ShardCtx],
            kv_block: int = 1024, n_loss_chunks: int = 8,
            precast: bool = False, remat_policy: str = "nothing"):
    """batch: {"tokens" (B,S+1) int32, optional "prefix_embeds",
    "frames"}.  Next-token prediction on tokens[:-1] -> tokens[1:].

    ``precast=True``: params are already in the compute dtype — the caller
    differentiates w.r.t. the bf16 copies so gradient reductions run in
    bf16 (halves cross-data grad bytes; §Perf)."""
    tokens = batch["tokens"]
    inputs, labels = tokens[:, :-1], tokens[:, 1:]
    compute_params = params if precast else cast_to_compute(params, cfg.dtype)
    kwargs = {}
    if cfg.encoder is not None:
        kwargs["enc_out"] = model_lib.encode(compute_params, cfg,
                                             batch["frames"], kv_block)
    elif cfg.frontend == "vision":
        kwargs["prefix_embeds"] = batch["prefix_embeds"]
    hidden, _, aux = model_lib.forward(
        compute_params, cfg, inputs, mode="train", kv_block=kv_block,
        shard=shard, return_hidden=True, remat_policy=remat_policy, **kwargs)
    head_w = compute_params["embed"].get("head")
    if head_w is None:
        head_w = compute_params["embed"]["tok"].T
    if shard is None:
        axes = None
    else:
        has_pod = "pod" in shard.mesh.axis_names
        axes = sh.MeshAxes(pod="pod" if has_pod else None)
    loss, metrics = chunked_cross_entropy(
        hidden, labels, head_w, n_chunks=n_loss_chunks, axes=axes,
        softcap=cfg.logit_softcap)
    # Switch-style load-balance auxiliary (zero for non-MoE stacks)
    aux_weight = 0.01
    metrics["aux_loss"] = aux
    return loss + aux_weight * aux, metrics


@dataclasses.dataclass
class TrainState:
    params: Any
    opt: Any

    def tree(self):
        return {"params": self.params, "opt": self.opt}


def init_train_state(key, cfg: ModelConfig, opt_cfg: opt_lib.OptConfig,
                     mesh: Optional[Mesh] = None,
                     axes: Optional[sh.MeshAxes] = None):
    """Initialize params + optimizer state, sharded onto the mesh."""
    if mesh is None:
        params = model_lib.init_params(key, cfg)
        return {"params": params, "opt": opt_lib.init_opt_state(params, opt_cfg)}
    axes = axes or sh.MeshAxes()
    abstract = jax.eval_shape(lambda k: model_lib.init_params(k, cfg), key)
    specs = sh.param_specs(abstract, mesh, axes)
    shardings = jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                             is_leaf=lambda x: isinstance(x, P))
    init_fn = jax.jit(lambda k: model_lib.init_params(k, cfg),
                      out_shardings=shardings)
    with jax.set_mesh(mesh) if hasattr(jax, "set_mesh") else mesh:
        params = init_fn(key)
    opt_state = {
        "m": jax.tree.map(lambda p, s: jax.device_put(
            jnp.zeros(p.shape, jnp.dtype(opt_cfg.moment_dtype)), s),
            params, shardings),
        "v": jax.tree.map(lambda p, s: jax.device_put(
            jnp.zeros(p.shape, jnp.dtype(opt_cfg.moment_dtype)), s),
            params, shardings),
        "step": jnp.zeros((), jnp.int32),
    }
    return {"params": params, "opt": opt_state}


def make_train_step(cfg: ModelConfig, opt_cfg: opt_lib.OptConfig,
                    mesh: Optional[Mesh], global_batch: int,
                    multi_pod: bool = False, kv_block: int = 1024,
                    n_loss_chunks: int = 8, donate: bool = True,
                    remat_policy: str = "nothing"):
    """Returns a jitted (state, batch) -> (state, metrics) step."""
    shard = make_shard_ctx(mesh, global_batch, multi_pod)

    def step(state, batch):
        # differentiate w.r.t. the bf16 compute copies: backward-pass
        # collectives (grad reductions, activation-transpose psums) then
        # run in bf16 instead of f32 (§Perf); masters stay f32 in AdamW
        compute_params = cast_to_compute(state["params"], cfg.dtype)
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(compute_params, cfg, batch, shard,
                                   kv_block, n_loss_chunks, precast=True,
                                   remat_policy=remat_policy)
        new_params, new_opt, opt_metrics = opt_lib.adamw_update(
            state["params"], grads, state["opt"], opt_cfg)
        metrics = {**metrics, **opt_metrics, "loss": loss}
        return {"params": new_params, "opt": new_opt}, metrics

    donate_argnums = (0,) if donate else ()
    if mesh is None:
        return jax.jit(step, donate_argnums=donate_argnums)
    return jax.jit(step, donate_argnums=donate_argnums)


# --------------------------------------------------------------------------
# spectral-layer training (the CROFT gradient workload)
# --------------------------------------------------------------------------


def spectral_loss_fn(plan, params, x, target):
    """Normalized spectral MSE of the learned filter layer
    (``repro.models.spectral``) against a target half/full spectrum.

    Normalizing by N^3 undoes the unnormalized forward transform's
    energy blow-up (Parseval), so per-mode curvature w.r.t. the filter
    is O(1) and plain SGD converges with an O(0.1) learning rate.
    """
    from repro.models import spectral as spectral_lib
    pred = spectral_lib.spectral_filter_apply(plan, params, x)
    d = pred - target
    n3 = float(plan.shape[0] * plan.shape[1] * plan.shape[2])
    return jnp.sum(jnp.real(d * jnp.conj(d))) / n3


def make_spectral_train_step(plan, lr: float = 0.05):
    """SGD step for the learned spectral filter over a planned transform.

    Returns ``(step, loss_fn)``: ``step(params, x, target) -> (params,
    loss)`` is jitted; ``loss_fn(params, x, target)`` is the raw scalar
    loss (what the benchmark differentiates for its oracle checks).
    Gradients flow through the plan's custom VJP — the backward pass
    replays the tuned schedule's adjoint (``repro.grad``), which is what
    ``Croft3D.tuned(grad=True)`` optimizes for.
    """

    def loss_fn(params, x, target):
        return spectral_loss_fn(plan, params, x, target)

    def step(params, x, target):
        loss, grads = jax.value_and_grad(loss_fn)(params, x, target)
        new = jax.tree.map(lambda p, g: p - lr * g, params, grads)
        return new, loss

    return jax.jit(step), loss_fn


# --------------------------------------------------------------------------
# serving
# --------------------------------------------------------------------------

def make_serve_steps(cfg: ModelConfig, mesh: Optional[Mesh],
                     global_batch: int, max_len: int,
                     multi_pod: bool = False, kv_block: int = 1024):
    """(prefill_fn, decode_fn).

    prefill(params, tokens, caches, **frontend) -> (last_logits, caches)
    decode(params, token, caches, t)            -> (logits, caches)
    """
    shard = make_shard_ctx(mesh, global_batch, multi_pod)

    def prefill(params, tokens, caches, prefix_embeds=None, frames=None):
        compute_params = cast_to_compute(params, cfg.dtype)
        kwargs = {}
        if cfg.encoder is not None:
            kwargs["enc_out"] = model_lib.encode(compute_params, cfg, frames,
                                                 kv_block)
        if prefix_embeds is not None:
            kwargs["prefix_embeds"] = prefix_embeds
        logits, caches = model_lib.forward(
            compute_params, cfg, tokens, mode="prefill", caches=caches,
            kv_block=kv_block, shard=shard, **kwargs)
        return logits[:, -1], caches

    def decode(params, token, caches, t):
        """token (B, 1); t = global position (prefix included)."""
        compute_params = cast_to_compute(params, cfg.dtype)
        logits, caches = model_lib.forward(
            compute_params, cfg, token, mode="decode", caches=caches,
            start=t, kv_block=kv_block, shard=shard)
        return logits[:, 0], caches

    return (jax.jit(prefill, donate_argnums=(2,)),
            jax.jit(decode, donate_argnums=(2,)))


def greedy_sample(logits: jax.Array) -> jax.Array:
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def temperature_sample(key, logits: jax.Array, temperature: float = 1.0):
    if temperature == 0.0:
        return greedy_sample(logits)
    return jax.random.categorical(key, logits / temperature).astype(jnp.int32)
