"""Async sharded checkpointing with elastic restore.

Format: one ``.npy`` per pytree leaf under ``<dir>/step_<n>/`` plus a JSON
manifest (paths, shapes, dtypes, step).  Writes happen on a background
thread into ``.tmp-`` directories committed by atomic rename, so a
preemption mid-write never corrupts the latest checkpoint.  Restore takes a
*target sharding tree*, so a checkpoint written on one mesh restores onto
any other (elastic re-scaling: logical shapes are mesh-independent).

Multi-host note: on a real cluster each process writes only the shards it
owns (``jax.experimental.multihost_utils``); this container is
single-process, so the host holds full arrays — the code path is guarded by
``process_index == 0``.
"""

from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any, Optional

import jax
import ml_dtypes
import numpy as np

# numpy can't natively (de)serialize bf16/fp8; store raw bits + dtype name
_EXOTIC_DTYPES = {
    "bfloat16": (ml_dtypes.bfloat16, np.uint16),
    "float8_e4m3fn": (ml_dtypes.float8_e4m3fn, np.uint8),
    "float8_e5m2": (ml_dtypes.float8_e5m2, np.uint8),
}


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(
            str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        out[key] = leaf
    return out, treedef


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_write: bool = True):
        self.dir = directory
        self.keep = keep
        self.async_write = async_write
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # -- save ---------------------------------------------------------------
    def save(self, step: int, tree: Any, block: bool = False):
        if jax.process_index() != 0:
            return
        self.wait()  # one in-flight write at a time
        host_tree = jax.tree.map(np.asarray, tree)  # device -> host copy

        def _write():
            tmp = os.path.join(self.dir, f".tmp-step_{step}")
            final = os.path.join(self.dir, f"step_{step}")
            shutil.rmtree(tmp, ignore_errors=True)
            os.makedirs(tmp)
            flat, _ = _flatten(host_tree)
            manifest = {"step": step, "leaves": {}}
            for key, leaf in flat.items():
                fname = key.replace("/", "__") + ".npy"
                arr = np.asarray(leaf)
                dtype_name = str(arr.dtype)
                if dtype_name in _EXOTIC_DTYPES:
                    arr = arr.view(_EXOTIC_DTYPES[dtype_name][1])
                np.save(os.path.join(tmp, fname), arr)
                manifest["leaves"][key] = {
                    "file": fname, "shape": list(np.shape(leaf)),
                    "dtype": dtype_name}
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            shutil.rmtree(final, ignore_errors=True)
            os.rename(tmp, final)
            self._gc()

        if self.async_write and not block:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()
        else:
            _write()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = sorted(self.all_steps())
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"),
                          ignore_errors=True)

    # -- restore --------------------------------------------------------------
    def all_steps(self):
        out = []
        for name in os.listdir(self.dir):
            m = re.fullmatch(r"step_(\d+)", name)
            if m:
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, template: Any, step: Optional[int] = None,
                shardings: Any = None) -> Any:
        """Restore into the structure of ``template``.

        ``shardings``: optional pytree of NamedShardings — the *current*
        mesh's layout; arrays are device_put shard-by-shard, so restoring
        onto a different mesh size (elastic) just works.
        """
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        d = os.path.join(self.dir, f"step_{step}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        flat_t, treedef = _flatten(template)
        flat_s, _ = _flatten(shardings) if shardings is not None else ({}, None)
        leaves = []
        for key in flat_t:
            info = manifest["leaves"].get(key)
            if info is None:
                raise KeyError(f"checkpoint step_{step} missing leaf {key}")
            arr = np.load(os.path.join(d, info["file"]))
            if info["dtype"] in _EXOTIC_DTYPES:
                arr = arr.view(_EXOTIC_DTYPES[info["dtype"]][0])
            expect = tuple(np.shape(flat_t[key]))
            if tuple(arr.shape) != expect:
                raise ValueError(
                    f"{key}: checkpoint shape {arr.shape} != model {expect}")
            sh = flat_s.get(key)
            leaves.append(jax.device_put(arr, sh) if sh is not None
                          else jax.numpy.asarray(arr))
        # rebuild in template order
        flat_paths = list(flat_t.keys())
        rebuilt = dict(zip(flat_paths, leaves))
        flat_with_path, td = jax.tree_util.tree_flatten_with_path(template)
        ordered = []
        for path, _ in flat_with_path:
            key = "/".join(
                str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
            ordered.append(rebuilt[key])
        return jax.tree_util.tree_unflatten(td, ordered)
