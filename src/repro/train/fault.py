"""Fault tolerance: preemption handling, straggler detection, elastic
re-meshing.

At 1000+ nodes three failure classes dominate (DESIGN.md §4):
  * planned preemption  -> SIGTERM handler flips a flag; the train loop
    checkpoints and exits cleanly at the next step boundary;
  * node loss           -> restart picks up the latest checkpoint and, if
    the device count changed, restores onto a *new* mesh (checkpoints store
    logical shapes only — see checkpoint.py);
  * stragglers          -> per-step wall times feed an EMA z-score monitor;
    flagged hosts are logged and (policy hook) can be drained or have their
    data shards reassigned — reassignment is trivial because the data
    pipeline is stateless in (seed, step, shard).
"""

from __future__ import annotations

import dataclasses
import math
import signal
import time
from typing import Callable, Optional

import jax


class PreemptionHandler:
    """SIGTERM/SIGINT -> graceful checkpoint-and-exit flag."""

    def __init__(self, signals=(signal.SIGTERM,)):
        self._requested = False
        self._installed = False
        self._signals = signals

    def install(self):
        if self._installed:
            return
        for sig in self._signals:
            try:
                signal.signal(sig, self._handle)
            except ValueError:
                pass  # non-main thread (tests)
        self._installed = True

    def _handle(self, signum, frame):
        self._requested = True

    @property
    def preemption_requested(self) -> bool:
        return self._requested


@dataclasses.dataclass
class StepStats:
    step: int
    seconds: float
    z_score: float
    is_straggler: bool


class StragglerMonitor:
    """EMA mean/variance of step wall time; flags outliers.

    On a multi-host deployment every host reports its step time into a
    cross-host allgather (cheap: one float); here the single-process variant
    monitors the global step and exposes the same policy hook.
    """

    def __init__(self, z_threshold: float = 4.0, ema: float = 0.95,
                 warmup_steps: int = 5,
                 on_straggler: Optional[Callable[[StepStats], None]] = None):
        self.z = z_threshold
        self.ema = ema
        self.warmup = warmup_steps
        self.mean = 0.0
        self.var = 0.0
        self.n = 0
        self.flagged: list[StepStats] = []
        self.on_straggler = on_straggler
        self._t0: Optional[float] = None

    def start_step(self):
        self._t0 = time.monotonic()

    def end_step(self, step: int) -> StepStats:
        dt = time.monotonic() - (self._t0 or time.monotonic())
        self.n += 1
        if self.n <= self.warmup:
            self.mean = dt if self.n == 1 else \
                (self.mean * (self.n - 1) + dt) / self.n
            self.var = max(self.var, (dt - self.mean) ** 2)
            return StepStats(step, dt, 0.0, False)
        sd = math.sqrt(self.var) if self.var > 0 else max(self.mean * 0.05, 1e-9)
        z = (dt - self.mean) / sd
        is_straggler = z > self.z
        self.mean = self.ema * self.mean + (1 - self.ema) * dt
        self.var = self.ema * self.var + (1 - self.ema) * (dt - self.mean) ** 2
        stats = StepStats(step, dt, z, is_straggler)
        if is_straggler:
            self.flagged.append(stats)
            if self.on_straggler:
                self.on_straggler(stats)
        return stats


def elastic_mesh(axis_names=("data", "model"), prefer_model: int = 16):
    """Build the largest valid mesh from the devices that are actually
    alive — the restart path after losing nodes.  Keeps the model axis at
    ``prefer_model`` when divisible, shrinking the data axis."""
    n = len(jax.devices())
    model = math.gcd(n, prefer_model)
    data = n // model
    return jax.make_mesh((data, model), axis_names,
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
