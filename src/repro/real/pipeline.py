"""Distributed packed r2c/c2r pipeline (pencil decomposition).

The paper leaves r2c/c2r as future work (§8); this is the native path —
the embedding fallback lives in ``repro.core.rfft``.  Layouts:

  real input    z-pencils: P(axes[0], axes[1], None) — (Nx/Py, Ny/Pz, Nz)
                local, z fully local so the r2c stage runs first.  This
                is ``Decomposition.spectral_spec()``, i.e. the mirror of
                the c2c pipeline: the real transform *starts* where the
                complex transform ends.
  packed        the shard-aligned half spectrum: (Nx, Ny, Nz/2) complex,
  spectrum      x-pencil sharded P(None, axes[0], axes[1]).  Bin 0 of the
                z axis carries the (real) DC and Nyquist planes folded
                into one complex plane (packing.py); bins 1..Nz/2-1 are
                the true spectrum.
  r2c output    (Nx, Ny, Nz//2 + 1), ``numpy.fft.rfftn``-compatible, in
                the z-local spectral layout P(axes[0], axes[1], None) —
                the packed body is resharded once (an all-to-all of the
                half volume) so the odd-sized Nh axis is never sharded,
                then one (Nx, Ny)-plane Hermitian reconstruction
                (``unfold_dc_plane``) splits the folded DC/Nyquist
                plane.  Keeping Nh local sidesteps the padding/gather
                pathologies of slicing a sharded z axis (the same
                choice ``core.rfft._guarded_half_slice`` makes for the
                embedding) and hands solvers a kz-local spectrum.

Forward stages (each overlapped with its all_to_all via the K-chunking
of ``core.distributed._stage``):

  1. pack two real z-pencils -> one complex pencil, FFT along z, unpack
     via Hermitian symmetry into the folded half spectrum   [stage 0]
  2. transpose z<->y over axes[1], FFT along y               [stage 1]
  3. transpose y<->x over axes[0], FFT along x               [stage 2]

Every transpose moves half the bytes of the c2c path and the z FFTs run
on half as many pencils — the ~2x first-stage bandwidth saving the
ROADMAP names, compounding with the spectral-layout trick (the packed
pipeline never pays restoring transposes).

The inverse runs the exact mirror and is algebraically exact: the
two-for-one split/merge is a linear bijection, so c2r(r2c(x)) == ifft
(fft(x)) up to the same rounding as the c2c path.
"""

from __future__ import annotations

import functools
import math
from typing import Mapping, Optional, Sequence, Union

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding

from repro.compat import shard_map
from repro.core.decomposition import Decomposition, _mesh_axis_sizes
from repro.core.distributed import FFTOptions, _all_to_all, _fft_along, _stage
from repro.real import packing


def packed_unsupported_reason(shape: Sequence[int], decomp: Decomposition,
                              mesh_or_sizes, opts: FFTOptions) -> Optional[str]:
    """None if the distributed packed pipeline supports the problem, else
    a human-readable reason (the planner and ``strategy="auto"`` use this
    to fall back to the embedding).  Pure arithmetic over axis sizes."""
    nx, ny, nz = shape[-3], shape[-2], shape[-1]
    if decomp is None:
        return "packed distributed path needs a Decomposition"
    if decomp.kind != "pencil":
        return f"packed pipeline supports pencil decomposition, not {decomp.kind}"
    if nz % 2:
        return f"packed two-for-one needs even Nz, got {nz}"
    try:
        sizes = _mesh_axis_sizes(mesh_or_sizes)
        py, pz = decomp.axis_sizes(sizes)
    except (KeyError, TypeError) as e:
        return f"decomposition axes unresolvable on this mesh: {e}"
    if nx % py:
        return f"Nx={nx} not divisible by Py={py} (z-pencil input)"
    if ny % pz:
        return f"Ny={ny} not divisible by Pz={pz} (z-pencil input)"
    if (ny // pz) % 2:
        return (f"local Ny={ny}//{pz} is odd — cannot pair two z-pencils "
                "per complex transform")
    if (nz // 2) % pz:
        return f"half spectrum Nz/2={nz // 2} not divisible by Pz={pz}"
    if ny % py:
        return f"Ny={ny} not divisible by Py={py} (y<->x transpose)"
    if opts is not None and opts.transpose_impl == "pairwise" and any(
            isinstance(a, tuple) for a in decomp.axes):
        return "pairwise transpose supports single mesh axes only"
    return None


# ---------------------------------------------------------------------------
# shard_map bodies.  Local axis order is (x, y, z); pairs ride on axis 1.
# ---------------------------------------------------------------------------

def _packed_fwd_body(blk: jax.Array, *, ax_y, ax_z, opts: FFTOptions) -> jax.Array:
    """Real (Nx/Py, Ny/Pz, Nz) z-pencil block -> packed (Nx, Ny/Py, Nz2/Pz)."""
    use_pallas = opts.stage_impl(0) == "pallas"

    def z_stage(c):
        p = packing.pack_two(c, pair_axis=1)
        C = _fft_along(p, 2, -1, opts, stage=0)
        S = packing.unpack_two(C, pair_axis=1, fold=True, use_pallas=use_pallas)
        return _all_to_all(S, ax_z, split_axis=2, concat_axis=1,
                           impl=opts.transpose_impl)

    k = opts.overlap_k
    if k <= 1 or blk.shape[0] % k:
        blk = z_stage(blk)                       # (Nx/Py, Ny, Nz2/Pz)
    else:  # K-chunked along the uninvolved x axis, like core._stage
        blk = jnp.concatenate(
            [z_stage(c) for c in jnp.split(blk, k, axis=0)], axis=0)
    blk = _stage(blk, fft_axis=1, comm_axis=ax_y, split_axis=1, concat_axis=0,
                 chunk_axis=2, sign=-1, opts=opts, stage=1)  # (Nx, Ny/Py, Nz2/Pz)
    return _fft_along(blk, 0, -1, opts, stage=2)


def _packed_inv_body(blk: jax.Array, *, ax_y, ax_z, nz: int,
                     opts: FFTOptions) -> jax.Array:
    """Packed (Nx, Ny/Py, Nz2/Pz) block -> real (Nx/Py, Ny/Pz, Nz)."""
    blk = _stage(blk, fft_axis=0, comm_axis=ax_y, split_axis=0, concat_axis=1,
                 chunk_axis=2, sign=+1, opts=opts, stage=0)  # (Nx/Py, Ny, Nz2/Pz)
    blk = _stage(blk, fft_axis=1, comm_axis=ax_z, split_axis=1, concat_axis=2,
                 chunk_axis=0, sign=+1, opts=opts, stage=1)  # (Nx/Py, Ny/Pz, Nz2)
    use_pallas = opts.stage_impl(2) == "pallas"
    C = packing.repack_halves(blk, pair_axis=1, nz=nz, folded=True,
                              use_pallas=use_pallas)
    c = _fft_along(C, 2, +1, opts, stage=2)
    return packing.split_pairs(c, pair_axis=1)


# ---------------------------------------------------------------------------
# DC/Nyquist plane fold/unfold — the only steps touching the odd
# (Nz//2 + 1)-sized axis, done once per transform on a single plane.
# ---------------------------------------------------------------------------

def unfold_dc_plane(packed: jax.Array) -> jax.Array:
    """Packed (Nx, Ny, Nz2) spectrum -> rfftn-style (Nx, Ny, Nz2 + 1).

    Bin 0 holds G = F2(DC_z) + i*F2(Nyq_z) with DC_z/Nyq_z real planes;
    the 2-D Hermitian split recovers both.  Runs at the global (traced)
    level so XLA shuffles only this one plane across shards.
    """
    g = packed[..., 0]
    rev = jnp.conj(packing.negate_freq(packing.negate_freq(g, -1), -2))
    dc = 0.5 * (g + rev)
    nyq = -0.5j * (g - rev)
    return jnp.concatenate([dc[..., None], packed[..., 1:], nyq[..., None]],
                           axis=-1)


def _hermitian_plane(p: jax.Array) -> jax.Array:
    """Project an (..., Nx, Ny) plane onto its 2-D-Hermitian part.

    ``numpy.fft.irfftn`` implicitly applies exactly this projection to
    the kz=0 and kz=Nyquist planes of a non-Hermitian half spectrum (its
    z-axis ``irfft`` drops the imaginary parts of those bins per pencil,
    and Re(ifft2(P)) == ifft2(Hermitian(P))).  For spectra that came
    from a real field the projection is the identity.
    """
    return 0.5 * (p + jnp.conj(packing.negate_freq(
        packing.negate_freq(p, -1), -2)))


def fold_dc_plane(y: jax.Array, nz: int) -> jax.Array:
    """Inverse of :func:`unfold_dc_plane`.

    The DC/Nyquist planes are first projected onto their Hermitian parts
    (a no-op for valid real-field spectra) so that arbitrary half
    spectra — e.g. derivative filters with a surviving Nyquist plane —
    invert exactly like ``numpy.fft.irfftn``.  Without the projection,
    anti-Hermitian content of the two planes would leak into each other
    through the complex fold.
    """
    nz2 = nz // 2
    g = _hermitian_plane(y[..., 0]) + 1j * _hermitian_plane(y[..., nz2])
    return jnp.concatenate([g[..., None], y[..., 1:nz2]], axis=-1)


# ---------------------------------------------------------------------------
# public entry points
# ---------------------------------------------------------------------------

def real_input_spec(decomp: Decomposition):
    """PartitionSpec of the packed pipeline's real input (z-pencils)."""
    return decomp.spectral_spec()


def constrain_sharding(y: jax.Array, sharding: NamedSharding) -> jax.Array:
    """Reshard ``y``: a sharding constraint under tracing, a device_put
    on concrete arrays (shared by the packed pipeline and core.rfft)."""
    if isinstance(y, jax.core.Tracer):
        return jax.lax.with_sharding_constraint(y, sharding)
    return jax.device_put(y, sharding)


def packed_rfft3d(x: jax.Array, mesh: Mesh, decomp: Decomposition,
                  opts: Optional[FFTOptions] = None) -> jax.Array:
    """Distributed packed r2c: real (Nx, Ny, Nz) -> (Nx, Ny, Nz//2 + 1)
    in the z-local spectral layout."""
    if opts is None:
        opts = FFTOptions()
    if x.ndim != 3:
        raise ValueError("packed_rfft3d expects a rank-3 (Nx,Ny,Nz) array")
    reason = packed_unsupported_reason(x.shape, decomp, mesh, opts)
    if reason is not None:
        raise ValueError(f"packed r2c unsupported here: {reason}")
    ax_y, ax_z = decomp.axes
    body = functools.partial(_packed_fwd_body, ax_y=ax_y, ax_z=ax_z, opts=opts)
    fn = shard_map(body, mesh=mesh, in_specs=real_input_spec(decomp),
                   out_specs=decomp.partition_spec())
    out_sharding = NamedSharding(mesh, decomp.spectral_spec())
    # one half-volume all-to-all brings z local, so the odd-sized Nh axis
    # stays unsharded and the plane unfold needs no cross-z traffic
    packed = constrain_sharding(fn(x), out_sharding)
    return constrain_sharding(unfold_dc_plane(packed), out_sharding)


def packed_irfft3d(y: jax.Array, nz: int, mesh: Mesh, decomp: Decomposition,
                   opts: Optional[FFTOptions] = None) -> jax.Array:
    """Distributed packed c2r: (Nx, Ny, Nz//2 + 1) -> real (Nx, Ny, Nz)."""
    if opts is None:
        opts = FFTOptions()
    if y.ndim != 3:
        raise ValueError("packed_irfft3d expects a rank-3 spectrum")
    nx, ny = y.shape[-3], y.shape[-2]
    reason = packed_unsupported_reason((nx, ny, nz), decomp, mesh, opts)
    if reason is not None:
        raise ValueError(f"packed c2r unsupported here: {reason}")
    # fold in the z-local layout (mirror of the forward's epilogue); the
    # shard_map in_specs below reshard the packed body back to x-pencils
    y = constrain_sharding(y, NamedSharding(mesh, decomp.spectral_spec()))
    packed = fold_dc_plane(y, nz)
    ax_y, ax_z = decomp.axes
    body = functools.partial(_packed_inv_body, ax_y=ax_y, ax_z=ax_z, nz=nz,
                             opts=opts)
    fn = shard_map(body, mesh=mesh, in_specs=decomp.partition_spec(),
                   out_specs=real_input_spec(decomp))
    x = fn(packed)
    return x * jnp.asarray(1.0 / (nx * ny * nz), x.dtype)
