"""Distributed packed r2c/c2r pipelines (pencil and slab decompositions).

The paper leaves r2c/c2r as future work (§8); this is the native path —
the embedding fallback lives in ``repro.core.rfft``.  Since the schedule
refactor the pipelines are *built*, not hardcoded: the functions below
return :class:`repro.core.schedule.Schedule` objects using the packed
stage ops (``PackTwo``/``UnpackTwo``/``RepackHalves``/``SplitPairs``),
and the entry points run them with the same executor as the complex
transform.  Layouts:

  real input    the decomposition's *spectral* layout (z fully local so
                the r2c stage runs first): pencil z-pencils
                (Nx/Py, Ny/Pz, Nz), slab z-slabs (Nx/P, Ny, Nz).  The
                real transform starts where the complex transform ends.
  packed        the shard-aligned half spectrum: (Nx, Ny, Nz/2) complex
  spectrum      in the decomposition's *natural* layout.  Bin 0 of the
                z axis carries the (real) DC and Nyquist planes folded
                into one complex plane (packing.py); bins 1..Nz/2-1 are
                the true spectrum.
  r2c output    (Nx, Ny, Nz//2 + 1), ``numpy.fft.rfftn``-compatible, in
                the z-local spectral layout — the packed body is
                resharded once (an out-of-body fused all-to-all of the
                half volume, ``Schedule.extra_comms``) so the odd-sized
                Nh axis is never sharded, then one (Nx, Ny)-plane
                Hermitian reconstruction (``unfold_dc_plane``) splits
                the folded DC/Nyquist plane.

Pencil forward stages (each overlapped with its all_to_all via the
K-chunking of ``schedule.run_stage``):

  1. pack two real z-pencils -> one complex pencil, FFT along z, unpack
     via Hermitian symmetry into the folded half spectrum   [stage 0]
  2. transpose z<->y over axes[1], FFT along y               [stage 1]
  3. transpose y<->x over axes[0], FFT along x               [stage 2]

The slab variant (ROADMAP "packed slab") pairs two x-lines instead —
local z-rfft, then the y FFT overlapped with the single z<->x transpose
of the half volume, then the x FFT — covering the 1-axis meshes where
the tuner previously had to fall back to the embedding.

Every transpose moves half the bytes of the c2c path and the z FFTs run
on half as many pencils — the ~2x first-stage bandwidth saving the
ROADMAP names, compounding with the spectral-layout trick (the packed
pipeline never pays restoring transposes).

The inverse runs the exact mirror and is algebraically exact: the
two-for-one split/merge is a linear bijection, so c2r(r2c(x)) == ifft
(fft(x)) up to the same rounding as the c2c path.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Mapping, Optional, Sequence, Union

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding

from repro.compat import shard_map
from repro.core import schedule as schedule_lib
from repro.core.decomposition import Decomposition, _mesh_axis_sizes
from repro.core.distributed import FFTOptions, _norm_scale
from repro.core.schedule import (ExtraComm, PackTwo, RepackHalves, Schedule,
                                 SplitPairs, Stage, UnpackTwo, layout_for)
from repro.real import packing

#: grid dim two real lines are paired along, per decomposition kind
PAIR_AXIS = {"pencil": 1, "slab": 0}


def packed_unsupported_reason(shape: Sequence[int], decomp: Decomposition,
                              mesh_or_sizes, opts: FFTOptions) -> Optional[str]:
    """None if the distributed packed pipeline supports the problem, else
    a human-readable reason (the planner and ``strategy="auto"`` use this
    to fall back to the embedding).  Pure arithmetic over axis sizes."""
    nx, ny, nz = shape[-3], shape[-2], shape[-1]
    if decomp is None:
        return "packed distributed path needs a Decomposition"
    if decomp.kind not in PAIR_AXIS:
        return (f"packed pipeline supports pencil and slab decompositions, "
                f"not {decomp.kind}")
    if nz % 2:
        return f"packed two-for-one needs even Nz, got {nz}"
    try:
        sizes = _mesh_axis_sizes(mesh_or_sizes)
        axis_sizes = decomp.axis_sizes(sizes)
    except (KeyError, TypeError) as e:
        return f"decomposition axes unresolvable on this mesh: {e}"
    if opts is not None and opts.transpose_impl in ("pairwise", "ring") and any(
            isinstance(a, tuple) for a in decomp.axes):
        return f"{opts.transpose_impl} transpose supports single mesh axes only"
    if decomp.kind == "slab":
        (p,) = axis_sizes
        if nx % p:
            return f"Nx={nx} not divisible by P={p} (z-slab input)"
        if (nx // p) % 2:
            return (f"local Nx={nx}//{p} is odd — cannot pair two x-lines "
                    "per complex transform")
        if (nz // 2) % p:
            return f"half spectrum Nz/2={nz // 2} not divisible by P={p}"
        return None
    py, pz = axis_sizes
    if nx % py:
        return f"Nx={nx} not divisible by Py={py} (z-pencil input)"
    if ny % pz:
        return f"Ny={ny} not divisible by Pz={pz} (z-pencil input)"
    if (ny // pz) % 2:
        return (f"local Ny={ny}//{pz} is odd — cannot pair two z-pencils "
                "per complex transform")
    if (nz // 2) % pz:
        return f"half spectrum Nz/2={nz // 2} not divisible by Pz={pz}"
    if ny % py:
        return f"Ny={ny} not divisible by Py={py} (y<->x transpose)"
    return None


# ---------------------------------------------------------------------------
# schedule builders.  Local axis order is (x, y, z); pairs ride on
# PAIR_AXIS[kind].  Input is the real spectral layout, body output the
# packed natural layout; the z-localizing epilogue reshard is recorded as
# an out-of-body ExtraComm (one fused all-to-all of the half volume).
# ---------------------------------------------------------------------------

def build_packed_forward(decomp: Decomposition) -> Schedule:
    """Real spectral-layout block -> packed natural-layout half spectrum."""
    pair = PAIR_AXIS[decomp.kind]
    layout_in = layout_for(decomp, "spectral", real=True)
    if decomp.kind == "pencil":
        ax_y, ax_z = decomp.axes
        stages = (
            Stage("pack+z-rfft+zy", fft_axis=2, impl_stage=0, comm_axis=ax_z,
                  split_axis=2, concat_axis=1, chunk_axis=0,
                  prologue=(PackTwo(pair),),
                  epilogue=(UnpackTwo(pair, impl_stage=0),)),
            Stage("y-fft+yx", fft_axis=1, impl_stage=1, comm_axis=ax_y,
                  split_axis=1, concat_axis=0, chunk_axis=2),
            Stage("x-fft", fft_axis=0, impl_stage=2),
        )
    else:  # slab: pair two x-lines, one z<->x transpose of the half volume
        # (the z-rfft chain overlaps the transpose, K-chunked along the
        # free y axis; y/x transforms run after, both local then)
        (ax_z,) = decomp.axes
        stages = (
            Stage("pack+z-rfft+zx", fft_axis=2, impl_stage=0, comm_axis=ax_z,
                  split_axis=2, concat_axis=0, chunk_axis=1,
                  prologue=(PackTwo(pair),),
                  epilogue=(UnpackTwo(pair, impl_stage=0),)),
            Stage("y-fft", fft_axis=1, impl_stage=1),
            Stage("x-fft", fft_axis=0, impl_stage=2),
        )
    sched = Schedule(f"{decomp.kind}/r2c/packed", -1, layout_in, stages)
    # the epilogue reshard moves the packed (half-volume) body output once
    return dataclasses.replace(
        sched, extra_comms=(ExtraComm("z-localize", sched.layout_out),))


def build_packed_inverse(decomp: Decomposition, nz: int) -> Schedule:
    """Packed natural-layout half spectrum -> real spectral-layout block."""
    pair = PAIR_AXIS[decomp.kind]
    layout_in = layout_for(decomp, "natural").with_den(2, mul=2)
    if decomp.kind == "pencil":
        ax_y, ax_z = decomp.axes
        stages = (
            Stage("x-ifft+xy", fft_axis=0, impl_stage=0, comm_axis=ax_y,
                  split_axis=0, concat_axis=1, chunk_axis=2),
            Stage("y-ifft+yz", fft_axis=1, impl_stage=1, comm_axis=ax_z,
                  split_axis=1, concat_axis=2, chunk_axis=0),
            Stage("repack+z-ifft+split", fft_axis=2, impl_stage=2,
                  prologue=(RepackHalves(pair, nz, impl_stage=2),),
                  epilogue=(SplitPairs(pair),)),
        )
    else:
        (ax_z,) = decomp.axes
        stages = (
            Stage("x-ifft+xz", fft_axis=0, impl_stage=0, comm_axis=ax_z,
                  split_axis=0, concat_axis=2, chunk_axis=1),
            Stage("y-ifft", fft_axis=1, impl_stage=1),
            Stage("repack+z-ifft+split", fft_axis=2, impl_stage=2,
                  prologue=(RepackHalves(pair, nz, impl_stage=2),),
                  epilogue=(SplitPairs(pair),)),
        )
    return Schedule(f"{decomp.kind}/c2r/packed", +1, layout_in, stages,
                    extra_comms=(ExtraComm("x-localize", layout_in),))


# ---------------------------------------------------------------------------
# DC/Nyquist plane fold/unfold — the only steps touching the odd
# (Nz//2 + 1)-sized axis, done once per transform on a single plane.
# ---------------------------------------------------------------------------

def unfold_dc_plane(packed: jax.Array) -> jax.Array:
    """Packed (..., Nx, Ny, Nz2) spectrum -> rfftn-style (..., Nx, Ny,
    Nz2 + 1).

    Bin 0 holds G = F2(DC_z) + i*F2(Nyq_z) with DC_z/Nyq_z real planes;
    the 2-D Hermitian split recovers both.  Runs at the global (traced)
    level so XLA shuffles only this one plane across shards.  The
    reconstruction is expressed over the trailing axes only, so a
    batched spectrum unfolds all its (Nx, Ny) planes in one vectorized
    pass — batched r2c never falls back to per-field dispatch.
    """
    g = packed[..., 0]
    rev = jnp.conj(packing.negate_freq(packing.negate_freq(g, -1), -2))
    dc = 0.5 * (g + rev)
    nyq = -0.5j * (g - rev)
    return jnp.concatenate([dc[..., None], packed[..., 1:], nyq[..., None]],
                           axis=-1)


def _hermitian_plane(p: jax.Array) -> jax.Array:
    """Project an (..., Nx, Ny) plane onto its 2-D-Hermitian part.

    ``numpy.fft.irfftn`` implicitly applies exactly this projection to
    the kz=0 and kz=Nyquist planes of a non-Hermitian half spectrum (its
    z-axis ``irfft`` drops the imaginary parts of those bins per pencil,
    and Re(ifft2(P)) == ifft2(Hermitian(P))).  For spectra that came
    from a real field the projection is the identity.
    """
    return 0.5 * (p + jnp.conj(packing.negate_freq(
        packing.negate_freq(p, -1), -2)))


def fold_dc_plane(y: jax.Array, nz: int) -> jax.Array:
    """Inverse of :func:`unfold_dc_plane`.

    The DC/Nyquist planes are first projected onto their Hermitian parts
    (a no-op for valid real-field spectra) so that arbitrary half
    spectra — e.g. derivative filters with a surviving Nyquist plane —
    invert exactly like ``numpy.fft.irfftn``.  Without the projection,
    anti-Hermitian content of the two planes would leak into each other
    through the complex fold.
    """
    nz2 = nz // 2
    g = _hermitian_plane(y[..., 0]) + 1j * _hermitian_plane(y[..., nz2])
    return jnp.concatenate([g[..., None], y[..., 1:nz2]], axis=-1)


# ---------------------------------------------------------------------------
# public entry points
# ---------------------------------------------------------------------------

def real_input_spec(decomp: Decomposition):
    """PartitionSpec of the packed pipeline's real input (z-local spectral
    layout, pencil and slab alike)."""
    return decomp.spectral_spec()


def _with_batch_dims(spec, n: int):
    """A rank-3 PartitionSpec widened with ``n`` leading unsharded batch
    axes (velocity-component stacks and other vmapped field batches)."""
    from jax.sharding import PartitionSpec as P
    if n == 0:
        return spec
    return P(*((None,) * n), *spec)


def constrain_sharding(y: jax.Array, sharding: NamedSharding) -> jax.Array:
    """Reshard ``y``: a sharding constraint under tracing, a device_put
    on concrete arrays (shared by the packed pipeline and core.rfft)."""
    if isinstance(y, jax.core.Tracer):
        return jax.lax.with_sharding_constraint(y, sharding)
    return jax.device_put(y, sharding)


def packed_rfft3d(x: jax.Array, mesh: Mesh, decomp: Decomposition,
                  opts: Optional[FFTOptions] = None,
                  norm: Optional[str] = None,
                  kspace_filter: Optional[jax.Array] = None,
                  fold_filter: bool = False) -> jax.Array:
    """Distributed packed r2c: real (Nx, Ny, Nz) -> (Nx, Ny, Nz//2 + 1)
    in the z-local spectral layout.

    ``kspace_filter`` (shaped like the output half spectrum) fuses the
    k-space multiply into the same jit, right after the plane unfold —
    the "unfolded epilogue" variant that works for any filter, including
    those with h(kz=0) != h(kz=Nyquist).

    Leading batch axes (velocity-component triples and the like) ride
    natively: a (B, Nx, Ny, Nz) input runs ONE schedule whose
    collectives move all B fields per launch and whose DC/Nyquist plane
    unfold reconstructs all B planes in a single pass — no per-field
    vmap dispatch (the executor offsets every axis index by the batch
    rank, ``run_schedule``'s ``off``).
    """
    if opts is None:
        opts = FFTOptions()
    if x.ndim < 3:
        raise ValueError("packed_rfft3d expects a (..., Nx, Ny, Nz) array")
    nbatch = x.ndim - 3
    reason = packed_unsupported_reason(x.shape, decomp, mesh, opts)
    if reason is not None:
        raise ValueError(f"packed r2c unsupported here: {reason}")
    scale = _norm_scale(x.shape, -1, norm)
    cdtype = jnp.result_type(x.dtype, jnp.complex64)
    # custom-vjp plans (repro.grad): the forward runs the same body +
    # one half-volume all-to-all bringing z local (the schedule's
    # recorded ExtraComm, so the odd-sized Nh axis stays unsharded and
    # the plane unfold needs no cross-z traffic) + plane unfold + norm
    # scale; the backward runs the adjoint schedule under the same opts
    from repro.grad import vjp as grad_vjp
    if kspace_filter is not None and fold_filter:
        # folded epilogue: multiply the *packed* half spectrum inside the
        # schedule, before the plane unfold — h must satisfy
        # h(kz=0) == h(kz=Nyquist) with that plane real and 2-D-even
        # (h[kx,ky] == h[-kx,-ky]); the filter's own Nyquist plane is
        # never read (and gets a zero cotangent under differentiation)
        hp = kspace_filter[..., : x.shape[-1] // 2].astype(cdtype)
        plan = grad_vjp.packed_rfft_folded_plan(mesh, decomp, opts, scale,
                                                nbatch, hp.ndim - 3)
        return plan(x, hp)
    y = grad_vjp.packed_rfft_plan(mesh, decomp, opts, scale, nbatch)(x)
    if kspace_filter is not None:
        from repro.kernels import spectral_scale as ss
        out_sharding = NamedSharding(
            mesh, _with_batch_dims(decomp.spectral_spec(), nbatch))
        y = constrain_sharding(
            ss.spectral_scale(y, kspace_filter.astype(y.dtype)), out_sharding)
    return y


def packed_irfft3d(y: jax.Array, nz: int, mesh: Mesh, decomp: Decomposition,
                   opts: Optional[FFTOptions] = None,
                   norm: Optional[str] = None) -> jax.Array:
    """Distributed packed c2r: (..., Nx, Ny, Nz//2 + 1) -> real
    (..., Nx, Ny, Nz); leading batch axes ride natively (see
    :func:`packed_rfft3d`)."""
    if opts is None:
        opts = FFTOptions()
    if y.ndim < 3:
        raise ValueError("packed_irfft3d expects a (..., Nx, Ny, Nh) spectrum")
    nbatch = y.ndim - 3
    nx, ny = y.shape[-3], y.shape[-2]
    reason = packed_unsupported_reason((nx, ny, nz), decomp, mesh, opts)
    if reason is not None:
        raise ValueError(f"packed c2r unsupported here: {reason}")
    # custom-vjp plan (repro.grad): fold in the z-local layout (mirror of
    # the forward's epilogue), reshard the packed body back to natural
    # (the schedule's recorded ExtraComm), run the inverse body, scale
    from repro.grad import vjp as grad_vjp
    scale = _norm_scale((nx, ny, nz), +1, norm)
    return grad_vjp.packed_irfft_plan(mesh, decomp, nz, opts, scale,
                                      nbatch)(y)
