"""Two-for-one pack/unpack primitives for real 3-D transforms.

The classic trick (Cooley/Tukey-era; P3DFFT and AccFFT both build their
r2c path on it): two real sequences a, b of length n cost ONE complex
FFT.  Pack c = a + i*b, transform C = FFT(c), and split with Hermitian
symmetry:

    A[k] = (C[k] + conj(C[-k mod n])) / 2
    B[k] = (C[k] - conj(C[-k mod n])) / (2i)
    C[k] = A[k] + i*B[k]                      (the exact inverse)

Here the two sequences are two real z-pencils of the local block, paired
along a local axis, so the distributed pipeline runs half as many z
transforms and every later stage moves half the bytes.

For even n the half spectrum has n/2 + 1 bins — one too many to stay
shard-aligned through the y/x transposes.  We use the packed
("halfcomplex" / CRAY-style) layout instead: DC and Nyquist bins of a
real transform are themselves real, so the Nyquist value rides in the
imaginary slot of bin 0 and the carried spectrum is exactly n/2 complex
bins — the same byte count as the real input, and divisible by the same
process counts.  Because the z-DC and z-Nyquist planes of a real field
are real (x, y)-planes, the folded bin stays a valid two-for-one packing
under the later y/x FFTs and is unfolded once, at the end, by a single
(Nx, Ny)-plane Hermitian reconstruction (``pipeline.unfold_dc_plane``).

All functions are pure jnp (they trace inside ``shard_map`` bodies);
``use_pallas=True`` routes the hot unpack / Hermitian-extend steps
through the fused Pallas kernels in ``repro.kernels.hermitian``.

Everything here is batch-transparent: the spectrum axis is always the
*last* axis and the pair axis an explicit (batch-offset) index, so
leading batch axes — vmapped velocity components, stacked fields —
vectorize through pack/unpack/repack in one pass (the Pallas paths
flatten every leading axis into kernel rows), and the distributed
pipeline's DC/Nyquist unfold amortizes across the whole batch instead
of falling back per-field.
"""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


def complex_dtype_for(real_dtype) -> jnp.dtype:
    """Spectrum dtype for a real input dtype (f32 -> c64, f64 -> c128)."""
    return (jnp.complex128 if jnp.dtype(real_dtype) == jnp.float64
            else jnp.complex64)


def real_dtype_for(complex_dtype) -> jnp.dtype:
    return (jnp.float64 if jnp.dtype(complex_dtype) == jnp.complex128
            else jnp.float32)


def negate_freq(a: jax.Array, axis: int = -1) -> jax.Array:
    """Index map k -> (-k) mod N along ``axis``: [0, N-1, N-2, ..., 1]."""
    return jnp.roll(jnp.flip(a, axis), 1, axis)


def pack_two(x: jax.Array, pair_axis: int) -> jax.Array:
    """Real block -> complex block, halved along ``pair_axis``.

    The first half along ``pair_axis`` becomes the real part, the second
    half the imaginary part (contiguous halves, not interleaved, so the
    unpacked spectra land back at their original positions with a single
    concatenate).  XLA fuses the two slices into the complex construction;
    there is no kernel-worthy work here.
    """
    m = x.shape[pair_axis]
    if m % 2:
        raise ValueError(f"pair axis extent {m} must be even to pack two-for-one")
    a = jax.lax.slice_in_dim(x, 0, m // 2, axis=pair_axis)
    b = jax.lax.slice_in_dim(x, m // 2, m, axis=pair_axis)
    return jax.lax.complex(a, b)


def unpack_two(C: jax.Array, pair_axis: int, *, nh: Optional[int] = None,
               fold: bool = False, use_pallas: bool = False) -> jax.Array:
    """Split the FFT of a packed block into the two half spectra.

    ``C`` is the z-transform of ``pack_two(x)``; the result restores the
    original extent along ``pair_axis`` with the A spectra in the first
    half and the B spectra in the second (mirroring ``pack_two``).

    fold=False  keep ``nh`` bins per spectrum (n//2 + 1; works for odd n)
    fold=True   even n only: keep n//2 bins with the (real) Nyquist bin
                folded into the imaginary slot of the (real) DC bin —
                the shard-aligned layout the distributed pipeline carries.
    """
    n = C.shape[-1]
    if fold:
        if n % 2:
            raise ValueError("fold=True needs an even transform size")
        if use_pallas and C.dtype == jnp.complex64:
            return _unpack_fold_pallas(C, pair_axis)
    rev = jnp.conj(negate_freq(C, -1))
    A = 0.5 * (C + rev)
    B = -0.5j * (C - rev)
    if fold:
        nz2 = n // 2

        def folded(S):
            # DC and Nyquist of a real transform are real; stash Nyquist
            # in DC's imaginary slot -> exactly nz2 bins, no bin lost
            s0 = jax.lax.complex(jnp.real(S[..., 0]), jnp.real(S[..., nz2]))
            return jnp.concatenate([s0[..., None], S[..., 1:nz2]], axis=-1)

        A, B = folded(A), folded(B)
    else:
        if nh is None:
            nh = n // 2 + 1
        A, B = A[..., :nh], B[..., :nh]
    return jnp.concatenate([A, B], axis=pair_axis)


def repack_halves(S: jax.Array, pair_axis: int, nz: int, *,
                  folded: bool = False, use_pallas: bool = False) -> jax.Array:
    """Inverse of :func:`unpack_two`: rebuild the full packed z-spectrum.

    Given the two half spectra stacked along ``pair_axis`` (``folded``
    matching how they were produced), reconstruct the length-``nz``
    spectrum C[k] = A[k] + i*B[k] via Hermitian extension
    (C[nz-k] = conj(A[k] - i*B[k])), ready for one complex inverse FFT
    whose real/imaginary parts are the two real pencils.
    """
    m = S.shape[pair_axis]
    SA = jax.lax.slice_in_dim(S, 0, m // 2, axis=pair_axis)
    SB = jax.lax.slice_in_dim(S, m // 2, m, axis=pair_axis)
    if folded:
        if use_pallas and S.dtype == jnp.complex64:
            return _hermitian_extend_pallas(SA, SB, nz)
        # bin 0 carries (DC, Nyquist) of each spectrum in (real, imag)
        a0, b0 = SA[..., 0], SB[..., 0]
        c0 = jax.lax.complex(jnp.real(a0), jnp.real(b0))      # A[0] + i B[0]
        cn = jax.lax.complex(jnp.imag(a0), jnp.imag(b0))      # A[ny] + i B[ny]
        body = SA[..., 1:] + 1j * SB[..., 1:]                 # bins 1..nz/2-1
        tail = jnp.flip(jnp.conj(SA[..., 1:] - 1j * SB[..., 1:]), -1)
        return jnp.concatenate(
            [c0[..., None], body, cn[..., None], tail], axis=-1)
    # DC (and, for even nz, Nyquist) bins of a real transform are real;
    # keep only their real parts — numpy's irfft applies exactly this
    # projection, and it is the identity for valid real-field spectra.
    # Mixing in the imaginary parts via SA + i*SB would leak each
    # spectrum's anti-Hermitian content into the *other* pencil.
    nh = SA.shape[-1]
    c0 = jax.lax.complex(jnp.real(SA[..., 0]), jnp.real(SB[..., 0]))
    parts = [c0[..., None]]
    has_nyq = nz % 2 == 0 and nh - 1 == nz // 2
    body_hi = nh - 1 if has_nyq else nh
    parts.append(SA[..., 1:body_hi] + 1j * SB[..., 1:body_hi])
    if has_nyq:
        cn = jax.lax.complex(jnp.real(SA[..., -1]), jnp.real(SB[..., -1]))
        parts.append(cn[..., None])
    ntail = nz - nh
    t = SA[..., 1:1 + ntail] - 1j * SB[..., 1:1 + ntail]
    parts.append(jnp.flip(jnp.conj(t), -1))
    return jnp.concatenate(parts, axis=-1)


def split_pairs(c: jax.Array, pair_axis: int) -> jax.Array:
    """Complex block -> real block, doubled along ``pair_axis``.

    Inverse of :func:`pack_two`: the real parts are the first-half
    pencils, the imaginary parts the second half.
    """
    return jnp.concatenate([jnp.real(c), jnp.imag(c)], axis=pair_axis)


# ---------------------------------------------------------------------------
# Pallas dispatch: flatten to (rows, bins) f32 planes, run the fused
# kernel, restore shape/dtype.  complex64 only (kernels are f32-plane
# kernels, matching kernels/spectral_scale.py).
# ---------------------------------------------------------------------------

def _unpack_fold_pallas(C: jax.Array, pair_axis: int) -> jax.Array:
    from repro.kernels import hermitian
    n = C.shape[-1]
    rows = math.prod(C.shape[:-1])
    cr = jnp.real(C).reshape(rows, n)
    ci = jnp.imag(C).reshape(rows, n)
    ar, ai, br, bi = hermitian.unpack_two_for_one_planes(cr, ci)
    half = C.shape[:-1] + (n // 2,)
    A = jax.lax.complex(ar, ai).reshape(half)
    B = jax.lax.complex(br, bi).reshape(half)
    return jnp.concatenate([A, B], axis=pair_axis)


def _hermitian_extend_pallas(SA: jax.Array, SB: jax.Array, nz: int) -> jax.Array:
    from repro.kernels import hermitian
    nz2 = SA.shape[-1]
    rows = math.prod(SA.shape[:-1])
    planes = [jnp.real(SA), jnp.imag(SA), jnp.real(SB), jnp.imag(SB)]
    planes = [p.reshape(rows, nz2) for p in planes]
    cr, ci = hermitian.hermitian_extend_planes(*planes)
    return jax.lax.complex(cr, ci).reshape(SA.shape[:-1] + (nz,))
