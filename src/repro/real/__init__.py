"""repro.real — first-class real-to-complex / complex-to-real transforms.

CROFT lists r2c/c2r as future work (§8); P3DFFT (arXiv:1905.02803) and
AccFFT (arXiv:1506.07933) treat real transforms as a native problem
class with a Hermitian-halved spectrum.  This subsystem does the same
for the JAX/XLA port, with two strategies:

  "packed"  the two-for-one trick (``packing.py``): two real z-pencils
            share one complex z transform, the spectrum is carried as
            exactly Nz/2 shard-aligned complex bins (Nyquist folded
            into DC), and every transpose/FFT stage after the first
            moves/computes half of what the c2c pipeline would
            (``pipeline.py``).  Pallas kernels for the hot unpack /
            Hermitian-extend steps live in ``repro.kernels.hermitian``.
  "embed"   cast real -> complex, run c2c, keep the non-redundant half
            (``repro.core.rfft``).  2x first-stage bandwidth waste, but
            valid for every decomposition/shape — it is the fallback
            and the numerical oracle for the packed path.

``resolve_strategy`` picks between them ("auto"); the autotuner treats
the choice as a search dimension (``repro.tuning`` with
``problem="r2c"``), and ``Croft3D(..., problem="r2c")`` /
``Croft3D.tuned(..., problem="r2c")`` expose planned real transforms.

Public entry points: ``repro.core.rfft.rfft3d/irfft3d(strategy=...)``.
"""

from __future__ import annotations

import math
from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core import local_fft
from repro.core.decomposition import Decomposition
from repro.core.distributed import FFTOptions, _norm_scale
from repro.real import packing
from repro.real.pipeline import (build_packed_forward, build_packed_inverse,
                                 constrain_sharding, packed_irfft3d,
                                 packed_rfft3d, packed_unsupported_reason,
                                 real_input_spec, unfold_dc_plane,
                                 fold_dc_plane)

STRATEGIES = ("auto", "packed", "embed")


def _choose_pair_axis(nx: int, ny: int) -> Optional[int]:
    """Axis to pair z-pencils along on a single device: prefer y (keeps
    x contiguous for the later transforms), fall back to x."""
    if ny % 2 == 0:
        return -2
    if nx % 2 == 0:
        return -3
    return None


def packed_local_reason(shape: Sequence[int]) -> Optional[str]:
    """None if the single-device packed path supports ``shape``."""
    nx, ny = shape[-3], shape[-2]
    if _choose_pair_axis(nx, ny) is None:
        return (f"no even axis to pair z-pencils along (Nx={nx}, Ny={ny} "
                "both odd)")
    return None


def local_rfft3d_packed(x: jax.Array, opts: Optional[FFTOptions] = None,
                        norm: Optional[str] = None) -> jax.Array:
    """Single-device packed r2c: real (..., Nx, Ny, Nz) -> (..., Nx, Ny, Nh).

    Works for odd Nz too (the fold-free two-for-one keeps all Nh bins —
    there is no shard alignment to preserve on one device).
    """
    if opts is None:
        opts = FFTOptions()
    nx, ny, nz = x.shape[-3], x.shape[-2], x.shape[-1]
    reason = packed_local_reason(x.shape)
    if reason is not None:
        raise ValueError(f"packed r2c unsupported here: {reason}")
    pair_axis = _choose_pair_axis(nx, ny)
    fold = nz % 2 == 0  # odd Nz has no Nyquist bin; carry all Nh bins
    c = packing.pack_two(x, pair_axis)
    C = local_fft.fft_1d(c, -1, -1, impl=opts.stage_impl(0),
                         plan_cache=opts.plan_cache)
    S = packing.unpack_two(C, pair_axis, nh=nz // 2 + 1, fold=fold,
                           use_pallas=opts.stage_impl(0) == "pallas")
    S = local_fft.fft_1d(S, -2, -1, impl=opts.stage_impl(1),
                         plan_cache=opts.plan_cache)
    S = local_fft.fft_1d(S, -3, -1, impl=opts.stage_impl(2),
                         plan_cache=opts.plan_cache)
    # the fold stays valid under the (linear) y/x transforms; unfold the
    # DC/Nyquist plane once, at the end, like the distributed pipeline
    y = unfold_dc_plane(S) if fold else S
    scale = _norm_scale((nx, ny, nz), -1, norm)
    return y if scale is None else y * jnp.asarray(scale, y.dtype)


def local_irfft3d_packed(y: jax.Array, nz: int,
                         opts: Optional[FFTOptions] = None,
                         norm: Optional[str] = None) -> jax.Array:
    """Single-device packed c2r: (..., Nx, Ny, Nh) -> real (..., Nx, Ny, Nz)."""
    if opts is None:
        opts = FFTOptions()
    nx, ny = y.shape[-3], y.shape[-2]
    reason = packed_local_reason((nx, ny, nz))
    if reason is not None:
        raise ValueError(f"packed c2r unsupported here: {reason}")
    pair_axis = _choose_pair_axis(nx, ny)
    fold = nz % 2 == 0
    t = fold_dc_plane(y, nz) if fold else y
    t = local_fft.fft_1d(t, -3, +1, impl=opts.stage_impl(0),
                         plan_cache=opts.plan_cache)
    t = local_fft.fft_1d(t, -2, +1, impl=opts.stage_impl(1),
                         plan_cache=opts.plan_cache)
    C = packing.repack_halves(t, pair_axis, nz, folded=fold,
                              use_pallas=opts.stage_impl(2) == "pallas")
    c = local_fft.fft_1d(C, -1, +1, impl=opts.stage_impl(2),
                         plan_cache=opts.plan_cache)
    x = packing.split_pairs(c, pair_axis)
    return x * jnp.asarray(_norm_scale((nx, ny, nz), +1, norm), x.dtype)


def unsupported_reason(shape: Sequence[int], mesh, decomp,
                       opts: Optional[FFTOptions]) -> Optional[str]:
    """Why the packed strategy cannot run this problem (None = it can)."""
    if mesh is None or math.prod(mesh.devices.shape) == 1:
        return packed_local_reason(shape)
    return packed_unsupported_reason(shape, decomp, mesh,
                                     opts or FFTOptions())


def resolve_strategy(strategy: Optional[str], shape: Sequence[int], mesh,
                     decomp, opts: Optional[FFTOptions]) -> str:
    """Resolve "auto" to "packed"/"embed"; validate explicit choices.

    Explicitly requesting "packed" on an unsupported problem raises with
    the reason; "auto" silently falls back to the embedding (which is
    always valid wherever the c2c pipeline is).
    """
    strategy = strategy or "auto"
    if strategy not in STRATEGIES:
        raise ValueError(f"strategy must be one of {STRATEGIES}, got {strategy!r}")
    if strategy == "embed":
        return "embed"
    reason = unsupported_reason(shape, mesh, decomp, opts)
    if reason is None:
        return "packed"
    if strategy == "packed":
        raise ValueError(f"packed r2c unsupported here: {reason}")
    return "embed"


__all__ = [
    "STRATEGIES", "build_packed_forward", "build_packed_inverse",
    "constrain_sharding", "fold_dc_plane", "local_irfft3d_packed",
    "local_rfft3d_packed", "packed_irfft3d", "packed_local_reason",
    "packed_rfft3d", "packed_unsupported_reason", "packing",
    "real_input_spec", "resolve_strategy", "unfold_dc_plane",
    "unsupported_reason",
]
