"""Pallas kernel: fused complex pointwise multiply-scale in frequency space.

y = alpha * x * h  — the inner op of spectral solvers (Poisson multiplier,
convolution filters) and of the 3-D inverse normalization.  Fusing the
complex product with the scalar keeps the frequency-domain round trip at one
HBM read + one write per plane instead of four.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _scale_kernel(xr_ref, xi_ref, hr_ref, hi_ref, or_ref, oi_ref, *,
                  alpha: float):
    xr = xr_ref[...] * alpha
    xi = xi_ref[...] * alpha
    hr = hr_ref[...]
    hi = hi_ref[...]
    or_ref[...] = xr * hr - xi * hi
    oi_ref[...] = xr * hi + xi * hr


def spectral_scale_planes(xr, xi, hr, hi, alpha: float = 1.0, *,
                          block_rows: int = 0, interpret: bool = True):
    """(B, N) f32 planes times (N,)-broadcast filter planes."""
    b, n = xr.shape
    if block_rows <= 0:
        block_rows = max(1, min(b, (4 * 1024 * 1024) // (6 * n * 4)))
        while b % block_rows:
            block_rows -= 1
    grid = (b // block_rows,)
    hr2 = hr.reshape(1, n)
    hi2 = hi.reshape(1, n)
    kernel = functools.partial(_scale_kernel, alpha=alpha)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, n), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, n), lambda i: (i, 0)),
            pl.BlockSpec((1, n), lambda i: (0, 0)),
            pl.BlockSpec((1, n), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_rows, n), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, n), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, n), jnp.float32),
            jax.ShapeDtypeStruct((b, n), jnp.float32),
        ],
        interpret=interpret,
    )(xr, xi, hr2, hi2)


def spectral_scale_planes_full(xr, xi, hr, hi, alpha: float = 1.0, *,
                               block_rows: int = 0, interpret: bool = True):
    """(B, N) f32 planes times same-shape (B, N) filter planes (the full
    3-D k-space filter of a spectral solver, flattened to rows)."""
    b, n = xr.shape
    if block_rows <= 0:
        block_rows = max(1, min(b, (4 * 1024 * 1024) // (6 * n * 4)))
        while b % block_rows:
            block_rows -= 1
    grid = (b // block_rows,)
    kernel = functools.partial(_scale_kernel, alpha=alpha)
    blk = pl.BlockSpec((block_rows, n), lambda i: (i, 0))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[blk, blk, blk, blk],
        out_specs=[blk, blk],
        out_shape=[
            jax.ShapeDtypeStruct((b, n), jnp.float32),
            jax.ShapeDtypeStruct((b, n), jnp.float32),
        ],
        interpret=interpret,
    )(xr, xi, hr, hi)


def spectral_scale(x: jax.Array, h: jax.Array, alpha: float = 1.0, *,
                   use_pallas: bool | None = None,
                   interpret: bool | None = None) -> jax.Array:
    """Fused ``alpha * x * h`` on complex arrays (the schedule-epilogue op).

    ``h`` must broadcast against ``x``.  On TPU (or ``use_pallas=True``)
    same-shape complex64 operands route through the Pallas plane kernel;
    everywhere else the plain jnp product is emitted — XLA fuses it into
    the surrounding jit, which is the point of attaching the multiply as
    a schedule epilogue instead of paying a second dispatch and an extra
    HBM round trip over the spectrum.
    """
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if use_pallas and x.dtype == jnp.complex64 and h.shape == x.shape:
        b, n = math.prod(x.shape[:-1]), x.shape[-1]
        xr = jnp.real(x).astype(jnp.float32).reshape(b, n)
        xi = jnp.imag(x).astype(jnp.float32).reshape(b, n)
        hr = jnp.real(h).astype(jnp.float32).reshape(b, n)
        hi = jnp.imag(h).astype(jnp.float32).reshape(b, n)
        yr, yi = spectral_scale_planes_full(xr, xi, hr, hi, alpha,
                                            interpret=interpret)
        return jax.lax.complex(yr, yi).reshape(x.shape)
    y = x * h
    if alpha != 1.0:
        y = y * jnp.asarray(alpha, y.dtype)
    return y
