"""Pallas TPU kernels for the FFT hot spots (validated in interpret mode).

fft_matmul      four-step (Bailey) batched 1-D FFT on the MXU
spectral_scale  fused frequency-domain complex multiply-scale
ops             jit'd complex-in/complex-out wrappers
ref             pure-jnp oracles for the test sweeps
"""

from repro.kernels.ops import fft_matmul_1d, spectral_scale_op

__all__ = ["fft_matmul_1d", "spectral_scale_op"]
