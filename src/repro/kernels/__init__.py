"""Pallas TPU kernels for the FFT hot spots (validated in interpret mode).

fft_matmul      four-step (Bailey) batched 1-D FFT on the MXU
spectral_scale  fused frequency-domain complex multiply-scale
hermitian       real-transform pack/unpack: fused two-for-one Hermitian
                split (r2c) and Hermitian extension (c2r) plane kernels
ops             jit'd complex-in/complex-out wrappers
ref             pure-jnp oracles for the test sweeps
"""

from repro.kernels.hermitian import (hermitian_extend_planes,
                                     unpack_two_for_one_planes)
from repro.kernels.ops import fft_matmul_1d, spectral_scale_op

__all__ = ["fft_matmul_1d", "hermitian_extend_planes", "spectral_scale_op",
           "unpack_two_for_one_planes"]
