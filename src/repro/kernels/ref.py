"""Pure-jnp oracles for every Pallas kernel in this package.

Each ``ref_*`` function has the same signature as the corresponding wrapper
in ``ops.py`` and is the ground truth the kernel sweeps assert against.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def ref_fft_1d(x: jax.Array, sign: int = -1) -> jax.Array:
    """Batched 1-D DFT along the last axis (complex in, complex out)."""
    return jnp.fft.fft(x) if sign == -1 else jnp.fft.fft(jnp.conj(x)).conj()


def ref_fft_1d_naive(x: np.ndarray, sign: int = -1) -> np.ndarray:
    """O(N^2) direct DFT — the independent oracle (never touches any FFT)."""
    n = x.shape[-1]
    w = np.exp(sign * 2j * np.pi * np.outer(np.arange(n), np.arange(n)) / n)
    return np.einsum("...n,nk->...k", x, w)


def ref_spectral_scale(x: jax.Array, h: jax.Array,
                       alpha: float = 1.0) -> jax.Array:
    """y = alpha * x * h with h broadcast over leading batch dims."""
    return (alpha * x) * h


def ref_stockham(x: jax.Array, sign: int = -1) -> jax.Array:
    return ref_fft_1d(x, sign)


def ref_flash_attention(q, k, v, causal=True, window=None, scale=None):
    """Oracle for the flash-attention kernel (GQA, causal/windowed)."""
    b, sq, h, d = q.shape
    _, sk, kvh, dv = v.shape
    g = h // kvh
    scale = scale if scale is not None else d ** -0.5
    k_rep = jnp.repeat(k, g, axis=2)
    v_rep = jnp.repeat(v, g, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32) * scale,
                   k_rep.astype(jnp.float32))
    qi = jnp.arange(sq)[:, None]
    ki = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask = mask & (ki <= qi)
    if window is not None:
        mask = mask & (qi - ki < window)
    s = jnp.where(mask[None, None], s, -2.0 ** 30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p,
                      v_rep.astype(jnp.float32)).astype(q.dtype)
