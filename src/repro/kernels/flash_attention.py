"""Pallas TPU kernel: fused causal/windowed GQA attention (flash-style).

The §Perf analysis shows every dense train cell is memory-bound on
attention-score round trips: the pure-XLA blockwise path streams the
(Sq x Skv) f32 scores through HBM several times per layer.  This kernel
keeps the whole online-softmax chain in VMEM: per (batch, q-head, q-block)
grid cell it loads one q block and the matching GQA kv head's K/V, loops
over kv chunks with running (m, l, acc), and writes only the (BQ, D)
output — one HBM read per operand, one write per result.

Forward only (serving + projection for training-fwd); the train path keeps
the XLA blockwise implementation whose backward is autodiff'd.
Validated in interpret mode against ``ref.ref_flash_attention``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -2.0 ** 30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, kv_chunk: int, causal: bool,
                  window, bq: int, scale: float):
    """One grid cell: q (BQ, D) vs full K/V (Skv, D) for its kv head."""
    qi = pl.program_id(2)
    skv, d = k_ref.shape[-2:]
    dv = v_ref.shape[-1]
    q = q_ref[...].reshape(bq, d).astype(jnp.float32) * scale
    k_all = k_ref[...].reshape(skv, d)
    v_all = v_ref[...].reshape(skv, dv)
    n_chunks = skv // kv_chunk

    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, 1), 0)

    def body(c, carry):
        m_prev, l_prev, acc = carry
        k = jax.lax.dynamic_slice_in_dim(
            k_all, c * kv_chunk, kv_chunk, 0).astype(jnp.float32)
        v = jax.lax.dynamic_slice_in_dim(
            v_all, c * kv_chunk, kv_chunk, 0).astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        k_pos = c * kv_chunk + jax.lax.broadcasted_iota(
            jnp.int32, (1, kv_chunk), 1)
        mask = jnp.ones((bq, kv_chunk), jnp.bool_)
        if causal:
            mask = mask & (k_pos <= q_pos)
        if window is not None:
            mask = mask & (q_pos - k_pos < window)
        s = jnp.where(mask, s, NEG_INF)
        m_cur = jnp.max(s, axis=1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_new = l_prev * alpha + jnp.sum(p, axis=1)
        acc = acc * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        return m_new, l_new, acc

    m0 = jnp.full((bq,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((bq,), jnp.float32)
    a0 = jnp.zeros((bq, dv), jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, n_chunks, body, (m0, l0, a0))
    out = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)
    o_ref[...] = out.reshape(o_ref.shape)


def flash_attention(q, k, v, *, causal: bool = True, window=None,
                    scale=None, q_block: int = 128, kv_chunk: int = 128,
                    interpret: bool = True):
    """q (B, Sq, H, D) · k,v (B, Skv, KV, D) -> (B, Sq, H, Dv).

    H % KV == 0 (GQA);  Sq % q_block == 0;  Skv % kv_chunk == 0.
    """
    b, sq, h, d = q.shape
    _, skv, kvh, dv = v.shape
    assert h % kvh == 0 and sq % q_block == 0 and skv % kv_chunk == 0
    g = h // kvh
    scale = scale if scale is not None else d ** -0.5

    # layout: heads to the front so each grid cell reads contiguous slabs
    qt = jnp.moveaxis(q, 2, 1)      # (B, H, Sq, D)
    kt = jnp.moveaxis(k, 2, 1)      # (B, KV, Skv, D)
    vt = jnp.moveaxis(v, 2, 1)

    kernel = functools.partial(
        _flash_kernel, kv_chunk=kv_chunk, causal=causal, window=window,
        bq=q_block, scale=scale)
    out = pl.pallas_call(
        kernel,
        grid=(b, h, sq // q_block),
        in_specs=[
            pl.BlockSpec((1, 1, q_block, d),
                         lambda bi, hi, qi: (bi, hi, qi, 0)),
            pl.BlockSpec((1, 1, skv, d),
                         lambda bi, hi, qi, g=g: (bi, hi // g, 0, 0)),
            pl.BlockSpec((1, 1, skv, dv),
                         lambda bi, hi, qi, g=g: (bi, hi // g, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, q_block, dv),
                               lambda bi, hi, qi: (bi, hi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, sq, dv), q.dtype),
        interpret=interpret,
    )(qt[:, :, :, :], kt, vt)
    return jnp.moveaxis(out, 1, 2)
