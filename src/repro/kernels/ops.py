"""jit'd public wrappers around the Pallas kernels.

Complex arrays are split into float32 planes at this boundary; callers see
normal complex64 in/out.  ``interpret=True`` on CPU (the validation mode);
on a real TPU backend the same calls lower to Mosaic.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import fft_matmul, spectral_scale


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("sign", "interpret"))
def fft_matmul_1d(x: jax.Array, sign: int = -1, interpret: bool | None = None):
    """Batched 1-D FFT along the last axis of a complex64 array (any rank)."""
    if interpret is None:
        interpret = not _on_tpu()
    shape = x.shape
    n = shape[-1]
    b = 1
    for s in shape[:-1]:
        b *= s
    xr = jnp.real(x).astype(jnp.float32).reshape(b, n)
    xi = jnp.imag(x).astype(jnp.float32).reshape(b, n)
    yr, yi = fft_matmul.fft4step_planes(xr, xi, sign, interpret=interpret)
    return jax.lax.complex(yr, yi).reshape(shape)


@functools.partial(jax.jit, static_argnames=("alpha", "interpret"))
def spectral_scale_op(x: jax.Array, h: jax.Array, alpha: float = 1.0,
                      interpret: bool | None = None):
    """alpha * x * h with h of shape (N,) broadcast against x (..., N)."""
    if interpret is None:
        interpret = not _on_tpu()
    shape = x.shape
    n = shape[-1]
    b = 1
    for s in shape[:-1]:
        b *= s
    xr = jnp.real(x).astype(jnp.float32).reshape(b, n)
    xi = jnp.imag(x).astype(jnp.float32).reshape(b, n)
    hr = jnp.real(h).astype(jnp.float32)
    hi = jnp.imag(h).astype(jnp.float32)
    yr, yi = spectral_scale.spectral_scale_planes(xr, xi, hr, hi, alpha,
                                                  interpret=interpret)
    return jax.lax.complex(yr, yi).reshape(shape)
