"""Pallas pack/unpack kernels for the ring/pairwise global transposes.

A P-rank ring (or pairwise) transpose moves P contiguous blocks of the
split axis to P peers and reassembles P received blocks along the concat
axis.  Rank r's send block for round s is the slice at ``(r + s) % P``
— a *rotated* block gather — and the received pieces land rotated by
``r`` the other way.  The executor used to express both sides as a
``dynamic_slice`` plus a full-size ``dynamic_update_slice`` per round:
P-1 full passes over the block just to shuffle it.

Both sides are really one data movement each: a cyclic rotation of the
P row-blocks by a rank-dependent shift.  :func:`rotate_blocks` does that
rotation in a single tiled pass — the Pallas kernel reads row-block
``(i + shift) % P`` and writes row-block ``i``, with the traced shift
(``jax.lax.axis_index``) carried as a scalar operand, so pack and unpack
each cost exactly one read + one write of the block:

  pack    rotate_blocks(x, split_axis, shift=idx)    then P static slices
  unpack  concatenate received pieces (static order), then
          rotate_blocks(y, concat_axis, shift=-idx)

Kernels follow the repo convention (``kernels/hermitian.py``): f32 plane
kernels, row-blocked grid, compiled on TPU and interpret mode elsewhere;
complex64 rides as separate real/imag planes.  Off-TPU the same data
movements lower to the forms XLA CPU/GPU copy fastest (raced
head-to-head on the CI host): a static-slice ``lax.switch`` pack, an
in-place ``dynamic_update_slice`` unpack, and a doubled-buffer dynamic
slice for :func:`rotate_blocks` itself — never ``jnp.roll``, whose
traced-shift form lowers to a gather.
"""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _resolve_interpret(interpret: Optional[bool]) -> bool:
    if interpret is not None:
        return interpret
    from repro.kernels.ops import _on_tpu
    return not _on_tpu()


def _rotate_kernel(shift_ref, xr_ref, xi_ref, or_ref, oi_ref):
    """Pure block copy: the rotation lives entirely in the index maps."""
    del shift_ref
    or_ref[...] = xr_ref[...]
    oi_ref[...] = xi_ref[...]


def rotate_block_rows_planes(xr: jax.Array, xi: jax.Array, shift: jax.Array,
                             n_blocks: int, *,
                             interpret: Optional[bool] = None):
    """(R, M) f32 planes -> planes with the ``n_blocks`` row-blocks
    cyclically rotated by ``shift`` blocks (out block i = in block
    (i + shift) % n_blocks).  ``shift`` is a shape-(1,) int32 array and
    may be traced (the rank index inside ``shard_map``).

    The shift rides as a *scalar-prefetch* operand consumed by the input
    index map — grid step i simply fetches block ``(i + shift) %
    n_blocks`` — so the kernel body is a pure tiled copy with no
    data-dependent indexing (the Mosaic-friendly form: the scalar lands
    in SMEM and only block scheduling depends on it)."""
    interpret = _resolve_interpret(interpret)
    r, m = xr.shape
    if r % n_blocks:
        raise ValueError(f"{r} rows not divisible into {n_blocks} blocks")
    block_rows = r // n_blocks
    from jax.experimental.pallas import tpu as pltpu
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_blocks,),
        in_specs=[pl.BlockSpec(
            (block_rows, m),
            lambda i, s_ref: ((i + s_ref[0]) % n_blocks, 0))] * 2,
        out_specs=[pl.BlockSpec((block_rows, m),
                                lambda i, s_ref: (i, 0))] * 2,
    )
    return pl.pallas_call(
        _rotate_kernel,
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((r, m), jnp.float32)] * 2,
        interpret=interpret,
    )(shift, xr, xi)


def rotate_blocks(x: jax.Array, axis: int, shift, n_blocks: int, *,
                  use_pallas: Optional[bool] = None,
                  interpret: Optional[bool] = None) -> jax.Array:
    """Cyclically rotate the ``n_blocks`` equal blocks of ``x`` along
    ``axis`` by ``shift`` blocks (block i of the result is block
    (i + shift) % n_blocks of the input).  ``shift`` may be traced.

    This is the fused pack/unpack primitive of the ring and pairwise
    transposes; ``use_pallas=None`` follows the repo convention (Pallas
    on TPU, plain jnp elsewhere — the fallback is a doubled-buffer
    dynamic slice, all contiguous copies).
    """
    if n_blocks == 1:
        return x
    extent = x.shape[axis]
    if extent % n_blocks:
        raise ValueError(
            f"axis {axis} extent {extent} not divisible by {n_blocks}")
    block = extent // n_blocks
    if use_pallas is None:
        from repro.kernels.ops import _on_tpu
        use_pallas = _on_tpu()
    if not use_pallas or x.dtype != jnp.complex64:
        # NOT jnp.roll: a *traced* shift makes roll lower to a gather
        # over the axis (index arithmetic per element).  Doubling the
        # array and taking one dynamic slice keeps every byte moved by
        # contiguous memcpy — 3 passes of plain copies beat 1 gather
        # pass by a wide margin on every backend.
        start = jnp.mod(jnp.asarray(shift, jnp.int32), n_blocks) * block
        doubled = jnp.concatenate([x, x], axis=axis)
        return jax.lax.dynamic_slice_in_dim(doubled, start, extent, axis)
    moved = jnp.moveaxis(x, axis, 0)
    rows = moved.shape[0]
    cols = math.prod(moved.shape[1:])
    xr = jnp.real(moved).reshape(rows, cols)
    xi = jnp.imag(moved).reshape(rows, cols)
    s = jnp.mod(jnp.asarray(shift, jnp.int32), n_blocks).reshape(1)
    yr, yi = rotate_block_rows_planes(xr, xi, s, n_blocks,
                                      interpret=interpret)
    y = jax.lax.complex(yr, yi).reshape(moved.shape)
    return jnp.moveaxis(y, 0, axis)


def unpack_pieces(pieces: list, axis: int, shift, *,
                  use_pallas: Optional[bool] = None) -> jax.Array:
    """The ring unpack: reassemble received pieces with block i of the
    result = ``pieces[(i + shift) % p]`` (``shift`` may be traced).

    On TPU: one concatenate + the fused :func:`rotate_blocks` pass.
    Elsewhere each piece lands with one ``dynamic_update_slice`` —
    placements the compiler performs in place (one total pass over the
    output), and unlike the pairwise emulation's chain the *ppermutes
    feeding them* stay mutually independent, so placement order never
    serializes the communication rounds.  (A p-way static-concat
    ``lax.switch`` and a doubled-buffer dynamic slice were raced
    head-to-head against this form on the CI host class; the in-place
    placement wins.)
    """
    p = len(pieces)
    if p == 1:
        return pieces[0]
    if use_pallas is None:
        from repro.kernels.ops import _on_tpu
        use_pallas = _on_tpu()
    if use_pallas and pieces[0].dtype == jnp.complex64:
        return rotate_blocks(jnp.concatenate(pieces, axis=axis), axis,
                             shift, p, use_pallas=use_pallas)
    block = pieces[0].shape[axis]
    out_shape = list(pieces[0].shape)
    out_shape[axis] = p * block
    out = jnp.zeros(out_shape, pieces[0].dtype)
    # pieces[m] is block (m - shift) % p of the result
    starts = jnp.mod(jnp.arange(p, dtype=jnp.int32)
                     - jnp.asarray(shift, jnp.int32), p) * block
    for m, piece in enumerate(pieces):
        out = jax.lax.dynamic_update_slice_in_dim(out, piece, starts[m], axis)
    return out


def pack_pieces(blk: jax.Array, axis: int, idx, n_blocks: int, *,
                use_pallas: Optional[bool] = None) -> list:
    """The ring/pairwise send pack: the ``n_blocks`` blocks of ``axis``
    as a list ordered by round (piece s is the block bound for rank
    ``(idx + s) % n_blocks``).

    On TPU this is the fused :func:`rotate_blocks` pass followed by free
    static slices; elsewhere a p-way ``lax.switch`` over static slice
    sets — the rank index takes only p values, so the compiler sees
    plain strided views (exactly one total pass over the block, no
    full-size intermediate, no dynamic indexing).
    """
    extent = blk.shape[axis]
    if extent % n_blocks:
        raise ValueError(
            f"axis {axis} extent {extent} not divisible by {n_blocks}")
    block = extent // n_blocks
    if use_pallas is None:
        from repro.kernels.ops import _on_tpu
        use_pallas = _on_tpu()
    if use_pallas and blk.dtype == jnp.complex64:
        packed = rotate_blocks(blk, axis, idx, n_blocks,
                               use_pallas=use_pallas)
        return jnp.split(packed, n_blocks, axis=axis)
    # p-way branch over static slice sets (see unpack_pieces): the
    # compiler sees plain strided views, not p dynamic slices
    p = n_blocks

    def cut(b, r):
        return tuple(
            jax.lax.slice_in_dim(b, ((r + s) % p) * block,
                                 ((r + s) % p + 1) * block, axis=axis)
            for s in range(p))

    branches = [(lambda b, r=r: cut(b, r)) for r in range(p)]
    return list(jax.lax.switch(jnp.mod(jnp.asarray(idx, jnp.int32), p),
                               branches, blk))
