"""Pallas TPU kernel: batched 1-D FFT via the four-step (Bailey) matmul
factorization — the MXU-native replacement for FFTW's butterfly kernels
(DESIGN.md §2, hardware adaptation).

Layout decisions:
  * complex data travels as separate float32 real/imag planes (TPU Pallas has
    no complex registers);
  * each DFT stage is ONE real matmul against a stacked-real matrix
      [xr xi] @ [[Wr, Wi], [-Wi, Wr]] = [Re(xW), Im(xW)]
    so with the default radix 64 the stage-1 operand is (rows, 128) @
    (128, 128) — exactly an MXU tile;
  * the batch dimension is tiled into VMEM blocks via BlockSpec; DFT
    matrices/twiddles are small (<=128x128 f32) and loaded whole per block.

VMEM budget per block (N = n1*n2 points, Bb batch rows):
  2 input planes + 2 output planes + ~4 intermediates ~= 8 * Bb * N * 4 bytes;
  Bb is chosen in ops.py so this stays under ~4 MiB.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import plan as plan_lib


def _complex_mul(ar, ai, br, bi):
    return ar * br - ai * bi, ar * bi + ai * br


def _fft4step_kernel(xr_ref, xi_ref, w1_ref, w2_ref, twr_ref, twi_ref,
                     or_ref, oi_ref, *, n1: int, n2: int):
    """One batch block: (Bb, N) real/imag planes -> transformed planes."""
    bb = xr_ref.shape[0]
    n = n1 * n2
    xr = xr_ref[...]
    xi = xi_ref[...]

    if n2 == 1:
        # single-matmul DFT: (Bb, 2N) @ (2N, 2N)
        xs = jnp.concatenate([xr, xi], axis=1)
        ys = jnp.dot(xs, w1_ref[...], preferred_element_type=jnp.float32)
        or_ref[...] = ys[:, :n]
        oi_ref[...] = ys[:, n:]
        return

    # stage 1: DFT over j1.  x[b, j1*n2 + j2] -> rows (b, j2), cols j1
    xr3 = xr.reshape(bb, n1, n2).transpose(0, 2, 1).reshape(bb * n2, n1)
    xi3 = xi.reshape(bb, n1, n2).transpose(0, 2, 1).reshape(bb * n2, n1)
    xs = jnp.concatenate([xr3, xi3], axis=1)              # (Bb*n2, 2*n1)
    ys = jnp.dot(xs, w1_ref[...], preferred_element_type=jnp.float32)
    yr = ys[:, :n1].reshape(bb, n2, n1)                   # [b, j2, k1]
    yi = ys[:, n1:].reshape(bb, n2, n1)

    # stage 2: twiddles T[j2, k1] = exp(sign*2πi*k1*j2/N)
    zr, zi = _complex_mul(yr, yi, twr_ref[...], twi_ref[...])

    # stage 3: DFT over j2.  rows (b, k1), cols j2
    zr2 = zr.transpose(0, 2, 1).reshape(bb * n1, n2)
    zi2 = zi.transpose(0, 2, 1).reshape(bb * n1, n2)
    zs = jnp.concatenate([zr2, zi2], axis=1)              # (Bb*n1, 2*n2)
    ws = jnp.dot(zs, w2_ref[...], preferred_element_type=jnp.float32)
    wr = ws[:, :n2].reshape(bb, n1, n2)                   # [b, k1, k2]
    wi = ws[:, n2:].reshape(bb, n1, n2)

    # output index k = k1 + n1*k2  ->  lay out (b, k2, k1), ravel
    or_ref[...] = wr.transpose(0, 2, 1).reshape(bb, n)
    oi_ref[...] = wi.transpose(0, 2, 1).reshape(bb, n)


def fft4step_planes(xr: jax.Array, xi: jax.Array, sign: int = -1, *,
                    block_rows: int = 0, interpret: bool = True) -> tuple:
    """Batched FFT over float32 planes of shape (B, N); N = n1*n2 pow-2,
    N <= MAX_TWO_LEVEL.  Returns (yr, yi).
    """
    b, n = xr.shape
    plan = plan_lib.make_plan(n, sign, "complex64")
    if plan.n2 > plan_lib.MAX_RADIX:
        raise ValueError(
            f"N={n} exceeds the two-level kernel limit "
            f"{plan_lib.MAX_TWO_LEVEL}; use the jnp six-step path")
    n1, n2 = plan.n1, plan.n2

    if block_rows <= 0:
        # keep ~8 live (Bb, N) f32 planes under ~4 MiB of VMEM
        block_rows = max(1, min(b, (4 * 1024 * 1024) // (8 * n * 4)))
        while b % block_rows:
            block_rows -= 1
    grid = (b // block_rows,)

    w1 = jnp.asarray(plan.w1_stacked)                     # (2n1, 2n1)
    if n2 == 1:
        w2 = jnp.zeros((2, 2), jnp.float32)               # placeholder
        twr = jnp.zeros((1, 1), jnp.float32)
        twi = jnp.zeros((1, 1), jnp.float32)
    else:
        w2 = jnp.asarray(plan.w2_stacked)                 # (2n2, 2n2)
        twr = jnp.asarray(plan.tw.real.astype(jnp.float32))   # (n2, n1)
        twi = jnp.asarray(plan.tw.imag.astype(jnp.float32))

    const = lambda shape: pl.BlockSpec(shape, lambda i: (0, 0))
    kernel = functools.partial(_fft4step_kernel, n1=n1, n2=n2)
    yr, yi = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, n), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, n), lambda i: (i, 0)),
            const(w1.shape), const(w2.shape),
            const(twr.shape), const(twi.shape),
        ],
        out_specs=[
            pl.BlockSpec((block_rows, n), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, n), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, n), jnp.float32),
            jax.ShapeDtypeStruct((b, n), jnp.float32),
        ],
        interpret=interpret,
    )(xr, xi, w1, w2, twr, twi)
    return yr, yi
