"""Pallas kernels for the real-transform hot steps (repro.real).

Two fused plane kernels in the style of ``spectral_scale.py`` (f32
real/imag planes, row-blocked grid, interpret mode on CPU):

unpack_two_for_one_planes   C = FFT(a + i*b) of two packed real pencils
                            -> the two half spectra A, B via Hermitian
                            symmetry, with the (real) Nyquist bin folded
                            into the (real) DC bin's imaginary slot —
                            one HBM read of C, one write of A and B,
                            instead of the 6+ passes the unfused
                            flip/conj/axpy chain costs.

hermitian_extend_planes     the exact inverse: folded half spectra A, B
                            -> the full length-n packed spectrum
                            C[k] = A[k] + i*B[k], C[n-k] = conj(A[k] - i*B[k]),
                            ready for one complex inverse FFT.

Rows are independent z-lines (the caller flattens (..., pairs) into the
row axis); each block sees full rows, so the frequency reversal
k -> (-k) mod n stays inside the block.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _resolve_interpret(interpret: Optional[bool]) -> bool:
    """Repo convention (kernels/ops.py): compiled on TPU, interpreter
    elsewhere, unless the caller forces it."""
    if interpret is not None:
        return interpret
    from repro.kernels.ops import _on_tpu
    return not _on_tpu()


def _pick_block_rows(b: int, n: int, n_planes: int) -> int:
    """Largest divisor of ``b`` keeping ~n_planes f32 planes under ~4 MB."""
    block = max(1, min(b, (4 * 1024 * 1024) // (n_planes * n * 4)))
    while b % block:
        block -= 1
    return block


def _unpack_kernel(cr_ref, ci_ref, ar_ref, ai_ref, br_ref, bi_ref):
    cr = cr_ref[...]
    ci = ci_ref[...]
    # C[(-k) mod n]: [0, n-1, ..., 1]
    rr = jnp.roll(jnp.flip(cr, -1), 1, -1)
    ri = jnp.roll(jnp.flip(ci, -1), 1, -1)
    n = cr.shape[-1]
    nz2 = n // 2
    a_r = 0.5 * (cr + rr)          # A = (C + conj(Crev)) / 2
    a_i = 0.5 * (ci - ri)
    b_r = 0.5 * (ci + ri)          # B = (C - conj(Crev)) / 2i
    b_i = -0.5 * (cr - rr)
    # fold: bin 0 becomes (DC, Nyquist) — both bins of a real transform
    # are real, so their real parts carry everything
    ar_ref[...] = a_r[..., :nz2]
    ai_ref[...] = jnp.concatenate([a_r[..., nz2:nz2 + 1], a_i[..., 1:nz2]], -1)
    br_ref[...] = b_r[..., :nz2]
    bi_ref[...] = jnp.concatenate([b_r[..., nz2:nz2 + 1], b_i[..., 1:nz2]], -1)


def unpack_two_for_one_planes(cr, ci, *, block_rows: int = 0,
                              interpret: Optional[bool] = None):
    """(B, n) f32 planes of C -> four (B, n//2) planes (Ar, Ai, Br, Bi)."""
    interpret = _resolve_interpret(interpret)
    b, n = cr.shape
    if n % 2:
        raise ValueError(f"two-for-one fold needs even n, got {n}")
    if block_rows <= 0:
        block_rows = _pick_block_rows(b, n, 6)
    nz2 = n // 2
    grid = (b // block_rows,)
    in_spec = pl.BlockSpec((block_rows, n), lambda i: (i, 0))
    out_spec = pl.BlockSpec((block_rows, nz2), lambda i: (i, 0))
    return pl.pallas_call(
        _unpack_kernel,
        grid=grid,
        in_specs=[in_spec, in_spec],
        out_specs=[out_spec] * 4,
        out_shape=[jax.ShapeDtypeStruct((b, nz2), jnp.float32)] * 4,
        interpret=interpret,
    )(cr, ci)


def _extend_kernel(sar_ref, sai_ref, sbr_ref, sbi_ref, cr_ref, ci_ref):
    sar = sar_ref[...]
    sai = sai_ref[...]
    sbr = sbr_ref[...]
    sbi = sbi_ref[...]
    # C[0] = A[0] + i B[0];  C[nyq] = A[nyq] + i B[nyq]  (folded in bin 0)
    c0_r, c0_i = sar[..., :1], sbr[..., :1]
    cn_r, cn_i = sai[..., :1], sbi[..., :1]
    # bins 1..nz2-1:  C[k] = A[k] + i B[k]
    body_r = sar[..., 1:] - sbi[..., 1:]
    body_i = sai[..., 1:] + sbr[..., 1:]
    # bins nz2+1..n-1:  C[n-k] = conj(A[k] - i B[k])
    tail_r = jnp.flip(sar[..., 1:] + sbi[..., 1:], -1)
    tail_i = jnp.flip(-(sai[..., 1:] - sbr[..., 1:]), -1)
    cr_ref[...] = jnp.concatenate([c0_r, body_r, cn_r, tail_r], -1)
    ci_ref[...] = jnp.concatenate([c0_i, body_i, cn_i, tail_i], -1)


def hermitian_extend_planes(sar, sai, sbr, sbi, *, block_rows: int = 0,
                            interpret: Optional[bool] = None):
    """Four (B, nz2) folded half-spectrum planes -> (B, 2*nz2) C planes."""
    interpret = _resolve_interpret(interpret)
    b, nz2 = sar.shape
    n = 2 * nz2
    if block_rows <= 0:
        block_rows = _pick_block_rows(b, n, 6)
    grid = (b // block_rows,)
    in_spec = pl.BlockSpec((block_rows, nz2), lambda i: (i, 0))
    out_spec = pl.BlockSpec((block_rows, n), lambda i: (i, 0))
    return pl.pallas_call(
        _extend_kernel,
        grid=grid,
        in_specs=[in_spec] * 4,
        out_specs=[out_spec] * 2,
        out_shape=[jax.ShapeDtypeStruct((b, n), jnp.float32)] * 2,
        interpret=interpret,
    )(sar, sai, sbr, sbi)
