import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import: jax locks the device count on first
# init.  512 placeholder host devices back the production meshes below.

"""Multi-pod dry-run: prove the distribution config is coherent.

For every (architecture x input shape) cell and for the paper's FFT grids,
``jax.jit(step).lower(**input_specs).compile()`` must succeed on the
single-pod 16x16 mesh AND the 2x16x16 multi-pod mesh.  The compiled
artifact yields ``memory_analysis()`` (fits?) and ``cost_analysis()``
(FLOPs/bytes), and its HLO is parsed for collective bytes — the inputs to
EXPERIMENTS.md §Dry-run and §Roofline.

Results are cached as JSON per cell under ``--out`` (re-runs skip finished
cells), because a 512-partition compile of a 60-layer MoE on one CPU core
is minutes, not seconds.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch yi-34b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --mesh both --fft
  PYTHONPATH=src python -m repro.launch.dryrun --all
"""

import argparse
import json
import math
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import (ARCHS, ASSIGNED, FFT_SHAPES, SHAPES, get_config,
                           shape_supported)
from repro.configs.croft_fft import CroftConfig, croft_1024, croft_128, croft_4096
from repro.core import Croft3D, Decomposition
from repro.core.distributed import FFTOptions
from repro.launch import roofline as rl
from repro.launch.mesh import fft_mesh_axes, make_production_mesh
from repro.models import model as model_lib
from repro.parallel import sharding as sh
from repro.train import train_step as ts
from repro.train.optimizer import OptConfig, init_opt_state


def _sds(shape, dtype, mesh=None, spec=None):
    sharding = NamedSharding(mesh, spec) if mesh is not None else None
    return jax.ShapeDtypeStruct(shape, dtype, sharding=sharding)


def _tree_sds(abstract_tree, spec_tree, mesh):
    return jax.tree.map(
        lambda a, s: _sds(a.shape, a.dtype, mesh, s), abstract_tree,
        spec_tree, is_leaf=lambda x: isinstance(x, P))


def input_specs(cfg, shape, mesh, multi_pod: bool):
    """ShapeDtypeStruct stand-ins for every model input of one cell:
    weak-type-correct, shardable, zero allocation."""
    axes = sh.MeshAxes(pod="pod" if multi_pod else None)
    dp = axes.dp_axes
    dp_size = math.prod(mesh.shape[a] for a in dp)
    gb = shape.global_batch
    batch_spec = dp if gb % dp_size == 0 else None
    if isinstance(batch_spec, tuple) and len(batch_spec) == 1:
        batch_spec = batch_spec[0]

    out = {}
    if shape.kind == "train":
        out["tokens"] = _sds((gb, shape.seq_len + 1), jnp.int32, mesh,
                             P(batch_spec, None))
    elif shape.kind == "prefill":
        out["tokens"] = _sds((gb, shape.seq_len), jnp.int32, mesh,
                             P(batch_spec, None))
    else:  # decode
        out["tokens"] = _sds((gb, 1), jnp.int32, mesh, P(batch_spec, None))
    if cfg.encoder is not None:
        out["frames"] = _sds((gb, cfg.n_frontend_tokens, cfg.d_model),
                             jnp.float32, mesh, P(batch_spec, None, None))
    elif cfg.frontend == "vision":
        out["prefix_embeds"] = _sds(
            (gb, cfg.n_frontend_tokens, cfg.d_model), jnp.float32, mesh,
            P(batch_spec, None, None))
    return out, batch_spec


def model_flops_for(cfg, shape) -> float:
    n = cfg.param_count()
    n_active = cfg.active_param_count()
    tokens = shape.global_batch * shape.seq_len
    if shape.kind == "train":
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        return 2.0 * n_active * tokens
    return 2.0 * n_active * shape.global_batch  # decode: one token/seq


# --------------------------------------------------------------------------
# LM cells
# --------------------------------------------------------------------------

def lower_lm_cell(arch: str, shape_name: str, multi_pod: bool,
                  kv_block: int = 0, opts: dict | None = None) -> dict:
    opts = opts or {}
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if kv_block <= 0:
        # §Perf: single-block attention for 4k training (no scan stacking);
        # prefill keeps 2k blocks (score memory scales Sq_loc x kv_block)
        kv_block = shape.seq_len if shape.kind == "train" else 2048
    ok, why = shape_supported(cfg, shape)
    if not ok:
        return {"status": "skip", "reason": why}
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = math.prod(mesh.devices.shape)
    axes = sh.MeshAxes(pod="pod" if multi_pod else None)

    abstract_params = jax.eval_shape(
        lambda k: model_lib.init_params(k, cfg), jax.random.key(0))
    pspecs = sh.param_specs(abstract_params, mesh, axes)
    params_sds = _tree_sds(abstract_params, pspecs, mesh)
    inputs, batch_spec = input_specs(cfg, shape, mesh, multi_pod)

    t0 = time.time()
    with jax.set_mesh(mesh):
        if shape.kind == "train":
            opt_cfg = OptConfig(
                moment_dtype=opts.get("moment_dtype", "bfloat16"))
            abstract_opt = jax.eval_shape(
                lambda p: init_opt_state(p, opt_cfg), abstract_params)
            opt_sds = {
                "m": _tree_sds(abstract_opt["m"], pspecs, mesh),
                "v": _tree_sds(abstract_opt["v"], pspecs, mesh),
                "step": jax.ShapeDtypeStruct((), jnp.int32),
            }
            step_fn = ts.make_train_step(
                cfg, opt_cfg, mesh, shape.global_batch, multi_pod=multi_pod,
                kv_block=kv_block, donate=False,
                remat_policy=opts.get("remat_policy", "nothing"))
            lowered = step_fn.lower({"params": params_sds, "opt": opt_sds},
                                    inputs)
        else:
            max_len = shape.seq_len
            abstract_caches = jax.eval_shape(
                lambda: model_lib.init_caches(
                    cfg, shape.global_batch, max_len,
                    enc_len=cfg.n_frontend_tokens if cfg.encoder else 0,
                    dtype=jnp.bfloat16))
            cspecs = sh.cache_specs(abstract_caches, mesh, axes)
            caches_sds = _tree_sds(abstract_caches, cspecs, mesh)
            prefill_fn, decode_fn = ts.make_serve_steps(
                cfg, mesh, shape.global_batch, max_len, multi_pod=multi_pod,
                kv_block=kv_block)
            tok = inputs.pop("tokens")
            if shape.kind == "prefill":
                lowered = prefill_fn.lower(params_sds, tok, caches_sds,
                                           **inputs)
            else:
                lowered = decode_fn.lower(params_sds, tok, caches_sds,
                                          shape.seq_len - 1)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    terms, coll, mem = rl.terms_from_compiled(
        compiled, n_dev, model_flops_for(cfg, shape))
    return {
        "status": "ok", "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_devices": n_dev,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "roofline": terms.to_dict(), "collectives": coll, "memory": mem,
        "params": cfg.param_count(), "active_params": cfg.active_param_count(),
        "options": opts,
    }


# --------------------------------------------------------------------------
# FFT cells (the paper's own workload)
# --------------------------------------------------------------------------

def lower_fft_cell(grid_name: str, multi_pod: bool,
                   decomposition: str = "pencil",
                   opts: FFTOptions = FFTOptions()) -> dict:
    fshape = FFT_SHAPES[grid_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = math.prod(mesh.devices.shape)
    if decomposition == "pencil":
        axes = fft_mesh_axes(mesh)
        decomp = Decomposition("pencil", axes)
    elif decomposition == "slab":
        names = mesh.axis_names
        decomp = Decomposition("slab", (tuple(names),))
    else:
        names = mesh.axis_names  # cell needs 3 axes: only multi-pod mesh
        if len(names) != 3:
            return {"status": "skip", "reason": "cell needs a 3-axis mesh"}
        decomp = Decomposition("cell", tuple(names))
    try:
        plan = Croft3D(fshape.grid, mesh, decomp, opts,
                       dtype=jnp.dtype(fshape.dtype))
    except ValueError as e:
        return {"status": "skip", "reason": str(e)}
    t0 = time.time()
    lowered = plan.lower_forward()
    compiled = lowered.compile()
    t_compile = time.time() - t0
    terms, coll, mem = rl.terms_from_compiled(compiled, n_dev,
                                              plan.flops_model())
    return {
        "status": "ok", "arch": f"croft-{decomposition}",
        "shape": grid_name,
        "mesh": "2x16x16" if multi_pod else "16x16", "n_devices": n_dev,
        "compile_s": round(t_compile, 1),
        "roofline": terms.to_dict(), "collectives": coll, "memory": mem,
        "comm_model_bytes": plan.comm_bytes_model(),
        "options": dataclasses_asdict(opts),
    }


def dataclasses_asdict(o):
    import dataclasses
    return dataclasses.asdict(o)


# --------------------------------------------------------------------------
# driver
# --------------------------------------------------------------------------

def run_cell(name: str, fn, out_dir: str, force: bool) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, name + ".json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            rec = json.load(f)
        if rec.get("status") != "error":  # errors always retry
            print(f"[cached] {name}: {rec.get('status')}")
            return rec
    print(f"[run]    {name} ...", flush=True)
    try:
        rec = fn()
    except Exception as e:
        rec = {"status": "error", "error": f"{type(e).__name__}: {e}",
               "traceback": traceback.format_exc()[-2000:]}
    finally:
        jax.clear_caches()  # keep 80-cell runs from accumulating executables
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    status = rec.get("status")
    extra = ""
    if status == "ok":
        r = rec["roofline"]
        extra = (f" compute={r['compute_s']:.4f}s memory={r['memory_s']:.4f}s"
                 f" coll={r['collective_s']:.4f}s -> {r['bottleneck']}"
                 f" (compile {rec.get('compile_s', '?')}s)")
    elif status == "error":
        extra = " " + rec["error"][:160]
    elif status == "skip":
        extra = " " + rec.get("reason", "")[:120]
    print(f"[done]   {name}: {status}{extra}", flush=True)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="one arch or 'all'")
    ap.add_argument("--shape", default=None, help="one shape or 'all'")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--fft", action="store_true", help="run FFT cells")
    ap.add_argument("--fft-grid", default="fft_1024")
    ap.add_argument("--fft-decomp", default="pencil")
    ap.add_argument("--all", action="store_true",
                    help="entire 40-cell LM matrix + FFT cells")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--kv-block", type=int, default=0,
                    help="0 = per-shape heuristic")
    args = ap.parse_args()

    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    records = []

    if args.fft or args.all:
        grids = list(FFT_SHAPES) if args.all else [args.fft_grid]
        decomps = (["pencil", "slab"] if args.all else [args.fft_decomp])
        for mp in meshes:
            for g in grids:
                for dec in decomps:
                    tag = f"fft-{g}-{dec}-{'mp' if mp else 'sp'}"
                    records.append(run_cell(
                        tag, lambda g=g, dec=dec, mp=mp: lower_fft_cell(
                            g, mp, dec), args.out, args.force))

    archs = []
    if args.all:
        archs = list(ASSIGNED)
    elif args.arch:
        archs = list(ASSIGNED) if args.arch == "all" else [args.arch]
    shapes = []
    if args.all:
        shapes = list(SHAPES)
    elif args.shape:
        shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    if archs and not shapes:
        shapes = list(SHAPES)
    if shapes and not archs:
        archs = list(ASSIGNED)

    for mp in meshes:
        for a in archs:
            for s in shapes:
                tag = f"{a}-{s}-{'mp' if mp else 'sp'}"
                records.append(run_cell(
                    tag, lambda a=a, s=s, mp=mp: lower_lm_cell(
                        a, s, mp, kv_block=args.kv_block),
                    args.out, args.force))

    n_ok = sum(r.get("status") == "ok" for r in records)
    n_skip = sum(r.get("status") == "skip" for r in records)
    n_err = sum(r.get("status") == "error" for r in records)
    print(f"\n=== dry-run summary: {n_ok} ok, {n_skip} skip, {n_err} error ===")
    return 0 if n_err == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
