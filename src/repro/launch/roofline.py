"""Roofline-term extraction from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), in seconds (DESIGN.md §8):

  compute    = HLO_FLOPs_per_device / peak_FLOPs          (197 TF/s bf16)
  memory     = HLO_bytes_per_device / HBM_bw              (819 GB/s)
  collective = collective_bytes_per_device / link_bw      (~50 GB/s/link)

``cost_analysis()`` on the compiled executable is per-partition (verified
empirically in tests/test_roofline.py), matching the formulas'
"/ chips" with global quantities.  Collective bytes are not in
cost_analysis: we parse the post-SPMD HLO and sum result-shape bytes of
every collective op, doubling all-reduce (reduce-scatter + all-gather
wire-equivalent).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Optional

# TPU v5e-class constants (per chip) — from the assignment brief
PEAK_FLOPS = 197e12        # bf16
HBM_BW = 819e9             # bytes/s
LINK_BW = 50e9             # bytes/s per ICI link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# `bf16[8,128]{1,0}` or scalar `f32[]`
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|[\w\[\]{},\s]+?)\s*"
    r"(all-gather-start|all-gather|all-reduce-start|all-reduce|"
    r"reduce-scatter|all-to-all|collective-permute-start|collective-permute)"
    r"\(", re.MULTILINE)


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_stats(hlo_text: str) -> dict:
    """Per-collective-kind {count, bytes} from post-SPMD HLO text."""
    stats: dict = {}
    for m in _OP_RE.finditer(hlo_text):
        shape_str, op = m.group(1), m.group(2)
        kind = op.replace("-start", "")
        b = _shape_bytes(shape_str)
        if kind == "all-reduce":
            b *= 2  # reduce-scatter + all-gather wire equivalent
        e = stats.setdefault(kind, {"count": 0, "bytes": 0})
        e["count"] += 1
        e["bytes"] += b
    return stats


@dataclasses.dataclass
class RooflineTerms:
    flops_per_device: float
    bytes_per_device: float
    collective_bytes_per_device: float
    n_devices: int
    model_flops: float = 0.0       # 6*N*D (train) / 2*N_active*tokens (serve)

    @property
    def compute_s(self) -> float:
        return self.flops_per_device / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.bytes_per_device / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.collective_bytes_per_device / LINK_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Optimistic (perfect-overlap) model: max of the three terms."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_fraction(self) -> float:
        """MODEL_FLOPS / (HLO flops summed over devices) — remat/
        redundancy waste shows up here."""
        total = self.flops_per_device * self.n_devices
        return self.model_flops / total if total else 0.0

    @property
    def mfu(self) -> float:
        """Model-FLOPs utilization at the modeled step time."""
        t = self.step_time_s
        if not t:
            return 0.0
        return self.model_flops / (self.n_devices * PEAK_FLOPS * t)

    def to_dict(self) -> dict:
        return {
            "flops_per_device": self.flops_per_device,
            "bytes_per_device": self.bytes_per_device,
            "collective_bytes_per_device": self.collective_bytes_per_device,
            "n_devices": self.n_devices,
            "model_flops": self.model_flops,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "bottleneck": self.bottleneck,
            "step_time_s": self.step_time_s,
            "useful_flops_fraction": self.useful_flops_fraction,
            "mfu": self.mfu,
        }


def terms_from_compiled(compiled, n_devices: int,
                        model_flops: float = 0.0) -> tuple:
    """(RooflineTerms, collective_stats dict, memory dict).

    Uses the trip-count-aware HLO analyzer (hlo_cost.py): XLA's own
    cost_analysis counts scan bodies once, undercounting layer-scanned
    models by O(depth).  The raw cost_analysis numbers ride along in the
    memory dict for cross-checking.
    """
    from repro import compat
    from repro.launch import hlo_cost

    ca = compat.cost_analysis(compiled)
    hlo = compiled.as_text()
    cost = hlo_cost.analyze(hlo)
    ma = compiled.memory_analysis()
    mem = {
        "argument_bytes": getattr(ma, "argument_size_in_bytes", 0),
        "output_bytes": getattr(ma, "output_size_in_bytes", 0),
        "temp_bytes": getattr(ma, "temp_size_in_bytes", 0),
        "alias_bytes": getattr(ma, "alias_size_in_bytes", 0),
        "xla_flops_unscaled": float(ca.get("flops", 0.0)),
        "xla_bytes_unscaled": float(ca.get("bytes accessed", 0.0)),
    }
    terms = RooflineTerms(
        flops_per_device=cost.flops, bytes_per_device=cost.bytes,
        collective_bytes_per_device=cost.collective_bytes,
        n_devices=n_devices, model_flops=model_flops)
    return terms, cost.collectives, mem
