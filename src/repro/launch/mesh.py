"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state; ``dryrun.py`` sets the 512-placeholder-device
XLA flag before calling it.
"""

from __future__ import annotations

import math

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_local_mesh(model: int = 0):
    """Best-effort mesh from whatever devices exist (tests / smoke runs)."""
    n = len(jax.devices())
    if model <= 0:
        model = 1
        for cand in (2, 4, 8, 16):
            if n % cand == 0 and cand <= n:
                model = cand
    return jax.make_mesh((n // model, model), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)


def fft_mesh_axes(mesh) -> tuple:
    """Pencil (Py, Pz) communicator axes on a production mesh: the pod axis
    folds into the Y communicator (DESIGN.md §2)."""
    names = mesh.axis_names
    if "pod" in names:
        return (("pod", "data"), "model")
    return ("data", "model")
