"""Serving driver for the transform service (and the legacy LM loop).

Default mode drives :class:`repro.serve.TransformService` with a
synthetic open-loop request stream and prints latency / occupancy /
plan-cache stats — the operational entry point for ROADMAP item 2:

``python -m repro.launch.serve --shape 32,32,32 --problem mix
--requests 64 --qps 50 --wisdom wisdom.json``

Passing ``--arch`` selects the legacy LM prefill+decode loop instead:

``python -m repro.launch.serve --arch rwkv6-3b --smoke --prompt-len 32
--gen-len 32 --batch 4``
"""

from __future__ import annotations

import argparse
import math
import time

import jax
import jax.numpy as jnp
import numpy as np


# -- transform-service mode (default) ---------------------------------------

def _mesh_for_transforms():
    """Pencil mesh over whatever devices exist; None = single device
    (the service then runs meshless local plans)."""
    n = len(jax.devices())
    if n < 2:
        return None
    py = int(math.sqrt(n))
    while n % py:
        py -= 1
    return jax.make_mesh((py, n // py), ("y", "z"))


def transforms_main(args) -> None:
    from repro.serve import TransformService

    mesh = _mesh_for_transforms()
    shape = tuple(int(s) for s in args.shape.split(","))
    if len(shape) != 3:
        raise SystemExit(f"--shape must be 3-D, got {shape}")
    print(f"mesh: {dict(mesh.shape) if mesh else 'single-device'}  "
          f"shape: {shape}  problem: {args.problem}")

    rng = np.random.RandomState(args.seed)
    cplx = (rng.randn(*shape) + 1j * rng.randn(*shape)).astype(np.complex64)
    real = rng.randn(*shape).astype(np.float32)
    filt = rng.randn(*shape).astype(np.complex64)
    workload = {
        "c2c": [(cplx, {})],
        "r2c": [(real, {"problem": "r2c"})],
        "filtered": [(cplx, {"problem": "filtered", "h": filt})],
    }
    reqs = (workload["c2c"] * 3 + workload["r2c"] * 2
            + workload["filtered"]) if args.problem == "mix" \
        else workload[args.problem]

    svc = TransformService(
        mesh, max_batch=args.max_batch, max_wait_ms=args.max_wait_ms,
        wisdom_path=args.wisdom, measure_after=args.measure_after)
    with svc:
        t0 = time.monotonic()
        futs = []
        for i in range(args.requests):
            if args.qps > 0:
                delay = t0 + i / args.qps - time.monotonic()
                if delay > 0:
                    time.sleep(delay)
            x, kw = reqs[i % len(reqs)]
            futs.append(svc.submit(x, **kw))
        results = [f.result(timeout=600) for f in futs]
        bad = [r for r in results if not r.ok]
        if bad:
            raise SystemExit(f"{len(bad)} requests failed; first error: "
                             f"{bad[0].error}")
        stats = svc.stats()

    lat = stats["latency_ms"]
    print(f"served {stats['requests']} requests in "
          f"{stats['batches']} batches "
          f"(mean batch {stats['mean_batch']:.2f}, "
          f"occupancy {stats['occupancy']:.0%})")
    print(f"latency ms: p50={lat['p50']:.2f} p90={lat['p90']:.2f} "
          f"p99={lat['p99']:.2f}")
    cache = stats["plan_cache"]
    print(f"plan cache: {cache['stats']}  states: "
          f"{ {k.split('|')[0] + '|' + k.split('|')[-1]: v['state'] for k, v in cache['plans'].items()} }")


# -- legacy LM prefill/decode loop (``--arch``) -----------------------------

def lm_main(args) -> None:
    from repro.configs import get_config
    from repro.launch.mesh import make_local_mesh
    from repro.models import init_caches, init_params
    from repro.train import make_serve_steps
    from repro.train.data import synth_tokens
    from repro.train.train_step import temperature_sample

    cfg = get_config(args.arch, smoke=args.smoke)
    if not cfg.supports_decode:
        raise SystemExit(f"{cfg.name} is encoder-only: no decode step")
    mesh = make_local_mesh()
    params = init_params(jax.random.PRNGKey(args.seed), cfg)

    max_len = args.prompt_len + args.gen_len \
        + (cfg.n_frontend_tokens if cfg.prefix_lm else 0)
    prefill_fn, decode_fn = make_serve_steps(
        cfg, mesh, args.batch, max_len, kv_block=args.kv_block)

    prompts = synth_tokens(args.seed, 0, args.batch, args.prompt_len,
                           cfg.vocab)
    enc_len = cfg.n_frontend_tokens if cfg.encoder is not None else 0
    caches = init_caches(cfg, args.batch, max_len, enc_len=enc_len,
                         dtype=jnp.bfloat16)
    kwargs = {}
    rng = np.random.default_rng(args.seed)
    if cfg.encoder is not None:
        kwargs["frames"] = jnp.asarray(rng.standard_normal(
            (args.batch, cfg.n_frontend_tokens, cfg.d_model), np.float32))
    elif cfg.frontend == "vision":
        kwargs["prefix_embeds"] = jnp.asarray(rng.standard_normal(
            (args.batch, cfg.n_frontend_tokens, cfg.d_model), np.float32))

    with jax.set_mesh(mesh):
        t0 = time.monotonic()
        logits, caches = prefill_fn(params, jnp.asarray(prompts), caches,
                                    **kwargs)
        logits.block_until_ready()
        t_prefill = time.monotonic() - t0
        key = jax.random.PRNGKey(args.seed)
        tok = temperature_sample(key, logits, args.temperature)[:, None]
        out = [tok]
        prefix = cfg.n_frontend_tokens if cfg.prefix_lm else 0
        t0 = time.monotonic()
        for i in range(args.gen_len - 1):
            t = prefix + args.prompt_len + i
            logits, caches = decode_fn(params, tok, caches, t)
            key, sub = jax.random.split(key)
            tok = temperature_sample(sub, logits, args.temperature)[:, None]
            out.append(tok)
        jax.block_until_ready(out[-1])
        t_decode = time.monotonic() - t0

    gen = np.concatenate([np.asarray(t) for t in out], axis=1)
    tps = args.batch * (args.gen_len - 1) / max(t_decode, 1e-9)
    print(f"prefill: {t_prefill:.3f}s for {args.batch}x{args.prompt_len} tok")
    print(f"decode : {t_decode:.3f}s for {args.gen_len-1} steps "
          f"({tps:.1f} tok/s)")
    print(f"sample generations (first 16 ids):\n{gen[:, :16]}")


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--seed", type=int, default=0)
    # transform-service mode
    ap.add_argument("--shape", default="32,32,32",
                    help="3-D transform shape, e.g. 64,64,64")
    ap.add_argument("--problem", default="mix",
                    choices=("c2c", "r2c", "filtered", "mix"))
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--qps", type=float, default=0.0,
                    help="offered request rate; 0 = as fast as possible")
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--max-wait-ms", type=float, default=2.0)
    ap.add_argument("--wisdom", default=None,
                    help="wisdom file: cold starts read it, background "
                         "measure upgrades merge into it")
    ap.add_argument("--measure-after", type=int, default=None,
                    help="dispatches of a key before the background "
                         "measure-mode upgrade")
    # legacy LM mode
    ap.add_argument("--arch", default=None,
                    help="run the legacy LM prefill/decode loop instead")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--kv-block", type=int, default=512)
    args = ap.parse_args(argv)
    if args.arch:
        lm_main(args)
    else:
        transforms_main(args)


if __name__ == "__main__":
    main()
