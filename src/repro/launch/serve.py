"""Serving driver: batched prefill + decode loop.

``python -m repro.launch.serve --arch rwkv6-3b --smoke --prompt-len 32
--gen-len 32 --batch 4``
"""

from __future__ import annotations

import argparse
import math
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.launch.mesh import make_local_mesh
from repro.models import init_caches
from repro.train import make_serve_steps
from repro.train.data import synth_tokens
from repro.train.train_step import temperature_sample


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--kv-block", type=int, default=512)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    if not cfg.supports_decode:
        raise SystemExit(f"{cfg.name} is encoder-only: no decode step")
    mesh = make_local_mesh()
    from repro.models import init_params
    params = init_params(jax.random.PRNGKey(args.seed), cfg)

    max_len = args.prompt_len + args.gen_len \
        + (cfg.n_frontend_tokens if cfg.prefix_lm else 0)
    prefill_fn, decode_fn = make_serve_steps(
        cfg, mesh, args.batch, max_len, kv_block=args.kv_block)

    prompts = synth_tokens(args.seed, 0, args.batch, args.prompt_len,
                           cfg.vocab)
    enc_len = cfg.n_frontend_tokens if cfg.encoder is not None else 0
    caches = init_caches(cfg, args.batch, max_len, enc_len=enc_len,
                         dtype=jnp.bfloat16)
    kwargs = {}
    rng = np.random.default_rng(args.seed)
    if cfg.encoder is not None:
        kwargs["frames"] = jnp.asarray(rng.standard_normal(
            (args.batch, cfg.n_frontend_tokens, cfg.d_model), np.float32))
    elif cfg.frontend == "vision":
        kwargs["prefix_embeds"] = jnp.asarray(rng.standard_normal(
            (args.batch, cfg.n_frontend_tokens, cfg.d_model), np.float32))

    with jax.set_mesh(mesh):
        t0 = time.monotonic()
        logits, caches = prefill_fn(params, jnp.asarray(prompts), caches,
                                    **kwargs)
        logits.block_until_ready()
        t_prefill = time.monotonic() - t0
        key = jax.random.PRNGKey(args.seed)
        tok = temperature_sample(key, logits, args.temperature)[:, None]
        out = [tok]
        prefix = cfg.n_frontend_tokens if cfg.prefix_lm else 0
        t0 = time.monotonic()
        for i in range(args.gen_len - 1):
            t = prefix + args.prompt_len + i
            logits, caches = decode_fn(params, tok, caches, t)
            key, sub = jax.random.split(key)
            tok = temperature_sample(sub, logits, args.temperature)[:, None]
            out.append(tok)
        jax.block_until_ready(out[-1])
        t_decode = time.monotonic() - t0

    gen = np.concatenate([np.asarray(t) for t in out], axis=1)
    tps = args.batch * (args.gen_len - 1) / max(t_decode, 1e-9)
    print(f"prefill: {t_prefill:.3f}s for {args.batch}x{args.prompt_len} tok")
    print(f"decode : {t_decode:.3f}s for {args.gen_len-1} steps "
          f"({tps:.1f} tok/s)")
    print(f"sample generations (first 16 ids):\n{gen[:, :16]}")


if __name__ == "__main__":
    main()
