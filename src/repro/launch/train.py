"""Training driver: ``python -m repro.launch.train --arch yi-9b --smoke``.

Production loop with the full fault-tolerance path wired in: auto-resume
from the latest checkpoint, SIGTERM-triggered save-and-exit, straggler
monitoring, deterministic data replay.
"""

from __future__ import annotations

import argparse
import json
import math
import os
import time

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config
from repro.launch.mesh import make_local_mesh
from repro.parallel import sharding as sh
from repro.train import (OptConfig, init_train_state, make_train_step)
from repro.train.checkpoint import CheckpointManager
from repro.train.data import Prefetcher, SyntheticDataset
from repro.train.fault import PreemptionHandler, StragglerMonitor


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--kv-block", type=int, default=512)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--moment-dtype", default="float32")
    ap.add_argument("--metrics-out", default=None)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    mesh = make_local_mesh()
    n_dev = math.prod(mesh.devices.shape)
    print(f"arch={cfg.name} params={cfg.param_count():,} mesh={mesh.shape} "
          f"devices={n_dev}")

    opt_cfg = OptConfig(lr=args.lr, warmup_steps=max(2, args.steps // 20),
                        decay_steps=args.steps,
                        moment_dtype=args.moment_dtype)
    state = init_train_state(jax.random.PRNGKey(args.seed), cfg, opt_cfg, mesh)
    step_fn = make_train_step(cfg, opt_cfg, mesh, args.global_batch,
                              kv_block=args.kv_block)

    ckpt = CheckpointManager(args.ckpt_dir, keep=2) if args.ckpt_dir else None
    start_step = 0
    if ckpt and ckpt.latest_step() is not None:
        axes = sh.MeshAxes()
        shardings = {
            "params": sh.param_shardings(state["params"], mesh, axes),
            "opt": {"m": sh.param_shardings(state["opt"]["m"], mesh, axes),
                    "v": sh.param_shardings(state["opt"]["v"], mesh, axes),
                    "step": None},
        }
        state = ckpt.restore(state, shardings=None)
        start_step = int(state["opt"]["step"])
        print(f"resumed from checkpoint at step {start_step}")

    extra = {}
    if cfg.encoder is not None:
        extra["frames"] = ((cfg.n_frontend_tokens, cfg.d_model), np.float32)
    elif cfg.frontend == "vision":
        extra["prefix_embeds"] = ((cfg.n_frontend_tokens, cfg.d_model),
                                  np.float32)
    ds = SyntheticDataset(
        cfg.vocab, args.seq_len, args.global_batch, seed=args.seed,
        sharding={"tokens": NamedSharding(mesh, P("data", None))},
        start_step=start_step, extra=extra)
    data = Prefetcher(iter(ds), depth=2)

    preempt = PreemptionHandler()
    preempt.install()
    monitor = StragglerMonitor(on_straggler=lambda s: print(
        f"  [straggler] step {s.step}: {s.seconds:.2f}s (z={s.z_score:.1f})"))

    history = []
    with jax.set_mesh(mesh):
        for step in range(start_step, args.steps):
            monitor.start_step()
            batch = next(data)
            state, metrics = step_fn(state, batch)
            loss = float(metrics["loss"])
            stats = monitor.end_step(step)
            history.append({"step": step, "loss": loss,
                            "sec": round(stats.seconds, 3)})
            if step % args.log_every == 0 or step == args.steps - 1:
                print(f"step {step:5d} loss {loss:.4f} "
                      f"lr {float(metrics['lr']):.2e} "
                      f"gnorm {float(metrics['grad_norm']):.2f} "
                      f"acc {float(metrics['accuracy']):.3f} "
                      f"({stats.seconds:.2f}s)")
            if ckpt and (step + 1) % args.ckpt_every == 0:
                ckpt.save(step + 1, state)
            if preempt.preemption_requested:
                print("preemption requested: checkpointing and exiting")
                if ckpt:
                    ckpt.save(step + 1, state, block=True)
                break
    if ckpt:
        ckpt.save(args.steps, state, block=True)
    if args.metrics_out:
        os.makedirs(os.path.dirname(args.metrics_out) or ".", exist_ok=True)
        with open(args.metrics_out, "w") as f:
            json.dump(history, f)
    print(f"final loss {history[-1]['loss']:.4f} "
          f"(first {history[0]['loss']:.4f})")


if __name__ == "__main__":
    main()
