"""Trip-count-aware HLO cost analysis.

``compiled.cost_analysis()`` counts a ``while`` (lax.scan) body ONCE
(verified in tests/test_roofline.py), which undercounts scanned-layer
models by the layer count.  This module parses the post-SPMD HLO text,
builds the computation call graph, extracts while-loop trip counts from
their condition computations, and accumulates:

  * flops: dot/convolution ops (2*out_elems*contracted; x4 for complex)
  * bytes: every op's operands + output (XLA's 'bytes accessed' convention)
  * collective bytes/counts by kind (all-reduce doubled: RS+AG equivalent)

each weighted by the product of enclosing while trip counts.

The parser is deliberately conservative: computations reachable only as
``fusion``/``to_apply`` subroutines are not double-counted (their cost is
attributed at the call site via the fusion op's operands/outputs).
"""

from __future__ import annotations

import dataclasses
import math
import re
from typing import Optional

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16,
}

_COMP_HEADER = re.compile(
    r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->\s*.+\{\s*$")
_OP_LINE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.*?)\s*([\w\-]+)\(")
_SHAPE = re.compile(r"(\w+)\[([\d,]*)\]")
_OPERANDS = re.compile(r"%([\w\.\-]+)")
_ATTR_CALL = re.compile(
    r"(body|condition|to_apply|calls)=\s*(?:\{([^}]*)\}|%?([\w\.\-]+))")
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_TRIP_CONST = re.compile(r"constant\((\d+)\)")

COLLECTIVE_KINDS = ("all-gather", "all-reduce", "reduce-scatter",
                    "all-to-all", "collective-permute")


def _shape_info(type_str: str):
    """[(dtype, elems, bytes)] for possibly-tuple type strings."""
    out = []
    for dtype, dims in _SHAPE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        out.append((dtype, n, n * _DTYPE_BYTES[dtype]))
    return out


def _total_bytes(type_str: str) -> int:
    return sum(b for _, _, b in _shape_info(type_str))


@dataclasses.dataclass
class Op:
    name: str
    kind: str
    type_str: str
    line: str
    operand_str: str    # text inside the op's argument parens


@dataclasses.dataclass
class Computation:
    name: str
    ops: list
    shapes: dict            # op name -> type string


def parse_computations(hlo: str) -> dict:
    comps: dict = {}
    cur: Optional[Computation] = None
    for line in hlo.splitlines():
        stripped = line.strip()
        m = _COMP_HEADER.match(stripped) if (
            stripped.endswith("{") and "->" in stripped
            and "=" not in stripped.split("(")[0]) else None
        if m:
            cur = Computation(m.group(1), [], {})
            comps[cur.name] = cur
            continue
        if cur is None:
            continue
        if stripped == "}":
            cur = None
            continue
        om = _OP_LINE.match(line)
        if om:
            name, type_str, kind = om.group(1), om.group(2), om.group(3)
            rest = line[om.end():]
            depth = 1
            end = 0
            for i, ch in enumerate(rest):
                if ch == "(":
                    depth += 1
                elif ch == ")":
                    depth -= 1
                    if depth == 0:
                        end = i
                        break
            operand_str = rest[:end]
            cur.ops.append(Op(name, kind, type_str, line, operand_str))
            cur.shapes[name] = type_str
    return comps


def _callees(op: Op) -> dict:
    """attr -> [computation names] referenced by this op."""
    out = {}
    for m in _ATTR_CALL.finditer(op.line):
        attr = m.group(1)
        names = []
        if m.group(2) is not None:
            names = [n.strip().lstrip("%") for n in m.group(2).split(",")]
        elif m.group(3):
            names = [m.group(3)]
        out.setdefault(attr, []).extend(names)
    return out


def _trip_count(cond: Computation) -> int:
    """Largest integer constant in the condition computation (scan emits
    ``compare(iter, constant(N), LT)``); 1 if none found."""
    best = 1
    for op in cond.ops:
        for c in _TRIP_CONST.findall(op.line):
            best = max(best, int(c))
    return best


def _dot_flops(op: Op, comp: Computation) -> float:
    info = _shape_info(op.type_str)
    if not info:
        return 0.0
    dtype, out_elems, _ = info[0]
    factor = 8.0 if dtype.startswith("c") else 2.0
    # contracted size from the lhs operand's shape
    cm = _CONTRACT.search(op.line)
    operand_names = _OPERANDS.findall(op.operand_str)
    contracted = 1
    if cm and operand_names:
        lhs_type = comp.shapes.get(operand_names[0], "")
        lhs_info = _shape_info(lhs_type)
        if lhs_info:
            dims_str = [d for d in cm.group(1).split(",") if d]
            lhs_dims = _SHAPE.search(lhs_type)
            if lhs_dims and lhs_dims.group(2):
                sizes = [int(x) for x in lhs_dims.group(2).split(",") if x]
                for d in dims_str:
                    di = int(d)
                    if di < len(sizes):
                        contracted *= sizes[di]
    return factor * out_elems * contracted


_VIEW_OPS = frozenset({"parameter", "constant", "tuple", "get-tuple-element",
                       "bitcast", "after-all", "add-dependency", "domain",
                       "opt-barrier", "partition-id", "replica-id",
                       # control ops: their data movement is inside the
                       # bodies (carries are aliased in place)
                       "while", "conditional", "call"})


def _fusion_operand_bytes(op: Op, comp: Computation, comps: dict) -> int:
    """Operand bytes of a fusion, with dynamic-slice/gather-consumed
    parameters counted at their *slice* size (a scan body reading one layer
    of a stacked weight must not be charged the whole stack per
    iteration)."""
    callees = _callees(op)
    called = None
    for cn in callees.get("calls", []):
        called = comps.get(cn)
    full_total = 0
    operand_names = _OPERANDS.findall(op.operand_str)
    if called is None:
        for name in operand_names:
            if name in comp.shapes:
                full_total += _total_bytes(comp.shapes[name])
        return full_total
    # param index -> bytes actually read
    param_sizes: dict = {}
    for inner in called.ops:
        if inner.kind == "parameter":
            param_sizes[inner.name] = _total_bytes(inner.type_str)
    sliced: dict = {}
    for inner in called.ops:
        if inner.kind in ("dynamic-slice", "gather", "slice"):
            srcs = _OPERANDS.findall(inner.operand_str)
            if srcs and srcs[0] in param_sizes:
                sliced[srcs[0]] = sliced.get(srcs[0], 0) \
                    + _total_bytes(inner.type_str)
    total = 0
    for pname, size in param_sizes.items():
        total += min(sliced.get(pname, size), size)
    return total


def _op_bytes(op: Op, comp: Computation, comps: Optional[dict] = None) -> int:
    if op.kind in _VIEW_OPS:
        return 0
    if op.kind == "copy":
        return 2 * _total_bytes(op.type_str)
    if op.kind == "fusion" and comps is not None:
        return _total_bytes(op.type_str) \
            + _fusion_operand_bytes(op, comp, comps)
    total = _total_bytes(op.type_str)
    for name in _OPERANDS.findall(op.operand_str):
        if name in comp.shapes:
            total += _total_bytes(comp.shapes[name])
    return total


@dataclasses.dataclass
class HloCost:
    flops: float = 0.0
    bytes: float = 0.0
    collective_bytes: float = 0.0
    collectives: dict = dataclasses.field(default_factory=dict)

    def add_collective(self, kind: str, count: float, nbytes: float):
        e = self.collectives.setdefault(kind, {"count": 0, "bytes": 0})
        e["count"] += count
        e["bytes"] += nbytes
        self.collective_bytes += nbytes


def analyze(hlo: str) -> HloCost:
    comps = parse_computations(hlo)
    # find the entry: computation named like the module entry — use the one
    # not referenced by anyone
    referenced = set()
    for comp in comps.values():
        for op in comp.ops:
            for names in _callees(op).values():
                referenced.update(names)
    entries = [c for c in comps if c not in referenced]
    cost = HloCost()
    seen_async: set = set()

    def visit(cname: str, mult: float):
        comp = comps.get(cname)
        if comp is None:
            return
        for op in comp.ops:
            kind = op.kind
            base = kind.replace("-start", "").replace("-done", "")
            if base in COLLECTIVE_KINDS:
                if kind.endswith("-done") or op.name in seen_async:
                    continue
                b = _total_bytes(op.type_str)
                if kind.endswith("-start"):
                    # start ops produce (in, out[, scratch]) tuples: halve
                    b = b // 2
                if base == "all-reduce":
                    b *= 2
                cost.add_collective(base, mult, b * mult)
            elif kind in ("dot", "convolution"):
                cost.flops += mult * _dot_flops(op, comp)
            callees = _callees(op)
            if kind == "while":
                trips = 1
                for cn in callees.get("condition", []):
                    if cn in comps:
                        trips = max(trips, _trip_count(comps[cn]))
                for bn in callees.get("body", []):
                    visit(bn, mult * trips)
                for cn in callees.get("condition", []):
                    visit(cn, mult * (trips + 1))
            elif kind in ("call", "async-start", "custom-call"):
                for group in ("calls", "to_apply"):
                    for cn in callees.get(group, []):
                        visit(cn, mult)
            # bytes: every op's operands + output (XLA convention)
            cost.bytes += mult * _op_bytes(op, comp, comps)
        return

    for e in entries:
        visit(e, 1.0)
    return cost


def analyze_compiled(compiled) -> Optional[HloCost]:
    """:func:`analyze` on a compiled executable's post-SPMD HLO text;
    None when the text is unavailable (some backends ship opaque
    executables)."""
    try:
        return analyze(compiled.as_text())
    except Exception:
        return None


def summarize(cost: Optional[HloCost]) -> dict:
    """Flat JSON-able view of an :class:`HloCost` (span/report payload):
    totals plus per-kind collective counts and bytes."""
    if cost is None:
        return {}
    out = {
        "hlo_flops": cost.flops,
        "hlo_bytes": cost.bytes,
        "hlo_collective_bytes": cost.collective_bytes,
        "hlo_collectives": sum(e["count"] for e in cost.collectives.values()),
    }
    for kind, e in sorted(cost.collectives.items()):
        out[f"hlo_{kind}_count"] = e["count"]
        out[f"hlo_{kind}_bytes"] = e["bytes"]
    return out
