"""Candidate enumeration: every valid (Decomposition, FFTOptions) pair.

The planner's search space is the cross product of

  * how the grid maps onto the mesh — slab / pencil / cell, over every
    ordered assignment of mesh axes (and folded axis groups) that covers
    the whole mesh, and
  * the ``FFTOptions`` knob matrix — overlap K, local 1-D FFT
    implementation (optionally per pipeline stage), output layout,
    transpose implementation,

filtered by :meth:`Decomposition.validate` (divisibility, P <= N limits,
overlap chunking).  ``problem="r2c"`` additionally enumerates the real-
transform strategy axis: every c2c candidate as an "embed" plan, plus a
"packed" two-for-one plan wherever ``repro.real`` supports it.
Everything here is pure arithmetic over axis *sizes*, so candidates can
be generated with no devices present.
"""

from __future__ import annotations

import dataclasses
import itertools
import math
import re
from typing import Iterator, Mapping, Optional, Sequence

from repro.core.decomposition import Decomposition
from repro.core.distributed import FFTOptions, build_schedule

# default knob ranges; "pallas" is intentionally absent (TPU-only kernel —
# callers on TPU pass local_impls=(..., "pallas") explicitly)
DEFAULT_OVERLAP_KS = (1, 2, 4)
DEFAULT_LOCAL_IMPLS = ("matmul", "stockham", "xla")
DEFAULT_LAYOUTS = ("natural", "spectral")
#: the ``_grad`` problems plan a *training step*: same search space as
#: their base problem, but the cost model prices forward + adjoint
#: schedule and measurement times ``jax.grad`` through the transform
PROBLEMS = ("c2c", "r2c", "c2c_grad", "r2c_grad")
GRAD_SUFFIX = "_grad"


def split_grad(problem: str) -> tuple:
    """``"r2c_grad" -> ("r2c", True)``; base problems pass through."""
    if problem.endswith(GRAD_SUFFIX):
        return problem[: -len(GRAD_SUFFIX)], True
    return problem, False


def _impl_str(impl) -> str:
    if isinstance(impl, tuple):
        return "-".join(impl)
    return impl


@dataclasses.dataclass(frozen=True)
class Candidate:
    """One point of the search space."""

    decomp: Decomposition
    opts: FFTOptions
    #: problem class this plan solves
    problem: str = "c2c"
    #: r2c only: "packed" | "embed"
    strategy: Optional[str] = None

    @property
    def label(self) -> str:
        def axis_str(a):
            if isinstance(a, tuple):
                return "+".join(a)
            return a
        axes = "x".join(axis_str(a) for a in self.decomp.axes)
        o = self.opts
        base = (f"{self.decomp.kind}[{axes}]/k{o.overlap_k}/"
                f"{_impl_str(o.local_impl)}/"
                f"{o.output_layout}/{o.transpose_impl}"
                + ("" if o.overlap_mode == "pipelined"
                   else f"/{_impl_str(o.overlap_mode)}")
                + ("" if o.plan_cache else "/noplan"))
        if self.problem != "c2c":
            base += f"/{self.problem}" + (f"-{self.strategy}"
                                          if self.strategy else "")
        return base

    # -- canonical string form ----------------------------------------------
    #
    # ``label`` is for humans (it elides default knobs); ``plan_key`` is
    # for caches: it covers every field that changes the compiled
    # executable and round trips exactly, including the per-stage
    # ``local_impl``/``overlap_mode`` 3-tuples.

    @property
    def plan_key(self) -> str:
        key = f"{self.decomp.to_token()}|{self.opts.to_token()}"
        if self.problem != "c2c":
            # strategy may be None (grad c2c plans) — emit the empty
            # string so from_plan_key round-trips it back to None
            key += f"|{self.problem}:{self.strategy or ''}"
        return key

    @classmethod
    def from_plan_key(cls, key: str) -> "Candidate":
        """Inverse of :attr:`plan_key`."""
        parts = key.split("|")
        if len(parts) not in (2, 3):
            raise ValueError(f"malformed plan key {key!r}")
        decomp = Decomposition.from_token(parts[0])
        opts = FFTOptions.from_token(parts[1])
        if len(parts) == 2:
            return cls(decomp, opts)
        problem, _, strategy = parts[2].partition(":")
        if problem not in PROBLEMS:
            # reject rather than construct a plan for a problem class this
            # version cannot build (e.g. a key written by a newer version)
            # — callers treat ValueError as a cache miss, not a crash
            raise ValueError(f"unknown problem {problem!r} in plan key "
                             f"{key!r} (known: {PROBLEMS})")
        return cls(decomp, opts, problem=problem,
                   strategy=strategy or None)


def _groupings(names: Sequence[str], k: int) -> Iterator[tuple]:
    """Ordered partitions of ``names`` into k non-empty groups.

    Each group becomes one Decomposition axis entry: a bare name when the
    group is a single axis, a folded tuple otherwise.  Every grouping
    covers the whole mesh — leaving an axis out would replicate the grid
    over it (never faster, so not part of the search space).
    """
    if len(names) < k:
        return
    for assignment in itertools.product(range(k), repeat=len(names)):
        if set(assignment) != set(range(k)):
            continue
        groups = []
        for g in range(k):
            members = tuple(n for n, a in zip(names, assignment) if a == g)
            groups.append(members[0] if len(members) == 1 else members)
        yield tuple(groups)


def decompositions_for(shape: Sequence[int], axis_sizes: Mapping[str, int],
                       overlap_k: int = 1) -> list[Decomposition]:
    """All decompositions valid for (shape, mesh axes) at the given K."""
    names = list(axis_sizes)
    out: list[Decomposition] = []
    for kind, slots in (("slab", 1), ("pencil", 2), ("cell", 3)):
        for axes in _groupings(names, slots):
            dec = Decomposition(kind, axes)
            if dec.is_valid(shape, axis_sizes, overlap_k):
                out.append(dec)
    return out


def _stagewise_impls(local_impls: Sequence) -> list:
    """Heterogeneous per-stage combinations (ROADMAP follow-on): every
    3-tuple over ``local_impls`` whose entries are not all equal (the
    homogeneous ones are already in the base space as scalars)."""
    singles = [i for i in local_impls if not isinstance(i, (tuple, list))]
    return [combo for combo in itertools.product(singles, repeat=3)
            if len(set(combo)) > 1]


def enumerate_candidates(
        shape: Sequence[int],
        axis_sizes: Mapping[str, int],
        *,
        overlap_ks: Sequence[int] = DEFAULT_OVERLAP_KS,
        local_impls: Sequence[str] = DEFAULT_LOCAL_IMPLS,
        layouts: Sequence[str] = DEFAULT_LAYOUTS,
        include_baselines: bool = False,
        heterogeneous_impls: bool = False,
        problem: str = "c2c",
) -> list[Candidate]:
    """The full valid search space, deterministically ordered.

    ``include_baselines`` adds configurations that model the paper's
    baselines and are never expected to win — ``transpose_impl="pairwise"``
    (FFTW3's sendrecv pattern) and ``plan_cache=False`` (options 1/3) —
    useful for benchmark sweeps, noise for production tuning.

    ``heterogeneous_impls`` widens the ``local_impl`` axis with per-stage
    3-tuples (e.g. matmul on the contiguous first stage, Stockham on the
    strided ones).

    ``problem="r2c"`` returns real-transform candidates: each valid c2c
    point as an "embed" plan plus a "packed" two-for-one plan where the
    packed pipeline's constraints hold (pencil or slab decomposition,
    even divisibility — see ``repro.real.packed_unsupported_reason``).
    """
    if problem not in PROBLEMS:
        raise ValueError(f"problem must be one of {PROBLEMS}, got {problem!r}")
    base_problem, is_grad = split_grad(problem)
    impls = list(local_impls)
    if heterogeneous_impls:
        impls += _stagewise_impls(local_impls)
    out: list[Candidate] = []
    for k in overlap_ks:
        for dec in decompositions_for(shape, axis_sizes, overlap_k=k):
            for impl in impls:
                for layout in layouts:
                    if layout == "spectral" and dec.kind == "cell":
                        continue  # cell pipeline restores natural layout
                    variants = [dict(transpose_impl="alltoall",
                                     plan_cache=True)]
                    # ring / pairwise ppermute over ONE mesh axis: folded
                    # axes and the cell regroup (which runs the pencil
                    # pipeline over a folded (y, x) communicator) are
                    # rejected by Decomposition.validate — never emit
                    # candidates that cannot trace
                    single_axes = (dec.kind != "cell" and all(
                        not isinstance(a, tuple) for a in dec.axes))
                    if single_axes:
                        # the ring pipeline is a real contender (it
                        # overlaps even when no chunk axis divides), so
                        # it is part of the production search space —
                        # the cost model's latency/bandwidth split ranks
                        # it, not a hardcoded preference
                        variants.append(dict(transpose_impl="ring",
                                             plan_cache=True))
                    if include_baselines:
                        variants.append(dict(transpose_impl="alltoall",
                                             plan_cache=False))
                        if single_axes:
                            variants.append(dict(transpose_impl="pairwise",
                                                 plan_cache=True))
                    for var in variants:
                        out.append(Candidate(dec, FFTOptions(
                            overlap_k=k, local_impl=impl,
                            output_layout=layout, **var)))
    if base_problem == "r2c":
        out = _realize_r2c(shape, axis_sizes, out)
    if is_grad:
        # same physical plans; the problem tag switches the cost model to
        # fwd+adjoint pricing and measurement to a value_and_grad step
        out = [dataclasses.replace(c, problem=problem) for c in out]
    return out


def _realize_r2c(shape, axis_sizes, base: list[Candidate]) -> list[Candidate]:
    """Map a c2c candidate list onto the r2c strategy axis.

    The packed pipelines (pencil and slab) ignore ``output_layout`` (they
    always start from the z-local spectral layout and never pay restoring
    transposes), so the packed variant rides only on the spectral-layout
    points to avoid duplicate plans.
    """
    from repro.real import packed_unsupported_reason
    out: list[Candidate] = []
    for c in base:
        out.append(dataclasses.replace(c, problem="r2c", strategy="embed"))
        if (c.opts.output_layout == "spectral"
                and packed_unsupported_reason(shape, c.decomp, axis_sizes,
                                              c.opts) is None):
            out.append(dataclasses.replace(c, problem="r2c",
                                           strategy="packed"))
    return out


def default_candidate(shape: Sequence[int], axis_sizes: Mapping[str, int],
                      problem: str = "c2c") -> Optional[Candidate]:
    """What an untuned caller would pick: the decomposition kind matching
    the mesh rank (slab for 1 axis, pencil for 2, cell for 3, folded
    pencil otherwise) with stock ``FFTOptions()``.  None if invalid for
    the shape."""
    names = list(axis_sizes)
    if len(names) == 1:
        dec = Decomposition("slab", (names[0],))
    elif len(names) == 2:
        dec = Decomposition("pencil", tuple(names))
    elif len(names) == 3:
        dec = Decomposition("cell", tuple(names))
    else:
        dec = Decomposition("pencil", (tuple(names[:-1]), names[-1]))
    opts = FFTOptions()
    if not dec.is_valid(shape, axis_sizes, opts.overlap_k):
        if not dec.is_valid(shape, axis_sizes, 1):
            return None
        opts = dataclasses.replace(opts, overlap_k=1)
    base_problem, _ = split_grad(problem)
    if base_problem == "r2c":
        from repro.real import packed_unsupported_reason
        strategy = ("packed" if packed_unsupported_reason(
            shape, dec, axis_sizes, opts) is None else "embed")
        return Candidate(dec, opts, problem=problem, strategy=strategy)
    return Candidate(dec, opts, problem=problem)


# ---------------------------------------------------------------------------
# schedule-space candidates: search *pipelines*, not just knobs
# ---------------------------------------------------------------------------
#
# A ScheduleCandidate is an explicit stage list over a decomposition —
# which dim each stage FFTs, which communicator it transposes over and
# how, plus *per-stage* transpose-impl / K overrides.  The fixed builders
# reach only a few points of this space (one transpose order per kind,
# one impl and one K for the whole pipeline); the enumerator below walks
# the rest, pruned by the same symbolic layout propagation that validates
# the fixed builders (malformed pipelines raise ScheduleError at build
# time) plus a divisibility check against the concrete shape.

SCHED_PREFIX = "sched:"
#: problems the schedule search covers (r2c pipelines carry pack/unpack
#: prologues the symbolic move space does not model)
SCHED_PROBLEMS = ("c2c", "c2c_grad")
_GRID = "xyz"
_IMPL_CODE = {"alltoall": "a", "ring": "r", "pairwise": "p"}
_CODE_IMPL = {v: k for k, v in _IMPL_CODE.items()}
_COMM_RE = re.compile(r"^t(\d+)s(\d)c(\d)h(\d)([arp])?(?:k(\d+))?$")


@dataclasses.dataclass(frozen=True)
class StageSpec:
    """One stage of a searched pipeline, symbolically.

    ``fft`` is a grid dim (0..2) or None; ``comm`` indexes
    ``decomp.axes`` (which communicator transposes) or None; ``split`` /
    ``concat`` / ``chunk`` are grid dims of the transpose (split gains
    the communicator's shards, concat loses them, chunk is the
    uninvolved axis the executor K-chunks along); ``impl`` / ``k`` are
    per-stage overrides of ``opts.transpose_impl`` / ``opts.overlap_k``
    (None = inherit the plan-wide knob).
    """

    fft: Optional[int] = None
    comm: Optional[int] = None
    split: int = 0
    concat: int = 0
    chunk: int = 0
    impl: Optional[str] = None
    k: Optional[int] = None

    def token(self) -> str:
        parts = []
        if self.fft is not None:
            parts.append(f"f{self.fft}")
        if self.comm is not None:
            t = f"t{self.comm}s{self.split}c{self.concat}h{self.chunk}"
            if self.impl is not None:
                t += _IMPL_CODE[self.impl]
            if self.k is not None:
                t += f"k{self.k}"
            parts.append(t)
        return ".".join(parts)

    @classmethod
    def from_token(cls, tok: str) -> "StageSpec":
        fft = comm = impl = k = None
        split = concat = chunk = 0
        saw_comm = False
        for part in tok.split("."):
            if re.fullmatch(r"f[0-2]", part) and fft is None and not saw_comm:
                fft = int(part[1:])
                continue
            m = _COMM_RE.match(part)
            if m is None or saw_comm:
                raise ValueError(f"malformed stage token {tok!r}")
            saw_comm = True
            comm, split, concat, chunk = (int(m.group(i)) for i in (1, 2, 3, 4))
            if m.group(5):
                impl = _CODE_IMPL[m.group(5)]
            if m.group(6):
                k = int(m.group(6))
        if fft is None and not saw_comm:
            raise ValueError(f"empty stage token {tok!r}")
        if saw_comm and (split == concat or chunk in (split, concat)):
            raise ValueError(f"degenerate transpose in stage token {tok!r}")
        return cls(fft=fft, comm=comm, split=split, concat=concat,
                   chunk=chunk, impl=impl, k=k)

    def name(self) -> str:
        """Builder-style stage name (``x-fft+xy`` / ``move-yz`` / ``z-fft``)."""
        if self.comm is None:
            return f"{_GRID[self.fft]}-fft"
        move = f"{_GRID[self.split]}{_GRID[self.concat]}"
        if self.fft is not None:
            return f"{_GRID[self.fft]}-fft+{move}"
        return f"move-{move}"


@dataclasses.dataclass(frozen=True)
class ScheduleCandidate:
    """A searched pipeline: an explicit stage list over a decomposition.

    Duck-types :class:`Candidate` everywhere the tuner needs (``decomp``,
    ``opts``, ``problem``, ``strategy``, ``plan_key``, ``label``) and
    adds ``build_schedule()`` — consumers that care (cost model, measure,
    ``Croft3D``) dispatch on ``is_schedule`` / ``build_schedule``.
    Always a forward (sign=-1) pipeline starting from the natural layout;
    the inverse is derived (``distributed.inverse_schedule``).
    """

    decomp: Decomposition
    opts: FFTOptions
    stages: tuple                 # of StageSpec
    problem: str = "c2c"

    is_schedule = True            # duck-type marker
    strategy = None               # Candidate-compat (schedule search = c2c)

    def __post_init__(self):
        if self.problem not in SCHED_PROBLEMS:
            raise ValueError(f"schedule candidates cover {SCHED_PROBLEMS}, "
                             f"got {self.problem!r}")

    # -- canonical string form ----------------------------------------------
    @property
    def plan_key(self) -> str:
        key = (SCHED_PREFIX + self.decomp.to_token() + "|"
               + self.opts.to_token() + "|"
               + ";".join(sp.token() for sp in self.stages))
        if self.problem != "c2c":
            key += f"|{self.problem}:"
        return key

    @classmethod
    def from_plan_key(cls, key: str) -> "ScheduleCandidate":
        """Inverse of :attr:`plan_key` (ValueError = cache miss upstream)."""
        if not key.startswith(SCHED_PREFIX):
            raise ValueError(f"not a schedule plan key: {key!r}")
        parts = key[len(SCHED_PREFIX):].split("|")
        if len(parts) not in (3, 4):
            raise ValueError(f"malformed schedule plan key {key!r}")
        decomp = Decomposition.from_token(parts[0])
        opts = FFTOptions.from_token(parts[1])
        stages = tuple(StageSpec.from_token(t)
                       for t in parts[2].split(";") if t)
        if not stages:
            raise ValueError(f"schedule plan key {key!r} has no stages")
        problem = "c2c"
        if len(parts) == 4:
            problem, _, strategy = parts[3].partition(":")
            if problem not in SCHED_PROBLEMS or strategy:
                raise ValueError(f"unknown problem tail {parts[3]!r} in "
                                 f"schedule plan key {key!r}")
        for sp in stages:
            if sp.comm is not None and sp.comm >= len(decomp.axes):
                raise ValueError(f"stage communicator {sp.comm} out of range "
                                 f"for {decomp.to_token()}")
        return cls(decomp, opts, stages, problem=problem)

    @property
    def label(self) -> str:
        impls = sorted({sp.impl for sp in self.stages
                        if sp.impl is not None} | {self.opts.transpose_impl})
        return (f"sched:{self.decomp.kind}[{len(self.stages)}st]/"
                f"k{self.opts.overlap_k}/{'+'.join(impls)}/"
                f"{self.opts.output_layout}"
                + (f"/{self.problem}" if self.problem != "c2c" else ""))

    # -- realization ---------------------------------------------------------
    def build_schedule(self, sign: int = -1):
        """The concrete :class:`~repro.core.schedule.Schedule`; raises
        ``ScheduleError`` for pipelines the layout propagation rejects."""
        from repro.core import schedule as schedule_lib
        stages = []
        n_fft = 0
        for sp in self.stages:
            stages.append(schedule_lib.Stage(
                sp.name(), fft_axis=sp.fft,
                comm_axis=(None if sp.comm is None
                           else self.decomp.axes[sp.comm]),
                split_axis=sp.split, concat_axis=sp.concat,
                chunk_axis=sp.chunk,
                impl_stage=min(n_fft, 2) if sp.fft is not None else 0,
                transpose_impl=sp.impl, overlap_k=sp.k))
            if sp.fft is not None:
                n_fft += 1
        return schedule_lib.Schedule(
            "sched/" + self.decomp.to_token(), sign,
            schedule_lib.layout_for(self.decomp, "natural"), tuple(stages))

    def validate(self, shape: Sequence[int],
                 axis_sizes: Mapping[str, int]) -> None:
        """Raise unless this pipeline can execute at the concrete shape
        (layout propagation + shard divisibility + per-stage impl rules).
        The fixed-builder chunk checks in ``Decomposition.validate`` do
        not apply here: searched orders chunk along their own axes, and
        the executor falls back to K=1 per stage when one doesn't divide."""
        sched = self.build_schedule()
        for sp in self.stages:
            if sp.comm is None:
                continue
            impl = sp.impl if sp.impl is not None else self.opts.transpose_impl
            if impl in ("ring", "pairwise") and isinstance(
                    self.decomp.axes[sp.comm], tuple):
                raise ValueError(f"{impl} transpose supports single mesh "
                                 f"axes only (stage {sp.token()!r})")
        if not _layouts_divisible(sched, shape, axis_sizes):
            raise ValueError(f"schedule {self.plan_key!r} has non-divisible "
                             f"layouts for shape {tuple(shape)}")

    def stage_summary(self) -> str:
        """Human-readable pipeline rendering for the wisdom CLI: stage
        names with each comm stage's resolved impl and K."""
        bits = []
        for sp in self.stages:
            b = sp.name()
            if sp.comm is not None:
                impl = sp.impl if sp.impl is not None \
                    else self.opts.transpose_impl
                k = sp.k if sp.k is not None else self.opts.overlap_k
                b += f"[{impl},K={k}]"
            bits.append(b)
        return " -> ".join(bits)

    # -- canonicalization / dedup -------------------------------------------
    def normalized(self) -> "ScheduleCandidate":
        """Fold homogeneous per-stage overrides into the base options and
        drop overrides equal to them, so candidates that run the exact
        same program serialize to the exact same plan token."""
        comm = [sp for sp in self.stages if sp.comm is not None]
        if not comm:
            return self
        opts = self.opts
        impls = {sp.impl if sp.impl is not None else opts.transpose_impl
                 for sp in comm}
        if len(impls) == 1:
            opts = dataclasses.replace(opts, transpose_impl=impls.pop())
        ks = {sp.k if sp.k is not None else opts.overlap_k for sp in comm}
        if len(ks) == 1:
            opts = dataclasses.replace(opts, overlap_k=ks.pop())
        stages = []
        for sp in self.stages:
            if sp.comm is None:
                stages.append(sp)
                continue
            impl = sp.impl if sp.impl is not None else opts.transpose_impl
            k = sp.k if sp.k is not None else opts.overlap_k
            stages.append(dataclasses.replace(
                sp, impl=None if impl == opts.transpose_impl else impl,
                k=None if k == opts.overlap_k else k))
        return dataclasses.replace(self, opts=opts, stages=tuple(stages))

    def as_options_candidate(self) -> Optional[Candidate]:
        """The equivalent fixed-builder :class:`Candidate` when this
        pipeline is expressible in the options space, else None — the
        dedup hook that keeps the searcher from re-measuring plans the
        knob enumeration already covers."""
        norm = self.normalized()
        if any(sp.impl is not None or sp.k is not None for sp in norm.stages):
            return None
        sig = tuple((sp.fft, sp.comm, sp.split, sp.concat, sp.chunk)
                    for sp in norm.stages)
        for layout in ("natural", "spectral"):
            opts = dataclasses.replace(norm.opts, output_layout=layout)
            try:
                fixed = build_schedule(self.decomp, opts, sign=-1)
                fsig = tuple(
                    (st.fft_axis,
                     None if st.comm_axis is None
                     else self.decomp.axes.index(st.comm_axis),
                     st.split_axis, st.concat_axis, st.chunk_axis)
                    for st in fixed.stages)
            except Exception:   # no such fixed pipeline (ScheduleError etc.)
                continue
            if fsig == sig and not any(st.prologue or st.epilogue
                                       for st in fixed.stages):
                return Candidate(self.decomp, opts, problem=norm.problem)
        return None

    @classmethod
    def from_candidate(cls, cand: Candidate) -> "ScheduleCandidate":
        """Wrap a fixed-builder candidate as a (no-override) schedule
        candidate, so fixed and searched plans can be priced by the same
        per-stage cost walk.  ValueError for pipelines with packing ops
        or communicators outside ``decomp.axes`` (cell's folded regroup)."""
        if split_grad(cand.problem)[0] != "c2c":
            raise ValueError("only c2c candidates wrap as schedules")
        sched = build_schedule(cand.decomp, cand.opts, sign=-1)
        specs = []
        for st in sched.stages:
            if st.prologue or st.epilogue:
                raise ValueError(f"stage {st.name!r} carries packing ops")
            try:
                comm = (None if st.comm_axis is None
                        else cand.decomp.axes.index(st.comm_axis))
            except ValueError:
                raise ValueError(f"stage {st.name!r} transposes over a "
                                 "communicator outside decomp.axes")
            specs.append(StageSpec(fft=st.fft_axis, comm=comm,
                                   split=st.split_axis, concat=st.concat_axis,
                                   chunk=st.chunk_axis))
        return cls(cand.decomp, cand.opts, tuple(specs), problem=cand.problem)


def candidate_from_plan_key(key: str):
    """Parse either candidate form from its plan token (the single entry
    point wisdom and the serve cache use)."""
    if key.startswith(SCHED_PREFIX):
        return ScheduleCandidate.from_plan_key(key)
    return Candidate.from_plan_key(key)


def _layouts_divisible(sched, shape: Sequence[int],
                       axis_sizes: Mapping[str, int]) -> bool:
    """True when every stage-point layout tiles the shape exactly (the
    shard product of each dim divides its global extent) — the concrete-
    shape validity check the symbolic propagation cannot do."""
    sizes = dict(axis_sizes)
    for pts in sched.points:
        for lay in (pts.entry, pts.comm, pts.out):
            for ax, n in zip(lay.axes, shape[-3:]):
                denom = math.prod(sizes[s] for s in ax.shards) * ax.den
                if n % denom:
                    return False
    return True


def dedupe_candidates(cands: Sequence) -> list:
    """Drop candidates that serialize to the same plan token, collapsing
    searched pipelines onto their options-space equivalent when one
    exists (a mixed per-stage tuple can normalize to a homogeneous
    candidate that is already in the list — without this, the planner
    costs and measures the identical executable twice)."""
    out, seen = [], set()
    for c in cands:
        if getattr(c, "is_schedule", False):
            c = c.normalized()
            eq = c.as_options_candidate()
            if eq is not None:
                c = eq
        key = c.plan_key
        if key in seen:
            continue
        seen.add(key)
        out.append(c)
    return out


def _orders(decomp: Decomposition, layouts: Sequence[str],
            max_transposes: int) -> list:
    """Enumerate transpose orders as (moves, final_layout) pairs.

    A move is ``("fft", dim)`` or ``("move", comm, src_dim, dst_dim)``.
    The walk is over symbolic states (which dim each communicator
    currently shards + which dims are transformed): FFT any free
    untransformed dim, or move a communicator to any free dim.  Once all
    three dims are transformed the state is a spectral-layout result;
    continuing home (each communicator back to its natural dim) yields
    the natural-layout result.  Pruned: revisited states within a path,
    back-to-back moves of the same communicator (a wasted round trip),
    and more than ``max_transposes`` moves total.
    """
    init = {"slab": (2,), "pencil": (1, 2)}[decomp.kind]
    n = len(init)
    results = []

    def rec(pos, ffted, moves, visited, last_moved):
        n_moves = sum(1 for m in moves if m[0] == "move")
        if len(ffted) == 3:
            kind = "natural" if pos == init else "spectral"
            if kind in layouts:
                results.append((moves, kind))
            if "natural" not in layouts or pos == init \
                    or n_moves >= max_transposes:
                return
            # restore phase: only home-bound moves remain
            for c in range(n):
                home = init[c]
                if pos[c] == home or home in pos:
                    continue
                npos = pos[:c] + (home,) + pos[c + 1:]
                rec(npos, ffted, moves + ((("move", c, pos[c], home),)),
                    visited, c)
            return
        for d in range(3):
            if d not in ffted and d not in pos:
                rec(pos, ffted | {d}, moves + ((("fft", d),)), visited, None)
        if n_moves >= max_transposes:
            return
        for c in range(n):
            if c == last_moved:
                continue
            for dst in range(3):
                if dst == pos[c] or dst in pos:
                    continue
                npos = pos[:c] + (dst,) + pos[c + 1:]
                state = (npos, frozenset(ffted))
                if state in visited:
                    continue
                rec(npos, ffted, moves + ((("move", c, pos[c], dst),)),
                    visited | {state}, c)

    start = (init, frozenset())
    rec(init, frozenset(), (), {start}, None)
    return results


def _pack_stages(moves: tuple, fuse: bool) -> tuple:
    """Turn a move sequence into a StageSpec tuple.

    ``fuse=True`` merges each FFT into the immediately following
    transpose when the FFT dim takes part in it (the builders' fused
    ``x-fft+xy`` shape — legal because the forced chunk axis is the
    third dim, never the FFT dim); ``fuse=False`` keeps every FFT and
    transpose as its own stage (more, smaller pipeline steps).
    """
    stages, pending_fft = [], None
    for mv in moves:
        if mv[0] == "fft":
            if pending_fft is not None:
                stages.append(StageSpec(fft=pending_fft))
            pending_fft = mv[1]
            continue
        _, c, src, dst = mv
        chunk = 3 - src - dst
        if fuse and pending_fft is not None and pending_fft in (src, dst):
            stages.append(StageSpec(fft=pending_fft, comm=c, split=dst,
                                    concat=src, chunk=chunk))
            pending_fft = None
        else:
            if pending_fft is not None:
                stages.append(StageSpec(fft=pending_fft))
                pending_fft = None
            stages.append(StageSpec(comm=c, split=dst, concat=src,
                                    chunk=chunk))
    if pending_fft is not None:
        stages.append(StageSpec(fft=pending_fft))
    return tuple(stages)


def _override_combos(stages: tuple, decomp: Decomposition,
                     sched, shape, axis_sizes,
                     stage_impls: Sequence[str],
                     overlap_ks: Sequence[int]) -> Iterator[tuple]:
    """(impl, k) override assignments per comm stage.

    With <= 2 comm stages (every spectral-layout order) the full product
    is small and exhaustive; beyond that (natural orders with restores)
    the space is pruned to homogeneous assignments plus the structured
    mixed points that motivate the search: ring on the smallest
    communicator / alltoall elsewhere (and the inverse), and the largest
    K from ``overlap_ks`` that divides each stage's own chunk extent.
    """
    comm_ids = [i for i, sp in enumerate(stages) if sp.comm is not None]
    per_stage_impls = []
    for i in comm_ids:
        folded = isinstance(decomp.axes[stages[i].comm], tuple)
        per_stage_impls.append(tuple(
            im for im in stage_impls
            if im == "alltoall" or not folded))
    sizes = dict(axis_sizes)
    csizes = [math.prod(sizes[s] for s in _flatten(decomp.axes[stages[i].comm]))
              for i in comm_ids]
    exts = {}
    ci = 0
    for j, st in enumerate(sched.stages):
        if st.comm_axis is not None:
            exts[comm_ids[ci]] = sched.points[j].entry.local_shape(
                shape, axis_sizes)[st.chunk_axis]
            ci += 1
    fit_ks = tuple(max((k for k in overlap_ks if exts[i] % k == 0),
                       default=1) for i in comm_ids)
    if len(comm_ids) <= 2:
        impl_combos = list(itertools.product(*per_stage_impls))
        k_combos = list(itertools.product(overlap_ks, repeat=len(comm_ids)))
    else:
        impl_combos = {tuple("alltoall" for _ in comm_ids)}
        if all("ring" in ch for ch in per_stage_impls):
            impl_combos.add(tuple("ring" for _ in comm_ids))
            small = min(csizes)
            impl_combos.add(tuple("ring" if cs == small else "alltoall"
                                  for cs in csizes))
            impl_combos.add(tuple("alltoall" if cs == small else "ring"
                                  for cs in csizes))
        impl_combos = sorted(impl_combos)
        k_combos = sorted({tuple(k for _ in comm_ids) for k in overlap_ks}
                          | {fit_ks})
    for impls in impl_combos:
        for ks in k_combos:
            yield comm_ids, impls, ks


def _flatten(axis) -> tuple:
    if isinstance(axis, tuple):
        out = []
        for a in axis:
            out.extend(_flatten(a))
        return tuple(out)
    return (axis,)


def enumerate_schedule_candidates(
        shape: Sequence[int],
        axis_sizes: Mapping[str, int],
        *,
        overlap_ks: Sequence[int] = DEFAULT_OVERLAP_KS,
        stage_impls: Sequence[str] = ("alltoall", "ring"),
        local_impl="matmul",
        layouts: Sequence[str] = DEFAULT_LAYOUTS,
        problem: str = "c2c",
        max_transposes: int = 4,
) -> list[ScheduleCandidate]:
    """The schedule-space search: every buildable pipeline over every
    slab/pencil decomposition — alternative transpose orders (including
    z-first spectral orders), fused vs split FFT/transpose stages, and
    per-stage impl/K overrides — normalized and deduped by plan token.

    Candidates already expressible by the fixed builders are *excluded*
    (they are exactly the knob space ``enumerate_candidates`` emits; the
    planner unions both lists and ``dedupe_candidates`` keeps one copy).
    Cell decompositions are out of scope: their regroup/scatter stages
    carry packing ops the symbolic move space does not model.
    """
    if problem not in SCHED_PROBLEMS:
        raise ValueError(f"schedule search covers {SCHED_PROBLEMS}, "
                         f"got {problem!r}")
    out, seen = [], set()
    for dec in decompositions_for(shape, axis_sizes, overlap_k=1):
        if dec.kind == "cell":
            continue
        for moves, layout_kind in _orders(dec, layouts, max_transposes):
            for fuse in (True, False):
                stages = _pack_stages(moves, fuse)
                base_opts = FFTOptions(overlap_k=1, local_impl=local_impl,
                                       output_layout=layout_kind,
                                       transpose_impl="alltoall")
                probe = ScheduleCandidate(dec, base_opts, stages,
                                          problem=problem)
                try:
                    sched = probe.build_schedule()
                except Exception:
                    continue
                if not _layouts_divisible(sched, shape, axis_sizes):
                    continue
                for comm_ids, impls, ks in _override_combos(
                        stages, dec, sched, shape, axis_sizes,
                        stage_impls, overlap_ks):
                    spec = list(stages)
                    for i, im, k in zip(comm_ids, impls, ks):
                        spec[i] = dataclasses.replace(spec[i], impl=im, k=k)
                    cand = ScheduleCandidate(dec, base_opts, tuple(spec),
                                             problem=problem).normalized()
                    if cand.as_options_candidate() is not None:
                        continue
                    if cand.plan_key in seen:
                        continue
                    seen.add(cand.plan_key)
                    out.append(cand)
    return out
