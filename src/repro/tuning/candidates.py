"""Candidate enumeration: every valid (Decomposition, FFTOptions) pair.

The planner's search space is the cross product of

  * how the grid maps onto the mesh — slab / pencil / cell, over every
    ordered assignment of mesh axes (and folded axis groups) that covers
    the whole mesh, and
  * the ``FFTOptions`` knob matrix — overlap K, local 1-D FFT
    implementation, output layout, transpose implementation,

filtered by :meth:`Decomposition.validate` (divisibility, P <= N limits,
overlap chunking).  Everything here is pure arithmetic over axis *sizes*,
so candidates can be generated with no devices present.
"""

from __future__ import annotations

import dataclasses
import itertools
import math
from typing import Iterator, Mapping, Optional, Sequence

from repro.core.decomposition import Decomposition
from repro.core.distributed import FFTOptions

# default knob ranges; "pallas" is intentionally absent (TPU-only kernel —
# callers on TPU pass local_impls=(..., "pallas") explicitly)
DEFAULT_OVERLAP_KS = (1, 2, 4)
DEFAULT_LOCAL_IMPLS = ("matmul", "stockham", "xla")
DEFAULT_LAYOUTS = ("natural", "spectral")


@dataclasses.dataclass(frozen=True)
class Candidate:
    """One point of the search space."""

    decomp: Decomposition
    opts: FFTOptions

    @property
    def label(self) -> str:
        def axis_str(a):
            if isinstance(a, tuple):
                return "+".join(a)
            return a
        axes = "x".join(axis_str(a) for a in self.decomp.axes)
        o = self.opts
        return (f"{self.decomp.kind}[{axes}]/k{o.overlap_k}/{o.local_impl}/"
                f"{o.output_layout}/{o.transpose_impl}"
                + ("" if o.plan_cache else "/noplan"))


def _groupings(names: Sequence[str], k: int) -> Iterator[tuple]:
    """Ordered partitions of ``names`` into k non-empty groups.

    Each group becomes one Decomposition axis entry: a bare name when the
    group is a single axis, a folded tuple otherwise.  Every grouping
    covers the whole mesh — leaving an axis out would replicate the grid
    over it (never faster, so not part of the search space).
    """
    if len(names) < k:
        return
    for assignment in itertools.product(range(k), repeat=len(names)):
        if set(assignment) != set(range(k)):
            continue
        groups = []
        for g in range(k):
            members = tuple(n for n, a in zip(names, assignment) if a == g)
            groups.append(members[0] if len(members) == 1 else members)
        yield tuple(groups)


def decompositions_for(shape: Sequence[int], axis_sizes: Mapping[str, int],
                       overlap_k: int = 1) -> list[Decomposition]:
    """All decompositions valid for (shape, mesh axes) at the given K."""
    names = list(axis_sizes)
    out: list[Decomposition] = []
    for kind, slots in (("slab", 1), ("pencil", 2), ("cell", 3)):
        for axes in _groupings(names, slots):
            dec = Decomposition(kind, axes)
            if dec.is_valid(shape, axis_sizes, overlap_k):
                out.append(dec)
    return out


def enumerate_candidates(
        shape: Sequence[int],
        axis_sizes: Mapping[str, int],
        *,
        overlap_ks: Sequence[int] = DEFAULT_OVERLAP_KS,
        local_impls: Sequence[str] = DEFAULT_LOCAL_IMPLS,
        layouts: Sequence[str] = DEFAULT_LAYOUTS,
        include_baselines: bool = False,
) -> list[Candidate]:
    """The full valid search space, deterministically ordered.

    ``include_baselines`` adds configurations that model the paper's
    baselines and are never expected to win — ``transpose_impl="pairwise"``
    (FFTW3's sendrecv pattern) and ``plan_cache=False`` (options 1/3) —
    useful for benchmark sweeps, noise for production tuning.
    """
    out: list[Candidate] = []
    for k in overlap_ks:
        for dec in decompositions_for(shape, axis_sizes, overlap_k=k):
            for impl in local_impls:
                for layout in layouts:
                    if layout == "spectral" and dec.kind == "cell":
                        continue  # cell pipeline restores natural layout
                    variants = [dict(transpose_impl="alltoall",
                                     plan_cache=True)]
                    if include_baselines:
                        variants.append(dict(transpose_impl="alltoall",
                                             plan_cache=False))
                        if all(not isinstance(a, tuple) for a in dec.axes):
                            variants.append(dict(transpose_impl="pairwise",
                                                 plan_cache=True))
                    for var in variants:
                        out.append(Candidate(dec, FFTOptions(
                            overlap_k=k, local_impl=impl,
                            output_layout=layout, **var)))
    return out


def default_candidate(shape: Sequence[int],
                      axis_sizes: Mapping[str, int]) -> Optional[Candidate]:
    """What an untuned caller would pick: the decomposition kind matching
    the mesh rank (slab for 1 axis, pencil for 2, cell for 3, folded
    pencil otherwise) with stock ``FFTOptions()``.  None if invalid for
    the shape."""
    names = list(axis_sizes)
    if len(names) == 1:
        dec = Decomposition("slab", (names[0],))
    elif len(names) == 2:
        dec = Decomposition("pencil", tuple(names))
    elif len(names) == 3:
        dec = Decomposition("cell", tuple(names))
    else:
        dec = Decomposition("pencil", (tuple(names[:-1]), names[-1]))
    opts = FFTOptions()
    if not dec.is_valid(shape, axis_sizes, opts.overlap_k):
        if not dec.is_valid(shape, axis_sizes, 1):
            return None
        opts = dataclasses.replace(opts, overlap_k=1)
    return Candidate(dec, opts)
