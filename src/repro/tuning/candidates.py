"""Candidate enumeration: every valid (Decomposition, FFTOptions) pair.

The planner's search space is the cross product of

  * how the grid maps onto the mesh — slab / pencil / cell, over every
    ordered assignment of mesh axes (and folded axis groups) that covers
    the whole mesh, and
  * the ``FFTOptions`` knob matrix — overlap K, local 1-D FFT
    implementation (optionally per pipeline stage), output layout,
    transpose implementation,

filtered by :meth:`Decomposition.validate` (divisibility, P <= N limits,
overlap chunking).  ``problem="r2c"`` additionally enumerates the real-
transform strategy axis: every c2c candidate as an "embed" plan, plus a
"packed" two-for-one plan wherever ``repro.real`` supports it.
Everything here is pure arithmetic over axis *sizes*, so candidates can
be generated with no devices present.
"""

from __future__ import annotations

import dataclasses
import itertools
import math
from typing import Iterator, Mapping, Optional, Sequence

from repro.core.decomposition import Decomposition
from repro.core.distributed import FFTOptions

# default knob ranges; "pallas" is intentionally absent (TPU-only kernel —
# callers on TPU pass local_impls=(..., "pallas") explicitly)
DEFAULT_OVERLAP_KS = (1, 2, 4)
DEFAULT_LOCAL_IMPLS = ("matmul", "stockham", "xla")
DEFAULT_LAYOUTS = ("natural", "spectral")
#: the ``_grad`` problems plan a *training step*: same search space as
#: their base problem, but the cost model prices forward + adjoint
#: schedule and measurement times ``jax.grad`` through the transform
PROBLEMS = ("c2c", "r2c", "c2c_grad", "r2c_grad")
GRAD_SUFFIX = "_grad"


def split_grad(problem: str) -> tuple:
    """``"r2c_grad" -> ("r2c", True)``; base problems pass through."""
    if problem.endswith(GRAD_SUFFIX):
        return problem[: -len(GRAD_SUFFIX)], True
    return problem, False


def _impl_str(impl) -> str:
    if isinstance(impl, tuple):
        return "-".join(impl)
    return impl


@dataclasses.dataclass(frozen=True)
class Candidate:
    """One point of the search space."""

    decomp: Decomposition
    opts: FFTOptions
    #: problem class this plan solves
    problem: str = "c2c"
    #: r2c only: "packed" | "embed"
    strategy: Optional[str] = None

    @property
    def label(self) -> str:
        def axis_str(a):
            if isinstance(a, tuple):
                return "+".join(a)
            return a
        axes = "x".join(axis_str(a) for a in self.decomp.axes)
        o = self.opts
        base = (f"{self.decomp.kind}[{axes}]/k{o.overlap_k}/"
                f"{_impl_str(o.local_impl)}/"
                f"{o.output_layout}/{o.transpose_impl}"
                + ("" if o.overlap_mode == "pipelined"
                   else f"/{_impl_str(o.overlap_mode)}")
                + ("" if o.plan_cache else "/noplan"))
        if self.problem != "c2c":
            base += f"/{self.problem}" + (f"-{self.strategy}"
                                          if self.strategy else "")
        return base

    # -- canonical string form ----------------------------------------------
    #
    # ``label`` is for humans (it elides default knobs); ``plan_key`` is
    # for caches: it covers every field that changes the compiled
    # executable and round trips exactly, including the per-stage
    # ``local_impl``/``overlap_mode`` 3-tuples.

    @property
    def plan_key(self) -> str:
        key = f"{self.decomp.to_token()}|{self.opts.to_token()}"
        if self.problem != "c2c":
            # strategy may be None (grad c2c plans) — emit the empty
            # string so from_plan_key round-trips it back to None
            key += f"|{self.problem}:{self.strategy or ''}"
        return key

    @classmethod
    def from_plan_key(cls, key: str) -> "Candidate":
        """Inverse of :attr:`plan_key`."""
        parts = key.split("|")
        if len(parts) not in (2, 3):
            raise ValueError(f"malformed plan key {key!r}")
        decomp = Decomposition.from_token(parts[0])
        opts = FFTOptions.from_token(parts[1])
        if len(parts) == 2:
            return cls(decomp, opts)
        problem, _, strategy = parts[2].partition(":")
        if problem not in PROBLEMS:
            # reject rather than construct a plan for a problem class this
            # version cannot build (e.g. a key written by a newer version)
            # — callers treat ValueError as a cache miss, not a crash
            raise ValueError(f"unknown problem {problem!r} in plan key "
                             f"{key!r} (known: {PROBLEMS})")
        return cls(decomp, opts, problem=problem,
                   strategy=strategy or None)


def _groupings(names: Sequence[str], k: int) -> Iterator[tuple]:
    """Ordered partitions of ``names`` into k non-empty groups.

    Each group becomes one Decomposition axis entry: a bare name when the
    group is a single axis, a folded tuple otherwise.  Every grouping
    covers the whole mesh — leaving an axis out would replicate the grid
    over it (never faster, so not part of the search space).
    """
    if len(names) < k:
        return
    for assignment in itertools.product(range(k), repeat=len(names)):
        if set(assignment) != set(range(k)):
            continue
        groups = []
        for g in range(k):
            members = tuple(n for n, a in zip(names, assignment) if a == g)
            groups.append(members[0] if len(members) == 1 else members)
        yield tuple(groups)


def decompositions_for(shape: Sequence[int], axis_sizes: Mapping[str, int],
                       overlap_k: int = 1) -> list[Decomposition]:
    """All decompositions valid for (shape, mesh axes) at the given K."""
    names = list(axis_sizes)
    out: list[Decomposition] = []
    for kind, slots in (("slab", 1), ("pencil", 2), ("cell", 3)):
        for axes in _groupings(names, slots):
            dec = Decomposition(kind, axes)
            if dec.is_valid(shape, axis_sizes, overlap_k):
                out.append(dec)
    return out


def _stagewise_impls(local_impls: Sequence) -> list:
    """Heterogeneous per-stage combinations (ROADMAP follow-on): every
    3-tuple over ``local_impls`` whose entries are not all equal (the
    homogeneous ones are already in the base space as scalars)."""
    singles = [i for i in local_impls if not isinstance(i, (tuple, list))]
    return [combo for combo in itertools.product(singles, repeat=3)
            if len(set(combo)) > 1]


def enumerate_candidates(
        shape: Sequence[int],
        axis_sizes: Mapping[str, int],
        *,
        overlap_ks: Sequence[int] = DEFAULT_OVERLAP_KS,
        local_impls: Sequence[str] = DEFAULT_LOCAL_IMPLS,
        layouts: Sequence[str] = DEFAULT_LAYOUTS,
        include_baselines: bool = False,
        heterogeneous_impls: bool = False,
        problem: str = "c2c",
) -> list[Candidate]:
    """The full valid search space, deterministically ordered.

    ``include_baselines`` adds configurations that model the paper's
    baselines and are never expected to win — ``transpose_impl="pairwise"``
    (FFTW3's sendrecv pattern) and ``plan_cache=False`` (options 1/3) —
    useful for benchmark sweeps, noise for production tuning.

    ``heterogeneous_impls`` widens the ``local_impl`` axis with per-stage
    3-tuples (e.g. matmul on the contiguous first stage, Stockham on the
    strided ones).

    ``problem="r2c"`` returns real-transform candidates: each valid c2c
    point as an "embed" plan plus a "packed" two-for-one plan where the
    packed pipeline's constraints hold (pencil or slab decomposition,
    even divisibility — see ``repro.real.packed_unsupported_reason``).
    """
    if problem not in PROBLEMS:
        raise ValueError(f"problem must be one of {PROBLEMS}, got {problem!r}")
    base_problem, is_grad = split_grad(problem)
    impls = list(local_impls)
    if heterogeneous_impls:
        impls += _stagewise_impls(local_impls)
    out: list[Candidate] = []
    for k in overlap_ks:
        for dec in decompositions_for(shape, axis_sizes, overlap_k=k):
            for impl in impls:
                for layout in layouts:
                    if layout == "spectral" and dec.kind == "cell":
                        continue  # cell pipeline restores natural layout
                    variants = [dict(transpose_impl="alltoall",
                                     plan_cache=True)]
                    # ring / pairwise ppermute over ONE mesh axis: folded
                    # axes and the cell regroup (which runs the pencil
                    # pipeline over a folded (y, x) communicator) are
                    # rejected by Decomposition.validate — never emit
                    # candidates that cannot trace
                    single_axes = (dec.kind != "cell" and all(
                        not isinstance(a, tuple) for a in dec.axes))
                    if single_axes:
                        # the ring pipeline is a real contender (it
                        # overlaps even when no chunk axis divides), so
                        # it is part of the production search space —
                        # the cost model's latency/bandwidth split ranks
                        # it, not a hardcoded preference
                        variants.append(dict(transpose_impl="ring",
                                             plan_cache=True))
                    if include_baselines:
                        variants.append(dict(transpose_impl="alltoall",
                                             plan_cache=False))
                        if single_axes:
                            variants.append(dict(transpose_impl="pairwise",
                                                 plan_cache=True))
                    for var in variants:
                        out.append(Candidate(dec, FFTOptions(
                            overlap_k=k, local_impl=impl,
                            output_layout=layout, **var)))
    if base_problem == "r2c":
        out = _realize_r2c(shape, axis_sizes, out)
    if is_grad:
        # same physical plans; the problem tag switches the cost model to
        # fwd+adjoint pricing and measurement to a value_and_grad step
        out = [dataclasses.replace(c, problem=problem) for c in out]
    return out


def _realize_r2c(shape, axis_sizes, base: list[Candidate]) -> list[Candidate]:
    """Map a c2c candidate list onto the r2c strategy axis.

    The packed pipelines (pencil and slab) ignore ``output_layout`` (they
    always start from the z-local spectral layout and never pay restoring
    transposes), so the packed variant rides only on the spectral-layout
    points to avoid duplicate plans.
    """
    from repro.real import packed_unsupported_reason
    out: list[Candidate] = []
    for c in base:
        out.append(dataclasses.replace(c, problem="r2c", strategy="embed"))
        if (c.opts.output_layout == "spectral"
                and packed_unsupported_reason(shape, c.decomp, axis_sizes,
                                              c.opts) is None):
            out.append(dataclasses.replace(c, problem="r2c",
                                           strategy="packed"))
    return out


def default_candidate(shape: Sequence[int], axis_sizes: Mapping[str, int],
                      problem: str = "c2c") -> Optional[Candidate]:
    """What an untuned caller would pick: the decomposition kind matching
    the mesh rank (slab for 1 axis, pencil for 2, cell for 3, folded
    pencil otherwise) with stock ``FFTOptions()``.  None if invalid for
    the shape."""
    names = list(axis_sizes)
    if len(names) == 1:
        dec = Decomposition("slab", (names[0],))
    elif len(names) == 2:
        dec = Decomposition("pencil", tuple(names))
    elif len(names) == 3:
        dec = Decomposition("cell", tuple(names))
    else:
        dec = Decomposition("pencil", (tuple(names[:-1]), names[-1]))
    opts = FFTOptions()
    if not dec.is_valid(shape, axis_sizes, opts.overlap_k):
        if not dec.is_valid(shape, axis_sizes, 1):
            return None
        opts = dataclasses.replace(opts, overlap_k=1)
    base_problem, _ = split_grad(problem)
    if base_problem == "r2c":
        from repro.real import packed_unsupported_reason
        strategy = ("packed" if packed_unsupported_reason(
            shape, dec, axis_sizes, opts) is None else "embed")
        return Candidate(dec, opts, problem=problem, strategy=strategy)
    return Candidate(dec, opts, problem=problem)
