"""Persistent wisdom — FFTW's ``fftw_export_wisdom`` for the planner.

A wisdom store is a JSON file mapping problem keys to the winning
(decomposition, options) plus how the winner was chosen (model score or
measured seconds).  The key captures everything the plan depends on:

    Nx x Ny x Nz | mesh axis names+sizes | dtype | backend [| problem]

(the problem suffix appears for non-default problem classes, i.e.
``r2c`` — c2c keys keep the original four-field format so existing
wisdom files stay valid) so a plan tuned once (e.g. on the job's first
process, or in a previous run) is reused everywhere the same problem
shows up.  ``merge`` keeps the better-measured entry on key collisions,
so wisdom files can be combined across hosts like FFTW wisdom.

Command line (FFTW's ``fftw-wisdom`` tool analogue)::

    python -m repro.tuning.wisdom merge OUT.json [IN.json ...] [--seed]
    python -m repro.tuning.wisdom show PATH.json
    python -m repro.tuning.wisdom stats PATH.json

``--seed`` folds in the shipped seed wisdom (``seed_wisdom.json``,
model-mode plans for common shape/mesh/problem combinations; measured
entries from your own runs always take precedence on merge).

Concurrency: the serving plan cache's background measurement thread
writes wisdom while requests are in flight, and several service
processes may share one wisdom file.  All persistent writes therefore go
through :func:`merge_entries` — reload-latest + record + write-to-temp +
atomic rename, serialized by a lock file — so concurrent writers merge
instead of clobbering each other's entries (last-loader-wins lost
updates).
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import threading
import time
from typing import Mapping, Optional, Sequence

import numpy as np

from repro.core.decomposition import Decomposition
from repro.core.distributed import FFTOptions
from repro.resil import inject as inject_lib
from repro.tuning.candidates import Candidate

WISDOM_VERSION = 1
DEFAULT_PATH_ENV = "CROFT_WISDOM"
SEED_PATH = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                         "seed_wisdom.json")


def wisdom_key(shape: Sequence[int], axis_sizes: Mapping[str, int],
               dtype, backend: str, problem: str = "c2c",
               batch: int = 1) -> str:
    from repro.tuning.candidates import split_grad
    shape_s = "x".join(str(int(s)) for s in shape)
    # canonical order: the same problem must hash identically regardless
    # of how the caller ordered the axis mapping
    mesh_s = ",".join(f"{n}={int(s)}"
                      for n, s in sorted(axis_sizes.items()))
    key = f"{shape_s}|{mesh_s}|{np.dtype(dtype).name}|{backend}"
    base_problem, is_grad = split_grad(problem)
    if base_problem != "c2c":  # c2c keys keep the legacy four-field format
        key += f"|{base_problem}"
    if batch != 1:  # unbatched keys keep the legacy format (= b1), so
        key += f"|b{int(batch)}"  # wisdom written before the batch
        # dimension existed still hits for batch=1 problems
    if is_grad:  # training-step plans never collide with inference plans
        key += "|grad"
    return key


def _listify(axes):
    return [list(a) if isinstance(a, tuple) else a for a in axes]


def _tuplify(axes):
    return tuple(tuple(a) if isinstance(a, list) else a for a in axes)


@dataclasses.dataclass
class WisdomEntry:
    """The chosen plan for one problem key."""

    decomp_kind: str
    decomp_axes: tuple
    opts: dict                      # FFTOptions fields
    source: str                     # "model" | "measure"
    model_s: Optional[float] = None
    measured_s: Optional[float] = None
    hlo: Optional[dict] = None      # collective stats of the winner
    created: Optional[float] = None
    problem: str = "c2c"            # "c2c" | "r2c"
    strategy: Optional[str] = None  # r2c: "packed" | "embed"
    #: searched-schedule winners: the full ``sched:...`` plan token.  The
    #: legacy fields above still describe the data placement, so wisdom
    #: readers that predate the schedule search parse these entries as a
    #: (decomp, opts) plan (from_json drops the unknown key); readers
    #: that understand it reconstruct the exact pipeline from the token.
    schedule: Optional[str] = None

    def candidate(self) -> Candidate:
        if self.schedule is not None:
            from repro.tuning.candidates import ScheduleCandidate
            return ScheduleCandidate.from_plan_key(self.schedule)
        # tolerate opts written by other versions: unknown keys dropped
        known = {f.name for f in dataclasses.fields(FFTOptions)}
        opts = {k: v for k, v in self.opts.items() if k in known}
        return Candidate(Decomposition(self.decomp_kind,
                                       _tuplify(self.decomp_axes)),
                         FFTOptions(**opts), problem=self.problem,
                         strategy=self.strategy)

    @classmethod
    def from_candidate(cls, cand: Candidate, source: str,
                       model_s: Optional[float] = None,
                       measured_s: Optional[float] = None,
                       hlo: Optional[dict] = None) -> "WisdomEntry":
        return cls(decomp_kind=cand.decomp.kind,
                   decomp_axes=cand.decomp.axes,
                   opts=dataclasses.asdict(cand.opts), source=source,
                   model_s=model_s, measured_s=measured_s, hlo=hlo,
                   created=time.time(), problem=cand.problem,
                   strategy=getattr(cand, "strategy", None),
                   schedule=cand.plan_key
                   if getattr(cand, "is_schedule", False) else None)

    def to_json(self) -> dict:
        d = dataclasses.asdict(self)
        d["decomp_axes"] = _listify(self.decomp_axes)
        return d

    @classmethod
    def from_json(cls, d: dict) -> "WisdomEntry":
        d = dict(d)
        d["decomp_axes"] = _tuplify(d.get("decomp_axes", []))
        known = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in known})

    def better_of(self, other: "WisdomEntry") -> "WisdomEntry":
        """Prefer measured over modeled, then the faster measurement.
        Between two unmeasured (model) entries the newer one wins, so
        cost-model improvements propagate into existing wisdom files
        (and merging an old file back in cannot clobber fresh plans)."""
        mine, theirs = self.measured_s, other.measured_s
        if mine is None and theirs is None:
            if (other.created or 0.0) >= (self.created or 0.0):
                return other
            return self
        if mine is None:
            return other
        if theirs is None or mine <= theirs:
            return self
        return other


def _entries_checksum(entries_json: Mapping) -> str:
    """Integrity checksum over the canonical entries JSON.  A store
    whose stored checksum disagrees was truncated or bit-rotted (a
    crashed writer cannot cause this — writes are temp-file + atomic
    rename); it is moved aside and rebuilt from model mode."""
    blob = json.dumps(entries_json, sort_keys=True).encode()
    return hashlib.sha256(blob).hexdigest()


def quarantine_corrupt(path: str) -> Optional[str]:
    """Move a corrupt wisdom file aside to ``<path>.corrupt-<n>`` (first
    free n) so the evidence survives for forensics while the planner
    rebuilds from model mode.  Returns the new name, or None if another
    process won the rename (or the move failed)."""
    for n in range(1, 1000):
        dst = f"{path}.corrupt-{n}"
        if os.path.exists(dst):
            continue
        try:
            os.rename(path, dst)  # atomic: exactly one mover wins
        except OSError:
            return None
        from repro.obs import metrics as metrics_lib
        metrics_lib.get_registry().counter("wisdom_corrupt_files").inc()
        return dst
    return None


class Wisdom:
    """In-memory wisdom table with JSON import/export."""

    def __init__(self, entries: Optional[dict] = None,
                 path: Optional[str] = None):
        self.entries: dict[str, WisdomEntry] = dict(entries or {})
        self.path = path

    # -- persistence --------------------------------------------------------
    @classmethod
    def load(cls, path: Optional[str] = None) -> "Wisdom":
        """Load from ``path`` (or $CROFT_WISDOM); missing file -> empty.

        A file that fails to parse, or whose stored ``checksum`` does
        not match its entries, is *quarantined*: moved aside to
        ``<path>.corrupt-<n>`` (see :func:`quarantine_corrupt`) so the
        next planner run rebuilds clean wisdom from model mode instead
        of tripping over the same corruption forever.  Files written
        before the checksum existed load normally (no checksum field =
        nothing to verify)."""
        path = path or os.environ.get(DEFAULT_PATH_ENV)
        w = cls(path=path)
        if path and os.path.exists(path):
            try:
                with open(path) as f:
                    blob = json.load(f)
                if not isinstance(blob, dict):
                    raise ValueError("wisdom store is not a JSON object")
            except (OSError, ValueError):
                quarantine_corrupt(path)
                return w  # unreadable/corrupt file -> empty wisdom
            if blob.get("version", 0) > WISDOM_VERSION:
                # from a newer version: valid, just unknown — treat as
                # empty and re-tune, but do NOT quarantine it
                return w
            entries_json = blob.get("entries", {})
            want = blob.get("checksum")
            if want is not None and want != _entries_checksum(entries_json):
                quarantine_corrupt(path)
                return w
            for key, d in entries_json.items():
                try:
                    w.entries[key] = WisdomEntry.from_json(d)
                except (TypeError, ValueError):
                    continue  # malformed entry -> miss, not a crash
        return w

    def save(self, path: Optional[str] = None) -> Optional[str]:
        path = path or self.path
        if not path:
            return None
        os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
        entries_json = {k: e.to_json() for k, e in self.entries.items()}
        blob = {"version": WISDOM_VERSION, "entries": entries_json,
                "checksum": _entries_checksum(entries_json)}
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(blob, f, indent=1, sort_keys=True)
        # chaos site: a writer killed here leaves the store intact plus a
        # stale .tmp that the next locked merge cleans up
        inject_lib.fire("wisdom.write.crash", path)
        os.replace(tmp, path)
        return path

    # -- access -------------------------------------------------------------
    def lookup(self, key: str) -> Optional[WisdomEntry]:
        return self.entries.get(key)

    def record(self, key: str, entry: WisdomEntry) -> None:
        prev = self.entries.get(key)
        self.entries[key] = entry if prev is None else prev.better_of(entry)

    def merge(self, other: "Wisdom") -> None:
        for key, entry in other.entries.items():
            self.record(key, entry)

    def __len__(self) -> int:
        return len(self.entries)


class _FileLock:
    """Tiny advisory lock: ``path.lock`` created O_EXCL, retried with
    backoff.  Stale locks (a writer that died mid-merge) are broken after
    ``stale_s`` so a crashed upgrade thread cannot wedge the service."""

    def __init__(self, path: str, timeout: float = 10.0,
                 stale_s: float = 30.0):
        self.path, self.timeout, self.stale_s = path, timeout, stale_s

    def __enter__(self):
        deadline = time.monotonic() + self.timeout
        while True:
            try:
                fd = os.open(self.path, os.O_CREAT | os.O_EXCL | os.O_WRONLY)
                os.write(fd, str(os.getpid()).encode())
                os.close(fd)
                return self
            except FileExistsError:
                try:
                    age = time.time() - os.path.getmtime(self.path)
                    if age > self.stale_s:
                        self._break_stale()
                        continue
                except OSError:
                    continue  # holder released between stat and unlink
                if time.monotonic() > deadline:
                    raise TimeoutError(
                        f"could not acquire wisdom lock {self.path}")
                time.sleep(0.02)

    def _break_stale(self) -> None:
        """Break a dead writer's lock without unlinking a live one.

        A bare unlink races: two waiters can both observe staleness, the
        first breaks the lock and re-acquires, and the second then
        unlinks the first's *fresh* lock — two writers in the critical
        section.  Instead, rename the lock to a unique name: rename is
        atomic, so exactly one waiter wins (losers get ENOENT and
        re-loop), and the winner owns the renamed file exclusively.  It
        then re-checks staleness on the renamed file — if it actually
        stole a fresh lock (broken and re-acquired in the stat/rename
        window), it restores it via ``link``, which refuses to clobber
        any newer lock."""
        unique = f"{self.path}.stale.{os.getpid()}.{threading.get_ident()}"
        try:
            os.rename(self.path, unique)
        except OSError:
            return  # another waiter won the rename (or holder released)
        try:
            fresh = (time.time() - os.path.getmtime(unique)) <= self.stale_s
        except OSError:
            fresh = False
        if fresh:
            try:
                os.link(unique, self.path)  # EEXIST if relocked meanwhile
            except OSError:
                pass
        try:
            os.unlink(unique)
        except OSError:
            pass

    def __exit__(self, *exc):
        try:
            os.unlink(self.path)
        except OSError:
            pass


def merge_entries(path: str, entries: Mapping[str, WisdomEntry]) -> int:
    """Merge ``entries`` into the wisdom file at ``path`` atomically.

    Safe under concurrent writers: reload the latest file contents under
    a lock file, fold the new entries in (``better_of`` per key), write
    to a temp file and rename.  Returns the merged store's size.  This
    is the single write path for production wisdom — the planner's
    ``save=True`` and the serving plan cache's background measurement
    thread both land here.
    """
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    with _FileLock(path + ".lock"):
        tmp = path + ".tmp"
        if os.path.exists(tmp):
            # stale temp from a writer killed between temp-write and
            # rename; we hold the lock, so no live writer owns it
            try:
                os.unlink(tmp)
            except OSError:
                pass
        w = Wisdom.load(path)
        w.path = path
        for key, entry in entries.items():
            w.record(key, entry)
        w.save(path)
        return len(w)


def merge_files(out: str, inputs: Sequence[str],
                include_seed: bool = False) -> int:
    """CLI ``merge``: fold wisdom files into ``out`` under the same lock
    discipline as :func:`merge_entries`."""
    folded = Wisdom()
    if include_seed:
        folded.merge(load_seed())
    for p in inputs:
        folded.merge(Wisdom.load(p))
    return merge_entries(out, folded.entries)


def load_seed() -> "Wisdom":
    """The shipped seed wisdom (model-mode plans for common problems).

    Opt-in by design: ``Wisdom.load`` never folds it in automatically, so
    planner behavior stays a pure function of the caller's wisdom file —
    use ``python -m repro.tuning.wisdom merge OUT --seed`` (or merge it
    yourself) to start a cluster's wisdom from the seed.
    """
    return Wisdom.load(SEED_PATH) if os.path.exists(SEED_PATH) else Wisdom()


# ---------------------------------------------------------------------------
# command line (the fftw-wisdom analogue)
# ---------------------------------------------------------------------------

def _main(argv=None) -> int:
    import argparse

    ap = argparse.ArgumentParser(
        prog="python -m repro.tuning.wisdom",
        description="Inspect and merge CROFT wisdom files.")
    sub = ap.add_subparsers(dest="cmd", required=True)
    mp = sub.add_parser("merge", help="merge wisdom files (better entry "
                                      "wins per key) into OUT")
    mp.add_argument("out", help="output wisdom file (merged in place if "
                                "it already exists)")
    mp.add_argument("inputs", nargs="*", help="wisdom files to fold in")
    mp.add_argument("--seed", action="store_true",
                    help="also fold in the shipped seed wisdom")
    sp = sub.add_parser("show", help="print a wisdom file's entries")
    sp.add_argument("path")
    tp = sub.add_parser("stats", help="summarize a wisdom file: keys, "
                                      "modes, staleness")
    tp.add_argument("path")
    args = ap.parse_args(argv)

    if args.cmd == "merge":
        n = merge_files(args.out, args.inputs, include_seed=args.seed)
        print(f"wrote {n} entries -> {args.out}")
        return 0
    if args.cmd == "stats":
        return _stats(args.path)
    w = Wisdom.load(args.path)
    for key in sorted(w.entries):
        e = w.entries[key]
        t = (f"{e.measured_s * 1e6:.0f}us measured" if e.measured_s is not None
             else f"{e.model_s * 1e6:.0f}us modeled" if e.model_s is not None
             else "?")
        stages = None
        try:
            cand = e.candidate()
            label = cand.label
            if getattr(cand, "is_schedule", False):
                stages = cand.stage_summary()
        except (TypeError, ValueError):
            label = "<unreadable entry>"
        print(f"{key}\n    [{e.source}] {label} ({t})")
        if stages is not None:
            print(f"    stages: {stages}")
    print(f"{len(w)} entries")
    return 0


def _age_s(entry: WisdomEntry, now: float) -> Optional[float]:
    return None if entry.created is None else max(0.0, now - entry.created)


def _fmt_age(age: Optional[float]) -> str:
    if age is None:
        return "age unknown"
    for unit, span in (("d", 86400.0), ("h", 3600.0), ("m", 60.0)):
        if age >= span:
            return f"{age / span:.1f}{unit} old"
    return f"{age:.0f}s old"


def _stats(path: str) -> int:
    """CLI ``stats``: per-key mode/problem/staleness, aggregate counts.

    Staleness matters in production: "model" entries are cold estimates
    awaiting a background measurement upgrade, and very old "measure"
    entries predate current code/hardware — both are re-tune candidates.
    """
    w = Wisdom.load(path)
    now = time.time()
    by_source: dict[str, int] = {}
    by_problem: dict[str, int] = {}
    ages = []
    n_sched = 0
    for key in sorted(w.entries):
        e = w.entries[key]
        by_source[e.source] = by_source.get(e.source, 0) + 1
        by_problem[e.problem] = by_problem.get(e.problem, 0) + 1
        age = _age_s(e, now)
        if age is not None:
            ages.append(age)
        t = (f"{e.measured_s * 1e6:.0f}us measured"
             if e.measured_s is not None else
             f"{e.model_s * 1e6:.0f}us modeled"
             if e.model_s is not None else "unscored")
        tag = f"{e.source}/{e.problem}"
        if e.schedule is not None:
            n_sched += 1
            tag += "/sched"
        print(f"{key}\n    [{tag}] {t}, {_fmt_age(age)}")
    print(f"{len(w)} entries"
          + (f" in {path}" if os.path.exists(path) else " (file missing)"))
    print("  by mode:    " + (", ".join(
        f"{k}={v}" for k, v in sorted(by_source.items())) or "-"))
    print("  by problem: " + (", ".join(
        f"{k}={v}" for k, v in sorted(by_problem.items())) or "-"))
    print(f"  searched:   {n_sched} schedule-keyed "
          f"entr{'y' if n_sched == 1 else 'ies'}")
    if ages:
        ages.sort()
        print(f"  staleness:  newest {_fmt_age(ages[0])}, median "
              f"{_fmt_age(ages[len(ages) // 2])}, oldest {_fmt_age(ages[-1])}")
    return 0


if __name__ == "__main__":
    raise SystemExit(_main())
