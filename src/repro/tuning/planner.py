"""The planner: candidate search + wisdom, orchestrated FFTW-style.

``tune()`` is the single entry point.  Modes map onto FFTW's planner
rigor levels:

  mode="wisdom"   use a stored plan if one matches; otherwise fall back
                  to "model" and remember the result.
  mode="model"    FFTW ESTIMATE — rank every valid candidate with the
                  analytic cost model, return the cheapest.  Zero
                  execution; works with no devices (pass axis_sizes).
  mode="measure"  FFTW PATIENT — model-rank, then compile and wall-clock
                  the top-k (plus the untuned default, so the tuned plan
                  is never slower than what the caller would have picked
                  by hand) and return the fastest measured.

The result carries the full ranked report for inspection and is written
into the wisdom store when a path is given.
"""

from __future__ import annotations

import dataclasses
from typing import Mapping, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core.decomposition import Decomposition
from repro.core.distributed import FFTOptions
from repro.obs import metrics as metrics_lib
from repro.obs import tracer as tracer_lib
from repro.tuning import candidates as cand_lib
from repro.tuning import cost_model, measure, wisdom as wisdom_lib

MODES = ("wisdom", "model", "measure")


@dataclasses.dataclass
class TuneResult:
    """Chosen plan + provenance."""

    decomp: Decomposition
    opts: FFTOptions
    source: str                 # "wisdom" | "model" | "measure"
    key: str
    ranked: list                # [{label, model_s, measured_s?}, ...]
    model_s: Optional[float] = None
    measured_s: Optional[float] = None
    wisdom_path: Optional[str] = None
    problem: str = "c2c"
    strategy: Optional[str] = None  # r2c: "packed" | "embed"
    # set when the winner came out of the schedule search (search=
    # "schedule") and is not expressible as a fixed (decomp, opts) pair;
    # pass it to Croft3D(schedule=...) — decomp/opts above then only
    # describe the data placement, not the pipeline
    schedule: Optional[object] = None

    def summary(self) -> str:
        best = self.schedule or cand_lib.Candidate(
            self.decomp, self.opts, problem=self.problem,
            strategy=self.strategy)
        t = (f"{self.measured_s * 1e6:.0f}us measured"
             if self.measured_s is not None else
             f"{self.model_s * 1e6:.0f}us modeled"
             if self.model_s is not None else "from wisdom")
        return f"[{self.source}] {best.label} ({t})"


def _resolve_axis_sizes(mesh, axis_sizes) -> Mapping[str, int]:
    if axis_sizes is not None:
        return dict(axis_sizes)
    if mesh is not None:
        return dict(mesh.shape)
    raise ValueError("tune() needs a mesh or an axis_sizes mapping")


def tune(shape: Sequence[int], mesh=None, *,
         axis_sizes: Optional[Mapping[str, int]] = None,
         mode: str = "model", dtype=jnp.complex64, top_k: int = 4,
         wisdom_path: Optional[str] = None, include_baselines: bool = False,
         heterogeneous_impls: bool = False, problem: str = "c2c",
         batch: int = 1, measure_iters: int = 5, measure_warmup: int = 2,
         save: bool = True, search: str = "options") -> TuneResult:
    """Pick (Decomposition, FFTOptions) for a 3-D FFT problem.

    ``mode="measure"`` requires a live ``mesh``; the other modes accept a
    bare ``axis_sizes`` mapping ({axis_name: size}) and never touch
    devices.

    ``problem="r2c"`` plans the real transform: the search space gains
    the packed/embed strategy axis (see ``repro.real``), the wisdom key
    a problem dimension, and measurement runs real-input plans.
    ``heterogeneous_impls`` widens the search with per-stage
    ``local_impl`` 3-tuples.

    ``batch`` plans for B vmapped fields: the cost model scales volume
    terms (not collective launch counts) by B, the wisdom key gains a
    ``|b{B}`` dimension (``batch=1`` keeps the legacy key format, so old
    wisdom files still hit), and ``mode="measure"`` times the *vmapped*
    transform over B stacked fields — the same thing the caller will run.

    ``search="schedule"`` widens the pool past (decomp, opts) knob tuples:
    the enumerator in :mod:`repro.tuning.candidates` generates candidate
    *pipelines* directly — alternative transpose orders, per-stage
    transpose impls and per-stage K — pruned by symbolic layout
    propagation.  c2c / c2c_grad only; the winner (when it is not a plan
    a fixed builder could have produced) rides back on
    ``TuneResult.schedule``.
    """
    if mode not in MODES:
        raise ValueError(f"mode must be one of {MODES}, got {mode!r}")
    if search not in ("options", "schedule"):
        raise ValueError(f'search must be "options" or "schedule", '
                         f'got {search!r}')
    if search == "schedule" and cand_lib.split_grad(problem)[0] != "c2c":
        raise ValueError('search="schedule" covers c2c/c2c_grad only — '
                         'r2c packing stages are not in the enumerator')
    if mode == "measure" and mesh is None:
        raise ValueError('mode="measure" needs a live mesh to time on')
    sizes = _resolve_axis_sizes(mesh, axis_sizes)
    backend = jax.default_backend() if mesh is not None else "any"
    key = wisdom_lib.wisdom_key(shape, sizes, jnp.dtype(dtype), backend,
                                problem, batch)
    wis = wisdom_lib.Wisdom.load(wisdom_path)

    if mode == "wisdom":
        # fall back to device-less wisdom (backend "any", written by
        # meshless mode="model" tunes) when no backend-exact entry exists
        hit = wis.lookup(key) or wis.lookup(
            wisdom_lib.wisdom_key(shape, sizes, jnp.dtype(dtype), "any",
                                  problem, batch))
        if hit is not None:
            try:
                cand = hit.candidate()
            except (TypeError, ValueError):
                cand = None  # corrupt entry values -> miss, re-estimate
        if hit is not None and cand is not None:
            return TuneResult(
                decomp=cand.decomp, opts=cand.opts, source="wisdom", key=key,
                ranked=[{"label": cand.label, "model_s": hit.model_s,
                         "measured_s": hit.measured_s}],
                model_s=hit.model_s, measured_s=hit.measured_s,
                wisdom_path=wis.path, problem=cand.problem,
                strategy=cand.strategy,
                schedule=cand if getattr(cand, "is_schedule", False)
                else None)
        mode = "model"  # miss: estimate now, remember below

    cands = cand_lib.enumerate_candidates(
        shape, sizes, include_baselines=include_baselines,
        heterogeneous_impls=heterogeneous_impls, problem=problem)
    if search == "schedule":
        cands = list(cands) + list(cand_lib.enumerate_schedule_candidates(
            shape, sizes, problem=problem))
    # distinct spec tuples can serialize to the same plan token (a
    # homogeneous per-stage override is the same pipeline as the scalar
    # knob) — collapse them so nothing gets costed or measured twice
    cands = cand_lib.dedupe_candidates(cands)
    if not cands:
        raise ValueError(
            f"no valid decomposition for shape={tuple(shape)} over mesh "
            f"axes {dict(sizes)} — check divisibility")
    with tracer_lib.get_tracer().span("tune:rank", "plan", key=key,
                                      n_candidates=len(cands)):
        scored = cost_model.rank_candidates(shape, cands, sizes, dtype,
                                            batch)
    ranked = [{"label": c.label, "model_s": b.total_s,
               "cost": b.to_dict()} for c, b in scored]

    if mode == "model":
        best, bcost = scored[0]
        entry = wisdom_lib.WisdomEntry.from_candidate(
            best, "model", model_s=bcost.total_s)
        result = TuneResult(decomp=best.decomp, opts=best.opts,
                            source="model", key=key, ranked=ranked,
                            model_s=bcost.total_s, wisdom_path=wis.path,
                            problem=best.problem,
                            strategy=getattr(best, "strategy", None),
                            schedule=best if getattr(best, "is_schedule",
                                                     False) else None)
    else:  # measure
        pool = [c for c, _ in scored[:max(1, top_k)]]
        default = cand_lib.default_candidate(shape, sizes, problem=problem)
        if default is not None and default not in pool:
            pool.append(default)
        model_by_cand = {c: b.total_s for c, b in scored}
        raced = []
        with tracer_lib.get_tracer().span("tune:measure", "plan", key=key,
                                          n_pool=len(pool)):
            for c in pool:
                t = measure.measure_candidate(
                    shape, mesh, c, dtype, warmup=measure_warmup,
                    iters=measure_iters, batch=batch)
                if t is not None:
                    raced.append((c, t))
        metrics_lib.get_registry().counter(
            "tune_measured_candidates").inc(len(raced))
        if not raced:
            raise RuntimeError("every measured candidate failed to compile")
        raced.sort(key=lambda ct: ct[1])
        best, best_t = raced[0]
        measured = {c.label: t for c, t in raced}
        for row in ranked:
            if row["label"] in measured:
                row["measured_s"] = measured[row["label"]]
        for c, t in raced:  # default candidate may not be in ranked top list
            if not any(r["label"] == c.label for r in ranked):
                ranked.append({"label": c.label, "measured_s": t})
        entry = wisdom_lib.WisdomEntry.from_candidate(
            best, "measure", model_s=model_by_cand.get(best),
            measured_s=best_t)
        if save and wis.path:
            # HLO collective stats ride along in persisted wisdom only —
            # extracting them costs a recompile of the winner (Croft3D
            # plans the base problem; grad-ness only changed the ranking)
            from repro.core.api import Croft3D
            entry.hlo = cost_model.hlo_collectives(
                Croft3D(tuple(shape), mesh, best.decomp, best.opts,
                        dtype=jnp.dtype(dtype),
                        problem=cand_lib.split_grad(best.problem)[0],
                        strategy=getattr(best, "strategy", None),
                        schedule=best if getattr(best, "is_schedule",
                                                 False) else None))
        result = TuneResult(decomp=best.decomp, opts=best.opts,
                            source="measure", key=key, ranked=ranked,
                            model_s=model_by_cand.get(best),
                            measured_s=best_t, wisdom_path=wis.path,
                            problem=best.problem,
                            strategy=getattr(best, "strategy", None),
                            schedule=best if getattr(best, "is_schedule",
                                                     False) else None)

    wis.record(key, entry)
    if save and wis.path:
        # reload-merge-rename under a lock: concurrent tuners (several
        # service processes, or the serving plan cache's background
        # measurement thread) fold entries together instead of clobbering
        # each other's writes
        wisdom_lib.merge_entries(wis.path, {key: entry})
    return result


def upgrade_wisdom(shape, mesh, *, dtype=jnp.complex64, problem: str = "c2c",
                   batch: int = 1, wisdom_path: Optional[str] = None,
                   **tune_kw) -> TuneResult:
    """FFTW's planner-in-production upgrade hook: re-plan one problem in
    ``mode="measure"`` and merge the winner into the wisdom store.

    This is what the serving plan cache's background thread calls once a
    key turns hot: the cold request paid only ``mode="model"``; this pays
    the compile-and-time cost off the request path and persists the
    measured plan (atomically, via :func:`repro.tuning.wisdom.merge_entries`)
    so every later process starts warm.
    """
    return tune(shape, mesh, mode="measure", dtype=dtype, problem=problem,
                batch=batch, wisdom_path=wisdom_path, **tune_kw)
