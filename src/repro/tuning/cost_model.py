"""Analytic candidate scoring — the planner's FFTW-``ESTIMATE`` leg.

Scores a :class:`~repro.tuning.candidates.Candidate` in modeled seconds
with zero execution.  Since the stage-schedule refactor the model does
not re-derive pipeline structure from ``Decomposition.kind``: it builds
the candidate's *actual* :class:`repro.core.schedule.Schedule` (the same
object the executor runs) and walks it —

  compute     5 n log2 n FLOPs per local FFT event, at the block size the
              schedule's symbolic layout reports for that stage, scaled
              by a per-``local_impl`` efficiency prior (the four-step
              matmul runs on the MXU, Stockham/XLA on the vector units)
  memory      ~10 local HBM passes over the per-device input block
  collective  per-stage transpose bytes (the layout at each stage's
              all_to_all, so the packed pipeline's half-volume stages and
              its out-of-body z-localizing reshard are charged at their
              true sizes) / link bandwidth
  latency     a per-collective launch cost using each stage's *effective*
              K (the executor's chunk-indivisible fallback is modeled,
              and out-of-body reshards count as one fused all-to-all);
              the alpha/beta split per transpose impl: "alltoall" pays
              one alpha per (chunk, stage) and its beta overlaps only
              when K >= 2 chunks exist to pipeline; "ring" pays P-1
              alphas per chunk plus one fused pack/unpack HBM pass each
              side, but its beta is overlapped with FFT compute even at
              K=1 (the rounds are independent of each other and of the
              neighbouring chunks' FFTs — the executor's explicit
              pipeline); "pairwise" pays P-1 alphas AND a serial
              placement chain (P-1 full-size output rewrites, never
              overlapped) — the FFTW3 baseline of figs 12-15

K-chunked overlap (the paper's core mechanism) combines compute and
collective with ``max(...)`` instead of ``+`` (§5.1 options 3/4), and
``plan_cache=False`` pays the twiddle re-materialization the paper's
options 1/3 measure.  The embedding r2c strategy additionally pays the
guarded half-slice reshard in the natural layout
(``core.rfft._guarded_half_slice``).

``batch`` models vmapped transforms (B stacked fields): volume terms
scale by B while collective launch counts do not — under vmap the
all_to_alls batch into the same ops — which is exactly what makes deeper
plans win at batch and why the wisdom key carries a ``|b{B}`` dimension.

For compiled refinement, :func:`hlo_collectives` extracts the *actual*
collective op count/bytes from post-SPMD HLO via ``launch/hlo_cost.py`` —
still execution-free, but it needs the mesh's devices to exist.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Mapping, Optional, Sequence

import jax.numpy as jnp

from repro.core.decomposition import Decomposition
from repro.core.distributed import FFTOptions, build_schedule
from repro.core.schedule import Schedule
from repro.launch.roofline import HBM_BW, LINK_BW, PEAK_FLOPS
from repro.tuning.candidates import Candidate

# fraction of peak FLOPs each local 1-D implementation is expected to
# sustain — coarse priors that mode="measure" refines empirically
IMPL_EFFICIENCY = {
    "matmul": 0.50,    # four-step DFT-by-matmul: MXU-native, extra flops
    "pallas": 0.40,    # same algorithm, hand-tiled kernel
    "stockham": 0.06,  # radix-2 butterflies on the vector units
    "xla": 0.08,       # backend-provided FFT custom call
}
_DEFAULT_EFFICIENCY = 0.08
LOCAL_PASSES = 10          # HBM round trips over the local block
COLLECTIVE_LATENCY_S = 2e-6
REPLAN_PASSES = 6          # twiddle re-materialization, options 1/3

#: environment variable naming a calibration JSON (written by
#: ``benchmarks/collective_profile.py``) with fitted
#: ``collective_alpha_s`` / ``collective_beta_s_per_byte``
CALIBRATION_ENV = "CROFT_CALIBRATION"
_calibration_file_cache: dict = {}


def _calibration_from_file() -> Optional[tuple]:
    import json
    import os
    path = os.environ.get(CALIBRATION_ENV)
    if not path or not os.path.exists(path):
        return None
    try:
        mtime = os.path.getmtime(path)
        cached = _calibration_file_cache.get(path)
        if cached is not None and cached[0] == mtime:
            return cached[1]
        with open(path) as f:
            d = json.load(f)
        vals = (float(d["collective_alpha_s"]),
                float(d["collective_beta_s_per_byte"]))
        _calibration_file_cache[path] = (mtime, vals)
        return vals
    except (OSError, ValueError, KeyError, TypeError):
        return None


def collective_constants() -> tuple:
    """(alpha seconds-per-launch, beta seconds-per-byte) for collectives.

    Precedence: live calibration published through the ``repro.obs``
    metrics registry (``benchmarks/collective_profile.py``'s lstsq fit —
    gauges ``collective_alpha_s`` / ``collective_beta_s_per_byte``) >
    a saved calibration JSON named by ``$CROFT_CALIBRATION`` > the
    hardcoded roofline constants.  Non-positive fits are ignored (a
    degenerate lstsq on noisy walls can go negative — the hardcoded
    floor is better than a nonsense model).
    """
    alpha, beta = COLLECTIVE_LATENCY_S, 1.0 / LINK_BW
    file_vals = _calibration_from_file()
    if file_vals is not None:
        fa, fb = file_vals
        alpha = fa if fa > 0 else alpha
        beta = fb if fb > 0 else beta
    try:
        from repro.obs import metrics as metrics_lib
        reg = metrics_lib.get_registry()
        ga = reg.gauge("collective_alpha_s").value
        gb = reg.gauge("collective_beta_s_per_byte").value
        alpha = ga if ga and ga > 0 else alpha
        beta = gb if gb and gb > 0 else beta
    except Exception:
        pass
    return alpha, beta


@dataclasses.dataclass(frozen=True)
class CostBreakdown:
    """Modeled wall-clock terms for one candidate (seconds)."""

    compute_s: float
    memory_s: float
    collective_s: float
    latency_s: float
    replan_s: float
    total_s: float
    flops: float
    local_bytes: float
    collective_bytes: float
    n_collectives: int
    n_procs: int
    #: ring pack/unpack passes or the pairwise serial placement chain
    transpose_overhead_s: float = 0.0

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


def flops_model(shape: Sequence[int]) -> float:
    """Analytic 5 N log2 N FLOPs of the full c2c 3-D transform."""
    n_total = math.prod(shape)
    return 5.0 * n_total * sum(math.log2(s) for s in shape)


def schedule_for(shape: Sequence[int], cand: Candidate) -> Schedule:
    """The forward schedule this candidate would execute — the single
    source of stage structure for both the executor and this model
    (``Croft3D._forward_schedule`` reads it too).

    The r2c embedding's guarded half-slice (``core.rfft``, natural
    layout only: the odd-sized Nh axis is resharded z-local before
    slicing) is recorded as an out-of-body ``ExtraComm`` of ~half the
    spectrum volume, so its bytes and launch are charged like any other
    collective.
    """
    from repro.tuning.candidates import split_grad
    build = getattr(cand, "build_schedule", None)
    if build is not None:
        # searched pipeline: the candidate IS the schedule (stage list +
        # per-stage overrides); nothing to re-derive from the builders
        return build()
    base_problem, _ = split_grad(cand.problem)
    if base_problem == "r2c" and cand.strategy == "packed":
        from repro.real import pipeline as real_pipeline
        return real_pipeline.build_packed_forward(cand.decomp)
    sched = build_schedule(cand.decomp, cand.opts, sign=-1)
    if (base_problem == "r2c" and cand.strategy == "embed"
            and cand.opts.output_layout == "natural"):
        from repro.core.schedule import ExtraComm
        half = sched.layout_out.with_den(2, mul=2)
        sched = dataclasses.replace(
            sched, extra_comms=sched.extra_comms
            + (ExtraComm("guarded-half-slice", half),))
    return sched


def schedules_for(shape: Sequence[int], cand: Candidate) -> list:
    """Every schedule one step of this candidate executes: the forward,
    plus its adjoint (``repro.grad``) for the ``_grad`` problems — the
    training-step cost is their sum, and the adjoint's stage structure
    (same transposes, mirrored order) is priced with the same model."""
    from repro.tuning.candidates import split_grad
    sched = schedule_for(shape, cand)
    _, is_grad = split_grad(cand.problem)
    if not is_grad:
        return [sched]
    from repro.grad import adjoint_schedule
    return [sched, adjoint_schedule(sched)]


def analytic_cost(shape: Sequence[int], cand: Candidate,
                  axis_sizes: Mapping[str, int],
                  dtype=jnp.complex64, batch: int = 1) -> CostBreakdown:
    """Modeled seconds for one execution of this candidate — one forward
    transform, or one fwd+bwd pair for the ``_grad`` problems (the
    schedules run sequentially, so their modeled times sum)."""
    parts = [_schedule_cost(shape, cand, sched, axis_sizes, dtype, batch)
             for sched in schedules_for(shape, cand)]
    if len(parts) == 1:
        return parts[0]
    return CostBreakdown(**{
        f.name: (sum(getattr(b, f.name) for b in parts)
                 if f.name != "n_procs" else parts[0].n_procs)
        for f in dataclasses.fields(CostBreakdown)})


def _schedule_cost(shape: Sequence[int], cand: Candidate, sched: Schedule,
                   axis_sizes: Mapping[str, int],
                   dtype=jnp.complex64, batch: int = 1) -> CostBreakdown:
    if getattr(cand, "is_schedule", False):
        return _searched_schedule_cost(shape, cand, sched, axis_sizes,
                                       dtype, batch)
    decomp, opts = cand.decomp, cand.opts
    itemsize = jnp.dtype(dtype).itemsize
    p = decomp.n_procs(axis_sizes)
    alpha, beta = collective_constants()

    # compute: one event per local FFT, at the schedule's reported size
    flops = 0.0
    compute_s = 0.0
    for impl_stage, elems, n_fft in sched.fft_events(shape, axis_sizes):
        f = 5.0 * elems * math.log2(n_fft)
        flops += f
        eff = IMPL_EFFICIENCY.get(opts.stage_impl(impl_stage),
                                  _DEFAULT_EFFICIENCY)
        compute_s += f / (PEAK_FLOPS * eff)
    flops *= batch
    compute_s *= batch

    local_bytes = sched.layout_in.bytes(shape, axis_sizes, itemsize) * batch
    memory_s = LOCAL_PASSES * local_bytes / HBM_BW

    events = sched.comm_events(shape, axis_sizes, itemsize)
    coll_bytes = float(sum(ev["bytes"] for ev in events)) * batch
    collective_s = coll_bytes * beta

    # collective-op count: effective K chunks per in-body transpose (the
    # executor's chunk-indivisible fallback, read from the schedule); the
    # ppermute-based transposes (ring, pairwise) issue (P_axis - 1)
    # rounds where the fused path issues one a2a; out-of-body reshards
    # are one fused a2a each.  Alongside the alpha count, each impl's
    # structural overhead: the ring pays one fused pack + one fused
    # unpack pass over the moved bytes, the pairwise emulation pays a
    # *serial* placement chain of P-1 full-size output rewrites.
    impl = opts.transpose_impl
    eff_ks = iter(sched.effective_k(shape, axis_sizes, opts.overlap_k))
    n_coll = 0
    k_eff_max = 1
    any_chunkable = False
    transpose_overhead_s = 0.0
    for ev in events:
        if not ev["chunkable"]:
            n_coll += 1
            continue
        any_chunkable = True
        k_eff = next(eff_ks)
        k_eff_max = max(k_eff_max, k_eff)
        ops = (ev["comm_size"] - 1) if impl in ("ring", "pairwise") else 1
        n_coll += k_eff * ops
        ev_bytes = ev["bytes"] * batch
        if impl == "ring":
            transpose_overhead_s += 2 * ev_bytes / HBM_BW
        elif impl == "pairwise":
            transpose_overhead_s += (ev["comm_size"] - 1) * ev_bytes / HBM_BW
    latency_s = n_coll * alpha

    replan_s = 0.0
    if not opts.plan_cache:
        replan_s = REPLAN_PASSES * local_bytes / HBM_BW

    busy = compute_s + memory_s
    if impl == "ring":
        busy += transpose_overhead_s  # pack/unpack pipeline with the rounds
    # beta overlap: K >= 2 chunks pipeline any impl's collective against
    # the neighbouring chunks' FFTs; the ring's independent rounds
    # additionally overlap at K=1.  The pairwise serial chain never
    # overlaps — each round's placement depends on the previous one.
    overlaps = (any_chunkable and impl != "pairwise"
                and (k_eff_max >= 2 or impl == "ring"))
    if overlaps:
        # paper §5.1: chunked pipeline hides the smaller of the two legs
        overlapped = max(busy, collective_s) + 0.1 * min(busy, collective_s)
    else:
        overlapped = busy + collective_s
        if impl == "pairwise":
            overlapped += transpose_overhead_s
    total = overlapped + latency_s + replan_s

    return CostBreakdown(
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        latency_s=latency_s, replan_s=replan_s, total_s=total, flops=flops,
        local_bytes=float(local_bytes), collective_bytes=float(coll_bytes),
        n_collectives=n_coll, n_procs=p,
        transpose_overhead_s=transpose_overhead_s)


def _searched_schedule_cost(shape: Sequence[int], cand, sched: Schedule,
                            axis_sizes: Mapping[str, int],
                            dtype=jnp.complex64,
                            batch: int = 1) -> CostBreakdown:
    """Per-stage §5.1 combine for searched pipelines.

    The legacy formula prices the whole schedule with one global
    ``max(busy, collective)`` — fine for homogeneous knobs, but it can
    hide a stage that *cannot* overlap (chunk-indivisible alltoall)
    under another stage's compute, which the per-stage measurements
    (``repro.obs.report``) show is not physical.  Searched schedules mix
    impls and K per stage, so each stage's overlap is priced against its
    OWN legs — the same decomposition :func:`per_stage_costs` reports —
    and the stage times sum.  Fixed-builder candidates keep the legacy
    combine so existing rankings and pins are bit-identical.
    """
    from repro.core.schedule import _flat, stage_transpose_impl
    opts = cand.opts
    itemsize = jnp.dtype(dtype).itemsize
    p = cand.decomp.n_procs(axis_sizes)
    alpha, beta = collective_constants()

    flops = 0.0
    compute_s = 0.0
    for impl_stage, elems, n_fft in sched.fft_events(shape, axis_sizes):
        f = 5.0 * elems * math.log2(n_fft)
        flops += f
        eff = IMPL_EFFICIENCY.get(opts.stage_impl(impl_stage),
                                  _DEFAULT_EFFICIENCY)
        compute_s += f / (PEAK_FLOPS * eff)
    flops *= batch
    compute_s *= batch

    local_bytes = sched.layout_in.bytes(shape, axis_sizes, itemsize) * batch
    memory_s = LOCAL_PASSES * local_bytes / HBM_BW

    events = sched.comm_events(shape, axis_sizes, itemsize)
    coll_bytes = float(sum(ev["bytes"] for ev in events)) * batch
    collective_s = coll_bytes * beta

    eff_ks = iter(sched.effective_k(shape, axis_sizes, opts.overlap_k))
    comm_stages = iter(sched.comm_stages())
    n_coll = 0
    transpose_overhead_s = 0.0
    for ev in events:
        if not ev["chunkable"]:
            n_coll += 1
            continue
        _, st = next(comm_stages)
        impl = stage_transpose_impl(st, opts)
        k_eff = next(eff_ks)
        ops = (ev["comm_size"] - 1) if impl in ("ring", "pairwise") else 1
        n_coll += k_eff * ops
        ev_bytes = ev["bytes"] * batch
        if impl == "ring":
            transpose_overhead_s += 2 * ev_bytes / HBM_BW
        elif impl == "pairwise":
            transpose_overhead_s += (ev["comm_size"] - 1) * ev_bytes / HBM_BW
    latency_s = n_coll * alpha

    replan_s = 0.0
    if not opts.plan_cache:
        replan_s = REPLAN_PASSES * local_bytes / HBM_BW

    # the per-stage combine: each stage hides the smaller of its own two
    # legs when it pipelines (ring overhead is already inside the rows'
    # compute leg; the pairwise chain rides in compute and never hides)
    rows = _stage_rows(shape, cand, sched, axis_sizes, dtype, batch, "fwd")
    staged = 0.0
    for r in rows:
        c, coll = r["compute_s"], r["collective_s"]
        if r["overlaps"]:
            staged += max(c, coll) + 0.1 * min(c, coll)
        else:
            staged += c + coll
    total = staged + latency_s + replan_s

    return CostBreakdown(
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        latency_s=latency_s, replan_s=replan_s, total_s=total, flops=flops,
        local_bytes=float(local_bytes), collective_bytes=float(coll_bytes),
        n_collectives=n_coll, n_procs=p,
        transpose_overhead_s=transpose_overhead_s)


def predicted_collectives(sched: Schedule, shape: Sequence[int],
                          axis_sizes: Mapping[str, int], opts) -> dict:
    """Per-kind collective-op counts the executor will emit for this
    schedule — what ``benchmarks/search_bench.py`` pins compiled HLO
    against: one ``all-to-all`` per effective chunk of a fused stage,
    ``K_eff * (P-1)`` ``collective-permute`` rounds for ring/pairwise,
    one fused all-to-all per out-of-body reshard."""
    from repro.core.schedule import _flat, stage_transpose_impl
    sizes = dict(axis_sizes)
    counts = {"all-to-all": 0, "collective-permute": 0}
    eff = sched.effective_k(shape, axis_sizes, opts.overlap_k)
    for (_, st), k_eff in zip(sched.comm_stages(), eff):
        impl = stage_transpose_impl(st, opts)
        csize = math.prod(sizes[n] for n in _flat(st.comm_axis))
        if impl == "alltoall":
            counts["all-to-all"] += k_eff
        else:
            counts["collective-permute"] += k_eff * (csize - 1)
    counts["all-to-all"] += len(sched.extra_comms)
    return counts


def per_stage_costs(shape: Sequence[int], cand: Candidate,
                    axis_sizes: Mapping[str, int],
                    dtype=jnp.complex64, batch: int = 1) -> list:
    """Modeled per-stage compute/collective split — what the traced
    per-stage timings (``repro.obs.instrument``) are joined against.

    One row per schedule stage (plus one per out-of-body reshard), using
    the same conventions as :func:`analytic_cost`: FFT flops at the
    layout-reported block size over ``PEAK_FLOPS * IMPL_EFFICIENCY``,
    the ``LOCAL_PASSES`` HBM budget spread evenly across the stages that
    do local work, ring pack/unpack passes charged to the compute leg,
    and the §5.1 overlap rule (0.9 of the smaller leg hides under the
    larger when the stage pipelines: any chunkable stage with effective
    K >= 2, or the ring's independent rounds even at K=1; the pairwise
    serial chain never overlaps).  ``predicted_efficiency`` is the
    modeled fraction of the stage's collective time hidden under
    compute — the per-stage form of the paper's 42-51% claim.
    """
    rows = []
    scheds = schedules_for(shape, cand)
    for direction, sched in zip(("fwd", "bwd"), scheds):
        rows.extend(_stage_rows(shape, cand, sched, axis_sizes, dtype,
                                batch, direction))
    return rows


def _stage_rows(shape, cand, sched, axis_sizes, dtype, batch,
                direction) -> list:
    opts = cand.opts
    itemsize = jnp.dtype(dtype).itemsize
    _, beta = collective_constants()
    eff_ks = iter(sched.effective_k(shape, axis_sizes, opts.overlap_k))

    from repro.core.schedule import (_flat, stage_category,
                                     stage_transpose_impl)
    n_local = sum(1 for st in sched.stages
                  if st.fft_axis is not None or st.prologue or st.epilogue)
    mem_passes = LOCAL_PASSES / max(1, n_local)

    rows = []
    for i, (st, pts) in enumerate(zip(sched.stages, sched.points)):
        compute_s = 0.0
        if st.fft_axis is not None:
            loc = pts.fft.local_shape(shape, axis_sizes)
            f = 5.0 * math.prod(loc) * math.log2(loc[st.fft_axis])
            eff = IMPL_EFFICIENCY.get(opts.stage_impl(st.impl_stage),
                                      _DEFAULT_EFFICIENCY)
            compute_s += f / (PEAK_FLOPS * eff)
        if st.fft_axis is not None or st.prologue or st.epilogue:
            compute_s += (mem_passes
                          * pts.entry.bytes(shape, axis_sizes, itemsize)
                          / HBM_BW)
        compute_s *= batch

        collective_s = 0.0
        k_eff = 1
        overlaps = False
        if st.comm_axis is not None:
            impl = stage_transpose_impl(st, opts)
            ev_bytes = pts.comm.bytes(shape, axis_sizes, itemsize) * batch
            collective_s = ev_bytes * beta
            k_eff = next(eff_ks)
            overlaps = impl != "pairwise" and (k_eff >= 2 or impl == "ring")
            if impl == "ring":
                compute_s += 2 * ev_bytes / HBM_BW
            elif impl == "pairwise":
                csize = math.prod(axis_sizes[n] for n in _flat(st.comm_axis))
                compute_s += (csize - 1) * ev_bytes / HBM_BW

        hidden = 0.9 * min(compute_s, collective_s) if overlaps else 0.0
        rows.append({
            "stage": i,
            "name": st.name,
            "direction": direction,
            "category": stage_category(st),
            "impl": (stage_transpose_impl(st, opts)
                     if st.comm_axis is not None else None),
            "compute_s": compute_s,
            "collective_s": collective_s,
            "k_eff": k_eff,
            "overlaps": overlaps,
            "hidden_s": hidden,
            "predicted_efficiency": (hidden / collective_s
                                     if collective_s else None),
        })
    for ec in sched.extra_comms:
        coll = ec.layout.bytes(shape, axis_sizes, itemsize) * batch * beta
        rows.append({
            "stage": None, "name": ec.name, "direction": direction,
            "category": "collective",
            "compute_s": 0.0, "collective_s": coll, "k_eff": 1,
            "overlaps": False, "hidden_s": 0.0,
            "predicted_efficiency": 0.0 if coll else None,
        })
    return rows


def rank_candidates(shape: Sequence[int], cands: Sequence[Candidate],
                    axis_sizes: Mapping[str, int],
                    dtype=jnp.complex64,
                    batch: int = 1) -> list[tuple[Candidate, CostBreakdown]]:
    """Candidates sorted by modeled total time, cheapest first (stable —
    enumeration order breaks ties, keeping ranking deterministic)."""
    scored = [(c, analytic_cost(shape, c, axis_sizes, dtype, batch))
              for c in cands]
    scored.sort(key=lambda t: t[1].total_s)
    return scored


def hlo_collectives(plan) -> Optional[dict]:
    """Collective counts/bytes of the compiled forward, from post-SPMD HLO
    (``launch/hlo_cost.py``).  Compiles but never executes; returns None
    when lowering is impossible (e.g. the mesh's devices don't exist in
    this process)."""
    from repro.launch import hlo_cost
    try:
        compiled = plan.lower_forward().compile()
        cost = hlo_cost.analyze(compiled.as_text())
    except Exception:
        return None
    return {
        "collective_bytes": cost.collective_bytes,
        "collectives": cost.collectives,
        "flops": cost.flops,
        "bytes": cost.bytes,
    }
