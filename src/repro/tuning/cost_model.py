"""Analytic candidate scoring — the planner's FFTW-``ESTIMATE`` leg.

Scores a :class:`~repro.tuning.candidates.Candidate` in modeled seconds
with zero execution, from the same three roofline terms the launch layer
uses (``launch/roofline.py`` constants):

  compute     5 N log2 N FLOPs / P, scaled by a per-``local_impl``
              efficiency prior (the four-step matmul runs on the MXU,
              Stockham/XLA on the vector units)
  memory      ~10 local HBM passes over the per-device block
  collective  transpose traffic / link bandwidth — the slab/pencil/cell
              counts of ``Croft3D.comm_bytes_model``, halved for the
              beyond-paper spectral layout
  latency     a per-collective launch cost; this is what separates one
              fused all_to_all from the P-1 pairwise exchanges of the
              FFTW3-style transpose (paper figs 12-15)

K-chunked overlap (the paper's core mechanism) combines compute and
collective with ``max(...)`` instead of ``+`` (§5.1 options 3/4), and
``plan_cache=False`` pays the twiddle re-materialization the paper's
options 1/3 measure.

Real-transform candidates (``problem="r2c"``) add a strategy term: the
packed two-for-one plan halves flops, HBM traffic, and transpose bytes
(the carried spectrum is Nz/2 bins); the embedding pays full c2c cost
plus, in the natural layout, the guarded half-slice reshard.  Per-stage
``local_impl`` tuples score each pipeline stage with its own
efficiency prior.

For compiled refinement, :func:`hlo_collectives` extracts the *actual*
collective op count/bytes from post-SPMD HLO via ``launch/hlo_cost.py`` —
still execution-free, but it needs the mesh's devices to exist.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Mapping, Optional, Sequence

import jax.numpy as jnp

from repro.core.decomposition import Decomposition
from repro.core.distributed import FFTOptions
from repro.launch.roofline import HBM_BW, LINK_BW, PEAK_FLOPS
from repro.tuning.candidates import Candidate

# fraction of peak FLOPs each local 1-D implementation is expected to
# sustain — coarse priors that mode="measure" refines empirically
IMPL_EFFICIENCY = {
    "matmul": 0.50,    # four-step DFT-by-matmul: MXU-native, extra flops
    "pallas": 0.40,    # same algorithm, hand-tiled kernel
    "stockham": 0.06,  # radix-2 butterflies on the vector units
    "xla": 0.08,       # backend-provided FFT custom call
}
_DEFAULT_EFFICIENCY = 0.08
LOCAL_PASSES = 10          # HBM round trips over the local block
COLLECTIVE_LATENCY_S = 2e-6
REPLAN_PASSES = 6          # twiddle re-materialization, options 1/3


@dataclasses.dataclass(frozen=True)
class CostBreakdown:
    """Modeled wall-clock terms for one candidate (seconds)."""

    compute_s: float
    memory_s: float
    collective_s: float
    latency_s: float
    replan_s: float
    total_s: float
    flops: float
    local_bytes: float
    collective_bytes: float
    n_collectives: int
    n_procs: int

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


def flops_model(shape: Sequence[int]) -> float:
    """Analytic 5 N log2 N FLOPs of the full c2c 3-D transform."""
    n_total = math.prod(shape)
    return 5.0 * n_total * sum(math.log2(s) for s in shape)


def transpose_count(decomp: Decomposition, opts: FFTOptions,
                    strategy: Optional[str] = None) -> int:
    """Global transposes per forward transform (matches
    ``Croft3D.comm_bytes_model``).  The packed real pipeline runs two
    (half-volume) pipeline transposes plus the z-localizing epilogue
    reshard (also half volume)."""
    if strategy == "packed":
        return 3
    n = {"slab": 1, "pencil": 2, "cell": 3}[decomp.kind]
    if decomp.kind == "cell":
        return 4 * 2  # regroup + pencil(2) + scatter, both ways
    if opts.output_layout == "natural":
        n *= 2
    return n


def comm_bytes_model(shape: Sequence[int], decomp: Decomposition,
                     axis_sizes: Mapping[str, int], opts: FFTOptions,
                     itemsize: int = 8,
                     strategy: Optional[str] = None) -> float:
    """Bytes each chip injects per transform."""
    local = math.prod(decomp.local_shape(shape, axis_sizes)) * itemsize
    if strategy == "packed":
        local *= 0.5  # the carried spectrum is Nz/2 complex bins
    return local * transpose_count(decomp, opts, strategy)


def _compute_seconds(shape: Sequence[int], decomp: Decomposition,
                     opts: FFTOptions, p: int) -> float:
    """Per-device FFT seconds, honoring per-stage ``local_impl`` tuples.

    Each axis contributes 5 N log2(n_axis) FLOPs; stage order follows the
    pipeline (slab transforms y first, pencil/cell x first).
    """
    n_total = math.prod(shape)
    order = (1, 0, 2) if decomp.kind == "slab" else (0, 1, 2)
    total = 0.0
    for stage, ax in enumerate(order):
        eff = IMPL_EFFICIENCY.get(opts.stage_impl(stage), _DEFAULT_EFFICIENCY)
        total += 5.0 * n_total * math.log2(shape[ax]) / p / (PEAK_FLOPS * eff)
    return total


def analytic_cost(shape: Sequence[int], cand: Candidate,
                  axis_sizes: Mapping[str, int],
                  dtype=jnp.complex64) -> CostBreakdown:
    decomp, opts = cand.decomp, cand.opts
    strategy = cand.strategy if cand.problem == "r2c" else None
    itemsize = jnp.dtype(dtype).itemsize
    p = decomp.n_procs(axis_sizes)

    flops = flops_model(shape) / p
    compute_s = _compute_seconds(shape, decomp, opts, p)
    if strategy == "packed":
        # two-for-one: half the z transforms, y/x stages on half the bins
        flops *= 0.5
        compute_s *= 0.5

    local_bytes = math.prod(decomp.local_shape(shape, axis_sizes)) * itemsize
    if strategy == "packed":
        local_bytes *= 0.5
    memory_s = LOCAL_PASSES * local_bytes / HBM_BW

    coll_bytes = comm_bytes_model(shape, decomp, axis_sizes, opts, itemsize,
                                  strategy)
    if strategy == "embed" and opts.output_layout == "natural":
        # the guarded half-slice reshards ~half the spectrum so the
        # truncation never crosses shards (core.rfft._guarded_half_slice)
        coll_bytes += 0.5 * local_bytes
    collective_s = coll_bytes / LINK_BW

    # collective-op count: K chunks per transpose; the pairwise transpose
    # issues (P_axis - 1) ppermutes where the fused path issues one a2a
    comm_sizes = decomp.axis_sizes(axis_sizes)
    n_coll = 0
    n_stages = transpose_count(decomp, opts, strategy)
    for i, sz in enumerate(comm_sizes):
        # distribute the transposes over the communicators (cell's 8 don't
        # divide by 3 axes evenly; round-robin the remainder)
        per_stage = n_stages // len(comm_sizes) \
            + (1 if i < n_stages % len(comm_sizes) else 0)
        ops_per_transpose = (sz - 1) if opts.transpose_impl == "pairwise" else 1
        n_coll += per_stage * opts.overlap_k * ops_per_transpose
    latency_s = n_coll * COLLECTIVE_LATENCY_S

    replan_s = 0.0
    if not opts.plan_cache:
        replan_s = REPLAN_PASSES * local_bytes / HBM_BW

    busy = compute_s + memory_s
    if opts.overlap_k >= 2:
        # paper §5.1: chunked pipeline hides the smaller of the two legs
        overlapped = max(busy, collective_s) + 0.1 * min(busy, collective_s)
    else:
        overlapped = busy + collective_s
    total = overlapped + latency_s + replan_s

    return CostBreakdown(
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        latency_s=latency_s, replan_s=replan_s, total_s=total, flops=flops,
        local_bytes=float(local_bytes), collective_bytes=float(coll_bytes),
        n_collectives=n_coll, n_procs=p)


def rank_candidates(shape: Sequence[int], cands: Sequence[Candidate],
                    axis_sizes: Mapping[str, int],
                    dtype=jnp.complex64) -> list[tuple[Candidate, CostBreakdown]]:
    """Candidates sorted by modeled total time, cheapest first (stable —
    enumeration order breaks ties, keeping ranking deterministic)."""
    scored = [(c, analytic_cost(shape, c, axis_sizes, dtype)) for c in cands]
    scored.sort(key=lambda t: t[1].total_s)
    return scored


def hlo_collectives(plan) -> Optional[dict]:
    """Collective counts/bytes of the compiled forward, from post-SPMD HLO
    (``launch/hlo_cost.py``).  Compiles but never executes; returns None
    when lowering is impossible (e.g. the mesh's devices don't exist in
    this process)."""
    from repro.launch import hlo_cost
    try:
        compiled = plan.lower_forward().compile()
        cost = hlo_cost.analyze(compiled.as_text())
    except Exception:
        return None
    return {
        "collective_bytes": cost.collective_bytes,
        "collectives": cost.collectives,
        "flops": cost.flops,
        "bytes": cost.bytes,
    }
