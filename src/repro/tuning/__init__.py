"""repro.tuning — FFTW-style autotuning planner for the distributed 3-D FFT.

CROFT's option study (§5.1) and its FFTW3 comparison are ultimately about
*plan selection*: the same transform can be run with different
decompositions (slab/pencil/cell), overlap depths (K), local 1-D kernels,
output layouts, and transpose implementations, and the right combination
depends on shape, mesh, dtype, and hardware.  This package chooses it,
mapping directly onto FFTW's planner design:

  FFTW concept          here
  --------------------  ---------------------------------------------------
  planner search space  ``candidates.enumerate_candidates`` — every valid
                        (Decomposition, FFTOptions) pair for (shape, mesh),
                        filtered by divisibility/overlap constraints
  FFTW_ESTIMATE         ``mode="model"`` — ``cost_model.analytic_cost``
                        builds the candidate's actual stage schedule
                        (``repro.core.schedule``, the same object the
                        executor runs) and walks it: per-stage FFT sizes
                        and transpose bytes, effective overlap-K,
                        collective launch counts — with zero execution;
                        optional HLO-derived collective counts via
                        ``cost_model.hlo_collectives``
  FFTW_PATIENT          ``mode="measure"`` — ``measure.measure_candidate``
                        compiles and wall-clocks the model-ranked top-k
                        (plus the untuned default) on the live mesh
  wisdom import/export  ``wisdom.Wisdom`` — JSON store keyed by
                        shape|mesh|dtype|backend[|problem]; ``mode="wisdom"``
                        reuses a stored plan without re-searching, and stores
                        can be merged across processes/hosts
                        (``python -m repro.tuning.wisdom merge``, with a
                        shipped seed file via ``--seed``)

Problem classes: ``problem="c2c"`` (default) and ``problem="r2c"`` — the
real transform is a first-class citizen: its candidates carry a
packed/embed strategy axis (the two-for-one pipelines of ``repro.real``,
pencil and slab alike, vs the embedding fallback), the schedule-derived
cost model charges the packed stages at their true half-volume sizes,
measurement runs real-input plans, and wisdom keys gain a problem
dimension.  The ``_grad`` variants (``"c2c_grad"``/``"r2c_grad"``) plan a
*training step*: same physical search space, but the cost model prices
the forward schedule **plus** its adjoint (``repro.grad``), measurement
races ``jax.value_and_grad`` through the plan, and the wisdom key gains a
trailing ``|grad`` dimension.  ``heterogeneous_impls=True`` additionally
searches per-stage ``local_impl`` 3-tuples, and ``batch=B`` plans for
vmapped transforms (volume terms scale by B, collective launch counts do
not; the wisdom key gains ``|b{B}``).

The collective cost constants (alpha latency / beta inverse-bandwidth)
are calibrated, not guessed, when data exists: ``benchmarks/
collective_profile.py`` publishes its fitted alpha/beta to the metrics
registry and a calibration JSON (``$CROFT_CALIBRATION``), and
``cost_model.collective_constants`` picks them up with hardcoded
fallbacks.

Entry points: :func:`tune` below, ``Croft3D.tuned(...)`` /
``Croft3D(..., tune="model")`` in ``repro.core.api``, and the
``benchmarks/tuning_bench.py`` / ``benchmarks/rfft_bench.py`` sweeps
(``BENCH_tuning.json`` / ``BENCH_rfft.json``).
"""

from repro.tuning.candidates import (PROBLEMS, Candidate, default_candidate,
                                     decompositions_for, enumerate_candidates,
                                     split_grad)
from repro.tuning.cost_model import (CostBreakdown, analytic_cost,
                                     collective_constants, hlo_collectives,
                                     per_stage_costs, rank_candidates)
from repro.tuning.measure import (measure_candidate, time_forward,
                                  time_train_step)
from repro.tuning.planner import MODES, TuneResult, tune, upgrade_wisdom
from repro.tuning.wisdom import (Wisdom, WisdomEntry, load_seed,
                                 merge_entries, wisdom_key)

__all__ = [
    "Candidate", "CostBreakdown", "MODES", "PROBLEMS", "TuneResult",
    "Wisdom", "WisdomEntry", "analytic_cost", "collective_constants",
    "decompositions_for", "default_candidate", "enumerate_candidates",
    "hlo_collectives", "load_seed", "measure_candidate", "merge_entries",
    "per_stage_costs", "rank_candidates", "split_grad", "time_forward",
    "time_train_step", "tune", "upgrade_wisdom", "wisdom_key",
]
