"""repro.tuning — FFTW-style autotuning planner for the distributed 3-D FFT.

CROFT's option study (§5.1) and its FFTW3 comparison are ultimately about
*plan selection*: the same transform can be run with different
decompositions (slab/pencil/cell), overlap depths (K), local 1-D kernels,
output layouts, and transpose implementations, and the right combination
depends on shape, mesh, dtype, and hardware.  This package chooses it,
mapping directly onto FFTW's planner design:

  FFTW concept          here
  --------------------  ---------------------------------------------------
  planner search space  ``candidates.enumerate_candidates`` — every valid
                        (Decomposition, FFTOptions) pair for (shape, mesh),
                        filtered by divisibility/overlap constraints
  FFTW_ESTIMATE         ``mode="model"`` — ``cost_model.analytic_cost``
                        builds the candidate's actual stage schedule
                        (``repro.core.schedule``, the same object the
                        executor runs) and walks it: per-stage FFT sizes
                        and transpose bytes, effective overlap-K,
                        collective launch counts — with zero execution;
                        optional HLO-derived collective counts via
                        ``cost_model.hlo_collectives``
  FFTW_PATIENT          ``mode="measure"`` — ``measure.measure_candidate``
                        compiles and wall-clocks the model-ranked top-k
                        (plus the untuned default) on the live mesh
  wisdom import/export  ``wisdom.Wisdom`` — JSON store keyed by
                        shape|mesh|dtype|backend[|problem]; ``mode="wisdom"``
                        reuses a stored plan without re-searching, and stores
                        can be merged across processes/hosts
                        (``python -m repro.tuning.wisdom merge``, with a
                        shipped seed file via ``--seed``)

Problem classes: ``problem="c2c"`` (default) and ``problem="r2c"`` — the
real transform is a first-class citizen: its candidates carry a
packed/embed strategy axis (the two-for-one pipelines of ``repro.real``,
pencil and slab alike, vs the embedding fallback), the schedule-derived
cost model charges the packed stages at their true half-volume sizes,
measurement runs real-input plans, and wisdom keys gain a problem
dimension.  ``heterogeneous_impls=True`` additionally searches per-stage
``local_impl`` 3-tuples, and ``batch=B`` plans for vmapped transforms
(volume terms scale by B, collective launch counts do not; the wisdom
key gains ``|b{B}``).

Entry points: :func:`tune` below, ``Croft3D.tuned(...)`` /
``Croft3D(..., tune="model")`` in ``repro.core.api``, and the
``benchmarks/tuning_bench.py`` / ``benchmarks/rfft_bench.py`` sweeps
(``BENCH_tuning.json`` / ``BENCH_rfft.json``).
"""

from repro.tuning.candidates import (Candidate, default_candidate,
                                     decompositions_for, enumerate_candidates)
from repro.tuning.cost_model import (CostBreakdown, analytic_cost,
                                     hlo_collectives, rank_candidates)
from repro.tuning.measure import measure_candidate, time_forward
from repro.tuning.planner import MODES, TuneResult, tune, upgrade_wisdom
from repro.tuning.wisdom import (Wisdom, WisdomEntry, load_seed,
                                 merge_entries, wisdom_key)

__all__ = [
    "Candidate", "CostBreakdown", "MODES", "TuneResult", "Wisdom",
    "WisdomEntry", "analytic_cost", "decompositions_for",
    "default_candidate", "enumerate_candidates", "hlo_collectives",
    "load_seed", "measure_candidate", "merge_entries", "rank_candidates",
    "time_forward", "tune", "upgrade_wisdom", "wisdom_key",
]
