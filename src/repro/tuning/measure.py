"""Empirical measurement — the planner's FFTW-``PATIENT`` leg.

Lowers, compiles, and wall-clock-times candidate plans on the live mesh.
Only the model-ranked top-k reach this stage (compiling every candidate
would be minutes of XLA time for a large mesh), mirroring how FFTW's
PATIENT mode prunes with heuristics before timing.
"""

from __future__ import annotations

import time
from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from repro.obs import metrics as metrics_lib
from repro.obs import tracer as tracer_lib
from repro.tuning.candidates import Candidate


def _random_input(shape, dtype, sharding):
    key = jax.random.PRNGKey(0)
    if jnp.issubdtype(jnp.dtype(dtype), jnp.complexfloating):
        real_dt = jnp.dtype(f"float{jnp.dtype(dtype).itemsize * 4}")
        kr, ki = jax.random.split(key)
        x = (jax.random.normal(kr, shape, real_dt)
             + 1j * jax.random.normal(ki, shape, real_dt)).astype(dtype)
    else:
        x = jax.random.normal(key, shape, dtype)
    if sharding is not None:
        x = jax.device_put(x, sharding)
    return x


def _batched_sharding(sharding, batch: int):
    """The plan's per-field sharding with a leading replicated batch axis."""
    if sharding is None:
        return None
    from jax.sharding import NamedSharding, PartitionSpec as P
    return NamedSharding(sharding.mesh, P(None, *sharding.spec))


def time_forward(plan, *, warmup: int = 2, iters: int = 5,
                 batch: int = 1) -> float:
    """Median wall seconds per forward transform of a built plan.

    ``batch > 1`` times the *vmapped* transform over B stacked fields —
    what a ``tune(batch=B)`` caller will actually run — instead of the
    B=1 proxy (under vmap the per-stage all_to_alls batch into single
    collectives, so deeper plans amortize their launches and the B=1
    timing would mis-rank them).
    """
    in_dtype = getattr(plan, "input_dtype", plan.dtype)  # real for r2c plans
    if batch > 1:
        x = _random_input((batch,) + tuple(plan.shape), in_dtype,
                          _batched_sharding(plan.input_sharding, batch))
        fwd = jax.jit(jax.vmap(plan.forward))
    else:
        x = _random_input(plan.shape, in_dtype, plan.input_sharding)
        fwd = plan.forward
    for _ in range(warmup):
        jax.block_until_ready(fwd(x))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fwd(x))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def time_train_step(plan, *, warmup: int = 2, iters: int = 5,
                    batch: int = 1) -> float:
    """Median wall seconds per ``value_and_grad`` step through the plan.

    This is what a ``*_grad`` tune races: a scalar loss (sum |F x|^2)
    differentiated back through the transform, so the timing covers the
    forward schedule *and* the adjoint schedule the custom VJP replays —
    the quantity a training loop actually pays per step.
    """
    in_dtype = getattr(plan, "input_dtype", plan.dtype)
    fwd = jax.vmap(plan.forward) if batch > 1 else plan.forward
    if batch > 1:
        x = _random_input((batch,) + tuple(plan.shape), in_dtype,
                          _batched_sharding(plan.input_sharding, batch))
    else:
        x = _random_input(plan.shape, in_dtype, plan.input_sharding)

    def loss(v):
        y = fwd(v)
        return jnp.sum(jnp.real(y * jnp.conj(y)))

    step = jax.jit(jax.value_and_grad(loss))
    for _ in range(warmup):
        jax.block_until_ready(step(x))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(step(x))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def measure_candidate(shape: Sequence[int], mesh, cand: Candidate,
                      dtype=jnp.complex64, *, warmup: int = 2,
                      iters: int = 5, batch: int = 1) -> Optional[float]:
    """Median forward seconds for one candidate on the live mesh (vmapped
    over ``batch`` stacked fields when batch > 1); None if the candidate
    fails to build/compile (it is then dropped from the race rather than
    failing the whole tune).  ``*_grad`` candidates race a full
    ``value_and_grad`` step (see :func:`time_train_step`) on the base
    problem's plan."""
    from repro.core.api import Croft3D
    from repro.tuning.candidates import split_grad
    # tag_scope marks every span/transform emitted while timing as tuner
    # traffic, so a shared trace never confuses measurement runs with
    # serving traffic (the two interleave when the plan cache's
    # background upgrade thread measures while the worker serves)
    with tracer_lib.tag_scope(traffic="tuning"):
        with tracer_lib.get_tracer().span("measure:candidate", "plan",
                                          plan=cand.label, batch=batch):
            try:
                from repro.resil import inject as inject_lib
                inject_lib.fire("tune.measure", cand.label)
                base_problem, is_grad = split_grad(cand.problem)
                plan = Croft3D(tuple(shape), mesh, cand.decomp, cand.opts,
                               dtype=jnp.dtype(dtype), problem=base_problem,
                               strategy=getattr(cand, "strategy", None),
                               schedule=cand if getattr(cand, "is_schedule",
                                                        False) else None)
                timer = time_train_step if is_grad else time_forward
                t = timer(plan, warmup=warmup, iters=iters, batch=batch)
            except Exception:
                metrics_lib.get_registry().counter(
                    "tune_measure_failures").inc()
                return None
    metrics_lib.get_registry().counter("tune_measure_runs").inc()
    return t
