"""The 40-cell (arch x shape) roofline table, read from dry-run artifacts.

Emits per-cell modeled step time (us) plus the three roofline terms; the
full table (with bottleneck labels and MFU) lands in EXPERIMENTS.md via
``python -m benchmarks.lm_roofline --write``.
"""

from __future__ import annotations

import json
import os

from benchmarks.common import DRYRUN_DIR, emit, load_dryrun
from repro.configs import ASSIGNED, SHAPES


def iter_cells(mesh_tag: str = "sp"):
    for arch in ASSIGNED:
        for shape in SHAPES:
            rec = load_dryrun(f"{arch}-{shape}-{mesh_tag}")
            yield arch, shape, rec


def run():
    n = 0
    for arch, shape, rec in iter_cells("sp"):
        if rec is None:
            continue
        r = rec["roofline"]
        emit(f"roofline/{arch}/{shape}/step", r["step_time_s"] * 1e6, True)
        emit(f"roofline/{arch}/{shape}/mfu_pct", 100 * r["mfu"], True)
        n += 1
    emit("roofline/cells-available", n, True)


def table_markdown(mesh_tag: str = "sp") -> str:
    rows = ["| arch | shape | compute s | memory s | collective s | "
            "bottleneck | MODEL_FLOPs/HLO | MFU |",
            "|---|---|---|---|---|---|---|---|"]
    for arch, shape, rec in iter_cells(mesh_tag):
        if rec is None:
            name = f"{arch}-{shape}-{mesh_tag}"
            path = os.path.join(DRYRUN_DIR, name + ".json")
            note = "missing"
            if os.path.exists(path):
                with open(path) as f:
                    d = json.load(f)
                note = d.get("status") + ": " + d.get(
                    "reason", d.get("error", ""))[:60]
            rows.append(f"| {arch} | {shape} | — | — | — | {note} | — | — |")
            continue
        r = rec["roofline"]
        rows.append(
            f"| {arch} | {shape} | {r['compute_s']:.4f} | "
            f"{r['memory_s']:.4f} | {r['collective_s']:.4f} | "
            f"**{r['bottleneck']}** | {r['useful_flops_fraction']:.2f} | "
            f"{100 * r['mfu']:.1f}% |")
    return "\n".join(rows)


if __name__ == "__main__":
    import sys
    if "--write" in sys.argv:
        print(table_markdown())
    else:
        run()
