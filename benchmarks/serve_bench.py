"""Serving bench: open-loop load on the transform service -> BENCH_serve.json.

Two parts, both on an 8-virtual-device CPU mesh in a subprocess:

1. **Deterministic batching gate.**  The service's whole premise is that
   batched dispatch amortizes collectives: a (B, ...) stacked dispatch
   must compile to the SAME per-stage collective count as a single
   request, with bytes scaling exactly xB (collective amortization is
   structural, not a scheduling accident).  The gate compares post-SPMD
   HLO collective stats of the B=1 and B=4 executables for a c2c and a
   packed r2c plan and FAILS the bench (and CI) on any mismatch.

2. **Open-loop load sweep.**  Poisson arrivals at fixed offered QPS
   drive a mixed workload (c2c 32^3, r2c 32^3, filtered c2c 16^3)
   through ``TransformService``; requests are timed end to end (submit
   -> host result, including H2D/D2H).  Reported per point: p50/p99
   latency, achieved QPS, batch occupancy (real rows / padded rows).
   Plus the plan-cache hit rate split into the cold phase (first
   requests pay ``mode="wisdom"``->model planning) and the steady state.

Wall-clock numbers are recorded but non-gating: this container
schedules 8 device threads on ~2 cores (the PR 5 caveat), so absolute
latencies track host load, not the code.  The gate is part 1.

``run(smoke=True)`` is the CI path (fewer QPS points, shorter windows).

The service's metrics-registry snapshot (counters, padding waste, batch
size / latency / queue-wait histograms) is always recorded under
``metrics`` in ``BENCH_serve.json``.  ``run(trace=...)`` (CLI: ``--trace
out.json``) additionally enables the ``repro.obs`` tracer for the load
sweep — request-lifecycle spans (submit -> queue wait -> dispatch ->
h2d/compute/d2h) plus plan-cache events — records the per-category time
rollup under ``phase_rollup``, and saves the Chrome trace.
"""

from __future__ import annotations

import json
import os

from benchmarks.common import REPO, emit, run_subprocess_bench

BENCH_JSON = os.path.join(REPO, "BENCH_serve.json")

_BENCH_CODE = """
import json, os, tempfile, time
import numpy as np, jax, jax.numpy as jnp

from repro.core import Croft3D
from repro.launch import hlo_cost
from repro.serve import PlanCache, TransformService

SMOKE = {smoke}
TRACE = {trace!r}
tracer = None
if TRACE:
    from repro import obs
    tracer = obs.enable()
mesh = jax.make_mesh((2, 4), ("y", "z"))
wisdom = os.path.join(tempfile.mkdtemp(), "serve_wisdom.json")
report = {{"backend": jax.default_backend(),
           "mesh": dict(mesh.shape),
           "caveat": ("8 virtual devices on a ~2-core host: wall-clock "
                      "latency tracks host load; the deterministic gate "
                      "is the HLO collective-count comparison"),
           }}

# ---- part 1: deterministic collective-amortization gate -------------------
cache = PlanCache(mesh, wisdom_path=wisdom)
GATE_B = 4
gate = {{"batch": GATE_B, "cases": {{}}, "ok": True}}
from repro.core import Decomposition
gate_plans = [
    # the tuner-picked c2c plan the service itself would dispatch
    ("c2c", cache.get((32, 32, 32), np.complex64, "c2c").plan),
    # packed r2c forced explicitly: its batched path is the NATIVE
    # leading-batch pipeline (not vmap), the stronger claim to gate
    ("r2c", Croft3D((32, 32, 32), mesh,
                    Decomposition("pencil", ("y", "z")),
                    problem="r2c", strategy="packed")),
]
for problem, plan in gate_plans:
    single = hlo_cost.analyze(
        plan.lower_forward().compile().as_text()).collectives

    def batched_collectives(B):
        fn = plan._batched_fn("forward")
        spec = jax.ShapeDtypeStruct((B,) + plan.shape, plan.input_dtype,
                                    sharding=plan.batched_sharding("input"))
        return hlo_cost.analyze(fn.lower(spec).compile().as_text()
                                ).collectives

    case = {{"single": single}}
    for B in (1, GATE_B):
        got = batched_collectives(B)
        case[f"batched_b{{B}}"] = got
        counts_ok = (set(got) == set(single) and all(
            got[k]["count"] == single[k]["count"] for k in single))
        bytes_ok = all(got[k]["bytes"] == B * single[k]["bytes"]
                       for k in single)
        case[f"b{{B}}_count_equal"] = counts_ok
        case[f"b{{B}}_bytes_scale_exact"] = bytes_ok
        gate["ok"] = gate["ok"] and counts_ok and bytes_ok
    gate["cases"][f"{{problem}}/{{plan.strategy or 'c2c'}}"] = case
report["gate"] = gate

# ---- part 2: open-loop load sweep -----------------------------------------
rng = np.random.RandomState(0)
N_BIG, N_SMALL = 32, 16
fields = {{
    "c2c32": ((rng.randn(N_BIG, N_BIG, N_BIG)
               + 1j * rng.randn(N_BIG, N_BIG, N_BIG)).astype(np.complex64),
              dict(problem="c2c")),
    "r2c32": (rng.randn(N_BIG, N_BIG, N_BIG).astype(np.float32),
              dict(problem="r2c")),
    "filt16": ((rng.randn(N_SMALL, N_SMALL, N_SMALL)
                + 1j * rng.randn(N_SMALL, N_SMALL, N_SMALL)
                ).astype(np.complex64),
               dict(problem="filtered",
                    h=rng.randn(N_SMALL, N_SMALL, N_SMALL
                                ).astype(np.complex64))),
}}
MIX = ["c2c32", "c2c32", "c2c32", "r2c32", "r2c32", "filt16"]
QPS_POINTS = (20.0, 60.0) if SMOKE else (10.0, 30.0, 100.0)
DURATION = 2.0 if SMOKE else 5.0

svc = TransformService(mesh, max_batch=4, max_wait_ms=3.0, cache=cache)
svc.start()

# cold phase: first request per key pays wisdom/model planning + compile;
# also warms every (bucket-size) executable so the timed phase measures
# serving, not XLA compiles
cold_stats0 = dict(hits=cache.stats.hits, misses=cache.stats.misses)
for name, (x, kw) in fields.items():
    for wave in (1, 2, 4):
        futs = [svc.submit(x, **kw) for _ in range(wave)]
        for f in futs:
            r = f.result(timeout=300)
            assert r.ok, r.error
cold = {{"misses": cache.stats.misses - cold_stats0["misses"],
         "hits": cache.stats.hits - cold_stats0["hits"]}}

points = []
for qps in QPS_POINTS:
    arrivals = np.cumsum(rng.exponential(1.0 / qps,
                                         size=int(qps * DURATION)))
    pre = svc.stats()
    pre_cache = dict(cache.stats.as_dict())
    futs = []
    t0 = time.monotonic()
    for i, t_arr in enumerate(arrivals):
        delay = t0 + t_arr - time.monotonic()
        if delay > 0:
            time.sleep(delay)
        x, kw = fields[MIX[i % len(MIX)]]
        futs.append(svc.submit(x, **kw))
    results = [f.result(timeout=300) for f in futs]
    t_total = time.monotonic() - t0
    assert all(r.ok for r in results)
    post = svc.stats()
    post_cache = dict(cache.stats.as_dict())
    lats = sorted(r.latency_s for r in results)
    d_real = post["real_rows"] - pre["real_rows"]
    d_batches = post["batches"] - pre["batches"]
    d_padded = post["padded_rows"] - pre["padded_rows"]
    points.append({{
        "offered_qps": qps,
        "achieved_qps": len(results) / t_total,
        "n_requests": len(results),
        "p50_ms": lats[len(lats) // 2] * 1e3,
        "p99_ms": lats[min(len(lats) - 1, int(0.99 * len(lats)))] * 1e3,
        "occupancy": d_real / d_padded if d_padded else None,
        "mean_batch": d_real / d_batches if d_batches else None,
        "steady_hit_rate": (
            (post_cache["hits"] - pre_cache["hits"])
            / max(1, (post_cache["hits"] - pre_cache["hits"]
                      + post_cache["misses"] - pre_cache["misses"]))),
    }})
report["load"] = {{"duration_s": DURATION, "mix": MIX, "points": points,
                  "cold_phase": cold}}
report["service_stats"] = svc.stats()
svc.stop()
report["plan_cache"] = cache.snapshot()
# per-phase breakdown: the registry snapshot is the always-on view
# (counters + batch/latency/queue-wait histograms with quantiles);
# plan-cache lifecycle counters live in the cache's own registry here
# because this bench builds the cache standalone
report["metrics"] = svc.registry.snapshot()
report["plan_cache_metrics"] = cache.registry.snapshot()
if tracer is not None:
    from repro.obs import report as obs_report
    report["phase_rollup"] = obs_report.category_rollup(tracer.events())
    tracer.save(TRACE)
    print("TRACE_WRITTEN " + TRACE)
print("SERVE_JSON " + json.dumps(report, default=float))
"""


def run(smoke: bool = False, trace: str | None = None) -> dict:
    out = run_subprocess_bench(
        _BENCH_CODE.format(smoke=repr(bool(smoke)), trace=trace),
        n_devices=8, timeout=1800)
    if trace and "TRACE_WRITTEN" not in out:
        raise RuntimeError("serve bench did not write the trace JSON")
    line = next(ln for ln in out.splitlines()
                if ln.startswith("SERVE_JSON "))
    report = json.loads(line[len("SERVE_JSON "):])

    for point in report["load"]["points"]:
        qps = point["offered_qps"]
        emit(f"serve/p50@q{qps:g}", point["p50_ms"] * 1e3, derived=False)
        emit(f"serve/p99@q{qps:g}", point["p99_ms"] * 1e3, derived=False)
    occ = [p["occupancy"] for p in report["load"]["points"]
           if p["occupancy"]]
    if occ:
        emit("serve/occupancy_max_pct", max(occ) * 100.0, derived=False)
    hit = report["plan_cache"]["stats"]["hit_rate"]
    emit("serve/plan_cache_hit_pct", hit * 100.0, derived=False)

    with open(BENCH_JSON, "w") as f:
        json.dump(report, f, indent=1, sort_keys=True)
    print(f"# wrote {BENCH_JSON}")

    gate = report["gate"]
    if not gate["ok"]:
        raise RuntimeError(
            "serve batching gate FAILED: batched dispatch does not "
            "compile to the single-request collective profile — "
            + json.dumps(gate["cases"]))
    print(f"# gate OK: batched B={gate['batch']} dispatch compiles to the "
          "same collective counts as one request (bytes scale exactly xB) "
          "for c2c and packed r2c")
    return report


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--trace", metavar="OUT.json", default=None,
                    help="enable the obs tracer for the load sweep and "
                         "save the Chrome trace here")
    args = ap.parse_args()
    run(smoke=args.smoke, trace=args.trace)
