"""Measured kernel microbenchmarks on this host (derived=0).

Pallas kernels run in interpret mode on CPU (validation mode, not perf
mode), so their absolute numbers are not TPU projections — the measured
rows exist to track regressions and to time the pure-jnp implementations
the distributed transform actually lowers.
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.core import local_fft
from repro.kernels import fft_matmul_1d, spectral_scale_op


def run():
    rng = np.random.RandomState(0)
    for n in [1024, 4096]:
        x = jnp.asarray((rng.randn(32, n) + 1j * rng.randn(32, n))
                        .astype(np.complex64))
        for name, fn in [
            ("fft-matmul-jnp", jax.jit(lambda v: local_fft.fft_matmul(v))),
            ("fft-stockham-jnp", jax.jit(lambda v: local_fft.fft_stockham(v))),
            ("fft-xla", jax.jit(lambda v: jnp.fft.fft(v))),
        ]:
            emit(f"micro/{name}/b32xn{n}", time_fn(fn, x), False)
        emit(f"micro/fft-matmul-pallas-interpret/b32xn{n}",
             time_fn(lambda v: fft_matmul_1d(v), x), False)
    h = jnp.asarray((rng.randn(4096) + 1j * rng.randn(4096))
                    .astype(np.complex64))
    x = jnp.asarray((rng.randn(32, 4096) + 1j * rng.randn(32, 4096))
                    .astype(np.complex64))
    emit("micro/spectral-scale-pallas-interpret/b32xn4096",
         time_fn(lambda v: spectral_scale_op(v, h), x), False)

    # end-to-end local 3-D transform (the per-pencil workload of one chip)
    g = jnp.asarray((rng.randn(128, 16, 16)
                     + 1j * rng.randn(128, 16, 16)).astype(np.complex64))
    fwd = jax.jit(lambda v: local_fft.fft3d_local(v, impl="matmul"))
    emit("micro/fft3d-local-128x16x16", time_fn(fwd, g), False)
