"""Schedule-search benchmark: does searching *pipelines* beat searching
knobs?  Writes ``BENCH_search.json`` with two deterministic gates:

* **model_win** (gate A): at the anisotropic gate point —
  ``(512, 512, 4)`` on a 2x4 pencil mesh, where the first transpose's
  chunk axis is down to one plane per rank and cannot split — the best
  *searched* schedule's modeled cost must be strictly below the best
  fixed-builder plan's, with BOTH priced by the same per-stage
  compute/collective combine (fixed candidates are wrapped via
  ``ScheduleCandidate.from_candidate``; the legacy whole-plan combine
  would average the unhideable stage away — per-stage attribution from
  ``repro.obs`` is precisely what showed it shouldn't be).  The winner
  must also be fixed-inexpressible (``as_options_candidate() is None``),
  i.e. a genuinely new point: mixed per-stage impls/K or a transpose
  order no builder emits.

* **hlo_mirror** (gate B): the winning pipeline structure, compiled at
  ``(32, 32, 4)`` on an 8-virtual-device CPU mesh, must contain exactly
  the per-stage predicted collective ops (``cost_model.
  predicted_collectives``): ring stages K_eff*(P-1) collective-permutes,
  alltoall stages K_eff all-to-alls.  This pins the per-stage override
  threading through the executor — an override silently ignored would
  compile to the homogeneous counts and fail here.

Wall-clock of searched-vs-fixed at the compile point is recorded
(``measured``) but NOT gated: on a single-host virtual mesh the
collectives are memcpys, so the modeled contention regime does not
reproduce — the numbers are for eyeballing, the model and HLO structure
are the contract.

``python -m benchmarks.search_bench --smoke`` is the CI entry point
(both gates; full mode adds a second mesh split and grad-problem rows).
"""

from __future__ import annotations

import argparse
import json
import os

import jax.numpy as jnp

from benchmarks.common import REPO, emit, run_subprocess_bench

BENCH_JSON = os.path.join(REPO, "BENCH_search.json")

GATE_SHAPE = (512, 512, 4)
GATE_AXES = {"data": 2, "model": 4}
COMPILE_SHAPE = (32, 32, 4)


def _gate_model_win(shape, axes) -> dict:
    """Gate A at one (shape, mesh) point; returns the report section."""
    from repro.tuning import candidates as cand_lib
    from repro.tuning import cost_model

    fixed = cand_lib.enumerate_candidates(shape, axes)
    wrapped, skipped = [], 0
    for c in fixed:
        try:
            wrapped.append(cand_lib.ScheduleCandidate.from_candidate(c))
        except ValueError:
            skipped += 1  # cell pipelines carry packing ops; logged below
    searched = cand_lib.enumerate_schedule_candidates(shape, axes)
    if skipped:
        print(f"# note: {skipped} fixed candidates (cell regroup "
              "pipelines) not priceable per-stage; compared on the rest")
    rw = cost_model.rank_candidates(shape, wrapped, axes, jnp.complex64, 1)
    rs = cost_model.rank_candidates(shape, searched, axes, jnp.complex64, 1)
    best_fixed, c_fixed = rw[0]
    best_sched, c_sched = rs[0]
    section = {
        "shape": list(shape),
        "axes": dict(axes),
        "n_fixed": len(wrapped),
        "n_fixed_unpriceable": skipped,
        "n_searched": len(searched),
        "best_fixed": {"plan_key": best_fixed.plan_key,
                       "model_s": c_fixed.total_s},
        "best_searched": {"plan_key": best_sched.plan_key,
                          "stages": best_sched.stage_summary(),
                          "model_s": c_sched.total_s},
        "win": c_sched.total_s < c_fixed.total_s,
        "inexpressible": best_sched.as_options_candidate() is None,
    }
    emit(f"search/model-fixed/{'x'.join(map(str, shape))}",
         c_fixed.total_s * 1e6, True)
    emit(f"search/model-searched/{'x'.join(map(str, shape))}",
         c_sched.total_s * 1e6, True)
    return section


_HLO_CODE = """
import json, numpy as np, jax, jax.numpy as jnp
from repro.core import Croft3D
from repro.launch import hlo_cost
from repro.tuning import candidates as cand_lib, cost_model
from repro.tuning.measure import _random_input, time_forward

shape = tuple({shape})
axes = {axes}
mesh = jax.make_mesh(tuple(axes.values()), tuple(axes))

cand = cand_lib.ScheduleCandidate.from_plan_key({token!r})
cand.validate(shape, axes)
sched = cand.build_schedule()
pred = cost_model.predicted_collectives(sched, shape, axes, cand.opts)

plan = Croft3D(shape, mesh=mesh, schedule=cand)
cost = hlo_cost.analyze(plan.lower_forward().compile().as_text())
got = {{k: int(v["count"]) for k, v in cost.collectives.items()}}
got = {{k: v for k, v in got.items() if v}}
pred = {{k: v for k, v in pred.items() if v}}

# wall clock, searched vs the untuned fixed default (recorded, NOT gated)
t_sched = time_forward(plan, warmup=2, iters=5)
dflt = cand_lib.default_candidate(shape, axes)
pf = Croft3D(shape, mesh, dflt.decomp, dflt.opts)
t_fixed = time_forward(pf, warmup=2, iters=5)

print("SEARCHJSON " + json.dumps({{
    "predicted": pred, "compiled": got, "match": pred == got,
    "measured_searched_s": t_sched, "measured_fixed_s": t_fixed}}))
"""


def _gate_hlo_mirror(token: str, shape, axes) -> dict:
    out = run_subprocess_bench(
        _HLO_CODE.format(shape=list(shape), axes=dict(axes), token=token),
        n_devices=8, timeout=900)
    for line in out.splitlines():
        if line.startswith("SEARCHJSON "):
            section = json.loads(line[len("SEARCHJSON "):])
            break
    else:
        raise RuntimeError("hlo-mirror subprocess produced no report")
    section.update(shape=list(shape), axes=dict(axes), plan_key=token)
    emit(f"search/wall-searched/{'x'.join(map(str, shape))}",
         section["measured_searched_s"] * 1e6, False)
    emit(f"search/wall-fixed/{'x'.join(map(str, shape))}",
         section["measured_fixed_s"] * 1e6, False)
    return section


def run(smoke: bool = False) -> None:
    report = {"model_win": [], "hlo_mirror": []}

    points = [(GATE_SHAPE, GATE_AXES)]
    if not smoke:
        points.append((GATE_SHAPE, {"data": 4, "model": 2}))
    for shape, axes in points:
        report["model_win"].append(_gate_model_win(shape, axes))

    gate_a = report["model_win"][0]
    if not (gate_a["win"] and gate_a["inexpressible"]):
        _dump(report)
        raise SystemExit(
            "REGRESSION: schedule search no longer finds a fixed-"
            f"inexpressible win at the gate point: {gate_a}")

    # gate B compiles the winning pipeline structure at the small shape
    # (same decomp/opts/stage tokens; the win shape's z extent carries
    # over so the chunk-indivisibility regime is preserved)
    token = gate_a["best_searched"]["plan_key"]
    report["hlo_mirror"].append(
        _gate_hlo_mirror(token, COMPILE_SHAPE, GATE_AXES))
    if not report["hlo_mirror"][0]["match"]:
        _dump(report)
        raise SystemExit(
            "REGRESSION: compiled collective counts diverge from the "
            f"per-stage prediction: {report['hlo_mirror'][0]}")

    _dump(report)


def _dump(report: dict) -> None:
    with open(BENCH_JSON, "w") as f:
        json.dump(report, f, indent=1, sort_keys=True)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="fast CI run: one gate point per gate")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(smoke=args.smoke)


if __name__ == "__main__":
    main()
