"""Overlap-engine sweep: alltoall vs pairwise vs ring at K in {1, 2, 4}.

Times ``Croft3D`` forward transforms on an 8-virtual-device CPU mesh in
a subprocess for every (transpose_impl, overlap K) point, on the pencil
(2x4, the acceptance case) and slab (8) decompositions at 64^3, and
emits ``BENCH_overlap.json``:

  * per-point wall times: ``wall_s`` (median) and ``wall_s_min`` — the
    best-of-N convention of FFT benchmarking (benchFFT): on a shared CI
    host the minimum of interleaved rounds is the only estimator that
    tracks the code rather than the host load,
  * per-point measured speedups vs the alltoall/K=1 reference
    (best-of-N over interleaved rounds, so load bursts hit all points),
  * HLO collective counts/bytes of the compiled forwards — the
    *structural* evidence of the overlap engine: ring at K=4 compiles to
    K*(P-1) independent collective-permutes per transpose where
    alltoall/K=1 compiles to one fused all-to-all,
  * the cost model's alpha/beta split (``derived``: TPU roofline
    constants, no TPU in this container): ring's P-1 launches vs its
    overlapped bandwidth term, the ranking the tuner's ``mode="model"``
    uses.

Caveat recorded in the JSON: this container schedules 8 device threads
on ~2 cores, so collective launches serialize and wall-clock overlap
gains cannot physically manifest (the interleaved best-of-N ratio
swings +-20% run to run).  The ring parity gate therefore has three
legs — two deterministic, one catastrophic-only:

  hlo    ring compiles to exactly sum(P_stage - 1) independent
         collective-permutes and strictly fewer collective bytes than
         alltoall (the self-piece never crosses the wire)
  model  ring's overlapped beta must beat the unoverlapped alltoall
         outright at 128^3 (deterministic arithmetic over the same
         Schedule the executor runs)
  wall   recorded, floor 0.5 (catches a real pack/unpack regression,
         not host-load coin flips)

``run(smoke=True)`` is the CI path (fewer rounds, same gate).
``run(trace=...)`` (CLI: ``--trace out.json``) additionally runs the
``repro.obs`` per-stage attribution pass on the pencil alltoall-K2 and
ring-K1 plans — after the timed sweep, so tracing never perturbs the
wall numbers — records the model-vs-measured phase breakdown under
``phases`` in ``BENCH_overlap.json``, and saves the Chrome trace.  The
breakdown itself is always recorded; ``trace`` only adds the JSON file.
"""

from __future__ import annotations

import json
import os

from benchmarks.common import REPO, emit, run_subprocess_bench

BENCH_JSON = os.path.join(REPO, "BENCH_overlap.json")

_SWEEP_CODE = """
import json, time, numpy as np, jax, jax.numpy as jnp
from repro.core import Croft3D, Decomposition, FFTOptions
from repro.tuning import cost_model
from repro.tuning.candidates import Candidate
from repro.tuning.measure import _random_input

rounds = {rounds}
N = 64
KS = (1, 2, 4)
IMPLS = ("alltoall", "pairwise", "ring")
report = {{"backend": jax.default_backend(), "shape": [N, N, N],
           "estimator": "best-of-%d interleaved rounds" % rounds,
           "caveat": ("8 virtual devices on a ~2-core host: collective "
                      "launches serialize, so wall-clock overlap cannot "
                      "manifest here; see the hlo/model entries for the "
                      "structural and roofline comparison"),
           "cases": {{}}}}

cases = [
    ("pencil", jax.make_mesh((2, 4), ("y", "z")),
     Decomposition("pencil", ("y", "z"))),
    ("slab", jax.make_mesh((8,), ("p",)), Decomposition("slab", ("p",))),
]
pencil_plans = None
for name, mesh, dec in cases:
    plans = {{}}
    for impl in IMPLS:
        for k in KS:
            plans[(impl, k)] = Croft3D(
                (N, N, N), mesh, dec,
                FFTOptions(overlap_k=k, transpose_impl=impl,
                           output_layout="spectral"))
    x = _random_input((N, N, N), jnp.complex64,
                      plans[("alltoall", 1)].input_sharding)
    if name == "pencil":
        pencil_plans, pencil_x = plans, x
    for p in plans.values():
        for _ in range(3):
            jax.block_until_ready(p.forward(x))
    # interleave every point each round: host-load bursts hit all impls
    walls = {{key: [] for key in plans}}
    for _ in range(rounds):
        for key, p in plans.items():
            t0 = time.perf_counter()
            jax.block_until_ready(p.forward(x))
            walls[key].append(time.perf_counter() - t0)
    base = min(walls[("alltoall", 1)])
    case = {{"mesh": dict(mesh.shape), "impls": {{}}}}
    for impl in IMPLS:
        ke = {{}}
        for k in KS:
            ws = sorted(walls[(impl, k)])
            cand = Candidate(dec, FFTOptions(
                overlap_k=k, transpose_impl=impl, output_layout="spectral"))
            cb = cost_model.analytic_cost((N, N, N), cand, dict(mesh.shape))
            ke["k%d" % k] = {{
                "wall_s": ws[len(ws) // 2],
                "wall_s_min": ws[0],
                "speedup_vs_alltoall_k1": base / ws[0],
                "model_total_s": cb.total_s,
                "model_latency_s": cb.latency_s,
                "model_collective_s": cb.collective_s,
                "model_transpose_overhead_s": cb.transpose_overhead_s,
                "model_n_collectives": cb.n_collectives,
            }}
            # HLO collective counts: the structural overlap evidence
            # (K=1 and K=4 bracket the chunked pipeline; skip K=2 to
            # halve the compile bill)
            if k in (1, 4):
                ke["k%d" % k]["hlo"] = cost_model.hlo_collectives(
                    plans[(impl, k)])
        case["impls"][impl] = ke
    for impl in ("pairwise", "ring"):
        best_k = max(KS, key=lambda k:
                     case["impls"][impl]["k%d" % k]["speedup_vs_alltoall_k1"])
        case["%s_best_k" % impl] = best_k
        case["speedup_%s_best_k_vs_alltoall_k1" % impl] = (
            case["impls"][impl]["k%d" % best_k]["speedup_vs_alltoall_k1"])
    a2a_model = case["impls"]["alltoall"]["k1"]["model_total_s"]
    case["model_speedup_ring_best_k_vs_alltoall_k1"] = max(
        a2a_model / case["impls"]["ring"]["k%d" % k]["model_total_s"]
        for k in KS)
    report["cases"][name] = case
    for impl in IMPLS:
        for k in KS:
            ws = sorted(walls[(impl, k)])
            print("ROW,overlap/%s/%s-k%d,%0.3f,0"
                  % (name, impl, k, ws[len(ws) // 2] * 1e6))
    print("SPEEDUP,%s-ring,%0.3f"
          % (name, case["speedup_ring_best_k_vs_alltoall_k1"]))

# acceptance gate (pencil 64^3/8): ring at parity-or-better vs the
# unoverlapped alltoall.  The wall-clock ratio on this host is NOT a
# stable statistic — 8 device threads on ~2 cores serialize collective
# launches and swing interleaved best-of-N ratios by +-20% run to run —
# so parity is established by the gate's *deterministic* legs and the
# wall ratio is recorded with only a catastrophic floor:
#   hlo    ring must compile to exactly sum_stages(K*(P_stage-1))
#          independent collective-permutes and STRICTLY FEWER collective
#          bytes than alltoall (the self-piece never crosses the wire) —
#          the structural form of "overlapped at no extra traffic"
#   model  the alpha/beta split must put ring's best K at parity within
#          the launch-latency term at 64^3 and AHEAD outright at 128^3
#          (the scale where bytes dominate launches) — deterministic
#          arithmetic over the same Schedule the executor runs
#   wall   recorded (best-of-N), floor 0.5: catches a real implementation
#          regression (e.g. a gather sneaking into the pack path costs
#          2-3x), not host-load coin flips
pcase = report["cases"]["pencil"]
pr = pcase["speedup_ring_best_k_vs_alltoall_k1"]
ring_hlo = pcase["impls"]["ring"]["k1"]["hlo"]
a2a_hlo = pcase["impls"]["alltoall"]["k1"]["hlo"]
ring_permutes = sum(v["count"] for k, v in ring_hlo["collectives"].items()
                    if "permute" in k)
model_128 = {{}}
for impl in ("alltoall", "ring"):
    cand = Candidate(Decomposition("pencil", ("y", "z")), FFTOptions(
        overlap_k=1, transpose_impl=impl, output_layout="spectral"))
    model_128[impl] = cost_model.analytic_cost(
        (128, 128, 128), cand, {{"y": 2, "z": 4}}).total_s
m128 = model_128["alltoall"] / model_128["ring"]
report["gate"] = {{
    "case": "pencil",
    "wall": {{"metric": "speedup_ring_best_k_vs_alltoall_k1",
              "value": pr, "floor": 0.5,
              "note": "launch-serializing host; see caveat"}},
    "hlo": {{"ring_collective_permutes": ring_permutes,
             "expected_permutes": (2 - 1) + (4 - 1),
             "ring_collective_bytes": ring_hlo["collective_bytes"],
             "alltoall_collective_bytes": a2a_hlo["collective_bytes"]}},
    "model": {{"speedup_ring_best_k_64":
               report["cases"]["pencil"]
               ["model_speedup_ring_best_k_vs_alltoall_k1"],
               "speedup_ring_k1_128": m128, "floor_128": 1.0}},
}}
fails = []
if ring_permutes != (2 - 1) + (4 - 1):
    fails.append("ring compiled to %d collective-permutes, expected 4"
                 % ring_permutes)
if not ring_hlo["collective_bytes"] < a2a_hlo["collective_bytes"]:
    fails.append("ring moves %s collective bytes vs alltoall %s — the "
                 "self-piece is crossing the wire"
                 % (ring_hlo["collective_bytes"],
                    a2a_hlo["collective_bytes"]))
if m128 < 1.0:
    fails.append("model puts ring K=1 at %.2fx vs alltoall K=1 at 128^3 "
                 "(must be >= 1.0: overlapped beta beats serialized beta "
                 "once bytes dominate)" % m128)
if pr < 0.5:
    fails.append("measured ring %.2fx vs alltoall K=1 (catastrophic "
                 "floor 0.5)" % pr)
if fails:
    raise SystemExit("REGRESSION: " + "; ".join(fails))

# ---- per-phase attribution (repro.obs) -------------------------------------
# Runs AFTER the timed sweep so span bookkeeping never touches the wall
# numbers above.  Traces the two acceptance plans stage by stage and
# joins measured legs against the cost model's predicted split.
from repro import obs
from repro.obs import instrument
tracer = obs.enable()
report["phases"] = {{}}
for label, pk in (("alltoall-k2", ("alltoall", 2)), ("ring-k1", ("ring", 1))):
    _, summary = instrument.trace_forward(pencil_plans[pk], pencil_x,
                                          tracer=tracer, iters=2,
                                          label=label)
    report["phases"][label] = summary
    print("ROW,overlap/attrib/%s/overlap-eff-pct,%0.3f,0"
          % (label, 100.0 * summary["overall"]["efficiency"]))
trace_path = {trace!r}
if trace_path:
    tracer.save(trace_path)
    print("TRACE_WRITTEN " + trace_path)

with open({out!r}, "w") as f:
    json.dump(report, f, indent=1, sort_keys=True)
print("JSON_WRITTEN")
"""


def run(smoke: bool = False, trace: str | None = None) -> None:
    code = _SWEEP_CODE.format(rounds=21 if smoke else 41, out=BENCH_JSON,
                              trace=trace)
    out = run_subprocess_bench(code, n_devices=8, timeout=1800)
    for line in out.splitlines():
        if line.startswith("ROW,"):
            _, name, us, derived = line.split(",")
            emit(name, float(us), bool(int(derived)))
    if "JSON_WRITTEN" not in out:
        raise RuntimeError("overlap sweep did not write BENCH_overlap.json")
    if trace and "TRACE_WRITTEN" not in out:
        raise RuntimeError("overlap sweep did not write the trace JSON")


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--trace", metavar="OUT.json", default=None,
                    help="save the attribution pass's Chrome trace here")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(smoke=args.smoke, trace=args.trace)
