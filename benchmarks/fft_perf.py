"""FFT §Perf sweep: paper-faithful baseline vs beyond-paper variants.

Runs the 1024^3 (and optionally 4096^3) pencil transform through the
hillclimb axes on the production mesh and records roofline terms per
variant:

  baseline        natural layout, K=2, plan cache, matmul local FFT
                  (CROFT option 4 — the paper-faithful configuration)
  k1 / k4 / k8    overlap-chunk sweep (paper's K knob)
  no-plan         option 3 (twiddles rematerialized per call)
  spectral        beyond-paper: skip the restoring transposes
  xla-fft         XLA's native FFT op as the local kernel
  slab            the FFTW3-model decomposition
  spectral+k4     combined best

Usage: XLA flag is set by the module itself (production mesh);
    PYTHONPATH=src python -m benchmarks.fft_perf [--grid fft_4096]
"""

import os

if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import json

from repro.core.distributed import FFTOptions


VARIANTS = {
    "baseline-opt4": FFTOptions(overlap_k=2, plan_cache=True),
    "k1-no-overlap": FFTOptions(overlap_k=1),
    "k4": FFTOptions(overlap_k=4),
    "k8": FFTOptions(overlap_k=8),
    "opt3-no-plan": FFTOptions(overlap_k=2, plan_cache=False),
    "spectral": FFTOptions(overlap_k=2, output_layout="spectral"),
    "xla-fft": FFTOptions(overlap_k=2, local_impl="xla"),
    "stockham": FFTOptions(overlap_k=2, local_impl="stockham"),
    "spectral+k4": FFTOptions(overlap_k=4, output_layout="spectral"),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--grid", default="fft_1024")
    ap.add_argument("--out", default="results/fft_perf")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--slab", action="store_true", help="include slab row")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    from repro.launch.dryrun import lower_fft_cell  # after XLA_FLAGS

    rows = []
    for name, opts in VARIANTS.items():
        rec = lower_fft_cell(args.grid, args.multi_pod, "pencil", opts)
        rec["variant"] = name
        rows.append(rec)
        r = rec.get("roofline", {})
        print(f"{name:16s} status={rec['status']} "
              f"compute={r.get('compute_s', 0):.6f}s "
              f"memory={r.get('memory_s', 0):.6f}s "
              f"coll={r.get('collective_s', 0):.6f}s "
              f"a2a_ops={rec.get('collectives', {}).get('all-to-all', {}).get('count', 0)}",
              flush=True)
        with open(os.path.join(args.out, f"{args.grid}-{name}.json"), "w") as f:
            json.dump(rec, f, indent=1)
    if args.slab:
        rec = lower_fft_cell(args.grid, args.multi_pod, "slab", FFTOptions())
        rec["variant"] = "slab"
        with open(os.path.join(args.out, f"{args.grid}-slab.json"), "w") as f:
            json.dump(rec, f, indent=1)
        r = rec.get("roofline", {})
        print(f"{'slab':16s} status={rec['status']} "
              f"memory={r.get('memory_s', 0):.6f}s "
              f"coll={r.get('collective_s', 0):.6f}s")


if __name__ == "__main__":
    main()
