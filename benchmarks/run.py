"""Benchmark harness: one module per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run``  prints ``name,us_per_call,derived``
CSV rows (derived=0: measured on this host; 1: modeled from compiled
artifacts / roofline constants — no TPU in this container).

``--smoke`` runs only the fast sweeps — the autotuner
(``benchmarks.tuning_bench``), the real-transform packed-vs-embed
comparison (``benchmarks.rfft_bench``), the transpose overlap-engine
sweep (``benchmarks.overlap_bench``), and the transform-service load
sweep (``benchmarks.serve_bench``) — the CI path exercising the planner,
the r2c pipeline, all three transpose impls, and the serving layer
(including its deterministic batched-collective gate) end to end on
every push.
"""

import argparse
import sys
import traceback

FULL_MODULES = ["benchmarks.fft_tables", "benchmarks.collective_profile",
                "benchmarks.kernel_micro", "benchmarks.lm_roofline",
                "benchmarks.train_bench", "benchmarks.tuning_bench",
                "benchmarks.rfft_bench", "benchmarks.overlap_bench",
                "benchmarks.serve_bench"]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="fast tuner-only sweep (CI)")
    args = ap.parse_args()

    print("name,us_per_call,derived")
    failures = []
    if args.smoke:
        from benchmarks import (overlap_bench, rfft_bench, serve_bench,
                                tuning_bench)
        tuning_bench.run(smoke=True)
        rfft_bench.run(smoke=True)
        overlap_bench.run(smoke=True)
        serve_bench.run(smoke=True)
        return
    for modname in FULL_MODULES:
        try:
            mod = __import__(modname, fromlist=["run"])
            mod.run()
        except Exception as e:
            failures.append((modname, e))
            print(f"# ERROR in {modname}: {type(e).__name__}: {e}",
                  file=sys.stderr)
            traceback.print_exc()
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
