"""Benchmark harness: one module per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run``  prints ``name,us_per_call,derived``
CSV rows (derived=0: measured on this host; 1: modeled from compiled
artifacts / roofline constants — no TPU in this container).

``--smoke`` runs only the fast sweeps — the autotuner
(``benchmarks.tuning_bench``), the real-transform packed-vs-embed
comparison (``benchmarks.rfft_bench``), the transpose overlap-engine
sweep (``benchmarks.overlap_bench``), the transform-service load
sweep (``benchmarks.serve_bench``), and the collective-op profile with
its alpha/beta calibration fit (``benchmarks.collective_profile``) —
the CI path exercising the planner, the r2c pipeline, all three
transpose impls, and the serving layer (including its deterministic
batched-collective gate) end to end on every push.

``--trace DIR`` has the overlap and serve sweeps save Chrome-trace JSON
(``DIR/overlap_trace.json`` / ``DIR/serve_trace.json``) alongside their
``BENCH_*.json`` phase breakdowns.
"""

import argparse
import sys
import traceback

FULL_MODULES = ["benchmarks.fft_tables", "benchmarks.collective_profile",
                "benchmarks.kernel_micro", "benchmarks.lm_roofline",
                "benchmarks.train_bench", "benchmarks.tuning_bench",
                "benchmarks.search_bench", "benchmarks.rfft_bench",
                "benchmarks.overlap_bench", "benchmarks.serve_bench",
                "benchmarks.chaos_bench", "benchmarks.trace_smoke"]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="fast tuner-only sweep (CI)")
    ap.add_argument("--trace", metavar="DIR", default=None,
                    help="save Chrome-trace JSON from the overlap/serve "
                         "sweeps into DIR")
    args = ap.parse_args()

    print("name,us_per_call,derived")
    failures = []
    if args.smoke:
        import os

        from benchmarks import (chaos_bench, collective_profile,
                                overlap_bench, rfft_bench, serve_bench,
                                trace_smoke, tuning_bench)
        tdir = args.trace
        if tdir:
            os.makedirs(tdir, exist_ok=True)
        tuning_bench.run(smoke=True)
        rfft_bench.run(smoke=True)
        overlap_bench.run(
            smoke=True,
            trace=os.path.join(tdir, "overlap_trace.json") if tdir else None)
        serve_bench.run(
            smoke=True,
            trace=os.path.join(tdir, "serve_trace.json") if tdir else None)
        chaos_bench.run(smoke=True)
        collective_profile.run(smoke=True)
        trace_smoke.run(smoke=True)
        return
    for modname in FULL_MODULES:
        try:
            mod = __import__(modname, fromlist=["run"])
            mod.run()
        except Exception as e:
            failures.append((modname, e))
            print(f"# ERROR in {modname}: {type(e).__name__}: {e}",
                  file=sys.stderr)
            traceback.print_exc()
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
