"""Benchmark harness: one module per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run``  prints ``name,us_per_call,derived``
CSV rows (derived=0: measured on this host; 1: modeled from compiled
artifacts / roofline constants — no TPU in this container).
"""

import sys
import traceback


def main() -> None:
    print("name,us_per_call,derived")
    failures = []
    for modname in ["benchmarks.fft_tables", "benchmarks.collective_profile",
                    "benchmarks.kernel_micro", "benchmarks.lm_roofline",
                    "benchmarks.train_bench"]:
        try:
            mod = __import__(modname, fromlist=["run"])
            mod.run()
        except Exception as e:
            failures.append((modname, e))
            print(f"# ERROR in {modname}: {type(e).__name__}: {e}",
                  file=sys.stderr)
            traceback.print_exc()
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
