"""Benchmark helpers: timing, CSV rows, analytic cluster model.

Rows follow ``name,us_per_call,derived`` — ``derived=0`` means measured
wall time on this host; ``derived=1`` means modeled from roofline terms /
compiled artifacts (this container has no TPU to time).
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
import time
from typing import Callable

import jax

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DRYRUN_DIR = os.path.join(REPO, "results", "dryrun")

ROWS: list[tuple[str, float, int]] = []


def emit(name: str, us_per_call: float, derived: bool):
    ROWS.append((name, us_per_call, int(derived)))
    print(f"{name},{us_per_call:.3f},{int(derived)}")


def time_fn(fn: Callable, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall microseconds per call."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def load_dryrun(name: str):
    path = os.path.join(DRYRUN_DIR, name + ".json")
    if not os.path.exists(path):
        return None
    with open(path) as f:
        rec = json.load(f)
    return rec if rec.get("status") == "ok" else None


def run_subprocess_bench(code: str, n_devices: int, timeout: int = 600) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src") + os.pathsep + \
        env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=timeout)
    if proc.returncode != 0:
        raise RuntimeError(proc.stderr[-2000:])
    return proc.stdout


# --- analytic cluster model (paper tables without the cluster) -------------
# Param Bioblaze-analogue on TPU v5e constants; used to extrapolate the
# P-sweeps of tables 1-3 from the per-device transpose/compute volumes.

from repro.launch.roofline import HBM_BW, LINK_BW, PEAK_FLOPS  # noqa: E402


def fft_step_model(grid, n_procs: int, decomposition: str = "pencil",
                   overlap: bool = True, layout: str = "natural",
                   itemsize: int = 8) -> dict:
    """Modeled 3-D FFT wall time on n_procs chips.

    compute: 5 N log2 N / P on the MXU;  memory: ~10 local passes;
    collective: transpose volume / link bw; overlap hides
    min(comm, compute+memory) when enabled (the paper's mechanism).
    """
    import math
    n_total = grid[0] * grid[1] * grid[2]
    local = n_total // n_procs * itemsize
    flops = 5 * n_total * sum(math.log2(g) for g in grid) / n_procs
    n_transposes = {"slab": 1, "pencil": 2, "cell": 3}[decomposition]
    if layout == "natural":
        n_transposes *= 2
    comm = n_transposes * local
    t_compute = flops / PEAK_FLOPS
    t_mem = 10 * local / HBM_BW
    t_comm = comm / LINK_BW
    if overlap:
        t = max(t_compute + t_mem, t_comm) + 0.1 * min(t_compute + t_mem, t_comm)
    else:
        t = t_compute + t_mem + t_comm
    return {"total_s": t, "compute_s": t_compute, "memory_s": t_mem,
            "collective_s": t_comm}
