"""Chaos bench: a seeded fault script against the serving SLO ->
BENCH_chaos.json.

One 8-virtual-device subprocess runs a deterministic fault script
through ``TransformService`` (``repro.resil.inject`` arms every fault at
an exact invocation index, so the prediction is computable before the
run) and the gate demands:

  * **zero hung futures** — every submitted future resolves;
  * **healthy availability 100%** — every request the script did NOT
    target succeeds, bitwise-equal to the direct plan call where a
    reference is computed;
  * **exact event accounting** — observed metrics counters equal the
    script's prediction exactly (one injected fault -> one retry /
    quarantine / shed / degradation event, never zero, never double);
  * **degradation parity** — after the scripted quarantine the degraded
    bucket's results equal the direct bottom-rung plan bit for bit.

The script (see ``_BENCH_CODE``):

  A. transient dispatch faults on the r2c bucket at invocations (0, 1)
     -> exactly 2 retries, then success;
  B. persistent dispatch faults on the primary c2c bucket with
     ``quarantine_after=2`` -> 2 failures, 1 quarantine, 1 degradation,
     then bitwise-parity service on the default rung;
  C. one NaN payload co-batched with two healthy requests -> 1 poisoned
     isolation, 2 individual re-dispatches, healthy results intact;
  D. a deadline storm (6 requests with ``deadline_s=0``) -> 6 typed
     deadline misses, nothing dispatched;
  E. bounded-queue shedding (``max_queue=4``, 4 HIGH + 3 LOW pending)
     -> exactly the 3 LOWs shed with typed queue-full results;
  F. one wisdom-store corruption + one crash-mid-write -> 1 quarantined
     ``.corrupt-1`` file, store stays loadable, stale temp cleaned.
"""

from __future__ import annotations

import json
import os

from benchmarks.common import REPO, emit, run_subprocess_bench

BENCH_JSON = os.path.join(REPO, "BENCH_chaos.json")

_BENCH_CODE = """
import json, os, tempfile, time
import numpy as np, jax

from repro.core import Croft3D
from repro.obs import metrics as metrics_lib
from repro.resil import (CrashMidWrite, FaultSpec, degrade, injection)
from repro.serve import (PRIORITY_HIGH, PRIORITY_LOW, PlanCache, ShedResult,
                         TransformService)
from repro.tuning import wisdom as wisdom_lib
from repro.tuning.candidates import default_candidate

SMOKE = {smoke}
N = 16
AXES = {{"y": 2, "z": 4}}
mesh = jax.make_mesh((2, 4), ("y", "z"))
rng = np.random.RandomState(0)
xc = (rng.randn(N, N, N) + 1j * rng.randn(N, N, N)).astype(np.complex64)
xr = rng.randn(N, N, N).astype(np.float32)

report = {{"backend": jax.default_backend(), "mesh": dict(mesh.shape),
           "scenarios": {{}}}}
futures = []       # (label, future) — the zero-hung-futures ledger
healthy = []       # (label, ok, bitwise_ok_or_None) — availability ledger
predicted = {{}}   # counter name -> exact predicted value

def resolve(label, fut, timeout=300):
    futures.append(label)
    return fut.result(timeout=timeout)   # a hang fails the bench here

# the primary c2c plan comes from seeded wisdom (measured, so it is born
# "warm" and never arms a background upgrade): the stock K=2 candidate,
# one rung above the ladder's K=1 bottom
wisdom = os.path.join(tempfile.mkdtemp(), "w.json")
cand = default_candidate((N, N, N), AXES)
key_c2c = wisdom_lib.wisdom_key((N, N, N), AXES, np.complex64,
                                jax.default_backend())
wisdom_lib.merge_entries(wisdom, {{key_c2c:
    wisdom_lib.WisdomEntry.from_candidate(cand, source="measure",
                                          measured_s=1e-3)}})

reg = metrics_lib.MetricsRegistry()
cache = PlanCache(mesh, wisdom_path=wisdom, quarantine_after=2,
                  registry=reg)
svc = TransformService(mesh, max_batch=4, max_wait_ms=150.0, cache=cache,
                       registry=reg, retry_backoff_s=0.0)
svc.start()

# pre-build the primary plan so its pipeline token is known to the fault
# script (the scripted error matches the PRIMARY token only — after the
# quarantine swaps the bottom rung in, the bucket token changes and the
# fault stops matching, exactly like a plan-specific crash would)
cp0 = cache.get((N, N, N), np.complex64, "c2c")
token_c2c = cp0.plan_token

script = [
    FaultSpec("serve.dispatch", times=(0, 1), kind="transient",
              match="|r2c"),          # A: r2c bucket, attempts 0 and 1
    FaultSpec("serve.dispatch", times=(0, 1), kind="error",
              match=token_c2c),       # B: primary c2c bucket, 2 dispatches
]

with injection(script) as fault_plan:
    # --- A: transient faults retry with backoff, then succeed ----------
    r = resolve("A:r2c", svc.submit(xr, problem="r2c"))
    plan_r = cache.get((N, N, N), np.complex64, "r2c").plan
    ref_r = np.asarray(plan_r.forward(jax.device_put(
        xr.astype(plan_r.input_dtype), plan_r.input_sharding)))
    healthy.append(("A:r2c", r.ok, bool(np.array_equal(r.value, ref_r))))
    report["scenarios"]["A_transient_retry"] = {{
        "ok": r.ok, "retries_predicted": 2}}
    predicted["serve_dispatch_retries"] = 2

    # --- B: persistent faults -> quarantine -> degradation -------------
    fails = [resolve(f"B:storm{{i}}", svc.submit(xc)) for i in range(2)]
    assert all(not r.ok for r in fails), [r.error for r in fails]
    predicted["plan_dispatch_failures"] = 2
    predicted["plan_quarantines"] = 1
    predicted["plan_degradations"] = 1

bottom = degrade.bottom_candidate((N, N, N), AXES)
fallback = Croft3D((N, N, N), mesh, bottom.decomp, bottom.opts)
ref_c = np.asarray(fallback.forward(
    jax.device_put(xc, fallback.input_sharding)))
cp1 = cache.get((N, N, N), np.complex64, "c2c")
degraded = [resolve(f"B:degraded{{i}}", svc.submit(xc)) for i in range(2)]
parity = [bool(np.array_equal(r.value, ref_c)) for r in degraded]
for i, r in enumerate(degraded):
    healthy.append((f"B:degraded{{i}}", r.ok, parity[i]))
report["scenarios"]["B_quarantine_degrade"] = {{
    "primary_token": token_c2c, "degraded_rung": cp1.rung,
    "quarantined": cp1.quarantined, "fallback_parity": parity}}
assert cp1.rung == "default" and cp1.quarantined, cp1.rung

# --- C: NaN payload isolation on the (degraded) c2c bucket -------------
bad = xc.copy(); bad[0, 0, 0] = np.nan
f_bad = svc.submit(bad)
f_mates = [svc.submit(xc) for _ in range(2)]
rb = resolve("C:poisoned", f_bad)
assert not rb.ok and "poisoned payload" in rb.error, rb.error
for i, f in enumerate(f_mates):
    r = resolve(f"C:mate{{i}}", f)
    healthy.append((f"C:mate{{i}}", r.ok,
                    bool(np.array_equal(r.value, ref_c))))
predicted["serve_poisoned_requests"] = 1
predicted["serve_poison_redispatches"] = 2
predicted["serve_nan_outputs"] = 0
predicted["serve_failures"] = 2 + 1   # B's storm + C's poisoned request
report["scenarios"]["C_nan_isolation"] = {{"poisoned": 1, "redispatch": 2}}

# --- D: deadline storm (never dispatches, always typed) ----------------
DEADLINE_STORM = 6
miss_reasons = []
for i in range(DEADLINE_STORM):
    r = resolve(f"D:storm{{i}}", svc.submit(xc, deadline_s=0.0))
    miss_reasons.append(isinstance(r, ShedResult)
                        and r.shed_reason == "deadline")
assert all(miss_reasons), miss_reasons
predicted["serve_deadline_misses"] = DEADLINE_STORM
report["scenarios"]["D_deadline_storm"] = {{"misses": DEADLINE_STORM}}

# --- E: bounded-queue shedding (own meshless service: the 60s wait
#        budget keeps everything pending, so counts are exact) ----------
svc2 = TransformService(max_batch=8, max_wait_ms=60000.0, max_queue=4)
svc2.start()
M = 8
x8 = (rng.randn(M, M, M) + 1j * rng.randn(M, M, M)).astype(np.complex64)
highs = [svc2.submit(x8, priority=PRIORITY_HIGH) for _ in range(4)]
lows = [svc2.submit(x8, priority=PRIORITY_LOW) for _ in range(3)]
shed_ok = [isinstance(resolve(f"E:low{{i}}", f), ShedResult)
           for i, f in enumerate(lows)]
svc2.stop()  # drain serves the HIGHs
for i, f in enumerate(highs):
    r = resolve(f"E:high{{i}}", f)
    healthy.append((f"E:high{{i}}", r.ok, None))
assert all(shed_ok), shed_ok
report["scenarios"]["E_queue_shed"] = {{"shed": 3, "served": 4}}

svc.stop()

# --- F: wisdom corruption + crash-mid-write ----------------------------
blob = json.load(open(wisdom))
blob["entries"][key_c2c]["model_s"] = 1e9   # tamper; checksum now stale
json.dump(blob, open(wisdom, "w"))
w = wisdom_lib.Wisdom.load(wisdom)
corrupt_moved = os.path.exists(wisdom + ".corrupt-1")
assert len(w) == 0 and corrupt_moved
crashed = False
try:
    with injection([FaultSpec("wisdom.write.crash", times=(0,),
                              kind="crash")]) as crash_plan:
        wisdom_lib.merge_entries(wisdom, {{key_c2c:
            wisdom_lib.WisdomEntry.from_candidate(cand, source="model",
                                                  model_s=1e-3)}})
except CrashMidWrite:
    crashed = True
tmp_left = os.path.exists(wisdom + ".tmp")
wisdom_lib.merge_entries(wisdom, {{key_c2c:
    wisdom_lib.WisdomEntry.from_candidate(cand, source="model",
                                          model_s=1e-3)}})
rebuilt = sorted(wisdom_lib.Wisdom.load(wisdom).entries) == [key_c2c]
tmp_cleaned = not os.path.exists(wisdom + ".tmp")
assert crashed and tmp_left and rebuilt and tmp_cleaned
predicted["wisdom_corrupt_files"] = 1      # global registry
report["scenarios"]["F_wisdom"] = {{
    "corrupt_moved": corrupt_moved, "crash_left_tmp": tmp_left,
    "rebuilt": rebuilt, "tmp_cleaned": tmp_cleaned}}

# --- gates -------------------------------------------------------------
snap = reg.snapshot()
snap2 = svc2.registry.snapshot()
gsnap = metrics_lib.get_registry().snapshot()
predicted["serve_shed_requests"] = 3       # svc2 registry

def observed(name):
    # total events across both services + the global registry (the two
    # service registries are disjoint; wisdom/fault counters are global)
    return int(sum(s[name]["value"] for s in (snap, snap2, gsnap)
                   if name in s))

counters = {{name: {{"predicted": want, "observed": observed(name)}}
            for name, want in predicted.items()}}
counts_exact = all(c["predicted"] == c["observed"]
                   for c in counters.values())

# injected-fault accounting: every scripted index fired exactly once
fired = fault_plan.fired_counts()
fault_exact = (fired == {{"serve.dispatch": 4}}
               and fault_plan.predicted_counts()
               == {{"serve.dispatch": 4}})

availability = (sum(1 for _l, ok, _p in healthy if ok)
                / max(1, len(healthy)))
parity_ok = all(p for _l, _ok, p in healthy if p is not None)

report["gate"] = {{
    "futures_resolved": len(futures), "hung_futures": 0,
    "healthy_total": len(healthy), "availability": availability,
    "bitwise_parity": parity_ok, "counters": counters,
    "counters_exact": counts_exact,
    "faults_fired": fired, "faults_exact": fault_exact,
    "ok": bool(counts_exact and fault_exact and parity_ok
               and availability == 1.0),
}}
print("CHAOS_JSON " + json.dumps(report, default=float))
"""


def run(smoke: bool = False) -> dict:
    out = run_subprocess_bench(_BENCH_CODE.format(smoke=repr(bool(smoke))),
                               n_devices=8, timeout=1800)
    line = next(ln for ln in out.splitlines()
                if ln.startswith("CHAOS_JSON "))
    report = json.loads(line[len("CHAOS_JSON "):])

    gate = report["gate"]
    emit("chaos/availability_pct", gate["availability"] * 100.0,
         derived=False)
    emit("chaos/hung_futures", float(gate["hung_futures"]), derived=False)
    emit("chaos/counters_exact", float(gate["counters_exact"]),
         derived=False)

    with open(BENCH_JSON, "w") as f:
        json.dump(report, f, indent=1, sort_keys=True)
    print(f"# wrote {BENCH_JSON}")

    if not gate["ok"]:
        raise RuntimeError(
            "chaos gate FAILED: injected faults did not map 1:1 to "
            "observed resilience events — " + json.dumps(gate))
    print(f"# gate OK: {gate['futures_resolved']} futures resolved, "
          f"availability {gate['availability']:.0%}, every scripted fault "
          "accounted for exactly (retries/quarantines/sheds/degradations)")
    return report


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()
    run(smoke=args.smoke)
