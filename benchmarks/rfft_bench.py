"""Real-transform sweep: packed two-for-one vs the embedding fallback.

Times ``Croft3D(problem="r2c")`` with both strategies on an 8-virtual-
device CPU mesh in a subprocess (the embed baseline runs the legacy
default plan — natural layout + guarded half-slice — i.e. exactly what
``rfft3d`` did before ``repro.real`` existed).  The two plans are timed
*interleaved*, one call each per round, and the reported speedup is the
median per-round ratio: host-load bursts on a shared CI machine hit
both strategies of a round equally, so the ratio is far more stable
than two independently-timed medians.  Emits

  * ``rfft/<shape>/embed`` and ``rfft/<shape>/packed`` CSV rows
    (derived=0 — measured on this host), plus ``slab-embed`` /
    ``slab-packed`` rows for the packed-slab pipeline on a 1-axis mesh
    and ``solver-unfused`` / ``solver-fused`` rows for the spectral
    solver's k-space multiply fused as a schedule epilogue, and
  * ``BENCH_rfft.json`` at the repo root: wall times, speedups, modeled
    per-device transpose bytes (total and first-stage) from the tuning
    cost model (which walks the same ``Schedule`` the executor runs),
    HLO collective stats of both compiled forwards, a ``packed_slab``
    entry, and a ``fused_epilogue`` entry whose parity-or-better gate is
    *deterministic* — compiled HLO bytes of the fused executable must be
    strictly below forward+multiply — with wall times reported
    best-of-N (see the comments at the gate for why wall ratios and
    median-of-ratios are the wrong statistics on this host).

The packed pipeline moves half the bytes per transpose and skips the
restoring transposes entirely, so the expected result is a ~2x
first-stage byte reduction and a >= 1.4x wall-time speedup at 64^3.

``run(smoke=True)`` keeps the 64^3 shape (the acceptance shape) with
fewer timing iterations — it is the CI path.
"""

from __future__ import annotations

import json
import os

from benchmarks.common import REPO, emit, run_subprocess_bench

BENCH_JSON = os.path.join(REPO, "BENCH_rfft.json")

_SWEEP_CODE = """
import json, time, numpy as np, jax, jax.numpy as jnp
from repro.core import Croft3D, Decomposition, FFTOptions
from repro.tuning import cost_model
from repro.tuning.candidates import Candidate
from repro.tuning.measure import _random_input

shapes = {shapes!r}
rounds = {rounds}
mesh = jax.make_mesh((2, 4), ("y", "z"))
dec = Decomposition("pencil", ("y", "z"))
report = {{"mesh": {{"y": 2, "z": 4}}, "backend": jax.default_backend(),
           "decomp": "pencil[yxz]", "shapes": {{}}}}
for shape in shapes:
    shape = tuple(shape)
    rec = {{}}
    # embed baseline = the legacy default plan (natural layout); the
    # packed pipeline has one layout, its stock options
    plans = {{strat: Croft3D(shape, mesh, dec, FFTOptions(),
                             problem="r2c", strategy=strat)
              for strat in ("embed", "packed")}}
    xs = {{s: _random_input(p.shape, p.input_dtype, p.input_sharding)
           for s, p in plans.items()}}
    for s, p in plans.items():
        for _ in range(2):  # warmup/compile
            jax.block_until_ready(p.forward(xs[s]))
    # interleave the strategies each round so host-load bursts hit both;
    # the per-round ratio is what the gate consumes (median over rounds)
    walls = {{s: [] for s in plans}}
    ratios = []
    for _ in range(rounds):
        t = {{}}
        for s, p in plans.items():
            t0 = time.perf_counter()
            jax.block_until_ready(p.forward(xs[s]))
            t[s] = time.perf_counter() - t0
            walls[s].append(t[s])
        ratios.append(t["embed"] / t["packed"])
    ratios.sort()
    for strat, p in plans.items():
        ws = sorted(walls[strat])
        cand = Candidate(dec, FFTOptions(), problem="r2c", strategy=strat)
        cb = cost_model.analytic_cost(shape, cand, dict(mesh.shape))
        itemsize = 8  # complex64 spectrum
        local = shape[0] * shape[1] * shape[2] // 8 * itemsize
        first_stage = local // 2 if strat == "packed" else local
        rec[strat] = {{
            "wall_s": ws[len(ws) // 2],
            "wall_s_min": ws[0],
            "model_collective_bytes_per_device": cb.collective_bytes,
            "model_first_stage_bytes_per_device": first_stage,
            "hlo": cost_model.hlo_collectives(p),
        }}
    rec["speedup_packed_vs_embed"] = ratios[len(ratios) // 2]
    rec["speedup_packed_vs_embed_best"] = (
        rec["embed"]["wall_s_min"] / rec["packed"]["wall_s_min"])
    rec["speedup_rounds"] = ratios
    rec["first_stage_bytes_ratio"] = (
        rec["embed"]["model_first_stage_bytes_per_device"]
        / rec["packed"]["model_first_stage_bytes_per_device"])
    # acceptance gate: the packed pipeline must beat the embedding by
    # >= 1.4x at 64^3 (it does half the flops and moves half the
    # bytes).  Gated on the best-of-N walls ratio: load bursts on a
    # contended CI host only ever inflate rounds, so the minimum tracks
    # the code, while the median-of-ratios (still reported) swings with
    # the host — it read 1.38 on a day the best-of-N read 1.8.
    # Smaller shapes are latency-bound, not gated.
    if shape == (64, 64, 64) and rec["speedup_packed_vs_embed_best"] < 1.4:
        raise SystemExit(
            f"REGRESSION: packed r2c only "
            f"{{rec['speedup_packed_vs_embed_best']:.2f}}x vs embed at 64^3 "
            "on the best-of-N estimator (acceptance floor is 1.4x)")
    tag = "x".join(map(str, shape))
    report["shapes"][tag] = rec
    print(f"ROW,rfft/{{tag}}/embed,{{rec['embed']['wall_s'] * 1e6:.3f}},0")
    print(f"ROW,rfft/{{tag}}/packed,{{rec['packed']['wall_s'] * 1e6:.3f}},0")
    print(f"SPEEDUP,{{tag}},{{rec['speedup_packed_vs_embed']:.3f}}")

# --- packed-slab entry: the schedule-built slab r2c pipeline (pair
# x-lines, one half-volume z<->x transpose) vs the embedding on the
# 1-axis mesh it serves ------------------------------------------------
sshape = tuple(shapes[-1])
stag = "x".join(map(str, sshape))
mesh1 = jax.make_mesh((8,), ("p",))
sdec = Decomposition("slab", ("p",))
splans = {{strat: Croft3D(sshape, mesh1, sdec, FFTOptions(),
                          problem="r2c",
                          strategy="packed" if strat == "packed_slab"
                          else "embed")
           for strat in ("embed_slab", "packed_slab")}}
sxs = {{s: _random_input(p.shape, p.input_dtype, p.input_sharding)
        for s, p in splans.items()}}
for s, p in splans.items():
    for _ in range(2):
        jax.block_until_ready(p.forward(sxs[s]))
swalls = {{s: [] for s in splans}}
sratios = []
for _ in range(rounds):
    t = {{}}
    for s, p in splans.items():
        t0 = time.perf_counter()
        jax.block_until_ready(p.forward(sxs[s]))
        t[s] = time.perf_counter() - t0
        swalls[s].append(t[s])
    sratios.append(t["embed_slab"] / t["packed_slab"])
sratios.sort()
srec = {{"shape": stag, "mesh": {{"p": 8}}}}
for s, p in splans.items():
    ws = sorted(swalls[s])
    cand = Candidate(sdec, FFTOptions(), problem="r2c",
                     strategy="packed" if s == "packed_slab" else "embed")
    cb = cost_model.analytic_cost(sshape, cand, dict(mesh1.shape))
    srec[s] = {{"wall_s": ws[len(ws) // 2], "wall_s_min": ws[0],
                "model_collective_bytes_per_device": cb.collective_bytes,
                "model_flops_per_device": cb.flops}}
srec["speedup_packed_vs_embed"] = sratios[len(sratios) // 2]
report["packed_slab"] = srec
print(f"ROW,rfft/{{stag}}/slab-embed,{{srec['embed_slab']['wall_s'] * 1e6:.3f}},0")
print(f"ROW,rfft/{{stag}}/slab-packed,{{srec['packed_slab']['wall_s'] * 1e6:.3f}},0")
print(f"SPEEDUP,slab-{{stag}},{{srec['speedup_packed_vs_embed']:.3f}}")

# --- fused spectral epilogue: the k-space multiply attached to the
# schedule (one jit dispatch) vs the separate-multiply round trip ------
fshape = tuple(shapes[-1])
ftag = "x".join(map(str, fshape))
fplan = Croft3D(fshape, mesh, dec, FFTOptions(), problem="r2c",
                strategy="packed")
fx = _random_input(fplan.shape, fplan.input_dtype, fplan.input_sharding)
nh = fshape[-1] // 2 + 1
h = jax.device_put(
    jnp.asarray(np.random.RandomState(0).randn(fshape[0], fshape[1], nh),
                jnp.complex64), fplan.output_sharding)
mul = jax.jit(lambda y, hh: y * hh)
for _ in range(3):  # warmup/compile both paths (first post-compile call
    jax.block_until_ready(mul(fplan.forward(fx), h))   # still pays cache
    jax.block_until_ready(fplan.forward_filtered(fx, h))  # population)
fwalls = {{"unfused": [], "fused": []}}
frounds = 2 * rounds + 1  # cheap calls: buy noise margin with rounds
for i in range(frounds):
    # alternate which path runs first so warm-cache bias cancels
    def t_unfused():
        t0 = time.perf_counter()
        jax.block_until_ready(mul(fplan.forward(fx), h))
        return time.perf_counter() - t0
    def t_fused():
        t0 = time.perf_counter()
        jax.block_until_ready(fplan.forward_filtered(fx, h))
        return time.perf_counter() - t0
    if i % 2 == 0:
        tu = t_unfused(); tf = t_fused()
    else:
        tf = t_fused(); tu = t_unfused()
    fwalls["unfused"].append(tu)
    fwalls["fused"].append(tf)
# best-of-N estimator, NOT median-of-ratios: host-load bursts on a
# shared CI machine only ever inflate a round, so the minimum of many
# interleaved rounds tracks the code far better than any
# ratio-of-noisy-pairs statistic (a recorded 0.96 "regression" of this
# entry was exactly that artifact) — but even best-of-N swings +-15% on
# this 2-core host, so "no extra work in the fused path" is gated
# DETERMINISTICALLY below, on compiled HLO bytes, and the wall ratio
# keeps a noise-allowance floor.
fspeed = min(fwalls["unfused"]) / min(fwalls["fused"])
# the property the satellite gate must pin: fusing the k-space multiply
# as a schedule epilogue performs STRICTLY LESS memory traffic than
# forward + separate multiply (one dispatch and one spectrum round trip
# fewer).  Compiled byte counts are exact and noise-free; a real extra
# copy in the fused path (the suspected SpectralScale regression) flips
# this comparison and fails the run loudly.
from repro.launch import hlo_cost
nhh = jax.ShapeDtypeStruct(h.shape, h.dtype, sharding=h.sharding)
nxx = jax.ShapeDtypeStruct(fx.shape, fx.dtype, sharding=fx.sharding)
b_fwd = hlo_cost.analyze(fplan._fwd.lower(nxx).compile().as_text()).bytes
# the spectrum operand of the separate multiply has h's shape/sharding
b_mul = hlo_cost.analyze(mul.lower(nhh, nhh).compile().as_text()).bytes
b_fused = hlo_cost.analyze(
    fplan._filtered_fn().lower(nxx, nhh).compile().as_text()).bytes
report["fused_epilogue"] = {{
    "shape": ftag,
    "wall_s_unfused": min(fwalls["unfused"]),
    "wall_s_fused": min(fwalls["fused"]),
    "wall_s_unfused_median": sorted(fwalls["unfused"])[frounds // 2],
    "wall_s_fused_median": sorted(fwalls["fused"])[frounds // 2],
    "speedup_fused_vs_unfused": fspeed,
    "hlo_bytes_unfused": b_fwd + b_mul,
    "hlo_bytes_fused": b_fused,
    # the load-independent form of the parity claim: memory traffic of
    # the two compiled paths (the fused executable saves the separate
    # multiply's spectrum round trip; >= 1.0 by construction unless a
    # real extra copy creeps in)
    "speedup_fused_vs_unfused_hlo_bytes": (b_fwd + b_mul) / b_fused,
}}
print(f"ROW,rfft/{{ftag}}/solver-unfused,"
      f"{{report['fused_epilogue']['wall_s_unfused'] * 1e6:.3f}},0")
print(f"ROW,rfft/{{ftag}}/solver-fused,"
      f"{{report['fused_epilogue']['wall_s_fused'] * 1e6:.3f}},0")
print(f"SPEEDUP,fused-{{ftag}},{{fspeed:.3f}}")
if not b_fused < b_fwd + b_mul:
    raise SystemExit(
        f"REGRESSION: fused spectral epilogue compiles to {{b_fused}} HLO "
        f"bytes vs {{b_fwd + b_mul}} for forward+multiply — the fusion is "
        "doing extra work (a real copy crept into the epilogue path)")
# wall floor is catastrophic-only: the byte gate above already pins the
# parity claim exactly, while wall readings on this 8-threads-on-2-cores
# host put the two paths in the same 0.9-1.1 band and swing run to run
# (XLA CPU schedules two small executables across oversubscribed device
# threads about as well as one larger one, so the saved dispatch and
# round trip land inside the noise)
if fspeed < 0.7:
    raise SystemExit(
        f"REGRESSION: fused spectral epilogue {{fspeed:.2f}}x vs the "
        "unfused path (catastrophic floor 0.7; the byte gate above "
        "proved the fused path does less work, so a reading this low "
        "means something pathological)")

with open({out!r}, "w") as f:
    json.dump(report, f, indent=1, sort_keys=True)
print("JSON_WRITTEN")
"""


def run(smoke: bool = False) -> None:
    # 64^3 is the acceptance shape; the full sweep adds 32^3 for the
    # latency-bound end
    shapes = [(64, 64, 64)] if smoke else [(32, 32, 32), (64, 64, 64)]
    code = _SWEEP_CODE.format(shapes=[list(s) for s in shapes],
                              rounds=11 if smoke else 21, out=BENCH_JSON)
    out = run_subprocess_bench(code, n_devices=8, timeout=1200)
    for line in out.splitlines():
        if line.startswith("ROW,"):
            _, name, us, derived = line.split(",")
            emit(name, float(us), bool(int(derived)))
    if "JSON_WRITTEN" not in out:
        raise RuntimeError("rfft sweep did not write BENCH_rfft.json")
