"""Trace smoke: capture a CI trace and validate it -> TRACE_smoke.json.

The observability acceptance path (ISSUE 7): one subprocess on the
8-virtual-device mesh enables the ``repro.obs`` tracer, then

  * runs a **tuned 32^3 forward** through the per-stage attribution
    re-driver (``obs.instrument.trace_forward``),
  * traces the two acceptance plans — pencil **alltoall-K2** and
    **ring-K1** — so the report carries an overlap-efficiency number
    for both,
  * drives a **short serve run** (5 ragged requests through
    ``TransformService``, max_batch=4) so request-lifecycle and
    plan-cache spans land in the same trace,

and saves the Chrome-trace JSON.  The parent then validates the
artifact the way a trace consumer would:

  schema   every event has ``name``/``ph``/``ts``/``pid``/``tid``, ``ph``
           in {"X", "i"}, a known category, non-negative ``dur``;
  spans    the number of distinct per-stage spans per traced plan
           equals that plan's schedule stage count (printed by the
           subprocess from the real ``Schedule``);
  report   ``repro.obs.report`` renders it, and the attribution
           metadata holds an overlap-efficiency number for both
           acceptance plans;
  serve    the request-lifecycle span names all appear.

CI uploads ``TRACE_smoke.json`` next to the ``BENCH_*.json`` artifacts;
load it in chrome://tracing / Perfetto or feed it to
``python -m repro.obs.report``.
"""

from __future__ import annotations

import json
import os

from benchmarks.common import REPO, emit, run_subprocess_bench

TRACE_JSON = os.path.join(REPO, "TRACE_smoke.json")

_CODE = """
import os, tempfile, numpy as np, jax, jax.numpy as jnp
from repro import obs
from repro.core import Croft3D, Decomposition, FFTOptions
from repro.obs import instrument
from repro.serve import TransformService
from repro.tuning.measure import _random_input

tracer = obs.enable()
mesh = jax.make_mesh((2, 4), ("y", "z"))
N = 32

# -- tuned 32^3 forward + the two acceptance plans -------------------------
plans = [("tuned-32", Croft3D.tuned((N, N, N), mesh, mode="model"))]
for label, impl, k in (("alltoall-k2", "alltoall", 2), ("ring-k1", "ring", 1)):
    plans.append((label, Croft3D(
        (N, N, N), mesh, Decomposition("pencil", ("y", "z")),
        FFTOptions(overlap_k=k, transpose_impl=impl,
                   output_layout="spectral"))))
for label, plan in plans:
    x = _random_input((N, N, N), jnp.complex64, plan.input_sharding)
    y, summary = instrument.trace_forward(plan, x, tracer=tracer, iters=2,
                                          label=label)
    np.testing.assert_allclose(np.asarray(jax.device_get(y)),
                               np.asarray(jax.device_get(plan.forward(x))),
                               rtol=2e-4, atol=2e-4)
    print("STAGECOUNT,%s,%d" % (label, len(plan._forward_schedule().stages)))
    print("EFF,%s,%s" % (label, summary["overall"]["efficiency"]))

# -- short serve run: 5 ragged requests, request-lifecycle spans -----------
rng = np.random.RandomState(0)
x = (rng.randn(N, N, N) + 1j * rng.randn(N, N, N)).astype(np.complex64)
wisdom = os.path.join(tempfile.mkdtemp(), "w.json")
with TransformService(mesh, max_batch=4, max_wait_ms=2.0,
                      wisdom_path=wisdom) as svc:
    futs = [svc.submit(x) for _ in range(5)]
    for f in futs:
        r = f.result(timeout=300)
        assert r.ok, r.error

tracer.save({out!r})
print("TRACE_WRITTEN")
"""

_SERVE_SPANS = ("request:submit", "request:queue", "batch:dispatch",
                "batch:compute", "batch:d2h")


def _validate(doc: dict, expected_stages: dict) -> list:
    """Schema + span-count checks; returns a list of failure strings."""
    from repro.obs import CATEGORIES
    fails = []
    events = doc.get("traceEvents")
    if not isinstance(events, list) or not events:
        return ["traceEvents missing or empty"]
    for ev in events:
        if ev.get("ph") not in ("X", "i"):
            fails.append(f"bad ph in {ev}")
        elif not isinstance(ev.get("name"), str) or not ev["name"]:
            fails.append(f"bad name in {ev}")
        elif ev.get("cat") not in CATEGORIES:
            fails.append(f"unknown category {ev.get('cat')!r}")
        elif not isinstance(ev.get("ts"), (int, float)) or ev["ts"] < 0:
            fails.append(f"bad ts in {ev['name']}")
        elif "pid" not in ev or "tid" not in ev:
            fails.append(f"missing pid/tid in {ev['name']}")
        elif ev["ph"] == "X" and ev.get("dur", -1) < 0:
            fails.append(f"bad dur in {ev['name']}")
        if fails:
            break  # one schema failure is enough signal
    for label, n_stages in expected_stages.items():
        got = {ev["args"].get("stage") for ev in events
               if ev.get("ph") == "X"
               and ev.get("args", {}).get("part") == "stage"
               and ev.get("args", {}).get("plan") == label}
        if len(got) != n_stages:
            fails.append(f"{label}: {len(got)} stage spans, schedule has "
                         f"{n_stages} stages")
    names = {ev["name"] for ev in events}
    for need in _SERVE_SPANS:
        if need not in names:
            fails.append(f"serve lifecycle span {need!r} missing")
    plans = {s.get("plan"): s for s in
             (doc.get("metadata") or {}).get("attribution") or []}
    for label in ("alltoall-k2", "ring-k1"):
        overall = (plans.get(label) or {}).get("overall") or {}
        if not isinstance(overall.get("efficiency"), float):
            fails.append(f"{label}: no overlap-efficiency in attribution")
    return fails


def run(smoke: bool = False) -> None:
    del smoke  # one size: the capture is already the fast CI shape
    out = run_subprocess_bench(_CODE.format(out=TRACE_JSON), n_devices=8,
                               timeout=1800)
    if "TRACE_WRITTEN" not in out:
        raise RuntimeError("trace smoke did not write the trace JSON")
    expected = {}
    for line in out.splitlines():
        if line.startswith("STAGECOUNT,"):
            _, label, n = line.split(",")
            expected[label] = int(n)
        elif line.startswith("EFF,"):
            _, label, eff = line.split(",")
            emit(f"trace/{label}/overlap-eff-pct", 100.0 * float(eff), True)

    with open(TRACE_JSON) as f:
        doc = json.load(f)
    fails = _validate(doc, expected)
    if fails:
        raise RuntimeError("trace validation FAILED: " + "; ".join(fails))

    # the report must render the artifact end to end (the acceptance CLI)
    from repro.obs import report as obs_report
    if obs_report.main([TRACE_JSON]) != 0:
        raise RuntimeError("repro.obs.report failed on the captured trace")
    emit("trace/n_events", len(doc["traceEvents"]), True)
    print(f"# wrote {TRACE_JSON} ({len(doc['traceEvents'])} events, "
          f"{len(expected)} plans attributed)")


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
