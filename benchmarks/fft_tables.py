"""Paper tables 1-3 + figures 7-11 analogues.

Table 1: 128^3 P-sweep      Table 2: process-layout sweep
Table 3 / figs 7-9: 1024^3 with the options 1-4 matrix
Fig 11: speedup curve (derived from table 3)

Wall times are modeled from roofline terms on v5e constants (``derived=1``;
no TPU in this container) — the *shape* of each table reproduces the paper's
phenomena: the slab scaling wall at P > N, pencil scaling through 512, and
the overlap options' ranking.  Local-FFT compute is additionally *measured*
on this host (derived=0 rows) so one leg of the model is empirical.
"""

from __future__ import annotations

import math

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, fft_step_model, time_fn
from repro.core import fft3d_local


def table1_small_grid():
    """128^3 across P = 4..512: pencil vs slab (slab == FFTW3's model).

    Paper phenomenon: FFTW3/slab cannot use more than P = N cores (table 1
    shows its times explode past 128); pencil keeps scaling.
    """
    grid = (128, 128, 128)
    for p in [4, 8, 16, 32, 64, 128, 256, 512]:
        m = fft_step_model(grid, p, "pencil", overlap=True)
        emit(f"table1/croft-pencil/128^3/P{p}", m["total_s"] * 1e6, True)
        if p <= grid[2]:
            s = fft_step_model(grid, p, "slab", overlap=False)
            emit(f"table1/fftw3-slab/128^3/P{p}", s["total_s"] * 1e6, True)
        else:
            # the paper's wall: slab cannot decompose beyond Nz
            emit(f"table1/fftw3-slab/128^3/P{p}", float("inf"), True)


def table2_layouts():
    """Py x Pz aspect-ratio sweep at P=64 (paper's custom process layouts).

    Aspect changes the two transposes' message counts; the near-square
    layout minimizes the larger communicator (paper table 2's improvement).
    """
    grid = (128, 128, 128)
    p = 64
    for py in [1, 2, 4, 8, 16, 32, 64]:
        pz = p // py
        if 128 % py or 128 % pz:
            continue
        # message count per a2a ~ (comm size - 1); latency-weighted model
        local = math.prod(grid) // p * 8
        t_bw = 4 * local / 50e9
        t_lat = 2 * ((py - 1) + (pz - 1)) * 1e-6
        emit(f"table2/layout/{py}x{pz}", (t_bw + t_lat) * 1e6, True)


def table3_large_grid():
    """1024^3 with CROFT options 1-4 (overlap x plan reuse) + FFTW3 slab.

    Option ranking reproduces the paper: opt4 (overlap + single plan) <
    opt2 < opt3 < opt1, FFTW3 slab slowest at scale and walled at P=1024.
    """
    grid = (1024, 1024, 1024)
    # plan rematerialization cost: twiddle recompute adds ~2 elementwise
    # passes over the local volume per 1-D stage
    for p in [4, 8, 16, 32, 64, 128, 256, 512]:
        local_bytes = math.prod(grid) // p * 8
        replan = 6 * local_bytes / 819e9  # options 1/3: per-stage twiddle gen
        for opt, (overlap, cached) in {
            1: (False, False), 2: (False, True),
            3: (True, False), 4: (True, True),
        }.items():
            m = fft_step_model(grid, p, "pencil", overlap=overlap)
            t = m["total_s"] + (0.0 if cached else replan)
            emit(f"table3/croft-opt{opt}/1024^3/P{p}", t * 1e6, True)
        s = fft_step_model(grid, p, "slab", overlap=False)
        emit(f"table3/fftw3-slab/1024^3/P{p}", (s["total_s"] + replan) * 1e6,
             True)


def fig11_speedup():
    """Speedup vs P=4 baseline for option 4 (paper fig. 11)."""
    grid = (1024, 1024, 1024)
    base = fft_step_model(grid, 4, "pencil", overlap=True)["total_s"]
    for p in [4, 8, 16, 32, 64, 128, 256, 512]:
        t = fft_step_model(grid, p, "pencil", overlap=True)["total_s"]
        emit(f"fig11/speedup-opt4/P{p}", base / t, True)


def measured_local_fft():
    """Measured (derived=0): the local per-pencil FFT volume of a 1024^3 /
    P=256 cell, run on this host's CPU — one empirical leg of the model."""
    x = jnp.asarray((np.random.RandomState(0).randn(64, 64, 64)
                     + 1j * np.random.RandomState(1).randn(64, 64, 64))
                    .astype(np.complex64))
    for impl in ["matmul", "stockham", "xla"]:
        us = time_fn(lambda v: fft3d_local(v, impl=impl), x, iters=3)
        emit(f"measured/local-fft3d-64^3/{impl}", us, False)


def run():
    table1_small_grid()
    table2_layouts()
    table3_large_grid()
    fig11_speedup()
    measured_local_fft()
