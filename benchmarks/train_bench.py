"""Measured train/serve step times for smoke configs on this host
(derived=0) — the framework's end-to-end latency sanity row — plus modeled
production step times from the dry-run artifacts (derived=1).
"""

from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import emit, load_dryrun, time_fn
from repro.configs import get_config
from repro.train import OptConfig, init_train_state, make_train_step
from repro.train.data import SyntheticDataset


def run():
    for arch in ["yi-9b", "rwkv6-3b"]:
        cfg = get_config(arch, smoke=True)
        ocfg = OptConfig(lr=1e-3)
        state = init_train_state(jax.random.PRNGKey(0), cfg, ocfg, None)
        step = make_train_step(cfg, ocfg, None, 4, kv_block=32, donate=False)
        ds = SyntheticDataset(cfg.vocab, 64, 4)
        batch = ds.batch_at(0)
        us = time_fn(lambda s, b: step(s, b)[1]["loss"], state, batch,
                     warmup=1, iters=3)
        emit(f"train/smoke-step/{arch}", us, False)

    # production cells: modeled step time from the compiled dry-run
    for cell in ["yi-34b-train_4k-sp", "mixtral-8x22b-train_4k-sp",
                 "deepseek-v2-236b-train_4k-sp", "rwkv6-3b-decode_32k-sp"]:
        rec = load_dryrun(cell)
        if rec:
            emit(f"train/modeled-step/{cell}",
                 rec["roofline"]["step_time_s"] * 1e6, True)
