"""Training-step benchmark: the differentiable distributed transform.

Two sections:

* **Spectral training workload** (both modes, the CI gate): a learned
  spectral filter — real-space gate + k-space filter around the packed
  r2c pipeline (``repro.models.spectral``) — trained with plain SGD on
  an 8-virtual-device pencil mesh in a subprocess.  Gradients flow
  through ``repro.grad``'s adjoint schedules, not XLA collective
  autodiff.  Writes ``BENCH_train.json`` with deterministic gates:

    - ``loss_monotone`` / ``loss_halved``: the smoke run's loss must
      strictly decrease and at least halve (the run is seeded, so this
      is deterministic, not a flaky convergence bet);
    - ``grad_vs_numerical_max_rel``: analytic grads vs central finite
      differences (the loss is quadratic along any single-coordinate
      line, so central differences are *exact* up to float32 rounding);
    - ``grad_packed_vs_embed_rel``: the packed pipeline's custom-VJP
      grads vs the embed strategy (XLA autodiff over the Hermitian glue
      composed with the c2c core's adjoint) — two independent gradient
      routes through different code;
    - ``hlo_mirror``: for the c2c core (alltoall both layouts, ring,
      pairwise) the backward pass must compile to *exactly* the forward
      schedule's per-type collective counts, and the all-to-all count
      must equal the adjoint schedule's per-stage prediction
      (``per_stage_costs`` ``k_eff`` — one launch per K-chunk), straight
      from the same IR the executor runs.  The packed r2c counts are
      recorded unequal-by-design: the DC/Nyquist plane unfold reflects
      across *sharded* kx/ky axes, so its transpose adds a few
      plane-sized permutes the forward does not have.

* **LM step times** (full mode only): the original smoke-config
  train-step wall rows plus modeled production step times from the
  dry-run artifacts.

``python -m benchmarks.train_bench --smoke`` is the CI entry point.
"""

from __future__ import annotations

import argparse
import os

from benchmarks.common import (REPO, emit, load_dryrun, run_subprocess_bench,
                               time_fn)

BENCH_JSON = os.path.join(REPO, "BENCH_train.json")

_SPECTRAL_CODE = """
import json, time, numpy as np, jax, jax.numpy as jnp
from repro.core import Croft3D, Decomposition, FFTOptions
from repro.launch import hlo_cost
from repro.models.spectral import (init_spectral_filter_params,
                                   place_spectral_filter_params,
                                   spectral_filter_apply)
from repro.train import make_spectral_train_step, spectral_loss_fn
from repro.tuning import Candidate, per_stage_costs

N = {n}
steps = {steps}
shape = (N, N, N)
mesh = jax.make_mesh((4, 2), ("y", "x"))
dec = Decomposition("pencil", ("y", "x"))
sizes = dict(mesh.shape)
report = {{"shape": list(shape), "mesh": sizes,
           "backend": jax.default_backend(), "gates": {{}}, "hlo": {{}}}}

def collective_counts(fn, *args):
    txt = jax.jit(fn).lower(*args).compile().as_text()
    return {{k: int(v["count"])
             for k, v in hlo_cost.analyze(txt).collectives.items()}}

# ---- training loop: learned spectral filter over the packed r2c plan ----
plan = Croft3D(shape, mesh, dec, FFTOptions(), problem="r2c",
               strategy="packed")
rng = np.random.RandomState(0)
x = jax.device_put(jnp.asarray(rng.randn(*shape), plan.input_dtype),
                   plan.input_sharding)
true = place_spectral_filter_params(plan, {{
    "gate": jnp.asarray(1.0 + 0.3 * rng.randn(*shape), jnp.float32),
    "filter": jnp.asarray(1.0 + 0.3 * rng.randn(*plan.spectrum_shape),
                          jnp.float32)}})
target = spectral_filter_apply(plan, true, x)
step, loss_fn = make_spectral_train_step(plan, lr=0.05)
params = place_spectral_filter_params(
    plan, init_spectral_filter_params(jax.random.PRNGKey(1), plan))

losses, wall0 = [], None
for i in range(steps):
    params, loss = step(params, x, target)
    losses.append(float(loss))  # float() syncs, so the wall below is honest
    if i == 0:
        wall0 = time.perf_counter()  # step 0 paid compilation
wall = (time.perf_counter() - wall0) / max(1, steps - 1)
report["losses"] = losses
report["step_wall_s"] = wall
gate_mono = all(b < a for a, b in zip(losses, losses[1:]))
gate_conv = losses[-1] < 0.5 * losses[0]
report["gates"]["loss_monotone"] = gate_mono
report["gates"]["loss_halved"] = gate_conv
if not (gate_mono and gate_conv):
    raise SystemExit(f"REGRESSION: spectral training loss not decreasing "
                     f"over the seeded smoke run: {{losses}}")
print(f"ROW,train/spectral-step/{{N}}^3,{{wall * 1e6:.3f}},0")

# ---- oracle 1: grads vs central finite differences ----------------------
g = jax.jit(jax.grad(loss_fn))(params, x, target)
fd_max_rel = 0.0
for field in ("gate", "filter"):
    for ij in [(1, 2, 3), (0, 0, 0), (3, 1, 2)]:
        eps = 0.5  # loss is quadratic along this line: central diff exact
        def loss_at(v, field=field, ij=ij):
            pp = dict(params)
            pp[field] = params[field].at[ij].add(v)
            return float(loss_fn(pp, x, target))
        fd = (loss_at(eps) - loss_at(-eps)) / (2 * eps)
        an = float(g[field][ij])
        rel = abs(fd - an) / max(abs(fd), abs(an), 1e-6)
        fd_max_rel = max(fd_max_rel, rel)
report["gates"]["grad_vs_numerical_max_rel"] = fd_max_rel
if fd_max_rel > 1e-2:
    raise SystemExit(f"REGRESSION: analytic gradient {{fd_max_rel:.2e}} "
                     "rel off the finite-difference oracle (gate 1e-2)")

# ---- oracle 2: packed custom-VJP grads vs the embed strategy ------------
embed = Croft3D(shape, mesh, dec, FFTOptions(), problem="r2c",
                strategy="embed")
xe = jax.device_put(x, embed.input_sharding)
ge = jax.jit(jax.grad(
    lambda p, v, t: spectral_loss_fn(embed, p, v, t)))(params, xe, target)
embed_rels = {{}}
for field in ("gate", "filter"):
    num = float(jnp.linalg.norm(g[field] - ge[field]))
    den = float(jnp.linalg.norm(g[field])) or 1.0
    embed_rels[field] = num / den
report["gates"]["grad_packed_vs_embed_rel"] = embed_rels
if max(embed_rels.values()) > 1e-4:
    raise SystemExit(f"REGRESSION: packed-vs-embed gradient routes "
                     f"disagree: {{embed_rels}} (gate 1e-4)")

# ---- gate 3: backward HLO mirrors the adjoint schedule ------------------
mirror_ok = True
for tag, opts in {{
    "c2c-alltoall-natural": FFTOptions(),
    "c2c-alltoall-spectral": FFTOptions(output_layout="spectral"),
    "c2c-ring": FFTOptions(output_layout="spectral", transpose_impl="ring"),
    "c2c-pairwise": FFTOptions(output_layout="spectral",
                               transpose_impl="pairwise"),
}}.items():
    cplan = Croft3D(shape, mesh, dec, opts)
    xc = jax.device_put(jnp.zeros(shape, jnp.complex64),
                        cplan.input_sharding)
    fwd_counts = collective_counts(cplan._fwd, xc)
    y, pull = jax.vjp(cplan._fwd, xc)
    bwd_counts = collective_counts(pull, jnp.ones_like(y))
    rec = {{"fwd": fwd_counts, "bwd": bwd_counts,
            "mirror": bwd_counts == fwd_counts}}
    if opts.transpose_impl == "alltoall":
        rows = per_stage_costs(shape, Candidate(dec, opts,
                                                problem="c2c_grad"),
                               sizes, jnp.complex64)
        pred = sum(int(r["k_eff"]) for r in rows
                   if r["direction"] == "bwd" and r["collective_s"] > 0)
        rec["predicted_bwd_all_to_all"] = pred
        rec["prediction_match"] = pred == bwd_counts.get("all-to-all", 0)
        mirror_ok = mirror_ok and rec["prediction_match"]
    mirror_ok = mirror_ok and rec["mirror"]
    report["hlo"][tag] = rec
# recorded, not equality-gated: the packed pipeline's DC/Nyquist unfold
# reflects across sharded kx/ky axes, so its transpose adds plane-sized
# permutes (see module docstring)
yp, pullp = jax.vjp(plan._fwd, x)
report["hlo"]["r2c-packed"] = {{
    "fwd": collective_counts(plan._fwd, x),
    "bwd": collective_counts(pullp, jnp.ones_like(yp))}}
report["gates"]["hlo_mirror"] = mirror_ok
if not mirror_ok:
    raise SystemExit("REGRESSION: backward HLO collective counts do not "
                     f"mirror the adjoint schedule: {{report['hlo']}}")

with open({out!r}, "w") as f:
    json.dump(report, f, indent=1, sort_keys=True)
print("JSON_WRITTEN")
"""


def _run_spectral(smoke: bool) -> None:
    code = _SPECTRAL_CODE.format(n=16 if smoke else 32,
                                 steps=10 if smoke else 20, out=BENCH_JSON)
    out = run_subprocess_bench(code, n_devices=8, timeout=1200)
    for line in out.splitlines():
        if line.startswith("ROW,"):
            _, name, us, derived = line.split(",")
            emit(name, float(us), bool(int(derived)))
    if "JSON_WRITTEN" not in out:
        raise RuntimeError("spectral train sweep did not write "
                           "BENCH_train.json")


def _run_lm() -> None:
    import jax

    from repro.configs import get_config
    from repro.train import OptConfig, init_train_state, make_train_step
    from repro.train.data import SyntheticDataset

    for arch in ["yi-9b", "rwkv6-3b"]:
        cfg = get_config(arch, smoke=True)
        ocfg = OptConfig(lr=1e-3)
        state = init_train_state(jax.random.PRNGKey(0), cfg, ocfg, None)
        step = make_train_step(cfg, ocfg, None, 4, kv_block=32, donate=False)
        ds = SyntheticDataset(cfg.vocab, 64, 4)
        batch = ds.batch_at(0)
        us = time_fn(lambda s, b: step(s, b)[1]["loss"], state, batch,
                     warmup=1, iters=3)
        emit(f"train/smoke-step/{arch}", us, False)

    # production cells: modeled step time from the compiled dry-run
    for cell in ["yi-34b-train_4k-sp", "mixtral-8x22b-train_4k-sp",
                 "deepseek-v2-236b-train_4k-sp", "rwkv6-3b-decode_32k-sp"]:
        rec = load_dryrun(cell)
        if rec:
            emit(f"train/modeled-step/{cell}",
                 rec["roofline"]["step_time_s"] * 1e6, True)


def run(smoke: bool = False) -> None:
    if not smoke:
        _run_lm()
    _run_spectral(smoke)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="fast CI run: spectral workload only, 16^3")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(smoke=args.smoke)


if __name__ == "__main__":
    main()
