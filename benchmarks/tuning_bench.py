"""Autotuner sweep: planner vs the hand-picked default plan.

For each benchmarked shape, runs the full planner pipeline on an
8-virtual-device CPU mesh in a subprocess (model ranking -> top-k
measurement -> wisdom), times the untuned default plan against the tuned
winner, and emits

  * ``tuning/<shape>/default`` and ``tuning/<shape>/tuned`` CSV rows
    (derived=0 — these are measured on this host), plus the modeled best
    (derived=1) for comparison, and
  * ``BENCH_tuning.json`` at the repo root: the ranked candidate report,
    measured times, chosen plan, and speedup per shape.

``run(smoke=True)`` is the CI entry point: one small shape, minimal
measure iterations.
"""

from __future__ import annotations

import json
import os

from benchmarks.common import REPO, emit, run_subprocess_bench

BENCH_JSON = os.path.join(REPO, "BENCH_tuning.json")

_SWEEP_CODE = """
import dataclasses, json, numpy as np, jax, jax.numpy as jnp
from repro.core import Croft3D
from repro import tuning

shapes = {shapes!r}
top_k = {top_k}
iters = {iters}
mesh = jax.make_mesh((2, 4), ("data", "model"))
report = {{"mesh": {{"data": 2, "model": 4}}, "backend": jax.default_backend(),
           "shapes": {{}}}}
for shape in shapes:
    shape = tuple(shape)
    result = tuning.tune(shape, mesh, mode="measure", top_k=top_k,
                         measure_iters=iters, wisdom_path={wisdom!r})
    # the planner already raced the untuned default candidate; read its
    # measurement from the report instead of recompiling it
    default = tuning.default_candidate(shape, dict(mesh.shape))
    t_default = None
    if default is not None:
        t_default = next((r.get("measured_s") for r in result.ranked
                          if r["label"] == default.label), None)
        if t_default is None:
            t_default = tuning.measure_candidate(shape, mesh, default,
                                                 warmup=2, iters=iters)
    tag = "x".join(map(str, shape))
    report["shapes"][tag] = {{
        "chosen": result.summary(),
        "decomp": {{"kind": result.decomp.kind,
                    "axes": [list(a) if isinstance(a, tuple) else a
                             for a in result.decomp.axes]}},
        "opts": dataclasses.asdict(result.opts),
        "model_s": result.model_s,
        "tuned_s": result.measured_s,
        "default_s": t_default,
        "speedup_vs_default": (t_default / result.measured_s
                               if result.measured_s and t_default else None),
        "ranked": result.ranked,
    }}
    if t_default is not None:
        print(f"ROW,tuning/{{tag}}/default,{{t_default * 1e6:.3f}},0")
    print(f"ROW,tuning/{{tag}}/tuned,{{result.measured_s * 1e6:.3f}},0")
    print(f"ROW,tuning/{{tag}}/modeled-best,{{result.model_s * 1e6:.3f}},1")
with open({out!r}, "w") as f:
    json.dump(report, f, indent=1, sort_keys=True)
print("JSON_WRITTEN")
"""


def run(smoke: bool = False) -> None:
    shapes = [(32, 32, 32)] if smoke else [(32, 32, 32), (64, 64, 64)]
    wisdom = os.path.join(REPO, "results", "wisdom.json")
    code = _SWEEP_CODE.format(shapes=[list(s) for s in shapes],
                              top_k=2 if smoke else 4,
                              iters=2 if smoke else 5,
                              wisdom=wisdom, out=BENCH_JSON)
    out = run_subprocess_bench(code, n_devices=8, timeout=1200)
    for line in out.splitlines():
        if line.startswith("ROW,"):
            _, name, us, derived = line.split(",")
            emit(name, float(us), bool(int(derived)))
    if "JSON_WRITTEN" not in out:
        raise RuntimeError("tuning sweep did not write BENCH_tuning.json")
