"""Figs 12-15 analogue: collective-op profile, CROFT pencil vs the
FFTW3-style pairwise-exchange transpose.

The paper's ITAC profile shows CROFT needs 64 MPI_Alltoall calls where
FFTW3 issues 864 MPI calls (112 Sendrecv) at P=8 / 1024^3.  Here we compile
both transpose strategies at P=8 on the CPU backend and count collective
ops in the lowered HLO — the same claim, measured on the compiled artifact.

Beyond the counts, each variant is wall-clocked and the three (ops,
bytes, wall) points are least-squares fit to ``wall = alpha*ops +
beta*bytes`` — a crude on-host calibration of the cost model's launch
latency (alpha) and inverse bandwidth (beta).  The estimates flow
through the ``repro.obs`` metrics registry (gauges
``collective_alpha_s`` / ``collective_beta_s_per_byte``) so cost-model
calibration and tracing share one output path; the CSV rows below read
them back out of the registry.

The fit is also persisted to a calibration JSON (``$CROFT_CALIBRATION``
when set, else ``calibration.json`` in the working directory) so *later*
processes can tune with measured constants:
``repro.tuning.cost_model.collective_constants`` loads the file via the
same env var, after checking the in-process registry.
"""

from __future__ import annotations

from benchmarks.common import emit, run_subprocess_bench

CODE = """
import time, jax, json
from repro.core import Croft3D, Decomposition, FFTOptions
from repro.launch import hlo_cost
mesh = jax.make_mesh((8,), ("p",), axis_types=(jax.sharding.AxisType.Auto,))
N = {n}  # scaled-down stand-in for 1024^3 (same op structure)
out = {{}}
for tag, opts in {{
    "croft-alltoall": FFTOptions(overlap_k=2, transpose_impl="alltoall"),
    "croft-k1": FFTOptions(overlap_k=1, transpose_impl="alltoall"),
    "fftw3-pairwise": FFTOptions(overlap_k=1, transpose_impl="pairwise"),
}}.items():
    plan = Croft3D((N, N, N), mesh, Decomposition("slab", ("p",)), opts)
    cost = hlo_cost.analyze(plan.lower_forward().compile().as_text())
    out[tag] = {{k: v["count"] for k, v in cost.collectives.items()}}
    out[tag + "/bytes"] = sum(v["bytes"] for v in cost.collectives.values())
    x = jax.device_put(
        jax.numpy.zeros((N, N, N), "complex64"), plan.input_sharding)
    jax.block_until_ready(plan.forward(x))  # compile + warm
    times = []
    for _ in range(3):
        t0 = time.perf_counter()
        jax.block_until_ready(plan.forward(x))
        times.append(time.perf_counter() - t0)
    times.sort()
    out[tag + "/wall_s"] = times[len(times) // 2]
print(json.dumps(out))
"""

TAGS = ["croft-alltoall", "croft-k1", "fftw3-pairwise"]


def run(smoke: bool = False):
    import json

    import numpy as np

    from repro.obs import get_registry

    stdout = run_subprocess_bench(CODE.format(n=64 if smoke else 256),
                                  n_devices=8)
    data = json.loads(stdout.strip().splitlines()[-1])
    for tag in TAGS:
        counts = data[tag]
        total_ops = sum(counts.values())
        emit(f"fig12-15/{tag}/collective-ops", total_ops, True)
        emit(f"fig12-15/{tag}/collective-bytes", data[tag + "/bytes"], True)
        emit(f"fig12-15/{tag}/wall", data[tag + "/wall_s"] * 1e6, False)
    # the paper's headline ratio: pairwise needs ~(P-1)x more calls
    ratio = (sum(data["fftw3-pairwise"].values())
             / max(1, sum(data["croft-k1"].values())))
    emit("fig12-15/call-ratio-fftw3-over-croft", ratio, True)

    # alpha/beta calibration: wall ~= alpha*ops + beta*bytes over the
    # three variants, published through the shared metrics registry
    a = np.array([[sum(data[t].values()), data[t + "/bytes"]]
                  for t in TAGS], dtype=float)
    y = np.array([data[t + "/wall_s"] for t in TAGS])
    (alpha, beta), *_ = np.linalg.lstsq(a, y, rcond=None)
    reg = get_registry()
    reg.gauge("collective_alpha_s",
              "fitted per-collective launch seconds").set(alpha)
    reg.gauge("collective_beta_s_per_byte",
              "fitted seconds per collective byte").set(beta)
    emit("fig12-15/fit/alpha-us-per-collective",
         reg.gauge("collective_alpha_s").value * 1e6, True)
    emit("fig12-15/fit/beta-us-per-MiB",
         reg.gauge("collective_beta_s_per_byte").value * 1e6 * 2 ** 20, True)

    # persist the fit so other processes (CI tuning runs, training jobs)
    # can load it through $CROFT_CALIBRATION — the registry above only
    # calibrates *this* process
    import os

    from repro.tuning.cost_model import CALIBRATION_ENV
    path = os.environ.get(CALIBRATION_ENV) or "calibration.json"
    with open(path, "w") as f:
        json.dump({"collective_alpha_s": float(alpha),
                   "collective_beta_s_per_byte": float(beta),
                   "fit_points": len(TAGS)}, f, indent=2)
    emit("fig12-15/fit/saved", 1, True)
