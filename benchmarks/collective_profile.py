"""Figs 12-15 analogue: collective-op profile, CROFT pencil vs the
FFTW3-style pairwise-exchange transpose.

The paper's ITAC profile shows CROFT needs 64 MPI_Alltoall calls where
FFTW3 issues 864 MPI calls (112 Sendrecv) at P=8 / 1024^3.  Here we compile
both transpose strategies at P=8 on the CPU backend and count collective
ops in the lowered HLO — the same claim, measured on the compiled artifact.
"""

from __future__ import annotations

from benchmarks.common import emit, run_subprocess_bench

CODE = """
import jax, json
from repro.core import Croft3D, Decomposition, FFTOptions
from repro.launch import hlo_cost
mesh = jax.make_mesh((8,), ("p",), axis_types=(jax.sharding.AxisType.Auto,))
N = 256  # scaled-down stand-in for 1024^3 (same op structure)
out = {}
for tag, opts in {
    "croft-alltoall": FFTOptions(overlap_k=2, transpose_impl="alltoall"),
    "croft-k1": FFTOptions(overlap_k=1, transpose_impl="alltoall"),
    "fftw3-pairwise": FFTOptions(overlap_k=1, transpose_impl="pairwise"),
}.items():
    plan = Croft3D((N, N, N), mesh, Decomposition("slab", ("p",)), opts)
    cost = hlo_cost.analyze(plan.lower_forward().compile().as_text())
    out[tag] = {k: v["count"] for k, v in cost.collectives.items()}
    out[tag + "/bytes"] = sum(v["bytes"] for v in cost.collectives.values())
print(json.dumps(out))
"""


def run():
    import json
    stdout = run_subprocess_bench(CODE, n_devices=8)
    data = json.loads(stdout.strip().splitlines()[-1])
    for tag in ["croft-alltoall", "croft-k1", "fftw3-pairwise"]:
        counts = data[tag]
        total_ops = sum(counts.values())
        emit(f"fig12-15/{tag}/collective-ops", total_ops, True)
        emit(f"fig12-15/{tag}/collective-bytes", data[tag + "/bytes"], True)
    # the paper's headline ratio: pairwise needs ~(P-1)x more calls
    ratio = (sum(data["fftw3-pairwise"].values())
             / max(1, sum(data["croft-k1"].values())))
    emit("fig12-15/call-ratio-fftw3-over-croft", ratio, True)
