"""Pseudo-spectral PDE driver on CROFT: periodic Poisson solve + a few
steps of 3-D viscous Burgers — the HPC workload class the paper targets
(turbulence codes built on distributed 3-D FFTs).

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/spectral_solver.py --devices 8

The fields are real, so the driver runs on the real-transform subsystem
(``repro.real`` via ``Croft3D(problem="r2c")``): the forward transform
returns the (N, N, N//2 + 1) Hermitian half spectrum and the inverse is
the exact c2r — with the packed two-for-one strategy, every pipeline
stage computes and communicates half of what the old
complex-embedding round trip paid.  ``--strategy embed`` switches back
to the embedding for comparison; the default lets the plan (or the
autotuner) pick.

The FFT plan comes from the autotuner (``repro.tuning``): ``--tune
measure`` (default) races the model-ranked top candidates on the mesh
— including the packed/embed strategy axis — ``--tune model`` picks
analytically with zero execution, and ``--tune wisdom`` reuses a plan
stored by a previous run (``--wisdom PATH``).

The Poisson solve runs the *fused spectral epilogue*: ``poisson_solve``
attaches the 1/(-k²) multiply to the forward transform's schedule
(``Croft3D.forward_filtered`` -> ``Schedule.with_epilogue`` /
``kernels/spectral_scale.py``), so the whole solve is one forward
dispatch plus one inverse — no separate pass over the spectrum
(``benchmarks/rfft_bench.py`` gates this at parity-or-better).
"""

import argparse
import math
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import Croft3D, FFTOptions, poisson_solve


def wavenumbers(n):
    return jnp.fft.fftfreq(n, d=1.0 / n)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=64)
    ap.add_argument("--devices", type=int, default=1)
    ap.add_argument("--steps", type=int, default=5)
    ap.add_argument("--nu", type=float, default=0.05)
    ap.add_argument("--tune", default="measure",
                    choices=["model", "measure", "wisdom"],
                    help="autotuner mode (repro.tuning)")
    ap.add_argument("--wisdom", default=None,
                    help="wisdom JSON path for --tune wisdom / persistence")
    ap.add_argument("--strategy", default=None,
                    choices=["packed", "embed"],
                    help="force the r2c strategy (default: planner/auto)")
    args = ap.parse_args()

    n = args.n
    nh = n // 2 + 1
    if args.devices > 1:
        mesh = jax.make_mesh((2, args.devices // 2), ("y", "z"),
                             axis_types=(jax.sharding.AxisType.Auto,) * 2)
        if args.strategy is None:
            plan = Croft3D.tuned((n, n, n), mesh, mode=args.tune,
                                 problem="r2c", wisdom_path=args.wisdom)
            print("tuned plan:", plan.tune_result.summary())
        else:
            # forcing a strategy bypasses the planner: hand-picked
            # default pencil plan (say so — --tune/--wisdom are ignored)
            print(f"--strategy {args.strategy}: bypassing the autotuner "
                  "(--tune/--wisdom ignored), using the default pencil plan")
            from repro.core import Decomposition
            plan = Croft3D((n, n, n), mesh,
                           Decomposition("pencil", ("y", "z")), FFTOptions(),
                           problem="r2c", strategy=args.strategy)
    else:
        mesh = None
        plan = Croft3D((n, n, n), None, None, FFTOptions(),
                       problem="r2c", strategy=args.strategy)
    print(f"r2c strategy: {plan.strategy} "
          f"(spectrum {plan.spectrum_shape}, input {plan.input_dtype})")

    # --- Poisson: manufactured solution ------------------------------------
    g = 2 * math.pi * np.arange(n) / n
    X, Y, Z = np.meshgrid(g, g, g, indexing="ij")
    u_true = np.sin(X) * np.cos(2 * Y) * np.sin(3 * Z)
    f = -(1 + 4 + 9) * u_true
    fd = jnp.asarray(f, jnp.float32)
    if mesh is not None:
        fd = jax.device_put(fd, plan.input_sharding)
    u = poisson_solve(fd, plan)
    err = float(jnp.max(jnp.abs(u - u_true)))
    print(f"Poisson {n}^3: max error {err:.2e}")

    # --- viscous Burgers (scalar, semi-implicit spectral stepping) ---------
    # the r2c spectrum halves kz: rfftfreq bins, all arrays (n, n, nh)
    kx = wavenumbers(n)[:, None, None]
    ky = wavenumbers(n)[None, :, None]
    kz = jnp.fft.rfftfreq(n, d=1.0 / n)[None, None, :]
    k2 = kx ** 2 + ky ** 2 + kz ** 2
    if mesh is not None:
        k2 = jax.device_put(k2, plan.output_sharding)
        kxs = jax.device_put(jnp.broadcast_to(kx, (n, n, nh)),
                             plan.output_sharding)
    else:
        kxs = jnp.broadcast_to(kx, (n, n, nh))

    u = jnp.asarray(np.sin(X) * np.cos(Y) * np.cos(Z), jnp.float32)
    if mesh is not None:
        u = jax.device_put(u, plan.input_sharding)
    dt = 0.01

    @jax.jit
    def step(u):
        u_hat = plan.forward(u)                  # real -> half spectrum
        ux = plan.inverse(1j * kxs.astype(plan.dtype) * u_hat)
        rhs = -u * ux                            # nonlinear term, real space
        rhs_hat = plan.forward(rhs)
        u_hat_new = (u_hat + dt * rhs_hat) / (1 + dt * args.nu * k2)
        return plan.inverse(u_hat_new)           # exact c2r: real output

    e0 = float(jnp.mean(u ** 2))
    t0 = time.perf_counter()
    for i in range(args.steps):
        u = step(u)
    jax.block_until_ready(u)
    dt_wall = (time.perf_counter() - t0) / args.steps
    e1 = float(jnp.mean(u ** 2))
    print(f"Burgers {args.steps} steps: energy {e0:.4f} -> {e1:.4f} "
          f"(viscous decay expected), {dt_wall * 1e3:.1f} ms/step")
    assert e1 < e0, "viscosity must dissipate energy"
    print("OK")


if __name__ == "__main__":
    main()
