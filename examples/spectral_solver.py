"""Pseudo-spectral PDE driver on CROFT: periodic Poisson solve + a few
steps of 3-D viscous Burgers — the HPC workload class the paper targets
(turbulence codes built on distributed 3-D FFTs).

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/spectral_solver.py --devices 8

The FFT plan comes from the autotuner (``repro.tuning``): ``--tune
measure`` (default) races the model-ranked top candidates on the mesh,
``--tune model`` picks analytically with zero execution, and ``--tune
wisdom`` reuses a plan stored by a previous run (``--wisdom PATH``).  The
planner routinely lands on the beyond-paper ``spectral`` output layout:
the forward stays in z-pencil layout, the frequency-domain multiply runs
on the sharded spectrum, and the inverse consumes it directly, skipping
the restoring transposes the natural layout pays per round trip.
"""

import argparse
import math
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import Croft3D, FFTOptions, poisson_solve


def wavenumbers(n):
    return jnp.fft.fftfreq(n, d=1.0 / n)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=64)
    ap.add_argument("--devices", type=int, default=1)
    ap.add_argument("--steps", type=int, default=5)
    ap.add_argument("--nu", type=float, default=0.05)
    ap.add_argument("--tune", default="measure",
                    choices=["model", "measure", "wisdom"],
                    help="autotuner mode (repro.tuning)")
    ap.add_argument("--wisdom", default=None,
                    help="wisdom JSON path for --tune wisdom / persistence")
    args = ap.parse_args()

    n = args.n
    if args.devices > 1:
        mesh = jax.make_mesh((2, args.devices // 2), ("y", "z"),
                             axis_types=(jax.sharding.AxisType.Auto,) * 2)
        plan = Croft3D.tuned((n, n, n), mesh, mode=args.tune,
                             wisdom_path=args.wisdom)
        print("tuned plan:", plan.tune_result.summary())
    else:
        mesh = None
        plan = Croft3D((n, n, n), None, None,
                       FFTOptions(output_layout="spectral"))

    # --- Poisson: manufactured solution ------------------------------------
    g = 2 * math.pi * np.arange(n) / n
    X, Y, Z = np.meshgrid(g, g, g, indexing="ij")
    u_true = np.sin(X) * np.cos(2 * Y) * np.sin(3 * Z)
    f = -(1 + 4 + 9) * u_true
    fd = jnp.asarray(f, jnp.complex64)
    if mesh is not None:
        fd = jax.device_put(fd, plan.input_sharding)
    u = poisson_solve(fd, plan)
    err = float(jnp.max(jnp.abs(jnp.real(u) - u_true)))
    print(f"Poisson {n}^3: max error {err:.2e}")

    # --- viscous Burgers (scalar, semi-implicit spectral stepping) ---------
    kx = wavenumbers(n)[:, None, None]
    ky = wavenumbers(n)[None, :, None]
    kz = wavenumbers(n)[None, None, :]
    k2 = kx ** 2 + ky ** 2 + kz ** 2
    if mesh is not None:
        k2 = jax.device_put(k2, plan.output_sharding)
        kxs = jax.device_put(jnp.broadcast_to(kx, (n, n, n)),
                             plan.output_sharding)
    else:
        kxs = jnp.broadcast_to(kx, (n, n, n))

    u = jnp.asarray(np.sin(X) * np.cos(Y) * np.cos(Z), jnp.complex64)
    if mesh is not None:
        u = jax.device_put(u, plan.input_sharding)
    dt = 0.01

    @jax.jit
    def step(u):
        u_hat = plan.forward(u)
        ux = plan.inverse(1j * kxs.astype(jnp.complex64) * u_hat)
        rhs = -u * ux                       # nonlinear term in real space
        rhs_hat = plan.forward(rhs)
        u_hat_new = (u_hat + dt * rhs_hat) / (1 + dt * args.nu * k2)
        return plan.inverse(u_hat_new)

    e0 = float(jnp.mean(jnp.abs(u) ** 2))
    t0 = time.perf_counter()
    for i in range(args.steps):
        u = step(u)
    jax.block_until_ready(u)
    dt_wall = (time.perf_counter() - t0) / args.steps
    e1 = float(jnp.mean(jnp.abs(u) ** 2))
    print(f"Burgers {args.steps} steps: energy {e0:.4f} -> {e1:.4f} "
          f"(viscous decay expected), {dt_wall * 1e3:.1f} ms/step")
    assert e1 < e0, "viscosity must dissipate energy"
    print("OK")


if __name__ == "__main__":
    main()
