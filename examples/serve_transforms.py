"""Transform-service quickstart: submit heterogeneous spectral transforms
to one shared, plan-cached, continuously batched service.

Three client "apps" share the service concurrently — a c2c solver, an
r2c analysis pass, and a filtered (Poisson-style) solve.  Requests that
land in the same dispatch window and hit the same compiled executable
are stacked into one batch, which costs the SAME number of collectives
as a single request (the PR 5 property the bench gates).

    PYTHONPATH=src python examples/serve_transforms.py
    PYTHONPATH=src python examples/serve_transforms.py --wisdom wisdom.json

Run it twice with ``--wisdom``: the second run starts warm from the
plans the first run's background measurement merged into the file.
"""

import argparse
import threading

import numpy as np

from repro.serve import TransformService

N = 16


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--wisdom", default=None,
                    help="wisdom file for cross-run plan reuse")
    ap.add_argument("--requests", type=int, default=8,
                    help="requests per client app")
    args = ap.parse_args()

    rng = np.random.RandomState(0)
    errs = []

    def solver(svc):
        """c2c round trip: forward, then inverse of the spectrum."""
        x = (rng.randn(N, N, N) + 1j * rng.randn(N, N, N)
             ).astype(np.complex64)
        for _ in range(args.requests):
            y = svc.transform(x, problem="c2c")
            x_back = svc.transform(y, problem="c2c", direction="inverse")
            errs.append(("c2c roundtrip",
                         float(np.max(np.abs(x_back - x)))))

    def analysis(svc):
        """r2c half-spectrum of a real field (inverse needs shape=)."""
        x = rng.randn(N, N, N).astype(np.float32)
        for _ in range(args.requests):
            y = svc.transform(x, problem="r2c")
            x_back = svc.transform(y, problem="r2c", direction="inverse",
                                   shape=(N, N, N))
            errs.append(("r2c roundtrip",
                         float(np.max(np.abs(x_back - x)))))

    def filtered(svc):
        """Fused forward+filter epilogue: FFT(x) * h in one dispatch."""
        x = (rng.randn(N, N, N) + 1j * rng.randn(N, N, N)
             ).astype(np.complex64)
        h = np.exp(-0.1 * np.arange(N * N * N).reshape(N, N, N)
                   ).astype(np.complex64)
        for _ in range(args.requests):
            y = svc.transform(x, problem="filtered", h=h)
            ref = svc.transform(x, problem="c2c") * h
            errs.append(("filtered vs c2c*h",
                         float(np.max(np.abs(y - ref)))))

    with TransformService(max_batch=4, max_wait_ms=2.0,
                          wisdom_path=args.wisdom) as svc:
        threads = [threading.Thread(target=fn, args=(svc,))
                   for fn in (solver, analysis, filtered)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        stats = svc.stats()

    worst = {}
    for name, err in errs:
        worst[name] = max(worst.get(name, 0.0), err)
    for name, err in sorted(worst.items()):
        print(f"{name:20s} max|err| = {err:.3e}")
    print(f"\nserved {stats['requests']} requests in {stats['batches']} "
          f"batches (mean batch {stats['mean_batch']:.2f}, occupancy "
          f"{stats['occupancy']:.0%})")
    print(f"plan cache: {stats['plan_cache']['stats']}")
    assert all(e < 1e-3 for e in worst.values()), worst
    print("OK")


if __name__ == "__main__":
    main()
