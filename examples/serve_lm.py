"""LM serving example: the spectral-mixer layer as a transform service.

The FNet-style mixer (``repro.models.spectral``) is ``Re(FFT_seq(
FFT_model(x)))`` — a 2-D FFT over (seq, d_model).  Embedded as a 3-D
c2c of shape (1, S, D) (the size-1 leading axis transforms to itself),
each user's mixing call becomes one :class:`repro.serve.TransformService`
request: concurrent users land in the same dispatch window, get stacked
into one batched FFT, and share a single plan — the same continuous
batching an LM server applies to decode steps, here at the layer level.

    PYTHONPATH=src python examples/serve_lm.py --users 4 --layers 3

Each user's served output is checked against the direct
``spectral_mixer`` call.  The legacy prefill/decode loop lives on in
``python -m repro.launch.serve --arch rwkv6-3b --smoke``.
"""

import argparse
import threading

import jax.numpy as jnp
import numpy as np

from repro.models.spectral import spectral_mixer
from repro.serve import TransformService


def mixer_via_service(svc: TransformService, x: np.ndarray) -> np.ndarray:
    """One mixer layer for one user, served: x (S, D) real -> (S, D)."""
    spectrum = svc.transform(x[None].astype(np.complex64), problem="c2c")
    return np.real(spectrum[0]).astype(x.dtype)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--users", type=int, default=4)
    ap.add_argument("--layers", type=int, default=3,
                    help="stacked mixer layers per user")
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--dmodel", type=int, default=32)
    ap.add_argument("--wisdom", default=None)
    args = ap.parse_args()

    rng = np.random.RandomState(0)
    prompts = [rng.randn(args.seq, args.dmodel).astype(np.float32)
               for _ in range(args.users)]
    outputs = [None] * args.users

    def user(i):
        h = prompts[i]
        for _ in range(args.layers):
            h = mixer_via_service(svc, h)
        outputs[i] = h

    with TransformService(max_batch=args.users, max_wait_ms=2.0,
                          wisdom_path=args.wisdom) as svc:
        threads = [threading.Thread(target=user, args=(i,))
                   for i in range(args.users)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        stats = svc.stats()

    worst = 0.0
    for i in range(args.users):
        ref = np.asarray(prompts[i][None])
        for _ in range(args.layers):
            ref = np.asarray(spectral_mixer(jnp.asarray(ref)))
        worst = max(worst, float(np.max(np.abs(outputs[i] - ref[0]))))
    scale = max(float(np.max(np.abs(o))) for o in outputs)

    print(f"{args.users} users x {args.layers} mixer layers "
          f"({args.seq}x{args.dmodel}): max|served - direct| = {worst:.3e} "
          f"(output scale {scale:.1f})")
    print(f"served {stats['requests']} requests in {stats['batches']} "
          f"batches (mean batch {stats['mean_batch']:.2f}, occupancy "
          f"{stats['occupancy']:.0%})")
    print(f"plan cache: {stats['plan_cache']['stats']}")
    assert worst < 1e-2 * max(scale, 1.0), worst
    print("OK")


if __name__ == "__main__":
    main()
