"""Batched serving example: prefill a prompt batch, decode with greedy or
temperature sampling through the ring/latent/recurrent caches.

    PYTHONPATH=src python examples/serve_lm.py --arch rwkv6-3b
    PYTHONPATH=src python examples/serve_lm.py --arch deepseek-v2-236b \
        --temperature 0.8
"""

import argparse

from repro.launch import serve as serve_cli


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="rwkv6-3b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()
    serve_cli.main(["--arch", args.arch, "--smoke",
                    "--batch", str(args.batch),
                    "--prompt-len", str(args.prompt_len),
                    "--gen-len", str(args.gen_len),
                    "--temperature", str(args.temperature)])


if __name__ == "__main__":
    main()
