"""End-to-end LM training driver (example c of the deliverables).

Default: a ~100M-parameter dense transformer trained for a few hundred
steps on synthetic data via the full production path (sharded params,
chunked loss, checkpointing, straggler monitor).  On this CPU-only
container use ``--preset tiny`` for a fast smoke run; ``--preset 100m`` is
the real configuration (expect minutes/step on CPU; it is sized for a
single TPU host).

    PYTHONPATH=src python examples/train_lm.py --preset tiny --steps 30
"""

import argparse

from repro.configs import get_config  # noqa: F401  (registry also usable)
from repro.launch import train as train_cli
from repro.models.config import (AttentionSpec, LayerSpec, ModelConfig,
                                 simple_stack)

PRESETS = {
    # ~101M params: 12L d=768 12H swiglu, 32k vocab (GPT-2-small-ish)
    "100m": dict(layers=12, d=768, heads=12, kv=12, ff=3072, vocab=32768,
                 seq=512, batch=8, steps=300),
    "tiny": dict(layers=2, d=64, heads=4, kv=2, ff=128, vocab=256,
                 seq=64, batch=4, steps=30),
}


def build_config(p) -> ModelConfig:
    spec = LayerSpec(
        mixer="attn",
        attn=AttentionSpec(kind="gqa", n_heads=p["heads"],
                           n_kv_heads=p["kv"], head_dim=p["d"] // p["heads"]),
        ffn="swiglu",
    )
    return ModelConfig(
        name="example-lm", family="dense", d_model=p["d"], d_ff=p["ff"],
        vocab=p["vocab"], stages=simple_stack(p["layers"], spec),
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="tiny", choices=sorted(PRESETS))
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()
    p = PRESETS[args.preset]
    cfg = build_config(p)
    print(f"example LM: {cfg.param_count():,} params")

    # register it so the production CLI path drives it unchanged
    import repro.configs as configs
    import sys, types
    mod = types.ModuleType("examples._example_lm")
    mod.full = lambda: cfg
    mod.smoke = lambda: cfg
    sys.modules["examples._example_lm"] = mod
    configs.ARCHS["example-lm"] = "examples._example_lm"

    argv = ["--arch", "example-lm",
            "--steps", str(args.steps or p["steps"]),
            "--global-batch", str(p["batch"]),
            "--seq-len", str(p["seq"]),
            "--log-every", "10"]
    if args.ckpt_dir:
        argv += ["--ckpt-dir", args.ckpt_dir]
    train_cli.main(argv)


if __name__ == "__main__":
    main()
