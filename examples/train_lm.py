"""Training drivers: the differentiable distributed transform, end to end.

Default workload (``--workload spectral``): a learned spectral filter —
real-space gate + k-space filter around the distributed r2c transform
(``repro.models.spectral``) — trained with SGD.  Gradients replay the
tuned plan's *adjoint schedule* (``repro.grad``), and with more than one
device the plan comes from ``Croft3D.tuned(..., grad=True)``: the
autotuner prices forward + adjoint, so the winning plan is optimal for
the training step, not just inference.

    PYTHONPATH=src XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
        python examples/train_lm.py --steps 20

``--workload lm`` keeps the original driver: a ~100M-parameter dense
transformer (``--preset 100m``; ``--preset tiny`` for a CPU smoke run)
trained on synthetic data via the full production path (sharded params,
chunked loss, checkpointing, straggler monitor).

    PYTHONPATH=src python examples/train_lm.py --workload lm --preset tiny
"""

import argparse

from repro.configs import get_config  # noqa: F401  (registry also usable)
from repro.launch import train as train_cli
from repro.models.config import (AttentionSpec, LayerSpec, ModelConfig,
                                 simple_stack)

PRESETS = {
    # ~101M params: 12L d=768 12H swiglu, 32k vocab (GPT-2-small-ish)
    "100m": dict(layers=12, d=768, heads=12, kv=12, ff=3072, vocab=32768,
                 seq=512, batch=8, steps=300),
    "tiny": dict(layers=2, d=64, heads=4, kv=2, ff=128, vocab=256,
                 seq=64, batch=4, steps=30),
}


def build_config(p) -> ModelConfig:
    spec = LayerSpec(
        mixer="attn",
        attn=AttentionSpec(kind="gqa", n_heads=p["heads"],
                           n_kv_heads=p["kv"], head_dim=p["d"] // p["heads"]),
        ffn="swiglu",
    )
    return ModelConfig(
        name="example-lm", family="dense", d_model=p["d"], d_ff=p["ff"],
        vocab=p["vocab"], stages=simple_stack(p["layers"], spec),
    )


def run_spectral(args):
    """Train the learned spectral filter over a grad-tuned plan."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core import Croft3D, Decomposition, FFTOptions
    from repro.models.spectral import (init_spectral_filter_params,
                                       place_spectral_filter_params,
                                       spectral_filter_apply)
    from repro.train import make_spectral_train_step

    n = args.size
    shape = (n, n, n)
    n_dev = len(jax.devices())
    if n_dev == 1:
        plan = Croft3D(shape, problem="r2c")
        print(f"spectral workload: {shape} single-device")
    else:
        if n_dev % 2 == 0:
            mesh = jax.make_mesh((n_dev // 2, 2), ("y", "x"))
        else:
            mesh = jax.make_mesh((n_dev,), ("y",))
        # grad=True: the planner prices forward + adjoint schedule, so
        # the chosen plan is the best *training step*, not best forward
        plan = Croft3D.tuned(shape, mesh, mode="model", problem="r2c",
                             grad=True)
        print(f"spectral workload: {shape} on {dict(mesh.shape)} — "
              f"{plan.tune_result.summary()}")

    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randn(*shape), plan.input_dtype)
    if plan.mesh is not None:
        x = jax.device_put(x, plan.input_sharding)
    true = place_spectral_filter_params(plan, {
        "gate": jnp.asarray(1.0 + 0.3 * rng.randn(*shape), jnp.float32),
        "filter": jnp.asarray(
            1.0 + 0.3 * rng.randn(*plan.spectrum_shape), jnp.float32)})
    target = spectral_filter_apply(plan, true, x)
    step, _ = make_spectral_train_step(plan, lr=args.lr)
    params = place_spectral_filter_params(
        plan, init_spectral_filter_params(jax.random.PRNGKey(1), plan))
    steps = args.steps or 20
    for i in range(steps):
        params, loss = step(params, x, target)
        if i % max(1, steps // 10) == 0 or i == steps - 1:
            print(f"step {i:4d}  loss {float(loss):.4f}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--workload", default="spectral",
                    choices=("spectral", "lm"))
    ap.add_argument("--preset", default="tiny", choices=sorted(PRESETS))
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--size", type=int, default=32,
                    help="spectral: grid size N (N^3 field)")
    ap.add_argument("--lr", type=float, default=0.05,
                    help="spectral: SGD learning rate")
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()
    if args.workload == "spectral":
        run_spectral(args)
        return
    p = PRESETS[args.preset]
    cfg = build_config(p)
    print(f"example LM: {cfg.param_count():,} params")

    # register it so the production CLI path drives it unchanged
    import repro.configs as configs
    import sys, types
    mod = types.ModuleType("examples._example_lm")
    mod.full = lambda: cfg
    mod.smoke = lambda: cfg
    sys.modules["examples._example_lm"] = mod
    configs.ARCHS["example-lm"] = "examples._example_lm"

    argv = ["--arch", "example-lm",
            "--steps", str(args.steps or p["steps"]),
            "--global-batch", str(p["batch"]),
            "--seq-len", str(p["seq"]),
            "--log-every", "10"]
    if args.ckpt_dir:
        argv += ["--ckpt-dir", args.ckpt_dir]
    train_cli.main(argv)


if __name__ == "__main__":
    main()
