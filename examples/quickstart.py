"""CROFT quickstart: plan, transform, verify — single device or any mesh.

    PYTHONPATH=src python examples/quickstart.py
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/quickstart.py --devices 8
"""

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import Croft3D, Decomposition, FFTOptions


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=64)
    ap.add_argument("--devices", type=int, default=1)
    ap.add_argument("--k", type=int, default=2, help="CROFT overlap chunks")
    ap.add_argument("--decomp", default="pencil",
                    choices=["pencil", "slab", "cell"])
    args = ap.parse_args()

    n = args.n
    rng = np.random.RandomState(0)
    x = (rng.randn(n, n, n) + 1j * rng.randn(n, n, n)).astype(np.complex64)

    if args.devices > 1:
        if args.decomp == "pencil":
            py = 2
            mesh = jax.make_mesh(
                (py, args.devices // py), ("y", "z"),
                axis_types=(jax.sharding.AxisType.Auto,) * 2)
            decomp = Decomposition("pencil", ("y", "z"))
        elif args.decomp == "slab":
            mesh = jax.make_mesh((args.devices,), ("z",),
                                 axis_types=(jax.sharding.AxisType.Auto,))
            decomp = Decomposition("slab", ("z",))
        else:
            mesh = jax.make_mesh((2, 2, args.devices // 4), ("a", "b", "c"),
                                 axis_types=(jax.sharding.AxisType.Auto,) * 3)
            decomp = Decomposition("cell", ("a", "b", "c"))
    else:
        mesh = decomp = None

    opts = FFTOptions(overlap_k=args.k)
    plan = Croft3D((n, n, n), mesh, decomp, opts)
    print(f"grid {n}^3, decomposition={args.decomp}, K={args.k}, "
          f"devices={args.devices}")
    if mesh is not None:
        print(f"local pencil shape per device: {plan.local_shape()}")

    xd = jnp.asarray(x)
    if mesh is not None:
        xd = jax.device_put(xd, plan.input_sharding)
    y = plan.forward(xd)
    ref = np.fft.fftn(x)
    err = float(jnp.max(jnp.abs(y - ref))) / np.abs(ref).max()
    print(f"forward vs numpy.fftn relative error: {err:.2e}")

    xb = plan.inverse(y)
    rerr = float(jnp.max(jnp.abs(xb - x)))
    print(f"inverse(forward(x)) max abs error:   {rerr:.2e}")
    print(f"analytic FLOPs: {plan.flops_model():.3e}, "
          f"comm bytes/chip: {plan.comm_bytes_model():.3e}")


if __name__ == "__main__":
    main()
