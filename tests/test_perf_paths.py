"""Coverage for the §Perf optimization paths: sharded MoE dispatch modes,
absorbed MLA, remat-step attention — each asserted equal to its reference
implementation."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from conftest import run_multidevice


def test_absorbed_mla_equals_decompressed(rng):
    from repro.models.attention import mla_fwd, init_mla, MaskSpec
    from repro.models.config import AttentionSpec
    a = AttentionSpec(kind="mla", n_heads=4, n_kv_heads=4, head_dim=24,
                      q_lora_rank=16, kv_lora_rank=8, qk_nope_dim=16,
                      qk_rope_dim=8, v_head_dim=16)
    p = init_mla(jax.random.PRNGKey(0), 32, a)
    x = jnp.asarray(rng.randn(2, 8, 32).astype(np.float32))
    pos = jnp.arange(8)
    y_abs, lat_a = mla_fwd(p, x, a, MaskSpec(causal=True), pos, absorbed=True)
    y_dec, lat_d = mla_fwd(p, x, a, MaskSpec(causal=True), pos, absorbed=False)
    np.testing.assert_allclose(np.asarray(y_abs), np.asarray(y_dec),
                               atol=2e-6)
    np.testing.assert_array_equal(np.asarray(lat_a), np.asarray(lat_d))


def test_remat_step_attention_same_values_and_grads(rng):
    from repro.models.attention import blockwise_attention, MaskSpec
    b, s, h, d = 1, 32, 2, 8
    q = jnp.asarray(rng.randn(b, s, h, d).astype(np.float32))
    k = jnp.asarray(rng.randn(b, s, h, d).astype(np.float32))
    v = jnp.asarray(rng.randn(b, s, h, d).astype(np.float32))
    pos = jnp.arange(s)

    def loss(qq, remat):
        o = blockwise_attention(qq, k, v, MaskSpec(causal=True), pos, pos,
                                kv_block=8, remat_step=remat)
        return jnp.sum(o ** 2)

    v1, g1 = jax.value_and_grad(lambda qq: loss(qq, True))(q)
    v2, g2 = jax.value_and_grad(lambda qq: loss(qq, False))(q)
    np.testing.assert_allclose(float(v1), float(v2), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), atol=1e-5)


def test_moe_sharded_modes_match_reference():
    run_multidevice("""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.models.config import MoESpec
from repro.models.moe import init_moe, moe_fwd
from repro.models.moe_sharded import moe_fwd_sharded
mesh = jax.make_mesh((2,4), ("data","model"), axis_types=(jax.sharding.AxisType.Auto,)*2)
rng = np.random.RandomState(0)
x = jnp.asarray(rng.randn(4, 8, 16).astype(np.float32))
# ep mode (E % tp == 0)
m = MoESpec(n_experts=8, top_k=2, d_ff_expert=32, capacity_factor=16.0)
p = init_moe(jax.random.PRNGKey(0), 16, m)
ref = np.asarray(moe_fwd(p, x, m))
with jax.set_mesh(mesh):
    xd = jax.device_put(x, NamedSharding(mesh, P("data","model",None)))
    got = np.asarray(moe_fwd_sharded(p, xd, m, mesh=mesh, dp="data",
                                     cp_axis="model", tp_axis="model"))
assert np.max(np.abs(got-ref)) < 1e-5, np.max(np.abs(got-ref))
# tp mode (E % tp != 0) + shared expert
m2 = MoESpec(n_experts=6, top_k=2, n_shared=1, d_ff_expert=32, capacity_factor=16.0)
p2 = init_moe(jax.random.PRNGKey(1), 16, m2)
ref2 = np.asarray(moe_fwd(p2, x, m2))
with jax.set_mesh(mesh):
    got2 = np.asarray(moe_fwd_sharded(p2, xd, m2, mesh=mesh, dp="data",
                                      cp_axis="model", tp_axis="model"))
assert np.max(np.abs(got2-ref2)) < 1e-5, np.max(np.abs(got2-ref2))
# decode shape (S=1, cp None)
x1 = jnp.asarray(rng.randn(8, 1, 16).astype(np.float32))
ref3 = np.asarray(moe_fwd(p, x1, m))
with jax.set_mesh(mesh):
    x1d = jax.device_put(x1, NamedSharding(mesh, P("data",None,None)))
    got3 = np.asarray(moe_fwd_sharded(p, x1d, m, mesh=mesh, dp="data",
                                      cp_axis=None, tp_axis="model"))
assert np.max(np.abs(got3-ref3)) < 1e-5
# gradients flow through both modes
def loss(pp):
    return jnp.sum(moe_fwd_sharded(pp, xd, m, mesh=mesh, dp="data",
                                   cp_axis="model", tp_axis="model")**2)
with jax.set_mesh(mesh):
    g = jax.grad(loss)(p)
assert all(bool(jnp.all(jnp.isfinite(v))) for v in jax.tree.leaves(g))
print("OK moe_sharded ep/tp/decode + grads")
""")


def test_onehot_cache_write_equals_dus(rng):
    from repro.models import kvcache as kc
    from repro.models.config import AttentionSpec
    a = AttentionSpec(n_heads=2, n_kv_heads=2, head_dim=4, window=None)
    cache = kc.init_attn_cache(a, batch=2, max_len=8, dtype=jnp.float32)
    # prefill 5 tokens via the dus path
    k5 = jnp.asarray(rng.randn(2, 5, 2, 4).astype(np.float32))
    v5 = jnp.asarray(rng.randn(2, 5, 2, 4).astype(np.float32))
    cache = kc.write_attn_cache(cache, k5, v5, jnp.asarray(0))
    # decode 1 token via the one-hot path
    k1 = jnp.asarray(rng.randn(2, 1, 2, 4).astype(np.float32))
    v1 = jnp.asarray(rng.randn(2, 1, 2, 4).astype(np.float32))
    cache = kc.write_attn_cache(cache, k1, v1, jnp.asarray(5))
    np.testing.assert_allclose(np.asarray(cache["k"][:, 5:6]),
                               np.asarray(k1))
    np.testing.assert_allclose(np.asarray(cache["k"][:, :5]),
                               np.asarray(k5))
    assert list(np.asarray(cache["pos"])) == [0, 1, 2, 3, 4, 5, -1, -1]


def test_onehot_ring_wraparound(rng):
    from repro.models import kvcache as kc
    from repro.models.config import AttentionSpec
    a = AttentionSpec(n_heads=1, n_kv_heads=1, head_dim=4, window=4)
    cache = kc.init_attn_cache(a, batch=1, max_len=64, dtype=jnp.float32)
    assert cache["k"].shape[1] == 4  # ring of window slots
    ks = []
    for t in range(7):
        k1 = jnp.full((1, 1, 1, 4), float(t))
        cache = kc.write_attn_cache(cache, k1, k1, jnp.asarray(t))
        ks.append(k1)
    # slots hold positions 4,5,6,3 (t mod 4)
    np.testing.assert_array_equal(np.asarray(cache["pos"]), [4, 5, 6, 3])
    np.testing.assert_allclose(float(cache["k"][0, 2, 0, 0]), 6.0)
