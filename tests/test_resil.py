"""repro.resil: seeded fault injection, degradation ladders, hardening.

Single-device tests drive the whole request-lifecycle surface (sheds,
deadlines, retries, NaN isolation, preemption, upgrade rollback, wisdom
integrity) on meshless plans; the distributed story — HLO byte-identity
with an armed injector, executor-output poisoning, quarantine -> ladder
degradation with bitwise fallback parity — runs once in an 8-virtual-
device subprocess.
"""

import json
import os
import signal
import threading
import time

import numpy as np
import pytest

from repro.core import Croft3D
from repro.resil import (CrashMidWrite, FaultPlan, FaultSpec, InjectedFault,
                         TransientFault, degrade, inject, injection,
                         seeded_times)
from repro.serve import (PRIORITY_HIGH, PRIORITY_LOW, PlanCache, ShedResult,
                         TransformService)
from repro.tuning import wisdom as wisdom_lib
from repro.tuning.candidates import default_candidate
from conftest import run_multidevice

N = 8


def _cplx(rng, n=N):
    return (rng.randn(n, n, n) + 1j * rng.randn(n, n, n)).astype(np.complex64)


def _entry(measured=None):
    cand = default_candidate((8, 8, 8), {"y": 2, "z": 2})
    return wisdom_lib.WisdomEntry.from_candidate(
        cand, source="measure" if measured else "model",
        model_s=1e-3, measured_s=measured)


# --- fault plan mechanics ---------------------------------------------------

def test_fault_plan_times_and_match_are_exact():
    plan = FaultPlan([FaultSpec("serve.dispatch", times=(1,),
                                kind="transient"),
                      FaultSpec("plan.build", match="abc")])
    assert plan.check("serve.dispatch", "k") is None      # idx 0: scripted off
    spec, idx = plan.check("serve.dispatch", "k")         # idx 1: fires
    assert idx == 1 and spec.kind == "transient"
    assert plan.check("serve.dispatch", "k") is None      # idx 2: off again
    # match filters BEFORE the index counts: non-matching keys are
    # invisible to the spec's invocation stream
    assert plan.check("plan.build", "xyz") is None
    _spec, idx = plan.check("plan.build", "zzabczz")
    assert idx == 0
    assert plan.fired_counts() == {"serve.dispatch": 1, "plan.build": 1}
    # explicit times predict exactly; times=None predicts None (unknown)
    assert plan.predicted_counts() == {"serve.dispatch": 1,
                                       "plan.build": None}
    # un-scripted sites return None without bookkeeping
    assert plan.check("wisdom.write.crash", "p") is None


def test_fault_spec_validation_and_kinds():
    with pytest.raises(ValueError, match="kind"):
        FaultSpec("plan.build", kind="explode")
    with injection([FaultSpec("tune.measure", times=(0,))]) as plan:
        with pytest.raises(InjectedFault) as ei:
            inject.fire("tune.measure", "lbl")
        assert ei.value.site == "tune.measure" and ei.value.index == 0
        inject.fire("tune.measure", "lbl")  # idx 1: no-op
        assert plan.fired_counts() == {"tune.measure": 1}
    assert inject.get_plan() is None  # injection() always disarms
    with injection([FaultSpec("serve.dispatch", kind="transient"),
                    FaultSpec("wisdom.write.crash", kind="crash")]):
        with pytest.raises(TransientFault):
            inject.fire("serve.dispatch", "b")
        with pytest.raises(CrashMidWrite):
            inject.fire("wisdom.write.crash", "p")
    # disarmed: fire/corrupt are no-ops
    inject.fire("serve.dispatch", "b")
    assert inject.corrupt("exec.output", "s") is False


def test_seeded_times_deterministic():
    a = seeded_times(7, "serve.dispatch", 10, 3)
    assert a == seeded_times(7, "serve.dispatch", 10, 3)
    assert a != seeded_times(8, "serve.dispatch", 10, 3)
    assert a != seeded_times(7, "plan.build", 10, 3)
    assert len(a) == 3 and list(a) == sorted(set(a))
    assert all(0 <= t < 10 for t in a)


# --- degradation ladder (unit) ----------------------------------------------

def test_degrade_ladder_walks_to_default():
    axis_sizes = {"y": 2, "z": 2}
    cand = default_candidate((8, 8, 8), axis_sizes)
    bottom = degrade.bottom_candidate((8, 8, 8), axis_sizes)
    assert bottom.opts.overlap_k == 1
    assert bottom.opts.transpose_impl == "alltoall"
    # stock candidate (K=2) sits one rung above the bottom
    step = degrade.next_rung(cand, (8, 8, 8), axis_sizes)
    assert step is not None and step[0] == "default"
    assert step[1].plan_key == bottom.plan_key
    # the bottom itself has nowhere to go
    assert degrade.next_rung(bottom, (8, 8, 8), axis_sizes) is None
    # packed r2c degrades to embed before the default rung
    r2c = default_candidate((8, 8, 8), axis_sizes, problem="r2c")
    if getattr(r2c, "strategy", None) == "packed":
        rung, emb = degrade.next_rung(r2c, (8, 8, 8), axis_sizes)
        assert rung == "embed" and emb.strategy == "embed"
    rb = degrade.bottom_candidate((8, 8, 8), axis_sizes, problem="r2c")
    assert rb.strategy == "embed"


def test_degrade_meshless_plan_has_no_ladder():
    assert degrade.ladder(Croft3D((N, N, N))) == []


# --- plan-cache resilience (single device) ----------------------------------

def test_plan_build_fault_falls_back_and_serves(rng):
    cache = PlanCache()
    with injection([FaultSpec("plan.build", times=(0,))]):
        cp = cache.get((N, N, N))
    assert cp.rung == "default"
    snap = cache.registry.snapshot()
    assert snap["plan_build_failures"]["value"] == 1
    assert snap["plan_build_fallbacks"]["value"] == 1
    x = _cplx(rng)
    assert np.array_equal(np.asarray(cp.plan.forward(x)),
                          np.asarray(Croft3D((N, N, N)).forward(x)))
    # a fresh key after the scripted window builds primary again
    cp2 = cache.get((N, N, 2 * N))
    assert cp2.rung == "primary"


def test_quarantine_exhausted_resets_failure_counter():
    """A meshless plan has no ladder: quarantine bottoms out, counts one
    exhaustion event, and resets the burst counter (bounded events)."""
    cache = PlanCache(quarantine_after=3)
    cp = cache.get((N, N, N))
    for _ in range(3):
        cache.report_dispatch_failure(cp.key)
    snap = cache.registry.snapshot()
    assert snap["plan_dispatch_failures"]["value"] == 3
    assert snap["plan_quarantines"]["value"] == 1
    assert snap["plan_degrade_exhausted"]["value"] == 1
    assert cache._plans[cp.key].failures == 0
    assert cache._plans[cp.key].plan is cp.plan  # still serving


def test_upgrade_failure_rolls_back_and_caps_retries(rng):
    """Satellite 1: a failing background upgrade must roll the entry back
    to its servable cold state, count serve_upgrade_failures, and stop
    re-arming after upgrade_max_retries."""
    cache = PlanCache(measure_after=1, upgrade_async=False,
                      upgrade_max_retries=2)
    cp = cache.get((N, N, N))
    cp.state = "cold"           # meshless plans are born warm; force the
    cache.mesh = object()       # upgrade path (injection raises before
    #                             anything touches the fake mesh)
    with injection([FaultSpec("plan.upgrade")]) as plan:
        for _ in range(5):
            cache._maybe_upgrade(cache._plans[cp.key])
        assert plan.fired_counts() == {"plan.upgrade": 2}  # capped
    cur = cache._plans[cp.key]
    assert cur.upgrade_failures == 2 and not cur.upgrading
    assert cur.state == "cold"
    snap = cache.registry.snapshot()
    assert snap["serve_upgrade_failures"]["value"] == 2
    assert snap["plan_cache_upgrade_starts"]["value"] == 2
    x = _cplx(rng)  # the rolled-back entry still serves
    assert np.array_equal(np.asarray(cur.plan.forward(x)),
                          np.asarray(Croft3D((N, N, N)).forward(x)))


def test_wait_idle_reports_timeout_and_prunes():
    """Satellite 2: wait_idle says whether threads actually joined."""
    cache = PlanCache()
    assert cache.wait_idle(timeout=0.1) is True  # nothing outstanding
    t = threading.Thread(target=lambda: time.sleep(0.5), daemon=True)
    cache._upgrade_threads.append(t)
    t.start()
    assert cache.wait_idle(timeout=0.05) is False
    assert cache.alive_upgrades() == 1
    assert cache.wait_idle(timeout=10.0) is True
    assert cache.alive_upgrades() == 0
    assert cache._upgrade_threads == []


# --- service request lifecycle (single device) ------------------------------

def test_transient_dispatch_fault_retries_and_succeeds(rng):
    with injection([FaultSpec("serve.dispatch", times=(0,),
                              kind="transient")]):
        with TransformService(max_batch=4, retry_backoff_s=0.0) as svc:
            x = _cplx(rng)
            got = svc.transform(x)
            assert np.array_equal(got,
                                  np.asarray(Croft3D((N, N, N)).forward(x)))
            snap = svc.registry.snapshot()
            assert snap["serve_dispatch_retries"]["value"] == 1
            assert snap["serve_failures"]["value"] == 0


def test_transient_fault_exhausts_retries_then_fails(rng):
    with injection([FaultSpec("serve.dispatch", kind="transient")]):
        with TransformService(max_batch=4, dispatch_retries=1,
                              retry_backoff_s=0.0) as svc:
            r = svc.submit(_cplx(rng)).result(timeout=60)
            assert not r.ok and "TransientFault" in r.error
            snap = svc.registry.snapshot()
            assert snap["serve_dispatch_retries"]["value"] == 1
            # the exhausted failure counts toward quarantine
            assert snap["plan_dispatch_failures"]["value"] == 1


def test_deadline_miss_resolves_typed_and_batchmates_survive(rng):
    with TransformService(max_batch=4, max_wait_ms=20.0) as svc:
        f_dead = svc.submit(_cplx(rng), deadline_s=0.0)
        f_live = svc.submit(_cplx(rng))
        rd = f_dead.result(timeout=60)
        assert isinstance(rd, ShedResult) and rd.shed_reason == "deadline"
        assert not rd.ok and "deadline" in rd.error
        assert f_live.result(timeout=60).ok
        assert svc.registry.snapshot()["serve_deadline_misses"]["value"] == 1


def test_bounded_queue_sheds_lowest_priority_first(rng):
    """max_queue=4 with 4 HIGH + 3 LOW pending: exactly the 3 LOWs shed
    with a typed queue-full ShedResult; the HIGHs all serve on drain.
    max_wait is huge so nothing dispatches until stop() — counts exact."""
    with TransformService(max_batch=8, max_wait_ms=60000.0,
                          max_queue=4) as svc:
        highs = [svc.submit(_cplx(rng), priority=PRIORITY_HIGH)
                 for _ in range(4)]
        lows = [svc.submit(_cplx(rng), priority=PRIORITY_LOW)
                for _ in range(3)]
        shed = [f.result(timeout=60) for f in lows]  # resolve pre-stop:
        #                                              a shed never hangs
        assert all(isinstance(r, ShedResult)
                   and r.shed_reason == "queue-full" for r in shed)
        assert svc.registry.snapshot()["serve_shed_requests"]["value"] == 3
    assert all(f.result(timeout=60).ok for f in highs)


def test_nan_payload_isolated_healthy_batchmates_redispatch(rng):
    """One NaN payload co-batched with two healthy requests: the poisoned
    request fails typed, both batch-mates re-dispatch individually and
    come back bitwise-equal to the direct transform."""
    xs = [_cplx(rng) for _ in range(2)]
    bad = _cplx(rng)
    bad[0, 0, 0] = np.nan
    ref = Croft3D((N, N, N))
    with TransformService(max_batch=4, max_wait_ms=200.0) as svc:
        fb = svc.submit(bad)
        fh = [svc.submit(x) for x in xs]
        rb = fb.result(timeout=120)
        assert not rb.ok and "poisoned payload" in rb.error
        for x, f in zip(xs, fh):
            r = f.result(timeout=120)
            assert r.ok, r.error
            assert np.array_equal(r.value, np.asarray(ref.forward(x)))
        snap = svc.registry.snapshot()
        assert snap["serve_poisoned_requests"]["value"] == 1
        assert snap["serve_poison_redispatches"]["value"] == 2


def test_preemption_drains_and_refuses_new_work(rng):
    """Satellite 3: SIGTERM flips the PreemptionHandler flag; the worker
    serves everything pending, stops cleanly, and submit() refuses."""
    from repro.train.fault import PreemptionHandler
    old = signal.getsignal(signal.SIGTERM)
    try:
        svc = TransformService(max_batch=8, max_wait_ms=60000.0,
                               preemption=PreemptionHandler())
        svc.start()
        futs = [svc.submit(_cplx(rng)) for _ in range(3)]
        signal.raise_signal(signal.SIGTERM)
        results = [f.result(timeout=120) for f in futs]
        assert all(r.ok for r in results), [r.error for r in results]
        t0 = time.monotonic()
        while svc._worker.is_alive() and time.monotonic() - t0 < 30:
            time.sleep(0.01)
        assert not svc._worker.is_alive(), "worker did not stop after drain"
        with pytest.raises(RuntimeError, match="not started"):
            svc.submit(_cplx(rng))
        assert svc.registry.snapshot()[
            "serve_preemption_drains"]["value"] == 1
        svc.stop()  # idempotent after the drain
    finally:
        signal.signal(signal.SIGTERM, old)


# --- wisdom integrity -------------------------------------------------------

def test_wisdom_checksum_corruption_quarantines_file(tmp_path):
    path = str(tmp_path / "w.json")
    wisdom_lib.merge_entries(path, {"ka": _entry()})
    blob = json.load(open(path))
    assert blob["checksum"] == wisdom_lib._entries_checksum(blob["entries"])
    blob["entries"]["ka"]["model_s"] = 99.0  # tamper, keep stale checksum
    json.dump(blob, open(path, "w"))
    assert len(wisdom_lib.Wisdom.load(path)) == 0
    assert os.path.exists(path + ".corrupt-1") and not os.path.exists(path)
    with open(path, "w") as f:
        f.write("{ not json")  # parse failure quarantines too
    assert len(wisdom_lib.Wisdom.load(path)) == 0
    assert os.path.exists(path + ".corrupt-2")


def test_wisdom_legacy_and_newer_version_files(tmp_path):
    path = str(tmp_path / "w.json")
    wisdom_lib.merge_entries(path, {"kb": _entry()})
    blob = json.load(open(path))
    del blob["checksum"]  # pre-checksum file: nothing to verify
    json.dump(blob, open(path, "w"))
    assert sorted(wisdom_lib.Wisdom.load(path).entries) == ["kb"]
    # a newer-version file is valid-but-unknown: empty, NOT quarantined
    json.dump({"version": 99, "entries": {}}, open(path, "w"))
    assert len(wisdom_lib.Wisdom.load(path)) == 0
    assert os.path.exists(path)
    assert not any(p.name.endswith(".corrupt-1")
                   for p in tmp_path.iterdir())


def test_wisdom_crash_mid_write_leaves_store_loadable(tmp_path):
    """Satellite 4: a writer killed between temp-write and atomic rename
    leaves the old store intact plus a stale .tmp; the next locked merge
    cleans the temp and lands both entries."""
    path = str(tmp_path / "w.json")
    wisdom_lib.merge_entries(path, {"k1": _entry()})
    with injection([FaultSpec("wisdom.write.crash", times=(0,),
                              kind="crash")]):
        with pytest.raises(CrashMidWrite):
            wisdom_lib.merge_entries(path, {"k2": _entry(measured=1e-3)})
    assert os.path.exists(path + ".tmp")  # the interrupted write
    assert sorted(wisdom_lib.Wisdom.load(path).entries) == ["k1"]
    wisdom_lib.merge_entries(path, {"k2": _entry(measured=1e-3)})
    assert not os.path.exists(path + ".tmp")
    assert sorted(wisdom_lib.Wisdom.load(path).entries) == ["k1", "k2"]
    assert not os.path.exists(path + ".lock")


# --- distributed: HLO pin, executor poisoning, ladder parity ----------------

_MULTIDEVICE_CODE = """
import dataclasses, os, tempfile
import numpy as np, jax
from repro.core import Croft3D, Decomposition, FFTOptions
from repro.obs.metrics import MetricsRegistry
from repro.resil import FaultSpec, degrade, injection
from repro.serve import PlanCache, TransformService
from repro.tuning import wisdom as wisdom_lib
from repro.tuning.candidates import default_candidate

mesh = jax.make_mesh((2, 4), ("y", "z"))
N = 16
dec = Decomposition("pencil", ("y", "z"))

# HLO pin: an armed-but-unmatched injector contributes zero ops — a plan
# compiled under it is byte-identical to one compiled with no injector
pa = Croft3D((N, N, N), mesh, dec, FFTOptions(overlap_k=2))
hlo_off = pa.lower_forward().compile().as_text()
with injection([FaultSpec("exec.output", match="no-such-schedule")]):
    pb = Croft3D((N, N, N), mesh, dec, FFTOptions(overlap_k=2))
    hlo_on = pb.lower_forward().compile().as_text()
assert hlo_on == hlo_off, "armed injector changed compiled HLO"

# executor-output poisoning: finite input -> NaN output is treated as a
# poisoned plan; at quarantine_after=1 the entry degrades to the bottom
# rung, whose results must equal the direct fallback plan bit for bit
wisdom = os.path.join(tempfile.mkdtemp(), "w.json")
cand = default_candidate((N, N, N), {"y": 2, "z": 2})
key = wisdom_lib.wisdom_key((N, N, N), {"y": 2, "z": 2}, np.complex64,
                            jax.default_backend())
wisdom_lib.merge_entries(wisdom, {key: wisdom_lib.WisdomEntry.from_candidate(
    cand, source="measure", measured_s=1e-3)})

reg = MetricsRegistry()
cache = PlanCache(mesh, wisdom_path=wisdom, quarantine_after=1,
                  registry=reg)
svc = TransformService(mesh, max_batch=4, max_wait_ms=20.0, cache=cache,
                       registry=reg)
rng = np.random.RandomState(0)
x = (rng.randn(N, N, N) + 1j * rng.randn(N, N, N)).astype(np.complex64)
with svc:
    with injection([FaultSpec("exec.output", kind="nan")]):
        r = svc.submit(x).result(timeout=400)
    assert not r.ok and "non-finite output" in r.error, r.error
    snap = svc.registry.snapshot()
    assert snap["serve_nan_outputs"]["value"] == 1
    assert snap["plan_quarantines"]["value"] == 1
    assert snap["plan_degradations"]["value"] == 1
    cp = cache._plans[cache.key_for((N, N, N), np.complex64, "c2c")]
    assert cp.rung == "default" and cp.quarantined
    r2 = svc.submit(x).result(timeout=400)
    assert r2.ok, r2.error
    bottom = degrade.bottom_candidate((N, N, N), {"y": 2, "z": 2})
    direct = Croft3D((N, N, N), mesh, bottom.decomp, bottom.opts)
    ref = np.asarray(direct.forward(
        jax.device_put(x, direct.input_sharding)))
    assert np.array_equal(r2.value, ref), "degraded bucket != fallback plan"
print("RESIL_MULTIDEVICE_OK")
"""


def test_resil_multidevice_poison_quarantine_parity():
    out = run_multidevice(_MULTIDEVICE_CODE, n_devices=8, timeout=480)
    assert "RESIL_MULTIDEVICE_OK" in out
