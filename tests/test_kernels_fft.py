"""Pallas kernel sweeps (interpret mode) against the pure-jnp oracles."""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.kernels import fft_matmul_1d, spectral_scale_op
from repro.kernels.fft_matmul import fft4step_planes
from repro.kernels.ref import ref_fft_1d, ref_spectral_scale


@pytest.mark.parametrize("n", [64, 128, 256, 1024, 4096])
@pytest.mark.parametrize("b", [1, 3, 32])
def test_fft_matmul_kernel_shapes(n, b, rng):
    x = (rng.randn(b, n) + 1j * rng.randn(b, n)).astype(np.complex64)
    y = np.asarray(fft_matmul_1d(jnp.asarray(x)))
    ref = np.asarray(ref_fft_1d(jnp.asarray(x)))
    np.testing.assert_allclose(y, ref, atol=3e-4 * max(1, np.abs(ref).max()))


@pytest.mark.parametrize("sign", [-1, +1])
def test_fft_matmul_kernel_signs(sign, rng):
    x = (rng.randn(4, 256) + 1j * rng.randn(4, 256)).astype(np.complex64)
    y = np.asarray(fft_matmul_1d(jnp.asarray(x), sign=sign))
    ref = np.asarray(ref_fft_1d(jnp.asarray(x), sign=sign))
    np.testing.assert_allclose(y, ref, atol=3e-4 * np.abs(ref).max())


def test_fft_matmul_kernel_rank3(rng):
    x = (rng.randn(2, 5, 128) + 1j * rng.randn(2, 5, 128)).astype(np.complex64)
    y = np.asarray(fft_matmul_1d(jnp.asarray(x)))
    ref = np.fft.fft(x)
    np.testing.assert_allclose(y, ref, atol=3e-4 * np.abs(ref).max())


def test_kernel_block_row_edge(rng):
    """Batch not divisible by the default block: falls back to divisors."""
    x = (rng.randn(7, 64) + 1j * rng.randn(7, 64)).astype(np.complex64)
    y = np.asarray(fft_matmul_1d(jnp.asarray(x)))
    np.testing.assert_allclose(y, np.fft.fft(x), atol=2e-4 * np.abs(x).max() * 64)


def test_kernel_explicit_block_rows(rng):
    xr = rng.randn(8, 256).astype(np.float32)
    xi = rng.randn(8, 256).astype(np.float32)
    yr, yi = fft4step_planes(jnp.asarray(xr), jnp.asarray(xi), -1,
                             block_rows=2)
    ref = np.fft.fft(xr + 1j * xi)
    np.testing.assert_allclose(np.asarray(yr) + 1j * np.asarray(yi), ref,
                               atol=3e-4 * np.abs(ref).max())


def test_kernel_too_large_raises():
    import repro.core.plan as plan_lib
    n = plan_lib.MAX_TWO_LEVEL * 2
    xr = jnp.zeros((1, n), jnp.float32)
    with pytest.raises(ValueError):
        fft4step_planes(xr, xr)


@pytest.mark.parametrize("n", [128, 1024])
@pytest.mark.parametrize("alpha", [1.0, 0.25])
def test_spectral_scale_kernel(n, alpha, rng):
    x = (rng.randn(6, n) + 1j * rng.randn(6, n)).astype(np.complex64)
    h = (rng.randn(n) + 1j * rng.randn(n)).astype(np.complex64)
    y = np.asarray(spectral_scale_op(jnp.asarray(x), jnp.asarray(h), alpha))
    ref = np.asarray(ref_spectral_scale(jnp.asarray(x), jnp.asarray(h), alpha))
    np.testing.assert_allclose(y, ref, atol=1e-5 * max(1, np.abs(ref).max()))


def test_kernel_vs_distributed_pipeline_consistency(rng):
    """local_impl='pallas' inside the 3-D transform == jnp oracle."""
    from repro.core import fft3d, FFTOptions
    x = (rng.randn(16, 8, 8) + 1j * rng.randn(16, 8, 8)).astype(np.complex64)
    # pallas path requires pow-2 >= small sizes; use 16,8,8
    y = np.asarray(fft3d(jnp.asarray(x), opts=FFTOptions(local_impl="pallas")))
    ref = np.fft.fftn(x)
    np.testing.assert_allclose(y, ref, atol=5e-4 * np.abs(ref).max())


import jax


@pytest.mark.parametrize("cfg", [
    dict(b=2, sq=256, skv=256, h=4, kv=2, d=64, causal=True, win=None),
    dict(b=1, sq=128, skv=256, h=8, kv=8, d=32, causal=True, win=64),
    dict(b=1, sq=256, skv=256, h=2, kv=1, d=64, causal=False, win=None),
    dict(b=1, sq=128, skv=128, h=4, kv=4, d=128, causal=True, win=32),
])
def test_flash_attention_kernel(cfg, rng):
    from repro.kernels.flash_attention import flash_attention
    from repro.kernels.ref import ref_flash_attention
    q = jnp.asarray(rng.randn(cfg["b"], cfg["sq"], cfg["h"], cfg["d"])
                    .astype(np.float32))
    k = jnp.asarray(rng.randn(cfg["b"], cfg["skv"], cfg["kv"], cfg["d"])
                    .astype(np.float32))
    v = jnp.asarray(rng.randn(cfg["b"], cfg["skv"], cfg["kv"], cfg["d"])
                    .astype(np.float32))
    out = flash_attention(q, k, v, causal=cfg["causal"], window=cfg["win"],
                          q_block=128, kv_chunk=128)
    ref = ref_flash_attention(q, k, v, causal=cfg["causal"],
                              window=cfg["win"])
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=5e-5)


def test_flash_attention_bf16(rng):
    from repro.kernels.flash_attention import flash_attention
    from repro.kernels.ref import ref_flash_attention
    q = jnp.asarray(rng.randn(1, 128, 2, 64), jnp.bfloat16)
    k = jnp.asarray(rng.randn(1, 128, 2, 64), jnp.bfloat16)
    v = jnp.asarray(rng.randn(1, 128, 2, 64), jnp.bfloat16)
    out = flash_attention(q, k, v, q_block=128, kv_chunk=64)
    ref = ref_flash_attention(q, k, v)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=3e-2)
