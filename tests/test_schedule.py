"""Stage-schedule IR: golden snapshots of the built pipelines, symbolic
layout propagation, effective-K reporting, cost-model derivation, the
batch wisdom-key dimension, and the pairwise-transpose rejections.

The golden strings pin the *stage structure* of every standard
decomposition: a refactor that changes what the executor would run (stage
order, transpose axes, chunk axes, pack/unpack placement) fails here
loudly instead of silently shifting numerics or cost-model rankings.
"""

import numpy as np
import jax.numpy as jnp
import pytest

from conftest import run_multidevice
from repro.core import Decomposition, FFTOptions
from repro.core import schedule as schedule_lib
from repro.core.distributed import build_schedule
from repro.grad import adjoint_schedule
from repro.real.pipeline import build_packed_forward, build_packed_inverse
from repro import tuning

SIZES = {"data": 2, "model": 4}
PENCIL = Decomposition("pencil", ("data", "model"))
SLAB = Decomposition("slab", ("p",))
CELL = Decomposition("cell", ("a", "b", "c"))


# --- golden snapshots --------------------------------------------------------

GOLDEN = {
    "pencil-natural": """\
schedule pencil/c2c/natural sign=-1
  in : C(Nx, Ny/data, Nz/model)
  0 x-fft+xy: fft[x]@s0 | a2a[data] split=0 concat=1 chunk=2 -> C(Nx/data, Ny, Nz/model)
  1 y-fft+yz: fft[y]@s1 | a2a[model] split=1 concat=2 chunk=0 -> C(Nx/data, Ny/model, Nz)
  2 z-fft: fft[z]@s2 -> C(Nx/data, Ny/model, Nz)
  3 restore-yz: a2a[model] split=2 concat=1 chunk=0 -> C(Nx/data, Ny, Nz/model)
  4 restore-xy: a2a[data] split=1 concat=0 chunk=2 -> C(Nx, Ny/data, Nz/model)
  out: C(Nx, Ny/data, Nz/model)""",
    "pencil-spectral": """\
schedule pencil/c2c/spectral sign=-1
  in : C(Nx, Ny/data, Nz/model)
  0 x-fft+xy: fft[x]@s0 | a2a[data] split=0 concat=1 chunk=2 -> C(Nx/data, Ny, Nz/model)
  1 y-fft+yz: fft[y]@s1 | a2a[model] split=1 concat=2 chunk=0 -> C(Nx/data, Ny/model, Nz)
  2 z-fft: fft[z]@s2 -> C(Nx/data, Ny/model, Nz)
  out: C(Nx/data, Ny/model, Nz)""",
    "pencil-from-spectral": """\
schedule pencil/c2c/from-spectral sign=+1
  in : C(Nx/data, Ny/model, Nz)
  0 z-fft+zy: fft[z]@s0 | a2a[model] split=2 concat=1 chunk=0 -> C(Nx/data, Ny, Nz/model)
  1 y-fft+yx: fft[y]@s1 | a2a[data] split=1 concat=0 chunk=2 -> C(Nx, Ny/data, Nz/model)
  2 x-fft: fft[x]@s2 -> C(Nx, Ny/data, Nz/model)
  out: C(Nx, Ny/data, Nz/model)""",
    "slab-natural": """\
schedule slab/c2c/natural sign=-1
  in : C(Nx, Ny, Nz/p)
  0 y-fft: fft[y]@s0 -> C(Nx, Ny, Nz/p)
  1 x-fft+xz: fft[x]@s1 | a2a[p] split=0 concat=2 chunk=1 -> C(Nx/p, Ny, Nz)
  2 z-fft: fft[z]@s2 -> C(Nx/p, Ny, Nz)
  3 restore-zx: a2a[p] split=2 concat=0 chunk=1 -> C(Nx, Ny, Nz/p)
  out: C(Nx, Ny, Nz/p)""",
    "cell-natural": """\
schedule cell/c2c/natural sign=-1
  in : C(Nx/a, Ny/b, Nz/c)
  0 regroup-x: a2a[a] split=1 concat=0 chunk=2 -> C(Nx, Ny/b/a, Nz/c)
  1 x-fft+xy: fft[x]@s0 | a2a[b+a] split=0 concat=1 chunk=2 -> C(Nx/b/a, Ny, Nz/c)
  2 y-fft+yz: fft[y]@s1 | a2a[c] split=1 concat=2 chunk=0 -> C(Nx/b/a, Ny/c, Nz)
  3 z-fft: fft[z]@s2 -> C(Nx/b/a, Ny/c, Nz)
  4 restore-yz: a2a[c] split=2 concat=1 chunk=0 -> C(Nx/b/a, Ny, Nz/c)
  5 restore-xy: a2a[b+a] split=1 concat=0 chunk=2 -> C(Nx, Ny/b/a, Nz/c)
  6 scatter-x: a2a[a] split=0 concat=1 chunk=2 -> C(Nx/a, Ny/b, Nz/c)
  out: C(Nx/a, Ny/b, Nz/c)""",
    "packed-pencil-fwd": """\
schedule pencil/r2c/packed sign=-1
  in : R(Nx/data, Ny/model, Nz)
  0 pack+z-rfft+zy: pack2[y] | fft[z]@s0 | unpack2[y] | a2a[model] split=2 concat=1 chunk=0 -> C(Nx/data, Ny, Nz:2/model)
  1 y-fft+yx: fft[y]@s1 | a2a[data] split=1 concat=0 chunk=2 -> C(Nx, Ny/data, Nz:2/model)
  2 x-fft: fft[x]@s2 -> C(Nx, Ny/data, Nz:2/model)
  + reshard z-localize: C(Nx, Ny/data, Nz:2/model) (one fused all-to-all)
  out: C(Nx, Ny/data, Nz:2/model)""",
    "packed-pencil-inv": """\
schedule pencil/c2r/packed sign=+1
  in : C(Nx, Ny/data, Nz:2/model)
  0 x-ifft+xy: fft[x]@s0 | a2a[data] split=0 concat=1 chunk=2 -> C(Nx/data, Ny, Nz:2/model)
  1 y-ifft+yz: fft[y]@s1 | a2a[model] split=1 concat=2 chunk=0 -> C(Nx/data, Ny/model, Nz:2)
  2 repack+z-ifft+split: repack2[y] | fft[z]@s2 | split2[y] -> R(Nx/data, Ny/model, Nz)
  + reshard x-localize: C(Nx, Ny/data, Nz:2/model) (one fused all-to-all)
  out: R(Nx/data, Ny/model, Nz)""",
    "packed-slab-fwd": """\
schedule slab/r2c/packed sign=-1
  in : R(Nx/p, Ny, Nz)
  0 pack+z-rfft+zx: pack2[x] | fft[z]@s0 | unpack2[x] | a2a[p] split=2 concat=0 chunk=1 -> C(Nx, Ny, Nz:2/p)
  1 y-fft: fft[y]@s1 -> C(Nx, Ny, Nz:2/p)
  2 x-fft: fft[x]@s2 -> C(Nx, Ny, Nz:2/p)
  + reshard z-localize: C(Nx, Ny, Nz:2/p) (one fused all-to-all)
  out: C(Nx, Ny, Nz:2/p)""",
    "packed-slab-inv": """\
schedule slab/c2r/packed sign=+1
  in : C(Nx, Ny, Nz:2/p)
  0 x-ifft+xz: fft[x]@s0 | a2a[p] split=0 concat=2 chunk=1 -> C(Nx/p, Ny, Nz:2)
  1 y-ifft: fft[y]@s1 -> C(Nx/p, Ny, Nz:2)
  2 repack+z-ifft+split: repack2[x] | fft[z]@s2 | split2[x] -> R(Nx/p, Ny, Nz)
  + reshard x-localize: C(Nx, Ny, Nz:2/p) (one fused all-to-all)
  out: R(Nx/p, Ny, Nz)""",
    # adjoint schedules (repro.grad): the backward pass of each pinned
    # forward is itself a pinned schedule — stage order reversed, each
    # transpose's split/concat swapped, each packed op replaced by its
    # explicit transpose.  A refactor that silently changes what the
    # training backward runs fails here, same as a forward change.
    "adj-pencil-natural": """\
schedule pencil/c2c/natural^T sign=-1
  in : C(Nx, Ny/data, Nz/model)
  0 adj-comm-restore-xy: a2a[data] split=0 concat=1 chunk=2 -> C(Nx/data, Ny, Nz/model)
  1 adj-comm-restore-yz: a2a[model] split=1 concat=2 chunk=0 -> C(Nx/data, Ny/model, Nz)
  2 adj-z-fft: fft[z]@s0 | a2a[model] split=2 concat=1 chunk=0 -> C(Nx/data, Ny, Nz/model)
  3 adj-y-fft+yz: fft[y]@s1 | a2a[data] split=1 concat=0 chunk=2 -> C(Nx, Ny/data, Nz/model)
  4 adj-x-fft+xy: fft[x]@s2 -> C(Nx, Ny/data, Nz/model)
  out: C(Nx, Ny/data, Nz/model)""",
    "adj-pencil-spectral": """\
schedule pencil/c2c/spectral^T sign=-1
  in : C(Nx/data, Ny/model, Nz)
  0 adj-z-fft: fft[z]@s0 | a2a[model] split=2 concat=1 chunk=0 -> C(Nx/data, Ny, Nz/model)
  1 adj-y-fft+yz: fft[y]@s1 | a2a[data] split=1 concat=0 chunk=2 -> C(Nx, Ny/data, Nz/model)
  2 adj-x-fft+xy: fft[x]@s2 -> C(Nx, Ny/data, Nz/model)
  out: C(Nx, Ny/data, Nz/model)""",
    "adj-packed-pencil-fwd": """\
schedule pencil/r2c/packed^T sign=-1
  in : C(Nx, Ny/data, Nz:2/model)
  0 adj-x-fft: fft[x]@s0 | a2a[data] split=0 concat=1 chunk=2 -> C(Nx/data, Ny, Nz:2/model)
  1 adj-y-fft+yx: fft[y]@s1 | a2a[model] split=1 concat=2 chunk=0 -> C(Nx/data, Ny/model, Nz:2)
  2 adj-pack+z-rfft+zy: unpack2T[y] | fft[z]@s2 | pack2T[y] -> R(Nx/data, Ny/model, Nz)
  + reshard adj-z-localize: C(Nx, Ny/data, Nz:2/model) (one fused all-to-all)
  out: R(Nx/data, Ny/model, Nz)""",
    "adj-packed-slab-fwd": """\
schedule slab/r2c/packed^T sign=-1
  in : C(Nx, Ny, Nz:2/p)
  0 adj-x-fft: fft[x]@s0 -> C(Nx, Ny, Nz:2/p)
  1 adj-y-fft: fft[y]@s1 -> C(Nx, Ny, Nz:2/p)
  2 adj-comm-pack+z-rfft+zx: a2a[p] split=0 concat=2 chunk=1 -> C(Nx/p, Ny, Nz:2)
  3 adj-pack+z-rfft+zx: unpack2T[x] | fft[z]@s2 | pack2T[x] -> R(Nx/p, Ny, Nz)
  + reshard adj-z-localize: C(Nx, Ny, Nz:2/p) (one fused all-to-all)
  out: R(Nx/p, Ny, Nz)""",
}


def _built():
    return {
        "pencil-natural": build_schedule(PENCIL, FFTOptions()),
        "pencil-spectral": build_schedule(
            PENCIL, FFTOptions(output_layout="spectral")),
        "pencil-from-spectral": build_schedule(
            PENCIL, FFTOptions(output_layout="spectral"), sign=+1),
        "slab-natural": build_schedule(SLAB, FFTOptions()),
        "cell-natural": build_schedule(CELL, FFTOptions()),
        "packed-pencil-fwd": build_packed_forward(PENCIL),
        "packed-pencil-inv": build_packed_inverse(PENCIL, 32),
        "packed-slab-fwd": build_packed_forward(SLAB),
        "packed-slab-inv": build_packed_inverse(SLAB, 32),
        "adj-pencil-natural": adjoint_schedule(
            build_schedule(PENCIL, FFTOptions())),
        "adj-pencil-spectral": adjoint_schedule(
            build_schedule(PENCIL, FFTOptions(output_layout="spectral"))),
        "adj-packed-pencil-fwd": adjoint_schedule(
            build_packed_forward(PENCIL)),
        "adj-packed-slab-fwd": adjoint_schedule(build_packed_forward(SLAB)),
    }


@pytest.mark.parametrize("key", sorted(GOLDEN))
def test_golden_schedules(key):
    assert _built()[key].describe() == GOLDEN[key], (
        f"stage structure of {key} changed — if intentional, update the "
        "golden snapshot AND re-verify numerics + cost-model rankings")


# --- symbolic layouts --------------------------------------------------------

def test_layout_specs_match_decomposition():
    for dec in (PENCIL, SLAB, CELL, Decomposition("pencil",
                                                  (("a", "b"), "c"))):
        assert (schedule_lib.layout_for(dec, "natural").partition_spec()
                == dec.partition_spec())
        assert (schedule_lib.layout_for(dec, "spectral").partition_spec()
                == dec.spectral_spec())
    # schedules restore the layouts the shard_map wrappers advertise
    sched = build_schedule(PENCIL, FFTOptions())
    assert sched.layout_in.partition_spec() == PENCIL.partition_spec()
    assert sched.layout_out.partition_spec() == PENCIL.partition_spec()
    spec = build_schedule(PENCIL, FFTOptions(output_layout="spectral"))
    assert spec.layout_out.partition_spec() == PENCIL.spectral_spec()


def test_layout_local_shapes_and_bytes():
    sched = build_packed_forward(PENCIL)
    shape = (32, 32, 32)
    # real input: same byte count as the Nz/2 complex spectrum it becomes
    assert sched.layout_in.local_shape(shape, SIZES) == (16, 8, 32)
    assert sched.layout_in.bytes(shape, SIZES, 8) == 16 * 8 * 32 * 4
    assert sched.layout_out.local_shape(shape, SIZES) == (32, 16, 4)
    assert sched.layout_out.bytes(shape, SIZES, 8) == 32 * 16 * 4 * 8


def test_builder_errors_are_loud():
    with pytest.raises(schedule_lib.ScheduleError):
        # FFT along a sharded axis must fail at build time, not trace time
        schedule_lib.Schedule(
            "bad", -1, schedule_lib.layout_for(PENCIL, "natural"),
            (schedule_lib.Stage("bad", fft_axis=1),))
    with pytest.raises(schedule_lib.ScheduleError):
        # transposing over a communicator the concat dim is not sharded by
        schedule_lib.Schedule(
            "bad", -1, schedule_lib.layout_for(PENCIL, "natural"),
            (schedule_lib.Stage("bad", comm_axis="model", split_axis=0,
                                concat_axis=1),))


# --- effective-K reporting (the executor's chunk-indivisible fallback) -------

def test_effective_k_reports_fallback():
    sched = build_schedule(PENCIL, FFTOptions())
    shape = (32, 32, 32)
    # divisible: every comm stage runs at the requested K
    assert sched.effective_k(shape, SIZES, 2) == (2, 2, 2, 2)
    assert sched.effective_k(shape, SIZES, 4) == (4, 4, 4, 4)
    # K=16 fits only the stages chunked along x (local extent 16), not
    # those chunked along z (local 8) — per-stage, not all-or-nothing
    assert sched.effective_k(shape, SIZES, 16) == (1, 16, 16, 1)
    cell = build_schedule(CELL, FFTOptions())
    abc = {"a": 2, "b": 2, "c": 2}
    assert cell.effective_k((8, 8, 8), abc, 3) == (1,) * 6
    assert cell.effective_k((8, 8, 8), abc, 2) == (2,) * 6


def test_chunk_fallback_matches_k1_numerics():
    """K not dividing the chunk axes must silently fall back per stage and
    still produce the identical transform (cell validate does not gate
    overlap chunking, so this path is reachable)."""
    run_multidevice("""
import numpy as np, jax, jax.numpy as jnp
from repro.core import Croft3D, Decomposition, FFTOptions
from repro.core.distributed import build_schedule
mesh = jax.make_mesh((2,2,2), ("a","b","c"),
                     axis_types=(jax.sharding.AxisType.Auto,)*3)
dec = Decomposition("cell", ("a","b","c"))
N = 8
sched = build_schedule(dec, FFTOptions(overlap_k=3))
ks = sched.effective_k((N,N,N), dict(mesh.shape), 3)
assert ks == (1,)*6, ks          # every stage falls back
rng = np.random.RandomState(0)
x = (rng.randn(N,N,N) + 1j*rng.randn(N,N,N)).astype(np.complex64)
outs = {}
for k in (1, 3):
    plan = Croft3D((N,N,N), mesh, dec, FFTOptions(overlap_k=k))
    xd = jax.device_put(jnp.asarray(x), plan.input_sharding)
    outs[k] = np.asarray(plan.forward(xd))
assert np.array_equal(outs[1], outs[3])   # identical op graph -> bitwise
ref = np.fft.fftn(x)
assert np.max(np.abs(outs[3] - ref)) / np.abs(ref).max() < 1e-5
print("OK chunk fallback == K=1")
""")


# --- cost model walks the schedule ------------------------------------------

def test_cost_model_counts_derive_from_schedule():
    shape = (32, 32, 32)
    mk = lambda dec, **kw: tuning.Candidate(dec, FFTOptions(**kw))
    for cand, n_transposes in [
            (mk(PENCIL), 4),
            (mk(PENCIL, output_layout="spectral"), 2),
            (mk(Decomposition("slab", ("model",))), 2),
            (tuning.Candidate(PENCIL, FFTOptions(output_layout="spectral"),
                              problem="r2c", strategy="packed"), 3),
    ]:
        from repro.tuning.cost_model import schedule_for
        sched = schedule_for(shape, cand)
        assert sched.transpose_count() == n_transposes
        events = sched.comm_events(shape, SIZES)
        assert len(events) == n_transposes
        cost = tuning.analytic_cost(shape, cand, SIZES)
        assert cost.collective_bytes == float(
            sum(ev["bytes"] for ev in events))
    # cell: regroup + pencil natural (4) + scatter = 6 transposes (the
    # old hand-derived model charged 8 — the schedule knows better)
    from repro.tuning.cost_model import schedule_for
    cell = tuning.Candidate(CELL, FFTOptions())
    assert schedule_for(shape, cell).transpose_count() == 6


def test_cost_model_packed_slab_candidate():
    """The packed-slab strategy is enumerated on 1-axis meshes, halves the
    volume terms, and is modeled cheaper than the embedding at scale.

    Unlike the pencil case, packed-slab does not halve *collective*
    bytes (one half-volume transpose + the half-volume z-localizing
    reshard equal the embedding's single full-volume transpose), so its
    win comes from compute/memory — latency-dominated small shapes stay
    with the embedding, exactly what a schedule-derived model shows.
    """
    sizes = {"p": 8}
    cands = tuning.enumerate_candidates((64,) * 3, sizes, problem="r2c")
    packed = [c for c in cands if c.strategy == "packed"]
    assert packed and all(c.decomp.kind == "slab" for c in packed)
    slab = Decomposition("slab", ("p",))
    mk = lambda strat: tuning.Candidate(
        slab, FFTOptions(output_layout="spectral"), problem="r2c",
        strategy=strat)
    p = tuning.analytic_cost((64,) * 3, mk("packed"), sizes)
    e = tuning.analytic_cost((64,) * 3, mk("embed"), sizes)
    assert p.flops == e.flops / 2
    assert p.local_bytes == e.local_bytes / 2
    assert p.collective_bytes == e.collective_bytes
    big_p = tuning.analytic_cost((256,) * 3, mk("packed"), sizes)
    big_e = tuning.analytic_cost((256,) * 3, mk("embed"), sizes)
    assert big_p.total_s < big_e.total_s


def test_cost_model_chunk_fallback_disables_overlap_bonus():
    """A K that no stage can honor must be modeled as unoverlapped."""
    big = (256, 256, 256)
    dec = PENCIL
    k1 = tuning.analytic_cost(big, tuning.Candidate(
        dec, FFTOptions(overlap_k=1)), SIZES)
    k2 = tuning.analytic_cost(big, tuning.Candidate(
        dec, FFTOptions(overlap_k=2)), SIZES)
    # 3 does not divide the 64/128-sized chunk extents: falls back
    k3 = tuning.analytic_cost(big, tuning.Candidate(
        dec, FFTOptions(overlap_k=3)), SIZES)
    assert k2.total_s < k1.total_s
    assert k3.total_s == pytest.approx(k1.total_s)


def test_cost_model_batch_scales_volume_not_launches():
    cand = tuning.Candidate(PENCIL, FFTOptions())
    b1 = tuning.analytic_cost((32,) * 3, cand, SIZES, batch=1)
    b8 = tuning.analytic_cost((32,) * 3, cand, SIZES, batch=8)
    assert b8.flops == 8 * b1.flops
    assert b8.local_bytes == 8 * b1.local_bytes
    assert b8.collective_bytes == 8 * b1.collective_bytes
    assert b8.n_collectives == b1.n_collectives
    assert b8.latency_s == b1.latency_s


# --- wisdom batch dimension --------------------------------------------------

def test_wisdom_key_batch_dimension():
    k1 = tuning.wisdom_key((32,) * 3, SIZES, jnp.complex64, "cpu")
    kb = tuning.wisdom_key((32,) * 3, SIZES, jnp.complex64, "cpu", batch=8)
    assert kb == k1 + "|b8"
    # batch=1 keeps the legacy format: wisdom written before the batch
    # dimension existed still hits ("old keys parse as b1")
    assert tuning.wisdom_key((32,) * 3, SIZES, jnp.complex64, "cpu",
                             batch=1) == k1
    kr = tuning.wisdom_key((32,) * 3, SIZES, jnp.complex64, "cpu", "r2c", 4)
    assert kr.endswith("|r2c|b4")


def test_tune_batch_threads_through(tmp_path):
    path = str(tmp_path / "w.json")
    r1 = tuning.tune((32,) * 3, axis_sizes=SIZES, mode="model",
                     wisdom_path=path)
    rb = tuning.tune((32,) * 3, axis_sizes=SIZES, mode="model", batch=8,
                     wisdom_path=path)
    assert rb.key == r1.key + "|b8"
    # both keys recorded independently
    w = tuning.Wisdom.load(path)
    assert w.lookup(r1.key) is not None and w.lookup(rb.key) is not None


# --- pairwise-transpose rejection (satellite) --------------------------------

def test_pairwise_rejected_for_folded_and_cell():
    folded = Decomposition("pencil", (("a", "b"), "c"))
    sizes = {"a": 2, "b": 2, "c": 2}
    folded.validate((32,) * 3, sizes)  # fine with the fused all_to_all
    with pytest.raises(ValueError, match="pairwise"):
        folded.validate((32,) * 3, sizes, 1, "pairwise")
    with pytest.raises(ValueError, match="folded"):
        CELL.validate((32,) * 3, sizes, 1, "pairwise")
    assert not CELL.is_valid((32,) * 3, sizes, 1, "pairwise")
    # single-axis slab/pencil stay valid with pairwise
    SLAB.validate((32,) * 3, {"p": 8}, 1, "pairwise")
    # candidate generation never emits pairwise for cell meshes
    cands = tuning.enumerate_candidates((32,) * 3, sizes,
                                        include_baselines=True)
    for c in cands:
        if c.opts.transpose_impl == "pairwise":
            assert c.decomp.kind != "cell"
            assert all(not isinstance(a, tuple) for a in c.decomp.axes)


# --- transpose impls: alltoall / ring / pairwise -----------------------------

def test_transpose_impls_bitwise_identical():
    """The three global-transpose impls (and both chunk emission modes)
    are pure data-movement variants: every (impl, K, mode) point must
    produce the *bitwise identical* transform — across pencil, slab and
    cell, c2c and packed r2c, including the K-chunked pipelined path
    (K=3's chunk-indivisible fallback is covered by
    ``test_chunk_fallback_matches_k1_numerics`` — pencil/slab validation
    rejects indivisible K at plan build)."""
    run_multidevice("""
import numpy as np, jax, jax.numpy as jnp
from repro.core import Croft3D, Decomposition, FFTOptions
N = 16
rng = np.random.RandomState(0)
xc = (rng.randn(N,N,N) + 1j*rng.randn(N,N,N)).astype(np.complex64)
xr = rng.randn(N,N,N).astype(np.float32)

def sweep(mesh, dec, impls, problem, xin, ref):
    outs = {}
    kw = dict(problem="r2c", strategy="packed") if problem == "r2c" else {}
    for impl in impls:
        for k in (1, 2, 4):
            for mode in ("pipelined", "unrolled"):
                plan = Croft3D((N,N,N), mesh, dec,
                               FFTOptions(overlap_k=k, transpose_impl=impl,
                                          overlap_mode=mode), **kw)
                xd = jax.device_put(jnp.asarray(xin), plan.input_sharding)
                outs[(impl, k, mode)] = np.asarray(plan.forward(xd))
    base = outs[(impls[0], 1, "pipelined")]
    err = np.max(np.abs(base - ref)) / np.abs(ref).max()
    assert err < 1e-5, err
    for key, v in outs.items():
        assert np.array_equal(v, base), f"transform differs at {key}"

ALL = ("alltoall", "ring", "pairwise")
mesh2 = jax.make_mesh((2,4), ("y","z"),
                      axis_types=(jax.sharding.AxisType.Auto,)*2)
pencil = Decomposition("pencil", ("y","z"))
sweep(mesh2, pencil, ALL, "c2c", xc, np.fft.fftn(xc))
sweep(mesh2, pencil, ALL, "r2c", xr, np.fft.rfftn(xr))
mesh1 = jax.make_mesh((8,), ("p",),
                      axis_types=(jax.sharding.AxisType.Auto,))
slab = Decomposition("slab", ("p",))
sweep(mesh1, slab, ALL, "c2c", xc, np.fft.fftn(xc))
sweep(mesh1, slab, ALL, "r2c", xr, np.fft.rfftn(xr))
mesh3 = jax.make_mesh((2,2,2), ("a","b","c"),
                      axis_types=(jax.sharding.AxisType.Auto,)*3)
cell = Decomposition("cell", ("a","b","c"))
sweep(mesh3, cell, ("alltoall",), "c2c", xc, np.fft.fftn(xc))
# ring/pairwise over the cell's folded regroup communicator must be
# rejected at plan-build time, not fail inside shard_map
for impl in ("ring", "pairwise"):
    try:
        Croft3D((N,N,N), mesh3, cell, FFTOptions(transpose_impl=impl))
        raise AssertionError(f"cell + {impl} was not rejected")
    except ValueError:
        pass
print("OK transpose impls bitwise identical")
""", timeout=900)


def test_transpose_pack_kernels(rng):
    """rotate_blocks / pack_pieces / unpack_pieces: jnp fallback and the
    Pallas plane kernel agree with the roll reference, traced and
    concrete, and pack -> unpack round-trips the ring's permutation."""
    import jax
    import jax.numpy as jnp
    from repro.kernels import transpose_pack as tp

    x = (rng.randn(4, 24, 5) + 1j * rng.randn(4, 24, 5)).astype(np.complex64)
    p = 8
    for shift in (0, 1, 3, -2, 11):
        ref = np.roll(x, -(shift % p) * 3, axis=1)
        got = np.asarray(tp.rotate_blocks(jnp.asarray(x), 1, shift, p,
                                          use_pallas=False))
        np.testing.assert_array_equal(got, ref)
        ker = np.asarray(tp.rotate_blocks(jnp.asarray(x), 1, shift, p,
                                          use_pallas=True, interpret=True))
        np.testing.assert_array_equal(ker, ref)
    # traced shift (what shard_map's axis_index produces)
    f = jax.jit(lambda a, s: tp.rotate_blocks(a, 1, s, p, use_pallas=False))
    got = np.asarray(f(jnp.asarray(x), jnp.asarray(2)))
    np.testing.assert_array_equal(got, np.roll(x, -6, axis=1))

    # pack: piece s is the block bound for rank (idx + s) % p
    for idx in (0, 2, 7):
        pieces = tp.pack_pieces(jnp.asarray(x), 1, idx, p)
        assert len(pieces) == p
        for s, piece in enumerate(pieces):
            d = (idx + s) % p
            np.testing.assert_array_equal(np.asarray(piece),
                                          x[:, d * 3:(d + 1) * 3])
        # unpack: result block i = pieces[(i + shift) % p]
        out = np.asarray(tp.unpack_pieces(pieces, 1, -idx))
        rot = np.asarray(tp.rotate_blocks(jnp.concatenate(pieces, 1), 1,
                                          -idx, p, use_pallas=False))
        np.testing.assert_array_equal(out, rot)

    with pytest.raises(ValueError):
        tp.rotate_blocks(jnp.asarray(x), 1, 1, 7)  # 24 % 7 != 0


def test_fftoptions_overlap_knobs():
    o = FFTOptions(overlap_mode=("pipelined", "unrolled", "pipelined"),
                   transpose_impl="ring")
    assert o.stage_overlap(1) == "unrolled"
    assert o.stage_overlap(2) == "pipelined"
    # homogeneous tuples collapse (canonical wisdom-key form)
    assert FFTOptions(overlap_mode=("unrolled",) * 3).overlap_mode == "unrolled"
    with pytest.raises(ValueError, match="transpose_impl"):
        FFTOptions(transpose_impl="bruck")
    with pytest.raises(ValueError, match="overlap_mode"):
        FFTOptions(overlap_mode="eager")
    with pytest.raises(ValueError):
        FFTOptions(overlap_mode=("pipelined", "unrolled"))  # needs 3


def test_ring_rejected_for_folded_and_cell():
    folded = Decomposition("pencil", (("a", "b"), "c"))
    sizes = {"a": 2, "b": 2, "c": 2}
    with pytest.raises(ValueError, match="ring"):
        folded.validate((32,) * 3, sizes, 1, "ring")
    with pytest.raises(ValueError, match="folded"):
        CELL.validate((32,) * 3, sizes, 1, "ring")
    SLAB.validate((32,) * 3, {"p": 8}, 1, "ring")  # single axis: fine
    # the DEFAULT candidate space carries ring wherever it can trace —
    # and only there (no folded axes, no cell; on this 2-axis mesh that
    # is the single-axis pencil points)
    cands = tuning.enumerate_candidates((32,) * 3, SIZES)
    by_impl = {}
    for c in cands:
        by_impl.setdefault(c.opts.transpose_impl, []).append(c)
    assert "ring" in by_impl and "pairwise" not in by_impl
    for c in by_impl["ring"]:
        assert c.decomp.kind != "cell"
        assert all(not isinstance(a, tuple) for a in c.decomp.axes)


def test_cost_model_transpose_impl_split():
    """The alpha/beta split: ring pays K*(P-1) launches plus pack/unpack
    passes but overlaps its bandwidth term even at K=1; pairwise pays
    the same launches plus a serialized placement chain; alltoall keeps
    the legacy behaviour (one alpha per chunk, overlap only at K>=2).
    The ranking emerges from the terms — ring beats the unoverlapped
    alltoall once bytes dominate, and pairwise never wins."""
    sizes = SIZES
    mk = lambda impl, k=1: tuning.Candidate(PENCIL, FFTOptions(
        overlap_k=k, transpose_impl=impl, output_layout="spectral"))
    a1 = tuning.analytic_cost((128,) * 3, mk("alltoall"), sizes)
    r1 = tuning.analytic_cost((128,) * 3, mk("ring"), sizes)
    p1 = tuning.analytic_cost((128,) * 3, mk("pairwise"), sizes)
    # launch counts: 2 stages over (data=2, model=4) -> a2a 2, ring/pw
    # (2-1) + (4-1) = 4 ppermute rounds
    assert a1.n_collectives == 2
    assert r1.n_collectives == 4 and p1.n_collectives == 4
    assert r1.transpose_overhead_s > 0 and p1.transpose_overhead_s > 0
    assert a1.transpose_overhead_s == 0
    # at 128^3 the overlapped ring beats the unoverlapped alltoall and
    # the serialized pairwise loses to both — no hardcoded preference,
    # pure arithmetic (at 32^3 the alpha terms flip ring below alltoall)
    assert r1.total_s < a1.total_s
    assert p1.total_s > a1.total_s
    small_r = tuning.analytic_cost((32,) * 3, mk("ring"), sizes)
    small_a = tuning.analytic_cost((32,) * 3, mk("alltoall"), sizes)
    assert small_r.total_s > small_a.total_s
    # ring launches scale with K; model ranks via the same terms
    r4 = tuning.analytic_cost((128,) * 3, mk("ring", 4), sizes)
    assert r4.n_collectives == 4 * 4
    # mode="model" ranks the ring candidates alongside everything else
    res = tuning.tune((128,) * 3, axis_sizes=sizes, mode="model")
    labels = [row["label"] for row in res.ranked]
    assert any("/ring" in l for l in labels)


# --- fused epilogue ----------------------------------------------------------

def test_with_epilogue_structure():
    sched = build_schedule(PENCIL, FFTOptions(output_layout="spectral"))
    fused = sched.with_epilogue(schedule_lib.SpectralScale())
    assert len(fused.epilogue) == 1
    assert "kscale[filter]" in fused.describe()
    assert fused.layout_out == sched.layout_out  # pointwise: layout kept
    # executor demands the operand
    with pytest.raises(schedule_lib.ScheduleError, match="filter"):
        schedule_lib.SpectralScale().apply(jnp.ones((2, 2, 2),
                                                    jnp.complex64),
                                           FFTOptions(), {}, 0)


def test_spectral_scale_helper_matches_reference(rng):
    from repro.kernels.spectral_scale import spectral_scale
    x = (rng.randn(4, 4, 8) + 1j * rng.randn(4, 4, 8)).astype(np.complex64)
    h = (rng.randn(4, 4, 8) + 1j * rng.randn(4, 4, 8)).astype(np.complex64)
    ref = 0.5 * x * h
    got = np.asarray(spectral_scale(jnp.asarray(x), jnp.asarray(h), 0.5,
                                    use_pallas=False))
    np.testing.assert_allclose(got, ref, atol=1e-6)
    ker = np.asarray(spectral_scale(jnp.asarray(x), jnp.asarray(h), 0.5,
                                    use_pallas=True, interpret=True))
    np.testing.assert_allclose(ker, ref, atol=1e-6)


# --- adjoint schedules (repro.grad) ------------------------------------------

def test_adjoint_mirrors_layouts_and_comm_volume():
    """The adjoint runs output-layout -> input-layout with the same
    transpose count and the same total moved bytes — the symbolic
    foundation under the ``_grad`` cost model and the backward-HLO
    mirror gate in ``benchmarks.train_bench``."""
    shape = (32, 32, 32)
    cases = [
        (build_schedule(PENCIL, FFTOptions()), SIZES),
        (build_schedule(PENCIL, FFTOptions(output_layout="spectral")),
         SIZES),
        (build_schedule(SLAB, FFTOptions()), {"p": 8}),
        (build_schedule(CELL, FFTOptions()), {"a": 2, "b": 2, "c": 2}),
        (build_packed_forward(PENCIL), SIZES),
        (build_packed_forward(SLAB), {"p": 8}),
    ]
    for sched, sizes in cases:
        adj = adjoint_schedule(sched)
        assert (adj.layout_in.partition_spec()
                == sched.layout_out.partition_spec()), sched.name
        assert (adj.layout_out.partition_spec()
                == sched.layout_in.partition_spec()), sched.name
        assert adj.transpose_count() == sched.transpose_count(), sched.name
        fwd_bytes = sum(ev["bytes"] for ev in sched.comm_events(shape, sizes))
        adj_bytes = sum(ev["bytes"] for ev in adj.comm_events(shape, sizes))
        assert adj_bytes == fwd_bytes, sched.name


def test_cost_model_grad_prices_forward_plus_adjoint():
    """``c2c_grad`` is modeled as the forward schedule plus its adjoint:
    exactly double every volume/launch term when the adjoint is an exact
    mirror (all c2c layouts), and strictly pricier-than-forward for the
    packed r2c pipeline (mirrored comm, halved-volume compute)."""
    shape = (64,) * 3
    for opts in (FFTOptions(), FFTOptions(output_layout="spectral")):
        b = tuning.analytic_cost(shape, tuning.Candidate(PENCIL, opts), SIZES)
        g = tuning.analytic_cost(
            shape, tuning.Candidate(PENCIL, opts, problem="c2c_grad"), SIZES)
        assert g.flops == 2 * b.flops
        assert g.collective_bytes == 2 * b.collective_bytes
        assert g.n_collectives == 2 * b.n_collectives
        assert g.total_s == pytest.approx(2 * b.total_s)
    spec = FFTOptions(output_layout="spectral")
    rb = tuning.analytic_cost(shape, tuning.Candidate(
        PENCIL, spec, problem="r2c", strategy="packed"), SIZES)
    rg = tuning.analytic_cost(shape, tuning.Candidate(
        PENCIL, spec, problem="r2c_grad", strategy="packed"), SIZES)
    assert rb.total_s < rg.total_s <= 2.5 * rb.total_s
    assert rg.collective_bytes == 2 * rb.collective_bytes


def test_per_stage_costs_grad_directions_and_launch_prediction():
    """``per_stage_costs`` rows for a ``_grad`` candidate split into fwd
    and bwd directions, and the bwd all-to-all launch prediction (one per
    effective-K chunk) mirrors the forward exactly — this is the number
    the training bench gates the compiled backward HLO against."""
    cand = tuning.Candidate(
        PENCIL, FFTOptions(output_layout="spectral", overlap_k=2),
        problem="c2c_grad")
    rows = tuning.per_stage_costs((32,) * 3, cand, SIZES)
    fwd = [r for r in rows if r["direction"] == "fwd"]
    bwd = [r for r in rows if r["direction"] == "bwd"]
    assert fwd and bwd and len(fwd) + len(bwd) == len(rows)
    launches = lambda rs: sum(int(r["k_eff"]) for r in rs
                              if r["collective_s"] > 0)
    # 2 transposes x K=2 chunks each way
    assert launches(fwd) == launches(bwd) == 4
    # non-grad candidates stay single-direction (back-compat)
    base = tuning.per_stage_costs(
        (32,) * 3, tuning.Candidate(PENCIL, FFTOptions()), SIZES)
    assert {r["direction"] for r in base} == {"fwd"}


def test_wisdom_key_grad_dimension():
    """``|grad`` is a key dimension like batch: appended last, after the
    problem and ``|b{B}`` slots, so forward wisdom never aliases a
    training-step entry and legacy keys are untouched."""
    base = tuning.wisdom_key((32,) * 3, SIZES, jnp.complex64, "cpu")
    kg = tuning.wisdom_key((32,) * 3, SIZES, jnp.complex64, "cpu",
                           "c2c_grad")
    assert kg == base + "|grad"
    kr = tuning.wisdom_key((32,) * 3, SIZES, jnp.complex64, "cpu",
                           "r2c_grad", 4)
    assert kr.endswith("|r2c|b4|grad")
    assert tuning.wisdom_key((32,) * 3, SIZES, jnp.complex64, "cpu",
                             "r2c", 4) == kr[: -len("|grad")]


def test_ring_adjoint_collective_permute_rounds():
    """Ring-transpose pullback: the compiled backward issues exactly the
    forward's collective-permute count — K*(P_axis-1) rounds summed over
    stages — i.e. the custom VJP replays the ring schedule rather than
    letting XLA invent a different (or impossible) transpose."""
    run_multidevice("""
import jax, jax.numpy as jnp
from repro.core import Croft3D, Decomposition, FFTOptions
from repro.launch import hlo_cost
mesh = jax.make_mesh((2,4), ("data","model"),
                     axis_types=(jax.sharding.AxisType.Auto,)*2)
dec = Decomposition("pencil", ("data","model"))
N, K = 16, 2
plan = Croft3D((N,N,N), mesh, dec,
               FFTOptions(output_layout="spectral", transpose_impl="ring",
                          overlap_k=K))
x = jax.device_put(jnp.zeros((N,N,N), jnp.complex64), plan.input_sharding)

def counts(fn, *args):
    txt = jax.jit(fn).lower(*args).compile().as_text()
    return {k: int(v["count"])
            for k, v in hlo_cost.analyze(txt).collectives.items()}

fwd = counts(plan._fwd, x)
y, pull = jax.vjp(plan._fwd, x)
bwd = counts(pull, jnp.ones_like(y))
# spectral pencil: one ring stage over data (P=2), one over model (P=4)
expect = K * (2 - 1) + K * (4 - 1)
assert fwd.get("collective-permute", 0) == expect, fwd
assert bwd == fwd, (fwd, bwd)
print("OK ring adjoint rounds", expect)
""", timeout=900)
