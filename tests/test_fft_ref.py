"""Local FFT implementations vs numpy and the naive O(N^2) DFT."""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import local_fft as lf
from repro.core import plan as plan_lib
from repro.kernels.ref import ref_fft_1d_naive


@pytest.mark.parametrize("n", [2, 8, 64, 128, 512, 4096, 16384])
@pytest.mark.parametrize("impl", ["matmul", "stockham"])
def test_fft_1d_matches_numpy(n, impl, rng):
    x = (rng.randn(3, n) + 1j * rng.randn(3, n)).astype(np.complex64)
    fn = lf.fft_matmul if impl == "matmul" else lf.fft_stockham
    y = np.asarray(fn(jnp.asarray(x)))
    ref = np.fft.fft(x, axis=-1)
    np.testing.assert_allclose(y, ref, rtol=0, atol=2e-4 * np.abs(ref).max())


@pytest.mark.parametrize("n", [8, 32])
def test_fft_matches_naive_dft(n, rng):
    """Independent of any library FFT."""
    x = (rng.randn(2, n) + 1j * rng.randn(2, n)).astype(np.complex64)
    y = np.asarray(lf.fft_matmul(jnp.asarray(x)))
    ref = ref_fft_1d_naive(x)
    np.testing.assert_allclose(y, ref, atol=1e-3)


@pytest.mark.parametrize("n", [64, 1024])
def test_inverse_roundtrip(n, rng):
    x = (rng.randn(2, n) + 1j * rng.randn(2, n)).astype(np.complex64)
    y = lf.fft_matmul(jnp.asarray(x), -1)
    xb = np.asarray(lf.fft_matmul(y, +1)) / n
    np.testing.assert_allclose(xb, x, atol=1e-4)


def test_plan_cache_and_rematerialized_agree(rng):
    x = (rng.randn(2, 256) + 1j * rng.randn(2, 256)).astype(np.complex64)
    a = np.asarray(lf.fft_matmul(jnp.asarray(x), plan_cache=True))
    b = np.asarray(lf.fft_matmul(jnp.asarray(x), plan_cache=False))
    np.testing.assert_allclose(a, b, atol=2e-3)


def test_plan_factorization():
    for n in [2, 64, 128, 4096, 1 << 16, 1 << 19]:
        p = plan_lib.make_plan(n)
        assert p.n1 * p.n2 == n
        assert p.n1 <= plan_lib.MAX_RADIX
    with pytest.raises(ValueError):
        plan_lib.split_factors(100)  # not a power of two


def test_fft3d_local(rng):
    x = (rng.randn(8, 16, 32) + 1j * rng.randn(8, 16, 32)).astype(np.complex64)
    y = np.asarray(lf.fft3d_local(jnp.asarray(x)))
    ref = np.fft.fftn(x)
    np.testing.assert_allclose(y, ref, atol=2e-4 * np.abs(ref).max())
    # paper eq. (2): backward(forward(x)) == x with 1/(NxNyNz)
    xb = np.asarray(lf.fft3d_local(jnp.asarray(y), sign=+1, norm="backward"))
    np.testing.assert_allclose(xb, x, atol=2e-4 * np.abs(x).max())


def test_rfft3d_local(rng):
    from repro.core.rfft import rfft3d, irfft3d
    x = rng.randn(8, 4, 16).astype(np.float32)
    y = np.asarray(rfft3d(jnp.asarray(x)))
    ref = np.fft.rfftn(x)
    np.testing.assert_allclose(y, ref, atol=2e-4 * np.abs(ref).max())
    xb = np.asarray(irfft3d(jnp.asarray(y), 16))
    np.testing.assert_allclose(xb, x, atol=2e-4)
