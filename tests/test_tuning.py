"""Autotuning planner (repro.tuning): candidate generation, analytic cost
model, wisdom persistence, and end-to-end tuned plans on 8 virtual devices.

Everything except the final tuned-plan test runs meshless in this process
(the planner's mode="model"/"wisdom" paths are zero-execution by design).
"""

import dataclasses
import json
import os

import jax.numpy as jnp
import pytest

from conftest import run_multidevice
from repro.core import Decomposition, FFTOptions
from repro import tuning

SIZES = {"data": 2, "model": 4}
SHAPE = (32, 32, 32)


# --- candidate generation ---------------------------------------------------

def test_candidates_respect_divisibility():
    cands = tuning.enumerate_candidates(SHAPE, SIZES)
    assert cands, "search space must be non-empty for a divisible shape"
    for c in cands:
        # every emitted candidate revalidates cleanly
        c.decomp.validate(SHAPE, SIZES, c.opts.overlap_k)
    kinds = {c.decomp.kind for c in cands}
    assert kinds == {"slab", "pencil"}  # 2-axis mesh: no 3-slot cell


def test_candidates_reject_indivisible_shapes():
    # Ny=24 not divisible by the 4-sized axis in any pencil orientation
    # that also needs Nx % 4; slab over the folded 8 needs Nz % 8
    cands = tuning.enumerate_candidates((30, 30, 30), SIZES)
    assert cands == []
    # mixed: only configurations dividing 16 on the z axis survive
    ok = tuning.enumerate_candidates((32, 32, 16), SIZES)
    for c in ok:
        c.decomp.validate((32, 32, 16), SIZES, c.opts.overlap_k)


def test_candidates_cover_option_matrix():
    cands = tuning.enumerate_candidates(SHAPE, SIZES)
    ks = {c.opts.overlap_k for c in cands}
    impls = {c.opts.local_impl for c in cands}
    layouts = {c.opts.output_layout for c in cands}
    assert ks == {1, 2, 4}
    assert impls == {"matmul", "stockham", "xla"}
    assert layouts == {"natural", "spectral"}
    # production search space excludes the paper-baseline knobs (no-plan
    # caching, the pairwise FFTW3 emulation) but DOES carry the ring
    # transpose wherever it can trace — it is a real overlap strategy,
    # ranked by the cost model's alpha/beta split, not a baseline
    assert all(c.opts.plan_cache for c in cands)
    timpls = {c.opts.transpose_impl for c in cands}
    assert timpls == {"alltoall", "ring"}
    with_bases = tuning.enumerate_candidates(SHAPE, SIZES,
                                             include_baselines=True)
    assert any(not c.opts.plan_cache for c in with_bases)
    assert any(c.opts.transpose_impl == "pairwise" for c in with_bases)


def test_default_candidate_matches_mesh_rank():
    assert tuning.default_candidate(SHAPE, {"p": 8}).decomp.kind == "slab"
    assert tuning.default_candidate(SHAPE, SIZES).decomp.kind == "pencil"
    c3 = tuning.default_candidate(SHAPE, {"a": 2, "b": 2, "c": 2})
    assert c3.decomp.kind == "cell"


# --- analytic cost model ----------------------------------------------------

def test_cost_model_ranks_spectral_below_natural_on_comm_bytes():
    dec = Decomposition("pencil", ("data", "model"))
    nat = tuning.analytic_cost(
        SHAPE, tuning.Candidate(dec, FFTOptions(output_layout="natural")),
        SIZES)
    spec = tuning.analytic_cost(
        SHAPE, tuning.Candidate(dec, FFTOptions(output_layout="spectral")),
        SIZES)
    assert spec.collective_bytes == nat.collective_bytes / 2
    assert spec.total_s < nat.total_s


def test_cost_model_penalizes_pairwise_and_replan():
    dec = Decomposition("slab", ("model",))
    base = tuning.analytic_cost(
        SHAPE, tuning.Candidate(dec, FFTOptions(overlap_k=1)), SIZES)
    pair = tuning.analytic_cost(
        SHAPE, tuning.Candidate(
            dec, FFTOptions(overlap_k=1, transpose_impl="pairwise")), SIZES)
    noplan = tuning.analytic_cost(
        SHAPE, tuning.Candidate(
            dec, FFTOptions(overlap_k=1, plan_cache=False)), SIZES)
    assert pair.n_collectives > base.n_collectives
    assert pair.total_s > base.total_s
    assert noplan.replan_s > 0 and noplan.total_s > base.total_s


def test_cost_model_overlap_hides_communication():
    """At a comm-bound size, K>=2 must beat K=1 with the same knobs —
    the paper's central claim, reproduced by the model."""
    dec = Decomposition("pencil", ("data", "model"))
    big = (256, 256, 256)
    k1 = tuning.analytic_cost(
        big, tuning.Candidate(dec, FFTOptions(overlap_k=1)), SIZES)
    k2 = tuning.analytic_cost(
        big, tuning.Candidate(dec, FFTOptions(overlap_k=2)), SIZES)
    assert k2.total_s < k1.total_s


def test_rank_candidates_is_deterministic_and_sorted():
    cands = tuning.enumerate_candidates(SHAPE, SIZES)
    r1 = tuning.rank_candidates(SHAPE, cands, SIZES)
    r2 = tuning.rank_candidates(SHAPE, cands, SIZES)
    assert [c.label for c, _ in r1] == [c.label for c, _ in r2]
    totals = [b.total_s for _, b in r1]
    assert totals == sorted(totals)


# --- wisdom persistence -----------------------------------------------------

def test_wisdom_save_load_roundtrip(tmp_path):
    path = str(tmp_path / "wisdom.json")
    cand = tuning.Candidate(Decomposition("pencil", (("a", "b"), "c")),
                            FFTOptions(overlap_k=4, output_layout="spectral"))
    key = tuning.wisdom_key(SHAPE, {"a": 2, "b": 2, "c": 2},
                            jnp.complex64, "cpu")
    w = tuning.Wisdom(path=path)
    w.record(key, tuning.WisdomEntry.from_candidate(
        cand, "measure", model_s=1e-3, measured_s=5e-4))
    assert w.save() == path

    w2 = tuning.Wisdom.load(path)
    hit = w2.lookup(key)
    assert hit is not None and hit.measured_s == 5e-4
    got = hit.candidate()
    # nested folded axes survive the JSON round trip as tuples
    assert got.decomp == cand.decomp
    assert got.opts == cand.opts
    # file is plain JSON (exportable/mergeable)
    blob = json.load(open(path))
    assert blob["version"] == 1 and key in blob["entries"]


def test_wisdom_merge_prefers_faster_measurement():
    cand = tuning.Candidate(Decomposition("slab", ("p",)), FFTOptions())
    slow = tuning.WisdomEntry.from_candidate(cand, "measure", measured_s=2e-3)
    fast = tuning.WisdomEntry.from_candidate(
        dataclasses.replace(cand, opts=FFTOptions(overlap_k=4)),
        "measure", measured_s=1e-3)
    a, b = tuning.Wisdom(), tuning.Wisdom()
    a.record("k", slow)
    b.record("k", fast)
    a.merge(b)
    assert a.lookup("k").measured_s == 1e-3
    # modeled entries never displace measured ones
    modeled = tuning.WisdomEntry.from_candidate(cand, "model", model_s=1e-9)
    a.record("k", modeled)
    assert a.lookup("k").measured_s == 1e-3


def test_wisdom_mode_skips_measurement(tmp_path, monkeypatch):
    """mode="wisdom" with a hit must not compile or time anything."""
    path = str(tmp_path / "w.json")
    cand = tuning.Candidate(Decomposition("pencil", ("data", "model")),
                            FFTOptions(output_layout="spectral"))
    key = tuning.wisdom_key(SHAPE, SIZES, jnp.complex64, "any")
    w = tuning.Wisdom(path=path)
    w.record(key, tuning.WisdomEntry.from_candidate(
        cand, "measure", measured_s=1e-3))
    w.save()

    def boom(*a, **k):
        raise AssertionError("measurement ran on a wisdom hit")
    monkeypatch.setattr(tuning.measure, "measure_candidate", boom)
    monkeypatch.setattr(tuning.planner.measure, "measure_candidate", boom)

    r = tuning.tune(SHAPE, axis_sizes=SIZES, mode="wisdom", wisdom_path=path)
    assert r.source == "wisdom"
    assert r.decomp == cand.decomp and r.opts == cand.opts


def test_wisdom_miss_falls_back_to_model_and_records(tmp_path):
    path = str(tmp_path / "w.json")
    r = tuning.tune(SHAPE, axis_sizes=SIZES, mode="wisdom", wisdom_path=path)
    assert r.source == "model"          # miss -> ESTIMATE
    r2 = tuning.tune(SHAPE, axis_sizes=SIZES, mode="wisdom", wisdom_path=path)
    assert r2.source == "wisdom"        # and the estimate was remembered
    assert r2.decomp == r.decomp and r2.opts == r.opts


def test_tune_model_mode_needs_no_devices():
    r = tuning.tune(SHAPE, axis_sizes=SIZES, mode="model")
    assert r.source == "model" and r.model_s > 0
    assert r.decomp.is_valid(SHAPE, SIZES, r.opts.overlap_k)
    with pytest.raises(ValueError):
        tuning.tune(SHAPE, axis_sizes=SIZES, mode="measure")  # needs mesh
    with pytest.raises(ValueError):
        tuning.tune((30, 30, 30), axis_sizes=SIZES, mode="model")


# --- end to end on 8 virtual devices ---------------------------------------

def test_tuned_plan_roundtrip_and_wisdom(tmp_path):
    """Croft3D.tuned matches jnp.fft.fftn, beats-or-ties the default plan,
    and persists reusable wisdom."""
    wp = str(tmp_path / "wisdom.json")
    run_multidevice(f"""
import numpy as np, jax, jax.numpy as jnp
from repro.core import Croft3D, Decomposition, FFTOptions
from repro import tuning
mesh = jax.make_mesh((2,4), ("data","model"),
                     axis_types=(jax.sharding.AxisType.Auto,)*2)
N = 32
plan = Croft3D.tuned((N,N,N), mesh, mode="measure", wisdom_path={wp!r},
                     top_k=3, measure_iters=3)
print("chosen:", plan.tune_result.summary())
rng = np.random.RandomState(3)
x = (rng.randn(N,N,N) + 1j*rng.randn(N,N,N)).astype(np.complex64)
xd = jax.device_put(jnp.asarray(x), plan.input_sharding)
y = plan.forward(xd)
ref = jnp.fft.fftn(jnp.asarray(x))
err = float(jnp.max(jnp.abs(y - ref))) / float(jnp.max(jnp.abs(ref)))
assert err < 1e-5, err
xb = plan.inverse(y)
rerr = float(jnp.max(jnp.abs(xb - x)))
assert rerr < 1e-4, rerr

# measured winner is no slower than the hand-picked default plan
dflt = Croft3D((N,N,N), mesh, Decomposition("pencil", ("data","model")),
               FFTOptions())
t_dflt = tuning.time_forward(dflt, warmup=2, iters=3)
assert plan.tune_result.measured_s <= t_dflt * 1.25, (
    plan.tune_result.measured_s, t_dflt)

# the tune= constructor arg reuses the stored wisdom (no re-measuring)
plan2 = Croft3D((N,N,N), mesh, tune="wisdom", wisdom_path={wp!r})
assert plan2.tune_result.source == "wisdom"
assert plan2.decomp == plan.decomp and plan2.opts == plan.opts
y2 = plan2.forward(jax.device_put(jnp.asarray(x), plan2.input_sharding))
assert float(jnp.max(jnp.abs(y2 - y))) == 0.0
print("OK tuned roundtrip err", err, "rerr", rerr)
""", timeout=900)


# --- canonical plan keys (serve plan cache / wisdom) -------------------------

def test_decomposition_token_roundtrip():
    for dec in (Decomposition("slab", ("model",)),
                Decomposition("pencil", ("data", "model")),
                Decomposition("pencil", (("pod", "data"), "model")),
                Decomposition("cell", ("a", "b", "c"))):
        tok = dec.to_token()
        assert Decomposition.from_token(tok) == dec, tok


def test_fftoptions_token_roundtrip():
    for opts in (FFTOptions(),
                 FFTOptions(overlap_k=4, local_impl="stockham",
                            output_layout="spectral", transpose_impl="ring"),
                 FFTOptions(local_impl=("matmul", "stockham", "xla"),
                            overlap_mode=("pipelined", "unrolled",
                                          "unrolled")),
                 FFTOptions(plan_cache=False, overlap_k=1)):
        tok = opts.to_token()
        assert FFTOptions.from_token(tok) == opts, tok


def test_candidate_plan_key_roundtrip_covers_every_knob():
    """plan_key must round trip exactly — including the per-stage
    3-tuples and the r2c strategy axis — so the serving cache can never
    alias two different executables under one key."""
    cands = tuning.enumerate_candidates(
        SHAPE, SIZES, include_baselines=True, heterogeneous_impls=True)
    cands += tuning.enumerate_candidates(SHAPE, SIZES, problem="r2c")
    assert len({c.plan_key for c in cands}) == len(set(cands))
    for c in cands:
        back = tuning.Candidate.from_plan_key(c.plan_key)
        assert back == c, c.plan_key


def test_grad_candidates_mirror_base_space():
    """``c2c_grad``/``r2c_grad`` reuse the base search space knob-for-knob
    (the adjoint is derived, never searched) with only the problem tag
    changed."""
    base = tuning.enumerate_candidates(SHAPE, SIZES)
    grad = tuning.enumerate_candidates(SHAPE, SIZES, problem="c2c_grad")
    assert [(c.decomp, c.opts) for c in grad] \
        == [(c.decomp, c.opts) for c in base]
    assert all(c.problem == "c2c_grad" for c in grad)
    rbase = tuning.enumerate_candidates(SHAPE, SIZES, problem="r2c")
    rgrad = tuning.enumerate_candidates(SHAPE, SIZES, problem="r2c_grad")
    assert [(c.decomp, c.opts, c.strategy) for c in rgrad] \
        == [(c.decomp, c.opts, c.strategy) for c in rbase]
    assert {c.strategy for c in rgrad} == {"embed", "packed"}
    d = tuning.default_candidate(SHAPE, SIZES, problem="r2c_grad")
    assert d is not None and d.problem == "r2c_grad"


def test_grad_plan_keys_roundtrip_and_reject_unknown_problems():
    """Grad plan keys round trip (including strategy=None, which must not
    serialize as the string "None"), and an unknown problem tag is a loud
    ValueError — a stale or foreign wisdom entry becomes a miss upstream,
    never a misparsed plan."""
    cands = (tuning.enumerate_candidates(SHAPE, SIZES, problem="c2c_grad")
             + tuning.enumerate_candidates(SHAPE, SIZES, problem="r2c_grad"))
    assert len({c.plan_key for c in cands}) == len(set(cands))
    for c in cands:
        assert tuning.Candidate.from_plan_key(c.plan_key) == c, c.plan_key
    good = cands[0].plan_key
    with pytest.raises(ValueError, match="unknown problem"):
        tuning.Candidate.from_plan_key(good.replace("c2c_grad", "c2c_hess"))
    # and a grad entry survives the wisdom JSON round trip as a real
    # candidate (so `wisdom show`/`stats` render it, not <unreadable>)
    entry = tuning.WisdomEntry.from_candidate(cands[-1], "measure",
                                              measured_s=1e-3)
    back = tuning.WisdomEntry.from_json(
        json.loads(json.dumps(entry.to_json()))).candidate()
    assert back == cands[-1]


def test_tune_model_mode_grad_problem(tmp_path):
    """mode="model" prices fwd+adjoint for ``_grad`` problems, records
    under the ``|grad`` key, and the entry replays as a wisdom hit."""
    path = str(tmp_path / "w.json")
    r = tuning.tune(SHAPE, axis_sizes=SIZES, mode="model",
                    problem="c2c_grad", wisdom_path=path)
    assert r.key.endswith("|grad")
    base = tuning.tune(SHAPE, axis_sizes=SIZES, mode="model")
    assert r.key != base.key
    hit = tuning.Wisdom.load(path).lookup(r.key)
    assert hit is not None and hit.candidate().problem == "c2c_grad"
    r2 = tuning.tune(SHAPE, axis_sizes=SIZES, mode="wisdom",
                     problem="c2c_grad", wisdom_path=path)
    assert r2.source == "wisdom"
    assert r2.decomp == r.decomp and r2.opts == r.opts


def test_wisdom_cli_tolerates_grad_and_foreign_entries(tmp_path, capsys):
    """``wisdom show``/``stats`` must render ``|grad`` entries and
    survive an entry whose problem tag this version does not know (a
    newer or foreign wisdom file): unreadable at worst, never a crash."""
    from repro.tuning import wisdom as wisdom_lib
    path = str(tmp_path / "w.json")
    tuning.tune(SHAPE, axis_sizes=SIZES, mode="model", problem="r2c_grad",
                wisdom_path=path)
    with open(path) as f:
        blob = json.load(f)
    key, d = next(iter(blob["entries"].items()))
    assert key.endswith("|grad")
    blob["entries"][key.replace("|grad", "|hess")] = dict(d,
                                                          problem="c2c_hess")
    # a foreign writer would not maintain this version's integrity
    # checksum — drop it rather than ship a stale one (a *mismatching*
    # checksum means corruption and is quarantined; see test_resil.py)
    blob.pop("checksum", None)
    with open(path, "w") as f:
        json.dump(blob, f)
    assert wisdom_lib._main(["show", path]) == 0
    assert wisdom_lib._main(["stats", path]) == 0
    out = capsys.readouterr().out
    assert "|grad" in out


# --- calibrated collective constants -----------------------------------------

def test_collective_constants_calibration_precedence(tmp_path, monkeypatch):
    """(alpha, beta) precedence: live obs-registry gauges > the
    ``$CROFT_CALIBRATION`` JSON > hardcoded roofline constants; a
    non-positive fit is ignored rather than trusted."""
    from repro.obs import metrics as metrics_lib
    from repro.tuning import cost_model
    reg = metrics_lib.get_registry()
    ga = reg.gauge("collective_alpha_s")
    gb = reg.gauge("collective_beta_s_per_byte")
    old = (ga.value, gb.value)
    ga.set(0.0)
    gb.set(0.0)
    monkeypatch.delenv(cost_model.CALIBRATION_ENV, raising=False)
    try:
        assert cost_model.collective_constants() == (
            cost_model.COLLECTIVE_LATENCY_S, 1.0 / cost_model.LINK_BW)
        path = str(tmp_path / "calibration.json")
        with open(path, "w") as f:
            json.dump({"collective_alpha_s": 3e-6,
                       "collective_beta_s_per_byte": 2e-11}, f)
        monkeypatch.setenv(cost_model.CALIBRATION_ENV, path)
        assert cost_model.collective_constants() == (3e-6, 2e-11)
        ga.set(5e-6)
        gb.set(-1.0)  # degenerate lstsq fit: must fall through
        assert cost_model.collective_constants() == (5e-6, 2e-11)
        # the calibrated constants actually move the model
        base = tuning.analytic_cost(SHAPE, tuning.Candidate(
            Decomposition("pencil", ("data", "model")), FFTOptions()), SIZES)
        ga.set(5e-3)
        slow = tuning.analytic_cost(SHAPE, tuning.Candidate(
            Decomposition("pencil", ("data", "model")), FFTOptions()), SIZES)
        assert slow.latency_s > base.latency_s
    finally:
        ga.set(old[0])
        gb.set(old[1])


def test_candidate_label_distinguishes_overlap_mode():
    """Regression: the planner's measured={label: t} dict used to alias
    candidates differing only in overlap_mode."""
    a = tuning.Candidate(Decomposition("pencil", ("data", "model")),
                         FFTOptions(overlap_mode="pipelined"))
    b = tuning.Candidate(Decomposition("pencil", ("data", "model")),
                         FFTOptions(overlap_mode="unrolled"))
    assert a.label != b.label
