"""repro.obs: tracer, metrics registry, instrumentation guarantees.

The three load-bearing claims of the observability subsystem:

  * the tracer is safe under concurrent emission (the serve worker,
    plan-cache upgrade threads, and clients share one ring buffer);
  * histogram quantiles are honest (pinned against numpy within the
    log-bucket growth factor; exact for explicit-bounds histograms);
  * instrumentation is zero-cost when disabled — enabling the tracer
    must not change compiled HLO (pinned byte-for-byte in an
    8-virtual-device subprocess).
"""

import json
import math
import threading
import time

import numpy as np
import pytest

from repro import obs
from repro.obs import tracer as tracer_lib
from repro.serve import TransformService
from conftest import run_multidevice


@pytest.fixture
def tracer():
    """A recording tracer installed globally, restored afterwards."""
    prev = obs.get_tracer()
    tr = tracer_lib.Tracer()
    obs.set_tracer(tr)
    yield tr
    obs.set_tracer(prev)


# --- tracer -----------------------------------------------------------------

def test_noop_tracer_is_default_and_allocation_free():
    tr = obs.get_tracer()
    assert tr is obs.NOOP and not tr.enabled
    # one shared null context manager: no per-span allocation when disabled
    assert tr.span("a", "fft") is tr.span("b", "collective")
    assert tr.events() == []
    tr.instant("x")
    tr.complete("x", "fft", 0.0, 1.0)
    assert tr.events() == []


def test_span_nesting_and_error_capture(tracer):
    with tracer.span("outer", "plan", plan="p"):
        with tracer.span("inner", "fft") as sp:
            sp.set(chunk=3)
    with pytest.raises(ValueError):
        with tracer.span("boom", "collective"):
            raise ValueError("nope")
    evs = {e["name"]: e for e in tracer.events()}
    # inner closed before outer; both are complete events with args
    assert set(evs) == {"outer", "inner", "boom"}
    assert all(e["ph"] == "X" and e["dur"] >= 0 for e in evs.values())
    assert evs["inner"]["args"]["chunk"] == 3
    assert evs["outer"]["args"]["plan"] == "p"
    assert evs["boom"]["args"]["error"] == "ValueError"


def test_tag_scope_nests_and_restores(tracer):
    with obs.tag_scope(traffic="tuning"):
        with obs.tag_scope(plan="slab"):
            tracer.instant("in2", "plan")
        tracer.instant("in1", "plan")
    tracer.instant("out", "plan")
    evs = {e["name"]: e["args"] for e in tracer.events()}
    assert evs["in2"] == {"traffic": "tuning", "plan": "slab"}
    assert evs["in1"] == {"traffic": "tuning"}
    assert evs["out"] == {}


def test_tracer_thread_safety_under_concurrent_emission():
    """Worker + upgrade-thread shape: N threads race spans, instants, and
    retroactive completes into one tracer; every event lands, the buffer
    stays consistent."""
    tr = tracer_lib.Tracer(capacity=100_000)
    n_threads, n_each = 8, 200
    barrier = threading.Barrier(n_threads)

    def emitter(tid):
        barrier.wait()
        for i in range(n_each):
            with tr.span(f"t{tid}", "fft", i=i):
                pass
            tr.instant(f"t{tid}:i", "queue")
            t0 = time.monotonic()
            tr.complete(f"t{tid}:c", "collective", t0, t0 + 1e-4)

    threads = [threading.Thread(target=emitter, args=(t,))
               for t in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    evs = tr.events()
    assert len(evs) == n_threads * n_each * 3
    assert tr.dropped == 0
    per_thread = {}
    for e in evs:
        assert e["ph"] in ("X", "i") and e["ts"] >= 0
        per_thread[e["name"]] = per_thread.get(e["name"], 0) + 1
    for t in range(n_threads):
        assert per_thread[f"t{t}"] == n_each


def test_ring_buffer_caps_memory_and_counts_drops():
    tr = tracer_lib.Tracer(capacity=16)
    for i in range(40):
        tr.instant(f"e{i}", "plan")
    evs = tr.events()
    assert len(evs) == 16
    assert tr.dropped == 24
    assert [e["name"] for e in evs] == [f"e{i}" for i in range(24, 40)]
    assert tr.to_chrome()["metadata"]["dropped_events"] == 24


def test_chrome_trace_save_round_trip(tmp_path, tracer):
    with tracer.span("s", "fft", k=2):
        tracer.instant("i", "queue")
    tracer.add_meta("attribution", [{"plan": "p"}])
    path = tmp_path / "trace.json"
    tracer.save(str(path))
    doc = json.loads(path.read_text())
    assert set(doc) == {"traceEvents", "displayTimeUnit", "metadata"}
    assert doc["metadata"]["attribution"] == [{"plan": "p"}]
    for ev in doc["traceEvents"]:
        assert {"name", "cat", "ph", "pid", "tid", "ts"} <= set(ev)
        assert ev["cat"] in obs.CATEGORIES


def test_tracing_contextmanager_scopes_and_saves(tmp_path):
    path = tmp_path / "t.json"
    before = obs.get_tracer()
    with obs.tracing(str(path)) as tr:
        assert obs.get_tracer() is tr
        tr.instant("hello", "plan")
    assert obs.get_tracer() is before
    assert json.loads(path.read_text())["traceEvents"][0]["name"] == "hello"


# --- metrics ----------------------------------------------------------------

def test_counter_and_gauge_basics():
    reg = obs.MetricsRegistry()
    c = reg.counter("reqs", "requests")
    c.inc()
    c.inc(4)
    assert c.value == 5
    with pytest.raises(ValueError):
        c.inc(-1)
    g = reg.gauge("depth")
    g.set(3)
    g.inc()
    g.dec(2)
    assert g.value == 2
    # get-or-create returns the same object; kind mismatch is loud
    assert reg.counter("reqs") is c
    with pytest.raises(TypeError):
        reg.gauge("reqs")
    with pytest.raises(TypeError):
        reg.histogram("depth")


@pytest.mark.parametrize("dist", ["uniform", "lognormal"])
def test_histogram_quantiles_vs_numpy(dist):
    """Log-bucketed quantile estimates stay within one bucket growth
    factor of numpy's exact quantiles."""
    rng = np.random.RandomState(0)
    if dist == "uniform":
        xs = rng.uniform(1e-4, 1e-1, size=5000)
    else:
        xs = np.exp(rng.normal(loc=-6.0, scale=1.5, size=5000))
    growth = 1.4
    h = obs.Histogram("lat", growth=growth)
    for x in xs:
        h.observe(x)
    assert h.count == len(xs)
    assert math.isclose(h.sum, float(xs.sum()), rel_tol=1e-9)
    for q in (0.05, 0.25, 0.50, 0.90, 0.99):
        exact = float(np.quantile(xs, q))
        est = h.quantile(q)
        assert exact / growth <= est <= exact * growth, (
            f"{dist} q{q}: est {est} vs numpy {exact}")
    # clamped to observed extremes
    assert h.quantile(0.0) >= float(xs.min())
    assert h.quantile(1.0) <= float(xs.max())


def test_histogram_explicit_bounds_exact():
    h = obs.Histogram("batch", bounds=range(1, 9))
    for v, n in ((1, 3), (4, 2), (8, 1)):
        for _ in range(n):
            h.observe(v)
    # cumulative buckets diff back to the exact integer histogram
    per_size, prev = {}, 0
    for edge, cum in h.buckets()[:-1]:
        if cum > prev:
            per_size[int(edge)] = cum - prev
        prev = cum
    assert per_size == {1: 3, 4: 2, 8: 1}
    # a single-valued distribution reports that value at every quantile
    h1 = obs.Histogram("one", bounds=range(1, 9))
    for _ in range(10):
        h1.observe(4)
    assert h1.quantile(0.5) == 4 == h1.quantile(0.99)


def test_histogram_empty_and_overflow():
    h = obs.Histogram("x", bounds=[1.0, 2.0])
    assert h.quantile(0.5) is None
    h.observe(5.0)  # beyond the last edge -> +Inf bucket
    assert h.buckets()[-1] == (math.inf, 1)
    assert h.quantile(0.5) == 5.0  # clamped to observed max
    assert h.snapshot()["buckets"] == {"+Inf": 1}


def test_prometheus_exposition_format():
    reg = obs.MetricsRegistry()
    reg.counter("serve_requests", "served").inc(3)
    reg.gauge("queue-depth").set(2)  # name sanitized for prometheus
    h = reg.histogram("lat_s", bounds=[0.1, 1.0])
    h.observe(0.05)
    h.observe(0.5)
    text = reg.to_prometheus()
    assert "# TYPE serve_requests counter" in text
    assert "serve_requests 3" in text
    assert "queue_depth 2" in text
    lines = [ln for ln in text.splitlines() if ln.startswith("lat_s_bucket")]
    assert lines == ['lat_s_bucket{le="0.1"} 1', 'lat_s_bucket{le="1"} 2',
                     'lat_s_bucket{le="+Inf"} 2']
    assert "lat_s_count 2" in text
    # snapshot is JSON-able and mirrors the same objects
    snap = reg.snapshot()
    json.dumps(snap)
    assert snap["serve_requests"]["value"] == 3
    assert snap["lat_s"]["count"] == 2


# --- serve lifecycle --------------------------------------------------------

def test_serve_lifecycle_ordering_ragged_batch(tracer):
    """3 coalesced requests pad to 4: every result's timestamps satisfy
    submit <= dispatch <= resolve, lifecycle spans land in the trace,
    and the padding-waste counter sees the ragged batch's dead row."""
    rng = np.random.RandomState(0)
    xs = [(rng.randn(8, 8, 8) + 1j * rng.randn(8, 8, 8)).astype(np.complex64)
          for _ in range(3)]
    with TransformService(max_batch=4, max_wait_ms=100.0) as svc:
        futs = [svc.submit(x) for x in xs]
        results = [f.result(timeout=120) for f in futs]
        stats = svc.stats()
    assert all(r.ok for r in results)
    for r in results:
        assert 0.0 < r.t_submit <= r.t_dispatch <= r.t_done
        assert math.isclose(r.latency_s, r.t_done - r.t_submit, rel_tol=1e-6)

    # registry is the source of truth; stats() is the compat view over it
    reg = svc.registry
    assert reg.counter("serve_requests").value == 3
    real = reg.counter("serve_real_rows").value
    padded = reg.counter("serve_padded_rows").value
    waste = reg.counter("serve_padding_waste_rows").value
    assert waste == padded - real > 0  # 3 rows padded to 4: one dead slot
    assert stats["requests"] == 3
    assert stats["padding_waste_rows"] == waste
    assert sum(stats["batch_hist"].values()) == stats["batches"]
    assert sum(k * v for k, v in stats["batch_hist"].items()) == real
    assert stats["latency_ms"]["p50"] is not None
    prom = reg.to_prometheus()
    assert "serve_requests 3" in prom

    # lifecycle spans: per request, the queue span runs from submit to
    # dispatch on one monotonic clock
    evs = tracer.events()
    by_name = {}
    for e in evs:
        by_name.setdefault(e["name"], []).append(e)
    assert len(by_name["request:submit"]) == 3
    assert len(by_name["request:queue"]) == 3
    assert by_name["batch:dispatch"] and by_name["batch:compute"]
    assert by_name["batch:h2d"] and by_name["batch:d2h"]
    submit_ts = {e["args"]["req_id"]: e["ts"]
                 for e in by_name["request:submit"]}
    dispatch_end = max(d["ts"] + d["dur"] for d in by_name["batch:dispatch"])
    for q in by_name["request:queue"]:
        rid = q["args"]["req_id"]
        # queue span starts at submit (the submit instant fires just
        # after the enqueue) and ends before the dispatch span closes
        assert q["ts"] <= submit_ts[rid] + 1e4  # within 10ms bookkeeping
        assert q["ts"] + q["dur"] <= dispatch_end
        assert q["args"]["reason"] in ("full", "deadline", "drain")
    assert {d["args"]["n"] for d in by_name["batch:dispatch"]} == {3}


def test_service_stats_shape_unchanged_without_tracing():
    """The compat dict keeps its pre-obs keys with the noop tracer (the
    default): existing callers and benches keep working."""
    rng = np.random.RandomState(1)
    x = (rng.randn(8, 8, 8) + 1j * rng.randn(8, 8, 8)).astype(np.complex64)
    with TransformService(max_batch=2, max_wait_ms=2.0) as svc:
        assert svc.transform(x).shape == (8, 8, 8)
        stats = svc.stats()
    assert {"requests", "batches", "mean_batch", "real_rows", "padded_rows",
            "padding_waste_rows", "occupancy", "batch_hist", "pending",
            "latency_ms", "plan_cache"} <= set(stats)
    assert stats["requests"] == 1 and stats["pending"] == 0


# --- zero-cost + attribution (8 virtual devices) ----------------------------

def test_hlo_identical_with_tracing_and_attribution_reports():
    """The acceptance pin: enabling the tracer changes NOTHING in the
    compiled HLO (byte-identical), traced execution matches production
    output, and the report renders overlap efficiency for the
    alltoall-K2 and ring-K1 acceptance plans."""
    run_multidevice("""
import json, numpy as np, jax, jax.numpy as jnp
from repro import obs
from repro.core import Croft3D, Decomposition, FFTOptions
from repro.obs import instrument, report as report_lib
from repro.tuning.measure import _random_input

mesh = jax.make_mesh((2, 4), ("y", "z"))
N = 16
plans = {
    "alltoall-k2": Croft3D((N, N, N), mesh, Decomposition("pencil", ("y", "z")),
                           FFTOptions(overlap_k=2, transpose_impl="alltoall",
                                      output_layout="spectral")),
    "ring-k1": Croft3D((N, N, N), mesh, Decomposition("pencil", ("y", "z")),
                       FFTOptions(overlap_k=1, transpose_impl="ring",
                                  output_layout="spectral")),
}

# HLO pin: compile before enabling, then again with tracing live
hlo_off = {k: p.lower_forward().compile().as_text() for k, p in plans.items()}
tracer = obs.enable()
summaries = {}
for label, plan in plans.items():
    x = _random_input((N, N, N), jnp.complex64, plan.input_sharding)
    y, summary = instrument.trace_forward(plan, x, tracer=tracer, iters=2,
                                          label=label)
    np.testing.assert_allclose(np.asarray(jax.device_get(y)),
                               np.asarray(jax.device_get(plan.forward(x))),
                               rtol=2e-4, atol=2e-4)
    summaries[label] = summary
hlo_on = {k: p.lower_forward().compile().as_text() for k, p in plans.items()}
for label in plans:
    assert hlo_on[label] == hlo_off[label], (
        label + ": tracing changed the compiled HLO")

for label, s in summaries.items():
    assert s["overall"] is not None, label
    assert 0.0 <= s["overall"]["efficiency"] <= 1.0
    n_comm = sum(1 for row in s["stages"] if row["comm_s"] > 0)
    assert n_comm == 2, label  # pencil: two transposed stages
    for row in s["stages"]:
        assert row["model"] is not None  # joined against per_stage_costs
        assert row["hlo"].get("hlo_collectives", 0) >= 0

table = report_lib.render_plan(summaries["ring-k1"])
assert "overlap efficiency" in table and "ring-k1" in table
obs.disable()
print("OK")
""", n_devices=8)
