"""Distributed 3-D FFT == numpy fftn on an 8-virtual-device mesh.

These exercise real all_to_all/ppermute collectives on the CPU backend in a
subprocess (so the main test process keeps its single device).  One
subprocess per scenario group to amortize startup.
"""

import pytest

from conftest import run_multidevice

COMMON = """
import numpy as np, jax, jax.numpy as jnp
from repro.core import Croft3D, Decomposition, FFTOptions
rng = np.random.RandomState(42)
N = 32
x = (rng.randn(N,N,N) + 1j*rng.randn(N,N,N)).astype(np.complex64)
ref = np.fft.fftn(x)
scale = np.max(np.abs(ref))
def check(mesh, dec, opts, tag):
    plan = Croft3D((N,N,N), mesh, dec, opts)
    xd = jax.device_put(jnp.asarray(x), plan.input_sharding)
    y = plan.forward(xd)
    err = float(jnp.max(jnp.abs(y - ref))) / scale
    xb = plan.inverse(y)
    rerr = float(jnp.max(jnp.abs(xb - x)))
    assert err < 1e-5, (tag, err)
    assert rerr < 1e-4, (tag, rerr)
    print("OK", tag)
"""


def test_pencil_variants():
    run_multidevice(COMMON + """
mesh = jax.make_mesh((2,4), ("data","model"), axis_types=(jax.sharding.AxisType.Auto,)*2)
dec = Decomposition("pencil", ("data","model"))
check(mesh, dec, FFTOptions(overlap_k=1), "k1")
check(mesh, dec, FFTOptions(overlap_k=2), "k2 (CROFT default)")
check(mesh, dec, FFTOptions(overlap_k=4, plan_cache=False), "k4-noplan")
check(mesh, dec, FFTOptions(output_layout="spectral"), "spectral")
for opt in (1, 2, 3, 4):
    check(mesh, dec, FFTOptions.paper_option(opt), f"paper-option-{opt}")
""")


def test_local_impls_and_slab_cell():
    run_multidevice(COMMON + """
mesh = jax.make_mesh((2,4), ("data","model"), axis_types=(jax.sharding.AxisType.Auto,)*2)
dec = Decomposition("pencil", ("data","model"))
check(mesh, dec, FFTOptions(local_impl="stockham"), "stockham")
check(mesh, dec, FFTOptions(local_impl="xla"), "xla")
mesh8 = jax.make_mesh((8,), ("p",), axis_types=(jax.sharding.AxisType.Auto,))
sdec = Decomposition("slab", ("p",))
check(mesh8, sdec, FFTOptions(), "slab")
check(mesh8, sdec, FFTOptions(transpose_impl="pairwise"), "slab-pairwise(FFTW3-style)")
check(mesh8, sdec, FFTOptions(output_layout="spectral"), "slab-spectral")
mesh222 = jax.make_mesh((2,2,2), ("a","b","c"), axis_types=(jax.sharding.AxisType.Auto,)*3)
check(mesh222, Decomposition("cell", ("a","b","c")), FFTOptions(), "cell")
check(mesh222, Decomposition("pencil", (("a","b"),"c")), FFTOptions(), "pencil-folded")
""")


def test_non_cubic_grid():
    run_multidevice(COMMON + """
mesh = jax.make_mesh((2,4), ("data","model"), axis_types=(jax.sharding.AxisType.Auto,)*2)
dec = Decomposition("pencil", ("data","model"))
M = (64, 16, 8)
x2 = (rng.randn(*M) + 1j*rng.randn(*M)).astype(np.complex64)
plan = Croft3D(M, mesh, dec, FFTOptions())
xd = jax.device_put(jnp.asarray(x2), plan.input_sharding)
y = np.asarray(plan.forward(xd))
ref2 = np.fft.fftn(x2)
assert np.max(np.abs(y - ref2))/np.max(np.abs(ref2)) < 1e-5
print("OK non-cubic")
""")


def test_collective_counts_pencil_vs_pairwise():
    """Figs 12-15 analogue: pencil all-to-all needs far fewer collective
    ops than the FFTW3-style pairwise transpose."""
    run_multidevice(COMMON + """
import re
mesh8 = jax.make_mesh((8,), ("p",), axis_types=(jax.sharding.AxisType.Auto,))
sdec = Decomposition("slab", ("p",))
def count(opts):
    plan = Croft3D((N,N,N), mesh8, sdec, opts)
    txt = plan.lower_forward().compile().as_text()
    return (len(re.findall(r' all-to-all\\(', txt)),
            len(re.findall(r' collective-permute\\(', txt)))
a2a, cp = count(FFTOptions(overlap_k=1))
a2a_pw, cp_pw = count(FFTOptions(overlap_k=1, transpose_impl="pairwise"))
print("alltoall-impl:", a2a, cp, " pairwise-impl:", a2a_pw, cp_pw)
assert a2a >= 1 and cp == 0
assert cp_pw >= 7 * 2 and a2a_pw == 0   # (P-1) permutes per transpose
""")


def test_norm_roundtrips_pencil_slab_cell():
    """norm="ortho"/"backward" roundtrips and numpy parity across every
    decomposition kind (the normalization rides the schedule executor's
    output scaling, so each kind exercises its own stage list)."""
    run_multidevice("""
import numpy as np, jax, jax.numpy as jnp
from repro.core import Decomposition, FFTOptions, fft3d, ifft3d
from jax.sharding import NamedSharding
rng = np.random.RandomState(11)
N = 16
x = (rng.randn(N,N,N) + 1j*rng.randn(N,N,N)).astype(np.complex64)
meshes = {
  "pencil": (jax.make_mesh((2,4), ("y","z"),
             axis_types=(jax.sharding.AxisType.Auto,)*2),
             Decomposition("pencil", ("y","z"))),
  "slab": (jax.make_mesh((8,), ("p",),
           axis_types=(jax.sharding.AxisType.Auto,)),
           Decomposition("slab", ("p",))),
  "cell": (jax.make_mesh((2,2,2), ("a","b","c"),
           axis_types=(jax.sharding.AxisType.Auto,)*3),
           Decomposition("cell", ("a","b","c"))),
}
for kind, (mesh, dec) in meshes.items():
    xd = jax.device_put(jnp.asarray(x),
                        NamedSharding(mesh, dec.partition_spec()))
    for norm in ("ortho", "backward"):
        y = fft3d(xd, mesh, dec, FFTOptions(), norm=norm)
        ref = np.fft.fftn(x, norm=norm)
        err = float(jnp.max(jnp.abs(y - ref))) / np.abs(ref).max()
        xb = ifft3d(y, mesh, dec, FFTOptions(), norm=norm)
        rerr = float(jnp.max(jnp.abs(xb - x)))
        assert err < 1e-5, (kind, norm, err)
        assert rerr < 1e-4, (kind, norm, rerr)
        print("OK", kind, norm, err, rerr)
""", timeout=900)


def test_poisson_solver():
    run_multidevice("""
import numpy as np, jax, jax.numpy as jnp, math
from repro.core import Croft3D, Decomposition, FFTOptions, poisson_solve
mesh = jax.make_mesh((2,4), ("data","model"), axis_types=(jax.sharding.AxisType.Auto,)*2)
N = 32
plan = Croft3D((N,N,N), mesh, Decomposition("pencil", ("data","model")),
               FFTOptions(output_layout="spectral"))
# manufactured solution u = sin(x)cos(2y)sin(3z) => f = -(1+4+9) u
g = 2*math.pi*np.arange(N)/N
X, Y, Z = np.meshgrid(g, g, g, indexing="ij")
u = np.sin(X)*np.cos(2*Y)*np.sin(3*Z)
f = -(1+4+9)*u
ud = poisson_solve(jax.device_put(jnp.asarray(f, jnp.complex64), plan.input_sharding), plan)
err = float(jnp.max(jnp.abs(jnp.real(ud) - u)))
print("poisson err:", err)
assert err < 1e-4
""")


def test_double_precision_c128():
    """Paper §5: CROFT is implemented for double-precision complex; verify
    the c128 path at near-machine precision (the paper's 'exactly the
    same as FFTW3' claim is a double-precision claim)."""
    run_multidevice("""
import jax
jax.config.update("jax_enable_x64", True)
import numpy as np, jax.numpy as jnp
from repro.core import Croft3D, Decomposition, FFTOptions
mesh = jax.make_mesh((2,4), ("y","z"), axis_types=(jax.sharding.AxisType.Auto,)*2)
rng = np.random.RandomState(7)
N = 32
x = (rng.randn(N,N,N) + 1j*rng.randn(N,N,N)).astype(np.complex128)
plan = Croft3D((N,N,N), mesh, Decomposition("pencil", ("y","z")),
               FFTOptions(), dtype=jnp.complex128)
xd = jax.device_put(jnp.asarray(x), plan.input_sharding)
y = plan.forward(xd)
ref = np.fft.fftn(x)
err = float(jnp.max(jnp.abs(y - ref))) / np.abs(ref).max()
assert err < 1e-12, err
xb = plan.inverse(y)
rerr = float(jnp.max(jnp.abs(xb - x)))
assert rerr < 1e-11, rerr
print("c128 fwd relerr", err, "roundtrip", rerr)
""")
