"""Property-based tests of FFT invariants (hypothesis).

Linearity, Parseval energy conservation, the shift <-> phase-ramp theorem,
impulse -> constant spectrum, and forward/backward inversion — checked on
the matmul four-step implementation (the one the distributed pipeline
uses), sizes drawn from the power-of-two domain the paper restricts to.
"""

import numpy as np
import jax.numpy as jnp
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need the optional hypothesis dep")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import local_fft as lf

sizes = st.sampled_from([4, 8, 16, 64, 128, 256])
batches = st.integers(min_value=1, max_value=4)
seeds = st.integers(min_value=0, max_value=2 ** 31 - 1)


def _rand(seed, b, n):
    r = np.random.RandomState(seed)
    return (r.randn(b, n) + 1j * r.randn(b, n)).astype(np.complex64)


@settings(max_examples=25, deadline=None)
@given(seeds, batches, sizes)
def test_linearity(seed, b, n):
    x = _rand(seed, b, n)
    y = _rand(seed + 1, b, n)
    a = 0.7 - 0.2j
    lhs = np.asarray(lf.fft_matmul(jnp.asarray(a * x + y)))
    rhs = a * np.asarray(lf.fft_matmul(jnp.asarray(x))) \
        + np.asarray(lf.fft_matmul(jnp.asarray(y)))
    np.testing.assert_allclose(lhs, rhs, atol=3e-3 * max(1, np.abs(rhs).max()))


@settings(max_examples=25, deadline=None)
@given(seeds, sizes)
def test_parseval(seed, n):
    x = _rand(seed, 2, n)
    y = np.asarray(lf.fft_matmul(jnp.asarray(x)))
    e_time = np.sum(np.abs(x) ** 2, axis=-1)
    e_freq = np.sum(np.abs(y) ** 2, axis=-1) / n
    np.testing.assert_allclose(e_time, e_freq, rtol=1e-3)


@settings(max_examples=25, deadline=None)
@given(seeds, sizes, st.integers(min_value=0, max_value=63))
def test_shift_theorem(seed, n, shift):
    shift = shift % n
    x = _rand(seed, 1, n)
    y = np.asarray(lf.fft_matmul(jnp.asarray(np.roll(x, shift, axis=-1))))
    k = np.arange(n)
    ramp = np.exp(-2j * np.pi * k * shift / n)
    y0 = np.asarray(lf.fft_matmul(jnp.asarray(x))) * ramp
    np.testing.assert_allclose(y, y0, atol=3e-3 * max(1, np.abs(y0).max()))


@settings(max_examples=10, deadline=None)
@given(sizes)
def test_impulse_spectrum(n):
    x = np.zeros((1, n), np.complex64)
    x[0, 0] = 1.0
    y = np.asarray(lf.fft_matmul(jnp.asarray(x)))
    np.testing.assert_allclose(y, np.ones((1, n)), atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(seeds, batches, sizes)
def test_forward_backward_inversion(seed, b, n):
    x = _rand(seed, b, n)
    y = lf.fft_matmul(jnp.asarray(x), -1)
    xb = np.asarray(lf.fft_matmul(y, +1)) / n
    np.testing.assert_allclose(xb, x, atol=2e-3)


@settings(max_examples=15, deadline=None)
@given(seeds, sizes)
def test_real_input_hermitian_symmetry(seed, n):
    r = np.random.RandomState(seed)
    x = r.randn(1, n).astype(np.float32).astype(np.complex64)
    y = np.asarray(lf.fft_matmul(jnp.asarray(x)))[0]
    # Y[k] == conj(Y[-k mod n])
    mirrored = np.conj(np.roll(y[::-1], 1))
    np.testing.assert_allclose(y, mirrored, atol=3e-3 * max(1, np.abs(y).max()))
