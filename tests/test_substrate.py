"""Substrate tests: optimizer, data pipeline, checkpointing, loss, fault
tolerance, compression math."""

import os
import time

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.train.optimizer import (OptConfig, adamw_update, init_opt_state,
                                   schedule, global_norm)
from repro.train.data import SyntheticDataset, Prefetcher, synth_tokens
from repro.train.checkpoint import CheckpointManager
from repro.train.fault import StragglerMonitor, PreemptionHandler
from repro.parallel.loss import chunked_cross_entropy
from repro.parallel.compression import (compress_residual, dequantize_int8,
                                        quantize_int8, topk_densify,
                                        topk_sparsify)


# --------------------------------------------------------------------------
# optimizer
# --------------------------------------------------------------------------

def test_adamw_matches_reference(rng):
    cfg = OptConfig(lr=1e-2, warmup_steps=0, decay_steps=10**9,
                    weight_decay=0.0, clip_norm=0.0)
    p = {"w": jnp.asarray(rng.randn(4, 4).astype(np.float32))}
    g = {"w": jnp.asarray(rng.randn(4, 4).astype(np.float32))}
    st = init_opt_state(p, cfg)
    new_p, new_st, _ = adamw_update(p, g, st, cfg)
    # reference
    m = 0.1 * np.asarray(g["w"])
    v = 0.05 * np.asarray(g["w"]) ** 2
    mh, vh = m / 0.1, v / 0.05
    ref = np.asarray(p["w"]) - 1e-2 * mh / (np.sqrt(vh) + 1e-8)
    np.testing.assert_allclose(np.asarray(new_p["w"]), ref, atol=1e-5)
    assert int(new_st["step"]) == 1


def test_adamw_converges_quadratic():
    cfg = OptConfig(lr=0.1, warmup_steps=0, decay_steps=10**9,
                    weight_decay=0.0)
    p = {"w": jnp.asarray([5.0, -3.0])}
    st = init_opt_state(p, cfg)
    for _ in range(200):
        g = {"w": 2 * p["w"]}
        p, st, _ = adamw_update(p, g, st, cfg)
    assert float(jnp.max(jnp.abs(p["w"]))) < 0.05


def test_weight_decay_masked():
    cfg = OptConfig(lr=1e-2, warmup_steps=0, weight_decay=0.5, clip_norm=0.0)
    p = {"w": jnp.ones((2, 2)), "scale": jnp.ones((2,))}
    g = {"w": jnp.zeros((2, 2)), "scale": jnp.zeros((2,))}
    st = init_opt_state(p, cfg)
    new_p, _, _ = adamw_update(p, g, st, cfg)
    assert float(new_p["w"][0, 0]) < 1.0       # decayed
    assert float(new_p["scale"][0]) == 1.0     # 1-D spared


def test_schedule_warmup_cosine():
    cfg = OptConfig(lr=1.0, warmup_steps=10, decay_steps=110,
                    min_lr_ratio=0.1)
    assert float(schedule(cfg, jnp.asarray(0))) == 0.0
    assert abs(float(schedule(cfg, jnp.asarray(10))) - 1.0) < 1e-6
    assert abs(float(schedule(cfg, jnp.asarray(110))) - 0.1) < 1e-6


def test_grad_clip():
    cfg = OptConfig(lr=0.0, clip_norm=1.0, weight_decay=0.0)
    p = {"w": jnp.zeros((3,))}
    g = {"w": jnp.asarray([3.0, 4.0, 0.0])}
    st = init_opt_state(p, cfg)
    _, _, m = adamw_update(p, g, st, cfg)
    assert abs(float(m["grad_norm"]) - 5.0) < 1e-5
    assert abs(float(m["clip_scale"]) - 0.2) < 1e-5


def test_bf16_moments():
    cfg = OptConfig(moment_dtype="bfloat16")
    p = {"w": jnp.ones((2, 2))}
    st = init_opt_state(p, cfg)
    assert st["m"]["w"].dtype == jnp.bfloat16


# --------------------------------------------------------------------------
# data
# --------------------------------------------------------------------------

def test_data_deterministic():
    a = synth_tokens(7, 3, 4, 16, 1000)
    b = synth_tokens(7, 3, 4, 16, 1000)
    c = synth_tokens(7, 4, 4, 16, 1000)
    np.testing.assert_array_equal(a, b)
    assert not np.array_equal(a, c)
    assert a.min() >= 0 and a.max() < 1000


def test_data_zipfian_bias():
    t = synth_tokens(0, 0, 64, 256, 10_000)
    assert np.mean(t < 100) > 0.3  # mass concentrated at small ids


def test_data_learnable_structure():
    t = synth_tokens(0, 0, 16, 512, 1000)
    rep = np.mean(t[:, 1:] == t[:, :-1])
    assert rep > 0.15  # injected bigram structure


def test_prefetcher():
    ds = SyntheticDataset(100, 8, 2)
    pf = Prefetcher(iter(ds), depth=2)
    batches = [next(pf) for _ in range(3)]
    assert all(b["tokens"].shape == (2, 9) for b in batches)


# --------------------------------------------------------------------------
# checkpointing
# --------------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_write=False)
    tree = {"a": jnp.arange(6).reshape(2, 3),
            "nested": {"b": jnp.ones((4,), jnp.bfloat16)},
            "li": [jnp.zeros(2), jnp.ones(3)]}
    mgr.save(10, tree)
    out = mgr.restore(tree)
    np.testing.assert_array_equal(np.asarray(out["a"]), np.asarray(tree["a"]))
    assert out["nested"]["b"].dtype == jnp.bfloat16
    np.testing.assert_array_equal(np.asarray(out["li"][1]),
                                  np.asarray(tree["li"][1]))


def test_checkpoint_keep_k_and_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_write=False)
    tree = {"x": jnp.zeros(1)}
    for s in (1, 2, 3):
        mgr.save(s, {"x": jnp.full((1,), float(s))})
    assert mgr.all_steps() == [2, 3]
    out = mgr.restore(tree)
    assert float(out["x"][0]) == 3.0


def test_checkpoint_async(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=1, async_write=True)
    mgr.save(5, {"x": jnp.ones(4)})
    mgr.wait()
    assert mgr.latest_step() == 5


def test_checkpoint_shape_mismatch(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_write=False)
    mgr.save(1, {"x": jnp.ones(4)})
    with pytest.raises(ValueError, match="shape"):
        mgr.restore({"x": jnp.ones(5)})


def test_checkpoint_resume_bitexact(tmp_path):
    """restart-from-checkpoint reproduces the uninterrupted run."""
    from repro.configs import get_config
    from repro.train import OptConfig, init_train_state, make_train_step
    from repro.train.data import SyntheticDataset
    cfg = get_config("yi-9b", smoke=True)
    ocfg = OptConfig(lr=1e-3, warmup_steps=1, decay_steps=8)
    step_fn = make_train_step(cfg, ocfg, None, 2, kv_block=32, donate=False)
    ds = SyntheticDataset(cfg.vocab, 32, 2)

    state = init_train_state(jax.random.PRNGKey(0), cfg, ocfg, None)
    losses_full = []
    for i in range(4):
        state, m = step_fn(state, ds.batch_at(i))
        losses_full.append(float(m["loss"]))

    mgr = CheckpointManager(str(tmp_path), async_write=False)
    state2 = init_train_state(jax.random.PRNGKey(0), cfg, ocfg, None)
    for i in range(2):
        state2, _ = step_fn(state2, ds.batch_at(i))
    mgr.save(2, state2)
    state3 = mgr.restore(state2)
    losses_resumed = []
    for i in range(2, 4):
        state3, m = step_fn(state3, ds.batch_at(i))
        losses_resumed.append(float(m["loss"]))
    np.testing.assert_allclose(losses_resumed, losses_full[2:], rtol=1e-6)


# --------------------------------------------------------------------------
# loss
# --------------------------------------------------------------------------

def test_chunked_ce_matches_direct(rng):
    b, s, d, v = 2, 16, 8, 50
    hidden = jnp.asarray(rng.randn(b, s, d).astype(np.float32))
    head = jnp.asarray(rng.randn(d, v).astype(np.float32))
    labels = jnp.asarray(rng.randint(0, v, (b, s)))
    loss, metrics = chunked_cross_entropy(hidden, labels, head, n_chunks=4)
    logits = np.asarray(hidden @ head)
    lse = np.log(np.exp(logits - logits.max(-1, keepdims=True)).sum(-1)) \
        + logits.max(-1)
    lab = np.take_along_axis(logits, np.asarray(labels)[..., None],
                             -1)[..., 0]
    ref = np.mean(lse - lab)
    np.testing.assert_allclose(float(loss), ref, rtol=1e-5)
    assert int(metrics["n_tokens"]) == b * s


def test_chunked_ce_ignores_padding(rng):
    b, s, d, v = 1, 8, 4, 10
    hidden = jnp.asarray(rng.randn(b, s, d).astype(np.float32))
    head = jnp.asarray(rng.randn(d, v).astype(np.float32))
    labels = jnp.asarray([[1, 2, 3, -1, -1, -1, -1, -1]])
    _, metrics = chunked_cross_entropy(hidden, labels, head, n_chunks=2)
    assert int(metrics["n_tokens"]) == 3


# --------------------------------------------------------------------------
# fault tolerance
# --------------------------------------------------------------------------

def test_straggler_monitor_flags_outlier():
    mon = StragglerMonitor(z_threshold=3.0, warmup_steps=2)
    flagged = []
    mon.on_straggler = flagged.append
    for i in range(10):
        mon.start_step()
        mon._t0 -= 0.1  # simulate 100ms step
        mon.end_step(i)
    mon.start_step()
    mon._t0 -= 3.0      # 3s straggler
    st = mon.end_step(99)
    assert st.is_straggler and flagged and flagged[0].step == 99


def test_preemption_handler_flag():
    h = PreemptionHandler()
    assert not h.preemption_requested
    h._handle(15, None)
    assert h.preemption_requested


# --------------------------------------------------------------------------
# compression math
# --------------------------------------------------------------------------

def test_int8_quantize_bounds(rng):
    x = jnp.asarray(rng.randn(64).astype(np.float32) * 5)
    q, scale = quantize_int8(x)
    err = np.abs(np.asarray(dequantize_int8(q, scale)) - np.asarray(x))
    assert err.max() <= float(scale) * 0.5 + 1e-6


def test_error_feedback_conservation(rng):
    """q*scale + residual == input (+ carried residual) exactly."""
    x = jnp.asarray(rng.randn(32).astype(np.float32))
    res = jnp.asarray(rng.randn(32).astype(np.float32) * 0.01)
    q, scale, new_res = compress_residual(x, res)
    recon = np.asarray(dequantize_int8(q, scale)) + np.asarray(new_res)
    np.testing.assert_allclose(recon, np.asarray(x + res), atol=1e-6)


def test_topk_roundtrip(rng):
    x = jnp.asarray(rng.randn(100).astype(np.float32))
    vals, idx = topk_sparsify(x, 0.1)
    dense = np.asarray(topk_densify(vals, idx, (100,)))
    assert (dense != 0).sum() == 10
    top10 = np.argsort(-np.abs(np.asarray(x)))[:10]
    np.testing.assert_allclose(np.sort(dense[top10]),
                               np.sort(np.asarray(x)[top10]))
