"""Shared test helpers.

NOTE: no XLA_FLAGS here — unit tests and benches must see the real (single)
device.  Multi-device tests spawn subprocesses with
``--xla_force_host_platform_device_count`` set (see ``run_multidevice``).
"""

import os
import subprocess
import sys

import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")


def run_multidevice(code: str, n_devices: int = 8, timeout: int = 480) -> str:
    """Run a python snippet in a subprocess with N virtual CPU devices.
    Returns stdout; raises on nonzero exit."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=timeout)
    if proc.returncode != 0:
        raise AssertionError(
            f"subprocess failed (rc={proc.returncode})\n"
            f"--- stdout ---\n{proc.stdout}\n--- stderr ---\n{proc.stderr[-4000:]}")
    return proc.stdout


@pytest.fixture(scope="session")
def rng():
    return np.random.RandomState(0)
