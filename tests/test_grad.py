"""repro.grad correctness: adjoint-schedule gradients vs autodiff oracles.

The single-device entry points differentiate directly against ``jnp.fft``
autodiff (norm modes included).  The distributed matrix — problem x batch
x all three transpose impls — runs on 8 virtual devices and pins every
impl's gradient to the alltoall plan's gradient: the impls are
bitwise-identical forward, so their VJPs must agree to float tolerance,
even though the pairwise path is not XLA-differentiable at all (the
plan-level custom VJP is the only route through its
``optimization_barrier`` chain).  The folded-epilogue test is the formal
gate for the fused k-space multiply's adjoint placement.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from conftest import run_multidevice
from repro.core import fft3d, ifft3d, irfft3d, rfft3d


def _rel(a, b):
    den = max(float(jnp.max(jnp.abs(b))), 1e-30)
    return float(jnp.max(jnp.abs(a - b))) / den


# --- single-device oracles ---------------------------------------------------

@pytest.mark.parametrize("norm", [None, "ortho"])
def test_local_c2c_grads_match_jnp(rng, norm):
    n = 8
    x = jnp.asarray((rng.randn(n, n, n)
                     + 1j * rng.randn(n, n, n)).astype(np.complex64))
    ct = jnp.asarray((rng.randn(n, n, n)
                      + 1j * rng.randn(n, n, n)).astype(np.complex64))
    _, pull = jax.vjp(lambda v: fft3d(v, norm=norm), x)
    _, ref = jax.vjp(lambda v: jnp.fft.fftn(v, norm=norm), x)
    assert _rel(pull(ct)[0], ref(ct)[0]) < 1e-5
    inorm = norm or "backward"
    _, ipull = jax.vjp(lambda v: ifft3d(v, norm=inorm), x)
    _, iref = jax.vjp(lambda v: jnp.fft.ifftn(v, norm=inorm), x)
    assert _rel(ipull(ct)[0], iref(ct)[0]) < 1e-5


@pytest.mark.parametrize("norm", [None, "ortho"])
def test_local_r2c_grads_match_jnp(rng, norm):
    n = 8
    x = jnp.asarray(rng.randn(n, n, n).astype(np.float32))
    ct = jnp.asarray((rng.randn(n, n, n // 2 + 1)
                      + 1j * rng.randn(n, n, n // 2 + 1))
                     .astype(np.complex64))
    _, pull = jax.vjp(lambda v: rfft3d(v, norm=norm), x)
    _, ref = jax.vjp(lambda v: jnp.fft.rfftn(v, norm=norm), x)
    assert _rel(pull(ct)[0], ref(ct)[0]) < 1e-5
    y = jnp.fft.rfftn(x, norm=norm)
    ctr = jnp.asarray(rng.randn(n, n, n).astype(np.float32))
    _, ipull = jax.vjp(lambda v: irfft3d(v, n, norm=norm), y)
    _, iref = jax.vjp(lambda v: jnp.fft.irfftn(v, (n, n, n), norm=norm), y)
    assert _rel(ipull(ctr)[0], iref(ctr)[0]) < 1e-5


# --- distributed matrix: problem x batch x transpose impl --------------------

def test_distributed_grad_matrix():
    """Every transpose impl's plan-level gradient equals the alltoall
    plan's, c2c and packed r2c, single and vmapped-batch — the adjoint
    schedule is impl-agnostic data movement, so the grads must be too."""
    run_multidevice("""
import numpy as np, jax, jax.numpy as jnp
from repro.core import Croft3D, Decomposition, FFTOptions

N = 16
mesh = jax.make_mesh((2,4), ("data","model"),
                     axis_types=(jax.sharding.AxisType.Auto,)*2)
dec = Decomposition("pencil", ("data","model"))
rng = np.random.RandomState(0)
x1 = (rng.randn(N,N,N) + 1j*rng.randn(N,N,N)).astype(np.complex64)
xb = (rng.randn(2,N,N,N) + 1j*rng.randn(2,N,N,N)).astype(np.complex64)

def rel(a, b):
    return (float(jnp.max(jnp.abs(a - b)))
            / max(float(jnp.max(jnp.abs(b))), 1e-30))

for problem, kw in (("c2c", {}),
                    ("r2c", {"problem": "r2c", "strategy": "packed"})):
    for batch, x in ((1, x1), (2, xb)):
        grads = {}
        for impl in ("alltoall", "ring", "pairwise"):
            plan = Croft3D((N,N,N), mesh, dec,
                           FFTOptions(output_layout="spectral",
                                      transpose_impl=impl), **kw)
            xin = jnp.asarray(np.real(x) if problem == "r2c" else x,
                              plan.input_dtype)
            # pairwise has no batching rule (optimization_barrier), so
            # batch it unrolled — which also pins vmap batching of the
            # custom VJP against the unrolled reference
            if batch == 1:
                fwd = plan.forward
            elif impl == "pairwise":
                fwd = lambda v, f=plan.forward: jnp.stack(
                    [f(v[b]) for b in range(v.shape[0])])
            else:
                fwd = jax.vmap(plan.forward)
            def loss(v, fwd=fwd):
                y = fwd(v)
                return jnp.sum(jnp.real(y * jnp.conj(y)))
            grads[impl] = jax.jit(jax.grad(loss))(xin)
        for impl in ("ring", "pairwise"):
            r = rel(grads[impl], grads["alltoall"])
            assert r < 1e-4, (problem, batch, impl, r)
        print("OK", problem, "batch", batch)
print("OK distributed grad matrix")
""", timeout=900)


def test_distributed_norm_mode_grads():
    """Distributed functional entry points: VJPs match the jnp.fft oracle
    under both normalization conventions."""
    run_multidevice("""
import numpy as np, jax, jax.numpy as jnp
from repro.core import Decomposition, FFTOptions, fft3d, rfft3d

N = 16
mesh = jax.make_mesh((2,4), ("data","model"),
                     axis_types=(jax.sharding.AxisType.Auto,)*2)
dec = Decomposition("pencil", ("data","model"))
opts = FFTOptions(output_layout="spectral")
rng = np.random.RandomState(1)
x = jnp.asarray((rng.randn(N,N,N) + 1j*rng.randn(N,N,N))
                .astype(np.complex64))
ct = jnp.asarray((rng.randn(N,N,N) + 1j*rng.randn(N,N,N))
                 .astype(np.complex64))

def rel(a, b):
    return (float(jnp.max(jnp.abs(a - b)))
            / max(float(jnp.max(jnp.abs(b))), 1e-30))

for norm in (None, "ortho"):
    _, pull = jax.vjp(lambda v: fft3d(v, mesh, dec, opts, norm=norm), x)
    _, ref = jax.vjp(lambda v: jnp.fft.fftn(v, norm=norm), x)
    r = rel(pull(ct)[0], ref(ct)[0])
    assert r < 1e-4, ("c2c", norm, r)
    xr = jnp.real(x)
    ctr = ct[..., : N // 2 + 1]
    _, rpull = jax.vjp(lambda v: rfft3d(v, mesh, dec, opts,
                                        strategy="packed", norm=norm), xr)
    _, rref = jax.vjp(lambda v: jnp.fft.rfftn(v, norm=norm), xr)
    r = rel(rpull(ctr)[0], rref(ctr)[0])
    assert r < 1e-4, ("r2c", norm, r)
    print("OK norm", norm)
print("OK distributed norm grads")
""", timeout=900)


# --- folded spectral epilogue (satellite: fused-filter adjoint) --------------

def test_folded_filter_forward_and_grads_match_unfolded():
    """fold=True moves the k-space multiply before the DC/Nyquist unfold.
    For a compliant filter (kz-independent here: h(kz=0) == h(Nyquist)
    trivially, plane real and 2-D-even) the folded and unfolded
    pipelines are the same function of (x, g) — so outputs AND both
    gradients must agree, pinning the folded multiply's adjoint
    placement inside the packed schedule."""
    run_multidevice("""
import numpy as np, jax, jax.numpy as jnp
from repro.core import Croft3D, Decomposition, FFTOptions

N = 16
mesh = jax.make_mesh((2,4), ("data","model"),
                     axis_types=(jax.sharding.AxisType.Auto,)*2)
dec = Decomposition("pencil", ("data","model"))
plan = Croft3D((N,N,N), mesh, dec, FFTOptions(), problem="r2c",
               strategy="packed")
rng = np.random.RandomState(0)
x = jax.device_put(jnp.asarray(rng.randn(N,N,N), plan.input_dtype),
                   plan.input_sharding)
# 2-D-even real plane, tiled along kz: compliant for the folded path
neg = jnp.asarray((-np.arange(N)) % N)
g0 = rng.randn(N, N).astype(np.float32)
gj = jnp.asarray(0.5 * (g0 + g0[np.asarray(neg)][:, np.asarray(neg)]))

def loss(g, fold):
    # project onto the compliant manifold INSIDE the differentiated
    # function: fold==unfold only holds for compliant filters, so the
    # gradient comparison is only meaningful along compliant tangents
    ge = 0.5 * (g + g[neg][:, neg])
    h = jnp.broadcast_to(ge[:, :, None], plan.spectrum_shape)
    y = plan.forward_filtered(x, h, fold=fold)
    return jnp.sum(jnp.real(y * jnp.conj(y)))

h = jnp.broadcast_to(gj[:, :, None], plan.spectrum_shape)  # gj already even
y0 = plan.forward_filtered(x, h, fold=False)
y1 = plan.forward_filtered(x, h, fold=True)
rel_y = (float(jnp.max(jnp.abs(y1 - y0)))
         / float(jnp.max(jnp.abs(y0))))
assert rel_y < 1e-5, rel_y

l0, d0 = jax.value_and_grad(lambda g: loss(g, False))(gj)
l1, d1 = jax.value_and_grad(lambda g: loss(g, True))(gj)
assert abs(float(l1) - float(l0)) / abs(float(l0)) < 1e-5
rel_g = (float(jnp.max(jnp.abs(d1 - d0)))
         / max(float(jnp.max(jnp.abs(d0))), 1e-30))
assert rel_g < 1e-4, rel_g

# gradient w.r.t. the field agrees too (same linear operator both ways)
gx0 = jax.grad(lambda v: jnp.sum(jnp.real(
    (w := plan.forward_filtered(v, h, fold=False)) * jnp.conj(w))))(x)
gx1 = jax.grad(lambda v: jnp.sum(jnp.real(
    (w := plan.forward_filtered(v, h, fold=True)) * jnp.conj(w))))(x)
rel_x = (float(jnp.max(jnp.abs(gx1 - gx0)))
         / max(float(jnp.max(jnp.abs(gx0))), 1e-30))
assert rel_x < 1e-4, rel_x
print("OK folded filter fwd+grads", rel_y, rel_g, rel_x)
""", timeout=900)
